//! Gradient descent on [`leap::ops::ProjectionLoss`] — the operator
//! layer's "hello world": reconstruct Shepp-Logan from a fan-beam scan
//! using nothing but the loss value and its exact matched-adjoint
//! gradient, the way a training loop would consume the projector.
//!
//! ```bash
//! cargo run --release --example gradient_descent
//! ```
//!
//! This is deliberately the dumbest possible solver — a fixed `1.9/L`
//! step with a non-negativity clamp (projected gradient descent) — to
//! show that the *gradients* carry the reconstruction, not solver
//! tricks. With enough iterations it lands within 5% of SIRT's RMSE on
//! the same data (asserted below); SIRT's preconditioning only buys
//! speed.

use leap::geometry::{FanBeam, Geometry, VolumeGeometry};
use leap::metrics;
use leap::ops::{LinearOp, Objective, PlanOp, ProjectionLoss};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon::{self, fista_tv::power_iter_lipschitz_op, SirtOpts};

fn main() {
    // 1. a fan-beam Shepp-Logan scan (48² volume, 48 views, 64 columns)
    let vg = VolumeGeometry::slice2d(48, 48, 1.0);
    let g = FanBeam::standard(48, 64, 1.0, 120.0, 240.0);
    let p = Projector::new(Geometry::Fan(g), vg.clone(), Model::SF);
    let truth = shepp::shepp_logan_2d(20.0, 0.02).rasterize(&vg, 2);
    let y = p.forward(&truth);

    // 2. the scan as a LinearOp + a least-squares loss with exact grads
    let a = PlanOp::new(&p);
    let loss = ProjectionLoss::new(&a, &y.data, Objective::LeastSquares);

    // 3. plain projected gradient descent at a fixed step 1.9/L
    //    (stable for any step < 2/L on a convex least-squares objective)
    let lip = power_iter_lipschitz_op(&a, 20, 7).max(1e-12);
    let step = (1.9 / lip) as f32;
    let n = a.domain_shape().numel();
    let mut x = vec![0.0f32; n];
    let mut grad = vec![0.0f32; n];
    let iters = 2000;
    let t0 = std::time::Instant::now();
    for it in 0..iters {
        let l = loss.value_and_grad(&x, &mut grad);
        for i in 0..n {
            x[i] = (x[i] - step * grad[i]).max(0.0);
        }
        if it % 250 == 0 {
            println!("iter {it:4}  ½‖Ax−y‖² = {l:.5e}");
        }
    }
    let gd_time = t0.elapsed().as_secs_f64();

    // 4. SIRT on the same data as the reference solver
    let t0 = std::time::Instant::now();
    let sirt = recon::sirt(&p, &y, &p.new_vol(), &SirtOpts { iterations: 50, ..Default::default() });
    let sirt_time = t0.elapsed().as_secs_f64();

    let rmse_gd = metrics::rmse(&x, &truth.data);
    let rmse_sirt = metrics::rmse(&sirt.vol.data, &truth.data);
    println!("GD×{iters} (step 1.9/L): {gd_time:6.3}s  RMSE {rmse_gd:.6}");
    println!("SIRT×50               : {sirt_time:6.3}s  RMSE {rmse_sirt:.6}");
    assert!(
        rmse_gd <= 1.05 * rmse_sirt,
        "plain GD should land within 5% of SIRT's RMSE: {rmse_gd} vs {rmse_sirt}"
    );
    println!("plain gradient descent reaches SIRT-level RMSE (within 5%) — the matched");
    println!("adjoint, not the solver, carries the reconstruction.");
}
