//! Quickstart: simulate a scan, reconstruct it, check the numbers —
//! the 60-second tour of the library (paper Fig. 2's workflow), through
//! the typed `leap::api` front door: a builder-validated `Scan` whose
//! every operation returns `Result<_, LeapError>` instead of panicking.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use leap::api::{LeapError, ScanBuilder, Solver};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::ops::Objective;
use leap::phantom::shepp;
use leap::projector::Model;
use leap::recon::Window;

fn main() -> Result<(), LeapError> {
    // 1. describe the scan: 128² @ 1 mm voxels, 180 views over 180°,
    //    192-column detector at 1 mm pitch — everything quantitative (mm).
    //    build() validates the whole description and plans it once.
    let vg = VolumeGeometry::slice2d(128, 128, 1.0);
    let g = ParallelBeam::standard_2d(180, 192, 1.0);
    let scan = ScanBuilder::new()
        .geometry(Geometry::Parallel(g.clone()))
        .volume(vg.clone())
        .model(Model::SF)
        .build()?;

    // 2. a ground-truth phantom and its *analytic* sinogram (no inverse
    //    crime: line integrals of the continuous phantom)
    let phantom = shepp::shepp_logan_2d(55.0, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let sino = phantom.project(scan.geometry());
    println!("simulated {} views × {} bins", sino.nviews, sino.ncols);

    // 3. analytic reconstruction: FBP with a Hann-apodized ramp
    let t0 = std::time::Instant::now();
    let fbp = scan.solve(Solver::Fbp { window: Window::Hann }, &sino.data)?;
    println!(
        "FBP        : {:6.3}s  PSNR {:6.2} dB",
        t0.elapsed().as_secs_f64(),
        metrics::psnr(&fbp, &truth.data, None),
    );

    // 4. iterative reconstruction on the *matched* SF projector pair
    let t0 = std::time::Instant::now();
    let sirt =
        scan.solve(Solver::Sirt { iterations: 50, lambda: 1.0, nonneg: true }, &sino.data)?;
    println!(
        "SIRT×50    : {:6.3}s  PSNR {:6.2} dB",
        t0.elapsed().as_secs_f64(),
        metrics::psnr(&sirt, &truth.data, None),
    );

    // 5. the matched-pair property that makes gradients correct:
    //    ⟨Ax, y⟩ = ⟨x, Aᵀy⟩ — through the fallible forward/back pair
    let mut rng = leap::util::rng::Rng::new(1);
    let mut x = vec![0.0f32; scan.volume_len()];
    let mut y = vec![0.0f32; scan.sino_len()];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    rng.fill_uniform(&mut y, 0.0, 1.0);
    let lhs = leap::util::dot_f64(&scan.forward(&x)?, &y);
    let rhs = leap::util::dot_f64(&x, &scan.back(&y)?);
    println!(
        "adjoint    : ⟨Ax,y⟩={lhs:.4}  ⟨x,Aᵀy⟩={rhs:.4}  gap {:.2e}",
        (lhs - rhs).abs() / lhs.abs()
    );

    // 6. one exact gradient of ½‖Ax − b‖² through the matched adjoint —
    //    the hook a training loop calls thousands of times
    let mut grad = vec![0.0f32; scan.volume_len()];
    let loss = scan.loss_grad(Objective::LeastSquares, &sino.data, &x, &mut grad)?;
    let gnorm = grad.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    println!("loss/grad  : L = {loss:.4}, ‖∇L‖ = {gnorm:.4}");

    // 7. misuse is a typed error, not a panic: wrong buffer length …
    let err = scan.forward(&[1.0, 2.0]).unwrap_err();
    println!("typed error: {err} (wire code {})", err.code());
    // … and a degenerate scan description never builds
    let err = ScanBuilder::new()
        .geometry(Geometry::Parallel(g))
        .volume(VolumeGeometry::slice2d(128, 128, -1.0))
        .build()
        .unwrap_err();
    println!("typed error: {err} (wire code {})", err.code());

    // 8. if `make artifacts` has run, the same ops execute through the
    //    AOT-compiled JAX/Pallas path (Python is *not* running here)
    match leap::runtime::Engine::load("artifacts") {
        Ok(engine) if engine.spec.n == vg.nx => {
            let sino_art = engine.run1("fp_sf", &[&truth.data]).map_err(|e| {
                LeapError::Backend(format!("{e:#}"))
            })?;
            let native = scan.forward(&truth.data)?;
            let rel = leap::util::rel_l2(&sino_art, &native, 1e-12);
            println!("artifact   : fp_sf matches native SF (rel {rel:.2e})");
        }
        Ok(engine) => println!(
            "artifact   : spec n={} ≠ {} (rebuild with default config to compare)",
            engine.spec.n, vg.nx
        ),
        Err(_) => println!("artifact   : skipped (run `make artifacts`)"),
    }
    Ok(())
}
