//! Quickstart: simulate a scan, reconstruct it, check the numbers —
//! the 60-second tour of the library (paper Fig. 2's workflow, native).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;

fn main() {
    // 1. describe the scan: 128² @ 1 mm voxels, 180 views over 180°,
    //    192-column detector at 1 mm pitch — everything quantitative (mm)
    let vg = VolumeGeometry::slice2d(128, 128, 1.0);
    let g = ParallelBeam::standard_2d(180, 192, 1.0);

    // 2. a ground-truth phantom and its *analytic* sinogram (no inverse
    //    crime: line integrals of the continuous phantom)
    let phantom = shepp::shepp_logan_2d(55.0, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let sino = phantom.project(&Geometry::Parallel(g.clone()));
    println!("simulated {} views × {} bins", sino.nviews, sino.ncols);

    // 3. analytic reconstruction: FBP with a Hann-apodized ramp
    let t0 = std::time::Instant::now();
    let fbp = recon::fbp_parallel(&vg, &g, &sino, recon::Window::Hann, 1);
    println!(
        "FBP        : {:6.3}s  PSNR {:6.2} dB  SSIM {:.4}",
        t0.elapsed().as_secs_f64(),
        metrics::psnr(&fbp.data, &truth.data, None),
        metrics::ssim_vol(&fbp, &truth, None)
    );

    // 4. iterative reconstruction on the *matched* SF projector pair
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);
    let t0 = std::time::Instant::now();
    let sirt = recon::sirt(
        &p,
        &sino,
        &p.new_vol(),
        &recon::SirtOpts { iterations: 50, ..Default::default() },
    );
    println!(
        "SIRT×50    : {:6.3}s  PSNR {:6.2} dB  SSIM {:.4}",
        t0.elapsed().as_secs_f64(),
        metrics::psnr(&sirt.vol.data, &truth.data, None),
        metrics::ssim_vol(&sirt.vol, &truth, None)
    );

    // 5. the matched-pair property that makes gradients correct:
    //    ⟨Ax, y⟩ = ⟨x, Aᵀy⟩
    let mut rng = leap::util::rng::Rng::new(1);
    let mut x = p.new_vol();
    let mut y = p.new_sino();
    rng.fill_uniform(&mut x.data, 0.0, 1.0);
    rng.fill_uniform(&mut y.data, 0.0, 1.0);
    let lhs = leap::util::dot_f64(&p.forward(&x).data, &y.data);
    let rhs = leap::util::dot_f64(&x.data, &p.back(&y).data);
    println!(
        "adjoint    : ⟨Ax,y⟩={lhs:.4}  ⟨x,Aᵀy⟩={rhs:.4}  gap {:.2e}",
        (lhs - rhs).abs() / lhs.abs()
    );

    // 6. if `make artifacts` has run, the same ops execute through the
    //    AOT-compiled JAX/Pallas path (Python is *not* running here)
    match leap::runtime::Engine::load("artifacts") {
        Ok(engine) if engine.spec.n == vg.nx => {
            let sino_art = engine.run1("fp_sf", &[&truth.data]).unwrap();
            let native = p.forward(&truth);
            let rel = leap::util::rel_l2(&sino_art, &native.data, 1e-12);
            println!("artifact   : fp_sf matches native SF (rel {rel:.2e})");
        }
        Ok(engine) => println!(
            "artifact   : spec n={} ≠ {} (rebuild with default config to compare)",
            engine.spec.n, vg.nx
        ),
        Err(_) => println!("artifact   : skipped (run `make artifacts`)"),
    }
}
