//! Learned FBP — train the tape's trainable-filter pipeline on a
//! fan-beam Shepp-Logan scan and beat the hand-designed ramp FBP.
//!
//! ```bash
//! cargo run --release --example learned_fbp            # full budget
//! LEAP_TRAIN_SMOKE=1 cargo run --release --example learned_fbp  # CI smoke
//! ```
//!
//! The pipeline is `x̂ = g · Aᵀ( m ⊙ filter_w(b) )`
//! ([`leap::tape::learned_fbp`]): a learnable half-spectrum filter `w`
//! initialized to the analytic apodized ramp, learnable per-sample
//! sinogram weights `m` (room for the fan-beam cosine weighting the
//! analytic method hard-codes), and a learnable gain `g`. Training is
//! supervised — L2 against the rasterized phantom — with deterministic
//! Adam on exact matched-adjoint gradients, so every run of this example
//! produces bit-identical parameters and the asserted margin is stable.
//!
//! Asserted: within the fixed iteration budget the trained
//! reconstruction beats `recon::fbp_fan` (Hann window, the crate's
//! hand-rolled analytic baseline) by **≥ 5 % RMSE** on the training
//! scan — the tape's "trainable reconstruction" claim, end to end.

use leap::api::ScanBuilder;
use leap::geometry::{FanBeam, Geometry, VolumeGeometry};
use leap::metrics;
use leap::ops::LinearOp;
use leap::phantom::shepp;
use leap::projector::Model;
use leap::recon::{self, Window};
use leap::tape::{learned_fbp, FitCfg, Optimizer};
use leap::{Sino, StorageTier, Vol3};

fn main() {
    let smoke = std::env::var("LEAP_TRAIN_SMOKE").map(|v| v == "1").unwrap_or(false);
    // problem size and budget: fixed per mode, so the assertion below is
    // a deterministic gate, not a tuning suggestion
    let (n, nviews, ncols, iters) = if smoke { (32, 40, 48, 150) } else { (64, 60, 96, 400) };

    // 1. fan-beam Shepp-Logan scan
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let geom = Geometry::Fan(FanBeam::standard(nviews, ncols, 1.0, 150.0, 300.0));
    // pin f32 storage: the asserted RMSE margin is calibrated for exact
    // plan/sinogram storage, and a LEAP_STORAGE=bf16 environment must
    // not change what this gate measures
    let scan = ScanBuilder::new()
        .geometry(geom.clone())
        .volume(vg.clone())
        .model(Model::SF)
        .storage_tier(StorageTier::F32)
        .build()
        .expect("valid scan");
    let truth = shepp::shepp_logan_2d(n as f64 * 0.42, 0.02).rasterize(&vg, 2);
    let sino = scan.forward(&truth.data).expect("forward projection");

    // 2. the hand-designed baseline: analytic fan-beam FBP (Hann)
    let t0 = std::time::Instant::now();
    let sino_arr = Sino::from_vec(nviews, 1, ncols, sino.clone());
    let Geometry::Fan(fan) = &geom else { unreachable!() };
    let fbp: Vol3 = recon::fbp_fan(&vg, fan, &sino_arr, Window::Hann, 0);
    let fbp_time = t0.elapsed().as_secs_f64();
    let rmse_fbp = metrics::rmse(&fbp.data, &truth.data);

    // 3. the trainable version, initialized AT the analytic design
    let a = std::sync::Arc::new(leap::ops::PlanOp::from_plan(scan.plan().clone()))
        as std::sync::Arc<dyn LinearOp>;
    let mut pipe = learned_fbp(a, 1.0, Window::Hann).expect("learned fbp pipeline");
    let inputs: Vec<&[f32]> = vec![&sino, &truth.data];
    let before = pipe.loss(&inputs).expect("initial loss");
    let t0 = std::time::Instant::now();
    let report = scan
        .fit(
            &mut pipe,
            &inputs,
            &FitCfg { optimizer: Optimizer::adam(0.02), iterations: iters },
        )
        .expect("training runs");
    let train_time = t0.elapsed().as_secs_f64();
    let learned = pipe.eval(&inputs).expect("trained reconstruction");
    let rmse_learned = metrics::rmse(&learned, &truth.data);

    println!("fan-beam Shepp-Logan {n}×{n}, {nviews} views × {ncols} cols");
    println!(
        "analytic FBP (Hann ramp)     : {fbp_time:6.3}s            RMSE {rmse_fbp:.6}"
    );
    println!(
        "learned FBP  (Adam×{iters:4})   : {train_time:6.3}s train    RMSE {rmse_learned:.6}  \
         (loss {before:.4e} → {:.4e})",
        report.final_loss
    );
    let ratio = rmse_learned / rmse_fbp;
    println!(
        "learned/analytic RMSE ratio: {ratio:.4} (gate: ≤ 0.95 — trainable filter + weights + \
         gain must beat the hand-designed ramp by ≥ 5%)"
    );
    assert!(
        ratio <= 0.95,
        "learned FBP must beat analytic FBP RMSE by ≥ 5% within {iters} iterations: \
         {rmse_learned} vs {rmse_fbp}"
    );
    println!("OK — the learned pipeline beats the analytic design it was initialized from.");
}
