//! Cone-beam scenario: 3-D Shepp-Logan, circular flat-detector scan,
//! FDK reconstruction, and an SF-vs-Siddon accuracy comparison against
//! the analytic sinogram — the paper's second geometry type end-to-end.
//!
//! ```bash
//! cargo run --release --example cone_beam_fdk -- --n 48 --nviews 96
//! ```

use leap::geometry::{ConeBeam, Geometry, VolumeGeometry};
use leap::metrics;
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;
use leap::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 48);
    let nviews = args.usize_or("nviews", 96);
    let nrows = args.usize_or("nrows", n + 16);
    let ncols = args.usize_or("ncols", n + 32);

    let vg = VolumeGeometry::cube(n, 1.0);
    let g = ConeBeam::standard(nviews, nrows, ncols, 1.0, 1.0, 2.0 * n as f64, 4.0 * n as f64);
    println!(
        "cone-beam scan: {n}³ volume, {nviews} views × {nrows}×{ncols} detector, sod {} sdd {} (half cone angle {:.2}°)",
        g.sod,
        g.sdd,
        g.half_cone_angle().to_degrees()
    );

    let phantom = shepp::shepp_logan_3d(0.42 * n as f64, 0.02);
    let truth = phantom.rasterize(&vg, 2);

    // analytic measurement (continuous phantom — no inverse crime)
    let t0 = std::time::Instant::now();
    let sino = phantom.project(&Geometry::Cone(g.clone()));
    println!("analytic projection: {:.2}s", t0.elapsed().as_secs_f64());

    // accuracy of the discrete projectors vs the analytic sinogram (the
    // §2.1 accuracy ordering: SF ≥ Joseph ≥ Siddon on smooth data)
    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(Geometry::Cone(g.clone()), vg.clone(), model);
        let t0 = std::time::Instant::now();
        let fp = p.forward(&truth);
        let rel = leap::util::rel_l2(&fp.data, &sino.data, 1e-12);
        println!(
            "  {:<7} forward: {:.3}s  rel-err vs analytic {:.4}",
            model.name(),
            t0.elapsed().as_secs_f64(),
            rel
        );
    }

    // FDK reconstruction
    let t0 = std::time::Instant::now();
    let rec = recon::fdk(&vg, &g, &sino, recon::Window::Hann, 1);
    let dt = t0.elapsed().as_secs_f64();
    let psnr = metrics::psnr(&rec.data, &truth.data, None);
    let ssim = metrics::ssim_vol(&rec, &truth, None);
    println!("FDK: {dt:.2}s  PSNR {psnr:.2} dB  SSIM {ssim:.4} (central slice)");

    // iterative refinement of the FDK volume on the matched SF pair
    let p = Projector::new(Geometry::Cone(g.clone()), vg.clone(), Model::SF);
    let t0 = std::time::Instant::now();
    let sirt = recon::sirt(
        &p,
        &sino,
        &rec,
        &recon::SirtOpts { iterations: 10, ..Default::default() },
    );
    let psnr2 = metrics::psnr(&sirt.vol.data, &truth.data, None);
    println!(
        "FDK + SIRT×10 (warm start): {:.2}s  PSNR {psnr2:.2} dB",
        t0.elapsed().as_secs_f64()
    );

    if psnr2 <= psnr {
        println!("note: SIRT did not improve FDK here (short run)");
    }
}
