//! Learned unrolled CNN reconstruction — train the tape's ItNet-style
//! solver on a phantom corpus and beat FISTA-TV on held-out phantoms
//! at an equal iteration budget.
//!
//! ```bash
//! cargo run --release --example learned_unrolled            # full budget
//! LEAP_TRAIN_SMOKE=1 cargo run --release --example learned_unrolled  # CI smoke
//! ```
//!
//! The solver ([`leap::tape::unrolled_cnn`]) unrolls K iterations of
//! `x̃ = x − s_k·Aᵀ(Ax − b)` followed by a two-layer conv→relu→conv
//! residual correction, trained through the **exact** projector
//! adjoints. The second conv of every block starts at zero, so the
//! untrained pipeline is exactly projected gradient descent — training
//! can only improve on a known-good solver. Training data is a seeded
//! jittered Shepp-Logan corpus ([`leap::phantom::corpus`]); held-out
//! items come from disjoint per-item seeds and are never shown to the
//! optimizer. Mini-batch gradients aggregate bit-identically to a
//! sequential pass, and the [`leap::tape::Fitter`] checkpoint taken
//! mid-run resumes bit-for-bit (both asserted here).
//!
//! Asserted: on the held-out phantoms, the trained K-iteration solver's
//! mean RMSE beats K-iteration FISTA-TV (both start from zero, equal
//! projector budget).

use std::sync::Arc;

use leap::api::ScanBuilder;
use leap::geometry::{FanBeam, Geometry, VolumeGeometry};
use leap::metrics;
use leap::ops::LinearOp;
use leap::phantom::corpus::{Corpus, CorpusCfg, Family};
use leap::projector::Model;
use leap::recon::fista_tv::{fista_tv_op, power_iter_lipschitz_op, FistaOpts};
use leap::tape::{fit_batched, unrolled_cnn, BatchFitCfg, Fitter, Optimizer, UnrollCnnCfg};
use leap::StorageTier;

fn main() {
    let smoke = std::env::var("LEAP_TRAIN_SMOKE").map(|v| v == "1").unwrap_or(false);
    // fixed budgets per mode: the gate below is deterministic
    let (n, nviews, ncols, k_unroll, channels, count, epochs) =
        if smoke { (24, 30, 36, 4, 4, 6, 40) } else { (48, 60, 72, 6, 8, 16, 120) };

    // 1. fan-beam scan over the corpus grid (f32 storage pinned: the
    // gate is calibrated for exact plan/sinogram storage)
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let geom = Geometry::Fan(FanBeam::standard(nviews, ncols, 1.0, 150.0, 300.0));
    let scan = ScanBuilder::new()
        .geometry(geom)
        .volume(vg.clone())
        .model(Model::SF)
        .storage_tier(StorageTier::F32)
        .build()
        .expect("valid scan");
    let a: Arc<dyn LinearOp> = Arc::new(leap::ops::PlanOp::from_plan(scan.plan().clone()));

    // 2. seeded corpus: train on the head, hold out the tail
    let corpus = Corpus::new(
        CorpusCfg { family: Family::SheppJitter, count, test_frac: 0.25, ..CorpusCfg::default() },
        &vg,
        2024,
    )
    .expect("valid corpus");
    let make_item = |id: u64| -> Vec<Vec<f32>> {
        let truth = corpus.truth(id);
        let sino = a.apply(&truth.data);
        vec![sino, truth.data]
    };
    let train_items: Vec<Vec<Vec<f32>>> = corpus.train_ids().into_iter().map(make_item).collect();
    let test_items: Vec<Vec<Vec<f32>>> = corpus.test_ids().into_iter().map(make_item).collect();
    assert!(!test_items.is_empty(), "corpus must hold out items");

    // 3. the unrolled CNN solver, step sizes initialized at 1/L
    let lip = power_iter_lipschitz_op(a.as_ref(), 12, 1234).max(1e-12);
    let cfg = UnrollCnnCfg {
        iterations: k_unroll,
        step_init: (1.0 / lip) as f32,
        channels,
        ksize: 3,
        seed: 7,
    };
    let mut pipe = unrolled_cnn(a.clone(), &cfg).expect("unrolled cnn pipeline");

    // 4. train — two legs with a checkpoint in between, resumed into a
    // fresh pipeline to prove the save/restore path is bit-exact
    let t0 = std::time::Instant::now();
    let opt = Optimizer::adam(2e-3);
    let leg = |e: usize| BatchFitCfg { optimizer: opt, epochs: e, batch_size: 2, threads: 0 };
    let rep1 = fit_batched(&mut pipe, &train_items, &leg(epochs / 2)).expect("training leg 1");
    // NOTE: fit_batched starts a fresh Fitter, so the resume check
    // below replays leg 2 only — both sides share the checkpointed
    // parameters and a fresh optimizer state, keeping them comparable.
    let fitter = Fitter::new(&pipe, opt).expect("fitter");
    let ckpt = fitter.save(&pipe);
    let rep2 = fit_batched(&mut pipe, &train_items, &leg(epochs - epochs / 2))
        .expect("training leg 2");
    let train_time = t0.elapsed().as_secs_f64();

    // replay leg 2 from the checkpoint in a fresh pipeline: bit-identical
    let mut pipe_resume = unrolled_cnn(a.clone(), &cfg).expect("resume pipeline");
    let mut fit_resume = Fitter::new(&pipe_resume, opt).expect("resume fitter");
    fit_resume.restore(&mut pipe_resume, &ckpt).expect("restore checkpoint");
    let rep2b = fit_batched(&mut pipe_resume, &train_items, &leg(epochs - epochs / 2))
        .expect("resumed training");
    for (pa, pb) in pipe.params().iter().zip(pipe_resume.params().iter()) {
        let ba: Vec<u32> = pa.value.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = pb.value.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "checkpoint resume must be bit-identical ({})", pa.name);
    }
    assert_eq!(rep2.final_loss.to_bits(), rep2b.final_loss.to_bits());

    // 5. held-out evaluation vs FISTA-TV at the same iteration budget
    let fista_opts = FistaOpts { iterations: k_unroll, ..FistaOpts::default() };
    let zeros = vec![0.0f32; vg.nx * vg.ny * vg.nz];
    let (mut rmse_cnn, mut rmse_fista) = (0.0f64, 0.0f64);
    for item in &test_items {
        let (sino, truth) = (&item[0], &item[1]);
        let recon_cnn = pipe.eval(&[sino, truth]).expect("trained reconstruction");
        let recon_fista = fista_tv_op(a.as_ref(), sino, &zeros, &fista_opts);
        rmse_cnn += metrics::rmse(&recon_cnn, truth);
        rmse_fista += metrics::rmse(&recon_fista, truth);
    }
    rmse_cnn /= test_items.len() as f64;
    rmse_fista /= test_items.len() as f64;

    println!(
        "fan-beam jittered Shepp-Logan corpus: {n}×{n}, {nviews} views × {ncols} cols, \
         {} train / {} held-out",
        train_items.len(),
        test_items.len()
    );
    println!(
        "unrolled CNN (K={k_unroll}, c={channels}, Adam×{epochs} epochs): {train_time:6.1}s \
         train, loss {:.4e} → {:.4e}",
        rep1.initial_loss, rep2.final_loss
    );
    println!("held-out mean RMSE: unrolled CNN {rmse_cnn:.6}  vs  FISTA-TV(K={k_unroll}) {rmse_fista:.6}");
    let ratio = rmse_cnn / rmse_fista;
    println!(
        "cnn/fista RMSE ratio: {ratio:.4} (gate: < 1.0 — the trained solver must beat \
         FISTA-TV on phantoms it never saw, at an equal iteration budget)"
    );
    assert!(
        ratio < 1.0,
        "trained unrolled CNN must beat FISTA-TV on held-out phantoms: \
         {rmse_cnn} vs {rmse_fista}"
    );
    assert!(
        rep2.final_loss < rep1.initial_loss,
        "training must reduce the loss: {} → {}",
        rep1.initial_loss,
        rep2.final_loss
    );
    println!("OK — learned iterative reconstruction generalizes past its training set.");
}
