//! Matched vs unmatched projector pairs under long iteration — the §2.1
//! design argument: "our goal here is to implement methods that are stable
//! after over a thousand or more iterations, [so] we chose to implement
//! methods where the exact transpose is used."
//!
//! Runs SIRT-style iterations twice: once with the matched SF transpose,
//! once with the classic pixel-driven (unmatched) backprojector standing
//! in for Aᵀ (what "most reconstruction packages" use). Prints the data
//! residual over 1500 iterations: the matched pair keeps descending, the
//! unmatched one stalls/diverges.
//!
//! ```bash
//! cargo run --release --example matched_vs_unmatched
//! ```

use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::recon;

fn residual(p: &Projector, x: &leap::Vol3, y: &leap::Sino) -> f64 {
    let ax = p.forward(x);
    ax.data
        .iter()
        .zip(y.data.iter())
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

fn main() {
    let args = leap::util::cli::Args::from_env();
    let iters = args.usize_or("iters", 1500);
    let n = args.usize_or("n", 48);
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let g = ParallelBeam::standard_2d(60, n + 24, 1.0);
    let geo = Geometry::Parallel(g.clone());
    let p = Projector::new(geo, vg.clone(), Model::SF);
    let phantom = shepp::shepp_logan_2d(0.42 * n as f64, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let y = phantom.project(&Geometry::Parallel(g.clone()));

    // normalizations shared by both runs
    let row_sum = p.forward_ones();
    let inv_row: Vec<f32> =
        row_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let col_sum = p.back_ones();
    let inv_col_matched: Vec<f32> =
        col_sum.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();
    let bp_ones = {
        let mut s = p.new_sino();
        s.fill(1.0);
        recon::fbp::backproject_pixel_parallel(&vg, &g, &s, 1.0, 1)
    };
    let inv_col_unmatched: Vec<f32> =
        bp_ones.data.iter().map(|&v| if v > 1e-6 { 1.0 / v } else { 0.0 }).collect();

    let mut x_m = p.new_vol();
    let mut x_u = p.new_vol();
    let checkpoints = [1usize, 10, 50, 100, 250, 500, 1000, iters];
    println!("iter   matched-residual   unmatched-residual");
    for it in 1..=iters {
        // matched: x += Dv·Aᵀ(Dr·(y − Ax))
        let mut r = p.forward(&x_m);
        for i in 0..r.len() {
            r.data[i] = (y.data[i] - r.data[i]) * inv_row[i];
        }
        let g_m = p.back(&r);
        for i in 0..x_m.len() {
            x_m.data[i] = (x_m.data[i] + g_m.data[i] * inv_col_matched[i]).max(0.0);
        }
        // unmatched: same update with pixel-driven B ≠ Aᵀ
        let mut r = p.forward(&x_u);
        for i in 0..r.len() {
            r.data[i] = (y.data[i] - r.data[i]) * inv_row[i];
        }
        let g_u = recon::fbp::backproject_pixel_parallel(&vg, &g, &r, 1.0, 1);
        for i in 0..x_u.len() {
            x_u.data[i] = (x_u.data[i] + g_u.data[i] * inv_col_unmatched[i]).max(0.0);
        }
        if checkpoints.contains(&it) {
            println!(
                "{it:>5}  {:>16.6}  {:>18.6}",
                residual(&p, &x_m, &y),
                residual(&p, &x_u, &y)
            );
        }
    }
    let rm = residual(&p, &x_m, &y);
    let ru = residual(&p, &x_u, &y);
    let pm = leap::metrics::psnr(&x_m.data, &truth.data, None);
    let pu = leap::metrics::psnr(&x_u.data, &truth.data, None);
    println!("final: matched residual {rm:.5} (PSNR {pm:.2} dB), unmatched {ru:.5} (PSNR {pu:.2} dB)");
    println!(
        "matched pair {} after {iters} iterations",
        if rm < ru { "remains stable — reproduces the paper's §2.1 claim" } else { "did NOT beat unmatched (unexpected)" }
    );
}
