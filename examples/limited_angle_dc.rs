//! END-TO-END DRIVER — the paper's Figure-3 experiment: limited-angle CT
//! with inference-model prediction + sinogram completion + iterative
//! data-consistency refinement, over a test set of luggage phantoms.
//!
//! Mirrors the paper's setup (§4) at CPU scale: 720→180 parallel views
//! over 180°, 512²→128² images, ALERT luggage → seeded synthetic bags
//! (DESIGN.md §6), CT-Net+U-Net → FBP + convolutional/TV prior. The
//! headline shape to reproduce: **refinement improves mean PSNR and SSIM
//! over the prediction** (paper: 35.486→36.350 dB, 0.905→0.911).
//!
//! When `make artifacts` has run, the whole inference pipeline (fbp,
//! prior_denoise, complete_sinogram, dc_refine) executes through the
//! AOT-compiled JAX/Pallas artifacts via PJRT — Python never runs here.
//! Otherwise the native Rust path computes the identical pipeline.
//!
//! ```bash
//! cargo run --release --example limited_angle_dc -- --bags 25
//! ```

use leap::geometry::{angles_deg, Geometry, ParallelBeam, VolumeGeometry};
use leap::metrics;
use leap::phantom::luggage::{self, LuggageParams};
use leap::projector::{Model, Projector};
use leap::recon;
use leap::runtime::Engine;
use leap::util::cli::Args;
use leap::{Sino, Vol3};

/// Quarter-scale carry-on bags so the default artifact FOV (128 mm) holds
/// the whole object; attenuation statistics unchanged.
fn bag_params() -> LuggageParams {
    LuggageParams {
        case_half_w: (35.0, 50.0),
        case_half_h: (22.0, 37.0),
        shell_thickness: 1.6,
        ..LuggageParams::default()
    }
}

struct Pipeline {
    engine: Option<Engine>,
    p: Projector,
    vg: VolumeGeometry,
    g: ParallelBeam,
    keep: usize,
}

impl Pipeline {
    fn run_bag(&self, seed: u64) -> (f64, f64, f64, f64, f64, f64) {
        let bag = luggage::bag(seed, &bag_params());
        let truth = bag.rasterize(&self.vg, 2);
        // measured data: analytic line integrals (no inverse crime)
        let y_full = bag.project(&Geometry::Parallel(self.g.clone()));
        let nviews = self.g.angles.len();
        let mask = recon::ViewMask::contiguous(nviews, 0, self.keep);
        let mut y_masked = y_full.clone();
        mask.apply(&mut y_masked);

        // ── inference model stand-in: limited-angle FBP + denoising prior
        let (pred, refined) = match &self.engine {
            Some(engine) => {
                let fbp = engine.run1("fbp", &[&y_masked.data]).unwrap();
                let relu: Vec<f32> = fbp.iter().map(|&v| v.max(0.0)).collect();
                let pred = engine.run1("prior_denoise", &[&relu]).unwrap();
                // sinogram completion (kept for the completion metric) and
                // the fused 20-step DC refinement artifact
                let _completed = engine
                    .run1("complete_sinogram", &[&y_masked.data, &mask.weights, &pred])
                    .unwrap();
                let refined =
                    engine.run1("dc_refine", &[&pred, &y_masked.data, &mask.weights]).unwrap();
                // second refinement round = the paper's "iterative" step
                let refined =
                    engine.run1("dc_refine", &[&refined, &y_masked.data, &mask.weights]).unwrap();
                (
                    Vol3::from_vec(self.vg.nx, self.vg.ny, 1, pred),
                    Vol3::from_vec(self.vg.nx, self.vg.ny, 1, refined),
                )
            }
            None => {
                let g_lim = ParallelBeam {
                    angles: self.g.angles[0..self.keep].to_vec(),
                    ..self.g.clone()
                };
                let sino_lim = Sino::from_vec(
                    self.keep,
                    1,
                    self.g.ncols,
                    y_full.data[..self.keep * self.g.ncols].to_vec(),
                );
                let mut pred =
                    recon::fbp_parallel(&self.vg, &g_lim, &sino_lim, recon::Window::Hann, 1);
                leap::recon::fista_tv::tv_prox_vol(&mut pred, 2e-4, 15);
                for v in pred.data.iter_mut() {
                    *v = v.max(0.0);
                }
                let refined = recon::refine(
                    &self.p,
                    &y_masked,
                    &mask,
                    &pred,
                    &recon::DcOpts { iterations: 40, ..Default::default() },
                );
                (pred, refined)
            }
        };

        let psnr_pred = metrics::psnr(&pred.data, &truth.data, None);
        let ssim_pred = metrics::ssim_vol(&pred, &truth, None);
        let psnr_ref = metrics::psnr(&refined.data, &truth.data, None);
        let ssim_ref = metrics::ssim_vol(&refined, &truth, None);
        let dc_pred = recon::data_consistency_error(&self.p, &y_masked, &mask, &pred);
        let dc_ref = recon::data_consistency_error(&self.p, &y_masked, &mask, &refined);
        (psnr_pred, ssim_pred, psnr_ref, ssim_ref, dc_pred, dc_ref)
    }
}

fn main() {
    let args = Args::from_env();
    let bags = args.usize_or("bags", 8);
    let (n, nviews, ncols, voxel, du) = match Engine::load(args.str_or("artifacts", "artifacts")) {
        Ok(e) => (e.spec.n, e.spec.nviews, e.spec.ncols, e.spec.voxel, e.spec.du),
        Err(_) => (128, 180, 192, 1.0, 1.0),
    };
    let vg = VolumeGeometry::slice2d(n, n, voxel);
    let g = ParallelBeam {
        nrows: 1,
        ncols,
        du,
        dv: du,
        cu: 0.0,
        cv: 0.0,
        angles: angles_deg(nviews, 0.0, 180.0),
    };
    let engine = Engine::load(args.str_or("artifacts", "artifacts")).ok();
    let backend = if engine.is_some() { "artifacts(PJRT)" } else { "native" };
    let keep = nviews / 3; // 60° of 180°, as in the paper
    let pipeline = Pipeline {
        engine,
        p: Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF),
        vg,
        g,
        keep,
    };

    // the paper's split: train 165 / test 25 — we evaluate on test seeds
    let (_train, test) = luggage::split(190, 25.0 / 190.0);
    let seeds: Vec<u64> = test.into_iter().take(bags).collect();
    println!(
        "limited-angle DC experiment [{backend}]: {} bags, {}²@{voxel}mm, {keep}/{nviews} views (60° of 180°)",
        seeds.len(),
        n
    );
    println!("bag  PSNR(pred)  PSNR(refined)  SSIM(pred)  SSIM(refined)  DCerr(pred→ref)");

    let mut sums = [0.0f64; 6];
    let t0 = std::time::Instant::now();
    for &seed in &seeds {
        let (pp, sp, pr, sr, dp, dr) = pipeline.run_bag(seed);
        println!("{seed:>3}  {pp:>9.3}  {pr:>12.3}  {sp:>10.4}  {sr:>12.4}  {dp:.3}→{dr:.3}");
        for (acc, v) in sums.iter_mut().zip([pp, sp, pr, sr, dp, dr]) {
            *acc += v;
        }
    }
    let nb = seeds.len() as f64;
    let mean = |i: usize| sums[i] / nb;
    println!("──────────────────────────────────────────────────────────────");
    println!(
        "mean PSNR {:.3} → {:.3} dB   mean SSIM {:.4} → {:.4}   ({:.1}s total)",
        mean(0),
        mean(2),
        mean(1),
        mean(3),
        t0.elapsed().as_secs_f64()
    );
    println!("paper (512²/720v, trained net): PSNR 35.486 → 36.350, SSIM 0.905 → 0.911");
    let improved = mean(2) > mean(0) && mean(3) > mean(1);
    println!(
        "shape reproduced (refined > prediction on both metrics): {}",
        if improved { "YES" } else { "NO" }
    );
    // machine-readable record for EXPERIMENTS.md
    let record = leap::util::json::Json::obj(vec![
        ("experiment", leap::util::json::Json::Str("fig3_limited_angle_dc".into())),
        ("backend", leap::util::json::Json::Str(backend.into())),
        ("bags", leap::util::json::Json::Num(nb)),
        ("psnr_pred", leap::util::json::Json::Num(mean(0))),
        ("psnr_refined", leap::util::json::Json::Num(mean(2))),
        ("ssim_pred", leap::util::json::Json::Num(mean(1))),
        ("ssim_refined", leap::util::json::Json::Num(mean(3))),
        ("dc_err_pred", leap::util::json::Json::Num(mean(4))),
        ("dc_err_refined", leap::util::json::Json::Num(mean(5))),
    ]);
    let _ = std::fs::create_dir_all("target");
    let _ = std::fs::write("target/fig3_record.json", record.to_string());
    println!("record: target/fig3_record.json");
    if !improved {
        std::process::exit(1);
    }
}
