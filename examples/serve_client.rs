//! Serving scenario: spin up the coordinator + TCP server in-process,
//! drive it with concurrent clients, and report throughput/latency —
//! the "seamless integration with existing pipelines" claim as a service.
//!
//! Uses the PJRT artifact backend when `make artifacts` has run, plus the
//! native backend; requests are routed by op name and dynamically batched.
//!
//! ```bash
//! cargo run --release --example serve_client -- --clients 4 --requests 8
//! ```

use std::sync::Arc;

use leap::coordinator::server::{Client, Server};
use leap::coordinator::{BatchPolicy, Coordinator, Executor, NativeExecutor, Router};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 64);
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 8);

    // backends: artifacts (if built) + native
    let mut backends: Vec<Arc<dyn Executor>> = Vec::new();
    match leap::runtime::EngineHost::load(args.str_or("artifacts", "artifacts")) {
        Ok(host) => {
            println!("artifact backend: {} entries", host.entry_names().len());
            backends.push(Arc::new(host));
        }
        Err(e) => println!("artifact backend skipped: {e:#}"),
    }
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let g = ParallelBeam::standard_2d(90, (n * 3) / 2, 1.0);
    backends.push(Arc::new(NativeExecutor::new(Projector::new(
        Geometry::Parallel(g.clone()),
        vg.clone(),
        Model::SF,
    ))));
    let coord = Arc::new(Coordinator::new(
        Arc::new(Router::new(backends)),
        BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(4) },
        1 << 30,
        2,
    ));
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    println!("server on {}", server.addr);

    let phantom = shepp::shepp_logan_2d(0.4 * n as f64, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let payload = Arc::new(truth.data);

    let t0 = std::time::Instant::now();
    let addr = server.addr;
    let mut handles = Vec::new();
    for c in 0..clients {
        let payload = payload.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let t = std::time::Instant::now();
                let reply = client.call("native_fp", &[&payload]).unwrap();
                assert!(reply.get("outputs").is_some(), "client {c}: {reply}");
                latencies.push(t.elapsed().as_secs_f64());
            }
            latencies
        }));
    }
    let mut all: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = all.len();
    let q = |p: f64| all[((total as f64 - 1.0) * p) as usize];
    println!(
        "{total} projection requests over {clients} clients in {wall:.2}s → {:.1} req/s",
        total as f64 / wall
    );
    println!(
        "latency: p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms",
        q(0.5) * 1e3,
        q(0.9) * 1e3,
        q(0.99) * 1e3
    );
    let mut stats_client = Client::connect(&addr).unwrap();
    let stats = stats_client.stats().unwrap();
    println!("server telemetry: {}", stats.get("stats").unwrap());
}
