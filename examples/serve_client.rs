//! Serving scenario: spin up the coordinator + dual-protocol TCP server
//! in-process, register a scan over the protocol-v2 session handshake,
//! stream binary tensor frames at it from concurrent clients, drive the
//! same workload over legacy v1 JSON — and assert both protocols return
//! exactly the bits of the in-process `leap::api::Scan` path.
//!
//! This doubles as the CI client/server integration smoke (see
//! `.github/workflows/ci.yml`).
//!
//! ```bash
//! cargo run --release --example serve_client -- --clients 4 --requests 8
//! # 8 simultaneous v2 sessions multiplexed on the one event loop:
//! cargo run --release --example serve_client -- --clients 2 --requests 2 --sessions 8
//! ```
//!
//! `--sessions N` (default `--clients`) sets how many concurrent
//! protocol-v2 sessions run at once, each on its own TCP connection —
//! all multiplexed by the server's single event-loop thread onto the
//! shared worker pool.
//!
//! `--workers N` (default 0) additionally opens a cluster shard channel
//! and spawns N `leap worker` **processes** against it (the binary next
//! to this example, or `$LEAP_BIN`), so every session request executes
//! multi-process-sharded (`leap::cluster::ShardedOp`). With two or more
//! workers one of them is killed mid-run — requests must still complete
//! bit-identically via re-scatter to the survivors, and the `__stats`
//! snapshot must expose the shard channel's retry/latency telemetry:
//!
//! ```bash
//! cargo build --release   # the worker verb lives in the leap binary
//! cargo run --release --example serve_client -- --sessions 2 --workers 2
//! ```

use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use leap::api::ScanBuilder;
use leap::coordinator::server::{BinaryClient, Client, Server, ServerOptions};
use leap::coordinator::{
    BatchPolicy, Coordinator, Executor, NativeExecutor, Router, SessionExecutor, SessionRegistry,
};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::util::cli::Args;

/// The `leap` binary that provides the `worker` verb: `$LEAP_BIN` when
/// set, else resolved next to this example
/// (`target/<profile>/examples/serve_client` → `target/<profile>/leap`).
fn leap_binary() -> std::path::PathBuf {
    if let Ok(bin) = std::env::var("LEAP_BIN") {
        return bin.into();
    }
    let exe = std::env::current_exe().expect("current_exe");
    exe.parent()
        .and_then(|p| p.parent())
        .expect("example expected under target/<profile>/examples")
        .join("leap")
}

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 64);
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 8);
    let sessions = args.usize_or("sessions", clients);
    let workers = args.usize_or("workers", 0);

    // ── optional cluster: shard channel + N worker processes ──
    let cluster = if workers > 0 {
        Some(Arc::new(leap::cluster::ShardServer::start("127.0.0.1:0").unwrap()))
    } else {
        None
    };
    let mut children: Vec<Child> = Vec::new();
    if let Some(c) = &cluster {
        let bin = leap_binary();
        let shard_addr = c.addr.to_string();
        for _ in 0..workers {
            children.push(
                Command::new(&bin)
                    .args(["worker", "--connect", &shard_addr])
                    .stdout(Stdio::null())
                    .spawn()
                    .expect("spawn `leap worker` (build the leap binary, or set LEAP_BIN)"),
            );
        }
        let t0 = Instant::now();
        while c.workers() < workers {
            assert!(t0.elapsed() < Duration::from_secs(10), "workers failed to register");
            std::thread::sleep(Duration::from_millis(10));
        }
        println!("cluster: {workers} worker processes joined on {shard_addr}");
    }

    // backends: artifacts (if built) + native (v1 ops) + sessions (v2)
    let mut backends: Vec<Arc<dyn Executor>> = Vec::new();
    match leap::runtime::EngineHost::load(args.str_or("artifacts", "artifacts")) {
        Ok(host) => {
            println!("artifact backend: {} entries", host.entry_names().len());
            backends.push(Arc::new(host));
        }
        Err(e) => println!("artifact backend skipped: {e:#}"),
    }
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let g = ParallelBeam::standard_2d(90, (n * 3) / 2, 1.0);
    backends.push(Arc::new(NativeExecutor::new(Projector::new(
        Geometry::Parallel(g.clone()),
        vg.clone(),
        Model::SF,
    ))));
    let session_exec: Arc<dyn Executor> = match &cluster {
        Some(c) => {
            Arc::new(SessionExecutor::with_cluster(SessionRegistry::global_arc(), c.clone()))
        }
        None => Arc::new(SessionExecutor::new()),
    };
    backends.push(session_exec);
    let coord = Arc::new(Coordinator::new(
        Arc::new(Router::new(backends)),
        BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(4) },
        1 << 30,
        2,
    ));
    let server = Server::start_with(
        "127.0.0.1:0",
        coord.clone(),
        ServerOptions { cluster: cluster.clone(), ..ServerOptions::default() },
    )
    .unwrap();
    println!("server on {} (protocol v2 binary + legacy v1 json)", server.addr);

    // the in-process reference every served byte must match exactly
    let scan = ScanBuilder::new()
        .geometry(Geometry::Parallel(g.clone()))
        .volume(vg.clone())
        .model(Model::SF)
        .build()
        .unwrap();
    let phantom = shepp::shepp_logan_2d(0.4 * n as f64, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let payload = Arc::new(truth.data.clone());
    let reference = Arc::new(scan.forward(&payload).unwrap());

    // ── protocol v2: one session handshake, then raw tensor frames ──
    // `sessions` concurrent sessions, each on its own connection, all
    // in flight against the one event loop at the same time
    let t0 = std::time::Instant::now();
    let addr = server.addr;
    let cfg = scan.config();
    let mut handles = Vec::new();
    for c in 0..sessions {
        let payload = payload.clone();
        let reference = reference.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = BinaryClient::connect(&addr).unwrap();
            let session = client.open_session(&cfg, Model::SF, None).unwrap();
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let t = std::time::Instant::now();
                let sino = client.forward(session, &payload).unwrap();
                assert_eq!(
                    sino, *reference,
                    "client {c}: served v2 bits must match the in-process scan"
                );
                latencies.push(t.elapsed().as_secs_f64());
            }
            client.close_session(session).unwrap();
            latencies
        }));
    }
    // with ≥ 2 workers, kill one while the sessions stream: its
    // in-flight shards must be re-scattered to the survivor and every
    // reply must still match the in-process bits (asserted above)
    if workers > 1 {
        let mut victim = children.remove(0);
        std::thread::sleep(Duration::from_millis(50));
        let _ = victim.kill();
        let _ = victim.wait();
        println!("cluster: killed one worker mid-run (requests must survive via re-scatter)");
    }
    let mut v2: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let v2_wall = t0.elapsed().as_secs_f64();

    // ── legacy protocol v1: JSON text floats, per-request envelope ──
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let payload = payload.clone();
        let reference = reference.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let t = std::time::Instant::now();
                let sino = client.call_tensor("native_fp", &payload).unwrap();
                assert_eq!(
                    sino, *reference,
                    "client {c}: served v1 bits must match the in-process scan"
                );
                latencies.push(t.elapsed().as_secs_f64());
            }
            latencies
        }));
    }
    let mut v1: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let v1_wall = t0.elapsed().as_secs_f64();

    let report = |name: &str, conns: usize, all: &mut Vec<f64>, wall: f64| {
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total = all.len();
        let q = |p: f64| all[((total as f64 - 1.0) * p) as usize];
        println!(
            "{name}: {total} requests over {conns} connections in {wall:.2}s → {:.1} req/s \
             (p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms)",
            total as f64 / wall,
            q(0.5) * 1e3,
            q(0.9) * 1e3,
            q(0.99) * 1e3
        );
    };
    report("v2 binary sessions ", sessions, &mut v2, v2_wall);
    report("v1 json per-request", clients, &mut v1, v1_wall);
    println!("both protocols bit-identical to the in-process plan path ✓");
    println!("v2 speedup over v1: {:.2}×", v1_wall / v2_wall);

    let mut stats_client = Client::connect(&addr).unwrap();
    let stats = stats_client.stats().unwrap();
    println!("server telemetry: {}", stats.get("stats").unwrap());

    if workers > 0 {
        let s = stats.get("stats").unwrap();
        let live = s.get_f64("cluster_workers").expect("__stats must report cluster_workers");
        let shard = s.get("cluster").expect("__stats must report the shard-channel telemetry");
        println!("cluster telemetry: {live} worker(s) connected, shard channel {shard}");
        assert!(
            if workers > 1 { live as usize <= workers - 1 } else { live as usize == workers },
            "cluster_workers must reflect the killed worker"
        );
        for mut child in children {
            let _ = child.kill();
            let _ = child.wait();
        }
        println!("cluster smoke: sharded replies bit-identical, worker kill survived ✓");
    }
}
