//! Serving scenario: spin up the coordinator + dual-protocol TCP server
//! in-process, register a scan over the protocol-v2 session handshake,
//! stream binary tensor frames at it from concurrent clients, drive the
//! same workload over legacy v1 JSON — and assert both protocols return
//! exactly the bits of the in-process `leap::api::Scan` path.
//!
//! This doubles as the CI client/server integration smoke (see
//! `.github/workflows/ci.yml`).
//!
//! ```bash
//! cargo run --release --example serve_client -- --clients 4 --requests 8
//! # 8 simultaneous v2 sessions multiplexed on the one event loop:
//! cargo run --release --example serve_client -- --clients 2 --requests 2 --sessions 8
//! ```
//!
//! `--sessions N` (default `--clients`) sets how many concurrent
//! protocol-v2 sessions run at once, each on its own TCP connection —
//! all multiplexed by the server's single event-loop thread onto the
//! shared worker pool.

use std::sync::Arc;

use leap::api::ScanBuilder;
use leap::coordinator::server::{BinaryClient, Client, Server};
use leap::coordinator::{
    BatchPolicy, Coordinator, Executor, NativeExecutor, Router, SessionExecutor,
};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::shepp;
use leap::projector::{Model, Projector};
use leap::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 64);
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 8);
    let sessions = args.usize_or("sessions", clients);

    // backends: artifacts (if built) + native (v1 ops) + sessions (v2)
    let mut backends: Vec<Arc<dyn Executor>> = Vec::new();
    match leap::runtime::EngineHost::load(args.str_or("artifacts", "artifacts")) {
        Ok(host) => {
            println!("artifact backend: {} entries", host.entry_names().len());
            backends.push(Arc::new(host));
        }
        Err(e) => println!("artifact backend skipped: {e:#}"),
    }
    let vg = VolumeGeometry::slice2d(n, n, 1.0);
    let g = ParallelBeam::standard_2d(90, (n * 3) / 2, 1.0);
    backends.push(Arc::new(NativeExecutor::new(Projector::new(
        Geometry::Parallel(g.clone()),
        vg.clone(),
        Model::SF,
    ))));
    backends.push(Arc::new(SessionExecutor::new()));
    let coord = Arc::new(Coordinator::new(
        Arc::new(Router::new(backends)),
        BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(4) },
        1 << 30,
        2,
    ));
    let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
    println!("server on {} (protocol v2 binary + legacy v1 json)", server.addr);

    // the in-process reference every served byte must match exactly
    let scan = ScanBuilder::new()
        .geometry(Geometry::Parallel(g.clone()))
        .volume(vg.clone())
        .model(Model::SF)
        .build()
        .unwrap();
    let phantom = shepp::shepp_logan_2d(0.4 * n as f64, 0.02);
    let truth = phantom.rasterize(&vg, 2);
    let payload = Arc::new(truth.data.clone());
    let reference = Arc::new(scan.forward(&payload).unwrap());

    // ── protocol v2: one session handshake, then raw tensor frames ──
    // `sessions` concurrent sessions, each on its own connection, all
    // in flight against the one event loop at the same time
    let t0 = std::time::Instant::now();
    let addr = server.addr;
    let cfg = scan.config();
    let mut handles = Vec::new();
    for c in 0..sessions {
        let payload = payload.clone();
        let reference = reference.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = BinaryClient::connect(&addr).unwrap();
            let session = client.open_session(&cfg, Model::SF, None).unwrap();
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let t = std::time::Instant::now();
                let sino = client.forward(session, &payload).unwrap();
                assert_eq!(
                    sino, *reference,
                    "client {c}: served v2 bits must match the in-process scan"
                );
                latencies.push(t.elapsed().as_secs_f64());
            }
            client.close_session(session).unwrap();
            latencies
        }));
    }
    let mut v2: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let v2_wall = t0.elapsed().as_secs_f64();

    // ── legacy protocol v1: JSON text floats, per-request envelope ──
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let payload = payload.clone();
        let reference = reference.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut latencies = Vec::new();
            for _ in 0..requests {
                let t = std::time::Instant::now();
                let sino = client.call_tensor("native_fp", &payload).unwrap();
                assert_eq!(
                    sino, *reference,
                    "client {c}: served v1 bits must match the in-process scan"
                );
                latencies.push(t.elapsed().as_secs_f64());
            }
            latencies
        }));
    }
    let mut v1: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let v1_wall = t0.elapsed().as_secs_f64();

    let report = |name: &str, conns: usize, all: &mut Vec<f64>, wall: f64| {
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total = all.len();
        let q = |p: f64| all[((total as f64 - 1.0) * p) as usize];
        println!(
            "{name}: {total} requests over {conns} connections in {wall:.2}s → {:.1} req/s \
             (p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms)",
            total as f64 / wall,
            q(0.5) * 1e3,
            q(0.9) * 1e3,
            q(0.99) * 1e3
        );
    };
    report("v2 binary sessions ", sessions, &mut v2, v2_wall);
    report("v1 json per-request", clients, &mut v1, v1_wall);
    println!("both protocols bit-identical to the in-process plan path ✓");
    println!("v2 speedup over v1: {:.2}×", v1_wall / v2_wall);

    let mut stats_client = Client::connect(&addr).unwrap();
    let stats = stats_client.stats().unwrap();
    println!("server telemetry: {}", stats.get("stats").unwrap());
}
