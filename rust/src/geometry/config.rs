//! Geometry configuration files (JSON) — the paper's "CT parameters ...
//! can be specified using set functions or a configuration file".
//!
//! A config holds the scanner geometry and the volume grid:
//!
//! ```json
//! {
//!   "geometry": {"type": "cone", "nrows": 64, "ncols": 64, "du": 1.0,
//!                 "dv": 1.0, "cu": 0.0, "cv": 0.0, "sod": 500.0,
//!                 "sdd": 1000.0, "nviews": 90, "arc_deg": 360.0,
//!                 "detector": "flat"},
//!   "volume":   {"nx": 64, "ny": 64, "nz": 64, "vx": 1.0, "vy": 1.0,
//!                 "vz": 1.0, "cx": 0.0, "cy": 0.0, "cz": 0.0}
//! }
//! ```
//!
//! Explicit angle lists (`"angles_deg": [...]`) override `nviews`/`arc_deg`,
//! supporting the paper's non-equispaced acquisitions. Modular geometries
//! list per-view poses.

use crate::util::json::{parse, Json};

use super::{
    angles_deg, ConeBeam, DetectorShape, FanBeam, Geometry, ModularBeam, ModularView,
    ParallelBeam, VolumeGeometry,
};

/// A full scan description: scanner + volume grid.
#[derive(Clone, Debug)]
pub struct ScanConfig {
    pub geometry: Geometry,
    pub volume: VolumeGeometry,
}

fn angles_from(obj: &Json, default_arc: f64) -> Result<Vec<f64>, String> {
    if let Some(list) = obj.get_f64_vec("angles_deg") {
        return Ok(list.into_iter().map(|a| a.to_radians()).collect());
    }
    let nviews = obj.get_usize("nviews").ok_or("missing nviews or angles_deg")?;
    let start = obj.get_f64("start_deg").unwrap_or(0.0);
    let arc = obj.get_f64("arc_deg").unwrap_or(default_arc);
    Ok(angles_deg(nviews, start, arc))
}

fn vec3(obj: &Json, key: &str) -> Result<[f64; 3], String> {
    let v = obj.get_f64_vec(key).ok_or_else(|| format!("missing {key}"))?;
    if v.len() != 3 {
        return Err(format!("{key} must have 3 elements"));
    }
    Ok([v[0], v[1], v[2]])
}

/// Parse a geometry object (the `"geometry"` field of a config).
pub fn geometry_from_json(g: &Json) -> Result<Geometry, String> {
    let ty = g.get_str("type").ok_or("geometry missing type")?;
    let du = g.get_f64("du").unwrap_or(1.0);
    let dv = g.get_f64("dv").unwrap_or(du);
    let cu = g.get_f64("cu").unwrap_or(0.0);
    let cv = g.get_f64("cv").unwrap_or(0.0);
    let ncols = g.get_usize("ncols").ok_or("geometry missing ncols")?;
    match ty {
        "parallel" => Ok(Geometry::Parallel(ParallelBeam {
            nrows: g.get_usize("nrows").unwrap_or(1),
            ncols,
            du,
            dv,
            cu,
            cv,
            angles: angles_from(g, 180.0)?,
        })),
        "fan" => Ok(Geometry::Fan(FanBeam {
            ncols,
            du,
            cu,
            sod: g.get_f64("sod").ok_or("fan missing sod")?,
            sdd: g.get_f64("sdd").ok_or("fan missing sdd")?,
            angles: angles_from(g, 360.0)?,
        })),
        "cone" => Ok(Geometry::Cone(ConeBeam {
            nrows: g.get_usize("nrows").ok_or("cone missing nrows")?,
            ncols,
            du,
            dv,
            cu,
            cv,
            sod: g.get_f64("sod").ok_or("cone missing sod")?,
            sdd: g.get_f64("sdd").ok_or("cone missing sdd")?,
            angles: angles_from(g, 360.0)?,
            shape: match g.get_str("detector").unwrap_or("flat") {
                "flat" => DetectorShape::Flat,
                "curved" => DetectorShape::Curved,
                other => return Err(format!("unknown detector shape {other}")),
            },
        })),
        "modular" => {
            let views_json = g.get("views").and_then(|v| v.as_arr()).ok_or("modular missing views")?;
            let mut views = Vec::with_capacity(views_json.len());
            for (i, v) in views_json.iter().enumerate() {
                views.push(ModularView {
                    source: vec3(v, "source").map_err(|e| format!("view {i}: {e}"))?,
                    det_center: vec3(v, "det_center").map_err(|e| format!("view {i}: {e}"))?,
                    u_axis: vec3(v, "u_axis").map_err(|e| format!("view {i}: {e}"))?,
                    v_axis: vec3(v, "v_axis").map_err(|e| format!("view {i}: {e}"))?,
                });
            }
            let m = ModularBeam {
                nrows: g.get_usize("nrows").ok_or("modular missing nrows")?,
                ncols,
                du,
                dv,
                views,
            };
            m.validate()?;
            Ok(Geometry::Modular(m))
        }
        other => Err(format!("unknown geometry type {other}")),
    }
}

/// Parse a volume object (the `"volume"` field of a config).
pub fn volume_from_json(v: &Json) -> Result<VolumeGeometry, String> {
    let nx = v.get_usize("nx").ok_or("volume missing nx")?;
    let ny = v.get_usize("ny").unwrap_or(nx);
    let nz = v.get_usize("nz").unwrap_or(1);
    let vx = v.get_f64("vx").unwrap_or(1.0);
    Ok(VolumeGeometry {
        nx,
        ny,
        nz,
        vx,
        vy: v.get_f64("vy").unwrap_or(vx),
        vz: v.get_f64("vz").unwrap_or(vx),
        cx: v.get_f64("cx").unwrap_or(0.0),
        cy: v.get_f64("cy").unwrap_or(0.0),
        cz: v.get_f64("cz").unwrap_or(0.0),
    })
}

/// Parse a complete scan config document.
pub fn scan_from_str(text: &str) -> Result<ScanConfig, String> {
    let doc = parse(text)?;
    let geometry = geometry_from_json(doc.get("geometry").ok_or("missing geometry")?)?;
    let volume = volume_from_json(doc.get("volume").ok_or("missing volume")?)?;
    Ok(ScanConfig { geometry, volume })
}

/// Load a scan config from a JSON file.
pub fn scan_from_file(path: &str) -> Result<ScanConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    scan_from_str(&text)
}

/// Serialize a geometry back to JSON (round-trips through
/// [`geometry_from_json`]).
pub fn geometry_to_json(g: &Geometry) -> Json {
    let deg = |angles: &[f64]| Json::arr_f64(&angles.iter().map(|a| a.to_degrees()).collect::<Vec<_>>());
    match g {
        Geometry::Parallel(p) => Json::obj(vec![
            ("type", Json::Str("parallel".into())),
            ("nrows", Json::Num(p.nrows as f64)),
            ("ncols", Json::Num(p.ncols as f64)),
            ("du", Json::Num(p.du)),
            ("dv", Json::Num(p.dv)),
            ("cu", Json::Num(p.cu)),
            ("cv", Json::Num(p.cv)),
            ("angles_deg", deg(&p.angles)),
        ]),
        Geometry::Fan(f) => Json::obj(vec![
            ("type", Json::Str("fan".into())),
            ("ncols", Json::Num(f.ncols as f64)),
            ("du", Json::Num(f.du)),
            ("cu", Json::Num(f.cu)),
            ("sod", Json::Num(f.sod)),
            ("sdd", Json::Num(f.sdd)),
            ("angles_deg", deg(&f.angles)),
        ]),
        Geometry::Cone(c) => Json::obj(vec![
            ("type", Json::Str("cone".into())),
            ("nrows", Json::Num(c.nrows as f64)),
            ("ncols", Json::Num(c.ncols as f64)),
            ("du", Json::Num(c.du)),
            ("dv", Json::Num(c.dv)),
            ("cu", Json::Num(c.cu)),
            ("cv", Json::Num(c.cv)),
            ("sod", Json::Num(c.sod)),
            ("sdd", Json::Num(c.sdd)),
            (
                "detector",
                Json::Str(match c.shape {
                    DetectorShape::Flat => "flat".into(),
                    DetectorShape::Curved => "curved".into(),
                }),
            ),
            ("angles_deg", deg(&c.angles)),
        ]),
        Geometry::Modular(m) => Json::obj(vec![
            ("type", Json::Str("modular".into())),
            ("nrows", Json::Num(m.nrows as f64)),
            ("ncols", Json::Num(m.ncols as f64)),
            ("du", Json::Num(m.du)),
            ("dv", Json::Num(m.dv)),
            (
                "views",
                Json::Arr(
                    m.views
                        .iter()
                        .map(|v| {
                            Json::obj(vec![
                                ("source", Json::arr_f64(&v.source)),
                                ("det_center", Json::arr_f64(&v.det_center)),
                                ("u_axis", Json::arr_f64(&v.u_axis)),
                                ("v_axis", Json::arr_f64(&v.v_axis)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    }
}

/// Serialize a volume grid to JSON.
pub fn volume_to_json(v: &VolumeGeometry) -> Json {
    Json::obj(vec![
        ("nx", Json::Num(v.nx as f64)),
        ("ny", Json::Num(v.ny as f64)),
        ("nz", Json::Num(v.nz as f64)),
        ("vx", Json::Num(v.vx)),
        ("vy", Json::Num(v.vy)),
        ("vz", Json::Num(v.vz)),
        ("cx", Json::Num(v.cx)),
        ("cy", Json::Num(v.cy)),
        ("cz", Json::Num(v.cz)),
    ])
}

/// Serialize a full scan config.
pub fn scan_to_string(cfg: &ScanConfig) -> String {
    Json::obj(vec![
        ("geometry", geometry_to_json(&cfg.geometry)),
        ("volume", volume_to_json(&cfg.volume)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cone_config() {
        let cfg = scan_from_str(
            r#"{"geometry": {"type": "cone", "nrows": 8, "ncols": 16, "du": 1.5,
                 "sod": 500, "sdd": 1000, "nviews": 36},
                "volume": {"nx": 32, "vx": 0.5}}"#,
        )
        .unwrap();
        match &cfg.geometry {
            Geometry::Cone(c) => {
                assert_eq!(c.nrows, 8);
                assert_eq!(c.ncols, 16);
                assert_eq!(c.du, 1.5);
                assert_eq!(c.dv, 1.5); // defaults to du
                assert_eq!(c.angles.len(), 36);
                assert_eq!(c.shape, DetectorShape::Flat);
            }
            g => panic!("wrong geometry {g:?}"),
        }
        assert_eq!(cfg.volume.ny, 32);
        assert_eq!(cfg.volume.vz, 0.5);
    }

    #[test]
    fn explicit_angles_override() {
        let cfg = scan_from_str(
            r#"{"geometry": {"type": "parallel", "ncols": 4,
                 "angles_deg": [0, 30, 90]},
                "volume": {"nx": 4}}"#,
        )
        .unwrap();
        match &cfg.geometry {
            Geometry::Parallel(p) => {
                assert_eq!(p.angles.len(), 3);
                assert!((p.angles[1] - 30f64.to_radians()).abs() < 1e-12);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn roundtrip_all_geometries() {
        let cone = ConeBeam::standard(5, 4, 6, 1.0, 2.0, 300.0, 600.0);
        let geos = vec![
            Geometry::Parallel(ParallelBeam::standard_3d(7, 3, 9, 0.8, 1.1)),
            Geometry::Fan(FanBeam::standard(6, 11, 1.3, 250.0, 700.0)),
            Geometry::Cone(cone.clone()),
            Geometry::Modular(ModularBeam::from_cone(&cone)),
        ];
        for g in geos {
            let j = geometry_to_json(&g).to_string();
            let g2 = geometry_from_json(&parse(&j).unwrap()).unwrap();
            // compare via a sample ray
            let a = g.ray(2, 0, 1);
            let b = g2.ray(2, 0, 1);
            for ax in 0..3 {
                assert!((a.origin[ax] - b.origin[ax]).abs() < 1e-9, "{}", g.kind());
                assert!((a.dir[ax] - b.dir[ax]).abs() < 1e-9, "{}", g.kind());
            }
        }
    }

    #[test]
    fn errors_are_descriptive() {
        assert!(scan_from_str("{}").unwrap_err().contains("geometry"));
        let e = scan_from_str(
            r#"{"geometry": {"type": "warp", "ncols": 1, "nviews": 1}, "volume": {"nx": 1}}"#,
        )
        .unwrap_err();
        assert!(e.contains("warp"));
    }
}
