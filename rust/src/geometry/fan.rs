//! Fan-beam geometry (2-D divergent; the paper's "future release" type,
//! included here for completeness and as the 2-D section of cone-beam).
//!
//! Source on a circle of radius `sod` (source-to-object distance, mm), flat
//! detector at distance `sdd` (source-to-detector, mm) perpendicular to the
//! central ray. At view angle `φ` the source is
//! `s(φ) = sod·(cos φ, sin φ)` and the detector line passes through
//! `s − sdd·(cos φ, sin φ)` with axis `û = (−sin φ, cos φ)`.

use super::{angles_deg, Ray};

#[derive(Clone, Debug, PartialEq)]
pub struct FanBeam {
    pub ncols: usize,
    /// Detector pixel pitch (mm).
    pub du: f64,
    /// Detector center offset (mm).
    pub cu: f64,
    /// Source-to-object (rotation center) distance, mm.
    pub sod: f64,
    /// Source-to-detector distance, mm.
    pub sdd: f64,
    pub angles: Vec<f64>,
}

impl FanBeam {
    /// Standard fan geometry over 360°.
    pub fn standard(nviews: usize, ncols: usize, du: f64, sod: f64, sdd: f64) -> FanBeam {
        FanBeam { ncols, du, cu: 0.0, sod, sdd, angles: angles_deg(nviews, 0.0, 360.0) }
    }

    #[inline]
    pub fn u(&self, col: usize) -> f64 {
        (col as f64 - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu
    }

    /// Continuous column index for detector coordinate `u` (inverse of
    /// [`Self::u`]) — used by the fan-beam FBP backprojector.
    #[inline]
    pub fn col_of_u(&self, u: f64) -> f64 {
        (u - self.cu) / self.du + (self.ncols as f64 - 1.0) / 2.0
    }

    /// Source position at view `view`.
    #[inline]
    pub fn source(&self, view: usize) -> [f64; 2] {
        let (s, c) = self.angles[view].sin_cos();
        [self.sod * c, self.sod * s]
    }

    /// World position of detector column `col` at view `view`.
    pub fn det_pos(&self, view: usize, col: usize) -> [f64; 2] {
        let (s, c) = self.angles[view].sin_cos();
        let u = self.u(col);
        // detector center = source − sdd·(cos φ, sin φ); u axis = (−sin φ, cos φ)
        [
            self.sod * c - self.sdd * c - u * s,
            self.sod * s - self.sdd * s + u * c,
        ]
    }

    /// Ray from the source through detector column `col`.
    pub fn ray(&self, view: usize, col: usize) -> Ray {
        self.ray_at(view, col as f64)
    }

    /// Ray at a *fractional* detector column (bin-integrated projections).
    pub fn ray_at(&self, view: usize, col_f: f64) -> Ray {
        let (s, c) = self.angles[view].sin_cos();
        self.ray_with_trig(s, c, col_f)
    }

    /// Ray from precomputed view trig `(sin φ, cos φ)` — the plan/execute
    /// split's execution primitive; `ray_at` delegates here.
    #[inline]
    pub fn ray_with_trig(&self, s: f64, c: f64, col_f: f64) -> Ray {
        let u = (col_f - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu;
        let sp = [self.sod * c, self.sod * s];
        let dp = [
            self.sod * c - self.sdd * c - u * s,
            self.sod * s - self.sdd * s + u * c,
        ];
        Ray::new([sp[0], sp[1], 0.0], [dp[0] - sp[0], dp[1] - sp[1], 0.0])
    }

    /// Fan magnification at the rotation center (`sdd / sod`).
    pub fn magnification(&self) -> f64 {
        self.sdd / self.sod
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_on_circle() {
        let g = FanBeam::standard(8, 16, 1.0, 500.0, 1000.0);
        for v in 0..8 {
            let s = g.source(v);
            let r = (s[0] * s[0] + s[1] * s[1]).sqrt();
            assert!((r - 500.0).abs() < 1e-9);
        }
    }

    #[test]
    fn central_ray_hits_rotation_center() {
        // odd ncols + zero shift → center column passes through origin
        let g = FanBeam { ncols: 17, du: 1.0, cu: 0.0, sod: 400.0, sdd: 800.0, angles: angles_deg(6, 0.0, 360.0) };
        for v in 0..6 {
            let r = g.ray(v, 8);
            // distance from origin to the ray
            let ox = -r.origin[0];
            let oy = -r.origin[1];
            let cross = (ox * r.dir[1] - oy * r.dir[0]).abs();
            assert!(cross < 1e-9, "view {v}: {cross}");
        }
    }

    #[test]
    fn detector_behind_center() {
        let g = FanBeam::standard(4, 9, 1.0, 300.0, 700.0);
        let s = g.source(0); // (300, 0)
        let d = g.det_pos(0, 4); // central column
        assert!((d[0] - (300.0 - 700.0)).abs() < 1e-9);
        assert!(d[1].abs() < 1e-9);
        assert_eq!(s, [300.0, 0.0]);
    }

    #[test]
    fn magnification() {
        let g = FanBeam::standard(1, 2, 1.0, 250.0, 1000.0);
        assert_eq!(g.magnification(), 4.0);
    }
}
