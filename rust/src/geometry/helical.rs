//! Helical cone-beam geometry — the paper's announced "future release"
//! type ("future releases will include fan-beam and helical cone-beam
//! geometries"), implemented here as a thin extension of the axial cone
//! scan: the source advances along z by `pitch_mm` per full rotation while
//! the detector stays rigidly opposite.
//!
//! Rays are generic, so the Siddon/Joseph projectors (and the modular-beam
//! machinery) consume a helical scan unchanged; `to_modular` makes that
//! explicit by exporting per-view poses.

use super::{angles_deg, ModularBeam, ModularView, Ray};

#[derive(Clone, Debug, PartialEq)]
pub struct HelicalCone {
    pub nrows: usize,
    pub ncols: usize,
    pub du: f64,
    pub dv: f64,
    pub cu: f64,
    pub cv: f64,
    pub sod: f64,
    pub sdd: f64,
    /// Source z advance per full rotation (mm); 0 degenerates to axial.
    pub pitch_mm: f64,
    /// Source z at angle 0 (mm).
    pub z0: f64,
    pub angles: Vec<f64>,
}

impl HelicalCone {
    /// Standard helix: `turns` full rotations of `views_per_turn` views.
    pub fn standard(
        turns: f64,
        views_per_turn: usize,
        nrows: usize,
        ncols: usize,
        du: f64,
        dv: f64,
        sod: f64,
        sdd: f64,
        pitch_mm: f64,
    ) -> HelicalCone {
        let nviews = (turns * views_per_turn as f64).round() as usize;
        HelicalCone {
            nrows,
            ncols,
            du,
            dv,
            cu: 0.0,
            cv: 0.0,
            sod,
            sdd,
            pitch_mm,
            z0: -pitch_mm * turns / 2.0,
            angles: angles_deg(nviews, 0.0, 360.0 * turns),
        }
    }

    /// Source z at view `view`.
    #[inline]
    pub fn source_z(&self, view: usize) -> f64 {
        self.z0 + self.pitch_mm * self.angles[view] / (2.0 * std::f64::consts::PI)
    }

    /// Source position at view `view`.
    pub fn source(&self, view: usize) -> [f64; 3] {
        let (s, c) = self.angles[view].sin_cos();
        [self.sod * c, self.sod * s, self.source_z(view)]
    }

    /// Detector pixel world position (flat detector moving with the source).
    pub fn det_pos(&self, view: usize, row_f: f64, col_f: f64) -> [f64; 3] {
        let (sphi, cphi) = self.angles[view].sin_cos();
        let u = (col_f - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu;
        let v = (row_f - (self.nrows as f64 - 1.0) / 2.0) * self.dv + self.cv;
        [
            (self.sod - self.sdd) * cphi - u * sphi,
            (self.sod - self.sdd) * sphi + u * cphi,
            self.source_z(view) + v,
        ]
    }

    /// Ray from the source through pixel `(row, col)`.
    pub fn ray(&self, view: usize, row: usize, col: usize) -> Ray {
        let s = self.source(view);
        let d = self.det_pos(view, row as f64, col as f64);
        Ray::new(s, [d[0] - s[0], d[1] - s[1], d[2] - s[2]])
    }

    /// Export as a modular-beam geometry (per-view poses), which plugs
    /// into every generic-ray projector and the config system.
    pub fn to_modular(&self) -> ModularBeam {
        let views = (0..self.angles.len())
            .map(|view| {
                let (s, c) = self.angles[view].sin_cos();
                ModularView {
                    source: self.source(view),
                    det_center: self.det_pos(view, (self.nrows as f64 - 1.0) / 2.0, (self.ncols as f64 - 1.0) / 2.0),
                    u_axis: [-s, c, 0.0],
                    v_axis: [0.0, 0.0, 1.0],
                }
            })
            .collect();
        ModularBeam { nrows: self.nrows, ncols: self.ncols, du: self.du, dv: self.dv, views }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, VolumeGeometry};
    use crate::projector::{Model, Projector};

    #[test]
    fn zero_pitch_matches_axial_cone() {
        let h = HelicalCone::standard(1.0, 12, 6, 8, 1.0, 1.0, 80.0, 160.0, 0.0);
        let cone = crate::geometry::ConeBeam::standard(12, 6, 8, 1.0, 1.0, 80.0, 160.0);
        for view in [0, 5, 11] {
            let a = h.ray(view, 2, 3);
            let b = cone.ray(view, 2, 3);
            for ax in 0..3 {
                assert!((a.origin[ax] - b.origin[ax]).abs() < 1e-9);
                assert!((a.dir[ax] - b.dir[ax]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn source_advances_linearly() {
        let h = HelicalCone::standard(2.0, 8, 4, 4, 1.0, 1.0, 60.0, 120.0, 10.0);
        assert_eq!(h.angles.len(), 16);
        // z0 centers the helix
        assert!((h.source_z(0) - (-10.0)).abs() < 1e-9);
        // half a turn later: +pitch/2 ... views_per_turn=8 → view 8 is one turn
        assert!((h.source_z(8) - 0.0).abs() < 1e-9);
        assert!((h.source_z(15) - (10.0 - 10.0 / 8.0)).abs() < 1e-6);
    }

    #[test]
    fn projects_through_modular_with_adjoint() {
        // a helical scan runs through the generic projector stack and its
        // matched pair holds
        let h = HelicalCone::standard(1.5, 8, 6, 10, 1.5, 1.5, 50.0, 100.0, 8.0);
        let geom = Geometry::Modular(h.to_modular());
        let vg = VolumeGeometry::cube(10, 1.0);
        let p = Projector::new(geom, vg, Model::Joseph).with_threads(2);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut x = p.new_vol();
        let mut y = p.new_sino();
        rng.fill_uniform(&mut x.data, 0.0, 1.0);
        rng.fill_uniform(&mut y.data, 0.0, 1.0);
        let lhs = crate::util::dot_f64(&p.forward(&x).data, &y.data);
        let rhs = crate::util::dot_f64(&x.data, &p.back(&y).data);
        assert!((lhs - rhs).abs() / lhs.abs().max(1e-12) < 1e-4);
    }

    #[test]
    fn helix_covers_long_object() {
        // a long cylinder: the axial scan misses the ends, the helix sees
        // them (non-zero projections at first/last views' extreme rows)
        use crate::phantom::{Phantom, Shape};
        let ph = Phantom::new(vec![Shape::Ellipsoid {
            center: [0.0, 0.0, 0.0],
            axes: [8.0, 8.0, 40.0],
            phi: 0.0,
            density: 0.02,
        }]);
        let h = HelicalCone::standard(3.0, 10, 8, 16, 1.2, 1.2, 60.0, 120.0, 20.0);
        let geom = Geometry::Modular(h.to_modular());
        let sino = ph.project(&geom);
        // first view (source near z=-30) and last view (near z=+30) both
        // see the object
        let first: f64 = sino.view(0).iter().map(|&v| v as f64).sum();
        let last: f64 = sino.view(sino.nviews - 1).iter().map(|&v| v as f64).sum();
        assert!(first > 0.1, "first view sum {first}");
        assert!(last > 0.1, "last view sum {last}");
    }
}
