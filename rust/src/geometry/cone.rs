//! Axial cone-beam geometry, flat or curved detector (paper §2.1).
//!
//! Source on a circle of radius `sod` in the `z = 0` plane; detector
//! opposite at distance `sdd` from the source. For the **flat** detector,
//! pixel `(row, col)` sits at `center + u·û + v·v̂` with `û` the in-plane
//! tangent and `v̂ = ẑ`. For the **curved** detector (third-generation
//! medical CT), columns are equi-angular: `u` is interpreted as arc length
//! `sdd·α` along the cylinder of radius `sdd` centered on the source.

use super::{angles_deg, Ray};

/// Flat (planar) or curved (cylindrical, source-centered) detector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectorShape {
    Flat,
    Curved,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ConeBeam {
    pub nrows: usize,
    pub ncols: usize,
    /// Detector pitch (mm): `du` across columns (arc length if curved),
    /// `dv` across rows.
    pub du: f64,
    pub dv: f64,
    /// Detector center offsets (mm) — the paper's detector shifts.
    pub cu: f64,
    pub cv: f64,
    /// Source-to-object (rotation axis) distance, mm.
    pub sod: f64,
    /// Source-to-detector distance, mm.
    pub sdd: f64,
    pub angles: Vec<f64>,
    pub shape: DetectorShape,
}

impl ConeBeam {
    /// Standard circular cone-beam scan over 360° with a flat detector.
    pub fn standard(
        nviews: usize,
        nrows: usize,
        ncols: usize,
        du: f64,
        dv: f64,
        sod: f64,
        sdd: f64,
    ) -> ConeBeam {
        ConeBeam {
            nrows,
            ncols,
            du,
            dv,
            cu: 0.0,
            cv: 0.0,
            sod,
            sdd,
            angles: angles_deg(nviews, 0.0, 360.0),
            shape: DetectorShape::Flat,
        }
    }

    #[inline]
    pub fn u(&self, col: usize) -> f64 {
        (col as f64 - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu
    }

    #[inline]
    pub fn v(&self, row: usize) -> f64 {
        (row as f64 - (self.nrows as f64 - 1.0) / 2.0) * self.dv + self.cv
    }

    #[inline]
    pub fn col_of_u(&self, u: f64) -> f64 {
        (u - self.cu) / self.du + (self.ncols as f64 - 1.0) / 2.0
    }

    #[inline]
    pub fn row_of_v(&self, v: f64) -> f64 {
        (v - self.cv) / self.dv + (self.nrows as f64 - 1.0) / 2.0
    }

    /// Source position at view `view`.
    #[inline]
    pub fn source(&self, view: usize) -> [f64; 3] {
        let (s, c) = self.angles[view].sin_cos();
        [self.sod * c, self.sod * s, 0.0]
    }

    /// World position of detector pixel `(row, col)` at view `view`.
    pub fn det_pos(&self, view: usize, row: usize, col: usize) -> [f64; 3] {
        self.det_pos_f(view, row as f64, col as f64)
    }

    /// Detector position at *fractional* pixel coordinates.
    pub fn det_pos_f(&self, view: usize, row_f: f64, col_f: f64) -> [f64; 3] {
        let (sphi, cphi) = self.angles[view].sin_cos();
        self.det_pos_with_trig(sphi, cphi, row_f, col_f)
    }

    /// Detector position from precomputed view trig `(sin φ, cos φ)`;
    /// `det_pos_f` delegates here, so cached-trig callers are bit-identical.
    pub fn det_pos_with_trig(&self, sphi: f64, cphi: f64, row_f: f64, col_f: f64) -> [f64; 3] {
        let u = (col_f - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu;
        let v = (row_f - (self.nrows as f64 - 1.0) / 2.0) * self.dv + self.cv;
        match self.shape {
            DetectorShape::Flat => {
                // center = source − sdd·(cos φ, sin φ, 0); û = (−sin φ, cos φ, 0); v̂ = ẑ
                [
                    (self.sod - self.sdd) * cphi - u * sphi,
                    (self.sod - self.sdd) * sphi + u * cphi,
                    v,
                ]
            }
            DetectorShape::Curved => {
                // equi-angular columns on the cylinder of radius sdd around
                // the source: α = u / sdd, rotated about z at the source
                let alpha = u / self.sdd;
                let (sa, ca) = alpha.sin_cos();
                // central-ray direction from source toward rotation center
                let dx = -cphi;
                let dy = -sphi;
                // rotate (dx, dy) by α in-plane
                let rx = dx * ca - dy * sa;
                let ry = dx * sa + dy * ca;
                [
                    self.sod * cphi + self.sdd * rx,
                    self.sod * sphi + self.sdd * ry,
                    v,
                ]
            }
        }
    }

    /// Ray from the source through pixel `(row, col)`.
    pub fn ray(&self, view: usize, row: usize, col: usize) -> Ray {
        self.ray_at(view, row as f64, col as f64)
    }

    /// Ray at *fractional* pixel coordinates (bin-integrated projections).
    pub fn ray_at(&self, view: usize, row_f: f64, col_f: f64) -> Ray {
        let (sphi, cphi) = self.angles[view].sin_cos();
        self.ray_with_trig(sphi, cphi, row_f, col_f)
    }

    /// Ray from precomputed view trig `(sin φ, cos φ)` — the plan/execute
    /// split's execution primitive; `ray_at` delegates here.
    #[inline]
    pub fn ray_with_trig(&self, sphi: f64, cphi: f64, row_f: f64, col_f: f64) -> Ray {
        let s = [self.sod * cphi, self.sod * sphi, 0.0];
        let d = self.det_pos_with_trig(sphi, cphi, row_f, col_f);
        Ray::new(s, [d[0] - s[0], d[1] - s[1], d[2] - s[2]])
    }

    /// Magnification at the rotation axis.
    pub fn magnification(&self) -> f64 {
        self.sdd / self.sod
    }

    /// Half cone angle (radians) subtended by the detector rows.
    pub fn half_cone_angle(&self) -> f64 {
        let vmax = (self.nrows as f64 / 2.0) * self.dv + self.cv.abs();
        (vmax / self.sdd).atan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_central_pixel_opposite_source() {
        let g = ConeBeam::standard(4, 9, 9, 1.0, 1.0, 500.0, 1000.0);
        let s = g.source(0);
        let d = g.det_pos(0, 4, 4);
        assert_eq!(s, [500.0, 0.0, 0.0]);
        assert!((d[0] + 500.0).abs() < 1e-9);
        assert!(d[1].abs() < 1e-9 && d[2].abs() < 1e-9);
    }

    #[test]
    fn curved_central_column_matches_flat() {
        let mut g = ConeBeam::standard(8, 5, 11, 1.0, 1.0, 400.0, 900.0);
        let flat = g.det_pos(3, 2, 5);
        g.shape = DetectorShape::Curved;
        let curved = g.det_pos(3, 2, 5);
        for a in 0..3 {
            assert!((flat[a] - curved[a]).abs() < 1e-9, "axis {a}");
        }
    }

    #[test]
    fn curved_columns_equidistant_from_source() {
        let mut g = ConeBeam::standard(2, 3, 21, 2.0, 1.0, 300.0, 700.0);
        g.shape = DetectorShape::Curved;
        let s = g.source(1);
        for col in 0..21 {
            let d = g.det_pos(1, 1, col);
            let dist = ((d[0] - s[0]).powi(2) + (d[1] - s[1]).powi(2)).sqrt();
            assert!((dist - 700.0).abs() < 1e-9, "col {col}");
        }
    }

    #[test]
    fn ray_passes_through_pixel() {
        let g = ConeBeam::standard(6, 7, 7, 1.5, 1.5, 450.0, 950.0);
        let r = g.ray(2, 1, 6);
        let d = g.det_pos(2, 1, 6);
        // the pixel is at t = |d - source|
        let t = ((d[0] - r.origin[0]).powi(2)
            + (d[1] - r.origin[1]).powi(2)
            + (d[2] - r.origin[2]).powi(2))
        .sqrt();
        let p = r.point(t);
        for a in 0..3 {
            assert!((p[a] - d[a]).abs() < 1e-9);
        }
    }

    #[test]
    fn cone_angle_sane() {
        let g = ConeBeam::standard(1, 64, 64, 1.0, 1.0, 500.0, 1000.0);
        let half = g.half_cone_angle();
        assert!(half > 0.0 && half < 0.1);
        assert!((half - (32.0f64 / 1000.0).atan()).abs() < 1e-12);
    }
}
