//! Parallel-beam geometry (3-D; 2-D is the `nrows = 1` case).
//!
//! Rays at view angle `φ` travel along `d = (−sin φ, cos φ, 0)`; the
//! detector coordinate axes are `û = (cos φ, sin φ, 0)` (columns) and
//! `ẑ` (rows). Supports arbitrary detector shifts (`cu`, `cv`) and
//! non-equispaced angles, per the paper's "flexible specification".

use super::{angles_deg, Ray};

#[derive(Clone, Debug, PartialEq)]
pub struct ParallelBeam {
    pub nrows: usize,
    pub ncols: usize,
    /// Detector pixel pitch (mm): column direction `du`, row direction `dv`.
    pub du: f64,
    pub dv: f64,
    /// Detector center offset (mm) — the paper's horizontal/vertical shift.
    pub cu: f64,
    pub cv: f64,
    /// Projection angles in radians (need not be equispaced).
    pub angles: Vec<f64>,
}

impl ParallelBeam {
    /// Standard 2-D parallel geometry: `nviews` angles over 180°, single
    /// detector row.
    pub fn standard_2d(nviews: usize, ncols: usize, du: f64) -> ParallelBeam {
        ParallelBeam {
            nrows: 1,
            ncols,
            du,
            dv: du,
            cu: 0.0,
            cv: 0.0,
            angles: angles_deg(nviews, 0.0, 180.0),
        }
    }

    /// Standard 3-D parallel geometry over 180°.
    pub fn standard_3d(nviews: usize, nrows: usize, ncols: usize, du: f64, dv: f64) -> ParallelBeam {
        ParallelBeam { nrows, ncols, du, dv, cu: 0.0, cv: 0.0, angles: angles_deg(nviews, 0.0, 180.0) }
    }

    /// Detector column coordinate (mm).
    #[inline]
    pub fn u(&self, col: usize) -> f64 {
        (col as f64 - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu
    }

    /// Detector row coordinate (mm) — equals world `z` for parallel rays.
    #[inline]
    pub fn v(&self, row: usize) -> f64 {
        (row as f64 - (self.nrows as f64 - 1.0) / 2.0) * self.dv + self.cv
    }

    /// Continuous column index for detector coordinate `u` (inverse of
    /// [`Self::u`]) — used by backprojectors.
    #[inline]
    pub fn col_of_u(&self, u: f64) -> f64 {
        (u - self.cu) / self.du + (self.ncols as f64 - 1.0) / 2.0
    }

    #[inline]
    pub fn row_of_v(&self, v: f64) -> f64 {
        (v - self.cv) / self.dv + (self.nrows as f64 - 1.0) / 2.0
    }

    /// The ray through sample `(view, row, col)`. Origin is placed on the
    /// `u`-axis plane; Siddon/Joseph clip to the volume, so any point on
    /// the line is valid.
    pub fn ray(&self, view: usize, row: usize, col: usize) -> Ray {
        self.ray_at(view, row as f64, col as f64)
    }

    /// Ray at *fractional* detector coordinates — used by the
    /// bin-integrated analytic projections (accuracy experiments).
    pub fn ray_at(&self, view: usize, row_f: f64, col_f: f64) -> Ray {
        let phi = self.angles[view];
        let (s, c) = phi.sin_cos();
        self.ray_with_trig(s, c, row_f, col_f)
    }

    /// Ray from precomputed view trig `(sin φ, cos φ)` — the plan/execute
    /// split's execution primitive. `ray_at` delegates here, so a plan
    /// that caches `angles[view].sin_cos()` produces bit-identical rays.
    #[inline]
    pub fn ray_with_trig(&self, s: f64, c: f64, row_f: f64, col_f: f64) -> Ray {
        let u = (col_f - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu;
        let v = (row_f - (self.nrows as f64 - 1.0) / 2.0) * self.dv + self.cv;
        Ray { origin: [u * c, u * s, v], dir: [-s, c, 0.0] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detector_coords_centered() {
        let g = ParallelBeam::standard_2d(10, 5, 2.0);
        assert_eq!(g.u(2), 0.0);
        assert_eq!(g.u(0), -4.0);
        assert_eq!(g.u(4), 4.0);
        assert!((g.col_of_u(-4.0) - 0.0).abs() < 1e-12);
        assert!((g.col_of_u(3.0) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn shift_moves_center() {
        let mut g = ParallelBeam::standard_2d(1, 4, 1.0);
        g.cu = 0.5;
        // centers at -1, 0, 1, 2 mm
        assert_eq!(g.u(0), -1.0);
        assert_eq!(g.u(3), 2.0);
    }

    #[test]
    fn ray_at_zero_angle_points_along_y() {
        let g = ParallelBeam::standard_2d(4, 3, 1.0);
        let r = g.ray(0, 0, 2); // φ=0, u=+1
        assert!((r.dir[0]).abs() < 1e-12);
        assert!((r.dir[1] - 1.0).abs() < 1e-12);
        assert!((r.origin[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ray_perpendicular_to_detector_axis() {
        let g = ParallelBeam::standard_3d(8, 4, 6, 1.0, 1.0);
        for view in 0..8 {
            let r = g.ray(view, 1, 3);
            let phi = g.angles[view];
            let u_hat = [phi.cos(), phi.sin(), 0.0];
            let dot = r.dir[0] * u_hat[0] + r.dir[1] * u_hat[1];
            assert!(dot.abs() < 1e-12, "view {view}");
        }
    }

    #[test]
    fn row_maps_to_world_z() {
        let g = ParallelBeam::standard_3d(1, 5, 3, 1.0, 2.0);
        let r = g.ray(0, 4, 1);
        assert_eq!(r.origin[2], g.v(4));
        assert_eq!(g.v(2), 0.0);
        assert_eq!(g.v(4), 4.0);
    }
}
