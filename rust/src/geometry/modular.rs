//! Modular-beam geometry: arbitrary source/detector pose per view.
//!
//! The paper's third geometry type: "a method to specify arbitrary
//! locations and orientations of a set of source/detector pairs". Each view
//! carries its own source position, detector center and detector axes; the
//! generic-ray Siddon/Joseph projectors consume the resulting rays, so any
//! exotic acquisition (tomosynthesis arcs, irregular multi-source arrays,
//! robot-arm CT) is expressible.

use super::Ray;

/// One source/detector pose.
#[derive(Clone, Debug, PartialEq)]
pub struct ModularView {
    /// X-ray source position (mm).
    pub source: [f64; 3],
    /// Detector center position (mm).
    pub det_center: [f64; 3],
    /// Unit vector along detector columns.
    pub u_axis: [f64; 3],
    /// Unit vector along detector rows.
    pub v_axis: [f64; 3],
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModularBeam {
    pub nrows: usize,
    pub ncols: usize,
    pub du: f64,
    pub dv: f64,
    pub views: Vec<ModularView>,
}

fn normalize(v: [f64; 3]) -> [f64; 3] {
    let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
    [v[0] / n, v[1] / n, v[2] / n]
}

impl ModularBeam {
    /// Build a modular geometry that replicates a circular cone-beam scan —
    /// used by tests to prove modular == cone when poses coincide.
    pub fn from_cone(g: &super::ConeBeam) -> ModularBeam {
        assert!(
            matches!(g.shape, super::DetectorShape::Flat),
            "modular replication requires a flat detector"
        );
        let views = g
            .angles
            .iter()
            .map(|&phi| {
                let (s, c) = phi.sin_cos();
                ModularView {
                    source: [g.sod * c, g.sod * s, 0.0],
                    det_center: [
                        (g.sod - g.sdd) * c - g.cu * s,
                        (g.sod - g.sdd) * s + g.cu * c,
                        g.cv,
                    ],
                    u_axis: [-s, c, 0.0],
                    v_axis: [0.0, 0.0, 1.0],
                }
            })
            .collect();
        ModularBeam { nrows: g.nrows, ncols: g.ncols, du: g.du, dv: g.dv, views }
    }

    /// Validate axes are unit length and (near-)orthogonal.
    pub fn validate(&self) -> Result<(), String> {
        for (i, v) in self.views.iter().enumerate() {
            for (name, a) in [("u_axis", v.u_axis), ("v_axis", v.v_axis)] {
                let n = (a[0] * a[0] + a[1] * a[1] + a[2] * a[2]).sqrt();
                if (n - 1.0).abs() > 1e-6 {
                    return Err(format!("view {i}: {name} not unit length (|a|={n})"));
                }
            }
            let dot = v.u_axis[0] * v.v_axis[0]
                + v.u_axis[1] * v.v_axis[1]
                + v.u_axis[2] * v.v_axis[2];
            if dot.abs() > 1e-6 {
                return Err(format!("view {i}: detector axes not orthogonal (dot={dot})"));
            }
        }
        Ok(())
    }

    /// Normalize axes in place (convenience for hand-built configs).
    pub fn normalize_axes(&mut self) {
        for v in &mut self.views {
            v.u_axis = normalize(v.u_axis);
            v.v_axis = normalize(v.v_axis);
        }
    }

    #[inline]
    pub fn u(&self, col: usize) -> f64 {
        (col as f64 - (self.ncols as f64 - 1.0) / 2.0) * self.du
    }

    #[inline]
    pub fn v(&self, row: usize) -> f64 {
        (row as f64 - (self.nrows as f64 - 1.0) / 2.0) * self.dv
    }

    /// World position of detector pixel `(row, col)` of view `view`.
    pub fn det_pos(&self, view: usize, row: usize, col: usize) -> [f64; 3] {
        self.det_pos_f(view, row as f64, col as f64)
    }

    /// Detector position at *fractional* pixel coordinates.
    pub fn det_pos_f(&self, view: usize, row_f: f64, col_f: f64) -> [f64; 3] {
        let mv = &self.views[view];
        let u = (col_f - (self.ncols as f64 - 1.0) / 2.0) * self.du;
        let v = (row_f - (self.nrows as f64 - 1.0) / 2.0) * self.dv;
        [
            mv.det_center[0] + u * mv.u_axis[0] + v * mv.v_axis[0],
            mv.det_center[1] + u * mv.u_axis[1] + v * mv.v_axis[1],
            mv.det_center[2] + u * mv.u_axis[2] + v * mv.v_axis[2],
        ]
    }

    /// Ray from the view's source through pixel `(row, col)`.
    pub fn ray(&self, view: usize, row: usize, col: usize) -> Ray {
        self.ray_at(view, row as f64, col as f64)
    }

    /// Ray at *fractional* pixel coordinates (bin-integrated projections).
    pub fn ray_at(&self, view: usize, row_f: f64, col_f: f64) -> Ray {
        let s = self.views[view].source;
        let d = self.det_pos_f(view, row_f, col_f);
        Ray::new(s, [d[0] - s[0], d[1] - s[1], d[2] - s[2]])
    }
}

#[cfg(test)]
mod tests {
    use super::super::ConeBeam;
    use super::*;

    #[test]
    fn replicates_cone_rays() {
        let cone = ConeBeam::standard(12, 8, 8, 1.2, 0.9, 420.0, 860.0);
        let modular = ModularBeam::from_cone(&cone);
        modular.validate().unwrap();
        for view in [0, 3, 11] {
            for row in [0, 7] {
                for col in [0, 4, 7] {
                    let a = cone.ray(view, row, col);
                    let b = modular.ray(view, row, col);
                    for ax in 0..3 {
                        assert!((a.origin[ax] - b.origin[ax]).abs() < 1e-9);
                        assert!((a.dir[ax] - b.dir[ax]).abs() < 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn replicates_cone_with_detector_shift() {
        let mut cone = ConeBeam::standard(5, 4, 6, 1.0, 1.0, 300.0, 600.0);
        cone.cu = 2.5;
        cone.cv = -1.0;
        let modular = ModularBeam::from_cone(&cone);
        for view in 0..5 {
            let a = cone.det_pos(view, 2, 3);
            let b = modular.det_pos(view, 2, 3);
            for ax in 0..3 {
                assert!((a[ax] - b[ax]).abs() < 1e-9, "view {view} axis {ax}");
            }
        }
    }

    #[test]
    fn validate_rejects_bad_axes() {
        let mut m = ModularBeam {
            nrows: 1,
            ncols: 1,
            du: 1.0,
            dv: 1.0,
            views: vec![ModularView {
                source: [0.0, 0.0, 0.0],
                det_center: [0.0, -100.0, 0.0],
                u_axis: [2.0, 0.0, 0.0],
                v_axis: [0.0, 0.0, 1.0],
            }],
        };
        assert!(m.validate().is_err());
        m.normalize_axes();
        assert!(m.validate().is_ok());

        m.views[0].v_axis = [0.8, 0.0, 0.6]; // unit but not orthogonal to u
        assert!(m.validate().is_err());
    }
}
