//! CT scanner geometry descriptions — quantitative, in mm, like LEAP.
//!
//! The paper supports three 3-D geometry types — parallel-beam, axial
//! cone-beam (flat or curved detector) and "modular" beam (arbitrary
//! source/detector pose per view) — plus flexible specification: arbitrary
//! detector shifts and non-equispaced projection angles. Fan-beam (the
//! paper's "future release") is included as well.
//!
//! ## Conventions
//!
//! * World coordinates are mm. The rotation axis is `z`.
//! * Voxel `(i, j, k)` center: `x_i = (i − (nx−1)/2)·vx + cx`, etc.
//! * Detector column `c` coordinate: `u_c = (c − (ncols−1)/2)·du + cu`
//!   (so `cu`/`cv` are the paper's "horizontal/vertical detector shift").
//! * View angle `φ`: the parallel-beam ray direction is
//!   `d(φ) = (−sin φ, cos φ, 0)` and the detector axis is
//!   `û(φ) = (cos φ, sin φ, 0)`; for divergent beams the source sits at
//!   `s(φ) = sod·(cos φ, sin φ, 0)` with the detector opposite.
//! * Projections are line integrals: for attenuation in mm⁻¹ and lengths
//!   in mm the sinogram is dimensionless, and values are invariant under
//!   voxel-size refinement (verified by tests in `projector`).

pub mod parallel;
pub mod fan;
pub mod cone;
pub mod modular;
pub mod helical;
pub mod config;

pub use cone::{ConeBeam, DetectorShape};
pub use fan::FanBeam;
pub use helical::HelicalCone;
pub use modular::{ModularBeam, ModularView};
pub use parallel::ParallelBeam;

/// Description of the reconstruction volume grid (sizes in mm).
#[derive(Clone, Debug, PartialEq)]
pub struct VolumeGeometry {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    /// Voxel pitch in mm along x/y/z.
    pub vx: f64,
    pub vy: f64,
    pub vz: f64,
    /// Volume center offset in mm (paper: "volume center position").
    pub cx: f64,
    pub cy: f64,
    pub cz: f64,
}

impl VolumeGeometry {
    /// Cube of `n³` voxels with isotropic `voxel` mm pitch, centered at the
    /// origin.
    pub fn cube(n: usize, voxel: f64) -> VolumeGeometry {
        VolumeGeometry { nx: n, ny: n, nz: n, vx: voxel, vy: voxel, vz: voxel, cx: 0.0, cy: 0.0, cz: 0.0 }
    }

    /// Single-slice (2-D) grid of `nx × ny` voxels.
    pub fn slice2d(nx: usize, ny: usize, voxel: f64) -> VolumeGeometry {
        VolumeGeometry { nx, ny, nz: 1, vx: voxel, vy: voxel, vz: voxel, cx: 0.0, cy: 0.0, cz: 0.0 }
    }

    /// World x of voxel column `i`.
    #[inline]
    pub fn x(&self, i: usize) -> f64 {
        (i as f64 - (self.nx as f64 - 1.0) / 2.0) * self.vx + self.cx
    }
    #[inline]
    pub fn y(&self, j: usize) -> f64 {
        (j as f64 - (self.ny as f64 - 1.0) / 2.0) * self.vy + self.cy
    }
    #[inline]
    pub fn z(&self, k: usize) -> f64 {
        (k as f64 - (self.nz as f64 - 1.0) / 2.0) * self.vz + self.cz
    }

    /// Inverse of [`Self::x`]: continuous voxel index for world x.
    #[inline]
    pub fn ix(&self, x: f64) -> f64 {
        (x - self.cx) / self.vx + (self.nx as f64 - 1.0) / 2.0
    }
    #[inline]
    pub fn iy(&self, y: f64) -> f64 {
        (y - self.cy) / self.vy + (self.ny as f64 - 1.0) / 2.0
    }
    #[inline]
    pub fn iz(&self, z: f64) -> f64 {
        (z - self.cz) / self.vz + (self.nz as f64 - 1.0) / 2.0
    }

    /// Axis-aligned bounding box `([x0,y0,z0], [x1,y1,z1])` of the voxel
    /// grid (outer voxel *edges*, not centers).
    pub fn bounds(&self) -> ([f64; 3], [f64; 3]) {
        let hx = self.nx as f64 * self.vx / 2.0;
        let hy = self.ny as f64 * self.vy / 2.0;
        let hz = self.nz as f64 * self.vz / 2.0;
        (
            [self.cx - hx, self.cy - hy, self.cz - hz],
            [self.cx + hx, self.cy + hy, self.cz + hz],
        )
    }

    pub fn num_voxels(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Radius (mm) of the inscribed field-of-view cylinder.
    pub fn fov_radius(&self) -> f64 {
        0.5 * (self.nx as f64 * self.vx).min(self.ny as f64 * self.vy)
    }
}

/// A ray: `p(t) = origin + t · dir`, `dir` unit-length, t in mm.
#[derive(Clone, Copy, Debug)]
pub struct Ray {
    pub origin: [f64; 3],
    pub dir: [f64; 3],
}

impl Ray {
    pub fn new(origin: [f64; 3], dir: [f64; 3]) -> Ray {
        let n = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
        Ray { origin, dir: [dir[0] / n, dir[1] / n, dir[2] / n] }
    }

    #[inline]
    pub fn point(&self, t: f64) -> [f64; 3] {
        [
            self.origin[0] + t * self.dir[0],
            self.origin[1] + t * self.dir[1],
            self.origin[2] + t * self.dir[2],
        ]
    }
}

/// Evenly spaced view angles in radians over `[start, start + range)`
/// degrees — `range = 180` for parallel, `360` for cone, matching Table 1.
pub fn angles_deg(nviews: usize, start_deg: f64, range_deg: f64) -> Vec<f64> {
    (0..nviews)
        .map(|i| (start_deg + range_deg * i as f64 / nviews as f64).to_radians())
        .collect()
}

/// The scanner geometry union passed around the library and the CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Geometry {
    Parallel(ParallelBeam),
    Fan(FanBeam),
    Cone(ConeBeam),
    Modular(ModularBeam),
}

impl Geometry {
    pub fn nviews(&self) -> usize {
        match self {
            Geometry::Parallel(g) => g.angles.len(),
            Geometry::Fan(g) => g.angles.len(),
            Geometry::Cone(g) => g.angles.len(),
            Geometry::Modular(g) => g.views.len(),
        }
    }

    pub fn nrows(&self) -> usize {
        match self {
            Geometry::Parallel(g) => g.nrows,
            Geometry::Fan(_) => 1,
            Geometry::Cone(g) => g.nrows,
            Geometry::Modular(g) => g.nrows,
        }
    }

    pub fn ncols(&self) -> usize {
        match self {
            Geometry::Parallel(g) => g.ncols,
            Geometry::Fan(g) => g.ncols,
            Geometry::Cone(g) => g.ncols,
            Geometry::Modular(g) => g.ncols,
        }
    }

    /// The ray through detector sample `(view, row, col)`.
    pub fn ray(&self, view: usize, row: usize, col: usize) -> Ray {
        match self {
            Geometry::Parallel(g) => g.ray(view, row, col),
            Geometry::Fan(g) => g.ray(view, col),
            Geometry::Cone(g) => g.ray(view, row, col),
            Geometry::Modular(g) => g.ray(view, row, col),
        }
    }

    /// Ray at *fractional* detector coordinates — the sampling primitive
    /// for bin-integrated analytic projections.
    pub fn ray_at(&self, view: usize, row_f: f64, col_f: f64) -> Ray {
        match self {
            Geometry::Parallel(g) => g.ray_at(view, row_f, col_f),
            Geometry::Fan(g) => g.ray_at(view, col_f),
            Geometry::Cone(g) => g.ray_at(view, row_f, col_f),
            Geometry::Modular(g) => g.ray_at(view, row_f, col_f),
        }
    }

    /// Human-readable name (used by CLI/telemetry).
    pub fn kind(&self) -> &'static str {
        match self {
            Geometry::Parallel(_) => "parallel",
            Geometry::Fan(_) => "fan",
            Geometry::Cone(_) => "cone",
            Geometry::Modular(_) => "modular",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn voxel_centers_symmetric() {
        let vg = VolumeGeometry::cube(4, 2.0);
        // centers at -3, -1, 1, 3 mm
        assert_eq!(vg.x(0), -3.0);
        assert_eq!(vg.x(3), 3.0);
        assert_eq!(vg.x(1) + vg.x(2), 0.0);
    }

    #[test]
    fn ix_inverts_x() {
        let vg = VolumeGeometry { cx: 5.0, ..VolumeGeometry::cube(7, 0.5) };
        for i in 0..7 {
            let xi = vg.x(i);
            assert!((vg.ix(xi) - i as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn bounds_are_edges() {
        let vg = VolumeGeometry::cube(4, 2.0);
        let (lo, hi) = vg.bounds();
        assert_eq!(lo, [-4.0, -4.0, -4.0]);
        assert_eq!(hi, [4.0, 4.0, 4.0]);
    }

    #[test]
    fn angles_cover_range() {
        let a = angles_deg(4, 0.0, 180.0);
        assert_eq!(a.len(), 4);
        assert!((a[0] - 0.0).abs() < 1e-12);
        assert!((a[1] - 45f64.to_radians()).abs() < 1e-12);
        assert!((a[3] - 135f64.to_radians()).abs() < 1e-12);
    }

    #[test]
    fn ray_dir_normalized() {
        let r = Ray::new([0.0, 0.0, 0.0], [3.0, 4.0, 0.0]);
        assert!((r.dir[0] - 0.6).abs() < 1e-12);
        assert!((r.dir[1] - 0.8).abs() < 1e-12);
        let p = r.point(5.0);
        assert!((p[0] - 3.0).abs() < 1e-12 && (p[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fov_radius() {
        let vg = VolumeGeometry::slice2d(100, 50, 1.0);
        assert_eq!(vg.fov_radius(), 25.0);
    }
}
