//! Dense array containers shared by every layer.
//!
//! Layouts match the paper (and the PJRT artifacts) exactly:
//! * volumes `[z][y][x]`, x fastest — `Vol3`
//! * sinograms `[view][row][col]`, col fastest — `Sino`
//!
//! Both are contiguous `f32`, so they can be handed to the runtime (and to
//! a GPU in the original LEAP) without copies. 2-D problems use `nz = 1` /
//! `nrows = 1`.

/// A 3-D volume of x-ray linear attenuation coefficients (mm⁻¹).
#[derive(Clone, Debug, PartialEq)]
pub struct Vol3 {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub data: Vec<f32>,
}

impl Vol3 {
    /// Zero-filled volume.
    pub fn zeros(nx: usize, ny: usize, nz: usize) -> Vol3 {
        Vol3 { nx, ny, nz, data: vec![0.0; nx * ny * nz] }
    }

    /// Wrap an existing buffer (must have length `nx·ny·nz`).
    pub fn from_vec(nx: usize, ny: usize, nz: usize, data: Vec<f32>) -> Vol3 {
        assert_eq!(data.len(), nx * ny * nz, "Vol3 buffer size mismatch");
        Vol3 { nx, ny, nz, data }
    }

    /// 2-D convenience: a single-slice volume.
    pub fn zeros2d(nx: usize, ny: usize) -> Vol3 {
        Vol3::zeros(nx, ny, 1)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, i: usize, j: usize, k: usize) -> usize {
        debug_assert!(i < self.nx && j < self.ny && k < self.nz);
        (k * self.ny + j) * self.nx + i
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize, k: usize) -> f32 {
        self.data[self.idx(i, j, k)]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize, k: usize) -> &mut f32 {
        let idx = self.idx(i, j, k);
        &mut self.data[idx]
    }

    /// Immutable view of slice `k` (`ny·nx` elements).
    pub fn slice(&self, k: usize) -> &[f32] {
        let n = self.nx * self.ny;
        &self.data[k * n..(k + 1) * n]
    }

    pub fn slice_mut(&mut self, k: usize) -> &mut [f32] {
        let n = self.nx * self.ny;
        &mut self.data[k * n..(k + 1) * n]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Sum of all voxels (f64 accumulation).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn min_max(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }

    /// Memory footprint in bytes (the Table-1 "one copy" number).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// A stack of projections: `nviews` views of `nrows × ncols` detector
/// samples (line integrals, dimensionless).
#[derive(Clone, Debug, PartialEq)]
pub struct Sino {
    pub nviews: usize,
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<f32>,
}

impl Sino {
    pub fn zeros(nviews: usize, nrows: usize, ncols: usize) -> Sino {
        Sino { nviews, nrows, ncols, data: vec![0.0; nviews * nrows * ncols] }
    }

    pub fn from_vec(nviews: usize, nrows: usize, ncols: usize, data: Vec<f32>) -> Sino {
        assert_eq!(data.len(), nviews * nrows * ncols, "Sino buffer size mismatch");
        Sino { nviews, nrows, ncols, data }
    }

    /// 2-D convenience: single-row detector.
    pub fn zeros2d(nviews: usize, ncols: usize) -> Sino {
        Sino::zeros(nviews, 1, ncols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, view: usize, row: usize, col: usize) -> usize {
        debug_assert!(view < self.nviews && row < self.nrows && col < self.ncols);
        (view * self.nrows + row) * self.ncols + col
    }

    #[inline]
    pub fn at(&self, view: usize, row: usize, col: usize) -> f32 {
        self.data[self.idx(view, row, col)]
    }

    #[inline]
    pub fn at_mut(&mut self, view: usize, row: usize, col: usize) -> &mut f32 {
        let idx = self.idx(view, row, col);
        &mut self.data[idx]
    }

    /// One view (`nrows·ncols` elements).
    pub fn view(&self, v: usize) -> &[f32] {
        let n = self.nrows * self.ncols;
        &self.data[v * n..(v + 1) * n]
    }

    pub fn view_mut(&mut self, v: usize) -> &mut [f32] {
        let n = self.nrows * self.ncols;
        &mut self.data[v * n..(v + 1) * n]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vol_layout_x_fastest() {
        let mut v = Vol3::zeros(3, 4, 5);
        *v.at_mut(1, 0, 0) = 1.0;
        *v.at_mut(0, 1, 0) = 2.0;
        *v.at_mut(0, 0, 1) = 3.0;
        assert_eq!(v.data[1], 1.0);
        assert_eq!(v.data[3], 2.0);
        assert_eq!(v.data[12], 3.0);
    }

    #[test]
    fn sino_layout_col_fastest() {
        let mut s = Sino::zeros(2, 3, 4);
        *s.at_mut(0, 0, 1) = 1.0;
        *s.at_mut(0, 1, 0) = 2.0;
        *s.at_mut(1, 0, 0) = 3.0;
        assert_eq!(s.data[1], 1.0);
        assert_eq!(s.data[4], 2.0);
        assert_eq!(s.data[12], 3.0);
    }

    #[test]
    fn slices_are_views() {
        let mut v = Vol3::zeros(2, 2, 3);
        v.slice_mut(1)[0] = 7.0;
        assert_eq!(v.at(0, 0, 1), 7.0);
        assert_eq!(v.slice(1)[0], 7.0);
    }

    #[test]
    fn sum_and_minmax() {
        let v = Vol3::from_vec(2, 1, 1, vec![-1.0, 3.0]);
        assert_eq!(v.sum(), 2.0);
        assert_eq!(v.min_max(), (-1.0, 3.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Vol3::from_vec(2, 2, 2, vec![0.0; 7]);
    }

    #[test]
    fn nbytes_one_copy() {
        // Table 1's memory model: one copy of volume + one of projections.
        let v = Vol3::zeros(64, 64, 64);
        assert_eq!(v.nbytes(), 64 * 64 * 64 * 4);
    }
}
