//! Reduced-precision storage tiers: software f16 / bf16 conversion and
//! tier-quantized buffers — no external crates, no hardware intrinsics.
//!
//! The paper's stated edge over peer libraries is a minimal memory
//! footprint; peers make the same trade explicitly (TorchRadon ships
//! half-precision projection kernels, CTorch benchmarks fp16 *storage*
//! with fp32 *accumulation* as the practical operating point). This
//! module supplies that seam for the projector core:
//!
//! * [`StorageTier`] names the at-rest precision of bulk data —
//!   sinograms fed to backprojection and the cone-beam SF plan's
//!   detector-column weight arena. `F32` is the exact tier and a strict
//!   no-op on every code path.
//! * Conversions are **round-to-nearest-even** encodes plus exact
//!   decodes, bit-exact against the IEEE 754 binary16 / bfloat16
//!   layouts (exhaustively round-trip-tested over all 2^16 patterns).
//! * **Accumulation always stays f32.** Tiered values are decoded to
//!   f32 registers inside the kernels; only storage narrows. Within a
//!   tier results are bit-identical across thread counts (the PR 2/6
//!   determinism story), and toleranced against the f32 tier.
//!
//! Selection threads end-to-end like PR 6's backends: typed
//! [`crate::ScanBuilder::storage_tier`] knob, `LEAP_STORAGE` env
//! default, `"storage"` session meta on the v2 wire, and a plan-cache
//! key component.

use std::sync::OnceLock;

/// At-rest precision of bulk projector data (sinograms, plan weight
/// tables). Compute and accumulation are always f32 regardless of tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum StorageTier {
    /// Exact storage — the reference tier; every path is unchanged.
    #[default]
    F32,
    /// IEEE 754 binary16: 10 mantissa bits (~3 significant decimal
    /// digits, max ±65504). Accuracy class ~1e-4..1e-3 relative l2.
    F16,
    /// bfloat16: 7 mantissa bits, full f32 exponent range. Accuracy
    /// class ~1e-3..1e-2 relative l2; immune to overflow at f16's edge.
    Bf16,
}

impl StorageTier {
    /// Stable lowercase name — used in plan-cache keys, wire meta, env
    /// parsing and telemetry.
    pub fn name(&self) -> &'static str {
        match self {
            StorageTier::F32 => "f32",
            StorageTier::F16 => "f16",
            StorageTier::Bf16 => "bf16",
        }
    }

    /// Parse a tier name (the inverse of [`StorageTier::name`]).
    pub fn parse(s: &str) -> Option<StorageTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" | "single" => Some(StorageTier::F32),
            "f16" | "fp16" | "float16" | "half" => Some(StorageTier::F16),
            "bf16" | "bfloat16" => Some(StorageTier::Bf16),
            _ => None,
        }
    }

    /// Every tier, reference first.
    pub fn all() -> [StorageTier; 3] {
        [StorageTier::F32, StorageTier::F16, StorageTier::Bf16]
    }

    /// Bytes per stored sample.
    pub fn bytes_per_sample(&self) -> usize {
        match self {
            StorageTier::F32 => 4,
            StorageTier::F16 | StorageTier::Bf16 => 2,
        }
    }

    /// Encode one f32 into this tier's 16-bit pattern (RNE). Panics in
    /// debug builds if called on the `F32` tier, which has no 16-bit form.
    #[inline]
    pub fn encode_bits(&self, x: f32) -> u16 {
        match self {
            StorageTier::F32 => {
                debug_assert!(false, "F32 tier has no 16-bit encoding");
                0
            }
            StorageTier::F16 => f32_to_f16_bits(x),
            StorageTier::Bf16 => f32_to_bf16_bits(x),
        }
    }

    /// Decode one 16-bit pattern of this tier to f32 (exact).
    #[inline]
    pub fn decode_bits(&self, bits: u16) -> f32 {
        match self {
            StorageTier::F32 => {
                debug_assert!(false, "F32 tier has no 16-bit encoding");
                0.0
            }
            StorageTier::F16 => f16_bits_to_f32(bits),
            StorageTier::Bf16 => f32::from_bits((bits as u32) << 16),
        }
    }

    /// Round-trip one value through this tier's storage format: the
    /// value a kernel's f32 register holds after decoding tiered data.
    /// Identity on the `F32` tier.
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            StorageTier::F32 => x,
            _ => self.decode_bits(self.encode_bits(x)),
        }
    }

    /// Round-trip every element of `data` in place. No-op on `F32`.
    pub fn quantize_slice(&self, data: &mut [f32]) {
        if *self == StorageTier::F32 {
            return;
        }
        for v in data.iter_mut() {
            *v = self.decode_bits(self.encode_bits(*v));
        }
    }
}

// ---------------------------------------------------------------------------
// software binary16 (f16)
// ---------------------------------------------------------------------------

/// f32 → IEEE 754 binary16 bit pattern, round-to-nearest-even.
/// Handles normals, subnormals, ±0, ±inf and NaN (NaN stays NaN).
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN: keep NaN quiet by forcing a mantissa bit
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    // re-bias: f32 bias 127 → f16 bias 15
    let e = exp - 112;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows past half the smallest subnormal
        }
        // subnormal: shift the 24-bit significand (implicit bit set)
        // right so the result scales by 2^-24 per unit
        let full = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = full >> shift;
        let round_bit = 1u32 << (shift - 1);
        let rem = full & ((1u32 << shift) - 1);
        let mut h = half as u16;
        if rem > round_bit || (rem == round_bit && (h & 1) != 0) {
            h += 1; // may carry into the exponent — that is correct RNE
        }
        return sign | h;
    }
    // normal: drop 13 mantissa bits with RNE; a carry out of the
    // mantissa rolls into the exponent (up to inf), which is correct
    let mut h = ((e as u32) << 10 | (man >> 13)) as u16;
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) != 0) {
        h = h.wrapping_add(1);
    }
    sign | h
}

/// IEEE 754 binary16 bit pattern → f32 (exact: every f16 value is
/// representable in f32).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: value = man · 2^-24; normalize into f32
            let n = 31 - man.leading_zeros(); // MSB position, 0..=9
            sign | ((n + 103) << 23) | ((man << (23 - n)) & 0x007f_ffff)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// software bfloat16
// ---------------------------------------------------------------------------

/// f32 → bfloat16 bit pattern, round-to-nearest-even (truncation of the
/// low 16 bits with carry). NaN stays a quiet NaN.
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let rem = bits & 0xffff;
    let mut h = (bits >> 16) as u16;
    if rem > 0x8000 || (rem == 0x8000 && (h & 1) != 0) {
        h = h.wrapping_add(1); // carry may roll a large finite into inf — correct RNE
    }
    h
}

/// bfloat16 bit pattern → f32 (exact by construction).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// tiered buffers
// ---------------------------------------------------------------------------

/// A buffer of samples held at a storage tier's precision. `F32` keeps
/// the data exact; the 16-bit tiers store encoded patterns and decode
/// to f32 on read.
#[derive(Clone, Debug)]
pub enum TieredBuf {
    F32(Vec<f32>),
    Half { tier: StorageTier, bits: Vec<u16> },
}

impl TieredBuf {
    /// Encode an f32 slice into tier storage.
    pub fn encode(tier: StorageTier, data: &[f32]) -> TieredBuf {
        match tier {
            StorageTier::F32 => TieredBuf::F32(data.to_vec()),
            t => TieredBuf::Half { tier: t, bits: data.iter().map(|&x| t.encode_bits(x)).collect() },
        }
    }

    pub fn tier(&self) -> StorageTier {
        match self {
            TieredBuf::F32(_) => StorageTier::F32,
            TieredBuf::Half { tier, .. } => *tier,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TieredBuf::F32(d) => d.len(),
            TieredBuf::Half { bits, .. } => bits.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of sample storage (excluding the enum header).
    pub fn storage_bytes(&self) -> usize {
        self.len() * self.tier().bytes_per_sample()
    }

    /// Decode one sample to f32.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self {
            TieredBuf::F32(d) => d[i],
            TieredBuf::Half { tier, bits } => tier.decode_bits(bits[i]),
        }
    }

    /// Decode `range` into `out` (which must have the range's length).
    pub fn decode_range_into(&self, start: usize, out: &mut [f32]) {
        match self {
            TieredBuf::F32(d) => out.copy_from_slice(&d[start..start + out.len()]),
            TieredBuf::Half { tier, bits } => {
                for (o, &b) in out.iter_mut().zip(&bits[start..start + out.len()]) {
                    *o = tier.decode_bits(b);
                }
            }
        }
    }

    /// Decode the whole buffer to a fresh f32 vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.decode_range_into(0, &mut out);
        out
    }
}

/// A sinogram held at a storage tier's precision — the "tiered storage
/// variant" of [`crate::array::Sino`]. Layout matches `Sino`
/// (view-major, then row, then column); decode is exact, so
/// `from_sino → to_sino` equals quantizing every sample through the
/// tier.
#[derive(Clone, Debug)]
pub struct TieredSino {
    pub nviews: usize,
    pub nrows: usize,
    pub ncols: usize,
    pub data: TieredBuf,
}

impl TieredSino {
    /// Encode a sinogram into tier storage.
    pub fn from_sino(tier: StorageTier, sino: &crate::array::Sino) -> TieredSino {
        TieredSino {
            nviews: sino.nviews,
            nrows: sino.nrows,
            ncols: sino.ncols,
            data: TieredBuf::encode(tier, &sino.data),
        }
    }

    pub fn tier(&self) -> StorageTier {
        self.data.tier()
    }

    /// Bytes of sample storage.
    pub fn storage_bytes(&self) -> usize {
        self.data.storage_bytes()
    }

    /// Decode the full sinogram back to f32.
    pub fn to_sino(&self) -> crate::array::Sino {
        let mut s = crate::array::Sino::zeros(self.nviews, self.nrows, self.ncols);
        self.data.decode_range_into(0, &mut s.data);
        s
    }

    /// Decode one view's slab into `out` (`nrows · ncols` samples).
    pub fn view_into(&self, view: usize, out: &mut [f32]) {
        let slab = self.nrows * self.ncols;
        assert_eq!(out.len(), slab);
        self.data.decode_range_into(view * slab, out);
    }
}

// ---------------------------------------------------------------------------
// process default (env-resolved, like backend::default_kind)
// ---------------------------------------------------------------------------

/// Parse `LEAP_STORAGE` leniently: unset or unrecognized → `None`
/// (callers fall back to [`StorageTier::F32`]). Mirrors
/// `backend::kind_from_env`.
pub fn tier_from_env() -> Option<StorageTier> {
    std::env::var("LEAP_STORAGE").ok().and_then(|s| StorageTier::parse(&s))
}

/// The process-default storage tier: `LEAP_STORAGE` if set and valid,
/// else `F32`. Resolved once.
pub fn default_tier() -> StorageTier {
    static DEFAULT: OnceLock<StorageTier> = OnceLock::new();
    *DEFAULT.get_or_init(|| tier_from_env().unwrap_or(StorageTier::F32))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trip_is_bit_exact_for_all_patterns() {
        // every binary16 value decodes to an exactly-representable f32;
        // re-encoding must return the identical bits (NaNs: stay NaN)
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan(), "{h:#06x}");
                continue;
            }
            assert_eq!(f32_to_f16_bits(x), h, "pattern {h:#06x} decoded to {x}");
        }
    }

    #[test]
    fn bf16_round_trip_is_bit_exact_for_all_patterns() {
        for h in 0..=u16::MAX {
            let x = bf16_bits_to_f32(h);
            if x.is_nan() {
                assert!(bf16_bits_to_f32(f32_to_bf16_bits(x)).is_nan(), "{h:#06x}");
                continue;
            }
            assert_eq!(f32_to_bf16_bits(x), h, "pattern {h:#06x} decoded to {x}");
        }
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow → inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8); // smallest subnormal
        assert_eq!(f16_bits_to_f32(0x0400), 6.103_515_6e-5); // smallest normal
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001);
        // below half the smallest subnormal → 0
        assert_eq!(f32_to_f16_bits(1.0e-8), 0x0000);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10): ties go to the even mantissa (1.0)
        let halfway = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway), 0x3c00);
        // just above the tie rounds up
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20)), 0x3c01);
        // (1 + 2^-10) + 2^-11 is halfway between odd 0x3c01 and even 0x3c02
        let tie_up = 1.0 + 2.0f32.powi(-10) + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie_up), 0x3c02);
    }

    #[test]
    fn bf16_known_values_and_rne() {
        assert_eq!(f32_to_bf16_bits(0.0), 0x0000);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(-1.0), 0xbf80);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        // f32::MAX rounds up past bf16 max → inf (RNE carry)
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
        // 1 + 2^-8 is halfway between 1.0 and 1 + 2^-7: tie to even
        assert_eq!(f32_to_bf16_bits(1.0 + 2.0f32.powi(-8)), 0x3f80);
        assert_eq!(f32_to_bf16_bits(1.0 + 2.0f32.powi(-8) + 2.0f32.powi(-16)), 0x3f81);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn quantize_error_is_bounded_per_tier() {
        // RNE quantization error ≤ half a ULP: relative ≤ 2^-11 (f16)
        // and ≤ 2^-8 (bf16) for normal-range values
        let mut rng = crate::util::rng::Rng::new(612);
        let mut xs = vec![0.0f32; 4096];
        rng.fill_uniform(&mut xs, -100.0, 100.0);
        for (tier, bound) in [(StorageTier::F16, 2.0f64.powi(-11)), (StorageTier::Bf16, 2.0f64.powi(-8))]
        {
            for &x in &xs {
                let q = tier.quantize(x);
                let rel = ((q as f64 - x as f64) / (x as f64).abs().max(1e-12)).abs();
                assert!(rel <= bound, "{}: {x} → {q} rel {rel}", tier.name());
            }
        }
        // F32 is the identity, bit for bit
        for &x in &xs {
            assert_eq!(StorageTier::F32.quantize(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        // storing already-tiered data must not drift: decode(encode(x))
        // is a fixed point of the round-trip
        let mut rng = crate::util::rng::Rng::new(613);
        let mut xs = vec![0.0f32; 1024];
        rng.fill_uniform(&mut xs, -10.0, 10.0);
        for tier in [StorageTier::F16, StorageTier::Bf16] {
            for &x in &xs {
                let q = tier.quantize(x);
                assert_eq!(tier.quantize(q).to_bits(), q.to_bits(), "{}: {x}", tier.name());
            }
        }
    }

    #[test]
    fn tier_names_parse_and_round_trip() {
        for tier in StorageTier::all() {
            assert_eq!(StorageTier::parse(tier.name()), Some(tier));
        }
        assert_eq!(StorageTier::parse("FP16"), Some(StorageTier::F16));
        assert_eq!(StorageTier::parse("half"), Some(StorageTier::F16));
        assert_eq!(StorageTier::parse("bfloat16"), Some(StorageTier::Bf16));
        assert_eq!(StorageTier::parse(" single "), Some(StorageTier::F32));
        assert_eq!(StorageTier::parse("fp8"), None);
        assert_eq!(StorageTier::default(), StorageTier::F32);
    }

    #[test]
    fn tiered_buf_encodes_decodes_and_reports_bytes() {
        let mut rng = crate::util::rng::Rng::new(614);
        let mut xs = vec![0.0f32; 257];
        rng.fill_uniform(&mut xs, -1.0, 1.0);
        for tier in StorageTier::all() {
            let buf = TieredBuf::encode(tier, &xs);
            assert_eq!(buf.tier(), tier);
            assert_eq!(buf.len(), xs.len());
            assert_eq!(buf.storage_bytes(), xs.len() * tier.bytes_per_sample());
            let decoded = buf.decode();
            for (i, (&x, &d)) in xs.iter().zip(&decoded).enumerate() {
                assert_eq!(d.to_bits(), tier.quantize(x).to_bits(), "{} idx {i}", tier.name());
                assert_eq!(buf.get(i).to_bits(), d.to_bits());
            }
            // ranged decode matches the full decode
            let mut mid = vec![0.0f32; 100];
            buf.decode_range_into(57, &mut mid);
            assert_eq!(&decoded[57..157], &mid[..]);
        }
    }

    #[test]
    fn tiered_sino_round_trips_and_halves_storage() {
        let mut s = crate::array::Sino::zeros(3, 4, 5);
        let mut rng = crate::util::rng::Rng::new(615);
        rng.fill_uniform(&mut s.data, -2.0, 2.0);
        for tier in [StorageTier::F16, StorageTier::Bf16] {
            let t = TieredSino::from_sino(tier, &s);
            assert_eq!(t.storage_bytes() * 2, s.data.len() * 4);
            let back = t.to_sino();
            let mut want = s.clone();
            tier.quantize_slice(&mut want.data);
            assert_eq!(back.data, want.data, "{}", tier.name());
            // per-view decode matches the full decode
            let mut view = vec![0.0f32; 20];
            t.view_into(1, &mut view);
            assert_eq!(&back.data[20..40], &view[..]);
        }
        let exact = TieredSino::from_sino(StorageTier::F32, &s);
        assert_eq!(exact.to_sino().data, s.data);
    }

    #[test]
    fn env_parsing_is_lenient() {
        assert_eq!(StorageTier::parse("nonsense"), None);
        // tier_from_env with garbage set is exercised in integration
        // tests (env is process-global); here we only pin the contract
        // that default_tier() always yields a valid tier
        let t = default_tier();
        assert!(StorageTier::all().contains(&t));
    }
}
