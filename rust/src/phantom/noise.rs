//! Transmission noise model: Beer-Lambert photon statistics for realistic
//! measured sinograms (the data the paper's training pipelines consume).
//!
//! `I = Poisson(I0 · exp(−p))` per detector sample, re-logged to a noisy
//! line integral `p̂ = ln(I0 / max(I, 1))`. Deterministic per seed.

use crate::array::Sino;
use crate::util::rng::Rng;

/// Sample a Poisson variate (Knuth for small λ, normal approx for large).
pub fn poisson(rng: &mut Rng, lambda: f64) -> f64 {
    if lambda <= 0.0 {
        return 0.0;
    }
    if lambda > 50.0 {
        // normal approximation with continuity correction
        return (lambda + lambda.sqrt() * rng.normal()).round().max(0.0);
    }
    let l = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k as f64;
        }
        k += 1;
        if k > 10_000 {
            return lambda; // numerical guard
        }
    }
}

/// Apply transmission (Poisson) noise to a sinogram of line integrals.
/// `i0` is the unattenuated photon count per detector sample.
pub fn transmission_noise(sino: &Sino, i0: f64, seed: u64) -> Sino {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
    let mut out = sino.clone();
    for v in out.data.iter_mut() {
        let counts = poisson(&mut rng, i0 * (-(*v as f64)).exp());
        *v = (i0 / counts.max(1.0)).ln() as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_moments() {
        let mut rng = Rng::new(4);
        for lambda in [0.5f64, 5.0, 200.0] {
            let n = 4000;
            let mut mean = 0.0;
            let mut var = 0.0;
            for _ in 0..n {
                let x = poisson(&mut rng, lambda);
                mean += x;
                var += x * x;
            }
            mean /= n as f64;
            var = var / n as f64 - mean * mean;
            assert!((mean - lambda).abs() < 0.1 * lambda.max(1.0), "λ={lambda} mean {mean}");
            assert!((var - lambda).abs() < 0.25 * lambda.max(1.0), "λ={lambda} var {var}");
        }
    }

    #[test]
    fn noise_shrinks_with_dose() {
        let mut sino = Sino::zeros2d(10, 50);
        sino.fill(1.0); // line integral of 1
        let low = transmission_noise(&sino, 1e3, 7);
        let high = transmission_noise(&sino, 1e6, 7);
        let dev = |s: &Sino| {
            (s.data.iter().map(|&v| ((v - 1.0) as f64).powi(2)).sum::<f64>() / s.len() as f64)
                .sqrt()
        };
        assert!(dev(&high) < 0.5 * dev(&low), "{} vs {}", dev(&high), dev(&low));
        assert!(dev(&high) > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut sino = Sino::zeros2d(4, 16);
        sino.fill(0.5);
        let a = transmission_noise(&sino, 1e4, 11);
        let b = transmission_noise(&sino, 1e4, 11);
        let c = transmission_noise(&sino, 1e4, 12);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn zero_attenuation_stays_near_zero() {
        let sino = Sino::zeros2d(2, 32); // p = 0 → I ≈ I0
        let noisy = transmission_noise(&sino, 1e5, 3);
        for &v in &noisy.data {
            assert!(v.abs() < 0.05, "{v}");
        }
    }
}
