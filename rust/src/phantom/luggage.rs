//! Randomized "luggage" phantoms — the ALERT airport-bag dataset stand-in.
//!
//! The paper's Figure-3 experiment uses the ALERT automated-threat-
//! recognition luggage CT dataset (190 bags, 512², 720 parallel views),
//! which is access-controlled. Per DESIGN.md §6 we substitute a generative
//! model with matched statistics: a rounded-rectangular suitcase shell
//! containing 6–20 randomly posed objects (ellipses, rectangles, high-
//! density "threat-like" items, low-density clothing blobs). Piecewise-
//! constant contents with sharp edges are exactly the regime where
//! limited-angle artifacts appear and data-consistency refinement helps.
//!
//! Bags are generated from a seed: `bag(seed)` is deterministic, and the
//! train/test split of the experiment is just disjoint seed ranges.

use super::{Phantom, Shape};
use crate::util::rng::Rng;

/// Parameters of the bag generator. Defaults mimic a carry-on scanned at
/// ~0.8 mm resolution in a 512 mm field of view.
#[derive(Clone, Debug)]
pub struct LuggageParams {
    /// Half-width/height range of the case (mm).
    pub case_half_w: (f64, f64),
    pub case_half_h: (f64, f64),
    /// Attenuation of the shell and its thickness (mm).
    pub shell_mu: f64,
    pub shell_thickness: f64,
    /// Number of content objects.
    pub objects: (usize, usize),
    /// Content attenuation range (mm⁻¹); water ≈ 0.02 at ~60 keV.
    pub mu_range: (f64, f64),
    /// Probability of a high-density ("metal/threat") insert per bag.
    pub threat_prob: f64,
    pub threat_mu: f64,
}

impl Default for LuggageParams {
    fn default() -> Self {
        LuggageParams {
            case_half_w: (140.0, 200.0),
            case_half_h: (90.0, 150.0),
            shell_mu: 0.015,
            shell_thickness: 6.0,
            objects: (6, 20),
            mu_range: (0.004, 0.035),
            threat_prob: 0.5,
            threat_mu: 0.12,
        }
    }
}

/// Generate one bag phantom from a seed.
pub fn bag(seed: u64, p: &LuggageParams) -> Phantom {
    let mut rng = Rng::new(seed ^ 0x1bad_b002_cafe_f00d);
    let hw = rng.range(p.case_half_w.0, p.case_half_w.1);
    let hh = rng.range(p.case_half_h.0, p.case_half_h.1);
    let tilt = rng.range(-0.12, 0.12);

    let mut shapes = Vec::new();
    // suitcase shell: outer box minus inner box
    shapes.push(Shape::rect2d(0.0, 0.0, hw, hh, tilt, p.shell_mu));
    shapes.push(Shape::rect2d(
        0.0,
        0.0,
        hw - p.shell_thickness,
        hh - p.shell_thickness,
        tilt,
        -p.shell_mu,
    ));

    let n = p.objects.0 + rng.below(p.objects.1 - p.objects.0 + 1);
    for _ in 0..n {
        // keep object centers inside ~80% of the inner case
        let cx = rng.range(-0.8, 0.8) * (hw - p.shell_thickness);
        let cy = rng.range(-0.8, 0.8) * (hh - p.shell_thickness);
        let mu = rng.range(p.mu_range.0, p.mu_range.1);
        let phi = rng.range(0.0, std::f64::consts::PI);
        let a = rng.range(8.0, 0.35 * hw.min(hh));
        let b = rng.range(8.0, 0.35 * hw.min(hh));
        if rng.f64() < 0.5 {
            shapes.push(Shape::ellipse2d(cx, cy, a, b, phi, mu));
        } else {
            shapes.push(Shape::rect2d(cx, cy, a, b, phi, mu));
        }
    }

    if rng.f64() < p.threat_prob {
        // small, dense, elongated object (blade/detonator-like)
        let cx = rng.range(-0.6, 0.6) * hw;
        let cy = rng.range(-0.6, 0.6) * hh;
        let phi = rng.range(0.0, std::f64::consts::PI);
        shapes.push(Shape::rect2d(cx, cy, rng.range(15.0, 40.0), rng.range(1.5, 5.0), phi, p.threat_mu));
    }

    Phantom::new(shapes)
}

/// The experiment's dataset: bags `0..count` with a deterministic
/// train/test split (`test_frac` of the tail), mirroring the paper's
/// 165-train / 25-test division.
pub fn split(count: usize, test_frac: f64) -> (Vec<u64>, Vec<u64>) {
    let n_test = ((count as f64) * test_frac).round() as usize;
    let n_train = count - n_test;
    ((0..n_train as u64).collect(), (n_train as u64..count as u64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::VolumeGeometry;

    #[test]
    fn deterministic_per_seed() {
        let p = LuggageParams::default();
        let a = bag(7, &p);
        let b = bag(7, &p);
        assert_eq!(a.shapes.len(), b.shapes.len());
        let pt = [10.0, -20.0, 0.0];
        assert_eq!(a.mu(pt), b.mu(pt));
    }

    #[test]
    fn seeds_differ() {
        let p = LuggageParams::default();
        let a = bag(1, &p);
        let b = bag(2, &p);
        // extremely unlikely to match in count AND density at a probe point
        let same = a.shapes.len() == b.shapes.len()
            && (a.mu([5.0, 5.0, 0.0]) - b.mu([5.0, 5.0, 0.0])).abs() < 1e-15;
        assert!(!same);
    }

    #[test]
    fn object_count_in_bounds() {
        let p = LuggageParams::default();
        for seed in 0..30 {
            let b = bag(seed, &p);
            // shell = 2 shapes; contents 6..=20; threat 0/1
            let n = b.shapes.len();
            assert!((8..=23).contains(&n), "seed {seed}: {n} shapes");
        }
    }

    #[test]
    fn rasterizes_in_fov() {
        let p = LuggageParams::default();
        let vg = VolumeGeometry::slice2d(128, 128, 4.0); // 512 mm FOV
        let vol = bag(3, &p).rasterize(&vg, 1);
        let (lo, hi) = vol.min_max();
        assert!(lo >= -1e-6);
        assert!(hi > 0.0 && hi < 0.5, "hi {hi}");
    }

    #[test]
    fn split_disjoint_and_complete() {
        let (train, test) = split(190, 25.0 / 190.0);
        assert_eq!(train.len(), 165);
        assert_eq!(test.len(), 25);
        assert!(train.iter().all(|s| !test.contains(s)));
    }
}
