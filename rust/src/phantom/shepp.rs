//! Shepp-Logan phantoms: the standard 2-D ten-ellipse head phantom
//! (modified contrast variant of Toft) and the 3-D Kak-Slaney ellipsoid
//! table, plus a simplified FORBILD-style head.
//!
//! Coordinates in the classic tables live in the unit disk; we scale by a
//! caller-supplied radius in mm so phantoms stay quantitative.

use super::{Phantom, Shape};

/// Modified (high-contrast) 2-D Shepp-Logan. `radius` is the half-width of
/// the head in mm; densities are scaled by `mu_scale` (mm⁻¹) so the
/// brightest tissue is `mu_scale`.
pub fn shepp_logan_2d(radius: f64, mu_scale: f64) -> Phantom {
    // (cx, cy, a, b, phi_deg, density) — Toft's modified table.
    const T: [(f64, f64, f64, f64, f64, f64); 10] = [
        (0.0, 0.0, 0.69, 0.92, 0.0, 1.0),
        (0.0, -0.0184, 0.6624, 0.874, 0.0, -0.8),
        (0.22, 0.0, 0.11, 0.31, -18.0, -0.2),
        (-0.22, 0.0, 0.16, 0.41, 18.0, -0.2),
        (0.0, 0.35, 0.21, 0.25, 0.0, 0.1),
        (0.0, 0.1, 0.046, 0.046, 0.0, 0.1),
        (0.0, -0.1, 0.046, 0.046, 0.0, 0.1),
        (-0.08, -0.605, 0.046, 0.023, 0.0, 0.1),
        (0.0, -0.606, 0.023, 0.023, 0.0, 0.1),
        (0.06, -0.605, 0.023, 0.046, 0.0, 0.1),
    ];
    let shapes = T
        .iter()
        .map(|&(cx, cy, a, b, deg, d)| {
            Shape::ellipse2d(
                cx * radius,
                cy * radius,
                a * radius,
                b * radius,
                deg.to_radians(),
                d * mu_scale,
            )
        })
        .collect();
    Phantom::new(shapes)
}

/// 3-D Shepp-Logan (Kak & Slaney table, high-contrast densities).
/// `radius` scales the unit sphere to mm; densities scaled by `mu_scale`.
pub fn shepp_logan_3d(radius: f64, mu_scale: f64) -> Phantom {
    // (cx, cy, cz, a, b, c, phi_deg, density)
    const T: [(f64, f64, f64, f64, f64, f64, f64, f64); 10] = [
        (0.0, 0.0, 0.0, 0.69, 0.92, 0.81, 0.0, 1.0),
        (0.0, -0.0184, 0.0, 0.6624, 0.874, 0.78, 0.0, -0.8),
        (0.22, 0.0, 0.0, 0.11, 0.31, 0.22, -18.0, -0.2),
        (-0.22, 0.0, 0.0, 0.16, 0.41, 0.28, 18.0, -0.2),
        (0.0, 0.35, -0.15, 0.21, 0.25, 0.41, 0.0, 0.1),
        (0.0, 0.1, 0.25, 0.046, 0.046, 0.05, 0.0, 0.1),
        (0.0, -0.1, 0.25, 0.046, 0.046, 0.05, 0.0, 0.1),
        (-0.08, -0.605, 0.0, 0.046, 0.023, 0.05, 0.0, 0.1),
        (0.0, -0.606, 0.0, 0.023, 0.023, 0.02, 0.0, 0.1),
        (0.06, -0.605, 0.0, 0.023, 0.046, 0.02, 0.0, 0.1),
    ];
    let shapes = T
        .iter()
        .map(|&(cx, cy, cz, a, b, c, deg, d)| Shape::Ellipsoid {
            center: [cx * radius, cy * radius, cz * radius],
            axes: [a * radius, b * radius, c * radius],
            phi: deg.to_radians(),
            density: d * mu_scale,
        })
        .collect();
    Phantom::new(shapes)
}

/// A simplified FORBILD-style head slice: skull shell, brain, ventricle
/// pair and small lesions — sharper contrast structure than Shepp-Logan,
/// useful as a second accuracy phantom.
pub fn forbild_lite_2d(radius: f64, mu_scale: f64) -> Phantom {
    let r = radius;
    let m = mu_scale;
    Phantom::new(vec![
        // skull (high density shell: outer minus inner)
        Shape::ellipse2d(0.0, 0.0, 0.95 * r, 0.95 * r, 0.0, 2.0 * m),
        Shape::ellipse2d(0.0, 0.0, 0.85 * r, 0.85 * r, 0.0, -1.0 * m),
        // ventricles
        Shape::ellipse2d(-0.18 * r, 0.08 * r, 0.12 * r, 0.25 * r, 0.3, -0.25 * m),
        Shape::ellipse2d(0.18 * r, 0.08 * r, 0.12 * r, 0.25 * r, -0.3, -0.25 * m),
        // lesions
        Shape::ellipse2d(0.0, -0.4 * r, 0.05 * r, 0.05 * r, 0.0, 0.3 * m),
        Shape::ellipse2d(0.3 * r, 0.45 * r, 0.03 * r, 0.06 * r, 0.5, 0.4 * m),
        Shape::rect2d(-0.35 * r, -0.35 * r, 0.06 * r, 0.04 * r, 0.4, 0.35 * m),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::VolumeGeometry;

    #[test]
    fn shepp_2d_center_density() {
        // center of head: 1.0 − 0.8 = 0.2 (no small inserts at origin)
        let ph = shepp_logan_2d(100.0, 0.02);
        let mu = ph.mu([0.0, 0.0, 0.0]);
        assert!((mu - 0.2 * 0.02).abs() < 1e-12, "mu {mu}");
    }

    #[test]
    fn shepp_2d_outside_zero() {
        let ph = shepp_logan_2d(100.0, 0.02);
        assert_eq!(ph.mu([99.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn shepp_2d_rasterizes_nonneg_mostly() {
        let ph = shepp_logan_2d(30.0, 1.0);
        let vg = VolumeGeometry::slice2d(64, 64, 1.0);
        let vol = ph.rasterize(&vg, 2);
        let (lo, hi) = vol.min_max();
        assert!(lo >= -1e-6, "min {lo}");
        assert!(hi <= 1.01, "max {hi}");
        assert!(vol.sum() > 0.0);
    }

    #[test]
    fn shepp_3d_midplane_close_to_2d_structure() {
        let ph3 = shepp_logan_3d(50.0, 1.0);
        // at z=0 the big ellipsoids dominate; just sanity-check center value
        let mu = ph3.mu([0.0, 0.0, 0.0]);
        assert!((mu - 0.2).abs() < 1e-12);
        // off the top of the head
        assert_eq!(ph3.mu([0.0, 0.0, 49.0]), 0.0);
    }

    #[test]
    fn forbild_skull_brighter_than_brain() {
        let ph = forbild_lite_2d(80.0, 0.02);
        let skull = ph.mu([0.0, 0.9 * 80.0, 0.0]);
        let brain = ph.mu([0.0, 0.0, 0.0]);
        assert!(skull > brain, "skull {skull} brain {brain}");
    }
}
