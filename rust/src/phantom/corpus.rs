//! Deterministic phantom corpora for training learned reconstruction.
//!
//! A [`Corpus`] is a seeded, indexable family of ground-truth volumes
//! with a built-in train/held-out split: item `i` of a corpus is a
//! pure function of `(family, corpus seed, i)`, so two processes — or
//! the same process across checkpoint/resume — enumerate bit-identical
//! training data with no dataset files on disk. Two families ship:
//!
//! * [`Family::SheppJitter`] — the Shepp-Logan table (2-D or 3-D,
//!   chosen by the volume's slab count) plus a few randomized extra
//!   ellipses (lesion/void-like inserts), jittered per item.
//! * [`Family::Luggage`] — randomized suitcase phantoms from
//!   [`super::luggage::bag`], generated in their native ~512 mm frame
//!   and rescaled to the target volume's field of view so any grid
//!   size gets plausible bags.
//!
//! The split is by index range (train = head, held-out = tail) and the
//! per-item seeds are an injective mix of the corpus seed and the item
//! index — train and held-out items can never alias.

use crate::api::LeapError;
use crate::array::Vol3;
use crate::geometry::VolumeGeometry;
use crate::util::rng::Rng;

use super::luggage::{bag, LuggageParams};
use super::shepp::{shepp_logan_2d, shepp_logan_3d};
use super::{Phantom, Shape};

/// Which generative family a corpus draws from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Jittered Shepp-Logan heads (2-D table on single-slice volumes,
    /// 3-D Kak-Slaney table otherwise).
    SheppJitter,
    /// Randomized luggage bags rescaled to the volume's field of view.
    Luggage,
}

/// Configuration for [`Corpus::new`].
#[derive(Clone, Debug)]
pub struct CorpusCfg {
    pub family: Family,
    /// Total item count (train + held-out).
    pub count: usize,
    /// Fraction of items held out for evaluation (`[0, 1)`, rounded to
    /// the nearest item; at least one item always remains in train).
    pub test_frac: f64,
    /// Supersampling per axis when rasterizing truths (1 = point
    /// sampling at voxel centers).
    pub supersample: usize,
    /// Attenuation scale (mm⁻¹) of the Shepp family's table densities.
    pub mu_scale: f64,
}

impl Default for CorpusCfg {
    fn default() -> Self {
        CorpusCfg {
            family: Family::SheppJitter,
            count: 16,
            test_frac: 0.25,
            supersample: 2,
            mu_scale: 0.02,
        }
    }
}

/// A seeded phantom corpus over a fixed voxel grid. See the module
/// docs; construct with [`Corpus::new`], enumerate with
/// [`Corpus::train_ids`] / [`Corpus::test_ids`], and materialize items
/// with [`Corpus::phantom`] / [`Corpus::truth`].
#[derive(Clone, Debug)]
pub struct Corpus {
    cfg: CorpusCfg,
    vg: VolumeGeometry,
    seed: u64,
    n_train: usize,
}

impl Corpus {
    pub fn new(cfg: CorpusCfg, vg: &VolumeGeometry, seed: u64) -> Result<Corpus, LeapError> {
        if cfg.count == 0 {
            return Err(LeapError::InvalidArgument("corpus needs ≥ 1 item".into()));
        }
        if !(cfg.test_frac.is_finite() && (0.0..1.0).contains(&cfg.test_frac)) {
            return Err(LeapError::InvalidArgument(format!(
                "test fraction must be in [0, 1) (got {})",
                cfg.test_frac
            )));
        }
        if !(cfg.mu_scale.is_finite() && cfg.mu_scale > 0.0) {
            return Err(LeapError::InvalidArgument(format!(
                "mu scale must be positive and finite (got {})",
                cfg.mu_scale
            )));
        }
        let n_test = ((cfg.count as f64) * cfg.test_frac).round() as usize;
        let n_train = (cfg.count - n_test).max(1);
        Ok(Corpus { cfg, vg: vg.clone(), seed, n_train })
    }

    /// Training item ids (the head of the index range).
    pub fn train_ids(&self) -> Vec<u64> {
        (0..self.n_train as u64).collect()
    }

    /// Held-out item ids (the tail; disjoint from train by
    /// construction).
    pub fn test_ids(&self) -> Vec<u64> {
        (self.n_train as u64..self.cfg.count as u64).collect()
    }

    /// The per-item generator seed: an injective (odd-constant
    /// multiply) mix of the corpus seed and the item id, so distinct
    /// items never collide.
    fn item_seed(&self, id: u64) -> u64 {
        self.seed ^ (id.wrapping_add(1)).wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }

    /// The continuous phantom of item `id` (deterministic in
    /// `(cfg.family, seed, id)` — the voxel grid only sets the world
    /// scale).
    pub fn phantom(&self, id: u64) -> Phantom {
        match self.cfg.family {
            Family::SheppJitter => self.shepp_jitter(self.item_seed(id)),
            Family::Luggage => self.scaled_bag(self.item_seed(id)),
        }
    }

    /// Rasterized ground-truth volume of item `id`.
    pub fn truth(&self, id: u64) -> Vol3 {
        self.phantom(id).rasterize(&self.vg, self.cfg.supersample)
    }

    fn shepp_jitter(&self, seed: u64) -> Phantom {
        let mut rng = Rng::new(seed ^ 0x5e99_10ca_ed17_0001);
        let r = 0.9 * self.vg.fov_radius();
        let zhalf = 0.5 * self.vg.nz as f64 * self.vg.vz;
        let mu = self.cfg.mu_scale;
        let mut ph = if self.vg.nz == 1 {
            shepp_logan_2d(r, mu)
        } else {
            shepp_logan_3d(r, mu)
        };
        // a few randomized inserts inside the brain: small ellipses
        // with mild ± densities (lesions and voids)
        let n = 2 + rng.below(4);
        for _ in 0..n {
            let rho = rng.range(0.0, 0.5) * r;
            let th = rng.range(0.0, std::f64::consts::TAU);
            let (cx, cy) = (rho * th.cos(), rho * th.sin());
            let a = rng.range(0.03, 0.12) * r;
            let b = rng.range(0.03, 0.12) * r;
            let phi = rng.range(0.0, std::f64::consts::PI);
            let mag = rng.range(0.05, 0.15) * mu;
            let density = if rng.f64() < 0.5 { mag } else { -mag };
            if self.vg.nz == 1 {
                ph.shapes.push(Shape::ellipse2d(cx, cy, a, b, phi, density));
            } else {
                let cz = rng.range(-0.3, 0.3) * zhalf;
                let c = rng.range(0.05, 0.2) * zhalf.max(self.vg.vz);
                ph.shapes.push(Shape::Ellipsoid {
                    center: [cx, cy, cz],
                    axes: [a, b, c],
                    phi,
                    density,
                });
            }
        }
        ph
    }

    fn scaled_bag(&self, seed: u64) -> Phantom {
        // generate in the bag generator's native ~512 mm frame, then
        // rescale geometry to this grid's field of view (densities are
        // per-mm and stay as generated)
        let native = bag(seed, &LuggageParams::default());
        let s = self.vg.fov_radius() / 256.0;
        Phantom::new(native.shapes.iter().map(|sh| scale_shape(sh, s)).collect())
    }
}

fn scale_shape(sh: &Shape, s: f64) -> Shape {
    match sh {
        Shape::Ellipsoid { center, axes, phi, density } => Shape::Ellipsoid {
            center: [center[0] * s, center[1] * s, center[2] * s],
            axes: [axes[0] * s, axes[1] * s, axes[2] * s],
            phi: *phi,
            density: *density,
        },
        Shape::Box { center, half, phi, density } => Shape::Box {
            center: [center[0] * s, center[1] * s, center[2] * s],
            half: [half[0] * s, half[1] * s, half[2] * s],
            phi: *phi,
            density: *density,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> VolumeGeometry {
        VolumeGeometry::slice2d(24, 24, 1.0)
    }

    #[test]
    fn corpus_is_bit_deterministic() {
        for family in [Family::SheppJitter, Family::Luggage] {
            let cfg = CorpusCfg { family, count: 6, ..CorpusCfg::default() };
            let a = Corpus::new(cfg.clone(), &grid(), 11).unwrap();
            let b = Corpus::new(cfg, &grid(), 11).unwrap();
            for id in a.train_ids().into_iter().chain(a.test_ids()) {
                let ta = a.truth(id);
                let tb = b.truth(id);
                let ba: Vec<u32> = ta.data.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = tb.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ba, bb, "{family:?} item {id}");
            }
        }
    }

    #[test]
    fn split_is_disjoint_and_items_differ() {
        let c = Corpus::new(
            CorpusCfg { count: 8, test_frac: 0.25, ..CorpusCfg::default() },
            &grid(),
            3,
        )
        .unwrap();
        let (train, test) = (c.train_ids(), c.test_ids());
        assert_eq!(train.len(), 6);
        assert_eq!(test.len(), 2);
        assert!(train.iter().all(|i| !test.contains(i)));
        // different items are genuinely different volumes
        let t0 = c.truth(train[0]);
        let t1 = c.truth(train[1]);
        assert_ne!(t0.data, t1.data);
        // a different corpus seed reshuffles every item
        let d = Corpus::new(
            CorpusCfg { count: 8, test_frac: 0.25, ..CorpusCfg::default() },
            &grid(),
            4,
        )
        .unwrap();
        assert_ne!(c.truth(0).data, d.truth(0).data);
    }

    #[test]
    fn shepp_family_handles_3d_grids() {
        let vg = VolumeGeometry::cube(12, 2.0);
        let c = Corpus::new(CorpusCfg { count: 2, ..CorpusCfg::default() }, &vg, 5).unwrap();
        let t = c.truth(0);
        assert_eq!(t.data.len(), 12 * 12 * 12);
        let (_, hi) = t.min_max();
        assert!(hi > 0.0, "3-D shepp truth must be non-trivial");
    }

    #[test]
    fn luggage_family_fits_small_grids() {
        // the native bag frame is ~512 mm; after rescaling, a 24 mm FOV
        // must still contain a non-trivial, in-range bag
        let c = Corpus::new(
            CorpusCfg { family: Family::Luggage, count: 2, ..CorpusCfg::default() },
            &grid(),
            9,
        )
        .unwrap();
        let t = c.truth(0);
        let (lo, hi) = t.min_max();
        assert!(lo >= -1e-6, "lo {lo}");
        assert!(hi > 0.0 && hi < 0.5, "hi {hi}");
    }

    #[test]
    fn degenerate_configs_are_typed() {
        for cfg in [
            CorpusCfg { count: 0, ..CorpusCfg::default() },
            CorpusCfg { test_frac: 1.0, ..CorpusCfg::default() },
            CorpusCfg { test_frac: -0.1, ..CorpusCfg::default() },
            CorpusCfg { mu_scale: 0.0, ..CorpusCfg::default() },
        ] {
            assert!(
                matches!(Corpus::new(cfg.clone(), &grid(), 0), Err(LeapError::InvalidArgument(_))),
                "{cfg:?}"
            );
        }
        // tiny corpora keep at least one training item
        let c = Corpus::new(
            CorpusCfg { count: 1, test_frac: 0.9, ..CorpusCfg::default() },
            &grid(),
            0,
        )
        .unwrap();
        assert_eq!(c.train_ids(), vec![0]);
        assert!(c.test_ids().is_empty());
    }
}
