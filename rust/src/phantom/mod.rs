//! Phantoms: rasterized test volumes and *analytic* (discretization-free)
//! line integrals.
//!
//! * [`shepp`] — the standard 2-D Shepp-Logan and 3-D Kak-Slaney ellipsoid
//!   tables.
//! * [`luggage`] — randomized "bag" phantoms standing in for the ALERT
//!   airport-luggage dataset used in the paper's Figure-3 experiment (see
//!   DESIGN.md §6 for the substitution argument).
//! * [`corpus`] — seeded, indexable phantom corpora (jittered Shepp-Logan
//!   and luggage families) with deterministic train/held-out splits, the
//!   data source for training learned-reconstruction pipelines.
//! * Analytic projection of ellipsoid/box primitives: the exact X-ray
//!   transform of the continuous phantom, used as ground truth in the
//!   accuracy experiments (no inverse crime).

pub mod shepp;
pub mod luggage;
pub mod noise;
pub mod corpus;

use crate::array::{Sino, Vol3};
use crate::geometry::{Geometry, Ray, VolumeGeometry};

/// A geometric primitive with constant attenuation (mm⁻¹), rotated about z.
#[derive(Clone, Debug)]
pub enum Shape {
    /// Ellipsoid: center (mm), semi-axes (mm), rotation about z (radians),
    /// additive density.
    Ellipsoid { center: [f64; 3], axes: [f64; 3], phi: f64, density: f64 },
    /// Rectangular box: center, half-sizes, rotation about z, density.
    Box { center: [f64; 3], half: [f64; 3], phi: f64, density: f64 },
}

impl Shape {
    /// 2-D ellipse convenience (infinite in z — use |z half| large).
    pub fn ellipse2d(cx: f64, cy: f64, a: f64, b: f64, phi: f64, density: f64) -> Shape {
        Shape::Ellipsoid { center: [cx, cy, 0.0], axes: [a, b, 1e9], phi, density }
    }

    pub fn rect2d(cx: f64, cy: f64, hx: f64, hy: f64, phi: f64, density: f64) -> Shape {
        Shape::Box { center: [cx, cy, 0.0], half: [hx, hy, 1e9], phi, density }
    }

    /// Is the world point inside the shape?
    pub fn contains(&self, p: [f64; 3]) -> bool {
        match self {
            Shape::Ellipsoid { center, axes, phi, .. } => {
                let q = to_local(p, *center, *phi);
                let s = q[0] / axes[0];
                let t = q[1] / axes[1];
                let u = q[2] / axes[2];
                s * s + t * t + u * u <= 1.0
            }
            Shape::Box { center, half, phi, .. } => {
                let q = to_local(p, *center, *phi);
                q[0].abs() <= half[0] && q[1].abs() <= half[1] && q[2].abs() <= half[2]
            }
        }
    }

    pub fn density(&self) -> f64 {
        match self {
            Shape::Ellipsoid { density, .. } | Shape::Box { density, .. } => *density,
        }
    }

    /// Exact chord length (mm) of `ray` through the shape. The ray's
    /// direction must be unit length (guaranteed by [`Ray::new`]).
    pub fn chord(&self, ray: &Ray) -> f64 {
        match self {
            Shape::Ellipsoid { center, axes, phi, .. } => {
                let o = to_local(ray.origin, *center, *phi);
                let d = rot_z(ray.dir, -*phi);
                // scale to unit sphere
                let os = [o[0] / axes[0], o[1] / axes[1], o[2] / axes[2]];
                let ds = [d[0] / axes[0], d[1] / axes[1], d[2] / axes[2]];
                let a = ds[0] * ds[0] + ds[1] * ds[1] + ds[2] * ds[2];
                let b = 2.0 * (os[0] * ds[0] + os[1] * ds[1] + os[2] * ds[2]);
                let c = os[0] * os[0] + os[1] * os[1] + os[2] * os[2] - 1.0;
                let disc = b * b - 4.0 * a * c;
                if disc <= 0.0 || a == 0.0 {
                    0.0
                } else {
                    // (t2 - t1) in the *world* ray parameter (unit world dir)
                    disc.sqrt() / a
                }
            }
            Shape::Box { center, half, phi, .. } => {
                let o = to_local(ray.origin, *center, *phi);
                let d = rot_z(ray.dir, -*phi);
                // slab clipping
                let mut t0 = f64::NEG_INFINITY;
                let mut t1 = f64::INFINITY;
                for ax in 0..3 {
                    if d[ax].abs() < 1e-300 {
                        if o[ax].abs() > half[ax] {
                            return 0.0;
                        }
                    } else {
                        let ta = (-half[ax] - o[ax]) / d[ax];
                        let tb = (half[ax] - o[ax]) / d[ax];
                        t0 = t0.max(ta.min(tb));
                        t1 = t1.min(ta.max(tb));
                    }
                }
                (t1 - t0).max(0.0)
            }
        }
    }
}

#[inline]
fn rot_z(v: [f64; 3], phi: f64) -> [f64; 3] {
    let (s, c) = phi.sin_cos();
    [v[0] * c - v[1] * s, v[0] * s + v[1] * c, v[2]]
}

#[inline]
fn to_local(p: [f64; 3], center: [f64; 3], phi: f64) -> [f64; 3] {
    rot_z([p[0] - center[0], p[1] - center[1], p[2] - center[2]], -phi)
}

/// A phantom: a list of additive shapes.
#[derive(Clone, Debug, Default)]
pub struct Phantom {
    pub shapes: Vec<Shape>,
}

impl Phantom {
    pub fn new(shapes: Vec<Shape>) -> Phantom {
        Phantom { shapes }
    }

    /// Attenuation at a world point (sum of containing shapes).
    pub fn mu(&self, p: [f64; 3]) -> f64 {
        self.shapes.iter().filter(|s| s.contains(p)).map(|s| s.density()).sum()
    }

    /// Rasterize onto a voxel grid, with optional `supersample`-per-axis
    /// antialiasing (1 = point sampling at voxel centers).
    pub fn rasterize(&self, vg: &VolumeGeometry, supersample: usize) -> Vol3 {
        let ss = supersample.max(1);
        let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        let inv = 1.0 / (ss * ss * ss) as f64;
        for k in 0..vg.nz {
            for j in 0..vg.ny {
                for i in 0..vg.nx {
                    let mut acc = 0.0;
                    for sk in 0..ss {
                        for sj in 0..ss {
                            for si in 0..ss {
                                let fx = (si as f64 + 0.5) / ss as f64 - 0.5;
                                let fy = (sj as f64 + 0.5) / ss as f64 - 0.5;
                                let fz = (sk as f64 + 0.5) / ss as f64 - 0.5;
                                let p = [
                                    vg.x(i) + fx * vg.vx,
                                    vg.y(j) + fy * vg.vy,
                                    vg.z(k) + fz * vg.vz,
                                ];
                                acc += self.mu(p);
                            }
                        }
                    }
                    *vol.at_mut(i, j, k) = (acc * inv) as f32;
                }
            }
        }
        vol
    }

    /// Exact line integral along a ray (sum of density × chord).
    pub fn line_integral(&self, ray: &Ray) -> f64 {
        self.shapes.iter().map(|s| s.density() * s.chord(ray)).sum()
    }

    /// Analytic sinogram: the exact X-ray transform of the continuous
    /// phantom under `geom` — ground truth with no discretization error.
    pub fn project(&self, geom: &Geometry) -> Sino {
        let mut sino = Sino::zeros(geom.nviews(), geom.nrows(), geom.ncols());
        for view in 0..sino.nviews {
            for row in 0..sino.nrows {
                for col in 0..sino.ncols {
                    let ray = geom.ray(view, row, col);
                    *sino.at_mut(view, row, col) = self.line_integral(&ray) as f32;
                }
            }
        }
        sino
    }

    /// Bin-*integrated* analytic sinogram: averages `nsub × nsub` (or
    /// `nsub` for single-row detectors) line integrals across each
    /// detector pixel — the physically correct reference for projector
    /// models that integrate over finite bins (SF/DD). A point-sampled
    /// reference penalizes SF for modeling reality; see
    /// `benches/accuracy.rs`.
    pub fn project_binned(&self, geom: &Geometry, nsub: usize) -> Sino {
        let nsub = nsub.max(1);
        let mut sino = Sino::zeros(geom.nviews(), geom.nrows(), geom.ncols());
        let single_row = geom.nrows() == 1;
        let rsubs = if single_row { 1 } else { nsub };
        let inv = 1.0 / (nsub * rsubs) as f64;
        for view in 0..sino.nviews {
            for row in 0..sino.nrows {
                for col in 0..sino.ncols {
                    let mut acc = 0.0f64;
                    for sr in 0..rsubs {
                        let row_f = row as f64
                            + if single_row { 0.0 } else { (sr as f64 + 0.5) / rsubs as f64 - 0.5 };
                        for sc in 0..nsub {
                            let col_f = col as f64 + (sc as f64 + 0.5) / nsub as f64 - 0.5;
                            let ray = geom.ray_at(view, row_f, col_f);
                            acc += self.line_integral(&ray);
                        }
                    }
                    *sino.at_mut(view, row, col) = (acc * inv) as f32;
                }
            }
        }
        sino
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::ParallelBeam;

    #[test]
    fn sphere_chord_through_center() {
        let s = Shape::Ellipsoid { center: [0.0; 3], axes: [10.0, 10.0, 10.0], phi: 0.0, density: 1.0 };
        let ray = Ray::new([-100.0, 0.0, 0.0], [1.0, 0.0, 0.0]);
        assert!((s.chord(&ray) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn sphere_chord_off_center() {
        let s = Shape::Ellipsoid { center: [0.0; 3], axes: [10.0, 10.0, 10.0], phi: 0.0, density: 1.0 };
        // chord at impact parameter 6: 2·√(100−36) = 16
        let ray = Ray::new([-100.0, 6.0, 0.0], [1.0, 0.0, 0.0]);
        assert!((s.chord(&ray) - 16.0).abs() < 1e-9);
        let miss = Ray::new([-100.0, 11.0, 0.0], [1.0, 0.0, 0.0]);
        assert_eq!(s.chord(&miss), 0.0);
    }

    #[test]
    fn rotated_ellipse_chord() {
        // ellipse a=20 (x), b=5 (y) rotated 90° → chord along x at y=0 is 2b=10
        let s = Shape::Ellipsoid {
            center: [0.0; 3],
            axes: [20.0, 5.0, 1e9],
            phi: std::f64::consts::FRAC_PI_2,
            density: 1.0,
        };
        let ray = Ray::new([-100.0, 0.0, 0.0], [1.0, 0.0, 0.0]);
        assert!((s.chord(&ray) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn box_chord_and_diagonal() {
        let b = Shape::Box { center: [0.0; 3], half: [5.0, 5.0, 5.0], phi: 0.0, density: 1.0 };
        let ray = Ray::new([-100.0, 0.0, 0.0], [1.0, 0.0, 0.0]);
        assert!((b.chord(&ray) - 10.0).abs() < 1e-9);
        // diagonal in xy through center: length 10·√2
        let diag = Ray::new([-50.0, -50.0, 0.0], [1.0, 1.0, 0.0]);
        assert!((b.chord(&diag) - 10.0 * 2f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn rasterize_disk_area() {
        // disk r=20mm in 64² @ 1mm: voxel sum × voxel area ≈ π r²
        let ph = Phantom::new(vec![Shape::ellipse2d(0.0, 0.0, 20.0, 20.0, 0.0, 1.0)]);
        let vg = VolumeGeometry::slice2d(64, 64, 1.0);
        let vol = ph.rasterize(&vg, 3);
        let area = vol.sum();
        let exact = std::f64::consts::PI * 400.0;
        assert!((area - exact).abs() / exact < 0.01, "area {area} vs {exact}");
    }

    #[test]
    fn analytic_parallel_projection_symmetry() {
        let ph = Phantom::new(vec![Shape::ellipse2d(0.0, 0.0, 15.0, 15.0, 0.0, 0.02)]);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 33, 1.5));
        let sino = ph.project(&g);
        // circular phantom → every view identical, peak at center = 2·r·μ
        let peak = sino.at(0, 0, 16);
        assert!((peak - (2.0 * 15.0 * 0.02) as f32).abs() < 1e-6);
        for v in 1..8 {
            for c in 0..33 {
                assert!((sino.at(v, 0, c) - sino.at(0, 0, c)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mu_sums_overlapping_shapes() {
        let ph = Phantom::new(vec![
            Shape::ellipse2d(0.0, 0.0, 10.0, 10.0, 0.0, 1.0),
            Shape::ellipse2d(0.0, 0.0, 5.0, 5.0, 0.0, -0.5),
        ]);
        assert_eq!(ph.mu([0.0, 0.0, 0.0]), 0.5);
        assert_eq!(ph.mu([7.0, 0.0, 0.0]), 1.0);
        assert_eq!(ph.mu([11.0, 0.0, 0.0]), 0.0);
    }
}
