//! `leap` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   phantom      generate a phantom volume (+ analytic sinogram)
//!   project      forward-project a volume (native projectors)
//!   backproject  matched backprojection
//!   fbp          analytic reconstruction (FBP / fan FBP / FDK)
//!   recon        iterative reconstruction (sirt|os-sart|cgls|mlem|fista-tv)
//!   dc-refine    limited-angle data-consistency pipeline on a luggage bag
//!   serve        start the batching projection server (PJRT artifacts +
//!                native fallback); --cluster-addr opens the shard
//!                channel worker processes dial into
//!   worker       join a coordinator's shard channel and serve sharded
//!                forward/back ranges (leap::cluster)
//!   selftest     adjoint identities + artifact engine roundtrip
//!   info         list compiled artifact entries

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use leap::coordinator::server::{Server, ServerOptions, DEFAULT_MAX_INFLIGHT_PER_CONN};
use leap::coordinator::{
    BatchPolicy, Coordinator, Executor, NativeExecutor, Router, SessionExecutor,
};
use leap::geometry::config::{scan_from_file, ScanConfig};
use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
use leap::phantom::{luggage, shepp};
use leap::projector::{Model, Projector};
use leap::recon;
use leap::util::cli::Args;
use leap::{io, metrics, Sino, Vol3};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_str() {
        "phantom" => cmd_phantom(&args),
        "project" => cmd_project(&args),
        "backproject" => cmd_backproject(&args),
        "fbp" => cmd_fbp(&args),
        "recon" => cmd_recon(&args),
        "dc-refine" => cmd_dc_refine(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "selftest" => cmd_selftest(&args),
        "info" => cmd_info(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow!("unknown subcommand {other}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "leap — differentiable X-ray CT projectors (LEAP reproduction)\n\
         usage: leap <phantom|project|backproject|fbp|recon|dc-refine|serve|worker|selftest|info> [--opt value ...]"
    );
}

/// Scan setup shared by the CLI commands: either `--config file.json` or
/// flags (`--geometry parallel|fan|cone`, `--n`, `--nviews`, `--ncols`...).
fn scan_from_args(args: &Args) -> Result<ScanConfig> {
    if let Some(path) = args.str_opt("config") {
        return scan_from_file(path).map_err(|e| anyhow!(e));
    }
    let n = args.usize_or("n", 128);
    let nviews = args.usize_or("nviews", 180);
    let ncols = args.usize_or("ncols", (n * 3) / 2);
    let voxel = args.f64_or("voxel", 1.0);
    let du = args.f64_or("du", voxel);
    let nz = args.usize_or("nz", 1);
    let nrows = args.usize_or("nrows", nz);
    let geometry = match args.str_or("geometry", "parallel").as_str() {
        "parallel" => Geometry::Parallel(ParallelBeam {
            nrows,
            ncols,
            du,
            dv: args.f64_or("dv", voxel),
            cu: args.f64_or("cu", 0.0),
            cv: args.f64_or("cv", 0.0),
            angles: leap::geometry::angles_deg(
                nviews,
                args.f64_or("start-deg", 0.0),
                args.f64_or("arc-deg", 180.0),
            ),
        }),
        "fan" => Geometry::Fan(leap::geometry::FanBeam {
            ncols,
            du,
            cu: args.f64_or("cu", 0.0),
            sod: args.f64_or("sod", n as f64 * voxel * 2.0),
            sdd: args.f64_or("sdd", n as f64 * voxel * 4.0),
            angles: leap::geometry::angles_deg(
                nviews,
                args.f64_or("start-deg", 0.0),
                args.f64_or("arc-deg", 360.0),
            ),
        }),
        "cone" => Geometry::Cone(leap::geometry::ConeBeam {
            nrows: args.usize_or("nrows", nz.max(8)),
            ncols,
            du,
            dv: args.f64_or("dv", voxel),
            cu: args.f64_or("cu", 0.0),
            cv: args.f64_or("cv", 0.0),
            sod: args.f64_or("sod", n as f64 * voxel * 2.0),
            sdd: args.f64_or("sdd", n as f64 * voxel * 4.0),
            angles: leap::geometry::angles_deg(
                nviews,
                args.f64_or("start-deg", 0.0),
                args.f64_or("arc-deg", 360.0),
            ),
            shape: if args.str_or("detector", "flat") == "curved" {
                leap::geometry::DetectorShape::Curved
            } else {
                leap::geometry::DetectorShape::Flat
            },
        }),
        other => bail!("unknown geometry {other} (parallel|fan|cone; modular via --config)"),
    };
    let volume = VolumeGeometry {
        nx: n,
        ny: n,
        nz,
        vx: voxel,
        vy: voxel,
        vz: args.f64_or("vz", voxel),
        cx: 0.0,
        cy: 0.0,
        cz: 0.0,
    };
    Ok(ScanConfig { geometry, volume })
}

fn phantom_from_args(args: &Args, vg: &VolumeGeometry) -> leap::phantom::Phantom {
    let radius = 0.45 * vg.nx as f64 * vg.vx;
    match args.str_or("phantom", "shepp").as_str() {
        "luggage" | "bag" => {
            luggage::bag(args.u64_or("seed", 0), &luggage::LuggageParams::default())
        }
        "forbild" => shepp::forbild_lite_2d(radius, args.f64_or("mu", 0.02)),
        _ if vg.nz > 1 => shepp::shepp_logan_3d(radius, args.f64_or("mu", 0.02)),
        _ => shepp::shepp_logan_2d(radius, args.f64_or("mu", 0.02)),
    }
}

fn model_from_args(args: &Args) -> Result<Model> {
    Model::parse(&args.str_or("model", "sf"))
        .ok_or_else(|| anyhow!("bad --model (siddon|joseph|sf)"))
}

fn cmd_phantom(args: &Args) -> Result<()> {
    let cfg = scan_from_args(args)?;
    let ph = phantom_from_args(args, &cfg.volume);
    let vol = ph.rasterize(&cfg.volume, args.usize_or("supersample", 2));
    let out = args.str_or("out", "phantom.raw");
    io::save_vol(&out, &vol)?;
    println!("wrote {out} ({}x{}x{})", vol.nx, vol.ny, vol.nz);
    if args.flag("pgm") {
        let pgm = format!("{out}.pgm");
        io::write_pgm16(&pgm, vol.slice(vol.nz / 2), vol.nx, vol.ny)?;
        println!("wrote {pgm}");
    }
    if args.flag("sino") {
        let sino = ph.project(&cfg.geometry);
        let sout = args.str_or("sino-out", "sino.raw");
        io::save_sino(&sout, &sino)?;
        println!("wrote {sout} (analytic {} views)", sino.nviews);
    }
    Ok(())
}

fn cmd_project(args: &Args) -> Result<()> {
    let cfg = scan_from_args(args)?;
    let model = model_from_args(args)?;
    let p = Projector::new(cfg.geometry, cfg.volume.clone(), model);
    let vol = match args.str_opt("in") {
        Some(path) => io::load_vol(path)?,
        None => phantom_from_args(args, &cfg.volume).rasterize(&cfg.volume, 2),
    };
    let t0 = std::time::Instant::now();
    let sino = p.forward(&vol);
    let dt = t0.elapsed().as_secs_f64();
    let out = args.str_or("out", "sino.raw");
    io::save_sino(&out, &sino)?;
    let one_copy = metrics::one_copy_bytes(vol.len(), sino.len());
    println!(
        "forward[{}/{}] {:.3}s  ({} views, {:.1} MB one-copy memory)",
        p.model.name(),
        p.geom.kind(),
        dt,
        sino.nviews,
        one_copy as f64 / 1e6
    );
    println!("wrote {out}");
    Ok(())
}

fn cmd_backproject(args: &Args) -> Result<()> {
    let cfg = scan_from_args(args)?;
    let model = model_from_args(args)?;
    let p = Projector::new(cfg.geometry, cfg.volume.clone(), model);
    let sino = io::load_sino(args.str_opt("in").context("--in sino.raw required")?)?;
    let t0 = std::time::Instant::now();
    let vol = p.back(&sino);
    println!("backproject[{}] {:.3}s", p.model.name(), t0.elapsed().as_secs_f64());
    let out = args.str_or("out", "backprojection.raw");
    io::save_vol(&out, &vol)?;
    println!("wrote {out}");
    Ok(())
}

fn load_or_simulate_sino(args: &Args, cfg: &ScanConfig) -> Result<(Sino, Option<Vol3>)> {
    match args.str_opt("in") {
        Some(path) => Ok((io::load_sino(path)?, None)),
        None => {
            // simulate: analytic projection of the chosen phantom (no
            // inverse crime: continuous phantom, not the rasterized grid)
            let ph = phantom_from_args(args, &cfg.volume);
            let truth = ph.rasterize(&cfg.volume, 2);
            Ok((ph.project(&cfg.geometry), Some(truth)))
        }
    }
}

fn report_quality(vol: &Vol3, truth: &Option<Vol3>) {
    if let Some(t) = truth {
        let psnr = metrics::psnr(&vol.data, &t.data, None);
        let ssim = metrics::ssim_vol(vol, t, None);
        println!("quality vs truth: PSNR {psnr:.3} dB, SSIM {ssim:.4}");
    }
}

fn cmd_fbp(args: &Args) -> Result<()> {
    let cfg = scan_from_args(args)?;
    let (sino, truth) = load_or_simulate_sino(args, &cfg)?;
    let window = recon::Window::parse(&args.str_or("filter", "ramlak"))
        .ok_or_else(|| anyhow!("bad --filter"))?;
    let threads = args.usize_or("threads", leap::util::pool::default_threads());
    let t0 = std::time::Instant::now();
    let vol = match &cfg.geometry {
        Geometry::Parallel(g) => recon::fbp_parallel(&cfg.volume, g, &sino, window, threads),
        Geometry::Fan(g) => recon::fbp_fan(&cfg.volume, g, &sino, window, threads),
        Geometry::Cone(g) => recon::fdk(&cfg.volume, g, &sino, window, threads),
        Geometry::Modular(_) => bail!("FBP unsupported for modular beams; use recon"),
    };
    println!("fbp[{}] {:.3}s", window.name(), t0.elapsed().as_secs_f64());
    report_quality(&vol, &truth);
    let out = args.str_or("out", "fbp.raw");
    io::save_vol(&out, &vol)?;
    if args.flag("pgm") {
        io::write_pgm16(format!("{out}.pgm"), vol.slice(vol.nz / 2), vol.nx, vol.ny)?;
    }
    println!("wrote {out}");
    Ok(())
}

fn cmd_recon(args: &Args) -> Result<()> {
    let cfg = scan_from_args(args)?;
    let model = model_from_args(args)?;
    let (sino, truth) = load_or_simulate_sino(args, &cfg)?;
    let p = Projector::new(cfg.geometry, cfg.volume.clone(), model);
    let iters = args.usize_or("iters", 50);
    let algo = args.str_or("algo", "sirt");
    let t0 = std::time::Instant::now();
    let vol = match algo.as_str() {
        "sirt" => {
            recon::sirt(
                &p,
                &sino,
                &p.new_vol(),
                &recon::SirtOpts { iterations: iters, ..Default::default() },
            )
            .vol
        }
        "os-sart" | "ossart" => leap::recon::os_sart::os_sart(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::os_sart::OsSartOpts {
                iterations: iters,
                subsets: args.usize_or("subsets", 8),
                ..Default::default()
            },
        ),
        "cgls" => leap::recon::cgls::cgls(&p, &sino, iters).vol,
        "mlem" => leap::recon::mlem::mlem(&p, &sino, iters),
        "fista-tv" | "tv" => leap::recon::fista_tv::fista_tv(
            &p,
            &sino,
            &p.new_vol(),
            &leap::recon::fista_tv::FistaOpts {
                iterations: iters,
                tv_weight: args.f64_or("tv-weight", 1e-4) as f32,
                ..Default::default()
            },
        ),
        other => bail!("unknown --algo {other}"),
    };
    println!("{algo}[{}] x{iters} {:.3}s", p.model.name(), t0.elapsed().as_secs_f64());
    report_quality(&vol, &truth);
    let out = args.str_or("out", "recon.raw");
    io::save_vol(&out, &vol)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_dc_refine(args: &Args) -> Result<()> {
    // the Figure-3 pipeline on one bag; the full-dataset version is
    // examples/limited_angle_dc.rs
    let n = args.usize_or("n", 128);
    let nviews = args.usize_or("nviews", 180);
    let keep = args.usize_or("keep", nviews / 3); // 60° of 180°
    let fov = 512.0; // mm
    let voxel = fov / n as f64;
    let vg = VolumeGeometry::slice2d(n, n, voxel);
    let g = ParallelBeam::standard_2d(nviews, (n * 3) / 2, voxel);
    let p = Projector::new(Geometry::Parallel(g.clone()), vg.clone(), Model::SF);

    let bag = luggage::bag(args.u64_or("seed", 7), &luggage::LuggageParams::default());
    let truth = bag.rasterize(&vg, 2);
    let y = bag.project(&Geometry::Parallel(g.clone()));
    let mask = recon::ViewMask::contiguous(nviews, 0, keep);
    let mut y_masked = y.clone();
    mask.apply(&mut y_masked);

    // prior: limited-angle FBP ("inference model input"), then the
    // denoising prior (TV) stands in for the trained network
    let g_lim = ParallelBeam { angles: g.angles[0..keep].to_vec(), ..g.clone() };
    let sino_lim = Sino::from_vec(keep, 1, g.ncols, y.data[..keep * g.ncols].to_vec());
    let mut pred = recon::fbp_parallel(&vg, &g_lim, &sino_lim, recon::Window::Hann, p.threads);
    leap::recon::fista_tv::tv_prox_vol(&mut pred, args.f64_or("prior-tv", 2e-4) as f32, 20);
    for v in pred.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }

    let psnr_pred = metrics::psnr(&pred.data, &truth.data, None);
    let ssim_pred = metrics::ssim_vol(&pred, &truth, None);
    let t0 = std::time::Instant::now();
    let refined = recon::refine(
        &p,
        &y_masked,
        &mask,
        &pred,
        &recon::DcOpts { iterations: args.usize_or("iters", 40), ..Default::default() },
    );
    let dt = t0.elapsed().as_secs_f64();
    let psnr_ref = metrics::psnr(&refined.data, &truth.data, None);
    let ssim_ref = metrics::ssim_vol(&refined, &truth, None);
    println!("bag seed {}: {keep}/{nviews} views kept", args.u64_or("seed", 7));
    println!("  prediction : PSNR {psnr_pred:.3} dB  SSIM {ssim_pred:.4}");
    println!("  refined    : PSNR {psnr_ref:.3} dB  SSIM {ssim_ref:.4}  ({dt:.2}s)");
    if args.flag("pgm") {
        io::write_pgm16("dc_truth.pgm", truth.slice(0), n, n)?;
        io::write_pgm16("dc_pred.pgm", pred.slice(0), n, n)?;
        io::write_pgm16("dc_refined.pgm", refined.slice(0), n, n)?;
        println!("wrote dc_truth.pgm dc_pred.pgm dc_refined.pgm");
    }
    Ok(())
}

fn build_router(
    args: &Args,
    cluster: Option<Arc<leap::cluster::ShardServer>>,
) -> Result<(Arc<Router>, String)> {
    let mut backends: Vec<Arc<dyn Executor>> = Vec::new();
    let mut desc = String::new();
    let artifacts = args.str_or("artifacts", "artifacts");
    match leap::runtime::EngineHost::load(&artifacts) {
        Ok(engine) => {
            desc.push_str(&format!(
                "artifacts[{}]: n={} nviews={} ncols={} ({} entries)",
                artifacts,
                engine.spec.n,
                engine.spec.nviews,
                engine.spec.ncols,
                engine.entry_names().len()
            ));
            backends.push(Arc::new(engine));
        }
        Err(e) => {
            desc.push_str(&format!("artifacts unavailable ({e:#}); native only"));
        }
    }
    let cfg = scan_from_args(args)?;
    let model = model_from_args(args)?;
    backends.push(Arc::new(NativeExecutor::new(Projector::new(
        cfg.geometry,
        cfg.volume,
        model,
    ))));
    // protocol-v2 sessions: any scan config registered at runtime;
    // with a shard channel attached, session projections scatter
    // across connected worker processes (bit-identical to local)
    backends.push(match cluster {
        Some(c) => Arc::new(SessionExecutor::with_cluster(
            leap::coordinator::SessionRegistry::global_arc(),
            c,
        )),
        None => Arc::new(SessionExecutor::new()),
    });
    Ok((Arc::new(Router::new(backends)), desc))
}

fn cmd_serve(args: &Args) -> Result<()> {
    // optional shard channel: worker processes (`leap worker --connect
    // <addr>`) dial in and session projections scatter across them
    let cluster = match args.str_opt("cluster-addr") {
        Some(addr) => Some(Arc::new(leap::cluster::ShardServer::start(addr)?)),
        None => None,
    };
    let (router, desc) = build_router(args, cluster.clone())?;
    println!("{desc}");
    let mut coord = Coordinator::new(
        router,
        BatchPolicy {
            max_batch: args.usize_or("max-batch", 8),
            max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 5)),
        },
        args.usize_or("budget-mb", 2048) * (1 << 20),
        args.usize_or("workers", leap::util::pool::default_threads()),
    );
    // admission control: sheds with typed BudgetExceeded replies once
    // the pending queue reaches --max-pending, instead of queueing
    // unboundedly under overload (0 = unbounded)
    let max_pending = args.usize_or("max-pending", 256);
    if max_pending > 0 {
        coord = coord.with_max_pending(max_pending);
    }
    let coord = Arc::new(coord);
    let addr = args.str_or("addr", "127.0.0.1:7462");
    let opts = ServerOptions {
        max_inflight_per_conn: args.usize_or("max-inflight", DEFAULT_MAX_INFLIGHT_PER_CONN),
        cluster: cluster.clone(),
        ..ServerOptions::default()
    };
    let server = Server::start_with(&addr, coord.clone(), opts)?;
    println!("leap server listening on {} (protocol v2 binary + legacy v1 json)", server.addr);
    if let Some(c) = &cluster {
        println!("shard channel on {} — join with: leap worker --connect {}", c.addr, c.addr);
    }
    println!(
        "admission: max-pending {} / max-inflight-per-conn {}",
        if max_pending > 0 { max_pending.to_string() } else { "unbounded".into() },
        args.usize_or("max-inflight", DEFAULT_MAX_INFLIGHT_PER_CONN),
    );
    println!("ops: {:?}", coord.executor().ops());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let snap = coord.telemetry().to_json();
        println!("telemetry: {snap}");
    }
}

/// Join a coordinator's shard channel and serve sharded projection
/// ranges until the coordinator closes the channel (clean exit) or the
/// connection errors.
fn cmd_worker(args: &Args) -> Result<()> {
    let connect = args.str_or("connect", "127.0.0.1:7463");
    let opts = leap::cluster::WorkerOptions {
        heartbeat_period: std::time::Duration::from_millis(args.u64_or("heartbeat-ms", 2000)),
        threads: args.str_opt("threads").and_then(|t| t.parse().ok()),
        connect_retries: args.usize_or("connect-retries", 50) as u32,
    };
    println!("leap worker: joining shard channel at {connect}");
    leap::cluster::run_worker_with(&connect, opts)?;
    println!("leap worker: shard channel closed, exiting");
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    // 1. native adjoint identities
    let vg = VolumeGeometry::slice2d(24, 24, 1.0);
    let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 36, 1.0));
    let mut rng = leap::util::rng::Rng::new(1);
    for model in [Model::Siddon, Model::Joseph, Model::SF] {
        let p = Projector::new(g.clone(), vg.clone(), model);
        let mut x = p.new_vol();
        let mut y = p.new_sino();
        rng.fill_uniform(&mut x.data, 0.0, 1.0);
        rng.fill_uniform(&mut y.data, 0.0, 1.0);
        let lhs = leap::util::dot_f64(&p.forward(&x).data, &y.data);
        let rhs = leap::util::dot_f64(&x.data, &p.back(&y).data);
        let gap = (lhs - rhs).abs() / lhs.abs().max(1e-12);
        println!("adjoint[{}]: gap {gap:.2e}", model.name());
        if gap > 1e-4 {
            bail!("adjoint identity violated for {}", model.name());
        }
    }
    // 2. artifact engine roundtrip (if built)
    let artifacts = args.str_or("artifacts", "artifacts");
    match leap::runtime::Engine::load(&artifacts) {
        Ok(engine) => {
            let n = engine.spec.n;
            let vol = vec![0.5f32; n * n];
            let sino = engine.run1("fp_sf", &[&vol])?;
            println!(
                "engine fp_sf OK: {} -> {} samples (max {:.4})",
                vol.len(),
                sino.len(),
                sino.iter().cloned().fold(0.0f32, f32::max)
            );
            let back = engine.run1("bp_sf", &[&sino])?;
            println!("engine bp_sf OK: {} samples", back.len());
        }
        Err(e) => println!("artifact engine skipped: {e:#}"),
    }
    println!("selftest OK");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let engine = leap::runtime::Engine::load(&artifacts)?;
    println!(
        "artifact set: n={} nviews={} ncols={} voxel={} du={} arc={}°",
        engine.spec.n,
        engine.spec.nviews,
        engine.spec.ncols,
        engine.spec.voxel,
        engine.spec.du,
        engine.spec.arc_deg
    );
    for name in engine.entry_names() {
        let e = engine.entry(name).unwrap();
        println!("  {name}: inputs {:?} -> outputs {:?}", e.input_shapes, e.output_shapes);
    }
    Ok(())
}
