//! The typed error surface of the front-door API and the wire protocol.
//!
//! Every condition a caller can trigger with user-supplied data — wrong
//! buffer lengths, degenerate geometry, malformed frames, over-budget
//! jobs — surfaces as a [`LeapError`] variant instead of a panic, both
//! from [`crate::api`] entry points and across the coordinator's wire
//! path. Each variant carries a stable numeric wire code
//! ([`LeapError::code`], specified in `docs/PROTOCOL.md`) so protocol-v2
//! error frames stay typed end to end: a server-side `ShapeMismatch`
//! arrives at the client as a [`LeapError`] with
//! [`codes::SHAPE_MISMATCH`], not as an opaque string.

use std::fmt;

/// Stable wire codes for [`LeapError`] variants (protocol v2 error
/// frames carry these in their `code` meta field — see
/// `docs/PROTOCOL.md`). Codes are append-only: never renumber.
pub mod codes {
    pub const BACKEND: u16 = 0;
    pub const PROTOCOL: u16 = 1;
    pub const VERSION_MISMATCH: u16 = 2;
    pub const UNKNOWN_OP: u16 = 3;
    pub const SHAPE_MISMATCH: u16 = 4;
    pub const INVALID_GEOMETRY: u16 = 5;
    pub const BUDGET_EXCEEDED: u16 = 6;
    pub const UNKNOWN_SESSION: u16 = 7;
    pub const INVALID_ARGUMENT: u16 = 8;
    pub const UNSUPPORTED: u16 = 9;
    pub const IO: u16 = 10;
}

/// The typed error of the `leap::api` surface and the serving wire path.
#[derive(Clone, Debug, PartialEq)]
pub enum LeapError {
    /// A user-supplied buffer does not have the element count the scan
    /// requires (`what` names the buffer: "volume", "sinogram", …).
    ShapeMismatch { what: &'static str, expected: usize, got: usize },
    /// A scan description is degenerate (zero-sized grids, non-positive
    /// pitches, non-finite values, inconsistent distances, …).
    InvalidGeometry(String),
    /// A solver/loss option is out of its valid range.
    InvalidArgument(String),
    /// The operation is well-formed but not available for this scan
    /// (e.g. FBP on a modular geometry).
    Unsupported(String),
    /// The job can never fit the coordinator's memory budget.
    BudgetExceeded { needed: usize, cap: usize },
    /// A malformed or truncated wire frame / request document.
    Protocol(String),
    /// The peer speaks an unsupported protocol version.
    VersionMismatch { got: u8, want: u8 },
    /// No backend provides the requested operation.
    UnknownOp(String),
    /// A request referenced a session id that is not open.
    UnknownSession(u64),
    /// The executing backend failed for a reason of its own.
    Backend(String),
    /// An I/O error on the wire.
    Io(String),
    /// An error reported by a remote server whose wire code has no
    /// lossless local reconstruction; `code` preserves the typed wire
    /// code (see [`codes`]).
    Remote { code: u16, message: String },
}

impl LeapError {
    /// The stable wire code of this error (see [`codes`]).
    pub fn code(&self) -> u16 {
        match self {
            LeapError::Backend(_) => codes::BACKEND,
            LeapError::Protocol(_) => codes::PROTOCOL,
            LeapError::VersionMismatch { .. } => codes::VERSION_MISMATCH,
            LeapError::UnknownOp(_) => codes::UNKNOWN_OP,
            LeapError::ShapeMismatch { .. } => codes::SHAPE_MISMATCH,
            LeapError::InvalidGeometry(_) => codes::INVALID_GEOMETRY,
            LeapError::BudgetExceeded { .. } => codes::BUDGET_EXCEEDED,
            LeapError::UnknownSession(_) => codes::UNKNOWN_SESSION,
            LeapError::InvalidArgument(_) => codes::INVALID_ARGUMENT,
            LeapError::Unsupported(_) => codes::UNSUPPORTED,
            LeapError::Io(_) => codes::IO,
            LeapError::Remote { code, .. } => *code,
        }
    }

    /// Reconstruct a typed error from a wire `(code, message)` pair.
    /// Variants whose state is exactly their message round-trip
    /// losslessly; the rest keep their typed code in
    /// [`LeapError::Remote`].
    pub fn from_wire(code: u16, message: String) -> LeapError {
        match code {
            codes::BACKEND => LeapError::Backend(message),
            codes::PROTOCOL => LeapError::Protocol(message),
            codes::UNKNOWN_OP => LeapError::UnknownOp(message),
            codes::INVALID_GEOMETRY => LeapError::InvalidGeometry(message),
            codes::INVALID_ARGUMENT => LeapError::InvalidArgument(message),
            codes::UNSUPPORTED => LeapError::Unsupported(message),
            codes::IO => LeapError::Io(message),
            _ => LeapError::Remote { code, message },
        }
    }
}

impl fmt::Display for LeapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LeapError::ShapeMismatch { what, expected, got } => {
                write!(f, "shape mismatch: {what} needs {expected} elements, got {got}")
            }
            LeapError::InvalidGeometry(m) => write!(f, "invalid geometry: {m}"),
            LeapError::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            LeapError::Unsupported(m) => write!(f, "unsupported: {m}"),
            LeapError::BudgetExceeded { needed, cap } => {
                write!(f, "job exceeds memory budget ({needed} bytes > cap {cap})")
            }
            LeapError::Protocol(m) => write!(f, "protocol error: {m}"),
            LeapError::VersionMismatch { got, want } => {
                write!(f, "protocol version mismatch: peer speaks v{got}, this end v{want}")
            }
            LeapError::UnknownOp(op) => write!(f, "no backend provides op {op}"),
            LeapError::UnknownSession(id) => write!(f, "unknown session {id}"),
            LeapError::Backend(m) => write!(f, "backend error: {m}"),
            LeapError::Io(m) => write!(f, "io error: {m}"),
            LeapError::Remote { code, message } => {
                write!(f, "remote error (code {code}): {message}")
            }
        }
    }
}

impl std::error::Error for LeapError {}

impl From<std::io::Error> for LeapError {
    fn from(e: std::io::Error) -> LeapError {
        LeapError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_variants() -> Vec<LeapError> {
        vec![
            LeapError::ShapeMismatch { what: "volume", expected: 10, got: 3 },
            LeapError::InvalidGeometry("ncols = 0".into()),
            LeapError::InvalidArgument("lambda must be positive".into()),
            LeapError::Unsupported("fbp on modular".into()),
            LeapError::BudgetExceeded { needed: 100, cap: 10 },
            LeapError::Protocol("truncated frame".into()),
            LeapError::VersionMismatch { got: 3, want: 2 },
            LeapError::UnknownOp("warp".into()),
            LeapError::UnknownSession(9),
            LeapError::Backend("pjrt exploded".into()),
            LeapError::Io("connection reset".into()),
        ]
    }

    #[test]
    fn codes_are_distinct_and_stable() {
        let mut seen = std::collections::HashSet::new();
        for e in all_variants() {
            assert!(seen.insert(e.code()), "duplicate code for {e:?}");
        }
        // stable anchors (never renumber)
        assert_eq!(LeapError::Protocol("x".into()).code(), 1);
        assert_eq!(
            LeapError::ShapeMismatch { what: "volume", expected: 1, got: 2 }.code(),
            4
        );
        assert_eq!(LeapError::BudgetExceeded { needed: 1, cap: 0 }.code(), 6);
    }

    #[test]
    fn wire_roundtrip_preserves_the_code() {
        for e in all_variants() {
            let back = LeapError::from_wire(e.code(), e.to_string());
            assert_eq!(back.code(), e.code(), "{e:?} → {back:?}");
        }
    }

    #[test]
    fn display_is_descriptive() {
        let e = LeapError::ShapeMismatch { what: "sinogram", expected: 432, got: 7 };
        let s = e.to_string();
        assert!(s.contains("sinogram") && s.contains("432") && s.contains("7"), "{s}");
        assert!(LeapError::BudgetExceeded { needed: 9, cap: 4 }
            .to_string()
            .contains("memory budget"));
        assert!(LeapError::UnknownOp("warp".into()).to_string().contains("no backend"));
    }
}
