//! The typed front-door API: validated scans, fallible operations.
//!
//! The projector/recon modules are the *kernel layer*: fast, concrete,
//! and panicking on misuse — the right contract for internal hot loops
//! whose shapes are proven by construction. Integrating with a training
//! or serving pipeline needs the opposite contract: every user-supplied
//! buffer and every scan description is validated up front, and every
//! failure is a typed, matchable [`LeapError`] — never a panic. This
//! module is that front door, the shape TorchRadon/CTorch expose to
//! PyTorch and the one the wire protocol (see
//! [`crate::coordinator::wire`]) speaks natively:
//!
//! * [`ScanBuilder`] — collect geometry + volume + model (+ threads,
//!   + compute backend), then [`ScanBuilder::build`] validates the whole
//!   description (non-zero grids, positive pitches, finite values,
//!   consistent distances, an executable backend) and plans it once,
//!   returning a [`Scan`].
//! * [`Scan`] — a validated scan owning an `Arc<`[`ProjectionPlan`]`>`
//!   (shared through the process-wide plan cache, so repeated builds of
//!   the same scan never re-plan). `forward`/`back` run the matched
//!   pair, [`Scan::solve`] runs any reconstruction [`Solver`], and
//!   [`Scan::loss_grad`] evaluates a data-fit objective with its exact
//!   gradient — all returning `Result<_, LeapError>` after checking
//!   every buffer length. A `Scan` is itself a
//!   [`crate::ops::LinearOp`], so it drops into the operator layer and
//!   the generic solver cores directly.
//!
//! The panicking entry points ([`crate::projector::Projector::forward`],
//! the concrete solver functions, …) remain as the kernel layer beneath
//! this one and are what [`Scan`] dispatches to after validation;
//! new user-facing code should come through here.
//!
//! ```no_run
//! use leap::api::{ScanBuilder, Solver};
//! use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
//! use leap::projector::Model;
//! use leap::recon::Window;
//!
//! let scan = ScanBuilder::new()
//!     .geometry(Geometry::Parallel(ParallelBeam::standard_2d(180, 192, 1.0)))
//!     .volume(VolumeGeometry::slice2d(128, 128, 1.0))
//!     .model(Model::SF)
//!     .build()?;
//! let sino = scan.forward(&vec![0.01; 128 * 128])?;
//! let fbp = scan.solve(Solver::Fbp { window: Window::Hann }, &sino)?;
//! let sirt = scan.solve(Solver::Sirt { iterations: 50, lambda: 1.0, nonneg: true }, &sino)?;
//! # Ok::<(), leap::api::LeapError>(())
//! ```

pub mod error;

pub use error::{codes, LeapError};

use std::sync::{Arc, Mutex};

use crate::array::{Sino, Vol3};
use crate::backend::{self, BackendKind};
use crate::coordinator::plan_cache;
use crate::geometry::config::{scan_from_str, ScanConfig};
use crate::geometry::{Geometry, VolumeGeometry};
use crate::ops::{LinearOp, Objective, PlanOp, ProjectionLoss, Shape};
use crate::precision::StorageTier;
use crate::projector::{Model, ProjectionPlan, Projector};
use crate::recon;
use crate::recon::Window;

/// Grids beyond this element count are rejected as degenerate rather
/// than risking overflow/OOM from wire-supplied configs (2⁴⁰ ≈ 1 T
/// elements — far above any real scan).
const MAX_ELEMENTS: u128 = 1 << 40;

/// Validate a volume grid description.
pub fn validate_volume(vg: &VolumeGeometry) -> Result<(), LeapError> {
    let bad = |m: String| Err(LeapError::InvalidGeometry(m));
    if vg.nx == 0 || vg.ny == 0 || vg.nz == 0 {
        return bad(format!("volume grid must be non-empty (got {}×{}×{})", vg.nx, vg.ny, vg.nz));
    }
    if (vg.nx as u128) * (vg.ny as u128) * (vg.nz as u128) > MAX_ELEMENTS {
        return bad(format!("volume grid too large ({}×{}×{})", vg.nx, vg.ny, vg.nz));
    }
    for (name, v) in [("vx", vg.vx), ("vy", vg.vy), ("vz", vg.vz)] {
        if !(v.is_finite() && v > 0.0) {
            return bad(format!("voxel pitch {name} must be positive and finite (got {v})"));
        }
    }
    for (name, c) in [("cx", vg.cx), ("cy", vg.cy), ("cz", vg.cz)] {
        if !c.is_finite() {
            return bad(format!("volume center {name} must be finite (got {c})"));
        }
    }
    Ok(())
}

/// Validate a scanner geometry description.
pub fn validate_geometry(g: &Geometry) -> Result<(), LeapError> {
    let bad = |m: String| Err(LeapError::InvalidGeometry(m));
    if g.nviews() == 0 || g.nrows() == 0 || g.ncols() == 0 {
        return bad(format!(
            "detector must be non-empty ({} views × {} rows × {} cols)",
            g.nviews(),
            g.nrows(),
            g.ncols()
        ));
    }
    if (g.nviews() as u128) * (g.nrows() as u128) * (g.ncols() as u128) > MAX_ELEMENTS {
        return bad(format!(
            "sinogram too large ({}×{}×{})",
            g.nviews(),
            g.nrows(),
            g.ncols()
        ));
    }
    let check_pitch = |name: &str, v: f64| -> Result<(), LeapError> {
        if v.is_finite() && v > 0.0 {
            Ok(())
        } else {
            Err(LeapError::InvalidGeometry(format!(
                "detector pitch {name} must be positive and finite (got {v})"
            )))
        }
    };
    let check_angles = |angles: &[f64]| -> Result<(), LeapError> {
        match angles.iter().find(|a| !a.is_finite()) {
            Some(a) => Err(LeapError::InvalidGeometry(format!("non-finite view angle {a}"))),
            None => Ok(()),
        }
    };
    let check_sod_sdd = |sod: f64, sdd: f64| -> Result<(), LeapError> {
        if !(sod.is_finite() && sdd.is_finite() && sod > 0.0 && sdd > sod) {
            Err(LeapError::InvalidGeometry(format!(
                "need 0 < sod < sdd (got sod {sod}, sdd {sdd})"
            )))
        } else {
            Ok(())
        }
    };
    match g {
        Geometry::Parallel(p) => {
            check_pitch("du", p.du)?;
            check_pitch("dv", p.dv)?;
            check_angles(&p.angles)?;
        }
        Geometry::Fan(f) => {
            check_pitch("du", f.du)?;
            check_angles(&f.angles)?;
            check_sod_sdd(f.sod, f.sdd)?;
        }
        Geometry::Cone(c) => {
            check_pitch("du", c.du)?;
            check_pitch("dv", c.dv)?;
            check_angles(&c.angles)?;
            check_sod_sdd(c.sod, c.sdd)?;
        }
        Geometry::Modular(m) => {
            check_pitch("du", m.du)?;
            check_pitch("dv", m.dv)?;
            m.validate().map_err(LeapError::InvalidGeometry)?;
        }
    }
    Ok(())
}

/// Builder for a validated [`Scan`].
#[derive(Clone, Debug, Default)]
pub struct ScanBuilder {
    geometry: Option<Geometry>,
    volume: Option<VolumeGeometry>,
    model: Option<Model>,
    threads: Option<usize>,
    backend: Option<BackendKind>,
    backend_str: Option<String>,
    storage: Option<StorageTier>,
    storage_str: Option<String>,
}

impl ScanBuilder {
    pub fn new() -> ScanBuilder {
        ScanBuilder::default()
    }

    /// Start from a parsed scan config (geometry + volume).
    pub fn from_config(cfg: &ScanConfig) -> ScanBuilder {
        ScanBuilder::new().geometry(cfg.geometry.clone()).volume(cfg.volume.clone())
    }

    /// Start from a JSON scan config document (the same format
    /// [`crate::geometry::config`] reads from files).
    pub fn from_config_str(text: &str) -> Result<ScanBuilder, LeapError> {
        let cfg = scan_from_str(text).map_err(LeapError::InvalidGeometry)?;
        Ok(ScanBuilder::from_config(&cfg))
    }

    pub fn geometry(mut self, g: Geometry) -> ScanBuilder {
        self.geometry = Some(g);
        self
    }

    pub fn volume(mut self, vg: VolumeGeometry) -> ScanBuilder {
        self.volume = Some(vg);
        self
    }

    /// Projection model (defaults to [`Model::SF`], the paper's most
    /// accurate).
    pub fn model(mut self, m: Model) -> ScanBuilder {
        self.model = Some(m);
        self
    }

    /// Worker threads (defaults to the pool size; `0` clamps to 1).
    pub fn threads(mut self, n: usize) -> ScanBuilder {
        self.threads = Some(n);
        self
    }

    /// Compute backend the kernels execute on (defaults to the process
    /// default: `LEAP_BACKEND`, else runtime detection — see
    /// [`crate::backend::default_kind`]). [`Self::build`] rejects
    /// backends that cannot execute projection (the feature-gated PJRT
    /// slot) with a typed [`LeapError::Unsupported`].
    pub fn backend(mut self, kind: BackendKind) -> ScanBuilder {
        self.backend = Some(kind);
        self
    }

    /// [`Self::backend`] from a backend name (`"scalar"`, `"simd"`,
    /// `"pjrt"`), for config- and wire-driven callers. Unknown names are
    /// a typed [`LeapError::InvalidArgument`] at [`Self::build`] time; a
    /// typed [`Self::backend`] call takes precedence when both are set.
    pub fn backend_str(mut self, name: &str) -> ScanBuilder {
        self.backend_str = Some(name.to_string());
        self
    }

    /// Storage precision tier for data at rest — cached plan coefficient
    /// tables and backprojection input sinograms (defaults to the process
    /// default: `LEAP_STORAGE`, else f32 — see
    /// [`crate::precision::default_tier`]). Accumulation always stays
    /// f32; see `docs/MEMORY.md` for the per-tier accuracy classes.
    pub fn storage_tier(mut self, tier: StorageTier) -> ScanBuilder {
        self.storage = Some(tier);
        self
    }

    /// [`Self::storage_tier`] from a tier name (`"f32"`, `"f16"`,
    /// `"bf16"`), for config- and wire-driven callers. Unknown names are
    /// a typed [`LeapError::InvalidArgument`] at [`Self::build`] time; a
    /// typed [`Self::storage_tier`] call takes precedence when both are
    /// set.
    pub fn storage_tier_str(mut self, name: &str) -> ScanBuilder {
        self.storage_str = Some(name.to_string());
        self
    }

    /// Validate the description and plan the scan. The plan is fetched
    /// from (or inserted into) the process-wide plan cache, so repeated
    /// builds of the same scan share one [`ProjectionPlan`].
    pub fn build(self) -> Result<Scan, LeapError> {
        let geometry = self
            .geometry
            .ok_or_else(|| LeapError::InvalidGeometry("missing geometry".into()))?;
        let volume =
            self.volume.ok_or_else(|| LeapError::InvalidGeometry("missing volume".into()))?;
        validate_geometry(&geometry)?;
        validate_volume(&volume)?;
        let mut projector = Projector::new(geometry, volume, self.model.unwrap_or(Model::SF));
        if let Some(t) = self.threads {
            projector = projector.with_threads(t);
        }
        let kind = match (self.backend, &self.backend_str) {
            (Some(k), _) => Some(k),
            (None, Some(s)) => Some(BackendKind::parse(s.trim()).ok_or_else(|| {
                LeapError::InvalidArgument(format!(
                    "unknown backend {s:?} (expected scalar|simd|pjrt)"
                ))
            })?),
            (None, None) => None, // Projector::new took the process default
        };
        if let Some(kind) = kind {
            if !backend::get(kind).caps().projection {
                return Err(LeapError::Unsupported(format!(
                    "backend {:?} cannot execute projection (registered slot only; \
                     enable and wire its engine to use it)",
                    kind.name()
                )));
            }
            projector = projector.with_backend(kind);
        }
        let tier = match (self.storage, &self.storage_str) {
            (Some(t), _) => Some(t),
            (None, Some(s)) => Some(StorageTier::parse(s.trim()).ok_or_else(|| {
                LeapError::InvalidArgument(format!(
                    "unknown storage tier {s:?} (expected f32|f16|bf16)"
                ))
            })?),
            (None, None) => None, // Projector::new took the process default
        };
        if let Some(tier) = tier {
            projector = projector.with_storage_tier(tier);
        }
        let plan = plan_cache::global().get_or_plan(&projector);
        let scratch = Mutex::new((plan.new_vol(), plan.new_sino()));
        Ok(Scan { projector, plan, scratch })
    }
}

/// Reconstruction algorithm selector for [`Scan::solve`].
#[derive(Clone, Debug)]
pub enum Solver {
    /// Analytic: FBP (parallel/fan) or FDK (cone) with an apodized ramp.
    Fbp { window: Window },
    /// SIRT with relaxation `lambda` ∈ (0, 2).
    Sirt { iterations: usize, lambda: f32, nonneg: bool },
    /// Ordered-subsets SART (`subsets` interleaved view subsets).
    OsSart { iterations: usize, subsets: usize, lambda: f32, nonneg: bool },
    /// Conjugate gradients on the normal equations.
    Cgls { iterations: usize },
    /// Maximum-likelihood EM (Poisson noise model; `y ≥ 0`).
    Mlem { iterations: usize },
    /// FISTA with a total-variation prox (`tv_weight` ≥ 0).
    FistaTv { iterations: usize, tv_weight: f32 },
}

/// A validated, planned scan: the typed front door to the matched
/// projector pair, the solvers and the gradient layer. Owns an
/// `Arc<ProjectionPlan>` shared with the plan cache, plus one reusable
/// volume + sinogram scratch pair (under a lock, like
/// [`PlanOp`]) — so `forward_into`/`back_into` are allocation-free and
/// `forward`/`back` allocate only their returned buffer. Concurrent
/// applications on one `Scan` serialize on that scratch; for parallel
/// callers, build one [`PlanOp`] per thread from [`Scan::plan`] (the
/// plan itself is shared and immutable).
pub struct Scan {
    projector: Projector,
    plan: Arc<ProjectionPlan>,
    scratch: Mutex<(Vol3, Sino)>,
}

impl Scan {
    /// The underlying (kernel-layer) projector.
    pub fn projector(&self) -> &Projector {
        &self.projector
    }

    /// The shared plan (e.g. to build [`PlanOp`]s or other operators).
    pub fn plan(&self) -> &Arc<ProjectionPlan> {
        &self.plan
    }

    pub fn geometry(&self) -> &Geometry {
        &self.projector.geom
    }

    pub fn volume(&self) -> &VolumeGeometry {
        &self.projector.vg
    }

    pub fn model(&self) -> Model {
        self.projector.model
    }

    /// Compute backend this scan's kernels execute on (always an
    /// executable tier — [`ScanBuilder::build`] gates the rest).
    pub fn backend(&self) -> BackendKind {
        self.projector.backend
    }

    /// Storage precision tier this scan's data at rest is held in
    /// (coefficient tables and backprojection input sinograms).
    pub fn storage_tier(&self) -> StorageTier {
        self.projector.storage
    }

    /// The scan config this scan was built from (round-trips through
    /// the JSON config format and the protocol-v2 session handshake).
    pub fn config(&self) -> ScanConfig {
        ScanConfig { geometry: self.projector.geom.clone(), volume: self.projector.vg.clone() }
    }

    /// Element count of a volume buffer for this scan.
    pub fn volume_len(&self) -> usize {
        self.projector.vg.num_voxels()
    }

    /// Element count of a sinogram buffer for this scan.
    pub fn sino_len(&self) -> usize {
        let g = &self.projector.geom;
        g.nviews() * g.nrows() * g.ncols()
    }

    fn check(&self, what: &'static str, expected: usize, got: usize) -> Result<(), LeapError> {
        if expected == got {
            Ok(())
        } else {
            Err(LeapError::ShapeMismatch { what, expected, got })
        }
    }

    fn sino_from(&self, data: &[f32]) -> Result<Sino, LeapError> {
        self.check("sinogram", self.sino_len(), data.len())?;
        let g = &self.projector.geom;
        Ok(Sino::from_vec(g.nviews(), g.nrows(), g.ncols(), data.to_vec()))
    }

    /// Forward projection `A·vol` through the shared plan (allocates
    /// only the returned sinogram).
    pub fn forward(&self, vol: &[f32]) -> Result<Vec<f32>, LeapError> {
        self.check("volume", self.volume_len(), vol.len())?;
        let mut guard = self.scratch.lock().unwrap();
        let (v, s) = &mut *guard;
        v.data.copy_from_slice(vol);
        self.plan.forward_into(v, s);
        Ok(s.data.clone())
    }

    /// Forward projection into a caller-owned buffer —
    /// **allocation-free** (stages through the scan's reusable scratch).
    pub fn forward_into(&self, vol: &[f32], sino_out: &mut [f32]) -> Result<(), LeapError> {
        self.check("volume", self.volume_len(), vol.len())?;
        self.check("sinogram", self.sino_len(), sino_out.len())?;
        let mut guard = self.scratch.lock().unwrap();
        let (v, s) = &mut *guard;
        v.data.copy_from_slice(vol);
        self.plan.forward_into(v, s);
        sino_out.copy_from_slice(&s.data);
        Ok(())
    }

    /// Matched backprojection `Aᵀ·sino` through the shared plan
    /// (allocates only the returned volume).
    pub fn back(&self, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        self.check("sinogram", self.sino_len(), sino.len())?;
        let mut guard = self.scratch.lock().unwrap();
        let (v, s) = &mut *guard;
        s.data.copy_from_slice(sino);
        self.plan.back_into(s, v);
        Ok(v.data.clone())
    }

    /// Matched backprojection into a caller-owned buffer —
    /// **allocation-free** (stages through the scan's reusable scratch).
    pub fn back_into(&self, sino: &[f32], vol_out: &mut [f32]) -> Result<(), LeapError> {
        self.check("sinogram", self.sino_len(), sino.len())?;
        self.check("volume", self.volume_len(), vol_out.len())?;
        let mut guard = self.scratch.lock().unwrap();
        let (v, s) = &mut *guard;
        s.data.copy_from_slice(sino);
        self.plan.back_into(s, v);
        vol_out.copy_from_slice(&v.data);
        Ok(())
    }

    /// Reconstruct `sino` with `solver`, returning the volume (flat,
    /// `[z][y][x]` layout). Iterative solvers start from zeros and run
    /// their generic cores on this scan's shared plan.
    pub fn solve(&self, solver: Solver, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        self.check("sinogram", self.sino_len(), sino.len())?;
        let check_lambda = |lambda: f32| -> Result<(), LeapError> {
            if lambda.is_finite() && lambda > 0.0 {
                Ok(())
            } else {
                Err(LeapError::InvalidArgument(format!(
                    "relaxation lambda must be positive and finite (got {lambda})"
                )))
            }
        };
        if let Solver::Fbp { window } = solver {
            return self.fbp(sino, window);
        }
        let op = PlanOp::from_plan(self.plan.clone());
        let x0 = vec![0.0f32; self.volume_len()];
        match solver {
            Solver::Fbp { .. } => unreachable!("handled above"),
            Solver::Sirt { iterations, lambda, nonneg } => {
                check_lambda(lambda)?;
                let opts = recon::SirtOpts {
                    iterations,
                    lambda,
                    nonneg,
                    view_mask: None,
                    track_residual: false,
                };
                Ok(recon::sirt_op(&op, sino, &x0, &opts).0)
            }
            Solver::OsSart { iterations, subsets, lambda, nonneg } => {
                check_lambda(lambda)?;
                if subsets == 0 {
                    return Err(LeapError::InvalidArgument(
                        "os-sart needs at least one subset".into(),
                    ));
                }
                let opts = recon::os_sart::OsSartOpts { iterations, subsets, lambda, nonneg };
                Ok(recon::os_sart::os_sart_op(&op, sino, &x0, &opts))
            }
            Solver::Cgls { iterations } => Ok(recon::cgls::cgls_op(&op, sino, &x0, iterations).0),
            Solver::Mlem { iterations } => {
                if let Some(v) = sino.iter().find(|v| !(v.is_finite() && **v >= 0.0)) {
                    return Err(LeapError::InvalidArgument(format!(
                        "mlem needs non-negative finite measurements (got {v})"
                    )));
                }
                Ok(recon::mlem::mlem_op(&op, sino, iterations))
            }
            Solver::FistaTv { iterations, tv_weight } => {
                if !(tv_weight.is_finite() && tv_weight >= 0.0) {
                    return Err(LeapError::InvalidArgument(format!(
                        "tv weight must be non-negative and finite (got {tv_weight})"
                    )));
                }
                let opts = recon::fista_tv::FistaOpts {
                    iterations,
                    tv_weight,
                    ..Default::default()
                };
                Ok(recon::fista_tv::fista_tv_op(&op, sino, &x0, &opts))
            }
        }
    }

    fn fbp(&self, sino: &[f32], window: Window) -> Result<Vec<f32>, LeapError> {
        let s = self.sino_from(sino)?;
        let vg = &self.projector.vg;
        let threads = self.projector.threads;
        let vol = match &self.projector.geom {
            Geometry::Parallel(g) => recon::fbp_parallel(vg, g, &s, window, threads),
            Geometry::Fan(g) => recon::fbp_fan(vg, g, &s, window, threads),
            Geometry::Cone(g) => recon::fdk(vg, g, &s, window, threads),
            Geometry::Modular(_) => {
                return Err(LeapError::Unsupported(
                    "fbp is not defined for modular geometries (use an iterative solver)".into(),
                ))
            }
        };
        Ok(vol.data)
    }

    /// Start a [`crate::tape::PipelineBuilder`] with this scan's plan
    /// registered as the `"scan"` operator — the entry point for
    /// building trainable reconstruction pipelines (see
    /// [`crate::tape`]). Returns the builder and the operator handle:
    ///
    /// ```no_run
    /// # use leap::api::ScanBuilder;
    /// # use leap::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    /// use leap::ops::LinearOp; // domain_shape()/range_shape() on Scan
    /// # let scan = ScanBuilder::new()
    /// #     .geometry(Geometry::Parallel(ParallelBeam::standard_2d(8, 16, 1.0)))
    /// #     .volume(VolumeGeometry::slice2d(16, 16, 1.0))
    /// #     .build()?;
    /// let (mut pb, a) = scan.pipeline();
    /// let b = pb.input(scan.range_shape())?;
    /// let truth = pb.input(scan.domain_shape())?;
    /// let x0 = pb.fill(scan.domain_shape(), 0.0)?;
    /// let ax = pb.apply(a, x0)?;
    /// # Ok::<(), leap::api::LeapError>(())
    /// ```
    ///
    /// The `"scan"` name is what the serving side rebinds when the same
    /// pipeline is registered on a protocol-v2 session, so a pipeline
    /// built here trains identically in-process and over the wire.
    pub fn pipeline(&self) -> (crate::tape::PipelineBuilder, crate::tape::OpRef) {
        let mut pb = crate::tape::PipelineBuilder::new();
        let op = pb
            .op("scan", Arc::new(PlanOp::from_plan(self.plan.clone())))
            .expect("first op registration cannot collide");
        (pb, op)
    }

    /// Train a tape pipeline's parameters against `inputs` (typed
    /// validation, deterministic optimization — see
    /// [`crate::tape::optim::fit`]). The pipeline must have been built
    /// for **this** scan (its `"scan"` operator shapes are checked, so a
    /// pipeline from a different geometry is a typed error instead of a
    /// silent wrong-scan fit).
    pub fn fit(
        &self,
        pipe: &mut crate::tape::Pipeline,
        inputs: &[&[f32]],
        cfg: &crate::tape::FitCfg,
    ) -> Result<crate::tape::FitReport, LeapError> {
        let dom = self.plan.domain_shape();
        let rng = self.plan.range_shape();
        for entry in pipe.op_shapes() {
            let (name, pdom, prng) = entry;
            if name == "scan" && (pdom != dom || prng != rng) {
                return Err(LeapError::InvalidArgument(format!(
                    "pipeline was built for a different scan \
                     (its \"scan\" op is {:?}→{:?}, this scan is {:?}→{:?})",
                    pdom.0, prng.0, dom.0, rng.0
                )));
            }
        }
        crate::tape::optim::fit(pipe, inputs, cfg)
    }

    /// Evaluate a data-fit objective `L(x)` against measurements `data`
    /// and write its exact gradient (through the matched adjoint) into
    /// `grad`. Returns the loss value.
    pub fn loss_grad(
        &self,
        objective: Objective,
        data: &[f32],
        x: &[f32],
        grad: &mut [f32],
    ) -> Result<f64, LeapError> {
        self.check("measurements", self.sino_len(), data.len())?;
        self.check("volume", self.volume_len(), x.len())?;
        self.check("gradient", self.volume_len(), grad.len())?;
        if objective == Objective::PoissonNll {
            if let Some(v) = data.iter().find(|v| !(v.is_finite() && **v >= 0.0)) {
                return Err(LeapError::InvalidArgument(format!(
                    "poisson nll needs non-negative finite measurements (got {v})"
                )));
            }
        }
        let op: &dyn LinearOp = &*self.plan;
        Ok(ProjectionLoss::new(op, data, objective).value_and_grad(x, grad))
    }
}

/// A validated scan is directly a [`LinearOp`] (delegating to its shared
/// plan), so it composes with the operator layer and the generic solver
/// cores. Note the `LinearOp` contract is the kernel layer's: lengths
/// are the caller's responsibility there — use the `Scan` methods for
/// the checked surface.
impl LinearOp for Scan {
    fn domain_shape(&self) -> Shape {
        self.plan.domain_shape()
    }

    fn range_shape(&self) -> Shape {
        self.plan.range_shape()
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        self.plan.apply_into(x, y)
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        self.plan.adjoint_into(y, x)
    }

    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        self.plan.apply_batch_into(batch, xs, ys)
    }

    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        self.plan.adjoint_batch_into(batch, ys, xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::ParallelBeam;

    fn builder() -> ScanBuilder {
        ScanBuilder::new()
            .geometry(Geometry::Parallel(ParallelBeam::standard_2d(12, 18, 1.0)))
            .volume(VolumeGeometry::slice2d(12, 12, 1.0))
            .model(Model::SF)
            .threads(2)
    }

    #[test]
    fn build_validates_and_plans() {
        let scan = builder().build().unwrap();
        assert_eq!(scan.volume_len(), 144);
        assert_eq!(scan.sino_len(), 12 * 18);
        assert!(scan.plan().matches(scan.projector()));
    }

    #[test]
    fn degenerate_descriptions_are_typed_errors() {
        let zero_cols = ScanBuilder::new()
            .geometry(Geometry::Parallel(ParallelBeam {
                nrows: 1,
                ncols: 0,
                du: 1.0,
                dv: 1.0,
                cu: 0.0,
                cv: 0.0,
                angles: vec![0.0],
            }))
            .volume(VolumeGeometry::slice2d(4, 4, 1.0))
            .build();
        assert!(matches!(zero_cols, Err(LeapError::InvalidGeometry(_))), "{zero_cols:?}");

        let bad_pitch = builder().volume(VolumeGeometry::slice2d(4, 4, -1.0)).build();
        assert!(matches!(bad_pitch, Err(LeapError::InvalidGeometry(_))));

        let missing = ScanBuilder::new().volume(VolumeGeometry::slice2d(4, 4, 1.0)).build();
        assert!(matches!(missing, Err(LeapError::InvalidGeometry(_))));

        let bad_sod = ScanBuilder::new()
            .geometry(Geometry::Fan(crate::geometry::FanBeam::standard(
                4, 8, 1.0, 100.0, 50.0, // sdd < sod
            )))
            .volume(VolumeGeometry::slice2d(4, 4, 1.0))
            .build();
        assert!(matches!(bad_sod, Err(LeapError::InvalidGeometry(_))), "{bad_sod:?}");
    }

    #[test]
    fn forward_back_match_the_kernel_layer_bit_for_bit() {
        let scan = builder().build().unwrap();
        let mut rng = crate::util::rng::Rng::new(3);
        let mut x = vec![0.0f32; scan.volume_len()];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let via_api = scan.forward(&x).unwrap();
        let vol = Vol3::from_vec(12, 12, 1, x.clone());
        assert_eq!(via_api, scan.projector().forward(&vol).data);
        let mut y = vec![0.0f32; scan.sino_len()];
        rng.fill_uniform(&mut y, 0.0, 1.0);
        let back_api = scan.back(&y).unwrap();
        let sino = Sino::from_vec(12, 1, 18, y.clone());
        assert_eq!(back_api, scan.projector().back(&sino).data);
    }

    #[test]
    fn wrong_lengths_are_shape_mismatches_not_panics() {
        let scan = builder().build().unwrap();
        let e = scan.forward(&[1.0, 2.0]).unwrap_err();
        assert_eq!(e, LeapError::ShapeMismatch { what: "volume", expected: 144, got: 2 });
        let e = scan.back(&[0.0; 7]).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "sinogram", .. }));
        let e = scan.solve(Solver::Cgls { iterations: 1 }, &[0.0; 3]).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { .. }));
        let mut grad = vec![0.0; 10]; // wrong length
        let data = vec![0.0; scan.sino_len()];
        let x = vec![0.0; scan.volume_len()];
        let e = scan.loss_grad(Objective::LeastSquares, &data, &x, &mut grad).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "gradient", .. }));
    }

    #[test]
    fn solve_matches_the_concrete_solvers() {
        let scan = builder().build().unwrap();
        let truth = crate::phantom::shepp::shepp_logan_2d(5.0, 0.02)
            .rasterize(scan.volume(), 2);
        let y = scan.forward(&truth.data).unwrap();
        let via_api = scan
            .solve(Solver::Sirt { iterations: 5, lambda: 1.0, nonneg: true }, &y)
            .unwrap();
        let sino = Sino::from_vec(12, 1, 18, y.clone());
        let concrete = recon::sirt(
            scan.projector(),
            &sino,
            &scan.projector().new_vol(),
            &recon::SirtOpts { iterations: 5, ..Default::default() },
        );
        assert_eq!(via_api, concrete.vol.data, "api sirt must be bit-identical");

        let via_fbp = scan.solve(Solver::Fbp { window: Window::Hann }, &y).unwrap();
        assert_eq!(via_fbp.len(), scan.volume_len());

        let e = scan
            .solve(Solver::Sirt { iterations: 1, lambda: -1.0, nonneg: true }, &y)
            .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)));
        let e = scan
            .solve(Solver::OsSart { iterations: 1, subsets: 0, lambda: 1.0, nonneg: true }, &y)
            .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)));
    }

    #[test]
    fn loss_grad_matches_the_ops_layer() {
        let scan = builder().build().unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let mut x = vec![0.0f32; scan.volume_len()];
        rng.fill_uniform(&mut x, 0.2, 1.0);
        let mut truth = vec![0.0f32; scan.volume_len()];
        rng.fill_uniform(&mut truth, 0.2, 1.0);
        let b = scan.forward(&truth).unwrap();
        let mut grad_api = vec![0.0f32; scan.volume_len()];
        let l_api =
            scan.loss_grad(Objective::LeastSquares, &b, &x, &mut grad_api).unwrap();
        let op = PlanOp::from_plan(scan.plan().clone());
        let mut grad_ops = vec![0.0f32; scan.volume_len()];
        let l_ops = ProjectionLoss::new(&op, &b, Objective::LeastSquares)
            .value_and_grad(&x, &mut grad_ops);
        assert_eq!(l_api, l_ops);
        assert_eq!(grad_api, grad_ops);
    }

    #[test]
    fn scan_is_a_linear_op() {
        let scan = builder().build().unwrap();
        let op: &dyn LinearOp = &scan;
        assert_eq!(op.domain_shape().numel(), scan.volume_len());
        let x = vec![0.5f32; scan.volume_len()];
        assert_eq!(op.apply(&x), scan.forward(&x).unwrap());
    }

    #[test]
    fn scan_pipeline_builds_and_fit_trains_and_validates() {
        use crate::tape::{FitCfg, Optimizer};
        let scan = builder().build().unwrap();
        // one unrolled GD step with a learnable step size, built through
        // the front door
        let (mut pb, a) = scan.pipeline();
        let b_in = pb.input(scan.range_shape()).unwrap();
        let truth_in = pb.input(scan.domain_shape()).unwrap();
        let x0 = pb.fill(scan.domain_shape(), 0.0).unwrap();
        let ax = pb.apply(a, x0).unwrap();
        let r = pb.sub(ax, b_in).unwrap();
        let g = pb.adjoint(a, r).unwrap();
        let s = pb.scalar_param("step", 0.01).unwrap();
        let sg = pb.scale(g, s).unwrap();
        let x1 = pb.sub(x0, sg).unwrap();
        pb.set_output(x1).unwrap();
        let l = pb.l2_loss(x1, truth_in).unwrap();
        pb.set_loss(l).unwrap();
        let mut pipe = pb.build().unwrap();

        let mut truth = vec![0.0f32; scan.volume_len()];
        crate::util::rng::Rng::new(13).fill_uniform(&mut truth, 0.1, 1.0);
        let y = scan.forward(&truth).unwrap();
        let report = scan
            .fit(
                &mut pipe,
                &[&y, &truth],
                &FitCfg { optimizer: Optimizer::adam(0.02), iterations: 20 },
            )
            .unwrap();
        assert!(
            report.final_loss < report.initial_loss,
            "training must reduce the loss: {} → {}",
            report.initial_loss,
            report.final_loss
        );

        // a pipeline built for a different scan is a typed error
        let other = ScanBuilder::new()
            .geometry(Geometry::Parallel(crate::geometry::ParallelBeam::standard_2d(
                6, 10, 1.0,
            )))
            .volume(VolumeGeometry::slice2d(8, 8, 1.0))
            .threads(1)
            .build()
            .unwrap();
        let e = other
            .fit(
                &mut pipe,
                &[&y, &truth],
                &FitCfg { optimizer: Optimizer::adam(0.02), iterations: 1 },
            )
            .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");

        // wrong input lengths surface as typed errors from fit, too
        let e = scan
            .fit(
                &mut pipe,
                &[&y],
                &FitCfg { optimizer: Optimizer::adam(0.02), iterations: 1 },
            )
            .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
    }

    #[test]
    fn same_scan_shares_one_cached_plan() {
        let a = builder().build().unwrap();
        let b = builder().build().unwrap();
        assert!(Arc::ptr_eq(a.plan(), b.plan()));
    }

    #[test]
    fn backend_knob_selects_and_validates() {
        // typed knob: both executable tiers build and report themselves
        for kind in [BackendKind::Scalar, BackendKind::Simd] {
            let scan = builder().backend(kind).build().unwrap();
            assert_eq!(scan.backend(), kind);
            assert_eq!(scan.plan().backend(), kind);
            assert!(scan.plan().matches(scan.projector()));
        }
        // string knob parses (trimmed, case-insensitive via parse)
        let scan = builder().backend_str(" simd ").build().unwrap();
        assert_eq!(scan.backend(), BackendKind::Simd);
        // typed beats string when both are set
        let scan = builder().backend_str("simd").backend(BackendKind::Scalar).build().unwrap();
        assert_eq!(scan.backend(), BackendKind::Scalar);
        // unknown names are a typed InvalidArgument, not a panic
        let e = builder().backend_str("warp").build().unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        // the registered-but-non-executing pjrt slot is a typed
        // Unsupported naming the backend
        let e = builder().backend(BackendKind::Pjrt).build().unwrap_err();
        match e {
            LeapError::Unsupported(m) => assert!(m.contains("pjrt"), "{m}"),
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let e = builder().backend_str("pjrt").build().unwrap_err();
        assert!(matches!(e, LeapError::Unsupported(_)), "{e:?}");
    }

    #[test]
    fn backends_share_nothing_but_agree_on_results() {
        let scalar = builder().backend(BackendKind::Scalar).build().unwrap();
        let simd = builder().backend(BackendKind::Simd).build().unwrap();
        // distinct plan-cache entries (the backend keys the cache)
        assert!(!Arc::ptr_eq(scalar.plan(), simd.plan()));
        let mut rng = crate::util::rng::Rng::new(17);
        let mut x = vec![0.0f32; scalar.volume_len()];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        // SF parallel is a staged path: bit-identical across tiers
        assert_eq!(scalar.forward(&x).unwrap(), simd.forward(&x).unwrap());
        let mut y = vec![0.0f32; scalar.sino_len()];
        rng.fill_uniform(&mut y, 0.0, 1.0);
        assert_eq!(scalar.back(&y).unwrap(), simd.back(&y).unwrap());
    }

    #[test]
    fn config_str_roundtrip() {
        let scan = ScanBuilder::from_config_str(
            r#"{"geometry": {"type": "parallel", "ncols": 8, "nviews": 6},
                "volume": {"nx": 8}}"#,
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(scan.sino_len(), 48);
        let again = ScanBuilder::from_config(&scan.config()).build().unwrap();
        assert_eq!(again.sino_len(), 48);
        assert!(ScanBuilder::from_config_str("not json").is_err());
    }
}
