//! The differentiable operator layer: `A` and `Aᵀ` as composable,
//! batched, gradient-ready [`LinearOp`] objects.
//!
//! The paper's headline claim is a *differentiable* forward/back
//! projector that "integrates seamlessly with existing deep learning
//! training and inference pipelines" — which requires more than a pair
//! of concrete methods on [`crate::projector::Projector`]: training
//! loops want `A` as a first-class linear operator they can scale, mask,
//! compose, square (`AᵀA`) and differentiate through, exactly the shape
//! TorchRadon (Ronchetti 2020) and CTorch (Jiang et al. 2025) converged
//! on. This module is the rust_pallas equivalent:
//!
//! * [`LinearOp`] — the operator interface over flat `f32` buffers:
//!   `domain_shape`/`range_shape`, `apply_into` (`y = A·x`),
//!   `adjoint_into` (`x = Aᵀ·y`), and batched
//!   [`LinearOp::apply_batch_into`] / [`LinearOp::adjoint_batch_into`]
//!   over `B` stacked inputs.
//! * [`PlanOp`] — the planned matched projector pair as a `LinearOp`.
//!   One [`crate::projector::ProjectionPlan`] is built (or taken from
//!   the plan cache) up front and shared by every application; the
//!   batched entry points dispatch **one** pool region across the whole
//!   stack, splitting the workers between items (outputs are
//!   bit-identical for every thread split, so batching never changes
//!   results). The plan snapshots its compute backend
//!   ([`crate::backend`]) at build time, so `apply`/`adjoint` — direct
//!   and batched — dispatch to the selected kernel tier with no code in
//!   this layer: an operator built from a SIMD-lowered plan *is* a SIMD
//!   operator, and every solver above inherits the tier for free. [`crate::sysmatrix::SystemMatrix`] implements the same
//!   trait, so every consumer — all five iterative solvers, the
//!   data-consistency pipeline, the serving coordinator — runs
//!   unchanged against the stored-matrix baseline.
//! * Combinators: [`Scaled`] (`α·A`), [`Composed`] (`A∘B`),
//!   [`RowMasked`] (per-view weights — limited-angle masks and
//!   ordered-subset selections), [`Normal`] (`AᵀA`), plus
//!   [`RampFilterOp`] (the FBP ramp-filter step as a self-adjoint
//!   operator, composable with a projector into a filtered
//!   backprojection).
//! * [`grad`] — the minimal reverse-mode layer:
//!   [`grad::ProjectionLoss`] evaluates `½‖Ax−b‖²` or the Poisson
//!   negative log-likelihood and returns the **exact** gradient through
//!   the matched adjoint (`Aᵀ(Ax−b)`, resp. `Aᵀ(1 − b/Ax)`). This is
//!   the paper's matched-pair requirement (§2.1) made operational: the
//!   backprojector enumerates exactly the transpose coefficients of the
//!   forward model, so these gradients are the true analytic gradients
//!   of the discretized objective — not an approximation — and remain
//!   stable over thousands of iterations. A finite-difference check in
//!   the test suite verifies both objectives against every operator.
//!
//! ## Shapes and layout
//!
//! Operators work on contiguous `f32` slices. [`Shape`] carries the
//! logical dimensions: volume-like domains are `[nx, ny, nz]` with the
//! [`crate::array::Vol3`] layout (`x` fastest), sinogram-like ranges are
//! `[nviews, nrows, ncols]` with the [`crate::array::Sino`] layout
//! (`col` fastest). Only `numel` matters to the algebra; structured
//! consumers (view masks, per-slice TV) interpret the dimensions.
//!
//! ## Memory
//!
//! `PlanOp` applications stage through one reusable volume + sinogram
//! scratch pair (allocated once per operator, reused under a lock), so
//! a solver's hot loop stays at one copy of each buffer; batched
//! applications hold one volume + one sinogram per in-flight item —
//! exactly the payload being computed, never a system matrix.

pub mod grad;

use std::sync::{Arc, Mutex};

use crate::array::{Sino, Vol3};
use crate::geometry::Geometry;
use crate::projector::{ProjectionPlan, Projector};
use crate::recon::filters::{filter_rows, ramp_response, Window};
use crate::util::pool::{self, ParWriter};

pub use grad::{Objective, ProjectionLoss};

/// Logical dimensions of an operator's domain or range (see the module
/// docs for the volume/sinogram conventions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shape(pub [usize; 3]);

impl Shape {
    /// Shape of a volume domain: `[nx, ny, nz]`.
    pub fn vol(vg: &crate::geometry::VolumeGeometry) -> Shape {
        Shape([vg.nx, vg.ny, vg.nz])
    }

    /// Shape of a sinogram range: `[nviews, nrows, ncols]`.
    pub fn sino(geom: &Geometry) -> Shape {
        Shape([geom.nviews(), geom.nrows(), geom.ncols()])
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0[0] * self.0[1] * self.0[2]
    }
}

/// A matched linear operator `A` with its exact adjoint `Aᵀ`, over flat
/// `f32` buffers. Implementations must keep the pair matched —
/// `⟨Ax, y⟩ = ⟨x, Aᵀy⟩` to floating-point accuracy — which the generic
/// adjoint sweep in `tests/ops_property.rs` verifies for every
/// implementation in the crate.
pub trait LinearOp: Send + Sync {
    /// Shape of `x` in `y = A·x`.
    fn domain_shape(&self) -> Shape;

    /// Shape of `y` in `y = A·x`.
    fn range_shape(&self) -> Shape;

    /// `y = A·x` (overwrites `y`).
    fn apply_into(&self, x: &[f32], y: &mut [f32]);

    /// `x = Aᵀ·y` (overwrites `x`).
    fn adjoint_into(&self, y: &[f32], x: &mut [f32]);

    /// `ys = A·xs` for `batch` stacked inputs: `xs` is `batch` domain
    /// buffers back to back, `ys` `batch` range buffers. The default
    /// applies the items sequentially; implementations with internal
    /// parallelism (notably [`PlanOp`]) override it to run the whole
    /// stack in one dispatch.
    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        let dn = self.domain_shape().numel();
        let rn = self.range_shape().numel();
        assert_eq!(xs.len(), batch * dn, "batched input length");
        assert_eq!(ys.len(), batch * rn, "batched output length");
        for (x, y) in xs.chunks_exact(dn).zip(ys.chunks_exact_mut(rn)) {
            self.apply_into(x, y);
        }
    }

    /// `xs = Aᵀ·ys` for `batch` stacked inputs (see
    /// [`Self::apply_batch_into`]).
    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        let dn = self.domain_shape().numel();
        let rn = self.range_shape().numel();
        assert_eq!(ys.len(), batch * rn, "batched input length");
        assert_eq!(xs.len(), batch * dn, "batched output length");
        for (y, x) in ys.chunks_exact(rn).zip(xs.chunks_exact_mut(dn)) {
            self.adjoint_into(y, x);
        }
    }

    /// `A·x`, allocating the output.
    fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.range_shape().numel()];
        self.apply_into(x, &mut y);
        y
    }

    /// `Aᵀ·y`, allocating the output.
    fn adjoint(&self, y: &[f32]) -> Vec<f32> {
        let mut x = vec![0.0f32; self.domain_shape().numel()];
        self.adjoint_into(y, &mut x);
        x
    }
}

// References and Arcs to operators are operators (so combinators can
// borrow instead of consuming, and shared plans stay shared).
impl<T: LinearOp + ?Sized> LinearOp for &T {
    fn domain_shape(&self) -> Shape {
        (**self).domain_shape()
    }
    fn range_shape(&self) -> Shape {
        (**self).range_shape()
    }
    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        (**self).apply_into(x, y)
    }
    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        (**self).adjoint_into(y, x)
    }
    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        (**self).apply_batch_into(batch, xs, ys)
    }
    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        (**self).adjoint_batch_into(batch, ys, xs)
    }
}

impl<T: LinearOp + ?Sized> LinearOp for Arc<T> {
    fn domain_shape(&self) -> Shape {
        (**self).domain_shape()
    }
    fn range_shape(&self) -> Shape {
        (**self).range_shape()
    }
    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        (**self).apply_into(x, y)
    }
    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        (**self).adjoint_into(y, x)
    }
    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        (**self).apply_batch_into(batch, xs, ys)
    }
    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        (**self).adjoint_batch_into(batch, ys, xs)
    }
}

// ---------------------------------------------------------------------------
// the planned projector pair as an operator
// ---------------------------------------------------------------------------

/// A [`ProjectionPlan`] is directly a [`LinearOp`]: `A` is the planned
/// forward projection, `Aᵀ` the matched backprojection. Per-application
/// buffers are allocated on the fly — [`PlanOp`] wraps the same plan
/// with reusable scratch for allocation-free solver loops.
impl LinearOp for ProjectionPlan {
    fn domain_shape(&self) -> Shape {
        Shape::vol(self.vg())
    }

    fn range_shape(&self) -> Shape {
        Shape::sino(self.geom())
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        let s = self.domain_shape().0;
        let vol = Vol3::from_vec(s[0], s[1], s[2], x.to_vec());
        let mut sino = self.new_sino();
        self.forward_into(&vol, &mut sino);
        y.copy_from_slice(&sino.data);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        let s = self.range_shape().0;
        let sino = Sino::from_vec(s[0], s[1], s[2], y.to_vec());
        let mut vol = self.new_vol();
        self.back_into(&sino, &mut vol);
        x.copy_from_slice(&vol.data);
    }

    /// One pool region across all `batch` stacked inputs: each item runs
    /// its forward projection with a `⌈threads/batch⌉` share of the
    /// workers. Outputs are bit-identical for every worker split (the
    /// slab/unit ownership fixes the accumulation order), so a batched
    /// apply returns exactly the floats of `batch` sequential applies —
    /// with one plan and one dispatch instead of `batch`.
    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        let dn = self.domain_shape().numel();
        let rn = self.range_shape().numel();
        assert_eq!(xs.len(), batch * dn, "batched input length");
        assert_eq!(ys.len(), batch * rn, "batched output length");
        if batch == 0 {
            return;
        }
        let d = self.domain_shape().0;
        let r = self.range_shape().0;
        let threads = self.threads().max(1);
        let inner = threads.div_ceil(batch);
        let out = ParWriter::new(ys);
        pool::parallel_items(batch, threads.min(batch), |b| {
            // each item owns its ys range exclusively
            let vol = Vol3::from_vec(d[0], d[1], d[2], xs[b * dn..(b + 1) * dn].to_vec());
            let mut sino = Sino::zeros(r[0], r[1], r[2]);
            self.forward_into_with_threads(&vol, &mut sino, inner);
            let base = b * rn;
            for (j, &v) in sino.data.iter().enumerate() {
                out.set(base + j, v);
            }
        });
    }

    /// Batched matched backprojection (see [`Self::apply_batch_into`]).
    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        let dn = self.domain_shape().numel();
        let rn = self.range_shape().numel();
        assert_eq!(ys.len(), batch * rn, "batched input length");
        assert_eq!(xs.len(), batch * dn, "batched output length");
        if batch == 0 {
            return;
        }
        let d = self.domain_shape().0;
        let r = self.range_shape().0;
        let threads = self.threads().max(1);
        let inner = threads.div_ceil(batch);
        let out = ParWriter::new(xs);
        pool::parallel_items(batch, threads.min(batch), |b| {
            let sino = Sino::from_vec(r[0], r[1], r[2], ys[b * rn..(b + 1) * rn].to_vec());
            let mut vol = Vol3::zeros(d[0], d[1], d[2]);
            self.back_into_with_threads(&sino, &mut vol, inner);
            let base = b * dn;
            for (j, &v) in vol.data.iter().enumerate() {
                out.set(base + j, v);
            }
        });
    }
}

/// The planned matched projector pair as a [`LinearOp`] with reusable
/// application scratch: `A` = forward projection, `Aᵀ` = the matched
/// backprojection, both through one shared [`ProjectionPlan`]. This is
/// the operator the iterative solvers and the serving coordinator run
/// on; clone the inner `Arc` freely to share the plan.
pub struct PlanOp {
    plan: Arc<ProjectionPlan>,
    /// One staging volume + sinogram pair reused across applications so
    /// solver hot loops allocate nothing (copies in/out are O(buffer),
    /// far below the projection work they stage).
    scratch: Mutex<(Vol3, Sino)>,
}

impl PlanOp {
    /// Plan `p`'s scan once and wrap it as an operator.
    pub fn new(p: &Projector) -> PlanOp {
        PlanOp::from_plan(Arc::new(p.plan()))
    }

    /// Wrap an existing (possibly cached/shared) plan as an operator.
    pub fn from_plan(plan: Arc<ProjectionPlan>) -> PlanOp {
        let scratch = Mutex::new((plan.new_vol(), plan.new_sino()));
        PlanOp { plan, scratch }
    }

    /// The shared plan (e.g. to build further operators on it).
    pub fn plan(&self) -> &Arc<ProjectionPlan> {
        &self.plan
    }
}

impl LinearOp for PlanOp {
    fn domain_shape(&self) -> Shape {
        Shape::vol(self.plan.vg())
    }

    fn range_shape(&self) -> Shape {
        Shape::sino(self.plan.geom())
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.domain_shape().numel(), "operator domain length");
        assert_eq!(y.len(), self.range_shape().numel(), "operator range length");
        let mut guard = self.scratch.lock().unwrap();
        let (vol, sino) = &mut *guard;
        vol.data.copy_from_slice(x);
        self.plan.forward_into(vol, sino);
        y.copy_from_slice(&sino.data);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        assert_eq!(y.len(), self.range_shape().numel(), "operator range length");
        assert_eq!(x.len(), self.domain_shape().numel(), "operator domain length");
        let mut guard = self.scratch.lock().unwrap();
        let (vol, sino) = &mut *guard;
        sino.data.copy_from_slice(y);
        self.plan.back_into(sino, vol);
        x.copy_from_slice(&vol.data);
    }

    fn apply_batch_into(&self, batch: usize, xs: &[f32], ys: &mut [f32]) {
        // the plan's batched path (one pool region over the stack)
        self.plan.apply_batch_into(batch, xs, ys)
    }

    fn adjoint_batch_into(&self, batch: usize, ys: &[f32], xs: &mut [f32]) {
        self.plan.adjoint_batch_into(batch, ys, xs)
    }
}

// ---------------------------------------------------------------------------
// the FBP ramp-filter step as an operator
// ---------------------------------------------------------------------------

/// The apodized ramp-filter step of FBP/FDK as a [`LinearOp`] on
/// sinograms (domain = range = `[nviews, nrows, ncols]`), composable
/// with a projector's adjoint into a filtered backprojection.
///
/// **Self-adjoint.** Each detector row is convolved with the apodized
/// ramp kernel: a multiplication by a *real, even* frequency response,
/// i.e. circular convolution (over the zero-padded FFT length) with a
/// real even kernel, truncated back to `ncols` samples. The resulting
/// `ncols × ncols` matrix `B[i][j] = g[(i−j) mod nfft]` is symmetric
/// (`g[m] = g[−m]`), so `Aᵀ = A` exactly in exact arithmetic and
/// `adjoint_into` simply reapplies the filter.
pub struct RampFilterOp {
    nviews: usize,
    nrows: usize,
    ncols: usize,
    resp: Vec<f64>,
}

impl RampFilterOp {
    /// Filter for sinograms of `nviews × nrows × ncols` samples at
    /// `pitch` mm detector-column spacing.
    pub fn new(nviews: usize, nrows: usize, ncols: usize, pitch: f64, window: Window) -> Self {
        RampFilterOp { nviews, nrows, ncols, resp: ramp_response(ncols, pitch, window) }
    }

    /// Filter matched to a scan geometry's detector grid.
    pub fn for_scan(geom: &Geometry, window: Window) -> Self {
        let du = match geom {
            Geometry::Parallel(g) => g.du,
            Geometry::Fan(g) => g.du,
            Geometry::Cone(g) => g.du,
            Geometry::Modular(g) => g.du,
        };
        RampFilterOp::new(geom.nviews(), geom.nrows(), geom.ncols(), du, window)
    }
}

impl LinearOp for RampFilterOp {
    fn domain_shape(&self) -> Shape {
        Shape([self.nviews, self.nrows, self.ncols])
    }

    fn range_shape(&self) -> Shape {
        Shape([self.nviews, self.nrows, self.ncols])
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.domain_shape().numel(), "operator domain length");
        assert_eq!(y.len(), x.len(), "operator range length");
        y.copy_from_slice(x);
        filter_rows(y, self.ncols, &self.resp);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        // self-adjoint: see the type docs
        self.apply_into(y, x)
    }
}

// ---------------------------------------------------------------------------
// combinators
// ---------------------------------------------------------------------------

/// `α·A`: the operator scaled by a constant. Adjoint is `α·Aᵀ`.
pub struct Scaled<O: LinearOp> {
    op: O,
    alpha: f32,
}

impl<O: LinearOp> Scaled<O> {
    pub fn new(op: O, alpha: f32) -> Scaled<O> {
        Scaled { op, alpha }
    }
}

impl<O: LinearOp> LinearOp for Scaled<O> {
    fn domain_shape(&self) -> Shape {
        self.op.domain_shape()
    }

    fn range_shape(&self) -> Shape {
        self.op.range_shape()
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        self.op.apply_into(x, y);
        for v in y.iter_mut() {
            *v *= self.alpha;
        }
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        self.op.adjoint_into(y, x);
        for v in x.iter_mut() {
            *v *= self.alpha;
        }
    }
}

/// `A∘B`: apply `inner` (`B`) then `outer` (`A`). Adjoint is `Bᵀ∘Aᵀ`.
/// One intermediate buffer of `inner.range` size is allocated per
/// application.
pub struct Composed<A: LinearOp, B: LinearOp> {
    outer: A,
    inner: B,
}

impl<A: LinearOp, B: LinearOp> Composed<A, B> {
    /// Panics unless `outer.domain` and `inner.range` have the same
    /// element count.
    pub fn new(outer: A, inner: B) -> Composed<A, B> {
        assert_eq!(
            outer.domain_shape().numel(),
            inner.range_shape().numel(),
            "composed operators must chain: outer domain == inner range"
        );
        Composed { outer, inner }
    }
}

impl<A: LinearOp, B: LinearOp> LinearOp for Composed<A, B> {
    fn domain_shape(&self) -> Shape {
        self.inner.domain_shape()
    }

    fn range_shape(&self) -> Shape {
        self.outer.range_shape()
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        let mut mid = vec![0.0f32; self.inner.range_shape().numel()];
        self.inner.apply_into(x, &mut mid);
        self.outer.apply_into(&mid, y);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        let mut mid = vec![0.0f32; self.outer.domain_shape().numel()];
        self.outer.adjoint_into(y, &mut mid);
        self.inner.adjoint_into(&mid, x);
    }
}

/// Scale each view block (`per_view` consecutive samples) of a flat
/// range buffer by its weight, skipping identity weights — the single
/// definition of per-view masking, shared by [`RowMasked`] and the
/// solvers' `view_mask` option
/// ([`crate::recon::sirt::apply_view_mask_flat`]), so the operator layer
/// and the solvers can never diverge on masking semantics.
pub fn scale_view_blocks(data: &mut [f32], weights: &[f32], per_view: usize) {
    for (view, &w) in weights.iter().enumerate() {
        if w == 1.0 {
            continue;
        }
        for v in &mut data[view * per_view..(view + 1) * per_view] {
            *v *= w;
        }
    }
}

/// `M·A` with `M` a diagonal per-view weighting of the range: the
/// limited-angle / ordered-subsets operator. Views with weight 1 pass
/// through untouched, 0 removes them; the adjoint weights the sinogram
/// before backprojecting, so masked views contribute nothing to `Aᵀ` —
/// exactly the masked residual both the paper's data-consistency
/// refinement and OS-SART's subset sweeps need.
pub struct RowMasked<O: LinearOp> {
    op: O,
    weights: Vec<f32>,
}

impl<O: LinearOp> RowMasked<O> {
    /// `weights` must have one entry per view (the leading range
    /// dimension).
    pub fn new(op: O, weights: Vec<f32>) -> RowMasked<O> {
        assert_eq!(weights.len(), op.range_shape().0[0], "one weight per view");
        RowMasked { op, weights }
    }

    fn per_view(&self) -> usize {
        let r = self.op.range_shape().0;
        r[1] * r[2]
    }
}

impl<O: LinearOp> LinearOp for RowMasked<O> {
    fn domain_shape(&self) -> Shape {
        self.op.domain_shape()
    }

    fn range_shape(&self) -> Shape {
        self.op.range_shape()
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        self.op.apply_into(x, y);
        scale_view_blocks(y, &self.weights, self.per_view());
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        let mut masked = y.to_vec();
        scale_view_blocks(&mut masked, &self.weights, self.per_view());
        self.op.adjoint_into(&masked, x);
    }
}

/// `AᵀA`: the normal operator — symmetric, so it is its own adjoint.
/// This is the operator CGLS iterates on and power iteration bounds;
/// having it first-class lets generic Krylov/eigen code run against any
/// matched pair.
pub struct Normal<O: LinearOp> {
    op: O,
}

impl<O: LinearOp> Normal<O> {
    pub fn new(op: O) -> Normal<O> {
        Normal { op }
    }
}

impl<O: LinearOp> LinearOp for Normal<O> {
    fn domain_shape(&self) -> Shape {
        self.op.domain_shape()
    }

    fn range_shape(&self) -> Shape {
        self.op.domain_shape()
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        let mut mid = vec![0.0f32; self.op.range_shape().numel()];
        self.op.apply_into(x, &mut mid);
        self.op.adjoint_into(&mid, y);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        // (AᵀA)ᵀ = AᵀA
        self.apply_into(y, x)
    }
}

/// The planned projector pair applied as `S` *sequential shards* — each
/// shard one contiguous range of the plan's shard units (views for `A`,
/// output-ownership units for `Aᵀ`;
/// [`ProjectionPlan::forward_shard_units`] /
/// [`ProjectionPlan::back_shard_units`]) dispatched as its own pool
/// region.
///
/// Outputs are **bit-identical** to the unsharded plan: forwards stitch
/// disjoint view slabs, and each backprojection shard replays every view
/// for the voxels it owns in the same global order the full executor
/// uses — the same decomposition [`RowMasked`] and the OS-SART subset
/// sweeps already rely on, restricted to contiguous ranges so no
/// reduction step is needed. Sharding therefore never changes results;
/// what it changes is *scheduling*: one monolithic application holds the
/// worker pool's FIFO region queue for its whole duration, while `S`
/// shards yield the queue `S − 1` times, letting a multiplexed serving
/// plane interleave other requests between shards and cutting tail
/// latency under concurrency (see `coordinator`).
///
/// [`crate::cluster::ShardedOp`] is this operator's multi-process
/// sibling: the same unit decomposition and range kernels, but shards
/// scatter to worker *processes* over the shard channel (back shards
/// tree-reduced in a fixed order) instead of running sequentially in
/// one address space.
pub struct ViewSharded {
    plan: Arc<ProjectionPlan>,
    shards: usize,
}

impl ViewSharded {
    /// Shard `plan`'s applications into (at most) `shards` sequential
    /// pool regions. `shards = 1` is exactly the unsharded operator.
    pub fn new(plan: Arc<ProjectionPlan>, shards: usize) -> ViewSharded {
        ViewSharded { plan, shards: shards.max(1) }
    }

    /// The shared plan.
    pub fn plan(&self) -> &Arc<ProjectionPlan> {
        &self.plan
    }

    /// Effective shard count for an application with `units` total shard
    /// units: capped so every shard keeps at least two units (below
    /// that, region-dispatch overhead outweighs any interleaving win).
    fn shards_for(&self, units: usize) -> usize {
        self.shards.min(units / 2).max(1)
    }
}

impl LinearOp for ViewSharded {
    fn domain_shape(&self) -> Shape {
        Shape::vol(self.plan.vg())
    }

    fn range_shape(&self) -> Shape {
        Shape::sino(self.plan.geom())
    }

    fn apply_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.domain_shape().numel(), "operator domain length");
        assert_eq!(y.len(), self.range_shape().numel(), "operator range length");
        let d = self.domain_shape().0;
        let r = self.range_shape().0;
        let vol = Vol3::from_vec(d[0], d[1], d[2], x.to_vec());
        let mut sino = Sino::zeros(r[0], r[1], r[2]);
        let units = self.plan.forward_shard_units();
        let threads = self.plan.threads().max(1);
        for (v0, v1) in pool::chunk_ranges(units, self.shards_for(units)) {
            self.plan.forward_range_into_with_threads(&vol, &mut sino, threads, v0, v1);
        }
        y.copy_from_slice(&sino.data);
    }

    fn adjoint_into(&self, y: &[f32], x: &mut [f32]) {
        assert_eq!(y.len(), self.range_shape().numel(), "operator range length");
        assert_eq!(x.len(), self.domain_shape().numel(), "operator domain length");
        let d = self.domain_shape().0;
        let r = self.range_shape().0;
        let sino = Sino::from_vec(r[0], r[1], r[2], y.to_vec());
        let mut vol = Vol3::zeros(d[0], d[1], d[2]);
        let units = self.plan.back_shard_units();
        let threads = self.plan.threads().max(1);
        for (u0, u1) in pool::chunk_ranges(units, self.shards_for(units)) {
            self.plan.back_range_into_with_threads(&sino, &mut vol, threads, u0, u1);
        }
        x.copy_from_slice(&vol.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::Model;
    use crate::util::{dot_f64, rng::Rng};

    fn plan_op() -> PlanOp {
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 18, 1.0));
        PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(2))
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_uniform(&mut v, -1.0, 1.0);
        v
    }

    #[test]
    fn plan_op_matches_projector() {
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 18, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        let op = PlanOp::new(&p);
        let x = rand_vec(op.domain_shape().numel(), 3);
        let vol = Vol3::from_vec(12, 12, 1, x.clone());
        assert_eq!(op.apply(&x), p.forward(&vol).data);
        let y = rand_vec(op.range_shape().numel(), 4);
        let sino = Sino::from_vec(8, 1, 18, y.clone());
        assert_eq!(op.adjoint(&y), p.back(&sino).data);
    }

    #[test]
    fn batched_apply_is_bit_identical_to_sequential() {
        let op = plan_op();
        let dn = op.domain_shape().numel();
        let rn = op.range_shape().numel();
        let batch = 3;
        let xs = rand_vec(batch * dn, 7);
        let mut batched = vec![0.0f32; batch * rn];
        op.apply_batch_into(batch, &xs, &mut batched);
        for b in 0..batch {
            let single = op.apply(&xs[b * dn..(b + 1) * dn]);
            assert_eq!(batched[b * rn..(b + 1) * rn], single[..], "item {b}");
        }
        let ys = rand_vec(batch * rn, 8);
        let mut backs = vec![0.0f32; batch * dn];
        op.adjoint_batch_into(batch, &ys, &mut backs);
        for b in 0..batch {
            let single = op.adjoint(&ys[b * rn..(b + 1) * rn]);
            assert_eq!(backs[b * dn..(b + 1) * dn], single[..], "item {b}");
        }
    }

    #[test]
    fn scaled_and_masked_and_normal_shapes() {
        let op = plan_op();
        let s = Scaled::new(&op, 2.0);
        assert_eq!(s.domain_shape(), op.domain_shape());
        let x = rand_vec(op.domain_shape().numel(), 5);
        let ax = op.apply(&x);
        let sx = s.apply(&x);
        for i in 0..ax.len() {
            assert_eq!(sx[i], 2.0 * ax[i]);
        }

        let nviews = op.range_shape().0[0];
        let mask: Vec<f32> = (0..nviews).map(|v| if v % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let m = RowMasked::new(&op, mask);
        let mx = m.apply(&x);
        let per = op.range_shape().0[1] * op.range_shape().0[2];
        for view in 0..nviews {
            for i in view * per..(view + 1) * per {
                if view % 2 == 0 {
                    assert_eq!(mx[i], ax[i]);
                } else {
                    assert_eq!(mx[i], 0.0);
                }
            }
        }

        let n = Normal::new(&op);
        assert_eq!(n.range_shape(), op.domain_shape());
        let nx = n.apply(&x);
        assert_eq!(nx, op.adjoint(&ax));
    }

    #[test]
    fn composed_chains_and_checks_shapes() {
        let op = plan_op();
        let geom = Geometry::Parallel(ParallelBeam::standard_2d(8, 18, 1.0));
        let filt = RampFilterOp::for_scan(&geom, Window::Hann);
        let fa = Composed::new(&filt, &op); // filter ∘ project
        assert_eq!(fa.domain_shape(), op.domain_shape());
        assert_eq!(fa.range_shape(), filt.range_shape());
        let x = rand_vec(op.domain_shape().numel(), 6);
        assert_eq!(fa.apply(&x), filt.apply(&op.apply(&x)));
    }

    #[test]
    #[should_panic(expected = "must chain")]
    fn composed_rejects_shape_mismatch() {
        let op = plan_op(); // domain 12×12×1
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(6, 15, 1.0));
        let other = PlanOp::new(&Projector::new(g, vg, Model::SF)); // range 6×1×15
        let _ = Composed::new(&op, &other); // 144 ≠ 90: must panic
    }

    #[test]
    fn view_sharded_is_bit_identical_to_unsharded_for_all_models_and_geometries() {
        use crate::geometry::{ConeBeam, FanBeam, ModularBeam};
        let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let geoms = vec![
            Geometry::Parallel(crate::geometry::ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2)),
            Geometry::Fan(FanBeam::standard(5, 14, 1.3, 50.0, 100.0)),
            Geometry::Cone(cone.clone()),
            Geometry::Modular(ModularBeam::from_cone(&cone)),
        ];
        for geom in geoms {
            let vg = if matches!(geom, Geometry::Fan(_)) {
                VolumeGeometry::slice2d(9, 9, 1.0)
            } else {
                VolumeGeometry::cube(8, 1.0)
            };
            for model in [Model::Siddon, Model::Joseph, Model::SF] {
                let plan = Arc::new(
                    Projector::new(geom.clone(), vg.clone(), model).with_threads(3).plan(),
                );
                let x = rand_vec(Shape::vol(plan.vg()).numel(), 21);
                let y = rand_vec(Shape::sino(plan.geom()).numel(), 22);
                let full_fwd = plan.as_ref().apply(&x);
                let full_back = plan.as_ref().adjoint(&y);
                for shards in [1usize, 2, 3, 5] {
                    let op = ViewSharded::new(plan.clone(), shards);
                    assert_eq!(
                        op.apply(&x),
                        full_fwd,
                        "forward {model:?}/{geom:?} at {shards} shards"
                    );
                    assert_eq!(
                        op.adjoint(&y),
                        full_back,
                        "back {model:?}/{geom:?} at {shards} shards"
                    );
                }
            }
        }
    }

    #[test]
    fn ramp_filter_is_self_adjoint() {
        let geom = Geometry::Parallel(ParallelBeam::standard_2d(6, 32, 1.0));
        let f = RampFilterOp::for_scan(&geom, Window::RamLak);
        let n = f.domain_shape().numel();
        let x = rand_vec(n, 11);
        let y = rand_vec(n, 12);
        let lhs = dot_f64(&f.apply(&x), &y);
        let rhs = dot_f64(&x, &f.apply(&y));
        let gap = (lhs - rhs).abs() / lhs.abs().max(rhs.abs()).max(1e-12);
        assert!(gap < 1e-5, "ramp filter adjoint gap {gap}");
    }
}
