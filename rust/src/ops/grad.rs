//! Reverse-mode gradients through the matched operator pair.
//!
//! The paper's differentiability claim rests on the matched adjoint:
//! because the backprojector enumerates exactly the transpose
//! coefficients of the forward model, `Aᵀ` *is* the reverse-mode
//! derivative of `x ↦ A·x`, and data-fit objectives get their exact
//! analytic gradients from one forward + one back projection — no
//! autodiff tape, no unmatched-operator drift over thousands of
//! iterations (§2.1). [`ProjectionLoss`] packages the two objectives CT
//! pipelines actually train with:
//!
//! * [`Objective::LeastSquares`] — `L(x) = ½‖Ax − b‖²`, gradient
//!   `∇L = Aᵀ(Ax − b)`; the data-consistency term of §3–4.
//! * [`Objective::PoissonNll`] — `L(x) = Σᵢ (Ax)ᵢ − bᵢ·ln (Ax)ᵢ`
//!   (the Poisson negative log-likelihood up to a constant), gradient
//!   `∇L = Aᵀ(1 − b/Ax)`; the statistically-weighted model MLEM's
//!   fixed point optimizes.
//!
//! Both are verified against central finite differences for every
//! [`LinearOp`] implementation in `tests/ops_property.rs`.

use super::LinearOp;

/// Clamp on `Ax` inside the Poisson terms — matches the MLEM solver's
/// ratio clamp so loss and solver agree on the singular set.
pub const POISSON_EPS: f32 = 1e-9;

/// Turn predictions `ax` into the least-squares residual `ax − b` in
/// place and return `½‖ax − b‖²` (f64 accumulation). This is the single
/// definition of the L2 data-fit term, shared by [`ProjectionLoss`] and
/// the tape's L2 loss node ([`crate::tape`]) so the two layers can never
/// disagree on the objective.
pub fn l2_residual_in_place(ax: &mut [f32], b: &[f32]) -> f64 {
    debug_assert_eq!(ax.len(), b.len());
    let mut loss = 0.0f64;
    for (a, &b) in ax.iter_mut().zip(b.iter()) {
        let r = *a - b;
        loss += 0.5 * (r as f64) * (r as f64);
        *a = r;
    }
    loss
}

/// Turn predictions `ax` into the Poisson NLL residual `1 − b/max(ax,ε)`
/// in place and return `Σ max(ax,ε) − b·ln max(ax,ε)` (f64
/// accumulation). Shared by [`ProjectionLoss`] and the tape's Poisson
/// loss node, with the same [`POISSON_EPS`] clamp MLEM uses.
pub fn poisson_residual_in_place(ax: &mut [f32], b: &[f32]) -> f64 {
    debug_assert_eq!(ax.len(), b.len());
    let mut loss = 0.0f64;
    for (a, &b) in ax.iter_mut().zip(b.iter()) {
        let m = a.max(POISSON_EPS);
        loss += m as f64 - (b as f64) * (m as f64).ln();
        *a = 1.0 - b / m;
    }
    loss
}

/// Which data-fit objective [`ProjectionLoss`] evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// `½‖Ax − b‖²`.
    LeastSquares,
    /// `Σᵢ (Ax)ᵢ − bᵢ·ln (Ax)ᵢ` (Poisson NLL up to a constant;
    /// requires `b ≥ 0`).
    PoissonNll,
}

/// A data-fit loss `L(x)` on projections `b`, differentiable through
/// the matched adjoint of any [`LinearOp`].
pub struct ProjectionLoss<'a> {
    op: &'a dyn LinearOp,
    data: &'a [f32],
    objective: Objective,
}

impl<'a> ProjectionLoss<'a> {
    /// Loss against measured projections `data` (length must equal the
    /// operator's range).
    pub fn new(op: &'a dyn LinearOp, data: &'a [f32], objective: Objective) -> ProjectionLoss<'a> {
        assert_eq!(data.len(), op.range_shape().numel(), "data length must match operator range");
        ProjectionLoss { op, data, objective }
    }

    /// Evaluate `L(x)` and write the exact gradient into `grad`
    /// (length = operator domain). One forward and one matched back
    /// projection.
    pub fn value_and_grad(&self, x: &[f32], grad: &mut [f32]) -> f64 {
        assert_eq!(grad.len(), self.op.domain_shape().numel(), "gradient length");
        let mut ax = vec![0.0f32; self.data.len()];
        self.op.apply_into(x, &mut ax);
        let loss = self.residual_in_place(&mut ax);
        self.op.adjoint_into(&ax, grad);
        loss
    }

    /// Evaluate `L(x)` only (one forward projection).
    pub fn value(&self, x: &[f32]) -> f64 {
        let mut ax = vec![0.0f32; self.data.len()];
        self.op.apply_into(x, &mut ax);
        self.residual_in_place(&mut ax)
    }

    /// Turn `Ax` into the range-space residual `∂L/∂(Ax)` in place and
    /// return the loss value.
    fn residual_in_place(&self, ax: &mut [f32]) -> f64 {
        match self.objective {
            Objective::LeastSquares => l2_residual_in_place(ax, self.data),
            Objective::PoissonNll => poisson_residual_in_place(ax, self.data),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{PlanOp, RowMasked};
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::{Model, Projector};
    use crate::util::rng::Rng;

    fn setup() -> (PlanOp, Vec<f32>, Vec<f32>) {
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(7, 14, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        let op = PlanOp::new(&p);
        let mut rng = Rng::new(5);
        let mut x = vec![0.0f32; 100];
        rng.fill_uniform(&mut x, 0.2, 1.0); // positive: Poisson-safe
        let truth = {
            let mut t = vec![0.0f32; 100];
            rng.fill_uniform(&mut t, 0.2, 1.0);
            t
        };
        let b = op.apply(&truth);
        (op, x, b)
    }

    /// Directional finite-difference check: `⟨∇L, d⟩` vs the central
    /// difference of `L` along a random direction `d`.
    fn fd_gap(loss: &ProjectionLoss, x: &[f32], seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut d = vec![0.0f32; x.len()];
        rng.fill_uniform(&mut d, -1.0, 1.0);
        let mut grad = vec![0.0f32; x.len()];
        loss.value_and_grad(x, &mut grad);
        let analytic: f64 = grad.iter().zip(d.iter()).map(|(&g, &v)| g as f64 * v as f64).sum();
        let h = 1e-3f32;
        let xp: Vec<f32> = x.iter().zip(d.iter()).map(|(&a, &v)| a + h * v).collect();
        let xm: Vec<f32> = x.iter().zip(d.iter()).map(|(&a, &v)| a - h * v).collect();
        let fd = (loss.value(&xp) - loss.value(&xm)) / (2.0 * h as f64);
        (analytic - fd).abs() / analytic.abs().max(fd.abs()).max(1e-9)
    }

    #[test]
    fn least_squares_gradient_matches_finite_differences() {
        let (op, x, b) = setup();
        let loss = ProjectionLoss::new(&op, &b, Objective::LeastSquares);
        let gap = fd_gap(&loss, &x, 21);
        assert!(gap < 1e-2, "L2 fd gap {gap}");
    }

    #[test]
    fn poisson_gradient_matches_finite_differences() {
        let (op, x, b) = setup();
        let loss = ProjectionLoss::new(&op, &b, Objective::PoissonNll);
        let gap = fd_gap(&loss, &x, 22);
        assert!(gap < 1e-2, "Poisson fd gap {gap}");
    }

    #[test]
    fn masked_loss_gradient_ignores_masked_views() {
        // the gradient flows through Aᵀ·Mᵀ, so data in masked-out views
        // cannot move the reconstruction (the loss value still sees the
        // raw residual there — callers pass masked data, like sirt does)
        let (op, x, b) = setup();
        let nviews = op.range_shape().0[0];
        let per = op.range_shape().numel() / nviews;
        let mask: Vec<f32> = (0..nviews).map(|v| if v < 3 { 1.0 } else { 0.0 }).collect();
        let masked = RowMasked::new(&op, mask);
        let mut grad_a = vec![0.0f32; x.len()];
        ProjectionLoss::new(&masked, &b, Objective::LeastSquares).value_and_grad(&x, &mut grad_a);
        // corrupt the masked-out views wildly: gradient unchanged
        let mut b_bad = b.clone();
        for v in &mut b_bad[3 * per..] {
            *v = 1e6;
        }
        let mut grad_b = vec![0.0f32; x.len()];
        ProjectionLoss::new(&masked, &b_bad, Objective::LeastSquares)
            .value_and_grad(&x, &mut grad_b);
        assert_eq!(grad_a, grad_b);
    }

    #[test]
    fn zero_residual_means_zero_gradient() {
        let (op, _x, _b) = setup();
        let mut truth = vec![0.0f32; 100];
        Rng::new(9).fill_uniform(&mut truth, 0.2, 1.0);
        let b = op.apply(&truth);
        let loss = ProjectionLoss::new(&op, &b, Objective::LeastSquares);
        let mut grad = vec![1.0f32; 100];
        let l = loss.value_and_grad(&truth, &mut grad);
        assert!(l < 1e-9, "loss at the truth {l}");
        assert!(grad.iter().all(|&g| g.abs() < 1e-6));
    }
}
