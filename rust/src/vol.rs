//! Out-of-core volumes: slab-granular tiles under a residency budget.
//!
//! The paper's memory claim — one copy of the volume plus one copy of
//! the projections — still assumes the volume itself fits in RAM. A
//! [`TiledVol3`] removes that assumption: the volume lives as
//! **slab-granular tiles** on a file-backed store (an unlinked temp
//! file; a plain in-memory store when no scratch file can be created),
//! and at most `residency budget` bytes of tiles are held resident at
//! once under LRU eviction.
//!
//! **Execution model.** Tiles are aligned to the projector plan's
//! output-ownership units ([`ProjectionPlan::back_shard_units`]): a tile
//! is a contiguous unit range `u0..u1`, and its buffer is exactly the
//! *window* the plan's windowed kernels operate on
//! (`window_planes() · (u1 − u0) · nx` floats — see
//! `ProjectionPlan::window_runs` for the copy map to the resident
//! layout). Backprojection visits each tile once and runs the slab-owned
//! gather kernels with write indices rebased into the window — index
//! arithmetic only, so every float matches resident execution bit for
//! bit. Forward projection zeroes the sinogram once and replays tiles in
//! ascending unit order, each **accumulating** into the sinogram; per
//! detector bin that appends contributions in exactly the per-bin `+=`
//! order of the resident kernels, so tiled forward output is also
//! bit-identical (asserted by the property tests below at every budget,
//! including budgets that force repeated evictions).
//!
//! Windowed execution covers the scalar-backend SF plans (parallel, fan,
//! cone — cached or uncached); ray models and the SIMD tier are rejected
//! with a typed [`LeapError::Unsupported`] and should execute resident.
//!
//! The serving layer's `__stats` exposes the process-wide
//! [`resident_tile_bytes`] gauge so out-of-core memory behavior is
//! observable next to the plan-cache and admission-control numbers.

use std::collections::VecDeque;
#[cfg(unix)]
use std::fs::File;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::api::LeapError;
use crate::array::{Sino, Vol3};
use crate::projector::ProjectionPlan;

/// Process-wide gauge: bytes of [`TiledVol3`] tiles currently resident
/// across all live instances (reported by the server's `__stats`).
static RESIDENT_TILE_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Bytes of tile buffers currently resident process-wide.
pub fn resident_tile_bytes() -> usize {
    RESIDENT_TILE_BYTES.load(Ordering::Relaxed)
}

/// Monotonic id source for scratch-file names (pid keeps names unique
/// across processes sharing a temp dir, the counter across instances).
static SCRATCH_ID: AtomicUsize = AtomicUsize::new(0);

/// Tile backing store: an unlinked scratch file (bytes live only as long
/// as the handle), or plain heap vectors when no file can be created
/// (read-only temp dirs, exotic platforms).
enum Store {
    #[cfg(unix)]
    File(File),
    Mem(Vec<Vec<f32>>),
}

#[cfg(unix)]
fn open_scratch_file() -> Option<File> {
    let id = SCRATCH_ID.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("leap-tiles-{}-{}.bin", std::process::id(), id));
    let file = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&path)
        .ok()?;
    // unlink immediately: the store lives exactly as long as the handle,
    // with nothing left behind on any exit path
    let _ = std::fs::remove_file(&path);
    Some(file)
}

#[cfg(unix)]
fn store_write(file: &File, offset: u64, data: &[f32]) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    let mut bytes = vec![0u8; data.len() * 4];
    for (i, v) in data.iter().enumerate() {
        bytes[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
    file.write_all_at(&bytes, offset)
}

#[cfg(unix)]
fn store_read(file: &File, offset: u64, out: &mut [f32]) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    let mut bytes = vec![0u8; out.len() * 4];
    file.read_exact_at(&mut bytes, offset)?;
    for (i, v) in out.iter_mut().enumerate() {
        *v = f32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
    }
    Ok(())
}

/// A volume stored as slab-granular tiles with bounded residency — the
/// out-of-core backing for volumes larger than RAM (module docs).
pub struct TiledVol3 {
    /// Total output-ownership units (`plan.back_shard_units()`).
    units: usize,
    /// Floats per unit (`window_planes · nx`).
    unit_len: usize,
    /// Units per tile (last tile may be short).
    units_per_tile: usize,
    ntiles: usize,
    budget_bytes: usize,
    store: Store,
    /// Resident tile buffers (window layout), `None` when evicted.
    resident: Vec<Option<Vec<f32>>>,
    /// Tiles whose resident buffer differs from the store.
    dirty: Vec<bool>,
    /// Tiles that have ever been flushed (a fault of an unflushed tile
    /// materializes zeros instead of reading the store).
    flushed: Vec<bool>,
    /// Resident tiles, least-recently-used first.
    lru: VecDeque<usize>,
    resident_bytes: usize,
    evictions: u64,
}

impl TiledVol3 {
    /// Build an all-zero tiled volume for `plan`'s scan under
    /// `budget_bytes` of tile residency. Tiles are sized so at least two
    /// fit inside the budget (down to single-unit tiles for tiny
    /// budgets). Typed errors: plans without windowed kernels (ray
    /// models, SIMD backend) are [`LeapError::Unsupported`]; a zero
    /// budget is [`LeapError::InvalidArgument`].
    pub fn for_plan(plan: &ProjectionPlan, budget_bytes: usize) -> Result<TiledVol3, LeapError> {
        if !plan.supports_windows() {
            return Err(LeapError::Unsupported(
                "tiled execution needs a scalar-backend SF plan \
                 (ray models and the simd tier execute resident)"
                    .into(),
            ));
        }
        if budget_bytes == 0 {
            return Err(LeapError::InvalidArgument(
                "tile residency budget must be non-zero".into(),
            ));
        }
        let units = plan.back_shard_units();
        let unit_len = plan.window_planes() * plan.vg().nx;
        let unit_bytes = unit_len * 4;
        // at least two tiles under budget (double residency lets a copy
        // loop touch two tiles without thrashing); clamp to one unit
        let units_per_tile = (budget_bytes / (2 * unit_bytes)).clamp(1, units.max(1));
        let ntiles = units.div_ceil(units_per_tile);
        #[cfg(unix)]
        let store = match open_scratch_file() {
            Some(f) => Store::File(f),
            None => Store::Mem(vec![Vec::new(); ntiles]),
        };
        #[cfg(not(unix))]
        let store = Store::Mem(vec![Vec::new(); ntiles]);
        Ok(TiledVol3 {
            units,
            unit_len,
            units_per_tile,
            ntiles,
            budget_bytes,
            store,
            resident: (0..ntiles).map(|_| None).collect(),
            dirty: vec![false; ntiles],
            flushed: vec![false; ntiles],
            lru: VecDeque::new(),
            resident_bytes: 0,
            evictions: 0,
        })
    }

    /// [`Self::for_plan`] initialized from a resident volume.
    pub fn from_vol3(
        plan: &ProjectionPlan,
        vol: &Vol3,
        budget_bytes: usize,
    ) -> Result<TiledVol3, LeapError> {
        let mut tv = TiledVol3::for_plan(plan, budget_bytes)?;
        if vol.len() != plan.vg().num_voxels() {
            return Err(LeapError::ShapeMismatch {
                what: "volume",
                expected: plan.vg().num_voxels(),
                got: vol.len(),
            });
        }
        for t in 0..tv.ntiles {
            let (u0, u1) = tv.tile_range(t);
            let runs = plan.window_runs(u0, u1);
            let nx = plan.vg().nx;
            let buf = tv.fault(t);
            for (g, w) in runs {
                buf[w..w + nx].copy_from_slice(&vol.data[g..g + nx]);
            }
            tv.dirty[t] = true;
        }
        Ok(tv)
    }

    /// Gather the tiles back into a resident volume (faults every tile).
    pub fn to_vol3(&mut self, plan: &ProjectionPlan) -> Vol3 {
        self.check_plan(plan);
        let vg = plan.vg();
        let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        for t in 0..self.ntiles {
            let (u0, u1) = self.tile_range(t);
            let runs = plan.window_runs(u0, u1);
            let nx = vg.nx;
            let buf = self.fault(t);
            for (g, w) in runs {
                vol.data[g..g + nx].copy_from_slice(&buf[w..w + nx]);
            }
        }
        vol
    }

    /// Forward projection `sino = A·vol` tile by tile (overwrites
    /// `sino`; bit-identical to resident execution — module docs).
    pub fn forward_into(&mut self, plan: &ProjectionPlan, sino: &mut Sino) {
        self.check_plan(plan);
        // (per-window calls assert the sinogram shape)
        sino.fill(0.0);
        // ascending unit order: per detector bin, contributions append in
        // the resident kernels' exact += order
        for t in 0..self.ntiles {
            let (u0, u1) = self.tile_range(t);
            let buf = self.fault(t);
            // split borrow: fault returns &mut into self.resident; the
            // plan call only reads the buffer
            let buf: &[f32] = buf;
            plan.forward_accum_window(buf, u0, u1, sino);
        }
    }

    /// Matched backprojection `vol = Aᵀ·sino` tile by tile (overwrites
    /// the tiled volume; bit-identical to resident execution).
    pub fn back_into(&mut self, plan: &ProjectionPlan, sino: &Sino) {
        self.check_plan(plan);
        for t in 0..self.ntiles {
            let (u0, u1) = self.tile_range(t);
            let buf = self.fault(t);
            plan.back_window_into(sino, buf, u0, u1);
            self.dirty[t] = true;
        }
    }

    /// Unit range `[u0, u1)` owned by tile `t`.
    pub fn tile_range(&self, t: usize) -> (usize, usize) {
        let u0 = t * self.units_per_tile;
        (u0, (u0 + self.units_per_tile).min(self.units))
    }

    pub fn ntiles(&self) -> usize {
        self.ntiles
    }

    pub fn units(&self) -> usize {
        self.units
    }

    /// Tile evictions since construction (each one wrote a dirty tile to
    /// the store or dropped a clean one).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Bytes of this volume's tiles currently resident.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    fn check_plan(&self, plan: &ProjectionPlan) {
        assert_eq!(
            (self.units, self.unit_len),
            (plan.back_shard_units(), plan.window_planes() * plan.vg().nx),
            "tiled volume was built for a different plan"
        );
    }

    fn tile_len(&self, t: usize) -> usize {
        let (u0, u1) = self.tile_range(t);
        (u1 - u0) * self.unit_len
    }

    /// Fault tile `t` resident (zeros if never flushed, else read from
    /// the store), refresh its LRU position, evict past the budget.
    fn fault(&mut self, t: usize) -> &mut Vec<f32> {
        if self.resident[t].is_none() {
            let len = self.tile_len(t);
            let mut buf = vec![0.0f32; len];
            if self.flushed[t] {
                match &mut self.store {
                    #[cfg(unix)]
                    Store::File(f) => {
                        let off = (t * self.units_per_tile * self.unit_len * 4) as u64;
                        store_read(f, off, &mut buf)
                            .expect("tile store read failed (scratch file truncated?)");
                    }
                    Store::Mem(tiles) => buf.copy_from_slice(&tiles[t]),
                }
            }
            self.resident[t] = Some(buf);
            self.resident_bytes += len * 4;
            RESIDENT_TILE_BYTES.fetch_add(len * 4, Ordering::Relaxed);
            self.lru.push_back(t);
            self.enforce_budget(t);
        } else {
            // refresh LRU position
            if let Some(pos) = self.lru.iter().position(|&x| x == t) {
                self.lru.remove(pos);
            }
            self.lru.push_back(t);
        }
        self.resident[t].as_mut().expect("tile just faulted resident")
    }

    /// Evict least-recently-used tiles (never `keep`) until the resident
    /// set fits the budget or only `keep` remains.
    fn enforce_budget(&mut self, keep: usize) {
        while self.resident_bytes > self.budget_bytes && self.lru.len() > 1 {
            let victim = if self.lru.front() == Some(&keep) {
                // keep the working tile: evict the next-oldest
                self.lru.remove(1)
            } else {
                self.lru.pop_front()
            };
            let Some(v) = victim else { break };
            self.evict(v);
        }
    }

    fn evict(&mut self, t: usize) {
        let Some(buf) = self.resident[t].take() else { return };
        let len_bytes = buf.len() * 4;
        if self.dirty[t] {
            match &mut self.store {
                #[cfg(unix)]
                Store::File(f) => {
                    let off = (t * self.units_per_tile * self.unit_len * 4) as u64;
                    store_write(f, off, &buf)
                        .expect("tile store write failed (scratch volume full?)");
                }
                Store::Mem(tiles) => tiles[t] = buf,
            }
            self.dirty[t] = false;
            self.flushed[t] = true;
        }
        self.resident_bytes -= len_bytes;
        RESIDENT_TILE_BYTES.fetch_sub(len_bytes, Ordering::Relaxed);
        self.evictions += 1;
    }
}

impl Drop for TiledVol3 {
    fn drop(&mut self) {
        RESIDENT_TILE_BYTES.fetch_sub(self.resident_bytes, Ordering::Relaxed);
    }
}

/// One-shot tiled forward projection: stage `vol` through a
/// [`TiledVol3`] under `budget_bytes` and run `sino = A·vol` tile by
/// tile. Returns the eviction count (≥ how hard the budget squeezed).
/// Bit-identical to `plan.forward_into(vol, sino)` for supported plans;
/// unsupported plans are a typed error.
pub fn tiled_forward_into(
    plan: &ProjectionPlan,
    vol: &Vol3,
    sino: &mut Sino,
    budget_bytes: usize,
) -> Result<u64, LeapError> {
    let mut tv = TiledVol3::from_vol3(plan, vol, budget_bytes)?;
    tv.forward_into(plan, sino);
    Ok(tv.evictions())
}

/// One-shot tiled backprojection: run `vol = Aᵀ·sino` tile by tile under
/// `budget_bytes`, gathering the tiles into the returned resident
/// volume. Also returns the eviction count. Bit-identical to
/// `plan.back_into(sino, vol)` for supported plans.
pub fn tiled_back_into(
    plan: &ProjectionPlan,
    sino: &Sino,
    vol: &mut Vol3,
    budget_bytes: usize,
) -> Result<u64, LeapError> {
    let mut tv = TiledVol3::for_plan(plan, budget_bytes)?;
    tv.back_into(plan, sino);
    *vol = tv.to_vol3(plan);
    Ok(tv.evictions())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::geometry::{ConeBeam, FanBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::{Model, Projector};
    use crate::util::rng::Rng;

    /// Tests that create `TiledVol3`s serialize on this lock: the
    /// process-wide residency gauge is shared, so concurrent instances
    /// would make its assertions racy.
    fn gauge_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn sf_cases() -> Vec<(Geometry, VolumeGeometry)> {
        let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let mut curved = cone.clone();
        curved.shape = crate::geometry::DetectorShape::Curved;
        vec![
            (
                Geometry::Parallel(ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2)),
                VolumeGeometry::cube(8, 1.0),
            ),
            (
                Geometry::Fan(FanBeam::standard(5, 14, 1.3, 50.0, 100.0)),
                VolumeGeometry::slice2d(9, 9, 1.0),
            ),
            (Geometry::Cone(cone), VolumeGeometry::cube(8, 1.0)),
            (Geometry::Cone(curved), VolumeGeometry::cube(8, 1.0)),
        ]
    }

    #[test]
    fn tiled_matches_resident_bit_for_bit_at_eviction_forcing_budgets() {
        let _g = gauge_lock();
        let mut rng = Rng::new(23);
        for (geom, vg) in sf_cases() {
            let p = Projector::new(geom, vg, Model::SF)
                .with_threads(3)
                .with_backend(BackendKind::Scalar);
            let plan = p.plan();
            let mut x = p.new_vol();
            let mut y = p.new_sino();
            rng.fill_uniform(&mut x.data, 0.0, 1.0);
            rng.fill_uniform(&mut y.data, 0.0, 1.0);
            let fwd_ref = plan.forward(&x);
            let back_ref = plan.back(&y);
            let unit_bytes = plan.window_planes() * plan.vg().nx * 4;
            // budgets from "one unit per tile, one tile resident" up to
            // "everything resident": every one must reproduce the
            // resident floats exactly, and the small ones must evict
            for (budget, must_evict) in [
                (unit_bytes, true),                     // single-unit tiles
                (3 * unit_bytes, true),                 // small tiles
                (plan.back_shard_units() * unit_bytes * 4, false), // all fit
            ] {
                let mut fwd = plan.new_sino();
                let ev_f = tiled_forward_into(&plan, &x, &mut fwd, budget).unwrap();
                assert_eq!(
                    fwd_ref.data, fwd.data,
                    "{} forward, budget {budget}",
                    p.geom.kind()
                );
                let mut back = plan.new_vol();
                let ev_b = tiled_back_into(&plan, &y, &mut back, budget).unwrap();
                assert_eq!(
                    back_ref.data, back.data,
                    "{} back, budget {budget}",
                    p.geom.kind()
                );
                if must_evict {
                    assert!(
                        ev_f >= 2 && ev_b >= 2,
                        "{} budget {budget}: expected ≥2 evictions (got fwd {ev_f}, back {ev_b})",
                        p.geom.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn roundtrip_through_tiles_is_lossless() {
        let _g = gauge_lock();
        let (geom, vg) = sf_cases().remove(2); // cone: nz planes per unit
        let p = Projector::new(geom, vg, Model::SF).with_backend(BackendKind::Scalar);
        let plan = p.plan();
        let mut x = p.new_vol();
        Rng::new(5).fill_uniform(&mut x.data, -1.0, 1.0);
        let unit_bytes = plan.window_planes() * plan.vg().nx * 4;
        let mut tv = TiledVol3::from_vol3(&plan, &x, unit_bytes).unwrap();
        assert!(tv.ntiles() > 1, "tiny budget must produce multiple tiles");
        assert_eq!(tv.to_vol3(&plan).data, x.data);
        assert!(tv.evictions() > 0, "faulting all tiles twice under a one-tile budget must evict");
        // the process-wide gauge tracks this instance's residency
        assert!(resident_tile_bytes() >= tv.resident_bytes());
    }

    #[test]
    fn unsupported_plans_are_typed_errors() {
        let vg = VolumeGeometry::cube(8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2));
        // ray model
        let ray = Projector::new(g.clone(), vg.clone(), Model::Joseph).plan();
        assert!(matches!(
            TiledVol3::for_plan(&ray, 1 << 20),
            Err(LeapError::Unsupported(_))
        ));
        // simd backend
        let simd = Projector::new(g.clone(), vg.clone(), Model::SF)
            .with_backend(BackendKind::Simd)
            .plan();
        assert!(matches!(
            TiledVol3::for_plan(&simd, 1 << 20),
            Err(LeapError::Unsupported(_))
        ));
        // zero budget
        let ok = Projector::new(g, vg, Model::SF).with_backend(BackendKind::Scalar).plan();
        assert!(matches!(
            TiledVol3::for_plan(&ok, 0),
            Err(LeapError::InvalidArgument(_))
        ));
    }

    #[test]
    fn resident_gauge_returns_to_baseline_on_drop() {
        let _g = gauge_lock();
        let vg = VolumeGeometry::cube(8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2));
        let p = Projector::new(g, vg, Model::SF).with_backend(BackendKind::Scalar);
        let plan = p.plan();
        let before = resident_tile_bytes();
        {
            let mut x = p.new_vol();
            Rng::new(1).fill_uniform(&mut x.data, 0.0, 1.0);
            let tv = TiledVol3::from_vol3(&plan, &x, 1 << 12).unwrap();
            assert!(resident_tile_bytes() >= before + tv.resident_bytes());
        }
        assert_eq!(resident_tile_bytes(), before);
    }
}
