//! On-the-fly forward (`A`) and matched back (`Aᵀ`) projection — the
//! paper's core contribution.
//!
//! Three projector models (paper §2.1):
//! * [`Model::Siddon`] — exact radiological path; ray-driven; works for
//!   every geometry.
//! * [`Model::Joseph`] — major-axis marching with bilinear interpolation;
//!   ray-driven; every geometry; this is also the L1 Pallas kernel's
//!   formulation.
//! * [`Model::SF`] — separable footprints; voxel-driven; models finite
//!   voxel and detector-pixel extent (most accurate); parallel, fan and
//!   cone geometries (modular beams fall back to Joseph, documented in
//!   DESIGN.md).
//!
//! **Matched pairs.** For each model the backprojector enumerates exactly
//! the coefficients of the forward projector (same code path), so
//! `⟨Ax, y⟩ = ⟨x, Aᵀy⟩` holds to floating-point accuracy — the property
//! the paper requires for stable gradient-based reconstruction over
//! thousands of iterations.
//!
//! **Plan/execute.** Per-view geometry invariants (trig, detector bases,
//! SF footprint bounds, the Joseph marching axis) are factored into a
//! reusable [`ProjectionPlan`]: `let plan = p.plan();` then
//! [`Projector::forward_with_plan`] / [`Projector::back_with_plan`] (or
//! the plan's own `forward_into`/`back_into`) skip re-planning on every
//! operator application. The direct [`Projector::forward_into`] /
//! [`Projector::back_into`] run the same execute code with per-view
//! invariants built on the fly, so the two paths are bit-identical; the
//! iterative solvers in [`crate::recon`] plan once per solve and the
//! serving coordinator caches plans per scan config
//! ([`crate::coordinator::PlanCache`]).
//!
//! **Memory.** No system matrix is ever formed: peak memory is one copy
//! of the volume plus one copy of the projections, **independent of the
//! thread count**. Parallel backprojection is slab-owned — every worker
//! accumulates directly into the disjoint voxel rows it owns — so the
//! former `threads × volume` partial-volume scatter copies (and their
//! serial reduction) no longer exist on any path; the only transient
//! per-worker scratch is one cone view's `O(nx·ny)` footprint on the
//! unplanned path. Only held plans carry extra state: the cone-beam
//! plan's `O(nviews·nx·ny)` transaxial footprint cache (capped at
//! `LEAP_PLAN_MAX_BYTES` with a transparent on-the-fly fallback), and
//! ray-driven plans' 4 B/ray slab-span table (one sinogram-sized copy)
//! that lets slab-owned backprojection reject non-touching rays with two
//! integer compares. Compare [`crate::sysmatrix`] for the stored-matrix
//! baseline, and [`crate::ops`] for the operator/gradient layer built on
//! these pairs.
//!
//! **Layering.** This module is the *kernel layer*: concrete, fast, and
//! panicking on contract violations (wrong shapes are programming
//! errors here). User-facing code should come through the typed front
//! door instead — [`crate::api::ScanBuilder`] validates a scan
//! description into a [`crate::api::Scan`] whose `forward`/`back`/
//! `solve`/`loss_grad` return `Result<_, `[`crate::api::LeapError`]`>`
//! and dispatch to exactly this code after validation. The panicking
//! entry points below remain supported as the layer `Scan` (and the
//! solvers, and the serving executors) are shims over.
//!
//! **Execution.** All parallel loops run on the process-wide persistent
//! worker pool ([`crate::util::pool`], sized by `LEAP_THREADS`): operator
//! applications dispatch parked workers instead of spawning OS threads,
//! and irregular work (cone-SF views) is dynamically scheduled. Results
//! are bit-identical across thread counts for both forward and back
//! projection — on every backend.
//!
//! **Backends.** Each projector carries a [`crate::backend::BackendKind`]
//! selecting how the inner accumulation loops execute: the scalar
//! reference tier (this module's original loops) or the SIMD throughput
//! tier ([`crate::backend::simd`], staged/lane-unrolled drivers over the
//! *same* coefficient enumerators). The default comes from
//! `LEAP_BACKEND` or runtime detection ([`crate::backend::default_kind`]);
//! [`crate::api::ScanBuilder::backend`] sets it per scan. See
//! `docs/BACKENDS.md` for the identity-vs-tolerance contract between
//! tiers.

pub mod siddon;
pub mod joseph;
pub mod sf;
pub mod abel;
pub mod plan;

pub use plan::ProjectionPlan;

use crate::array::{Sino, Vol3};
use crate::backend::{self, BackendKind};
use crate::geometry::{Geometry, VolumeGeometry};
use crate::precision::{self, StorageTier};
use crate::util::pool;

/// Projection coefficient model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    Siddon,
    Joseph,
    SF,
}

impl Model {
    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "siddon" => Some(Model::Siddon),
            "joseph" => Some(Model::Joseph),
            "sf" | "separable" | "separable_footprint" => Some(Model::SF),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Model::Siddon => "siddon",
            Model::Joseph => "joseph",
            Model::SF => "sf",
        }
    }
}

/// A configured forward/back projector pair for one scan.
#[derive(Clone, Debug)]
pub struct Projector {
    pub geom: Geometry,
    pub vg: VolumeGeometry,
    pub model: Model,
    pub threads: usize,
    /// Compute backend the kernels execute on (snapshot into plans and
    /// the serving plan-cache key).
    pub backend: BackendKind,
    /// Storage precision tier for data at rest — cached plan coefficient
    /// tables and backprojection input sinograms ([`StorageTier`]).
    /// Accumulation always stays f32; see `docs/MEMORY.md`.
    pub storage: StorageTier,
}

impl Projector {
    pub fn new(geom: Geometry, vg: VolumeGeometry, model: Model) -> Projector {
        Projector {
            geom,
            vg,
            model,
            threads: pool::default_threads(),
            backend: backend::default_kind(),
            storage: precision::default_tier(),
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Projector {
        self.threads = threads.max(1);
        self
    }

    /// Select the compute backend. The kernel layer panics on the
    /// non-executing PJRT slot (validated entry points —
    /// [`crate::api::ScanBuilder`], plan lowering, the session handshake —
    /// reject it with a typed error before a projector can be built).
    pub fn with_backend(mut self, kind: BackendKind) -> Projector {
        self.backend = kind;
        self
    }

    /// Select the storage precision tier for data at rest (plan
    /// coefficient tables and backprojection input sinograms). The
    /// default comes from `LEAP_STORAGE` ([`precision::default_tier`]);
    /// [`crate::api::ScanBuilder::storage_tier`] sets it per scan.
    pub fn with_storage_tier(mut self, tier: StorageTier) -> Projector {
        self.storage = tier;
        self
    }

    /// `true` when the SIMD tier should drive the kernels for this scan.
    fn kernel_simd(&self) -> bool {
        match self.backend {
            BackendKind::Scalar => false,
            BackendKind::Simd => true,
            BackendKind::Pjrt => panic!(
                "pjrt backend is a registered slot, not an executable tier \
                 (validated entry points reject it before kernel dispatch)"
            ),
        }
    }

    /// Allocate a correctly-shaped sinogram for this scan.
    pub fn new_sino(&self) -> Sino {
        Sino::zeros(self.geom.nviews(), self.geom.nrows(), self.geom.ncols())
    }

    /// Allocate a correctly-shaped volume.
    pub fn new_vol(&self) -> Vol3 {
        Vol3::zeros(self.vg.nx, self.vg.ny, self.vg.nz)
    }

    /// Precompute this scan's per-view invariants for reuse across many
    /// operator applications (the plan step — see [`ProjectionPlan`]).
    pub fn plan(&self) -> ProjectionPlan {
        ProjectionPlan::new(self)
    }

    /// Forward projection `sino = A·vol` (overwrites `sino`). Plans each
    /// view on the fly; use [`Self::forward_with_plan`] in loops.
    pub fn forward_into(&self, vol: &Vol3, sino: &mut Sino) {
        plan::check_shapes(&self.geom, &self.vg, vol, sino);
        // Reduced-precision tiers execute through the planned path: a
        // transient plan packs/quantizes exactly the tables the cached
        // plan would, so direct and planned outputs stay bit-identical
        // per tier (the f32 invariant, generalized).
        if self.storage != StorageTier::F32 {
            return ProjectionPlan::new(self).forward_into_with_threads(vol, sino, self.threads);
        }
        let simd = self.kernel_simd();
        match (self.model, &self.geom) {
            (Model::SF, Geometry::Parallel(g)) if simd => {
                backend::simd::forward_parallel_simd(&self.vg, g, None, vol, sino, self.threads)
            }
            (Model::SF, Geometry::Parallel(g)) => {
                sf::forward_parallel(&self.vg, g, vol, sino, self.threads)
            }
            (Model::SF, Geometry::Fan(g)) if simd => {
                backend::simd::forward_fan_simd(&self.vg, g, None, vol, sino, self.threads)
            }
            (Model::SF, Geometry::Fan(g)) => sf::forward_fan(&self.vg, g, vol, sino, self.threads),
            (Model::SF, Geometry::Cone(g)) if simd => {
                backend::simd::forward_cone_simd(&self.vg, g, None, vol, sino, self.threads)
            }
            (Model::SF, Geometry::Cone(g)) => {
                sf::forward_cone(&self.vg, g, vol, sino, self.threads)
            }
            // SF is not defined for arbitrary modular poses; Joseph is the
            // documented fallback (DESIGN.md §3).
            (Model::SF, Geometry::Modular(_)) | (Model::Joseph, _) => plan::ray_forward_exec(
                &self.vg,
                &self.geom,
                None,
                false,
                simd,
                vol,
                sino,
                self.threads,
            ),
            (Model::Siddon, _) => plan::ray_forward_exec(
                &self.vg,
                &self.geom,
                None,
                true,
                simd,
                vol,
                sino,
                self.threads,
            ),
        }
    }

    /// `A·vol`, allocating the output.
    pub fn forward(&self, vol: &Vol3) -> Sino {
        let mut sino = self.new_sino();
        self.forward_into(vol, &mut sino);
        sino
    }

    /// Matched backprojection `vol = Aᵀ·sino` (overwrites `vol`). Plans
    /// each view on the fly; use [`Self::back_with_plan`] in loops.
    pub fn back_into(&self, sino: &Sino, vol: &mut Vol3) {
        // symmetric to forward_into: a mismatched sinogram would index out
        // of bounds (or silently truncate) inside the per-view kernels
        plan::check_shapes(&self.geom, &self.vg, vol, sino);
        if self.storage != StorageTier::F32 {
            return ProjectionPlan::new(self).back_into_with_threads(sino, vol, self.threads);
        }
        let simd = self.kernel_simd();
        match (self.model, &self.geom) {
            (Model::SF, Geometry::Parallel(g)) if simd => {
                backend::simd::back_parallel_simd(&self.vg, g, None, sino, vol, self.threads)
            }
            (Model::SF, Geometry::Parallel(g)) => {
                sf::back_parallel(&self.vg, g, sino, vol, self.threads)
            }
            (Model::SF, Geometry::Fan(g)) if simd => {
                backend::simd::back_fan_simd(&self.vg, g, None, sino, vol, self.threads)
            }
            (Model::SF, Geometry::Fan(g)) => sf::back_fan(&self.vg, g, sino, vol, self.threads),
            (Model::SF, Geometry::Cone(g)) if simd => {
                backend::simd::back_cone_simd(&self.vg, g, None, sino, vol, self.threads)
            }
            (Model::SF, Geometry::Cone(g)) => sf::back_cone(&self.vg, g, sino, vol, self.threads),
            // ray backprojection has no safely vectorizable inner loop
            // (guarded indirect scatter): both CPU tiers share this path
            (Model::SF, Geometry::Modular(_)) | (Model::Joseph, _) => {
                plan::ray_back_exec(&self.vg, &self.geom, None, false, sino, vol, self.threads)
            }
            (Model::Siddon, _) => {
                plan::ray_back_exec(&self.vg, &self.geom, None, true, sino, vol, self.threads)
            }
        }
    }

    /// `Aᵀ·sino`, allocating the output.
    pub fn back(&self, sino: &Sino) -> Vol3 {
        let mut vol = self.new_vol();
        self.back_into(sino, &mut vol);
        vol
    }

    /// Forward projection through a prebuilt plan (the execute step).
    /// Panics if `plan` was built for a different scan/model.
    pub fn forward_with_plan(&self, plan: &ProjectionPlan, vol: &Vol3, sino: &mut Sino) {
        assert!(plan.matches(self), "plan was built for a different scan");
        plan.forward_into(vol, sino);
    }

    /// Matched backprojection through a prebuilt plan (the execute step).
    /// Panics if `plan` was built for a different scan/model.
    pub fn back_with_plan(&self, plan: &ProjectionPlan, sino: &Sino, vol: &mut Vol3) {
        assert!(plan.matches(self), "plan was built for a different scan");
        plan.back_into(sino, vol);
    }

    /// `Aᵀ·1`: per-voxel total weight, used by SIRT/SART normalization.
    pub fn back_ones(&self) -> Vol3 {
        let mut ones = self.new_sino();
        ones.fill(1.0);
        self.back(&ones)
    }

    /// `A·1`: per-ray total intersection, used by SIRT/SART normalization.
    pub fn forward_ones(&self) -> Sino {
        let mut ones = self.new_vol();
        ones.fill(1.0);
        self.forward(&ones)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{ConeBeam, FanBeam, ModularBeam, ParallelBeam};
    use crate::util::{dot_f64, rng::Rng};

    fn adjoint_gap(p: &Projector, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut x = p.new_vol();
        let mut y = p.new_sino();
        rng.fill_uniform(&mut x.data, -1.0, 1.0);
        rng.fill_uniform(&mut y.data, -1.0, 1.0);
        let ax = p.forward(&x);
        let aty = p.back(&y);
        let lhs = dot_f64(&ax.data, &y.data);
        let rhs = dot_f64(&x.data, &aty.data);
        (lhs - rhs).abs() / lhs.abs().max(rhs.abs()).max(1e-12)
    }

    fn all_geometries() -> Vec<Geometry> {
        let cone = ConeBeam::standard(6, 10, 14, 1.6, 1.6, 60.0, 120.0);
        let mut curved = cone.clone();
        curved.shape = crate::geometry::DetectorShape::Curved;
        vec![
            Geometry::Parallel(ParallelBeam::standard_3d(7, 10, 14, 1.3, 1.3)),
            Geometry::Fan(FanBeam::standard(6, 18, 1.4, 60.0, 120.0)),
            Geometry::Cone(cone.clone()),
            Geometry::Cone(curved),
            Geometry::Modular(ModularBeam::from_cone(&cone)),
        ]
    }

    #[test]
    fn adjoint_identity_all_models_all_geometries() {
        for geom in all_geometries() {
            let vg = if matches!(geom, Geometry::Fan(_)) {
                VolumeGeometry::slice2d(12, 12, 1.0)
            } else {
                VolumeGeometry::cube(10, 1.0)
            };
            for model in [Model::Siddon, Model::Joseph, Model::SF] {
                let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(2);
                let gap = adjoint_gap(&p, 42);
                assert!(
                    gap < 5e-5,
                    "{} / {}: adjoint gap {gap}",
                    model.name(),
                    p.geom.kind()
                );
            }
        }
    }

    #[test]
    fn forward_linear() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 24, 1.0));
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(g.clone(), vg.clone(), model);
            let mut rng = Rng::new(3);
            let mut a = p.new_vol();
            let mut b = p.new_vol();
            rng.fill_uniform(&mut a.data, 0.0, 1.0);
            rng.fill_uniform(&mut b.data, 0.0, 1.0);
            let mut sum = p.new_vol();
            for i in 0..sum.len() {
                sum.data[i] = 2.0 * a.data[i] - 3.0 * b.data[i];
            }
            let pa = p.forward(&a);
            let pb = p.forward(&b);
            let psum = p.forward(&sum);
            for i in 0..psum.len() {
                let expect = 2.0 * pa.data[i] - 3.0 * pb.data[i];
                assert!(
                    (psum.data[i] - expect).abs() < 2e-4 * expect.abs().max(1.0),
                    "{}: {} vs {}",
                    model.name(),
                    psum.data[i],
                    expect
                );
            }
        }
    }

    #[test]
    fn projection_values_scale_invariant_under_refinement() {
        // paper: "all numerical values scale appropriately when changing
        // the voxel sizes". A disk projected at 1 mm vs 0.5 mm voxels gives
        // the same line integrals.
        let ph = crate::phantom::Phantom::new(vec![crate::phantom::Shape::ellipse2d(
            0.0, 0.0, 10.0, 10.0, 0.0, 0.05,
        )]);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(6, 32, 1.0));
        let mut sinos = Vec::new();
        for (n, v) in [(32usize, 1.0f64), (64, 0.5)] {
            let vg = VolumeGeometry::slice2d(n, n, v);
            let vol = ph.rasterize(&vg, 3);
            for model in [Model::Siddon, Model::Joseph, Model::SF] {
                let p = Projector::new(g.clone(), vg.clone(), model);
                sinos.push((model, v, p.forward(&vol)));
            }
        }
        // center-bin value ≈ 2·r·μ = 1.0 for every model and voxel size
        for (model, v, s) in &sinos {
            let c = s.at(0, 0, 16);
            assert!(
                (c - 1.0).abs() < 0.03,
                "{} @ voxel {v}: center {c}",
                model.name()
            );
        }
    }

    #[test]
    fn models_agree_on_smooth_phantom() {
        let ph = crate::phantom::shepp::shepp_logan_2d(14.0, 0.02);
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let vol = ph.rasterize(&vg, 2);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 48, 1.0));
        let sino_s = Projector::new(g.clone(), vg.clone(), Model::Siddon).forward(&vol);
        let sino_j = Projector::new(g.clone(), vg.clone(), Model::Joseph).forward(&vol);
        let sino_f = Projector::new(g.clone(), vg.clone(), Model::SF).forward(&vol);
        let ej = crate::util::rel_l2(&sino_j.data, &sino_s.data, 1e-9);
        let ef = crate::util::rel_l2(&sino_f.data, &sino_s.data, 1e-9);
        assert!(ej < 0.05, "joseph vs siddon {ej}");
        assert!(ef < 0.05, "sf vs siddon {ef}");
    }

    #[test]
    fn threads_do_not_change_results() {
        // slab-owned backprojection accumulates every voxel in the same
        // order for any worker count, so forward AND back must now be
        // bit-identical across thread counts — for every model × geometry
        let mut rng = Rng::new(11);
        for geom in all_geometries() {
            let vg = if matches!(geom, Geometry::Fan(_)) {
                VolumeGeometry::slice2d(12, 12, 1.0)
            } else {
                VolumeGeometry::cube(10, 1.0)
            };
            for model in [Model::Siddon, Model::Joseph, Model::SF] {
                let p1 = Projector::new(geom.clone(), vg.clone(), model).with_threads(1);
                let mut x = p1.new_vol();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                let mut y = p1.new_sino();
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                let a = p1.forward(&x);
                let va = p1.back(&y);
                for threads in [2usize, 4, 7] {
                    let pn =
                        Projector::new(geom.clone(), vg.clone(), model).with_threads(threads);
                    let b = pn.forward(&x);
                    assert_eq!(
                        a.data,
                        b.data,
                        "{}/{} forward, {threads} threads",
                        model.name(),
                        pn.geom.kind()
                    );
                    let vb = pn.back(&y);
                    assert_eq!(
                        va.data,
                        vb.data,
                        "{}/{} back, {threads} threads",
                        model.name(),
                        pn.geom.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn back_ones_positive_inside_fov() {
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(12, 24, 1.0));
        for model in [Model::Siddon, Model::Joseph, Model::SF] {
            let p = Projector::new(g.clone(), vg.clone(), model);
            let w = p.back_ones();
            // center voxel sees every view
            assert!(w.at(8, 8, 0) > 0.0, "{}", model.name());
        }
    }

    #[test]
    #[should_panic(expected = "sinogram shape mismatch")]
    fn back_into_rejects_mismatched_sinogram() {
        // the historical bug: back_into validated the volume but not the
        // sinogram, so a wrong-shaped sinogram read out of bounds
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(6, 12, 1.0));
        let p = Projector::new(g, vg, Model::SF);
        let bad = Sino::zeros(5, 1, 12); // 5 views instead of 6
        let mut vol = p.new_vol();
        p.back_into(&bad, &mut vol);
    }

    #[test]
    #[should_panic(expected = "sinogram shape mismatch")]
    fn forward_into_rejects_mismatched_sinogram() {
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(6, 12, 1.0));
        let p = Projector::new(g, vg, Model::Joseph);
        let mut bad = Sino::zeros(6, 1, 10); // 10 cols instead of 12
        let vol = p.new_vol();
        p.forward_into(&vol, &mut bad);
    }

    #[test]
    fn model_parse() {
        assert_eq!(Model::parse("SF"), Some(Model::SF));
        assert_eq!(Model::parse("siddon"), Some(Model::Siddon));
        assert_eq!(Model::parse("Joseph"), Some(Model::Joseph));
        assert_eq!(Model::parse("warp"), None);
    }
}
