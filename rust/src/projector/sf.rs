//! Separable-Footprint projector (Long, Fessler & Balter 2010).
//!
//! Each voxel's detector footprint is approximated as a separable product
//! of 1-D trapezoids: the transaxial trapezoid comes from projecting the
//! four in-plane voxel corners, the axial one from projecting the voxel's
//! z-extent. Detector coefficients are exact bin integrals of the
//! trapezoid (not point samples), which models the finite voxel *and*
//! detector pixel width — the accuracy advantage over Siddon/Joseph the
//! paper cites (§2.1).
//!
//! Quantitative normalization: with `T` a unit-area trapezoid, the
//! coefficient of voxel `p` for bin `(r, c)` is
//!
//! ```text
//!   A = amp(p) · (1/du)∫_bin_c T_u · (1/dv)∫_bin_r T_v
//!   amp = V · m_u · m_v / cos ψ
//! ```
//!
//! where `V` is the voxel volume, `m_u`, `m_v` the local magnifications
//! and `ψ` the ray-to-detector-normal angle (all 1 for parallel beam).
//! This conserves mass — `Σ_bins A = V·m_u·m_v/(du·dv·cos ψ)` — so values
//! scale correctly under voxel/detector size changes (paper: "all
//! numerical values scale appropriately").
//!
//! Both forward (scatter) and back (gather) projection enumerate the same
//! voxel→bin coefficients, so the pair is exactly matched.
//!
//! ## Plan/execute split
//!
//! Every geometry's coefficient enumeration is factored into a **plan**
//! step (`plan_*_view`: per-view trig, the shared transaxial trapezoid,
//! axial/row weights, and — for cone beams — the per-voxel-column
//! footprint bounds) and an **execute** step that replays the cached
//! invariants. The classic one-shot entry points plan each view on the
//! fly, so the direct and planned paths share a single code path and are
//! bit-identical by construction.
//! [`crate::projector::ProjectionPlan`] caches the per-view plans across
//! operator applications (iterative solvers, the serving coordinator).
//!
//! ## Slab-owned backprojection
//!
//! SF is voxel-driven, so the matched backprojection is a **gather**:
//! each worker owns a disjoint range of voxel rows (parallel beam:
//! `(z-slice, y-row)` units; fan/cone: `y`-rows, which own their full
//! voxel columns) and accumulates `Σ_views Σ_bins coeff·sino` straight
//! into the output volume. No worker ever writes another worker's voxels,
//! so there are **no per-thread partial volumes and no reduction** — peak
//! scratch memory is independent of the thread count, and each voxel's
//! contributions always arrive in (view, enumeration) order, making the
//! output bit-identical for every thread count. The per-view coefficient
//! enumeration restricted to a row range computes exactly the same
//! floating-point values as the full enumeration, so forward and back
//! remain an exactly matched pair.

use crate::array::{Sino, Vol3};
use crate::geometry::{ConeBeam, DetectorShape, FanBeam, ParallelBeam, VolumeGeometry};
use crate::precision::StorageTier;
use crate::util::pool::{parallel_chunks, parallel_items, parallel_items_with, ParWriter};

/// A trapezoid bump with unit area, described by four sorted breakpoints:
/// linear rise `b0→b1`, flat `b1→b2`, linear fall `b2→b3`.
#[derive(Clone, Copy, Debug)]
pub struct Trap {
    pub b: [f64; 4],
    pub h: f64,
}

impl Trap {
    /// Build from four (unsorted) projected corner coordinates.
    pub fn new(mut pts: [f64; 4]) -> Trap {
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let denom = (pts[3] + pts[2] - pts[1] - pts[0]) / 2.0;
        let h = if denom > 1e-12 { 1.0 / denom } else { 0.0 };
        Trap { b: pts, h }
    }

    /// Is this a degenerate (near-zero-width) footprint?
    pub fn is_degenerate(&self) -> bool {
        self.h == 0.0
    }

    /// ∫_{-∞}^{x} of the trapezoid (CDF; reaches 1 at `b3`).
    pub fn cdf(&self, x: f64) -> f64 {
        let [b0, b1, b2, b3] = self.b;
        if x <= b0 {
            0.0
        } else if x < b1 {
            let d = x - b0;
            self.h * d * d / (2.0 * (b1 - b0))
        } else if x < b2 {
            self.h * ((b1 - b0) / 2.0 + (x - b1))
        } else if x < b3 {
            let d = b3 - x;
            1.0 - self.h * d * d / (2.0 * (b3 - b2))
        } else {
            1.0
        }
    }

    /// ∫_{x0}^{x1} of the trapezoid.
    #[inline]
    pub fn integral(&self, x0: f64, x1: f64) -> f64 {
        self.cdf(x1) - self.cdf(x0)
    }
}

/// Accumulate `amp · (1/pitch)·∫_bin T` over all detector bins overlapped
/// by `trap`, calling `emit(bin_index, coefficient)`.
#[inline]
fn for_bins<F: FnMut(usize, f64)>(
    trap: &Trap,
    n: usize,
    pitch: f64,
    center_off: f64,
    amp: f64,
    mut emit: F,
) {
    // bin c spans [u_lo(c), u_lo(c)+pitch] with u_lo(c) = (c − (n−1)/2)·pitch + off − pitch/2
    let half = (n as f64 - 1.0) / 2.0;
    let u_lo_0 = -half * pitch + center_off - pitch / 2.0;
    if trap.is_degenerate() {
        // point mass: deposit everything in the containing bin
        let u = trap.b[0];
        let c = ((u - u_lo_0) / pitch).floor();
        if c >= 0.0 && (c as usize) < n {
            emit(c as usize, amp / pitch);
        }
        return;
    }
    let c_first = (((trap.b[0] - u_lo_0) / pitch).floor()).max(0.0) as usize;
    let c_last = (((trap.b[3] - u_lo_0) / pitch).ceil() as i64).min(n as i64 - 1);
    if c_last < 0 {
        return;
    }
    for c in c_first..=(c_last as usize) {
        let lo = u_lo_0 + c as f64 * pitch;
        let w = trap.integral(lo, lo + pitch);
        if w > 0.0 {
            emit(c, amp * w / pitch);
        }
    }
}

// ---------------------------------------------------------------------------
// parallel beam (2-D and 3-D; rows decouple because rays are horizontal)
// ---------------------------------------------------------------------------

/// Per-view specialized evaluator for a *fixed-shape* trapezoid centered
/// at a moving position — the SF parallel hot loop. Precomputes the ramp
/// reciprocals so the CDF is division-free, and bin integrals share the
/// CDF value at adjacent bin edges (perf pass: EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
struct TrapEval {
    b: [f64; 4],
    h: f64,
    half_inv_rise: f64,
    half_inv_fall: f64,
    flat_base: f64,
}

impl TrapEval {
    fn new(shape: &Trap) -> TrapEval {
        let [b0, b1, b2, b3] = shape.b;
        let h = shape.h;
        TrapEval {
            b: shape.b,
            h,
            half_inv_rise: if b1 > b0 { h / (2.0 * (b1 - b0)) } else { 0.0 },
            half_inv_fall: if b3 > b2 { h / (2.0 * (b3 - b2)) } else { 0.0 },
            flat_base: h * (b1 - b0) / 2.0,
        }
    }

    /// CDF at `x` relative to the trapezoid center.
    #[inline]
    fn cdf(&self, x: f64) -> f64 {
        let [b0, b1, b2, b3] = self.b;
        if x <= b0 {
            0.0
        } else if x < b1 {
            let d = x - b0;
            d * d * self.half_inv_rise
        } else if x < b2 {
            self.flat_base + self.h * (x - b1)
        } else if x < b3 {
            let d = b3 - x;
            1.0 - d * d * self.half_inv_fall
        } else {
            1.0
        }
    }
}

/// Per-view invariants of the parallel-beam SF footprint — the plan step.
/// Holds only what actually varies with the view: the trig and the
/// voxel-shape trapezoid (identical for every voxel at a view) with its
/// division-free evaluator. The axial (detector-row) weights are
/// view-invariant and live once per plan in [`ParallelRowWeights`].
#[derive(Clone, Debug)]
pub struct ParallelViewPlan {
    sin: f64,
    cos: f64,
    shape: Trap,
    eval: TrapEval,
    degenerate: bool,
}

/// View-invariant axial footprint of a parallel-beam scan: rays are
/// horizontal, so slice `k`'s z-extent maps to the same detector rows at
/// every view. Shared across all views of a plan — the former per-view
/// copy multiplied plan memory by `nviews` for no information.
#[derive(Clone, Debug)]
pub struct ParallelRowWeights {
    pure_2d: bool,
    /// `per_k[k]` = (row, weight) overlaps of slice `k`'s z-extent.
    per_k: Vec<Vec<(usize, f64)>>,
}

impl ParallelRowWeights {
    pub(crate) fn approx_bytes(&self) -> usize {
        std::mem::size_of::<ParallelRowWeights>()
            + self
                .per_k
                .iter()
                .map(|r| {
                    std::mem::size_of::<Vec<(usize, f64)>>()
                        + r.len() * std::mem::size_of::<(usize, f64)>()
                })
                .sum::<usize>()
    }
}

/// Everything [`crate::projector::ProjectionPlan`] caches for a
/// parallel-beam SF scan: one slim plan per view plus the shared
/// view-invariant row weights.
#[derive(Clone, Debug)]
pub struct ParallelPlanSet {
    pub(crate) views: Vec<ParallelViewPlan>,
    pub(crate) rows: ParallelRowWeights,
}

impl ParallelPlanSet {
    /// Approximate heap footprint of the cached invariants in bytes.
    pub(crate) fn approx_bytes(&self) -> usize {
        self.views.len() * std::mem::size_of::<ParallelViewPlan>() + self.rows.approx_bytes()
    }
}

/// Pre-build byte estimate of a parallel-beam SF plan, derived from the
/// real plan layouts via `size_of` — the single definition shared by
/// [`crate::projector::ProjectionPlan::estimate_heap_bytes`] and the
/// byte-budget tests, so the estimate and the actual resident bytes
/// cannot silently drift apart when `ParallelViewPlan` changes shape.
/// Exact for pure 2-D scans (whose shared row-weight table is empty);
/// for 3-D scans each slice's `(row, weight)` overlap list is
/// upper-bounded by the `ceil(vz/dv) + 1` detector rows a `vz`-wide
/// rect footprint can span.
pub fn parallel_plan_estimate_bytes(vg: &VolumeGeometry, g: &ParallelBeam) -> usize {
    // saturating like cone_plan_estimate_bytes: the estimator runs
    // BEFORE validation-by-allocation, so absurd (but representable)
    // grids must saturate to "too big" rather than wrap around
    let views = g.angles.len().saturating_mul(std::mem::size_of::<ParallelViewPlan>());
    let pure_2d = vg.nz == 1 && g.nrows == 1;
    let rows = std::mem::size_of::<ParallelRowWeights>().saturating_add(if pure_2d {
        0
    } else {
        let per_slice = if g.dv > 0.0 {
            (((vg.vz / g.dv).ceil() as usize) + 1).min(g.nrows.max(1))
        } else {
            g.nrows.max(1)
        };
        vg.nz.saturating_mul(
            std::mem::size_of::<Vec<(usize, f64)>>()
                .saturating_add(per_slice.saturating_mul(std::mem::size_of::<(usize, f64)>())),
        )
    });
    views.saturating_add(rows)
}

/// Pre-build estimate of a cone plan's cache: per voxel column one
/// `ConeVoxelFoot` plus one column-weight entry per detector column the
/// magnified in-plane voxel extent spans — geometry-aware so fine-pitch
/// detectors (wide footprints) don't slip past the memory cap with a
/// constant-bins guess. Entry sizes come from `size_of` on the real plan
/// types, like [`parallel_plan_estimate_bytes`].
pub fn cone_plan_estimate_bytes(g: &ConeBeam, vg: &VolumeGeometry) -> usize {
    let mag = if g.sod > 0.0 { g.sdd / g.sod } else { 1.0 };
    let cols_per_foot = if g.du > 0.0 {
        ((((vg.vx + vg.vy) * mag / g.du).ceil() + 1.0).max(2.0) as usize).min(g.ncols.max(1))
    } else {
        g.ncols.max(1)
    };
    g.angles
        .len()
        .saturating_mul(vg.nx.saturating_mul(vg.ny))
        .saturating_mul(
            std::mem::size_of::<ConeVoxelFoot>() + cols_per_foot * std::mem::size_of::<(u32, f64)>(),
        )
}

/// Build the per-view SF invariants for one parallel-beam view.
pub fn plan_parallel_view(vg: &VolumeGeometry, g: &ParallelBeam, view: usize) -> ParallelViewPlan {
    let phi = g.angles[view];
    let (s, c) = phi.sin_cos();
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    // transaxial trapezoid shape is identical for every voxel at this view
    let dx = hx * c;
    let dy = hy * s;
    let shape = Trap::new([-dx - dy, -dx + dy, dx - dy, dx + dy]);
    let eval = TrapEval::new(&shape);
    let degenerate = shape.is_degenerate();
    ParallelViewPlan { sin: s, cos: c, shape, eval, degenerate }
}

/// Build the shared (view-invariant) axial row weights of a parallel-beam
/// scan: rays are horizontal, so the voxel z-extent maps to v directly
/// (rect of width vz). Per-row weights depend only on the slice index.
pub fn plan_parallel_rows(vg: &VolumeGeometry, g: &ParallelBeam) -> ParallelRowWeights {
    let pure_2d = vg.nz == 1 && g.nrows == 1;
    let hz = vg.vz / 2.0;
    let mut per_k: Vec<Vec<(usize, f64)>> = Vec::new();
    if !pure_2d {
        per_k.reserve(vg.nz);
        for k in 0..vg.nz {
            let zc = vg.z(k);
            let vtrap = Trap::new([zc - hz, zc - hz, zc + hz, zc + hz]);
            let mut rows = Vec::new();
            for_bins(&vtrap, g.nrows, g.dv, g.cv, 1.0, |row, a_v| rows.push((row, a_v)));
            per_k.push(rows);
        }
    }
    ParallelRowWeights { pure_2d, per_k }
}

/// Build the full parallel-beam plan set (views serially; the plan step
/// in [`crate::projector::ProjectionPlan`] builds views in parallel and
/// assembles the set itself).
pub(crate) fn plan_parallel_set(vg: &VolumeGeometry, g: &ParallelBeam) -> ParallelPlanSet {
    ParallelPlanSet {
        views: (0..g.angles.len()).map(|v| plan_parallel_view(vg, g, v)).collect(),
        rows: plan_parallel_rows(vg, g),
    }
}

/// Enumerate SF coefficients for one parallel-beam view restricted to the
/// voxel-row range `m0..m1`, where row `m = k·ny + j` is one contiguous
/// x-run of the volume (the execute step), invoking
/// `emit(voxel_flat, row, col, coeff)`.
///
/// The rows decouple — no state crosses a row boundary — so restricting
/// the range yields exactly the floats of the full enumeration: the basis
/// of both the forward path (full range per view) and the slab-owned
/// backprojection (each worker gathers its own row range over all views).
pub(crate) fn parallel_rows_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    vp: &ParallelViewPlan,
    rows: &ParallelRowWeights,
    m0: usize,
    m1: usize,
    mut emit: F,
) {
    let (s, c) = (vp.sin, vp.cos);
    let shape = &vp.shape;
    let eval = &vp.eval;
    let degenerate = vp.degenerate;
    let pure_2d = rows.pure_2d;
    let amp_t = vg.vx * vg.vy; // 2-D area; z handled separately

    // detector bin grid
    let ncols = g.ncols;
    let half_det = (ncols as f64 - 1.0) / 2.0;
    let u_lo_0 = -half_det * g.du - g.du / 2.0 + g.cu;
    let inv_du = 1.0 / g.du;

    // fold scales so the innermost math is one multiply per coefficient
    let amp_u = amp_t * vg.vz * inv_du;
    let amp_2d = amp_t * inv_du;

    let duc = vg.vx * c; // uc increment per i (can be negative)
    for m in m0..m1 {
        let k = m / vg.ny;
        let j = m % vg.ny;
        let rw: &[(usize, f64)] = if pure_2d { &[] } else { &rows.per_k[k] };
        let y = vg.y(j);
        let mut uc = vg.x(0) * c + y * s;
        let mut flat = m * vg.nx;
        for _i in 0..vg.nx {
            if degenerate {
                // zero-width footprint: all mass into the containing bin
                let cbin = ((uc - u_lo_0) * inv_du).floor();
                if cbin >= 0.0 && (cbin as usize) < ncols {
                    let col = cbin as usize;
                    if pure_2d {
                        emit(flat, 0, col, amp_2d);
                    } else {
                        for &(row, a_v) in rw {
                            emit(flat, row, col, amp_u * a_v);
                        }
                    }
                }
                uc += duc;
                flat += 1;
                continue;
            }
            // overlapped bin range
            let c_first_f = ((uc + shape.b[0] - u_lo_0) * inv_du).floor();
            let c_first = if c_first_f < 0.0 { 0usize } else { c_first_f as usize };
            let c_last_f = ((uc + shape.b[3] - u_lo_0) * inv_du).ceil();
            if c_last_f < 0.0 || c_first >= ncols {
                uc += duc;
                flat += 1;
                continue;
            }
            let c_last = (c_last_f as usize).min(ncols - 1);
            // shared-edge CDF walk across the bins
            let mut f_prev = eval.cdf(u_lo_0 + c_first as f64 * g.du - uc);
            for col in c_first..=c_last {
                let f_next = eval.cdf(u_lo_0 + (col + 1) as f64 * g.du - uc);
                let w = f_next - f_prev;
                f_prev = f_next;
                if w <= 0.0 {
                    continue;
                }
                if pure_2d {
                    emit(flat, 0, col, amp_2d * w);
                } else {
                    let a_u = amp_u * w;
                    for &(row, a_v) in rw {
                        emit(flat, row, col, a_u * a_v);
                    }
                }
            }
            uc += duc;
            flat += 1;
        }
    }
}

/// Enumerate SF coefficients of every voxel for one parallel-beam view
/// from its plan (full row range).
pub(crate) fn parallel_view_coeffs_planned<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    vp: &ParallelViewPlan,
    rows: &ParallelRowWeights,
    emit: F,
) {
    parallel_rows_coeffs(vg, g, vp, rows, 0, vg.nz * vg.ny, emit)
}

/// Enumerate SF coefficients of every voxel for view `view` of a
/// parallel-beam geometry (plans the view on the fly), invoking
/// `emit(voxel_flat, row, col, coeff)`.
fn parallel_view_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    view: usize,
    emit: F,
) {
    let rows = plan_parallel_rows(vg, g);
    let vp = plan_parallel_view(vg, g, view);
    parallel_view_coeffs_planned(vg, g, &vp, &rows, emit)
}

/// Public coefficient enumeration for one parallel-beam view — used by
/// [`crate::sysmatrix`] to assemble the stored-matrix baseline from the
/// *identical* coefficients the on-the-fly path computes.
pub fn parallel_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, usize, f64),
) {
    parallel_view_coeffs(vg, g, view, |a, b, c, d| emit(a, b, c, d));
}

/// Public coefficient enumeration for one fan-beam view (see
/// [`parallel_view_coeffs_pub`]).
pub fn fan_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &FanBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, f64),
) {
    fan_view_coeffs(vg, g, view, |a, b, c| emit(a, b, c));
}

/// Public coefficient enumeration for one cone-beam view (see
/// [`parallel_view_coeffs_pub`]).
pub fn cone_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, usize, f64),
) {
    cone_view_coeffs(vg, g, view, |a, b, c, d| emit(a, b, c, d));
}

/// SF forward projection, parallel beam. Parallelized over views (each
/// view owns its output slab — scatter-safe).
pub fn forward_parallel(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_parallel_opt(vg, g, None, vol, sino, threads)
}

/// [`forward_parallel`] with an optional precomputed plan set. `None`
/// plans each view on the fly inside the worker; both paths share this
/// code, so planned output is bit-identical to the direct path. Views are
/// dynamically scheduled (each view's sinogram slab is written by exactly
/// the worker that claimed it).
pub(crate) fn forward_parallel_opt(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_parallel_range(vg, g, plans, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_parallel_opt`] restricted to the view range `v0..v1`: zeroes
/// and writes only those views' sinogram slabs, leaving the rest of the
/// buffer untouched. Views own disjoint output slabs, so stitching the
/// full view range out of any partition of sub-ranges reproduces the
/// unrestricted output bit for bit — the basis of view-sharded operator
/// execution ([`crate::ops::ViewSharded`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_parallel_range(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert_eq!(sino.nviews, g.angles.len());
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.data[v0 * nrows * ncols..v1 * nrows * ncols].fill(0.0);
    // the row weights are view-invariant: compute once per call when no
    // plan is supplied instead of once per view
    let local_rows;
    let rows: &ParallelRowWeights = match plans {
        Some(set) => &set.rows,
        None => {
            local_rows = plan_parallel_rows(vg, g);
            &local_rows
        }
    };
    let out = ParWriter::new(&mut sino.data);
    parallel_items(v1 - v0, threads, |r| {
        // each view's sinogram slab is written by exactly one worker
        let view = v0 + r;
        let base = view * nrows * ncols;
        let local;
        let vp = match plans {
            Some(set) => &set.views[view],
            None => {
                local = plan_parallel_view(vg, g, view);
                &local
            }
        };
        parallel_view_coeffs_planned(vg, g, vp, rows, |flat, row, col, coeff| {
            out.add(base + row * ncols + col, (coeff as f32) * vol.data[flat]);
        });
    });
}

/// Matched SF backprojection, parallel beam. Slab-owned gather: each
/// worker accumulates its own voxel rows over all views directly into the
/// output volume (exact transpose of [`forward_parallel`]; no partial
/// volumes, no reduction, thread-count-independent floats).
pub fn back_parallel(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_parallel_opt(vg, g, None, sino, vol, threads)
}

/// [`back_parallel`] with an optional precomputed plan set.
pub(crate) fn back_parallel_opt(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_parallel_range(vg, g, plans, sino, vol, threads, 0, vg.nz * vg.ny)
}

/// [`back_parallel_opt`] restricted to the voxel-row range `u0..u1`
/// (units are `(z, y)` rows, `m = k·ny + j`): zeroes and writes only the
/// flat range `u0·nx..u1·nx`. Every owned voxel still replays **all**
/// views in global order, so each voxel's accumulation chain — and hence
/// its bits — is identical to the unrestricted gather; stitching any
/// partition of unit ranges reproduces [`back_parallel`] exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_parallel_range(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let nunits = vg.nz * vg.ny;
    assert!(u0 <= u1 && u1 <= nunits, "unit range {u0}..{u1}");
    let ncols = sino.ncols;
    vol.data[u0 * vg.nx..u1 * vg.nx].fill(0.0);
    // the slim per-view invariants are O(nviews) scalars: the direct path
    // builds them per call (the plan step caches them across calls)
    let local_set;
    let set: &ParallelPlanSet = match plans {
        Some(s) => s,
        None => {
            local_set = plan_parallel_set(vg, g);
            &local_set
        }
    };
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(u1 - u0, threads, |a, b| {
        // this worker owns voxel rows u0+a..u0+b (flat ·nx) exclusively
        let (m0, m1) = (u0 + a, u0 + b);
        for (view, vp) in set.views.iter().enumerate() {
            let vdata = sino.view(view);
            parallel_rows_coeffs(vg, g, vp, &set.rows, m0, m1, |flat, row, col, coeff| {
                out.add(flat, (coeff as f32) * vdata[row * ncols + col]);
            });
        }
    });
}

// ---------------------------------------------------------------------------
// fan beam (2-D divergent)
// ---------------------------------------------------------------------------

/// Per-view invariants of the fan-beam SF footprint: the view trig, from
/// which the source position and detector frame derive. (The per-voxel
/// footprint of a divergent 2-D beam depends on the voxel, so it stays in
/// the execute step; caching it for every view would approach the stored
/// system matrix the paper argues against.)
#[derive(Clone, Copy, Debug)]
pub struct FanViewPlan {
    sin: f64,
    cos: f64,
}

/// Build the per-view SF invariants for one fan-beam view.
pub fn plan_fan_view(g: &FanBeam, view: usize) -> FanViewPlan {
    let (s, c) = g.angles[view].sin_cos();
    FanViewPlan { sin: s, cos: c }
}

/// Enumerate SF coefficients for one fan-beam view from its plan,
/// restricted to the voxel-row range `j0..j1` (rows decouple — every
/// voxel's footprint derives from its own corners — so the restriction is
/// float-identical to the full enumeration).
pub(crate) fn fan_rows_coeffs<F: FnMut(usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &FanBeam,
    vp: &FanViewPlan,
    j0: usize,
    j1: usize,
    mut emit: F,
) {
    let (sphi, cphi) = (vp.sin, vp.cos);
    let src = [g.sod * cphi, g.sod * sphi];
    // detector frame: normal n̂ points source→detector, û along columns
    let nhat = [-cphi, -sphi];
    let uhat = [-sphi, cphi];
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    let area = vg.vx * vg.vy;

    for j in j0..j1 {
        let y = vg.y(j);
        for i in 0..vg.nx {
            let x = vg.x(i);
            // project the 4 in-plane corners onto the detector
            let mut pts = [0.0f64; 4];
            let mut idx = 0;
            for (ddx, ddy) in [(-hx, -hy), (-hx, hy), (hx, -hy), (hx, hy)] {
                let px = x + ddx - src[0];
                let py = y + ddy - src[1];
                let t = px * nhat[0] + py * nhat[1]; // distance along normal
                let u = px * uhat[0] + py * uhat[1];
                pts[idx] = g.sdd * u / t;
                idx += 1;
            }
            let trap = Trap::new(pts);
            // amplitude at the voxel center
            let px = x - src[0];
            let py = y - src[1];
            let t = px * nhat[0] + py * nhat[1];
            let dist = (px * px + py * py).sqrt();
            let m = g.sdd / t;
            let cos_psi = t / dist;
            let amp = area * m / cos_psi;
            let flat = j * vg.nx + i;
            for_bins(&trap, g.ncols, g.du, g.cu, amp, |col, a| emit(flat, col, a));
        }
    }
}

/// Enumerate SF coefficients for one fan-beam view (plans on the fly).
fn fan_view_coeffs<F: FnMut(usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &FanBeam,
    view: usize,
    emit: F,
) {
    let vp = plan_fan_view(g, view);
    fan_rows_coeffs(vg, g, &vp, 0, vg.ny, emit)
}

/// SF forward projection, fan beam (2-D volume required).
pub fn forward_fan(vg: &VolumeGeometry, g: &FanBeam, vol: &Vol3, sino: &mut Sino, threads: usize) {
    forward_fan_opt(vg, g, None, vol, sino, threads)
}

/// [`forward_fan`] with optional precomputed per-view plans.
pub(crate) fn forward_fan_opt(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_fan_range(vg, g, plans, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_fan_opt`] restricted to the view range `v0..v1` (see
/// [`forward_parallel_range`] for the stitching contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_fan_range(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert_eq!(vg.nz, 1, "fan-beam SF requires a 2-D volume");
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let ncols = sino.ncols;
    sino.data[v0 * ncols..v1 * ncols].fill(0.0);
    let out = ParWriter::new(&mut sino.data);
    parallel_items(v1 - v0, threads, |r| {
        // each view's sinogram slab is written by exactly one worker
        let view = v0 + r;
        let base = view * ncols;
        let vp = match plans {
            Some(ps) => ps[view],
            None => plan_fan_view(g, view),
        };
        fan_rows_coeffs(vg, g, &vp, 0, vg.ny, |flat, col, coeff| {
            out.add(base + col, (coeff as f32) * vol.data[flat]);
        });
    });
}

/// Matched SF backprojection, fan beam. Slab-owned gather over voxel rows
/// (see [`back_parallel`]).
pub fn back_fan(vg: &VolumeGeometry, g: &FanBeam, sino: &Sino, vol: &mut Vol3, threads: usize) {
    back_fan_opt(vg, g, None, sino, vol, threads)
}

/// [`back_fan`] with optional precomputed per-view plans.
pub(crate) fn back_fan_opt(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_fan_range(vg, g, plans, sino, vol, threads, 0, vg.ny)
}

/// [`back_fan_opt`] restricted to the voxel-row range `u0..u1` (units are
/// `y`-rows; see [`back_parallel_range`] for the stitching contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_fan_range(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert_eq!(vg.nz, 1);
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    let nviews = g.angles.len();
    vol.data[u0 * vg.nx..u1 * vg.nx].fill(0.0);
    let local;
    let views: &[FanViewPlan] = match plans {
        Some(ps) => ps,
        None => {
            local = (0..nviews).map(|v| plan_fan_view(g, v)).collect::<Vec<_>>();
            &local
        }
    };
    let out = ParWriter::new(&mut vol.data);
    parallel_chunks(u1 - u0, threads, |a, b| {
        // this worker owns voxel rows u0+a..u0+b exclusively
        let (j0, j1) = (u0 + a, u0 + b);
        for (view, vp) in views.iter().enumerate() {
            let vdata = sino.view(view);
            fan_rows_coeffs(vg, g, vp, j0, j1, |flat, col, coeff| {
                out.add(flat, (coeff as f32) * vdata[col]);
            });
        }
    });
}

// ---------------------------------------------------------------------------
// cone beam (flat or curved detector), SF-TR style
// ---------------------------------------------------------------------------

/// Per-voxel-column entry of a [`ConeViewPlan`]: the center-of-voxel
/// scalars the axial (z) loop needs, plus the index range of the
/// transaxial detector-column weights in the plan's `bins` arena.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ConeVoxelFoot {
    /// Source→voxel-center distance along the detector normal; `≤ 0`
    /// marks a column behind the source (no coefficients).
    t_c: f64,
    /// In-plane source→voxel-center distance.
    d_inplane: f64,
    /// Axial magnification at the voxel center.
    m_v: f64,
    /// `V · m_u · m_v` — the amplitude numerator (`cos ψ` varies per z).
    amp_uv: f64,
    pub(crate) bin0: u32,
    pub(crate) bin1: u32,
}

/// Reduced-precision form of a cone plan's bins arena: detector columns
/// and tier-encoded transaxial weights in parallel arrays (same order and
/// `bin0..bin1` indexing as the exact arena it replaced). 6 B/entry vs
/// the exact arena's 16 B/entry — the storage-tier win for cached plans.
#[derive(Clone, Debug)]
pub(crate) struct PackedBins {
    pub(crate) tier: StorageTier,
    pub(crate) cols: Vec<u32>,
    pub(crate) w: Vec<u16>,
}

/// Borrowed view of one voxel column's transaxial weights, decoding
/// tier-encoded entries to f64 on the fly. The decoded value equals the
/// round-tripped value `quantize_in_place` writes into an exact arena, so
/// the packed (cached-plan) and quantized-exact (scratch/direct) paths
/// emit identical coefficient streams.
#[derive(Clone, Copy)]
pub(crate) enum BinsView<'a> {
    Exact(&'a [(u32, f64)]),
    Packed { tier: StorageTier, cols: &'a [u32], w: &'a [u16] },
}

impl<'a> BinsView<'a> {
    #[inline]
    pub(crate) fn is_empty(&self) -> bool {
        match self {
            BinsView::Exact(s) => s.is_empty(),
            BinsView::Packed { cols, .. } => cols.is_empty(),
        }
    }

    /// Invoke `f(detector_col, transaxial_weight)` for every entry.
    #[inline]
    pub(crate) fn for_each<F: FnMut(usize, f64)>(&self, mut f: F) {
        match self {
            BinsView::Exact(s) => {
                for &(col, a_u) in *s {
                    f(col as usize, a_u);
                }
            }
            BinsView::Packed { tier, cols, w } => {
                for (col, bits) in cols.iter().zip(w.iter()) {
                    f(*col as usize, tier.decode_bits(*bits) as f64);
                }
            }
        }
    }
}

/// Per-view invariants of the cone-beam SF footprint — the plan step.
/// Caches, for every transaxial voxel column `(i, j)`, the projected
/// footprint's detector-column weights and the magnification/amplitude
/// scalars; the execute step only runs the axial overlap loop. Memory is
/// `O(nx·ny)` per view — the transaxial footprint only, a factor of
/// `nz × nrows` smaller than the stored system matrix the paper's Table 1
/// argues against. With a reduced-precision [`StorageTier`] the arena is
/// re-packed to u16 weights ([`ConeViewPlan::pack`]), decoded back to
/// f32/f64 registers inside the kernels.
#[derive(Clone, Debug)]
pub struct ConeViewPlan {
    pub(crate) foot: Vec<ConeVoxelFoot>,
    /// Arena of (detector column, transaxial weight) runs indexed by
    /// `foot[·].bin0..bin1`. Empty when `packed` carries the arena.
    pub(crate) bins: Vec<(u32, f64)>,
    /// Tier-encoded arena replacing `bins` on reduced-precision plans.
    pub(crate) packed: Option<PackedBins>,
}

impl ConeViewPlan {
    pub(crate) fn empty() -> ConeViewPlan {
        ConeViewPlan { foot: Vec::new(), bins: Vec::new(), packed: None }
    }

    /// Approximate heap footprint of this view's cache in bytes.
    pub(crate) fn approx_bytes(&self) -> usize {
        self.foot.len() * std::mem::size_of::<ConeVoxelFoot>()
            + self.bins.len() * std::mem::size_of::<(u32, f64)>()
            + self.packed.as_ref().map_or(0, |p| {
                p.cols.len() * std::mem::size_of::<u32>()
                    + p.w.len() * std::mem::size_of::<u16>()
            })
    }

    /// Borrow one voxel column's transaxial weights (exact or packed).
    #[inline]
    pub(crate) fn u_bins(&self, f: &ConeVoxelFoot) -> BinsView<'_> {
        let (b0, b1) = (f.bin0 as usize, f.bin1 as usize);
        match &self.packed {
            Some(p) => BinsView::Packed { tier: p.tier, cols: &p.cols[b0..b1], w: &p.w[b0..b1] },
            None => BinsView::Exact(&self.bins[b0..b1]),
        }
    }

    /// Re-encode the exact arena through `tier` into the packed form
    /// (cached reduced-precision plans). No-op on the f32 tier.
    pub(crate) fn pack(&mut self, tier: StorageTier) {
        if tier == StorageTier::F32 || self.packed.is_some() {
            return;
        }
        let mut cols = Vec::with_capacity(self.bins.len());
        let mut w = Vec::with_capacity(self.bins.len());
        for &(col, a_u) in &self.bins {
            cols.push(col);
            w.push(tier.encode_bits(a_u as f32));
        }
        self.bins = Vec::new();
        self.packed = Some(PackedBins { tier, cols, w });
    }

    /// Round-trip the exact arena's weights through `tier` in place,
    /// keeping the exact layout (per-worker scratch plans on the direct /
    /// uncached paths — no long-lived storage to shrink, but the decoded
    /// values must match the packed arena bit for bit).
    pub(crate) fn quantize_in_place(&mut self, tier: StorageTier) {
        if tier == StorageTier::F32 {
            return;
        }
        for e in &mut self.bins {
            e.1 = tier.quantize(e.1 as f32) as f64;
        }
    }
}

/// Build the per-view SF invariants for one cone-beam view. Allocates a
/// fresh, size-trimmed plan — the form [`crate::projector::ProjectionPlan`]
/// caches. The direct path reuses a per-worker scratch plan through
/// [`plan_cone_rows_into`] instead.
pub fn plan_cone_view(vg: &VolumeGeometry, g: &ConeBeam, view: usize) -> ConeViewPlan {
    let mut out = ConeViewPlan::empty();
    plan_cone_rows_into(vg, g, view, 0, vg.ny, &mut out);
    // cached plans live long: trim growth slack so resident bytes match
    // what approx_bytes() reports
    out.foot.shrink_to_fit();
    out.bins.shrink_to_fit();
    out
}

/// Plan the voxel-column footprints of rows `j0..j1` for one cone-beam
/// view into a reusable buffer: clears and refills `out` (foot indexed
/// `(j − j0)·nx + i`), keeping its capacity. The full-view form
/// (`j0 = 0, j1 = ny`) is what [`plan_cone_view`] caches; the slab-owned
/// backprojection replans single rows per worker, which costs exactly one
/// full planning pass per operator application in total — the same work
/// the per-view direct path always did, with no `O(nx·ny)` churn.
pub(crate) fn plan_cone_rows_into(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    view: usize,
    j0: usize,
    j1: usize,
    out: &mut ConeViewPlan,
) {
    let phi = g.angles[view];
    let (sphi, cphi) = phi.sin_cos();
    let src = [g.sod * cphi, g.sod * sphi, 0.0];
    let nhat = [-cphi, -sphi];
    let uhat = [-sphi, cphi];
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    let vol_v = vg.vx * vg.vy * vg.vz;
    let curved = g.shape == DetectorShape::Curved;
    out.foot.clear();
    out.foot.reserve((j1 - j0) * vg.nx);
    out.bins.clear();
    out.packed = None;
    let foot = &mut out.foot;
    let bins = &mut out.bins;

    for j in j0..j1 {
        let y = vg.y(j);
        for i in 0..vg.nx {
            let x = vg.x(i);
            // transaxial footprint from the 4 in-plane corners
            let mut pts = [0.0f64; 4];
            let mut n = 0;
            for (ddx, ddy) in [(-hx, -hy), (-hx, hy), (hx, -hy), (hx, hy)] {
                let px = x + ddx - src[0];
                let py = y + ddy - src[1];
                let t = px * nhat[0] + py * nhat[1];
                let u_perp = px * uhat[0] + py * uhat[1];
                pts[n] = if curved {
                    g.sdd * u_perp.atan2(t)
                } else {
                    g.sdd * u_perp / t
                };
                n += 1;
            }
            let utrap = Trap::new(pts);

            // center-of-voxel quantities for the axial footprint + amplitude
            let px = x - src[0];
            let py = y - src[1];
            let t_c = px * nhat[0] + py * nhat[1];
            let d_inplane = (px * px + py * py).sqrt();
            let b0 = bins.len() as u32;
            if t_c <= 0.0 {
                // behind the source: no coefficients for this column
                foot.push(ConeVoxelFoot { t_c, d_inplane, m_v: 0.0, amp_uv: 0.0, bin0: b0, bin1: b0 });
                continue;
            }
            // axial magnification: flat uses distance along the normal,
            // curved uses the in-plane distance to the cylinder
            let m_v = if curved { g.sdd / d_inplane } else { g.sdd / t_c };
            let m_u = if curved { g.sdd / d_inplane } else { g.sdd / t_c };
            for_bins(&utrap, g.ncols, g.du, g.cu, 1.0, |col, a_u| bins.push((col as u32, a_u)));
            let b1 = bins.len() as u32;
            foot.push(ConeVoxelFoot {
                t_c,
                d_inplane,
                m_v,
                amp_uv: vol_v * m_u * m_v,
                bin0: b0,
                bin1: b1,
            });
        }
    }
}

/// The axial execute loop for one planned voxel column: z-slices ×
/// detector-row rect overlaps, replaying the cached transaxial column
/// weights. One definition shared by the forward scatter, the back
/// gather and the public enumeration, so every path emits the identical
/// coefficient stream for a column.
///
/// `plane` is the stride between consecutive z-slices of the *output
/// indexing*: `vg.ny·vg.nx` for a full resident volume, or the window's
/// row-span ×`nx` when executing against a tiled y-slab window (the
/// emitted flat indices are then window-local). The stride only shifts
/// indices — never the float math — so windowed execution is
/// bit-identical to resident execution per voxel.
#[inline]
pub(crate) fn cone_column_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    f: &ConeVoxelFoot,
    u_bins: BinsView<'_>,
    plane: usize,
    flat_idx_base: usize,
    mut emit: F,
) {
    if f.t_c <= 0.0 {
        return; // behind the source
    }
    if u_bins.is_empty() {
        return;
    }
    let hz = vg.vz / 2.0;
    let curved = g.shape == DetectorShape::Curved;
    // detector-row grid for the rect axial footprint
    let v_lo_0 = -(g.nrows as f64 - 1.0) / 2.0 * g.dv + g.cv - g.dv / 2.0;
    let inv_dv = 1.0 / g.dv;
    for k in 0..vg.nz {
        let z = vg.z(k);
        // rect footprint [v0, v1]: closed-form bin overlaps
        let v0 = (z - hz) * f.m_v;
        let v1 = (z + hz) * f.m_v;
        let width = v1 - v0;
        if width <= 0.0 {
            continue;
        }
        let dist = (f.d_inplane * f.d_inplane + z * z).sqrt();
        let cos_psi = if curved { f.d_inplane / dist } else { f.t_c / dist };
        let amp = f.amp_uv / cos_psi;
        let flat = k * plane + flat_idx_base;

        let r_first_f = ((v0 - v_lo_0) * inv_dv).floor();
        let r_last_f = ((v1 - v_lo_0) * inv_dv).floor();
        if r_last_f < 0.0 || r_first_f >= g.nrows as f64 {
            continue;
        }
        let r_first = if r_first_f < 0.0 { 0 } else { r_first_f as usize };
        let r_last = (r_last_f.max(0.0) as usize).min(g.nrows - 1);
        let inv_width_dv = 1.0 / (width * g.dv);
        for row in r_first..=r_last {
            let bin_lo = v_lo_0 + row as f64 * g.dv;
            let overlap = (v1.min(bin_lo + g.dv) - v0.max(bin_lo)).max(0.0);
            if overlap <= 0.0 {
                continue;
            }
            // a_v = (1/dv)·∫ rect = overlap / (width·dv)
            let a_v = overlap * inv_width_dv * amp;
            u_bins.for_each(|col, a_u| emit(flat, row, col, a_u * a_v));
        }
    }
}

/// Enumerate SF coefficients for one cone-beam view from its (full-view)
/// plan — the execute step.
pub(crate) fn cone_view_coeffs_planned<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    vp: &ConeViewPlan,
    mut emit: F,
) {
    let plane = vg.ny * vg.nx;
    for j in 0..vg.ny {
        for i in 0..vg.nx {
            let flat_idx_base = j * vg.nx + i;
            let f = vp.foot[flat_idx_base];
            cone_column_coeffs(vg, g, &f, vp.u_bins(&f), plane, flat_idx_base, &mut emit);
        }
    }
}

/// Enumerate SF coefficients for one cone-beam view (plans on the fly).
fn cone_view_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    view: usize,
    emit: F,
) {
    let vp = plan_cone_view(vg, g, view);
    cone_view_coeffs_planned(vg, g, &vp, emit)
}

/// SF forward projection, cone beam (flat or curved detector).
pub fn forward_cone(vg: &VolumeGeometry, g: &ConeBeam, vol: &Vol3, sino: &mut Sino, threads: usize) {
    forward_cone_opt(vg, g, None, vol, sino, threads)
}

/// [`forward_cone`] with optional precomputed per-view plans. `None`
/// plans each view transiently inside the worker (peak extra memory is
/// one view's transaxial footprint per thread). Views are dynamically
/// scheduled: cone footprint sizes vary strongly with the view angle, so
/// an atomic cursor replaces static chunks to keep all workers busy.
pub(crate) fn forward_cone_opt(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    forward_cone_range(vg, g, plans, StorageTier::F32, vol, sino, threads, 0, g.angles.len())
}

/// [`forward_cone_opt`] restricted to the view range `v0..v1` (see
/// [`forward_parallel_range`] for the stitching contract). `tier`
/// round-trips on-the-fly scratch plans through the storage tier so the
/// uncached path emits the same quantized weights a packed cached plan
/// decodes (cached plans carry their tier in the arena itself).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_cone_range(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    tier: StorageTier,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    assert!(v0 <= v1 && v1 <= g.angles.len(), "view range {v0}..{v1}");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.data[v0 * nrows * ncols..v1 * nrows * ncols].fill(0.0);
    let out = ParWriter::new(&mut sino.data);
    // per-worker scratch: the direct path refills it per view instead of
    // churning an O(nx·ny) allocation per view
    parallel_items_with(v1 - v0, threads, ConeViewPlan::empty, |scratch, r| {
        // each view's sinogram slab is written by exactly one worker
        let view = v0 + r;
        let base = view * nrows * ncols;
        let vp: &ConeViewPlan = match plans {
            Some(ps) => &ps[view],
            None => {
                plan_cone_rows_into(vg, g, view, 0, vg.ny, scratch);
                scratch.quantize_in_place(tier);
                scratch
            }
        };
        cone_view_coeffs_planned(vg, g, vp, |flat, row, col, coeff| {
            out.add(base + row * ncols + col, (coeff as f32) * vol.data[flat]);
        });
    });
}

/// Matched SF backprojection, cone beam. Slab-owned gather: each worker
/// owns whole voxel rows (a `y`-row owns its full `x × z` column block),
/// accumulating over all views directly into the volume — no per-thread
/// partial volumes, no reduction, bit-identical for every thread count.
pub fn back_cone(vg: &VolumeGeometry, g: &ConeBeam, sino: &Sino, vol: &mut Vol3, threads: usize) {
    back_cone_opt(vg, g, None, sino, vol, threads)
}

/// [`back_cone`] with optional precomputed per-view plans. Voxel rows are
/// dynamically scheduled; the direct path replans one row per (row, view)
/// into per-worker scratch, which sums to exactly one full planning pass
/// per application — the same total planning work as the forward path.
pub(crate) fn back_cone_opt(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    back_cone_range(vg, g, plans, StorageTier::F32, sino, vol, threads, 0, vg.ny)
}

/// [`back_cone_opt`] restricted to the voxel-row range `u0..u1` (units
/// are `y`-rows owning their full `x × z` column blocks). A row `j` owns
/// the non-contiguous flat runs `k·ny·nx + j·nx .. +nx` for every slice
/// `k`, so zeroing walks per-(k, j) x-rows; the stitching contract is
/// that of [`back_parallel_range`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_cone_range(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    tier: StorageTier,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let ny = vg.ny;
    assert!(u0 <= u1 && u1 <= ny, "unit range {u0}..{u1}");
    let plane = ny * vg.nx;
    for k in 0..vg.nz {
        vol.data[k * plane + u0 * vg.nx..k * plane + u1 * vg.nx].fill(0.0);
    }
    if g.angles.is_empty() {
        return;
    }
    back_cone_gather(vg, g, plans, tier, sino, &mut vol.data, plane, 0, threads, u0, u1);
}

/// The cone gather core shared by the resident range executor and the
/// tiled window executor: accumulates rows `u0..u1` over all views into
/// `out`, where a voxel `(k, j, i)` lands at
/// `k·plane + (j − j_base)·nx + i`. Identical float chains for any
/// `(plane, j_base)` — only the output indexing moves.
#[allow(clippy::too_many_arguments)]
fn back_cone_gather(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    tier: StorageTier,
    sino: &Sino,
    out: &mut [f32],
    plane: usize,
    j_base: usize,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let nviews = g.angles.len();
    let ncols = sino.ncols;
    let out = ParWriter::new(out);
    // each voxel row j (flat indices k·plane + (j−j_base)·nx + i over all
    // k, i) is claimed and written by exactly one worker
    parallel_items_with(u1 - u0, threads, ConeViewPlan::empty, |scratch, r| {
        let j = u0 + r;
        for view in 0..nviews {
            let (vp, j_off): (&ConeViewPlan, usize) = match plans {
                Some(ps) => (&ps[view], 0),
                None => {
                    plan_cone_rows_into(vg, g, view, j, j + 1, scratch);
                    scratch.quantize_in_place(tier);
                    (scratch, j)
                }
            };
            let vdata = sino.view(view);
            for i in 0..vg.nx {
                let f = vp.foot[(j - j_off) * vg.nx + i];
                let base = (j - j_base) * vg.nx + i;
                cone_column_coeffs(vg, g, &f, vp.u_bins(&f), plane, base, |flat, row, col, coeff| {
                    out.add(flat, (coeff as f32) * vdata[row * ncols + col]);
                });
            }
        }
    });
}

// ---------------------------------------------------------------------------
// windowed executors — the per-tile kernels of out-of-core execution
// ---------------------------------------------------------------------------
//
// Each windowed form is the matching range executor with the output (back)
// or input (forward) volume replaced by a *window slice* holding only the
// unit range `u0..u1`: parallel/fan windows are the contiguous flat run
// `[u0·nx, u1·nx)`, cone windows are the y-slab `nz × (u1−u0) × nx` in
// k-major order. Only index arithmetic changes — every float chain is the
// one the resident executor runs — so gathering tiles in ascending unit
// order reproduces resident execution bit for bit (the forward
// accumulators ADD into the sinogram, whose per-bin `+=` chain then
// concatenates across tiles exactly as the resident enumeration does; the
// caller zeroes the sinogram once before the first tile).

/// [`back_parallel_range`] writing into a window slice of rows `u0..u1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_parallel_window(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    sino: &Sino,
    out: &mut [f32],
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert!(u0 <= u1 && u1 <= vg.nz * vg.ny, "unit range {u0}..{u1}");
    assert_eq!(out.len(), (u1 - u0) * vg.nx, "window length");
    out.fill(0.0);
    let local_set;
    let set: &ParallelPlanSet = match plans {
        Some(s) => s,
        None => {
            local_set = plan_parallel_set(vg, g);
            &local_set
        }
    };
    let base_flat = u0 * vg.nx;
    let ncols = sino.ncols;
    let out = ParWriter::new(out);
    parallel_chunks(u1 - u0, threads, |a, b| {
        let (m0, m1) = (u0 + a, u0 + b);
        for (view, vp) in set.views.iter().enumerate() {
            let vdata = sino.view(view);
            parallel_rows_coeffs(vg, g, vp, &set.rows, m0, m1, |flat, row, col, coeff| {
                out.add(flat - base_flat, (coeff as f32) * vdata[row * ncols + col]);
            });
        }
    });
}

/// [`back_fan_range`] writing into a window slice of rows `u0..u1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_fan_window(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    sino: &Sino,
    out: &mut [f32],
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert_eq!(vg.nz, 1);
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    assert_eq!(out.len(), (u1 - u0) * vg.nx, "window length");
    out.fill(0.0);
    let local;
    let views: &[FanViewPlan] = match plans {
        Some(ps) => ps,
        None => {
            local = (0..g.angles.len()).map(|v| plan_fan_view(g, v)).collect::<Vec<_>>();
            &local
        }
    };
    let base_flat = u0 * vg.nx;
    let out = ParWriter::new(out);
    parallel_chunks(u1 - u0, threads, |a, b| {
        let (j0, j1) = (u0 + a, u0 + b);
        for (view, vp) in views.iter().enumerate() {
            let vdata = sino.view(view);
            fan_rows_coeffs(vg, g, vp, j0, j1, |flat, col, coeff| {
                out.add(flat - base_flat, (coeff as f32) * vdata[col]);
            });
        }
    });
}

/// [`back_cone_range`] writing into a y-slab window (`nz × (u1−u0) × nx`,
/// k-major) instead of the full volume.
#[allow(clippy::too_many_arguments)]
pub(crate) fn back_cone_window(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    tier: StorageTier,
    sino: &Sino,
    out: &mut [f32],
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    assert_eq!(out.len(), vg.nz * (u1 - u0) * vg.nx, "window length");
    out.fill(0.0);
    if g.angles.is_empty() {
        return;
    }
    back_cone_gather(vg, g, plans, tier, sino, out, (u1 - u0) * vg.nx, u0, threads, u0, u1);
}

/// Add rows `u0..u1`'s forward contribution (read from a window slice)
/// into `sino` — no zeroing; the tiled driver zeroes once, then streams
/// tiles in ascending unit order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_parallel_accum_window(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    plans: Option<&ParallelPlanSet>,
    win: &[f32],
    sino: &mut Sino,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert!(u0 <= u1 && u1 <= vg.nz * vg.ny, "unit range {u0}..{u1}");
    assert_eq!(win.len(), (u1 - u0) * vg.nx, "window length");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    let local_rows;
    let rows: &ParallelRowWeights = match plans {
        Some(set) => &set.rows,
        None => {
            local_rows = plan_parallel_rows(vg, g);
            &local_rows
        }
    };
    let base_flat = u0 * vg.nx;
    let out = ParWriter::new(&mut sino.data);
    parallel_items(g.angles.len(), threads, |view| {
        let base = view * nrows * ncols;
        let local;
        let vp = match plans {
            Some(set) => &set.views[view],
            None => {
                local = plan_parallel_view(vg, g, view);
                &local
            }
        };
        parallel_rows_coeffs(vg, g, vp, rows, u0, u1, |flat, row, col, coeff| {
            out.add(base + row * ncols + col, (coeff as f32) * win[flat - base_flat]);
        });
    });
}

/// Fan-beam forward tile accumulator (see
/// [`forward_parallel_accum_window`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_fan_accum_window(
    vg: &VolumeGeometry,
    g: &FanBeam,
    plans: Option<&[FanViewPlan]>,
    win: &[f32],
    sino: &mut Sino,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert_eq!(vg.nz, 1, "fan-beam SF requires a 2-D volume");
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    assert_eq!(win.len(), (u1 - u0) * vg.nx, "window length");
    let ncols = sino.ncols;
    let base_flat = u0 * vg.nx;
    let out = ParWriter::new(&mut sino.data);
    parallel_items(g.angles.len(), threads, |view| {
        let base = view * ncols;
        let vp = match plans {
            Some(ps) => ps[view],
            None => plan_fan_view(g, view),
        };
        fan_rows_coeffs(vg, g, &vp, u0, u1, |flat, col, coeff| {
            out.add(base + col, (coeff as f32) * win[flat - base_flat]);
        });
    });
}

/// Cone-beam forward tile accumulator over the y-slab window `u0..u1`
/// (see [`forward_parallel_accum_window`]; window layout as in
/// [`back_cone_window`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_cone_accum_window(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    plans: Option<&[ConeViewPlan]>,
    tier: StorageTier,
    win: &[f32],
    sino: &mut Sino,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    assert!(u0 <= u1 && u1 <= vg.ny, "unit range {u0}..{u1}");
    assert_eq!(win.len(), vg.nz * (u1 - u0) * vg.nx, "window length");
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    let wplane = (u1 - u0) * vg.nx;
    let out = ParWriter::new(&mut sino.data);
    parallel_items_with(g.angles.len(), threads, ConeViewPlan::empty, |scratch, view| {
        let base = view * nrows * ncols;
        let (vp, j_off): (&ConeViewPlan, usize) = match plans {
            Some(ps) => (&ps[view], 0),
            None => {
                plan_cone_rows_into(vg, g, view, u0, u1, scratch);
                scratch.quantize_in_place(tier);
                (scratch, u0)
            }
        };
        for j in u0..u1 {
            for i in 0..vg.nx {
                let f = vp.foot[(j - j_off) * vg.nx + i];
                let wbase = (j - u0) * vg.nx + i;
                cone_column_coeffs(vg, g, &f, vp.u_bins(&f), wplane, wbase, |flat, row, col, coeff| {
                    out.add(base + row * ncols + col, (coeff as f32) * win[flat]);
                });
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::angles_deg;

    #[test]
    fn trap_unit_area() {
        let t = Trap::new([1.0, 0.0, 3.0, 2.0]); // sorted: 0,1,2,3
        assert!((t.cdf(10.0) - 1.0).abs() < 1e-12);
        assert!((t.integral(0.0, 3.0) - 1.0).abs() < 1e-12);
        // symmetric halves
        assert!((t.cdf(1.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trap_rect_case() {
        let t = Trap::new([-1.0, -1.0, 1.0, 1.0]);
        assert!((t.h - 0.5).abs() < 1e-12);
        assert!((t.integral(-1.0, 0.0) - 0.5).abs() < 1e-12);
        assert!((t.integral(-2.0, -1.0)).abs() < 1e-12);
    }

    #[test]
    fn trap_degenerate_point() {
        let t = Trap::new([2.0, 2.0, 2.0, 2.0]);
        assert!(t.is_degenerate());
    }

    #[test]
    fn for_bins_mass_conserved() {
        // trapezoid fully inside the detector: Σ coeff = amp / pitch
        let t = Trap::new([-0.8, -0.3, 0.4, 0.9]);
        let mut total = 0.0;
        for_bins(&t, 64, 0.5, 0.0, 3.0, |_, a| total += a);
        assert!((total - 3.0 / 0.5).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn parallel_2d_projection_of_uniform_disk() {
        // uniform disk: central ray integral ≈ 2·r·μ
        let vg = VolumeGeometry::slice2d(64, 64, 1.0);
        let ph = crate::phantom::Phantom::new(vec![crate::phantom::Shape::ellipse2d(
            0.0, 0.0, 20.0, 20.0, 0.0, 0.01,
        )]);
        let vol = ph.rasterize(&vg, 2);
        let g = ParallelBeam::standard_2d(12, 65, 1.0);
        let mut sino = Sino::zeros2d(12, 65);
        forward_parallel(&vg, &g, &vol, &mut sino, 1);
        for view in 0..12 {
            let center = sino.at(view, 0, 32);
            assert!((center - 0.4).abs() < 0.01, "view {view}: {center}");
        }
    }

    #[test]
    fn parallel_mass_conservation_any_angle() {
        // Σ_bins A·x · du = Σ_vox x · vx·vy (total mass is angle-invariant)
        let vg = VolumeGeometry::slice2d(32, 32, 0.7);
        let mut vol = Vol3::zeros2d(32, 32);
        let mut rng = crate::util::rng::Rng::new(9);
        rng.fill_uniform(&mut vol.data, 0.0, 1.0);
        // zero the borders so no footprint mass falls off the detector
        let g = ParallelBeam { nrows: 1, ncols: 96, du: 0.5, dv: 0.5, cu: 0.0, cv: 0.0, angles: angles_deg(7, 0.0, 180.0) };
        let mut sino = Sino::zeros2d(7, 96);
        forward_parallel(&vg, &g, &vol, &mut sino, 1);
        let mass: f64 = vol.sum() * 0.7 * 0.7;
        for view in 0..7 {
            let m: f64 = sino.view(view).iter().map(|&v| v as f64 * 0.5).sum();
            assert!((m - mass).abs() / mass < 1e-6, "view {view}: {m} vs {mass}");
        }
    }

    #[test]
    fn fan_matches_parallel_at_large_sod() {
        // fan with sod → ∞ converges to parallel
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let ph = crate::phantom::shepp::shepp_logan_2d(14.0, 0.02);
        let vol = ph.rasterize(&vg, 2);

        let angles = angles_deg(4, 0.0, 180.0);
        let par = ParallelBeam { nrows: 1, ncols: 48, du: 1.0, dv: 1.0, cu: 0.0, cv: 0.0, angles: angles.clone() };
        // fan view φ looks along −(cos φ, sin φ); parallel view φ along
        // (−sin φ, cos φ): fan angle φ−90° aligns both the view direction
        // and the detector-u orientation. Same effective du at isocenter:
        // du_fan / mag = 1.0.
        let fan_angles: Vec<f64> =
            angles.iter().map(|a| a - std::f64::consts::FRAC_PI_2).collect();
        let fan = FanBeam { ncols: 48, du: 10.0, cu: 0.0, sod: 50_000.0, sdd: 500_000.0, angles: fan_angles };

        let mut s_par = Sino::zeros2d(4, 48);
        let mut s_fan = Sino::zeros2d(4, 48);
        forward_parallel(&vg, &par, &vol, &mut s_par, 1);
        forward_fan(&vg, &fan, &vol, &mut s_fan, 1);
        let err = crate::util::rel_l2(&s_fan.data, &s_par.data, 1e-12);
        assert!(err < 2e-3, "rel err {err}");
    }

    #[test]
    fn cone_center_row_matches_fan() {
        // the central detector row of a cone scan equals the fan scan of
        // the central slice (for a z-uniform... use single-slice volume at z=0)
        let vg = VolumeGeometry { nx: 24, ny: 24, nz: 1, vx: 1.0, vy: 1.0, vz: 1.0, cx: 0.0, cy: 0.0, cz: 0.0 };
        let mut vol = Vol3::zeros(24, 24, 1);
        let mut rng = crate::util::rng::Rng::new(4);
        rng.fill_uniform(&mut vol.data, 0.0, 0.05);

        let angles = angles_deg(5, 0.0, 360.0);
        let fan = FanBeam { ncols: 40, du: 1.0, cu: 0.0, sod: 100.0, sdd: 200.0, angles: angles.clone() };
        let cone = ConeBeam {
            nrows: 3,
            ncols: 40,
            du: 1.0,
            dv: 1.0,
            cu: 0.0,
            cv: 0.0,
            sod: 100.0,
            sdd: 200.0,
            angles,
            shape: DetectorShape::Flat,
        };
        let mut s_fan = Sino::zeros2d(5, 40);
        let mut s_cone = Sino::zeros(5, 3, 40);
        forward_fan(&vg, &fan, &vol, &mut s_fan, 1);
        forward_cone(&vg, &cone, &vol, &mut s_cone, 1);
        // Every voxel's axial footprint (width m_v·vz ∈ [1.8, 2.3] mm here)
        // fully covers the central row's 1 mm bin, so the central-row cone
        // coefficient reduces exactly to the fan coefficient.
        for view in 0..5 {
            for col in 5..35 {
                let f = s_fan.at(view, 0, col);
                let c = s_cone.at(view, 1, col);
                assert!(
                    (c - f).abs() <= 0.02 * f.abs().max(0.01),
                    "view {view} col {col}: cone {c} fan {f}"
                );
            }
        }
    }

    #[test]
    fn planned_views_match_on_the_fly_enumeration() {
        // the plan step must cache exactly what the direct path computes:
        // identical (flat, row, col, coeff) streams for every geometry
        let vg3 = VolumeGeometry::cube(10, 1.1);
        let cone = ConeBeam::standard(5, 8, 12, 1.3, 1.2, 40.0, 90.0);
        for view in 0..5 {
            let vp = plan_cone_view(&vg3, &cone, view);
            let mut direct = Vec::new();
            let mut planned = Vec::new();
            cone_view_coeffs(&vg3, &cone, view, |a, b, c, d| direct.push((a, b, c, d)));
            cone_view_coeffs_planned(&vg3, &cone, &vp, |a, b, c, d| planned.push((a, b, c, d)));
            assert_eq!(direct, planned, "cone view {view}");
        }

        let vg = VolumeGeometry::slice2d(12, 12, 0.9);
        let par = ParallelBeam::standard_2d(6, 20, 1.0);
        let rows = plan_parallel_rows(&vg, &par);
        for view in 0..6 {
            let vp = plan_parallel_view(&vg, &par, view);
            let mut direct = Vec::new();
            let mut planned = Vec::new();
            parallel_view_coeffs(&vg, &par, view, |a, b, c, d| direct.push((a, b, c, d)));
            parallel_view_coeffs_planned(&vg, &par, &vp, &rows, |a, b, c, d| {
                planned.push((a, b, c, d))
            });
            assert_eq!(direct, planned, "parallel view {view}");
        }

        let fan = FanBeam::standard(6, 18, 1.4, 60.0, 120.0);
        for view in 0..6 {
            let vp = plan_fan_view(&fan, view);
            let mut direct = Vec::new();
            let mut planned = Vec::new();
            fan_view_coeffs(&vg, &fan, view, |a, b, c| direct.push((a, b, c)));
            fan_rows_coeffs(&vg, &fan, &vp, 0, vg.ny, |a, b, c| planned.push((a, b, c)));
            assert_eq!(direct, planned, "fan view {view}");
        }
    }

    #[test]
    fn row_restricted_enumeration_is_float_identical() {
        // the slab-owned gather relies on this: enumerating a row range
        // must emit exactly the full enumeration's coefficients for those
        // rows, bit for bit
        let vg = VolumeGeometry { nx: 9, ny: 7, nz: 4, vx: 1.1, vy: 0.9, vz: 1.3, cx: 0.4, cy: -0.2, cz: 0.1 };
        let par = ParallelBeam::standard_3d(5, 6, 14, 1.2, 1.1);
        let rows = plan_parallel_rows(&vg, &par);
        for view in 0..5 {
            let vp = plan_parallel_view(&vg, &par, view);
            let mut full: Vec<(usize, usize, usize, u64)> = Vec::new();
            parallel_view_coeffs_planned(&vg, &par, &vp, &rows, |a, b, c, d| {
                full.push((a, b, c, d.to_bits()))
            });
            let mut stitched = Vec::new();
            let nunits = vg.nz * vg.ny;
            for m in 0..nunits {
                parallel_rows_coeffs(&vg, &par, &vp, &rows, m, m + 1, |a, b, c, d| {
                    stitched.push((a, b, c, d.to_bits()))
                });
            }
            assert_eq!(full, stitched, "parallel view {view}");
        }

        let vg2 = VolumeGeometry::slice2d(11, 8, 0.8);
        let fan = FanBeam::standard(4, 16, 1.1, 45.0, 95.0);
        for view in 0..4 {
            let vp = plan_fan_view(&fan, view);
            let mut full: Vec<(usize, usize, u64)> = Vec::new();
            fan_rows_coeffs(&vg2, &fan, &vp, 0, vg2.ny, |a, b, c| full.push((a, b, c.to_bits())));
            let mut stitched = Vec::new();
            for j in 0..vg2.ny {
                fan_rows_coeffs(&vg2, &fan, &vp, j, j + 1, |a, b, c| {
                    stitched.push((a, b, c.to_bits()))
                });
            }
            assert_eq!(full, stitched, "fan view {view}");
        }

        // cone: single-row scratch planning must reproduce the full plan's
        // column footprints exactly
        let vg3 = VolumeGeometry::cube(8, 1.2);
        let cone = ConeBeam::standard(4, 6, 10, 1.4, 1.3, 42.0, 88.0);
        let mut scratch = ConeViewPlan::empty();
        for view in 0..4 {
            let full = plan_cone_view(&vg3, &cone, view);
            for j in 0..vg3.ny {
                plan_cone_rows_into(&vg3, &cone, view, j, j + 1, &mut scratch);
                for i in 0..vg3.nx {
                    let a = full.foot[j * vg3.nx + i];
                    let b = scratch.foot[i];
                    assert_eq!(a.t_c.to_bits(), b.t_c.to_bits());
                    assert_eq!(a.m_v.to_bits(), b.m_v.to_bits());
                    assert_eq!(a.amp_uv.to_bits(), b.amp_uv.to_bits());
                    let ab = &full.bins[a.bin0 as usize..a.bin1 as usize];
                    let bb = &scratch.bins[b.bin0 as usize..b.bin1 as usize];
                    assert_eq!(ab.len(), bb.len());
                    for (x, y) in ab.iter().zip(bb.iter()) {
                        assert_eq!(x.0, y.0);
                        assert_eq!(x.1.to_bits(), y.1.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn back_gather_matches_serial_scatter_reference() {
        // the slab-owned gather must equal a serial view-by-view scatter
        // of the same coefficients bit for bit (per voxel both accumulate
        // in (view, enumeration) order)
        let mut rng = crate::util::rng::Rng::new(21);

        let vg = VolumeGeometry { nx: 10, ny: 9, nz: 3, vx: 1.0, vy: 1.1, vz: 0.9, cx: 0.0, cy: 0.0, cz: 0.0 };
        let par = ParallelBeam::standard_3d(6, 4, 15, 1.2, 1.2);
        let mut sino = Sino::zeros(6, 4, 15);
        rng.fill_uniform(&mut sino.data, -1.0, 1.0);
        let mut reference = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        for view in 0..6 {
            let vdata: Vec<f32> = sino.view(view).to_vec();
            parallel_view_coeffs(&vg, &par, view, |flat, row, col, coeff| {
                reference.data[flat] += (coeff as f32) * vdata[row * 15 + col];
            });
        }
        for threads in [1usize, 2, 5] {
            let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
            back_parallel(&vg, &par, &sino, &mut vol, threads);
            assert_eq!(reference.data, vol.data, "parallel threads {threads}");
        }

        let vg2 = VolumeGeometry::slice2d(12, 10, 1.0);
        let fan = FanBeam::standard(5, 16, 1.2, 55.0, 110.0);
        let mut sino2 = Sino::zeros2d(5, 16);
        rng.fill_uniform(&mut sino2.data, -1.0, 1.0);
        let mut ref2 = Vol3::zeros2d(12, 10);
        for view in 0..5 {
            let vdata: Vec<f32> = sino2.view(view).to_vec();
            fan_view_coeffs(&vg2, &fan, view, |flat, col, coeff| {
                ref2.data[flat] += (coeff as f32) * vdata[col];
            });
        }
        for threads in [1usize, 3, 4] {
            let mut vol = Vol3::zeros2d(12, 10);
            back_fan(&vg2, &fan, &sino2, &mut vol, threads);
            assert_eq!(ref2.data, vol.data, "fan threads {threads}");
        }

        let vg3 = VolumeGeometry::cube(8, 1.0);
        let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let mut sino3 = Sino::zeros(5, 6, 10);
        rng.fill_uniform(&mut sino3.data, -1.0, 1.0);
        let mut ref3 = Vol3::zeros(8, 8, 8);
        for view in 0..5 {
            let vdata: Vec<f32> = sino3.view(view).to_vec();
            cone_view_coeffs(&vg3, &cone, view, |flat, row, col, coeff| {
                ref3.data[flat] += (coeff as f32) * vdata[row * 10 + col];
            });
        }
        for threads in [1usize, 2, 4] {
            let mut vol = Vol3::zeros(8, 8, 8);
            back_cone(&vg3, &cone, &sino3, &mut vol, threads);
            for idx in 0..ref3.len() {
                assert_eq!(
                    ref3.data[idx].to_bits(),
                    vol.data[idx].to_bits(),
                    "cone threads {threads} idx {idx}"
                );
            }
        }
    }

    /// Split `0..n` into `parts` contiguous ranges covering every index.
    fn split(n: usize, parts: usize) -> Vec<(usize, usize)> {
        let parts = parts.clamp(1, n.max(1));
        (0..parts)
            .map(|s| (s * n / parts, (s + 1) * n / parts))
            .collect()
    }

    #[test]
    fn range_executors_stitch_to_the_full_output_bit_for_bit() {
        // the view-sharded serving plane relies on this: executing any
        // partition of view ranges (forward) or unit ranges (back) into
        // one buffer must reproduce the unrestricted executor exactly.
        // Buffers start poisoned so each range's own zeroing is proven.
        let mut rng = crate::util::rng::Rng::new(33);

        let vg = VolumeGeometry { nx: 9, ny: 7, nz: 3, vx: 1.0, vy: 1.1, vz: 0.9, cx: 0.2, cy: -0.1, cz: 0.0 };
        let par = ParallelBeam::standard_3d(7, 4, 13, 1.2, 1.1);
        let mut vol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        rng.fill_uniform(&mut vol.data, 0.0, 1.0);
        let mut full = Sino::zeros(7, 4, 13);
        forward_parallel(&vg, &par, &vol, &mut full, 2);
        let mut sino = Sino::zeros(7, 4, 13);
        let mut back_full = Vol3::zeros(vg.nx, vg.ny, vg.nz);
        rng.fill_uniform(&mut sino.data, -1.0, 1.0);
        back_parallel(&vg, &par, &sino, &mut back_full, 2);
        for shards in [1usize, 2, 3, 5] {
            let mut stitched = Sino::zeros(7, 4, 13);
            stitched.fill(7.0);
            for (v0, v1) in split(7, shards) {
                forward_parallel_range(&vg, &par, None, &vol, &mut stitched, 2, v0, v1);
            }
            assert_eq!(full.data, stitched.data, "parallel fwd {shards} shards");
            let mut bvol = Vol3::zeros(vg.nx, vg.ny, vg.nz);
            bvol.fill(7.0);
            for (u0, u1) in split(vg.nz * vg.ny, shards) {
                back_parallel_range(&vg, &par, None, &sino, &mut bvol, 2, u0, u1);
            }
            assert_eq!(back_full.data, bvol.data, "parallel back {shards} shards");
        }

        let vg2 = VolumeGeometry::slice2d(11, 8, 0.8);
        let fan = FanBeam::standard(6, 16, 1.1, 45.0, 95.0);
        let mut vol2 = Vol3::zeros2d(11, 8);
        rng.fill_uniform(&mut vol2.data, 0.0, 1.0);
        let mut full2 = Sino::zeros2d(6, 16);
        forward_fan(&vg2, &fan, &vol2, &mut full2, 2);
        let mut sino2 = Sino::zeros2d(6, 16);
        rng.fill_uniform(&mut sino2.data, -1.0, 1.0);
        let mut back_full2 = Vol3::zeros2d(11, 8);
        back_fan(&vg2, &fan, &sino2, &mut back_full2, 2);
        for shards in [2usize, 3] {
            let mut stitched = Sino::zeros2d(6, 16);
            stitched.fill(7.0);
            for (v0, v1) in split(6, shards) {
                forward_fan_range(&vg2, &fan, None, &vol2, &mut stitched, 2, v0, v1);
            }
            assert_eq!(full2.data, stitched.data, "fan fwd {shards} shards");
            let mut bvol = Vol3::zeros2d(11, 8);
            bvol.fill(7.0);
            for (u0, u1) in split(vg2.ny, shards) {
                back_fan_range(&vg2, &fan, None, &sino2, &mut bvol, 2, u0, u1);
            }
            assert_eq!(back_full2.data, bvol.data, "fan back {shards} shards");
        }

        let vg3 = VolumeGeometry::cube(8, 1.0);
        let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let mut vol3 = Vol3::zeros(8, 8, 8);
        rng.fill_uniform(&mut vol3.data, 0.0, 1.0);
        let mut full3 = Sino::zeros(5, 6, 10);
        forward_cone(&vg3, &cone, &vol3, &mut full3, 2);
        let mut sino3 = Sino::zeros(5, 6, 10);
        rng.fill_uniform(&mut sino3.data, -1.0, 1.0);
        let mut back_full3 = Vol3::zeros(8, 8, 8);
        back_cone(&vg3, &cone, &sino3, &mut back_full3, 2);
        for shards in [2usize, 3] {
            let mut stitched = Sino::zeros(5, 6, 10);
            stitched.fill(7.0);
            for (v0, v1) in split(5, shards) {
                forward_cone_range(&vg3, &cone, None, StorageTier::F32, &vol3, &mut stitched, 2, v0, v1);
            }
            assert_eq!(full3.data, stitched.data, "cone fwd {shards} shards");
            let mut bvol = Vol3::zeros(8, 8, 8);
            bvol.fill(7.0);
            for (u0, u1) in split(vg3.ny, shards) {
                back_cone_range(&vg3, &cone, None, StorageTier::F32, &sino3, &mut bvol, 2, u0, u1);
            }
            assert_eq!(back_full3.data, bvol.data, "cone back {shards} shards");
        }
    }
}
