//! Separable-Footprint projector (Long, Fessler & Balter 2010).
//!
//! Each voxel's detector footprint is approximated as a separable product
//! of 1-D trapezoids: the transaxial trapezoid comes from projecting the
//! four in-plane voxel corners, the axial one from projecting the voxel's
//! z-extent. Detector coefficients are exact bin integrals of the
//! trapezoid (not point samples), which models the finite voxel *and*
//! detector pixel width — the accuracy advantage over Siddon/Joseph the
//! paper cites (§2.1).
//!
//! Quantitative normalization: with `T` a unit-area trapezoid, the
//! coefficient of voxel `p` for bin `(r, c)` is
//!
//! ```text
//!   A = amp(p) · (1/du)∫_bin_c T_u · (1/dv)∫_bin_r T_v
//!   amp = V · m_u · m_v / cos ψ
//! ```
//!
//! where `V` is the voxel volume, `m_u`, `m_v` the local magnifications
//! and `ψ` the ray-to-detector-normal angle (all 1 for parallel beam).
//! This conserves mass — `Σ_bins A = V·m_u·m_v/(du·dv·cos ψ)` — so values
//! scale correctly under voxel/detector size changes (paper: "all
//! numerical values scale appropriately").
//!
//! Both forward (scatter) and back (gather) projection enumerate the same
//! voxel→bin coefficients, so the pair is exactly matched.

use crate::array::{Sino, Vol3};
use crate::geometry::{ConeBeam, DetectorShape, FanBeam, ParallelBeam, VolumeGeometry};
use crate::util::pool::{self, parallel_chunks};

/// A trapezoid bump with unit area, described by four sorted breakpoints:
/// linear rise `b0→b1`, flat `b1→b2`, linear fall `b2→b3`.
#[derive(Clone, Copy, Debug)]
pub struct Trap {
    pub b: [f64; 4],
    pub h: f64,
}

impl Trap {
    /// Build from four (unsorted) projected corner coordinates.
    pub fn new(mut pts: [f64; 4]) -> Trap {
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let denom = (pts[3] + pts[2] - pts[1] - pts[0]) / 2.0;
        let h = if denom > 1e-12 { 1.0 / denom } else { 0.0 };
        Trap { b: pts, h }
    }

    /// Is this a degenerate (near-zero-width) footprint?
    pub fn is_degenerate(&self) -> bool {
        self.h == 0.0
    }

    /// ∫_{-∞}^{x} of the trapezoid (CDF; reaches 1 at `b3`).
    pub fn cdf(&self, x: f64) -> f64 {
        let [b0, b1, b2, b3] = self.b;
        if x <= b0 {
            0.0
        } else if x < b1 {
            let d = x - b0;
            self.h * d * d / (2.0 * (b1 - b0))
        } else if x < b2 {
            self.h * ((b1 - b0) / 2.0 + (x - b1))
        } else if x < b3 {
            let d = b3 - x;
            1.0 - self.h * d * d / (2.0 * (b3 - b2))
        } else {
            1.0
        }
    }

    /// ∫_{x0}^{x1} of the trapezoid.
    #[inline]
    pub fn integral(&self, x0: f64, x1: f64) -> f64 {
        self.cdf(x1) - self.cdf(x0)
    }
}

/// Accumulate `amp · (1/pitch)·∫_bin T` over all detector bins overlapped
/// by `trap`, calling `emit(bin_index, coefficient)`.
#[inline]
fn for_bins<F: FnMut(usize, f64)>(
    trap: &Trap,
    n: usize,
    pitch: f64,
    center_off: f64,
    amp: f64,
    mut emit: F,
) {
    // bin c spans [u_lo(c), u_lo(c)+pitch] with u_lo(c) = (c − (n−1)/2)·pitch + off − pitch/2
    let half = (n as f64 - 1.0) / 2.0;
    let u_lo_0 = -half * pitch + center_off - pitch / 2.0;
    if trap.is_degenerate() {
        // point mass: deposit everything in the containing bin
        let u = trap.b[0];
        let c = ((u - u_lo_0) / pitch).floor();
        if c >= 0.0 && (c as usize) < n {
            emit(c as usize, amp / pitch);
        }
        return;
    }
    let c_first = (((trap.b[0] - u_lo_0) / pitch).floor()).max(0.0) as usize;
    let c_last = (((trap.b[3] - u_lo_0) / pitch).ceil() as i64).min(n as i64 - 1);
    if c_last < 0 {
        return;
    }
    for c in c_first..=(c_last as usize) {
        let lo = u_lo_0 + c as f64 * pitch;
        let w = trap.integral(lo, lo + pitch);
        if w > 0.0 {
            emit(c, amp * w / pitch);
        }
    }
}

// ---------------------------------------------------------------------------
// parallel beam (2-D and 3-D; rows decouple because rays are horizontal)
// ---------------------------------------------------------------------------

/// Per-view specialized evaluator for a *fixed-shape* trapezoid centered
/// at a moving position — the SF parallel hot loop. Precomputes the ramp
/// reciprocals so the CDF is division-free, and bin integrals share the
/// CDF value at adjacent bin edges (perf pass: EXPERIMENTS.md §Perf).
struct TrapEval {
    b: [f64; 4],
    h: f64,
    half_inv_rise: f64,
    half_inv_fall: f64,
    flat_base: f64,
}

impl TrapEval {
    fn new(shape: &Trap) -> TrapEval {
        let [b0, b1, b2, b3] = shape.b;
        let h = shape.h;
        TrapEval {
            b: shape.b,
            h,
            half_inv_rise: if b1 > b0 { h / (2.0 * (b1 - b0)) } else { 0.0 },
            half_inv_fall: if b3 > b2 { h / (2.0 * (b3 - b2)) } else { 0.0 },
            flat_base: h * (b1 - b0) / 2.0,
        }
    }

    /// CDF at `x` relative to the trapezoid center.
    #[inline]
    fn cdf(&self, x: f64) -> f64 {
        let [b0, b1, b2, b3] = self.b;
        if x <= b0 {
            0.0
        } else if x < b1 {
            let d = x - b0;
            d * d * self.half_inv_rise
        } else if x < b2 {
            self.flat_base + self.h * (x - b1)
        } else if x < b3 {
            let d = b3 - x;
            1.0 - d * d * self.half_inv_fall
        } else {
            1.0
        }
    }
}

/// Enumerate SF coefficients of every voxel for view `view` of a
/// parallel-beam geometry, invoking `emit(voxel_flat, row, col, coeff)`.
fn parallel_view_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    view: usize,
    mut emit: F,
) {
    let phi = g.angles[view];
    let (s, c) = phi.sin_cos();
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    // transaxial trapezoid shape is identical for every voxel at this view
    let dx = hx * c;
    let dy = hy * s;
    let shape = Trap::new([-dx - dy, -dx + dy, dx - dy, dx + dy]);
    let eval = TrapEval::new(&shape);
    let degenerate = shape.is_degenerate();
    let amp_t = vg.vx * vg.vy; // 2-D area; z handled separately

    // detector bin grid
    let ncols = g.ncols;
    let half_det = (ncols as f64 - 1.0) / 2.0;
    let u_lo_0 = -half_det * g.du - g.du / 2.0 + g.cu;
    let inv_du = 1.0 / g.du;

    // axial footprint: rays are horizontal, so the voxel z-extent maps to
    // v directly (rect of width vz). Its per-row weights depend only on k
    // — hoisted out of the (j, i) loops (perf pass).
    let pure_2d = vg.nz == 1 && g.nrows == 1;
    let hz = vg.vz / 2.0;
    let mut row_weights: Vec<Vec<(usize, f64)>> = Vec::new();
    if !pure_2d {
        row_weights.reserve(vg.nz);
        for k in 0..vg.nz {
            let zc = vg.z(k);
            let vtrap = Trap::new([zc - hz, zc - hz, zc + hz, zc + hz]);
            let mut rows = Vec::new();
            for_bins(&vtrap, g.nrows, g.dv, g.cv, 1.0, |row, a_v| rows.push((row, a_v)));
            row_weights.push(rows);
        }
    }

    // fold scales so the innermost math is one multiply per coefficient
    let amp_u = amp_t * vg.vz * inv_du;
    let amp_2d = amp_t * inv_du;

    let duc = vg.vx * c; // uc increment per i (can be negative)
    for k in 0..vg.nz {
        let rows: &[(usize, f64)] = if pure_2d { &[] } else { &row_weights[k] };
        for j in 0..vg.ny {
            let y = vg.y(j);
            let mut uc = vg.x(0) * c + y * s;
            let mut flat = (k * vg.ny + j) * vg.nx;
            for _i in 0..vg.nx {
                if degenerate {
                    // zero-width footprint: all mass into the containing bin
                    let cbin = ((uc - u_lo_0) * inv_du).floor();
                    if cbin >= 0.0 && (cbin as usize) < ncols {
                        let col = cbin as usize;
                        if pure_2d {
                            emit(flat, 0, col, amp_2d);
                        } else {
                            for &(row, a_v) in rows {
                                emit(flat, row, col, amp_u * a_v);
                            }
                        }
                    }
                    uc += duc;
                    flat += 1;
                    continue;
                }
                // overlapped bin range
                let c_first_f = ((uc + shape.b[0] - u_lo_0) * inv_du).floor();
                let c_first = if c_first_f < 0.0 { 0usize } else { c_first_f as usize };
                let c_last_f = ((uc + shape.b[3] - u_lo_0) * inv_du).ceil();
                if c_last_f < 0.0 || c_first >= ncols {
                    uc += duc;
                    flat += 1;
                    continue;
                }
                let c_last = (c_last_f as usize).min(ncols - 1);
                // shared-edge CDF walk across the bins
                let mut f_prev = eval.cdf(u_lo_0 + c_first as f64 * g.du - uc);
                for col in c_first..=c_last {
                    let f_next = eval.cdf(u_lo_0 + (col + 1) as f64 * g.du - uc);
                    let w = f_next - f_prev;
                    f_prev = f_next;
                    if w <= 0.0 {
                        continue;
                    }
                    if pure_2d {
                        emit(flat, 0, col, amp_2d * w);
                    } else {
                        let a_u = amp_u * w;
                        for &(row, a_v) in rows {
                            emit(flat, row, col, a_u * a_v);
                        }
                    }
                }
                uc += duc;
                flat += 1;
            }
        }
    }
}

/// Public coefficient enumeration for one parallel-beam view — used by
/// [`crate::sysmatrix`] to assemble the stored-matrix baseline from the
/// *identical* coefficients the on-the-fly path computes.
pub fn parallel_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &ParallelBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, usize, f64),
) {
    parallel_view_coeffs(vg, g, view, |a, b, c, d| emit(a, b, c, d));
}

/// Public coefficient enumeration for one fan-beam view (see
/// [`parallel_view_coeffs_pub`]).
pub fn fan_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &FanBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, f64),
) {
    fan_view_coeffs(vg, g, view, |a, b, c| emit(a, b, c));
}

/// Public coefficient enumeration for one cone-beam view (see
/// [`parallel_view_coeffs_pub`]).
pub fn cone_view_coeffs_pub(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    view: usize,
    emit: &mut dyn FnMut(usize, usize, usize, f64),
) {
    cone_view_coeffs(vg, g, view, |a, b, c, d| emit(a, b, c, d));
}

/// SF forward projection, parallel beam. Parallelized over views (each
/// view owns its output slab — scatter-safe).
pub fn forward_parallel(vg: &VolumeGeometry, g: &ParallelBeam, vol: &Vol3, sino: &mut Sino, threads: usize) {
    assert_eq!(sino.nviews, g.angles.len());
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.fill(0.0);
    let nviews = g.angles.len();
    let sino_ptr = SinoPtr(sino as *mut Sino);
    parallel_chunks(nviews, threads, |v0, v1| {
        // SAFETY: each view's slab is written by exactly one worker
        let sino = sino_ptr.get();
        for view in v0..v1 {
            let base = view * nrows * ncols;
            parallel_view_coeffs(vg, g, view, |flat, row, col, coeff| {
                sino.data[base + row * ncols + col] += (coeff as f32) * vol.data[flat];
            });
        }
    });
}

/// Matched SF backprojection, parallel beam. Gathers per view into
/// per-thread partial volumes, then reduces (exact transpose of
/// [`forward_parallel`]).
pub fn back_parallel(vg: &VolumeGeometry, g: &ParallelBeam, sino: &Sino, vol: &mut Vol3, threads: usize) {
    let nviews = g.angles.len();
    let nvox = vg.num_voxels();
    let ncols = sino.ncols;
    let result = pool::parallel_map_reduce(
        nviews,
        threads,
        |v0, v1| {
            let mut part = vec![0.0f32; nvox];
            for view in v0..v1 {
                let vdata = sino.view(view);
                parallel_view_coeffs(vg, g, view, |flat, row, col, coeff| {
                    part[flat] += (coeff as f32) * vdata[row * ncols + col];
                });
            }
            part
        },
        |mut a, b| {
            pool::add_assign(&mut a, &b);
            a
        },
    );
    if let Some(acc) = result {
        vol.data.copy_from_slice(&acc);
    } else {
        vol.fill(0.0);
    }
}

// ---------------------------------------------------------------------------
// fan beam (2-D divergent)
// ---------------------------------------------------------------------------

fn fan_view_coeffs<F: FnMut(usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &FanBeam,
    view: usize,
    mut emit: F,
) {
    let phi = g.angles[view];
    let (sphi, cphi) = phi.sin_cos();
    let src = [g.sod * cphi, g.sod * sphi];
    // detector frame: normal n̂ points source→detector, û along columns
    let nhat = [-cphi, -sphi];
    let uhat = [-sphi, cphi];
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    let area = vg.vx * vg.vy;

    for j in 0..vg.ny {
        let y = vg.y(j);
        for i in 0..vg.nx {
            let x = vg.x(i);
            // project the 4 in-plane corners onto the detector
            let mut pts = [0.0f64; 4];
            let mut idx = 0;
            for (ddx, ddy) in [(-hx, -hy), (-hx, hy), (hx, -hy), (hx, hy)] {
                let px = x + ddx - src[0];
                let py = y + ddy - src[1];
                let t = px * nhat[0] + py * nhat[1]; // distance along normal
                let u = px * uhat[0] + py * uhat[1];
                pts[idx] = g.sdd * u / t;
                idx += 1;
            }
            let trap = Trap::new(pts);
            // amplitude at the voxel center
            let px = x - src[0];
            let py = y - src[1];
            let t = px * nhat[0] + py * nhat[1];
            let dist = (px * px + py * py).sqrt();
            let m = g.sdd / t;
            let cos_psi = t / dist;
            let amp = area * m / cos_psi;
            let flat = j * vg.nx + i;
            for_bins(&trap, g.ncols, g.du, g.cu, amp, |col, a| emit(flat, col, a));
        }
    }
}

/// SF forward projection, fan beam (2-D volume required).
pub fn forward_fan(vg: &VolumeGeometry, g: &FanBeam, vol: &Vol3, sino: &mut Sino, threads: usize) {
    assert_eq!(vg.nz, 1, "fan-beam SF requires a 2-D volume");
    let ncols = sino.ncols;
    sino.fill(0.0);
    let nviews = g.angles.len();
    let sino_ptr = SinoPtr(sino as *mut Sino);
    parallel_chunks(nviews, threads, |v0, v1| {
        let sino = sino_ptr.get();
        for view in v0..v1 {
            let base = view * ncols;
            for_each_fan_coeff(vg, g, view, |flat, col, coeff| {
                sino.data[base + col] += (coeff as f32) * vol.data[flat];
            });
        }
    });
}

struct SinoPtr(*mut Sino);
unsafe impl Send for SinoPtr {}
unsafe impl Sync for SinoPtr {}
impl SinoPtr {
    /// Access through a method so closures capture the Sync wrapper, not
    /// the raw pointer field (edition-2021 disjoint capture).
    #[allow(clippy::mut_from_ref)]
    fn get(&self) -> &mut Sino {
        unsafe { &mut *self.0 }
    }
}

fn for_each_fan_coeff<F: FnMut(usize, usize, f64)>(vg: &VolumeGeometry, g: &FanBeam, view: usize, emit: F) {
    fan_view_coeffs(vg, g, view, emit);
}

/// Matched SF backprojection, fan beam.
pub fn back_fan(vg: &VolumeGeometry, g: &FanBeam, sino: &Sino, vol: &mut Vol3, threads: usize) {
    assert_eq!(vg.nz, 1);
    let nviews = g.angles.len();
    let nvox = vg.num_voxels();

    let result = pool::parallel_map_reduce(
        nviews,
        threads,
        |v0, v1| {
            let mut part = vec![0.0f32; nvox];
            for view in v0..v1 {
                let vdata = sino.view(view);
                fan_view_coeffs(vg, g, view, |flat, col, coeff| {
                    part[flat] += (coeff as f32) * vdata[col];
                });
            }
            part
        },
        |mut a, b| {
            pool::add_assign(&mut a, &b);
            a
        },
    );
    if let Some(acc) = result {
        vol.data.copy_from_slice(&acc);
    } else {
        vol.fill(0.0);
    }
}

// ---------------------------------------------------------------------------
// cone beam (flat or curved detector), SF-TR style
// ---------------------------------------------------------------------------

fn cone_view_coeffs<F: FnMut(usize, usize, usize, f64)>(
    vg: &VolumeGeometry,
    g: &ConeBeam,
    view: usize,
    mut emit: F,
) {
    let phi = g.angles[view];
    let (sphi, cphi) = phi.sin_cos();
    let src = [g.sod * cphi, g.sod * sphi, 0.0];
    let nhat = [-cphi, -sphi];
    let uhat = [-sphi, cphi];
    let hx = vg.vx / 2.0;
    let hy = vg.vy / 2.0;
    let hz = vg.vz / 2.0;
    let vol_v = vg.vx * vg.vy * vg.vz;
    let curved = g.shape == DetectorShape::Curved;
    // reusable transaxial-weight buffer (see perf note below)
    let mut u_bins: Vec<(usize, f64)> = Vec::with_capacity(8);

    for j in 0..vg.ny {
        let y = vg.y(j);
        for i in 0..vg.nx {
            let x = vg.x(i);
            // transaxial footprint from the 4 in-plane corners
            let mut pts = [0.0f64; 4];
            let mut n = 0;
            for (ddx, ddy) in [(-hx, -hy), (-hx, hy), (hx, -hy), (hx, hy)] {
                let px = x + ddx - src[0];
                let py = y + ddy - src[1];
                let t = px * nhat[0] + py * nhat[1];
                let u_perp = px * uhat[0] + py * uhat[1];
                pts[n] = if curved {
                    g.sdd * u_perp.atan2(t)
                } else {
                    g.sdd * u_perp / t
                };
                n += 1;
            }
            let utrap = Trap::new(pts);

            // center-of-voxel quantities for the axial footprint + amplitude
            let px = x - src[0];
            let py = y - src[1];
            let t_c = px * nhat[0] + py * nhat[1];
            let d_inplane = (px * px + py * py).sqrt();
            if t_c <= 0.0 {
                continue; // behind the source
            }
            // axial magnification: flat uses distance along the normal,
            // curved uses the in-plane distance to the cylinder
            let m_v = if curved { g.sdd / d_inplane } else { g.sdd / t_c };
            let m_u = if curved { g.sdd / d_inplane } else { g.sdd / t_c };

            // the transaxial bin weights are independent of k — enumerate
            // them once per (i, j) into a small buffer (perf pass)
            u_bins.clear();
            for_bins(&utrap, g.ncols, g.du, g.cu, 1.0, |col, a_u| u_bins.push((col, a_u)));
            if u_bins.is_empty() {
                continue;
            }

            // detector-row grid for the rect axial footprint
            let v_lo_0 = -(g.nrows as f64 - 1.0) / 2.0 * g.dv + g.cv - g.dv / 2.0;
            let inv_dv = 1.0 / g.dv;

            let flat_idx_base = j * vg.nx + i;
            for k in 0..vg.nz {
                let z = vg.z(k);
                // rect footprint [v0, v1]: closed-form bin overlaps
                let v0 = (z - hz) * m_v;
                let v1 = (z + hz) * m_v;
                let width = v1 - v0;
                if width <= 0.0 {
                    continue;
                }
                let dist = (d_inplane * d_inplane + z * z).sqrt();
                let cos_psi = if curved { d_inplane / dist } else { t_c / dist };
                let amp = vol_v * m_u * m_v / cos_psi;
                let flat = k * vg.ny * vg.nx + flat_idx_base;

                let r_first_f = ((v0 - v_lo_0) * inv_dv).floor();
                let r_last_f = ((v1 - v_lo_0) * inv_dv).floor();
                if r_last_f < 0.0 || r_first_f >= g.nrows as f64 {
                    continue;
                }
                let r_first = if r_first_f < 0.0 { 0 } else { r_first_f as usize };
                let r_last = (r_last_f.max(0.0) as usize).min(g.nrows - 1);
                let inv_width_dv = 1.0 / (width * g.dv);
                for row in r_first..=r_last {
                    let bin_lo = v_lo_0 + row as f64 * g.dv;
                    let overlap = (v1.min(bin_lo + g.dv) - v0.max(bin_lo)).max(0.0);
                    if overlap <= 0.0 {
                        continue;
                    }
                    // a_v = (1/dv)·∫ rect = overlap / (width·dv)
                    let a_v = overlap * inv_width_dv * amp;
                    for &(col, a_u) in &u_bins {
                        emit(flat, row, col, a_u * a_v);
                    }
                }
            }
        }
    }
}

/// SF forward projection, cone beam (flat or curved detector).
pub fn forward_cone(vg: &VolumeGeometry, g: &ConeBeam, vol: &Vol3, sino: &mut Sino, threads: usize) {
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    sino.fill(0.0);
    let nviews = g.angles.len();
    let sino_ptr = SinoPtr(sino as *mut Sino);
    parallel_chunks(nviews, threads, |v0, v1| {
        let sino = sino_ptr.get();
        for view in v0..v1 {
            let base = view * nrows * ncols;
            cone_view_coeffs(vg, g, view, |flat, row, col, coeff| {
                sino.data[base + row * ncols + col] += (coeff as f32) * vol.data[flat];
            });
        }
    });
}

/// Matched SF backprojection, cone beam.
pub fn back_cone(vg: &VolumeGeometry, g: &ConeBeam, sino: &Sino, vol: &mut Vol3, threads: usize) {
    let nviews = g.angles.len();
    let nvox = vg.num_voxels();
    let ncols = sino.ncols;
    let result = pool::parallel_map_reduce(
        nviews,
        threads,
        |v0, v1| {
            let mut part = vec![0.0f32; nvox];
            for view in v0..v1 {
                let vdata = sino.view(view);
                cone_view_coeffs(vg, g, view, |flat, row, col, coeff| {
                    part[flat] += (coeff as f32) * vdata[row * ncols + col];
                });
            }
            part
        },
        |mut a, b| {
            pool::add_assign(&mut a, &b);
            a
        },
    );
    if let Some(acc) = result {
        vol.data.copy_from_slice(&acc);
    } else {
        vol.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::angles_deg;

    #[test]
    fn trap_unit_area() {
        let t = Trap::new([1.0, 0.0, 3.0, 2.0]); // sorted: 0,1,2,3
        assert!((t.cdf(10.0) - 1.0).abs() < 1e-12);
        assert!((t.integral(0.0, 3.0) - 1.0).abs() < 1e-12);
        // symmetric halves
        assert!((t.cdf(1.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trap_rect_case() {
        let t = Trap::new([-1.0, -1.0, 1.0, 1.0]);
        assert!((t.h - 0.5).abs() < 1e-12);
        assert!((t.integral(-1.0, 0.0) - 0.5).abs() < 1e-12);
        assert!((t.integral(-2.0, -1.0)).abs() < 1e-12);
    }

    #[test]
    fn trap_degenerate_point() {
        let t = Trap::new([2.0, 2.0, 2.0, 2.0]);
        assert!(t.is_degenerate());
    }

    #[test]
    fn for_bins_mass_conserved() {
        // trapezoid fully inside the detector: Σ coeff = amp / pitch
        let t = Trap::new([-0.8, -0.3, 0.4, 0.9]);
        let mut total = 0.0;
        for_bins(&t, 64, 0.5, 0.0, 3.0, |_, a| total += a);
        assert!((total - 3.0 / 0.5).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn parallel_2d_projection_of_uniform_disk() {
        // uniform disk: central ray integral ≈ 2·r·μ
        let vg = VolumeGeometry::slice2d(64, 64, 1.0);
        let ph = crate::phantom::Phantom::new(vec![crate::phantom::Shape::ellipse2d(
            0.0, 0.0, 20.0, 20.0, 0.0, 0.01,
        )]);
        let vol = ph.rasterize(&vg, 2);
        let g = ParallelBeam::standard_2d(12, 65, 1.0);
        let mut sino = Sino::zeros2d(12, 65);
        forward_parallel(&vg, &g, &vol, &mut sino, 1);
        for view in 0..12 {
            let center = sino.at(view, 0, 32);
            assert!((center - 0.4).abs() < 0.01, "view {view}: {center}");
        }
    }

    #[test]
    fn parallel_mass_conservation_any_angle() {
        // Σ_bins A·x · du = Σ_vox x · vx·vy (total mass is angle-invariant)
        let vg = VolumeGeometry::slice2d(32, 32, 0.7);
        let mut vol = Vol3::zeros2d(32, 32);
        let mut rng = crate::util::rng::Rng::new(9);
        rng.fill_uniform(&mut vol.data, 0.0, 1.0);
        // zero the borders so no footprint mass falls off the detector
        let g = ParallelBeam { nrows: 1, ncols: 96, du: 0.5, dv: 0.5, cu: 0.0, cv: 0.0, angles: angles_deg(7, 0.0, 180.0) };
        let mut sino = Sino::zeros2d(7, 96);
        forward_parallel(&vg, &g, &vol, &mut sino, 1);
        let mass: f64 = vol.sum() * 0.7 * 0.7;
        for view in 0..7 {
            let m: f64 = sino.view(view).iter().map(|&v| v as f64 * 0.5).sum();
            assert!((m - mass).abs() / mass < 1e-6, "view {view}: {m} vs {mass}");
        }
    }

    #[test]
    fn fan_matches_parallel_at_large_sod() {
        // fan with sod → ∞ converges to parallel
        let vg = VolumeGeometry::slice2d(32, 32, 1.0);
        let ph = crate::phantom::shepp::shepp_logan_2d(14.0, 0.02);
        let vol = ph.rasterize(&vg, 2);

        let angles = angles_deg(4, 0.0, 180.0);
        let par = ParallelBeam { nrows: 1, ncols: 48, du: 1.0, dv: 1.0, cu: 0.0, cv: 0.0, angles: angles.clone() };
        // fan view φ looks along −(cos φ, sin φ); parallel view φ along
        // (−sin φ, cos φ): fan angle φ−90° aligns both the view direction
        // and the detector-u orientation. Same effective du at isocenter:
        // du_fan / mag = 1.0.
        let fan_angles: Vec<f64> =
            angles.iter().map(|a| a - std::f64::consts::FRAC_PI_2).collect();
        let fan = FanBeam { ncols: 48, du: 10.0, cu: 0.0, sod: 50_000.0, sdd: 500_000.0, angles: fan_angles };

        let mut s_par = Sino::zeros2d(4, 48);
        let mut s_fan = Sino::zeros2d(4, 48);
        forward_parallel(&vg, &par, &vol, &mut s_par, 1);
        forward_fan(&vg, &fan, &vol, &mut s_fan, 1);
        let err = crate::util::rel_l2(&s_fan.data, &s_par.data, 1e-12);
        assert!(err < 2e-3, "rel err {err}");
    }

    #[test]
    fn cone_center_row_matches_fan() {
        // the central detector row of a cone scan equals the fan scan of
        // the central slice (for a z-uniform... use single-slice volume at z=0)
        let vg = VolumeGeometry { nx: 24, ny: 24, nz: 1, vx: 1.0, vy: 1.0, vz: 1.0, cx: 0.0, cy: 0.0, cz: 0.0 };
        let mut vol = Vol3::zeros(24, 24, 1);
        let mut rng = crate::util::rng::Rng::new(4);
        rng.fill_uniform(&mut vol.data, 0.0, 0.05);

        let angles = angles_deg(5, 0.0, 360.0);
        let fan = FanBeam { ncols: 40, du: 1.0, cu: 0.0, sod: 100.0, sdd: 200.0, angles: angles.clone() };
        let cone = ConeBeam {
            nrows: 3,
            ncols: 40,
            du: 1.0,
            dv: 1.0,
            cu: 0.0,
            cv: 0.0,
            sod: 100.0,
            sdd: 200.0,
            angles,
            shape: DetectorShape::Flat,
        };
        let mut s_fan = Sino::zeros2d(5, 40);
        let mut s_cone = Sino::zeros(5, 3, 40);
        forward_fan(&vg, &fan, &vol, &mut s_fan, 1);
        forward_cone(&vg, &cone, &vol, &mut s_cone, 1);
        // Every voxel's axial footprint (width m_v·vz ∈ [1.8, 2.3] mm here)
        // fully covers the central row's 1 mm bin, so the central-row cone
        // coefficient reduces exactly to the fan coefficient.
        for view in 0..5 {
            for col in 5..35 {
                let f = s_fan.at(view, 0, col);
                let c = s_cone.at(view, 1, col);
                assert!(
                    (c - f).abs() <= 0.02 * f.abs().max(0.01),
                    "view {view} col {col}: cone {c} fan {f}"
                );
            }
        }
    }
}
