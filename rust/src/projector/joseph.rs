//! Joseph's method (Joseph 1982): march along the ray's major axis,
//! bilinearly interpolating across the two minor axes.
//!
//! Smoother coefficients than Siddon at essentially the same cost, and the
//! natural formulation for the L1 Pallas kernel (the inner loop is a dense
//! regular gather — see `python/compile/kernels/joseph.py`). Forward and
//! back share the identical weights through the same visitor, so the pair
//! is exactly matched.

use crate::geometry::{Ray, VolumeGeometry};

/// Index (0 = x, 1 = y, 2 = z) of the direction's dominant component —
/// the Joseph marching axis. Constant across a view for parallel beams,
/// so [`crate::projector::ProjectionPlan`] caches it per view.
#[inline]
pub fn major_axis(dir: &[f64; 3]) -> usize {
    let ad = [dir[0].abs(), dir[1].abs(), dir[2].abs()];
    if ad[0] >= ad[1] && ad[0] >= ad[2] {
        0
    } else if ad[1] >= ad[2] {
        1
    } else {
        2
    }
}

/// March `ray` through `vg` along its major axis, invoking
/// `visit(flat_index, weight_mm)` with bilinear interpolation weights
/// scaled by the per-plane step length.
pub fn walk_ray<F: FnMut(usize, f32)>(vg: &VolumeGeometry, ray: &Ray, visit: F) {
    walk_ray_with_axis(vg, ray, major_axis(&ray.dir), visit)
}

/// [`walk_ray`] with the major axis `a` supplied by the caller (a plan
/// that cached it). `a` must equal `major_axis(&ray.dir)` for the weights
/// to be the Joseph weights.
pub fn walk_ray_with_axis<F: FnMut(usize, f32)>(
    vg: &VolumeGeometry,
    ray: &Ray,
    a: usize,
    mut visit: F,
) {
    let d = ray.dir;
    if d[a].abs() < 1e-12 {
        return; // degenerate direction
    }
    // minor axes
    let (b, c) = match a {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };

    let n = [vg.nx, vg.ny, vg.nz];
    let pitch = [vg.vx, vg.vy, vg.vz];
    let origin = [vg.x(0), vg.y(0), vg.z(0)]; // center of voxel 0 along each axis
    let o = ray.origin;

    // step length per major plane (mm of ray per plane)
    let step = (pitch[a] / d[a].abs()) as f32;

    // clip the major-axis plane range to where the ray is inside the
    // volume bounds of the minor axes (cheap conservative clip: solve the
    // slab range in t, convert to plane indices)
    let (lo, hi) = vg.bounds();
    let mut tmin = f64::NEG_INFINITY;
    let mut tmax = f64::INFINITY;
    for ax in 0..3 {
        if d[ax].abs() < 1e-12 {
            if o[ax] <= lo[ax] || o[ax] >= hi[ax] {
                return;
            }
        } else {
            let ta = (lo[ax] - o[ax]) / d[ax];
            let tb = (hi[ax] - o[ax]) / d[ax];
            tmin = tmin.max(ta.min(tb));
            tmax = tmax.min(ta.max(tb));
        }
    }
    if tmin >= tmax {
        return;
    }
    // plane index range along the major axis
    let pa0 = (o[a] + tmin * d[a] - origin[a]) / pitch[a];
    let pa1 = (o[a] + tmax * d[a] - origin[a]) / pitch[a];
    let (mut m0, mut m1) = if pa0 <= pa1 { (pa0, pa1) } else { (pa1, pa0) };
    m0 = m0.max(0.0);
    m1 = m1.min(n[a] as f64 - 1.0);
    let m_start = m0.ceil() as usize;
    let m_end = m1.floor() as usize; // inclusive
    if m_start > m_end {
        return;
    }

    // strides in the flat Vol3 layout
    let strides = [1usize, vg.nx, vg.nx * vg.ny];
    let sa = strides[a];
    let sb = strides[b];
    let sc = strides[c];

    // continuous minor coordinates at plane m and their per-plane increments
    let t_of_plane = |m: f64| (origin[a] + m * pitch[a] - o[a]) / d[a];
    let t0 = t_of_plane(m_start as f64);
    let dt = pitch[a] / d[a]; // signed t increment per plane

    let fb_at = |t: f64| (o[b] + t * d[b] - origin[b]) / pitch[b];
    let fc_at = |t: f64| (o[c] + t * d[c] - origin[c]) / pitch[c];
    let mut fb = fb_at(t0);
    let mut fc = fc_at(t0);
    let dfb = dt * d[b] / pitch[b];
    let dfc = dt * d[c] / pitch[c];

    let nb = n[b] as i64;
    let nc = n[c] as i64;

    for m in m_start..=m_end {
        let ib = fb.floor() as i64;
        let ic = fc.floor() as i64;
        let wb1 = (fb - ib as f64) as f32;
        let wb0 = 1.0 - wb1;
        let wc1 = (fc - ic as f64) as f32;
        let wc0 = 1.0 - wc1;
        let base = m * sa;

        // 4 bilinear corners, skipping out-of-range indices (no clamping:
        // weight mass outside the grid is dropped, as in LEAP). Zero
        // weights are skipped too — in 2-D (nz = 1) the two z-corners are
        // always exactly zero, halving the visits (§Perf).
        let b_in0 = ib >= 0 && ib < nb;
        let b_in1 = ib + 1 >= 0 && ib + 1 < nb;
        let c_in0 = ic >= 0 && ic < nc;
        let c_in1 = ic + 1 >= 0 && ic + 1 < nc;
        if b_in0 && c_in0 {
            visit(base + ib as usize * sb + ic as usize * sc, wb0 * wc0 * step);
        }
        if b_in1 && c_in0 {
            visit(base + (ib + 1) as usize * sb + ic as usize * sc, wb1 * wc0 * step);
        }
        if b_in0 && c_in1 {
            visit(base + ib as usize * sb + (ic + 1) as usize * sc, wb0 * wc1 * step);
        }
        if b_in1 && c_in1 {
            visit(base + (ib + 1) as usize * sb + (ic + 1) as usize * sc, wb1 * wc1 * step);
        }
        fb += dfb;
        fc += dfc;
    }
}

/// Sum of weights along a ray (≈ chord length through the grid).
pub fn path_length(vg: &VolumeGeometry, ray: &Ray) -> f64 {
    let mut total = 0.0f64;
    walk_ray(vg, ray, |_, w| total += w as f64);
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Ray;

    #[test]
    fn major_axis_picks_dominant_component() {
        assert_eq!(major_axis(&[1.0, 0.2, -0.3]), 0);
        assert_eq!(major_axis(&[0.1, -0.9, 0.3]), 1);
        assert_eq!(major_axis(&[0.1, 0.2, 0.95]), 2);
    }

    #[test]
    fn precomputed_axis_matches_walk_ray() {
        let vg = VolumeGeometry::cube(12, 1.0);
        let ray = Ray::new([-30.0, 1.7, -0.4], [0.9, 0.4, 0.2]);
        let mut a = Vec::new();
        let mut b = Vec::new();
        walk_ray(&vg, &ray, |idx, w| a.push((idx, w)));
        walk_ray_with_axis(&vg, &ray, major_axis(&ray.dir), |idx, w| b.push((idx, w)));
        assert_eq!(a, b);
    }

    #[test]
    fn axis_aligned_matches_siddon() {
        let vg = VolumeGeometry::cube(8, 2.0);
        let ray = Ray::new([-100.0, 0.1, 0.3], [1.0, 0.0, 0.0]);
        let j = path_length(&vg, &ray);
        let s = crate::projector::siddon::path_length(&vg, &ray);
        assert!((j - s).abs() < 1e-6, "joseph {j} vs siddon {s}");
    }

    #[test]
    fn oblique_path_close_to_siddon() {
        let vg = VolumeGeometry::cube(32, 1.0);
        // ray through the middle, avoiding edges where the two models
        // differ by design
        let dir = [0.2, 0.95, 0.1];
        let ray = Ray::new([1.0, -50.0, -2.0], dir);
        let j = path_length(&vg, &ray);
        let s = crate::projector::siddon::path_length(&vg, &ray);
        assert!((j - s).abs() / s < 0.02, "joseph {j} vs siddon {s}");
    }

    #[test]
    fn weights_nonnegative_and_bounded() {
        let vg = VolumeGeometry::cube(16, 1.0);
        let ray = Ray::new([-30.0, 2.3, -1.2], [0.8, 0.5, 0.33]);
        walk_ray(&vg, &ray, |idx, w| {
            assert!(idx < 16 * 16 * 16);
            assert!(w >= 0.0);
            assert!(w as f64 <= 1.0 / 0.8f64.hypot(0.0) + 1e-6); // ≤ step
        });
    }

    #[test]
    fn per_plane_weights_sum_to_step() {
        // interior ray: the 4 bilinear weights at each plane sum to the step
        let vg = VolumeGeometry::cube(16, 1.0);
        let dir = [0.1, 0.99, 0.05];
        let ray = Ray::new([0.3, -40.0, 0.7], dir);
        let norm = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt();
        let step = 1.0 / (dir[1] / norm);
        let mut per_plane = std::collections::HashMap::new();
        walk_ray(&vg, &ray, |idx, w| {
            let j = (idx / 16) % 16; // y index = major plane
            *per_plane.entry(j).or_insert(0.0f64) += w as f64;
        });
        // interior planes (not clipped) sum to step
        for j in 2..14 {
            let s = per_plane.get(&j).copied().unwrap_or(0.0);
            assert!((s - step).abs() < 1e-5, "plane {j}: {s} vs {step}");
        }
    }

    #[test]
    fn miss_is_empty() {
        let vg = VolumeGeometry::cube(8, 1.0);
        let ray = Ray::new([-100.0, 40.0, 0.0], [1.0, 0.0, 0.0]);
        let mut any = false;
        walk_ray(&vg, &ray, |_, _| any = true);
        assert!(!any);
    }

    #[test]
    fn works_for_single_slice_2d() {
        // nz = 1: in-plane ray must interpolate only within the slice
        let vg = VolumeGeometry::slice2d(16, 16, 1.0);
        let ray = Ray::new([-30.0, 1.3, 0.0], [1.0, 0.2, 0.0]);
        let total = path_length(&vg, &ray);
        assert!(total > 10.0, "total {total}");
    }
}
