//! Forward/back projector pair for cylindrically symmetric objects —
//! the Abel transform (paper §2.1: "we also implemented forward/back
//! projector pairs for objects with cylindrical symmetry (Champley &
//! Maddox 2021). A special case of this is the Abel Transform which
//! applies to parallel-beam geometries").
//!
//! A radially symmetric slice is described by a 1-D profile `f(r)` on
//! `nr` rings of width `dr`; its parallel-beam projection is identical at
//! every view angle:
//!
//! ```text
//!   g(u) = 2 ∫_{|u|}^{R} f(r) · r / √(r² − u²) dr
//! ```
//!
//! Discretized with constant-per-ring profiles, the coefficient of ring
//! `[r0, r1]` for detector coordinate `u` is the exact chord-length pair
//! `2(√(r1²−u²) − √(max(r0,|u|)²−u²))` — so the forward operator is a
//! dense lower-triangular-ish matrix applied on the fly, and the matched
//! backprojector is its exact transpose (same `weight` function), keeping
//! the library's matched-pair guarantee.

use crate::util::pool::parallel_chunks;

/// Abel projector for one radially symmetric slice.
#[derive(Clone, Debug)]
pub struct Abel {
    /// number of radial rings
    pub nr: usize,
    /// ring width (mm)
    pub dr: f64,
    /// number of detector bins
    pub ncols: usize,
    /// detector pitch (mm)
    pub du: f64,
    /// detector center offset (mm)
    pub cu: f64,
}

impl Abel {
    pub fn new(nr: usize, dr: f64, ncols: usize, du: f64) -> Abel {
        Abel { nr, dr, ncols, du, cu: 0.0 }
    }

    /// Detector coordinate of bin `c` (mm).
    #[inline]
    pub fn u(&self, c: usize) -> f64 {
        (c as f64 - (self.ncols as f64 - 1.0) / 2.0) * self.du + self.cu
    }

    /// Chord-length coefficient of ring `ir` for detector coordinate `u`:
    /// the length of the line at offset `|u|` inside the annulus
    /// `[ir·dr, (ir+1)·dr]`.
    #[inline]
    pub fn weight(&self, ir: usize, u: f64) -> f64 {
        let au = u.abs();
        let r1 = (ir as f64 + 1.0) * self.dr;
        if au >= r1 {
            return 0.0;
        }
        let r0 = (ir as f64) * self.dr;
        let outer = (r1 * r1 - au * au).sqrt();
        let inner = if au >= r0 { 0.0 } else { (r0 * r0 - au * au).sqrt() };
        2.0 * (outer - inner)
    }

    /// Forward Abel transform: radial profile (`nr`) → projection (`ncols`).
    pub fn forward(&self, profile: &[f32], out: &mut [f32]) {
        assert_eq!(profile.len(), self.nr);
        assert_eq!(out.len(), self.ncols);
        let threads = crate::util::pool::default_threads();
        struct OutPtr(*mut f32);
        unsafe impl Send for OutPtr {}
        unsafe impl Sync for OutPtr {}
        impl OutPtr {
            fn slice(&self, len: usize) -> &mut [f32] {
                unsafe { std::slice::from_raw_parts_mut(self.0, len) }
            }
        }
        let out_ptr = OutPtr(out.as_mut_ptr());
        let ncols = self.ncols;
        parallel_chunks(ncols, threads, |c0, c1| {
            let out = out_ptr.slice(ncols);
            for c in c0..c1 {
                let u = self.u(c);
                // rings with r1 > |u| contribute
                let first = ((u.abs() / self.dr).floor() as usize).min(self.nr);
                let mut acc = 0.0f64;
                for ir in first..self.nr {
                    acc += self.weight(ir, u) * profile[ir] as f64;
                }
                out[c] = acc as f32;
            }
        });
    }

    /// Matched adjoint: projection (`ncols`) → radial profile (`nr`),
    /// using the identical weights (exact transpose).
    pub fn back(&self, proj: &[f32], profile: &mut [f32]) {
        assert_eq!(proj.len(), self.ncols);
        assert_eq!(profile.len(), self.nr);
        for ir in 0..self.nr {
            let mut acc = 0.0f64;
            for c in 0..self.ncols {
                let u = self.u(c);
                acc += self.weight(ir, u) * proj[c] as f64;
            }
            profile[ir] = acc as f32;
        }
    }

    /// Inverse via preconditioned CGLS on the matched pair — the "model
    /// based iterative reconstruction with the tilted Abel transform"
    /// use-case at its simplest.
    pub fn invert(&self, proj: &[f32], iterations: usize) -> Vec<f32> {
        let mut x = vec![0.0f32; self.nr];
        let mut r = proj.to_vec();
        let ax = {
            let mut t = vec![0.0f32; self.ncols];
            self.forward(&x, &mut t);
            t
        };
        for i in 0..r.len() {
            r[i] -= ax[i];
        }
        let mut s = vec![0.0f32; self.nr];
        self.back(&r, &mut s);
        let mut d = s.clone();
        let mut norm_s: f64 = s.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let mut ad = vec![0.0f32; self.ncols];
        for _ in 0..iterations {
            if norm_s < 1e-30 {
                break;
            }
            self.forward(&d, &mut ad);
            let denom: f64 = ad.iter().map(|&v| (v as f64) * (v as f64)).sum();
            if denom < 1e-30 {
                break;
            }
            let alpha = (norm_s / denom) as f32;
            for i in 0..x.len() {
                x[i] += alpha * d[i];
            }
            for i in 0..r.len() {
                r[i] -= alpha * ad[i];
            }
            self.back(&r, &mut s);
            let norm_new: f64 = s.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let beta = (norm_new / norm_s) as f32;
            for i in 0..d.len() {
                d[i] = s[i] + beta * d[i];
            }
            norm_s = norm_new;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{dot_f64, rng::Rng};

    #[test]
    fn uniform_disk_projection_is_chord() {
        // f(r) = μ for r < R: g(u) = 2μ√(R²−u²)
        let a = Abel::new(64, 0.5, 96, 0.5); // R = 32 mm
        let mu = 0.02f32;
        let profile = vec![mu; 64];
        let mut g = vec![0.0f32; 96];
        a.forward(&profile, &mut g);
        for c in 0..96 {
            let u = a.u(c);
            let expect = if u.abs() < 32.0 {
                2.0 * mu as f64 * (32.0f64 * 32.0 - u * u).sqrt()
            } else {
                0.0
            };
            assert!(
                (g[c] as f64 - expect).abs() < 1e-4,
                "c {c}: {} vs {expect}",
                g[c]
            );
        }
    }

    #[test]
    fn adjoint_identity() {
        let a = Abel::new(40, 0.7, 64, 0.9);
        let mut rng = Rng::new(3);
        let mut x = vec![0.0f32; 40];
        let mut y = vec![0.0f32; 64];
        rng.fill_uniform(&mut x, -1.0, 1.0);
        rng.fill_uniform(&mut y, -1.0, 1.0);
        let mut ax = vec![0.0f32; 64];
        a.forward(&x, &mut ax);
        let mut aty = vec![0.0f32; 40];
        a.back(&y, &mut aty);
        let lhs = dot_f64(&ax, &y);
        let rhs = dot_f64(&x, &aty);
        assert!((lhs - rhs).abs() / lhs.abs().max(1e-12) < 1e-5, "{lhs} vs {rhs}");
    }

    #[test]
    fn invert_recovers_profile() {
        let a = Abel::new(32, 1.0, 128, 0.5);
        // shell profile: hollow cylinder
        let profile: Vec<f32> =
            (0..32).map(|i| if (10..20).contains(&i) { 0.03 } else { 0.0 }).collect();
        let mut g = vec![0.0f32; 128];
        a.forward(&profile, &mut g);
        let rec = a.invert(&g, 60);
        for i in 0..32 {
            assert!(
                (rec[i] - profile[i]).abs() < 2e-3,
                "ring {i}: {} vs {}",
                rec[i],
                profile[i]
            );
        }
    }

    #[test]
    fn weight_zero_outside_ring() {
        let a = Abel::new(10, 1.0, 32, 1.0);
        assert_eq!(a.weight(3, 4.5), 0.0); // |u| ≥ r1
        assert!(a.weight(3, 3.5) > 0.0); // inside the annulus
        assert!(a.weight(3, 1.0) > 0.0); // chord crosses the annulus twice
    }

    #[test]
    fn matches_full_2d_projector_on_symmetric_object() {
        // the Abel path must agree with the general 2-D SF projector on a
        // radially symmetric phantom (any view)
        use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
        use crate::phantom::{Phantom, Shape};
        let ph = Phantom::new(vec![Shape::ellipse2d(0.0, 0.0, 12.0, 12.0, 0.0, 0.02)]);
        let ncols = 64;
        let a = Abel::new(48, 0.5, ncols, 0.75);
        // radial profile of the disk
        let profile: Vec<f32> = (0..48)
            .map(|i| if (i as f64 + 0.5) * 0.5 < 12.0 { 0.02 } else { 0.0 })
            .collect();
        let mut g_abel = vec![0.0f32; ncols];
        a.forward(&profile, &mut g_abel);
        let g2 = ParallelBeam::standard_2d(4, ncols, 0.75);
        let sino = ph.project(&Geometry::Parallel(g2));
        for c in 4..60 {
            let d = (g_abel[c] - sino.at(0, 0, c)).abs();
            assert!(d < 0.02 * 0.48 + 1e-3, "col {c}: {} vs {}", g_abel[c], sino.at(0, 0, c));
        }
    }
}
