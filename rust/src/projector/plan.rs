//! `ProjectionPlan` — the plan/execute split for the matched projector
//! pairs.
//!
//! Iterative solvers apply `A` and `Aᵀ` hundreds of times with the scan
//! geometry frozen, yet the one-shot entry points recompute every
//! per-view invariant (view trig, source/detector basis vectors, SF
//! footprint bounds, the Joseph marching axis) on each application. A
//! [`ProjectionPlan`] computes them once:
//!
//! * **plan** — [`Projector::plan`] walks the views and caches, per view:
//!   * ray-driven models (Siddon/Joseph, and Joseph as the modular-beam
//!     SF fallback): `(sin φ, cos φ)` so ray construction is pure
//!     arithmetic, plus the Joseph major axis where it is view-constant
//!     (parallel beams);
//!   * SF parallel: the shared transaxial trapezoid + evaluator per view
//!     ([`sf::ParallelViewPlan`]) plus **one** copy of the view-invariant
//!     per-slice detector-row weights ([`sf::ParallelRowWeights`] — rays
//!     are horizontal, so they are identical at every view);
//!   * SF cone: the per-voxel-column transaxial footprint (detector
//!     column weights + magnification/amplitude scalars,
//!     [`sf::ConeViewPlan`]) — `O(nx·ny)` per view, a factor `nz·nrows`
//!     below a stored system matrix;
//!   * SF fan: the view trig ([`sf::FanViewPlan`]).
//! * **execute** — [`ProjectionPlan::forward_into`] /
//!   [`ProjectionPlan::back_into`] replay the cached invariants. The
//!   direct `Projector::forward_into`/`back_into` run the *same* execute
//!   code with per-view invariants built on the fly inside the workers,
//!   so planned and direct outputs are **bit-identical by construction**
//!   (verified by `tests/plan_property.rs`).
//!
//! Ray-driven execution parallelizes over `(view, row)` units rather than
//! whole views: a few-view scan with many detector rows now load-balances
//! across all workers instead of leaving `threads − nviews` of them idle.
//!
//! Backprojection is **slab-owned**: every worker owns a disjoint slab of
//! the output volume (z-slabs, or y-slabs for single-slice scans) and
//! replays the sinogram units in global order, keeping only the
//! coefficients that land in its slab (cheap conservative ray/slab
//! interval rejection skips non-contributing rays before walking them).
//! There are no per-thread partial volumes and no reduction, and each
//! voxel accumulates its contributions in the same global unit order for
//! every thread count — backprojection floats are thread-count-invariant.
//!
//! The plan snapshots the projector's thread count (it is part of the
//! plan-cache identity and fixes the execution schedule), though results
//! no longer depend on it.
//!
//! The cone footprint cache is the only plan component that scales past
//! `O(nviews)`; when its estimate exceeds `LEAP_PLAN_MAX_BYTES` (default
//! 1 GiB) the plan transparently keeps per-view on-the-fly planning so
//! paper-scale scans never trade the one-copy memory claim for speed.

use crate::api::LeapError;
use crate::array::{Sino, Vol3};
use crate::backend::{self, BackendKind};
use crate::geometry::{Geometry, Ray, VolumeGeometry};
use crate::precision::{StorageTier, TieredSino};
use crate::util::pool::{self, chunk_ranges, parallel_items, run_region, ParWriter};

use super::{joseph, sf, siddon, Model, Projector};

/// Precomputed per-view invariants for one `(geometry, volume, model)`
/// triple. Build once with [`Projector::plan`], apply many times.
///
/// The plan also snapshots the projector's [`BackendKind`]: cached
/// invariants describe the *scan* (they are backend-independent), but the
/// execute step dispatches through the snapshot, and [`Self::lower`]
/// rebinds a plan to another backend without re-planning.
#[derive(Clone)]
pub struct ProjectionPlan {
    geom: Geometry,
    vg: VolumeGeometry,
    model: Model,
    threads: usize,
    backend: BackendKind,
    storage: StorageTier,
    kind: PlanKind,
}

#[derive(Clone)]
enum PlanKind {
    Ray { use_siddon: bool, views: RayViews },
    SfParallel(sf::ParallelPlanSet),
    SfFan(Vec<sf::FanViewPlan>),
    SfCone(Vec<sf::ConeViewPlan>),
    /// The cone footprint cache would exceed [`plan_max_bytes`]; execute
    /// plans each view on the fly instead — identical output (same code
    /// path as the direct projector), `O(nx·ny)` transient memory per
    /// worker instead of `O(nviews·nx·ny)` resident.
    SfConeUncached,
}

/// Default cap on a single plan's SF cone footprint cache (1 GiB). A
/// paper-scale 720-view 512² scan estimates tens of GiB — far past what
/// "plan reuse" should silently pin — so such plans degrade to on-the-fly
/// per-view planning. Override with the `LEAP_PLAN_MAX_BYTES` env var.
const DEFAULT_PLAN_MAX_BYTES: usize = 1 << 30;

fn plan_max_bytes() -> usize {
    std::env::var("LEAP_PLAN_MAX_BYTES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_PLAN_MAX_BYTES)
}

/// Shared shape validation for the direct and planned entry points — one
/// definition so the two paths can never diverge.
pub(crate) fn check_shapes(geom: &Geometry, vg: &VolumeGeometry, vol: &Vol3, sino: &Sino) {
    assert_eq!(vol.len(), vg.num_voxels(), "volume shape mismatch");
    assert_eq!(
        (sino.nviews, sino.nrows, sino.ncols),
        (geom.nviews(), geom.nrows(), geom.ncols()),
        "sinogram shape mismatch"
    );
}

/// Cached per-view ray-construction invariants.
#[derive(Clone)]
pub(crate) struct RayViews {
    /// `(sin φ, cos φ)` per view; empty for modular beams (their poses
    /// are already explicit per view).
    trig: Vec<(f64, f64)>,
    /// Joseph marching axis per view; non-empty only for parallel beams
    /// under the Joseph model (the one case where rays of a view share a
    /// direction).
    axis: Vec<usize>,
    /// Per-ray slab-axis voxel span `[(view, row, col)] → [s_lo, s_hi]`
    /// (inclusive, one voxel of padding folded in): the quantized form
    /// of the ray's coordinate extent along the backprojection slab
    /// axis over the voxel-padded volume clip ([`ray_slab_interval`]),
    /// computed once at plan time. Slab rejection then costs two
    /// integer compares — no ray construction, no 3-axis clip — per
    /// `(ray, slab)` pair, which is what lets the y-slab replay of 2-D
    /// fan/modular backprojection scale with threads instead of paying
    /// a full per-ray clip pass on every slab. 4 B per ray — one extra
    /// sinogram-sized table per held ray plan. `(u16::MAX, 0)` marks
    /// rays that miss the padded box (rejects against every slab);
    /// empty when the slab axis has too many voxels for `u16`
    /// (execute falls back to the on-the-fly clip).
    slab_span: Vec<(u16, u16)>,
}

impl RayViews {
    fn build(geom: &Geometry, model: Model, vg: &VolumeGeometry, threads: usize) -> RayViews {
        let trig: Vec<(f64, f64)> = match geom {
            Geometry::Parallel(g) => g.angles.iter().map(|a| a.sin_cos()).collect(),
            Geometry::Fan(g) => g.angles.iter().map(|a| a.sin_cos()).collect(),
            Geometry::Cone(g) => g.angles.iter().map(|a| a.sin_cos()).collect(),
            Geometry::Modular(_) => Vec::new(),
        };
        let axis = match (geom, model) {
            (Geometry::Parallel(g), Model::Joseph) => trig
                .iter()
                .map(|&(s, c)| joseph::major_axis(&g.ray_with_trig(s, c, 0.0, 0.0).dir))
                .collect(),
            _ => Vec::new(),
        };
        // slab axis mirrors ray_back_exec: z-slabs, y-slabs for nz == 1.
        // Rays come from ray_for with the cached trig — bit-identical to
        // the rays the execute step walks.
        let slab_ax = if vg.nz > 1 { 2usize } else { 1 };
        let n_ax = if slab_ax == 2 { vg.nz } else { vg.ny };
        if n_ax >= u16::MAX as usize {
            return RayViews { trig, axis, slab_span: Vec::new() };
        }
        let (lo, hi) = vg.bounds();
        let pitch = [vg.vx, vg.vy, vg.vz];
        let nrows = geom.nrows();
        let ncols = geom.ncols();
        let per_view = build_views(geom.nviews(), threads, |view| {
            let vt = if trig.is_empty() { None } else { Some(trig[view]) };
            let mut spans = Vec::with_capacity(nrows * ncols);
            for row in 0..nrows {
                for col in 0..ncols {
                    let ray = ray_for(geom, vt, view, row, col);
                    let iv = ray_slab_interval(&ray, &lo, &hi, &pitch, slab_ax);
                    spans.push(span_of_interval(iv, lo[slab_ax], pitch[slab_ax], n_ax));
                }
            }
            spans
        });
        RayViews { trig, axis, slab_span: per_view.concat() }
    }
}

/// A span that rejects against every slab (ray misses the padded box).
const MISS_SPAN: (u16, u16) = (u16::MAX, 0);

/// Quantize a ray's slab-axis interval to an inclusive voxel-index span
/// `[s_lo, s_hi]` with the one-voxel padding of the slab test folded in.
/// A chunk of voxel indices `[c0, c1)` can receive deposits from the ray
/// only if `s_hi >= c0 && s_lo <= c1` — two integer compares replacing
/// the float interval-vs-padded-extent test. Quantization only ever
/// widens (floor/ceil plus clamping), so the span test accepts a
/// superset of the rays [`ray_touches_slab`] accepts; the extra rays are
/// provably non-contributing inside the chunk, so walking them deposits
/// nothing and outputs are unchanged.
fn span_of_interval(iv: (f64, f64), ax_origin: f64, pitch: f64, n_ax: usize) -> (u16, u16) {
    let (w_lo, w_hi) = iv;
    if w_lo > w_hi {
        return MISS_SPAN; // the (∞, −∞) miss marker
    }
    // fractional voxel coordinates, padded one voxel outward — matches
    // the ±pitch padding ray_touches_slab applies to the slab extent
    let s_lo_f = ((w_lo - ax_origin) / pitch - 1.0).floor();
    let s_hi_f = ((w_hi - ax_origin) / pitch + 1.0).ceil();
    if s_hi_f < 0.0 || s_lo_f > n_ax as f64 {
        return MISS_SPAN; // strictly outside even the padded test
    }
    let s_lo = s_lo_f.max(0.0) as usize;
    let s_hi = s_hi_f.max(0.0).min(n_ax as f64) as usize;
    (s_lo as u16, s_hi as u16)
}

/// Build `f(view)` for every view, in view order, using the worker pool.
fn build_views<T, F>(nviews: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    pool::parallel_map_reduce(
        nviews,
        threads,
        |v0, v1| (v0..v1).map(&f).collect::<Vec<T>>(),
        |mut a, mut b| {
            a.append(&mut b);
            a
        },
    )
    .unwrap_or_default()
}

impl ProjectionPlan {
    /// Precompute the per-view invariants for `p`'s scan (the plan step).
    pub fn new(p: &Projector) -> ProjectionPlan {
        Self::new_with_cap(p, plan_max_bytes())
    }

    /// [`Self::new`] with an explicit cone-footprint-cache cap in bytes.
    fn new_with_cap(p: &Projector, cap_bytes: usize) -> ProjectionPlan {
        let threads = p.threads;
        let kind = match (p.model, &p.geom) {
            (Model::SF, Geometry::Parallel(g)) => PlanKind::SfParallel(sf::ParallelPlanSet {
                views: build_views(g.angles.len(), threads, |v| {
                    sf::plan_parallel_view(&p.vg, g, v)
                }),
                // view-invariant: one copy per plan, not one per view
                rows: sf::plan_parallel_rows(&p.vg, g),
            }),
            (Model::SF, Geometry::Fan(g)) => {
                PlanKind::SfFan((0..g.angles.len()).map(|v| sf::plan_fan_view(g, v)).collect())
            }
            (Model::SF, Geometry::Cone(g)) => {
                if sf::cone_plan_estimate_bytes(g, &p.vg) > cap_bytes {
                    PlanKind::SfConeUncached
                } else {
                    PlanKind::SfCone(build_views(g.angles.len(), threads, |v| {
                        sf::plan_cone_view(&p.vg, g, v)
                    }))
                }
            }
            (model, geom) => PlanKind::Ray {
                use_siddon: model == Model::Siddon,
                views: RayViews::build(geom, model, &p.vg, threads),
            },
        };
        let mut kind = kind;
        // Reduced-precision tiers store the cone footprint cache packed
        // (u32 column + u16 coefficient bits). The packed arena decodes to
        // exactly `tier.quantize(coeff)` — the same value the uncached /
        // direct path produces by quantizing its transient per-view scratch
        // — so planned and direct execution stay bit-identical per tier.
        if p.storage != StorageTier::F32 {
            if let PlanKind::SfCone(vs) = &mut kind {
                for vp in vs.iter_mut() {
                    vp.pack(p.storage);
                }
            }
        }
        ProjectionPlan {
            geom: p.geom.clone(),
            vg: p.vg.clone(),
            model: p.model,
            threads,
            backend: p.backend,
            storage: p.storage,
            kind,
        }
    }

    /// Does this plan describe the same scan as `p` — geometry, volume
    /// grid, model, thread count **and** backend? Slab-owned
    /// backprojection made the floats thread-count-invariant, but the
    /// thread count still fixes the execution schedule and keys the
    /// coordinator's plan cache, so it stays part of the plan identity —
    /// and the backend selects the kernel tier, so it must too.
    pub fn matches(&self, p: &Projector) -> bool {
        self.model == p.model
            && self.threads == p.threads
            && self.backend == p.backend
            && self.storage == p.storage
            && self.vg == p.vg
            && self.geom == p.geom
    }

    /// Rebind this plan to another backend without re-planning (the
    /// lowering step): the cached per-view invariants describe the scan,
    /// not the execution tier, so lowering is a snapshot-and-rebind.
    /// Non-executing slots (the feature-gated PJRT engine) are rejected
    /// with a typed error — the same capability gate
    /// [`crate::api::ScanBuilder`] applies before a projector is built.
    pub fn lower(&self, kind: BackendKind) -> Result<ProjectionPlan, LeapError> {
        if !backend::get(kind).caps().projection {
            return Err(LeapError::Unsupported(format!(
                "backend {:?} cannot execute projection (registered slot only; \
                 enable and wire its engine to use it)",
                kind.name()
            )));
        }
        let mut lowered = self.clone();
        lowered.backend = kind;
        Ok(lowered)
    }

    pub fn geom(&self) -> &Geometry {
        &self.geom
    }

    pub fn vg(&self) -> &VolumeGeometry {
        &self.vg
    }

    pub fn model(&self) -> Model {
        self.model
    }

    /// Thread count the plan's execution schedule was built for (part of
    /// the plan identity; see [`Self::matches`]).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Compute backend the execute step dispatches through (part of the
    /// plan identity; see [`Self::matches`] and [`Self::lower`]).
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Storage tier the plan's coefficient tables were packed for and the
    /// execute step quantizes through (part of the plan identity; see
    /// [`Self::matches`] and [`crate::precision::StorageTier`]).
    pub fn storage(&self) -> StorageTier {
        self.storage
    }

    /// `true` when the SIMD tier should drive this plan's kernels (same
    /// contract as `Projector::kernel_simd`: the PJRT slot cannot reach
    /// execute — [`Self::lower`] and the builder gates reject it first).
    fn kernel_simd(&self) -> bool {
        match self.backend {
            BackendKind::Scalar => false,
            BackendKind::Simd => true,
            BackendKind::Pjrt => panic!(
                "pjrt backend is a registered slot, not an executable tier \
                 (validated entry points reject it before kernel dispatch)"
            ),
        }
    }

    /// Pre-build estimate (bytes) of what [`Self::new`] would cache for
    /// `p` — lets callers like the coordinator's
    /// [`crate::coordinator::PlanCache`] decide *before* planning whether
    /// the result is worth building under a memory budget. The SF
    /// estimates are derived from the real plan layouts via `size_of` in
    /// [`sf::parallel_plan_estimate_bytes`] /
    /// [`sf::cone_plan_estimate_bytes`] — one definition shared with the
    /// byte-budget tests, so the estimate and the actual
    /// [`Self::approx_heap_bytes`] cannot silently drift apart.
    pub fn estimate_heap_bytes(p: &Projector) -> usize {
        match (p.model, &p.geom) {
            (Model::SF, Geometry::Cone(g)) => sf::cone_plan_estimate_bytes(g, &p.vg),
            (Model::SF, Geometry::Parallel(g)) => sf::parallel_plan_estimate_bytes(&p.vg, g),
            (Model::SF, Geometry::Fan(g)) => g.angles.len() * std::mem::size_of::<sf::FanViewPlan>(),
            // ray plans: per-view trig (+ marching axis for parallel
            // Joseph) plus the 4 B/ray slab-span table
            _ => {
                p.geom.nviews() * 24
                    + p.geom.nviews()
                        * p.geom.nrows()
                        * p.geom.ncols()
                        * std::mem::size_of::<(u16, u16)>()
            }
        }
    }

    /// Approximate heap bytes held by the cached per-view invariants —
    /// used by [`crate::coordinator::PlanCache`] for byte-bounded
    /// eviction, and useful for capacity planning.
    pub fn approx_heap_bytes(&self) -> usize {
        match &self.kind {
            PlanKind::Ray { views, .. } => {
                views.trig.len() * std::mem::size_of::<(f64, f64)>()
                    + views.axis.len() * std::mem::size_of::<usize>()
                    + views.slab_span.len() * std::mem::size_of::<(u16, u16)>()
            }
            PlanKind::SfParallel(set) => set.approx_bytes(),
            PlanKind::SfFan(vs) => vs.len() * std::mem::size_of::<sf::FanViewPlan>(),
            PlanKind::SfCone(vs) => vs.iter().map(|v| v.approx_bytes()).sum(),
            PlanKind::SfConeUncached => 0,
        }
    }

    /// Allocate a correctly-shaped sinogram for this scan.
    pub fn new_sino(&self) -> Sino {
        Sino::zeros(self.geom.nviews(), self.geom.nrows(), self.geom.ncols())
    }

    /// Allocate a correctly-shaped volume.
    pub fn new_vol(&self) -> Vol3 {
        Vol3::zeros(self.vg.nx, self.vg.ny, self.vg.nz)
    }

    /// Forward projection `sino = A·vol` through the cached plan
    /// (overwrites `sino`).
    pub fn forward_into(&self, vol: &Vol3, sino: &mut Sino) {
        self.forward_into_with_threads(vol, sino, self.threads)
    }

    /// [`Self::forward_into`] with an explicit worker count for this one
    /// application. Outputs are bit-identical for every `threads` value
    /// (the slab/unit ownership keeps accumulation order fixed); the
    /// batched operator layer ([`crate::ops`]) uses this to split the
    /// pool between the items of one stacked batch.
    pub fn forward_into_with_threads(&self, vol: &Vol3, sino: &mut Sino, threads: usize) {
        self.forward_range_into_with_threads(vol, sino, threads, 0, self.forward_shard_units())
    }

    /// Number of independent shard units one forward application divides
    /// into: **views**, for every plan kind — each view owns a disjoint
    /// sinogram slab, so any partition of `0..forward_shard_units()` into
    /// contiguous ranges executed via
    /// [`Self::forward_range_into_with_threads`] stitches to the
    /// unsharded output bit for bit.
    pub fn forward_shard_units(&self) -> usize {
        self.geom.nviews()
    }

    /// Number of independent shard units one backprojection divides into
    /// — the output-ownership granularity of each kind's gather/replay:
    /// `(z, y)` voxel rows for parallel-beam SF, `y` rows for fan/cone
    /// SF, and slab-axis slices (z; y when `nz == 1`) for the ray
    /// models. Every owned voxel replays all views in global order, so
    /// sharding by unit ranges preserves each voxel's accumulation chain
    /// exactly (see [`Self::back_range_into_with_threads`]).
    pub fn back_shard_units(&self) -> usize {
        match &self.kind {
            PlanKind::SfParallel(_) => self.vg.nz * self.vg.ny,
            PlanKind::SfFan(_) | PlanKind::SfCone(_) | PlanKind::SfConeUncached => self.vg.ny,
            PlanKind::Ray { .. } => {
                if self.vg.nz > 1 {
                    self.vg.nz
                } else {
                    self.vg.ny
                }
            }
        }
    }

    /// Forward projection restricted to the view range `v0..v1`: zeroes
    /// and writes only those views' sinogram slabs. Executing any
    /// partition of `0..forward_shard_units()` into one buffer
    /// reproduces [`Self::forward_into_with_threads`] bit for bit — the
    /// kernel each shard runs is the *same* range-restricted executor
    /// the full path runs over the full range, so there is one code
    /// path, not a sharded re-implementation.
    pub fn forward_range_into_with_threads(
        &self,
        vol: &Vol3,
        sino: &mut Sino,
        threads: usize,
        v0: usize,
        v1: usize,
    ) {
        check_shapes(&self.geom, &self.vg, vol, sino);
        let threads = threads.max(1);
        let simd = self.kernel_simd();
        match &self.kind {
            PlanKind::SfParallel(set) if simd => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                backend::simd::forward_parallel_simd_range(
                    &self.vg,
                    g,
                    Some(set),
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::SfParallel(set) => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                sf::forward_parallel_range(&self.vg, g, Some(set), vol, sino, threads, v0, v1)
            }
            PlanKind::SfFan(vs) if simd => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                backend::simd::forward_fan_simd_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::SfFan(vs) => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                sf::forward_fan_range(&self.vg, g, Some(vs.as_slice()), vol, sino, threads, v0, v1)
            }
            PlanKind::SfCone(vs) if simd => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                backend::simd::forward_cone_simd_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::SfCone(vs) => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::forward_cone_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::SfConeUncached if simd => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                backend::simd::forward_cone_simd_range(
                    &self.vg,
                    g,
                    None,
                    self.storage,
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::SfConeUncached => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::forward_cone_range(
                    &self.vg,
                    g,
                    None,
                    self.storage,
                    vol,
                    sino,
                    threads,
                    v0,
                    v1,
                )
            }
            PlanKind::Ray { use_siddon, views } => ray_forward_exec_range(
                &self.vg,
                &self.geom,
                Some(views),
                *use_siddon,
                simd,
                vol,
                sino,
                threads,
                v0,
                v1,
            ),
        }
    }

    /// Matched backprojection `vol = Aᵀ·sino` through the cached plan
    /// (overwrites `vol`).
    pub fn back_into(&self, sino: &Sino, vol: &mut Vol3) {
        self.back_into_with_threads(sino, vol, self.threads)
    }

    /// [`Self::back_into`] with an explicit worker count for this one
    /// application (see [`Self::forward_into_with_threads`]).
    pub fn back_into_with_threads(&self, sino: &Sino, vol: &mut Vol3, threads: usize) {
        self.back_range_into_with_threads(sino, vol, threads, 0, self.back_shard_units())
    }

    /// Matched backprojection restricted to the output-unit range
    /// `u0..u1` of [`Self::back_shard_units`]: zeroes and writes only the
    /// voxels those units own, but replays *every* view for them in the
    /// same global order as the full executor — so executing any
    /// partition of `0..back_shard_units()` into one buffer reproduces
    /// [`Self::back_into_with_threads`] bit for bit. Units are `(z, y)`
    /// voxel rows (parallel SF), `y` rows (fan/cone SF: each unit owns
    /// one x-row in every z-plane), or slab-axis slices (ray models).
    pub fn back_range_into_with_threads(
        &self,
        sino: &Sino,
        vol: &mut Vol3,
        threads: usize,
        u0: usize,
        u1: usize,
    ) {
        check_shapes(&self.geom, &self.vg, vol, sino);
        let threads = threads.max(1);
        let simd = self.kernel_simd();
        // Reduced-precision tiers model the sinogram held at rest in
        // tiered storage: quantize the input through one encode/decode
        // round-trip before the gather kernels read it. Deterministic and
        // schedule-independent (pure per-element map), so per-tier
        // thread-count invariance and range-stitching identities hold
        // unchanged; the f32 tier takes the borrow as-is.
        let quantized;
        let sino = if self.storage == StorageTier::F32 {
            sino
        } else {
            quantized = TieredSino::from_sino(self.storage, sino).to_sino();
            &quantized
        };
        match &self.kind {
            PlanKind::SfParallel(set) if simd => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                backend::simd::back_parallel_simd_range(
                    &self.vg,
                    g,
                    Some(set),
                    sino,
                    vol,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfParallel(set) => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                sf::back_parallel_range(&self.vg, g, Some(set), sino, vol, threads, u0, u1)
            }
            PlanKind::SfFan(vs) if simd => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                backend::simd::back_fan_simd_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    sino,
                    vol,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfFan(vs) => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                sf::back_fan_range(&self.vg, g, Some(vs.as_slice()), sino, vol, threads, u0, u1)
            }
            PlanKind::SfCone(vs) if simd => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                backend::simd::back_cone_simd_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    sino,
                    vol,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfCone(vs) => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::back_cone_range(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    sino,
                    vol,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfConeUncached if simd => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                backend::simd::back_cone_simd_range(
                    &self.vg,
                    g,
                    None,
                    self.storage,
                    sino,
                    vol,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfConeUncached => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::back_cone_range(&self.vg, g, None, self.storage, sino, vol, threads, u0, u1)
            }
            // ray backprojection has no safely vectorizable inner loop
            // (guarded indirect scatter): both CPU tiers share this path
            PlanKind::Ray { use_siddon, views } => ray_back_exec_range(
                &self.vg,
                &self.geom,
                Some(views),
                *use_siddon,
                sino,
                vol,
                threads,
                u0,
                u1,
            ),
        }
    }

    // -----------------------------------------------------------------
    // windowed (out-of-core) execution — the per-tile kernels behind
    // `crate::vol::TiledVol3`
    // -----------------------------------------------------------------

    /// `true` when this plan supports windowed (tile-buffer) execution:
    /// the scalar SF kernels, whose output-ownership units map to
    /// contiguous x-rows that a tile window can alias. Ray models would
    /// need slab-axis windows and the SIMD tier stages lane flushes
    /// through absolute indices; both fall back to resident execution at
    /// the [`crate::vol`] layer.
    pub(crate) fn supports_windows(&self) -> bool {
        !matches!(self.kind, PlanKind::Ray { .. }) && self.backend == BackendKind::Scalar
    }

    /// Number of z-planes a window of this plan's shard units spans: the
    /// unit range `u0..u1` owns a window buffer of
    /// `window_planes() · (u1 − u0) · nx` floats. Cone/fan SF units are
    /// y-rows owning one x-row in **every** z-plane (`nz` planes — 1 for
    /// the 2-D fan grid); parallel SF units are single `(z, y)` rows.
    pub(crate) fn window_planes(&self) -> usize {
        match &self.kind {
            PlanKind::SfParallel(_) | PlanKind::SfFan(_) => 1,
            PlanKind::SfCone(_) | PlanKind::SfConeUncached => self.vg.nz,
            PlanKind::Ray { .. } => panic!("ray plans do not execute through windows"),
        }
    }

    /// The nx-length rows a window over units `u0..u1` holds, as
    /// `(global_start, window_start)` flat-index pairs — the copy map
    /// between a window buffer and the full resident volume. Row interiors
    /// are contiguous in both layouts, so each pair describes one
    /// `copy_from_slice` of `nx` floats.
    pub(crate) fn window_runs(&self, u0: usize, u1: usize) -> Vec<(usize, usize)> {
        let nx = self.vg.nx;
        match &self.kind {
            PlanKind::SfParallel(_) | PlanKind::SfFan(_) => {
                (u0..u1).map(|m| (m * nx, (m - u0) * nx)).collect()
            }
            PlanKind::SfCone(_) | PlanKind::SfConeUncached => {
                let (ny, w) = (self.vg.ny, u1 - u0);
                let mut runs = Vec::with_capacity(self.vg.nz * w);
                for k in 0..self.vg.nz {
                    for j in u0..u1 {
                        runs.push((k * ny * nx + j * nx, k * w * nx + (j - u0) * nx));
                    }
                }
                runs
            }
            PlanKind::Ray { .. } => panic!("ray plans do not execute through windows"),
        }
    }

    /// Matched backprojection of units `u0..u1` into the window buffer
    /// `out` (layout per [`Self::window_planes`] /
    /// [`Self::window_runs`]): the same gather kernels as
    /// [`Self::back_range_into_with_threads`] with the write indices
    /// rebased into the window — index arithmetic only, so the window's
    /// floats are bit-identical to the corresponding rows of a resident
    /// backprojection.
    pub(crate) fn back_window_into(&self, sino: &Sino, out: &mut [f32], u0: usize, u1: usize) {
        assert!(self.supports_windows(), "plan does not support windowed execution");
        assert_eq!(
            (sino.nviews, sino.nrows, sino.ncols),
            (self.geom.nviews(), self.geom.nrows(), self.geom.ncols()),
            "sinogram shape mismatch"
        );
        let threads = self.threads.max(1);
        // same data-at-rest quantization as back_range_into_with_threads
        let quantized;
        let sino = if self.storage == StorageTier::F32 {
            sino
        } else {
            quantized = TieredSino::from_sino(self.storage, sino).to_sino();
            &quantized
        };
        match &self.kind {
            PlanKind::SfParallel(set) => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                sf::back_parallel_window(&self.vg, g, Some(set), sino, out, threads, u0, u1)
            }
            PlanKind::SfFan(vs) => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                sf::back_fan_window(&self.vg, g, Some(vs.as_slice()), sino, out, threads, u0, u1)
            }
            PlanKind::SfCone(vs) => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::back_cone_window(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    sino,
                    out,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfConeUncached => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::back_cone_window(&self.vg, g, None, self.storage, sino, out, threads, u0, u1)
            }
            PlanKind::Ray { .. } => unreachable!("supports_windows() rejected ray plans"),
        }
    }

    /// Forward-project the window buffer `win` (units `u0..u1`),
    /// **accumulating** into `sino` without zeroing it: the caller zeroes
    /// once and replays the tiles in ascending unit order, which appends
    /// each detector bin's contributions in exactly the per-bin `+=`
    /// order of the resident kernels — tiled forward output is
    /// bit-identical to resident output.
    pub(crate) fn forward_accum_window(&self, win: &[f32], u0: usize, u1: usize, sino: &mut Sino) {
        assert!(self.supports_windows(), "plan does not support windowed execution");
        assert_eq!(
            (sino.nviews, sino.nrows, sino.ncols),
            (self.geom.nviews(), self.geom.nrows(), self.geom.ncols()),
            "sinogram shape mismatch"
        );
        let threads = self.threads.max(1);
        match &self.kind {
            PlanKind::SfParallel(set) => {
                let Geometry::Parallel(g) = &self.geom else { unreachable!() };
                sf::forward_parallel_accum_window(&self.vg, g, Some(set), win, sino, threads, u0, u1)
            }
            PlanKind::SfFan(vs) => {
                let Geometry::Fan(g) = &self.geom else { unreachable!() };
                sf::forward_fan_accum_window(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    win,
                    sino,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfCone(vs) => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::forward_cone_accum_window(
                    &self.vg,
                    g,
                    Some(vs.as_slice()),
                    self.storage,
                    win,
                    sino,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::SfConeUncached => {
                let Geometry::Cone(g) = &self.geom else { unreachable!() };
                sf::forward_cone_accum_window(
                    &self.vg,
                    g,
                    None,
                    self.storage,
                    win,
                    sino,
                    threads,
                    u0,
                    u1,
                )
            }
            PlanKind::Ray { .. } => unreachable!("supports_windows() rejected ray plans"),
        }
    }

    /// `A·vol`, allocating the output.
    pub fn forward(&self, vol: &Vol3) -> Sino {
        let mut sino = self.new_sino();
        self.forward_into(vol, &mut sino);
        sino
    }

    /// `Aᵀ·sino`, allocating the output.
    pub fn back(&self, sino: &Sino) -> Vol3 {
        let mut vol = self.new_vol();
        self.back_into(sino, &mut vol);
        vol
    }

    /// `A·1`: per-ray total intersection, used by SIRT/SART normalization.
    pub fn forward_ones(&self) -> Sino {
        let mut ones = self.new_vol();
        ones.fill(1.0);
        self.forward(&ones)
    }

    /// `Aᵀ·1`: per-voxel total weight, used by SIRT/SART normalization.
    pub fn back_ones(&self) -> Vol3 {
        let mut ones = self.new_sino();
        ones.fill(1.0);
        self.back(&ones)
    }
}

// ---------------------------------------------------------------------------
// shared ray-driven executors (Siddon / Joseph / modular-SF fallback)
// ---------------------------------------------------------------------------

/// View trig for ray construction: cached from the plan when available,
/// else computed once per `(view, row)` unit (still hoisted out of the
/// per-ray loop). `None` for modular beams, whose rays come straight from
/// the stored poses.
#[inline]
fn view_trig(geom: &Geometry, views: Option<&RayViews>, view: usize) -> Option<(f64, f64)> {
    if let Some(v) = views {
        if !v.trig.is_empty() {
            return Some(v.trig[view]);
        }
        return None;
    }
    match geom {
        Geometry::Parallel(g) => Some(g.angles[view].sin_cos()),
        Geometry::Fan(g) => Some(g.angles[view].sin_cos()),
        Geometry::Cone(g) => Some(g.angles[view].sin_cos()),
        Geometry::Modular(_) => None,
    }
}

/// Joseph marching axis, where it is view-constant (parallel beams).
#[inline]
fn view_axis(
    geom: &Geometry,
    views: Option<&RayViews>,
    use_siddon: bool,
    trig: Option<(f64, f64)>,
    view: usize,
) -> Option<usize> {
    if use_siddon {
        return None;
    }
    let Geometry::Parallel(g) = geom else { return None };
    if let Some(v) = views {
        if !v.axis.is_empty() {
            return Some(v.axis[view]);
        }
    }
    let (s, c) = trig?;
    Some(joseph::major_axis(&g.ray_with_trig(s, c, 0.0, 0.0).dir))
}

/// The ray through `(view, row, col)`, from cached trig when available.
/// Delegates to the geometry's `ray_with_trig`, which `Geometry::ray`
/// itself uses, so both paths produce bit-identical rays.
#[inline]
fn ray_for(geom: &Geometry, trig: Option<(f64, f64)>, view: usize, row: usize, col: usize) -> Ray {
    match (geom, trig) {
        (Geometry::Parallel(g), Some((s, c))) => g.ray_with_trig(s, c, row as f64, col as f64),
        (Geometry::Fan(g), Some((s, c))) => g.ray_with_trig(s, c, col as f64),
        (Geometry::Cone(g), Some((s, c))) => g.ray_with_trig(s, c, row as f64, col as f64),
        _ => geom.ray(view, row, col),
    }
}

/// Walk one ray with the model's coefficient walker (Siddon exact
/// traversal, or Joseph with/without the cached view-constant axis) —
/// the single definition both accumulation shapes of
/// [`ray_forward_exec`] replay.
#[inline]
fn walk_one<F: FnMut(usize, f32)>(
    vg: &VolumeGeometry,
    ray: &Ray,
    use_siddon: bool,
    axis: Option<usize>,
    visit: F,
) {
    if use_siddon {
        siddon::walk_ray(vg, ray, visit);
    } else if let Some(a) = axis {
        joseph::walk_ray_with_axis(vg, ray, a, visit);
    } else {
        joseph::walk_ray(vg, ray, visit);
    }
}

/// Ray-driven forward projection over `(view, row)` units — each unit's
/// detector row is written by exactly one worker, so any schedule is
/// safe; units are handed out dynamically for load balance. Shared by
/// the direct path (`views = None`) and the planned path.
///
/// `simd` selects the marching accumulation shape: `false` keeps the
/// scalar running sum (the reference), `true` cycles each ray's terms
/// through 4 partial sums combined pairwise at the end — the
/// dependence-breaking shape the SIMD tier uses so the compiler can
/// vectorize the reduction. The summation tree differs, so the two
/// shapes agree to floating-point tolerance (not bit-identically); the
/// term order per ray is fixed either way, so each shape is
/// deterministic and thread-count-invariant.
pub(crate) fn ray_forward_exec(
    vg: &VolumeGeometry,
    geom: &Geometry,
    views: Option<&RayViews>,
    use_siddon: bool,
    simd: bool,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
) {
    let nviews = sino.nviews;
    ray_forward_exec_range(vg, geom, views, use_siddon, simd, vol, sino, threads, 0, nviews)
}

/// [`ray_forward_exec`] restricted to the view range `v0..v1`: zeroes
/// and writes only those views' sinogram slabs, walking the identical
/// per-`(view, row)` units the full executor would hand out for them.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ray_forward_exec_range(
    vg: &VolumeGeometry,
    geom: &Geometry,
    views: Option<&RayViews>,
    use_siddon: bool,
    simd: bool,
    vol: &Vol3,
    sino: &mut Sino,
    threads: usize,
    v0: usize,
    v1: usize,
) {
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    assert!(v0 <= v1 && v1 <= sino.nviews, "view range {v0}..{v1} out of 0..{}", sino.nviews);
    sino.data[v0 * nrows * ncols..v1 * nrows * ncols].fill(0.0);
    let out = ParWriter::new(&mut sino.data);
    parallel_items((v1 - v0) * nrows, threads, |r| {
        // each (view, row) unit owns its detector row of the sinogram
        let u = v0 * nrows + r;
        let view = u / nrows;
        let row = u % nrows;
        let trig = view_trig(geom, views, view);
        let axis = view_axis(geom, views, use_siddon, trig, view);
        let base = u * ncols;
        for col in 0..ncols {
            let ray = ray_for(geom, trig, view, row, col);
            let val = if simd {
                let mut acc = [0.0f32; 4];
                let mut lane = 0usize;
                walk_one(vg, &ray, use_siddon, axis, |idx, w| {
                    acc[lane & 3] += w * vol.data[idx];
                    lane += 1;
                });
                (acc[0] + acc[2]) + (acc[1] + acc[3])
            } else {
                let mut acc = 0.0f32;
                walk_one(vg, &ray, use_siddon, axis, |idx, w| acc += w * vol.data[idx]);
                acc
            };
            out.set(base + col, val);
        }
    });
}

/// The ray's coordinate interval along `slab_ax` over its traversal of
/// the volume's axis-aligned bounding box padded by one voxel on every
/// side, as `(w_lo, w_hi)` — or `(∞, −∞)` when the ray misses the padded
/// box entirely (so any overlap test fails). This is the plan-time half
/// of the conservative slab rejection: [`RayViews::build`] evaluates it
/// once per ray (then quantizes it via [`span_of_interval`], which only
/// widens), while the direct (unplanned) path evaluates it on the fly
/// through [`ray_touches_slab`]. The planned path may therefore walk a
/// few *extra* boundary rays the float test would reject — harmless,
/// because rejection is an optimization only: the per-deposit
/// `flat_lo..flat_hi` ownership guard in [`ray_back_exec`] is what
/// actually confines writes to the slab, and provably-non-touching rays
/// deposit nothing there. Outputs are identical either way.
#[inline]
fn ray_slab_interval(
    ray: &Ray,
    lo: &[f64; 3],
    hi: &[f64; 3],
    pitch: &[f64; 3],
    slab_ax: usize,
) -> (f64, f64) {
    const MISS: (f64, f64) = (f64::INFINITY, f64::NEG_INFINITY);
    let o = ray.origin;
    let d = ray.dir;
    let mut tmin = f64::NEG_INFINITY;
    let mut tmax = f64::INFINITY;
    for ax in 0..3 {
        let la = lo[ax] - pitch[ax];
        let ha = hi[ax] + pitch[ax];
        if d[ax].abs() < 1e-12 {
            if o[ax] <= la || o[ax] >= ha {
                return MISS;
            }
        } else {
            let ta = (la - o[ax]) / d[ax];
            let tb = (ha - o[ax]) / d[ax];
            tmin = tmin.max(ta.min(tb));
            tmax = tmax.min(ta.max(tb));
        }
    }
    if tmin >= tmax {
        return MISS;
    }
    if d[slab_ax].abs() < 1e-12 {
        (o[slab_ax], o[slab_ax])
    } else {
        let a = o[slab_ax] + tmin * d[slab_ax];
        let b = o[slab_ax] + tmax * d[slab_ax];
        if a <= b {
            (a, b)
        } else {
            (b, a)
        }
    }
}

/// Conservative ray/slab overlap test for the slab-owned ray-driven
/// backprojection: the ray's slab-axis extent over the voxel-padded
/// volume clip ([`ray_slab_interval`]) against the (already
/// voxel-padded) slab extent `[ax_lo, ax_hi]`. Must never reject a
/// contributing ray: the walkers (Siddon exact traversal, Joseph
/// ±1-cell bilinear) only deposit weight within one voxel of the ray
/// inside the *unpadded* box, which the double padding strictly
/// contains. A ray that misses the padded box misses the unpadded box,
/// where both walkers emit nothing.
#[inline]
fn ray_touches_slab(
    ray: &Ray,
    lo: &[f64; 3],
    hi: &[f64; 3],
    pitch: &[f64; 3],
    slab_ax: usize,
    ax_lo: f64,
    ax_hi: f64,
) -> bool {
    let (w_lo, w_hi) = ray_slab_interval(ray, lo, hi, pitch, slab_ax);
    w_hi >= ax_lo && w_lo <= ax_hi
}

/// Ray-driven matched backprojection, slab-owned: each worker owns a
/// contiguous slab of the volume (z-slabs; y-slabs when `nz == 1`) and
/// replays every `(view, row, col)` ray in global order, accumulating
/// only the coefficients that land in its slab. A conservative ray/slab
/// interval test skips rays that cannot touch the slab before walking
/// them, so near-axial geometries (parallel 3-D, small-cone scans) keep
/// close to `1/threads` of the walk work per worker. There are no
/// per-thread partial volumes and no reduction, and every voxel sums its
/// contributions in the same global order for any thread count —
/// backprojection floats are thread-count-invariant. On the planned path
/// the per-ray slab spans come precomputed from [`RayViews::build`], so
/// replaying a slab skips non-touching rays with two integer compares
/// and no ray construction — this is what restored thread scaling for
/// the 2-D fan/modular y-slab replay (previously each worker re-ran the
/// full 3-axis clip for every ray, which on small in-plane problems cost
/// about as much as the surviving walks). Shared by the direct and
/// planned paths; both reject exactly the same rays (identical interval
/// math), so outputs stay bit-identical.
pub(crate) fn ray_back_exec(
    vg: &VolumeGeometry,
    geom: &Geometry,
    views: Option<&RayViews>,
    use_siddon: bool,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
) {
    let n_ax = if vg.nz > 1 { vg.nz } else { vg.ny };
    ray_back_exec_range(vg, geom, views, use_siddon, sino, vol, threads, 0, n_ax)
}

/// [`ray_back_exec`] restricted to the slab-axis unit range `u0..u1`
/// (z-slices; y-rows when `nz == 1`): zeroes and writes only that
/// contiguous volume slab. Each owned voxel still replays *all* views in
/// global order — exactly the accumulation chain the full executor runs
/// for it — so stitching any partition of `0..n_ax` reproduces the
/// unsharded volume bit for bit. The absolute slab bounds feed the same
/// span-rejection compares and the same `flat_lo..flat_hi` ownership
/// guard as the full path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ray_back_exec_range(
    vg: &VolumeGeometry,
    geom: &Geometry,
    views: Option<&RayViews>,
    use_siddon: bool,
    sino: &Sino,
    vol: &mut Vol3,
    threads: usize,
    u0: usize,
    u1: usize,
) {
    let nrows = sino.nrows;
    let ncols = sino.ncols;
    let units = sino.nviews * nrows;
    // slab axis: z when the volume has depth, else y (single-slice scans)
    let slab_ax = if vg.nz > 1 { 2usize } else { 1 };
    let (n_ax, plane) = if slab_ax == 2 { (vg.nz, vg.nx * vg.ny) } else { (vg.ny, vg.nx) };
    assert!(u0 <= u1 && u1 <= n_ax, "slab range {u0}..{u1} out of 0..{n_ax}");
    vol.data[u0 * plane..u1 * plane].fill(0.0);
    if units == 0 || u0 == u1 {
        return;
    }
    let slabs: Vec<(usize, usize)> = chunk_ranges(u1 - u0, threads)
        .into_iter()
        .map(|(a, b)| (u0 + a, u0 + b))
        .collect();
    let (lo, hi) = vg.bounds();
    let pitch = [vg.vx, vg.vy, vg.vz];
    // planned path: the per-ray slab spans were precomputed at plan time
    // (ray_slab_interval quantized to voxel indices), so per (ray, slab)
    // rejection is two integer compares before any ray is constructed
    let cached_span = views
        .map(|v| v.slab_span.as_slice())
        .filter(|s| s.len() == units * ncols);
    let out = ParWriter::new(&mut vol.data);
    run_region(slabs.len(), |slot| {
        let (s0, s1) = slabs[slot];
        let flat_lo = s0 * plane;
        let flat_hi = s1 * plane;
        // world extent of this slab along the slab axis, padded one voxel
        // (walkers deposit within a voxel of the ray; see ray_touches_slab)
        let ax_lo = lo[slab_ax] + s0 as f64 * pitch[slab_ax] - pitch[slab_ax];
        let ax_hi = lo[slab_ax] + s1 as f64 * pitch[slab_ax] + pitch[slab_ax];
        // flat indices [flat_lo, flat_hi) are owned by this slot
        // units advance view-major, so the per-view invariants are cached
        // across the nrows × ncols rays of a view instead of re-derived
        // per unit
        let mut cur_view = usize::MAX;
        let mut trig = None;
        let mut axis = None;
        for u in 0..units {
            let view = u / nrows;
            if view != cur_view {
                cur_view = view;
                trig = view_trig(geom, views, view);
                axis = view_axis(geom, views, use_siddon, trig, view);
            }
            let row = u % nrows;
            let base = u * ncols;
            for col in 0..ncols {
                let y = sino.data[base + col];
                if y == 0.0 {
                    continue;
                }
                if let Some(spans) = cached_span {
                    let (sp_lo, sp_hi) = spans[base + col];
                    if (sp_hi as usize) < s0 || (sp_lo as usize) > s1 {
                        continue;
                    }
                }
                let ray = ray_for(geom, trig, view, row, col);
                if cached_span.is_none()
                    && !ray_touches_slab(&ray, &lo, &hi, &pitch, slab_ax, ax_lo, ax_hi)
                {
                    continue;
                }
                let deposit = |idx: usize, w: f32| {
                    if idx >= flat_lo && idx < flat_hi {
                        out.add(idx, w * y);
                    }
                };
                if use_siddon {
                    siddon::walk_ray(vg, &ray, deposit);
                } else if let Some(a) = axis {
                    joseph::walk_ray_with_axis(vg, &ray, a, deposit);
                } else {
                    joseph::walk_ray(vg, &ray, deposit);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{ConeBeam, FanBeam, ModularBeam, ParallelBeam};
    use crate::util::rng::Rng;

    fn geometries() -> Vec<Geometry> {
        let cone = ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0);
        let mut curved = cone.clone();
        curved.shape = crate::geometry::DetectorShape::Curved;
        vec![
            Geometry::Parallel(ParallelBeam::standard_3d(6, 6, 10, 1.2, 1.2)),
            Geometry::Fan(FanBeam::standard(5, 14, 1.3, 50.0, 100.0)),
            Geometry::Cone(cone.clone()),
            Geometry::Cone(curved),
            Geometry::Modular(ModularBeam::from_cone(&cone)),
        ]
    }

    #[test]
    fn plan_path_is_bit_identical_to_direct_path() {
        let mut rng = Rng::new(7);
        for geom in geometries() {
            let vg = if matches!(geom, Geometry::Fan(_)) {
                VolumeGeometry::slice2d(9, 9, 1.0)
            } else {
                VolumeGeometry::cube(8, 1.0)
            };
            for model in [Model::Siddon, Model::Joseph, Model::SF] {
                let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(3);
                let plan = p.plan();
                let mut x = p.new_vol();
                let mut y = p.new_sino();
                rng.fill_uniform(&mut x.data, 0.0, 1.0);
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                let fwd_direct = p.forward(&x);
                let fwd_planned = plan.forward(&x);
                assert_eq!(
                    fwd_direct.data,
                    fwd_planned.data,
                    "{}/{} forward",
                    model.name(),
                    p.geom.kind()
                );
                let back_direct = p.back(&y);
                let back_planned = plan.back(&y);
                assert_eq!(
                    back_direct.data,
                    back_planned.data,
                    "{}/{} back",
                    model.name(),
                    p.geom.kind()
                );
            }
        }
    }

    #[test]
    fn oversized_cone_plan_degrades_to_on_the_fly_and_stays_identical() {
        // cap 0 forces the uncached path: output must still match the
        // direct path exactly, with no resident footprint cache
        let vg = VolumeGeometry::cube(8, 1.0);
        let g = Geometry::Cone(ConeBeam::standard(5, 6, 10, 1.5, 1.5, 50.0, 100.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        let capped = ProjectionPlan::new_with_cap(&p, 0);
        assert_eq!(capped.approx_heap_bytes(), 0);
        let mut rng = Rng::new(9);
        let mut x = p.new_vol();
        rng.fill_uniform(&mut x.data, 0.0, 1.0);
        assert_eq!(p.forward(&x).data, capped.forward(&x).data);
        let y = p.forward(&x);
        assert_eq!(p.back(&y).data, capped.back(&y).data);
    }

    #[test]
    fn precomputed_slab_spans_are_conservative() {
        // every voxel a walker deposits into must have its slab-axis
        // index inside the ray's precomputed span — the property the
        // slab-owned replay's two-compare rejection relies on
        for geom in geometries() {
            let vg = if matches!(geom, Geometry::Fan(_)) {
                VolumeGeometry::slice2d(9, 9, 1.0)
            } else {
                VolumeGeometry::cube(8, 1.0)
            };
            let slab_ax = if vg.nz > 1 { 2usize } else { 1 };
            let nrows = geom.nrows();
            let ncols = geom.ncols();
            for model in [Model::Siddon, Model::Joseph] {
                let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(2);
                let plan = p.plan();
                let PlanKind::Ray { use_siddon, views } = &plan.kind else {
                    panic!("ray model must build a ray plan")
                };
                assert_eq!(views.slab_span.len(), geom.nviews() * nrows * ncols);
                for view in 0..geom.nviews() {
                    let trig = view_trig(&p.geom, Some(views), view);
                    let axis = view_axis(&p.geom, Some(views), *use_siddon, trig, view);
                    for row in 0..nrows {
                        for col in 0..ncols {
                            let ray = ray_for(&p.geom, trig, view, row, col);
                            let (sp_lo, sp_hi) =
                                views.slab_span[(view * nrows + row) * ncols + col];
                            let check = |idx: usize, _w: f32| {
                                let rest = idx / vg.nx;
                                let a = if slab_ax == 2 { rest / vg.ny } else { rest % vg.ny };
                                assert!(
                                    (sp_lo as usize) <= a && a <= (sp_hi as usize),
                                    "{}/{} view {view} row {row} col {col}: \
                                     deposit at axis index {a} outside span \
                                     [{sp_lo}, {sp_hi}]",
                                    model.name(),
                                    p.geom.kind()
                                );
                            };
                            if *use_siddon {
                                siddon::walk_ray(&vg, &ray, check);
                            } else if let Some(a) = axis {
                                joseph::walk_ray_with_axis(&vg, &ray, a, check);
                            } else {
                                joseph::walk_ray(&vg, &ray, check);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn two_d_y_slab_replay_uses_the_span_table_and_stays_thread_invariant() {
        // Regression for the PR 2 accepted tradeoff: 2-D fan/modular
        // ray-driven backprojection used to replay every ray per y-slab
        // with only the full 3-axis clip test (≈serial wall-clock). The
        // per-ray span table must (a) exist for single-slice plans of
        // BOTH geometries — so the replay rejects with two integer
        // compares — (b) actually reject (not degenerate to full-axis
        // spans), and (c) keep backprojection bit-identical across
        // thread counts.
        let single_row_cone = ConeBeam::standard(6, 1, 14, 1.3, 1.3, 50.0, 100.0);
        let geoms = vec![
            Geometry::Fan(FanBeam::standard(6, 14, 1.3, 50.0, 100.0)),
            Geometry::Modular(ModularBeam::from_cone(&single_row_cone)),
        ];
        let vg = VolumeGeometry::slice2d(9, 9, 1.0);
        let mut rng = Rng::new(31);
        for geom in geoms {
            for model in [Model::Siddon, Model::Joseph] {
                let p = Projector::new(geom.clone(), vg.clone(), model).with_threads(4);
                let plan = p.plan();
                let PlanKind::Ray { views, .. } = &plan.kind else {
                    panic!("ray model must build a ray plan")
                };
                let units = geom.nviews() * geom.nrows() * geom.ncols();
                assert_eq!(
                    views.slab_span.len(),
                    units,
                    "{}/{}: 2-D plan must carry a full span table",
                    model.name(),
                    p.geom.kind()
                );
                // real rejection: some ray must span strictly less than
                // the whole y axis (an all-[0, ny-1] table would mean the
                // replay degenerates back to walking every ray per slab)
                let ny = vg.ny as u16;
                assert!(
                    views
                        .slab_span
                        .iter()
                        .any(|&(lo, hi)| lo > hi || (hi - lo + 1) < ny),
                    "{}/{}: span table rejects nothing",
                    model.name(),
                    p.geom.kind()
                );
                // thread-count invariance of the y-slab replay itself
                let mut y = p.new_sino();
                rng.fill_uniform(&mut y.data, 0.0, 1.0);
                let p1 = Projector::new(geom.clone(), vg.clone(), model).with_threads(1);
                let reference = p1.plan().back(&y);
                for threads in [2usize, 4, 7] {
                    let pn = Projector::new(geom.clone(), vg.clone(), model)
                        .with_threads(threads);
                    assert_eq!(
                        reference.data,
                        pn.plan().back(&y).data,
                        "{}/{} back, {threads} threads",
                        model.name(),
                        pn.geom.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn sf_parallel_estimate_matches_actual_layout() {
        // pure 2-D: the size_of-derived shared estimate is exact
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(7, 16, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        assert_eq!(ProjectionPlan::estimate_heap_bytes(&p), p.plan().approx_heap_bytes());

        // 3-D: an upper bound, tight to within the estimated overlap
        // entries per slice
        let vg3 = VolumeGeometry::cube(10, 1.0);
        let g3 = Geometry::Parallel(ParallelBeam::standard_3d(5, 6, 10, 1.3, 1.3));
        let p3 = Projector::new(g3, vg3.clone(), Model::SF).with_threads(2);
        let est = ProjectionPlan::estimate_heap_bytes(&p3);
        let act = p3.plan().approx_heap_bytes();
        assert!(est >= act, "estimate {est} must bound actual {act}");
        let slack = vg3.nz * 2 * std::mem::size_of::<(usize, f64)>();
        assert!(est - act <= slack, "estimate {est} vs actual {act}: slack over {slack}");
    }

    #[test]
    fn plan_matches_its_projector_only() {
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(4, 12, 1.0));
        let p = Projector::new(g.clone(), vg.clone(), Model::SF);
        let plan = p.plan();
        assert!(plan.matches(&p));
        let other = Projector::new(g, vg, Model::Joseph);
        assert!(!plan.matches(&other));
    }
}
