//! Siddon's method (Siddon 1985): the exact radiological path of a ray
//! through a voxel grid.
//!
//! The ray is clipped to the grid, then marched from plane crossing to
//! plane crossing; each segment lies inside exactly one voxel and its
//! length (mm) is the system-matrix coefficient. The walk is expressed as
//! a visitor — forward projection accumulates `w·x[idx]`, backprojection
//! scatters `w·y` — so the forward/back pair shares the *identical*
//! coefficients and is exactly matched (paper §2.1).

use crate::geometry::{Ray, VolumeGeometry};

/// March `ray` through `vg`, invoking `visit(flat_index, length_mm)` for
/// every voxel the ray crosses. The flat index uses the `Vol3` layout
/// (`(k·ny + j)·nx + i`). Direction must be unit length, so `t` is mm.
pub fn walk_ray<F: FnMut(usize, f32)>(vg: &VolumeGeometry, ray: &Ray, mut visit: F) {
    let (lo, hi) = vg.bounds();
    let o = ray.origin;
    let d = ray.dir;

    // clip to the volume slab-by-slab
    let mut tmin = f64::NEG_INFINITY;
    let mut tmax = f64::INFINITY;
    for ax in 0..3 {
        if d[ax].abs() < 1e-12 {
            if o[ax] <= lo[ax] || o[ax] >= hi[ax] {
                return;
            }
        } else {
            let ta = (lo[ax] - o[ax]) / d[ax];
            let tb = (hi[ax] - o[ax]) / d[ax];
            tmin = tmin.max(ta.min(tb));
            tmax = tmax.min(ta.max(tb));
        }
    }
    if tmin >= tmax {
        return;
    }

    let pitch = [vg.vx, vg.vy, vg.vz];
    let n = [vg.nx, vg.ny, vg.nz];

    // entry voxel
    let eps = 1e-9;
    let p_entry = ray.point(tmin + eps);
    let mut idx = [0i64; 3];
    let fidx = [vg.ix(p_entry[0]), vg.iy(p_entry[1]), vg.iz(p_entry[2])];
    for ax in 0..3 {
        // voxel i spans continuous index [i-0.5, i+0.5)
        idx[ax] = (fidx[ax] + 0.5).floor() as i64;
        if idx[ax] < 0 {
            idx[ax] = 0;
        }
        if idx[ax] >= n[ax] as i64 {
            idx[ax] = n[ax] as i64 - 1;
        }
    }

    // per-axis: t of next plane crossing, and t-increment per voxel
    let mut t_next = [f64::INFINITY; 3];
    let mut dt = [f64::INFINITY; 3];
    let mut step = [0i64; 3];
    let lows = [lo[0], lo[1], lo[2]];
    for ax in 0..3 {
        if d[ax] > 1e-12 {
            step[ax] = 1;
            // next plane at the voxel's upper edge: lo + (idx+1)·pitch
            let plane = lows[ax] + (idx[ax] + 1) as f64 * pitch[ax];
            t_next[ax] = (plane - o[ax]) / d[ax];
            dt[ax] = pitch[ax] / d[ax];
        } else if d[ax] < -1e-12 {
            step[ax] = -1;
            let plane = lows[ax] + idx[ax] as f64 * pitch[ax];
            t_next[ax] = (plane - o[ax]) / d[ax];
            dt[ax] = -pitch[ax] / d[ax];
        }
    }

    let nx = vg.nx;
    let nxy = vg.nx * vg.ny;
    let mut t = tmin;
    loop {
        // the axis whose plane is crossed first
        let mut ax = 0;
        if t_next[1] < t_next[ax] {
            ax = 1;
        }
        if t_next[2] < t_next[ax] {
            ax = 2;
        }
        let t_end = t_next[ax].min(tmax);
        let seg = t_end - t;
        if seg > 0.0 {
            let flat = idx[2] as usize * nxy + idx[1] as usize * nx + idx[0] as usize;
            visit(flat, seg as f32);
        }
        if t_next[ax] >= tmax {
            break;
        }
        t = t_next[ax];
        idx[ax] += step[ax];
        if idx[ax] < 0 || idx[ax] >= n[ax] as i64 {
            break;
        }
        t_next[ax] += dt[ax];
    }
}

/// Total radiological path (mm) of a ray through the grid — the sum of all
/// visited segment lengths; used by tests and the accuracy bench.
pub fn path_length(vg: &VolumeGeometry, ray: &Ray) -> f64 {
    let mut total = 0.0f64;
    walk_ray(vg, ray, |_, w| total += w as f64);
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Ray;

    fn vg(n: usize, voxel: f64) -> VolumeGeometry {
        VolumeGeometry::cube(n, voxel)
    }

    #[test]
    fn axis_aligned_ray_full_path() {
        let g = vg(8, 2.0); // extent [-8, 8]
        let ray = Ray::new([-100.0, 0.1, 0.1], [1.0, 0.0, 0.0]);
        let mut count = 0;
        let mut total = 0.0;
        walk_ray(&g, &ray, |_, w| {
            count += 1;
            total += w as f64;
        });
        assert_eq!(count, 8);
        assert!((total - 16.0).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn diagonal_ray_path() {
        let g = vg(4, 1.0); // extent [-2,2]³
        let ray = Ray::new([-10.0, -10.0, 0.1], [1.0, 1.0, 0.0]);
        let total = path_length(&g, &ray);
        // in-plane diagonal of a 4×4 square of 1mm voxels = 4√2
        // (tolerance: segments are accumulated as f32)
        assert!((total - 4.0 * 2f64.sqrt()).abs() < 1e-5, "total {total}");
    }

    #[test]
    fn miss_visits_nothing() {
        let g = vg(4, 1.0);
        let ray = Ray::new([-10.0, 5.0, 0.0], [1.0, 0.0, 0.0]);
        let mut visited = false;
        walk_ray(&g, &ray, |_, _| visited = true);
        assert!(!visited);
    }

    #[test]
    fn segments_within_voxel_pitch() {
        let g = vg(16, 0.5);
        let ray = Ray::new([-20.0, 1.3, -0.7], [0.9, 0.3, 0.1]);
        walk_ray(&g, &ray, |idx, w| {
            assert!(idx < 16 * 16 * 16);
            // a segment can never exceed the voxel diagonal
            assert!(w as f64 <= (0.25f64 + 0.25 + 0.25).sqrt() + 1e-9);
            assert!(w > 0.0);
        });
    }

    #[test]
    fn path_equals_chord_for_oblique_ray() {
        // grid extent [-8,8]²; ray at 30° through center must have chord 16/cos30 within the x-slab clip... compute via clip: the path equals the exact chord through the cube
        let g = vg(16, 1.0);
        let dir = [30f64.to_radians().cos(), 30f64.to_radians().sin(), 0.0];
        let ray = Ray::new([-50.0 * dir[0], -50.0 * dir[1], 0.2], dir);
        let total = path_length(&g, &ray);
        // chord through square [-8,8]²: limited by y extent? dir_y=0.5, y span 16 → t_y = 32; x span 16 → t_x=16/cos30≈18.47 → chord = 18.475
        let expect = 16.0 / 30f64.to_radians().cos();
        assert!((total - expect).abs() < 1e-5, "total {total} vs {expect}");
    }

    #[test]
    fn visits_each_voxel_once() {
        let g = vg(8, 1.0);
        // a ray guaranteed to pass through the interior point (0.3, 0.4, 0.2)
        let dir = [0.8, 0.55, 0.23];
        let r0 = Ray::new([0.3, 0.4, 0.2], dir);
        let ray = Ray::new(r0.point(-30.0), dir);
        let mut seen = std::collections::HashSet::new();
        walk_ray(&g, &ray, |idx, _| {
            assert!(seen.insert(idx), "voxel {idx} visited twice");
        });
        assert!(!seen.is_empty());
    }

    #[test]
    fn invariant_to_origin_along_ray() {
        // total path must not depend on where along the line the origin
        // sits (segment lists can differ by zero-length boundary slivers)
        let g = vg(12, 0.7);
        let dir = [0.3, -0.8, 0.5];
        let r0 = Ray::new([0.1, -0.2, 0.3], dir);
        let r1 = Ray::new(r0.point(-25.0), dir);
        let r2 = Ray::new(r0.point(13.0), dir);
        let p1 = path_length(&g, &r1);
        let p2 = path_length(&g, &r2);
        assert!(p1 > 1.0, "ray should cross the grid: {p1}");
        assert!((p1 - p2).abs() < 1e-5, "{p1} vs {p2}");
    }
}
