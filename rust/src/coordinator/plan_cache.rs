//! Projection-plan cache keyed by scan configuration.
//!
//! Serving traffic repeats scan configs: every request against the same
//! geometry/volume/model triple can share one [`ProjectionPlan`]. The
//! cache key is the canonical JSON serialization of the scan config
//! ([`crate::geometry::config::scan_to_string`]) plus model and thread
//! count, so anything that round-trips to the same config shares a plan.
//! [`super::NativeExecutor::new`] consults the process-wide [`global`]
//! cache — repeated executors (one per `serve` request router, per
//! connection, per test) skip planning entirely.
//!
//! Bounded FIFO eviction keeps the cache from pinning cone-beam plans
//! (`O(nviews·nx·ny)` each) for scans that stopped arriving.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::geometry::config::{scan_to_string, ScanConfig};
use crate::projector::{ProjectionPlan, Projector};

/// A bounded, thread-safe plan cache — bounded both by entry count and by
/// approximate resident bytes ([`ProjectionPlan::approx_heap_bytes`]), so
/// a handful of large cone-beam plans cannot silently pin gigabytes the
/// coordinator's [`super::MemoryBudget`] never sees. Clone the returned
/// `Arc`s freely; eviction only drops the cache's own reference.
pub struct PlanCache {
    cap: usize,
    max_bytes: usize,
    inner: Mutex<CacheInner>,
    /// Signals completion of an in-flight planning job.
    cv: Condvar,
}

struct CacheInner {
    /// Insertion order (with per-entry byte estimate) for FIFO eviction.
    order: Vec<(String, usize)>,
    map: HashMap<String, Arc<ProjectionPlan>>,
    bytes: usize,
    /// Keys currently being planned (outside the lock) by some thread;
    /// other threads for the same key wait on `cv` instead of planning
    /// the same config redundantly (thundering-herd protection).
    inflight: HashSet<String>,
    /// Keys whose *actual* plan turned out to exceed `max_bytes` even
    /// though the pre-planning estimate passed; later requesters bypass
    /// the in-flight gate for them (plan in parallel, never serialize
    /// behind a result that will not be cached).
    oversized: HashSet<String>,
}

/// Default resident-byte bound for the process-wide cache (2 GiB).
const DEFAULT_CACHE_MAX_BYTES: usize = 2 << 30;

impl PlanCache {
    /// Cache holding at most `cap` plans (≥ 1), bounded at 2 GiB resident.
    pub fn new(cap: usize) -> PlanCache {
        PlanCache::with_max_bytes(cap, DEFAULT_CACHE_MAX_BYTES)
    }

    /// Cache bounded by both entry count and approximate resident bytes.
    /// A single plan larger than `max_bytes` is returned but not cached.
    pub fn with_max_bytes(cap: usize, max_bytes: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            max_bytes,
            inner: Mutex::new(CacheInner {
                order: Vec::new(),
                map: HashMap::new(),
                bytes: 0,
                inflight: HashSet::new(),
                oversized: HashSet::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Canonical cache key for a projector's scan config. The backend is
    /// part of the key: plans snapshot the kernel tier they dispatch
    /// through ([`ProjectionPlan::backend`]), so a scalar and a SIMD
    /// session over the same geometry must not share one plan entry. The
    /// storage tier keys too — a reduced-precision plan packs its
    /// coefficient tables ([`ProjectionPlan::storage`]), so an f32 and an
    /// f16 session must not share one either.
    pub fn key_for(p: &Projector) -> String {
        let cfg = ScanConfig { geometry: p.geom.clone(), volume: p.vg.clone() };
        format!(
            "{}|t{}|b:{}|s:{}|{}",
            p.model.name(),
            p.threads,
            p.backend.name(),
            p.storage.name(),
            scan_to_string(&cfg)
        )
    }

    /// Fetch the plan for `p`'s scan config, planning it on a miss.
    /// Concurrent misses for the same key plan exactly once: the first
    /// thread plans, the rest wait on the result instead of redundantly
    /// burning CPU and transient memory on identical plans.
    pub fn get_or_plan(&self, p: &Projector) -> Arc<ProjectionPlan> {
        // Predictably uncacheable (estimate exceeds the byte budget):
        // skip the in-flight gate entirely so N concurrent requesters
        // plan in parallel instead of serializing N× behind a result
        // that would never be cached anyway.
        if ProjectionPlan::estimate_heap_bytes(p) > self.max_bytes {
            return Arc::new(p.plan());
        }
        let key = Self::key_for(p);
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                if let Some(hit) = inner.map.get(&key) {
                    return hit.clone();
                }
                if inner.oversized.contains(&key) {
                    // known-uncacheable from a previous attempt
                    drop(inner);
                    return Arc::new(p.plan());
                }
                if !inner.inflight.contains(&key) {
                    inner.inflight.insert(key.clone());
                    break; // this thread plans
                }
                // someone else is planning this key; wait for them
                inner = self.cv.wait(inner).unwrap();
            }
        }
        // plan outside the lock — planning a large cone scan is the slow
        // part, and misses for *different* configs shouldn't serialize.
        // The guard clears the inflight marker (and wakes waiters) even
        // if planning panics, so waiters never deadlock.
        let guard = InflightGuard { cache: self, key: key.clone() };
        let plan = Arc::new(p.plan());
        let plan_bytes = plan.approx_heap_bytes();
        {
            let mut inner = self.inner.lock().unwrap();
            if plan_bytes <= self.max_bytes {
                while !inner.order.is_empty()
                    && (inner.map.len() >= self.cap || inner.bytes + plan_bytes > self.max_bytes)
                {
                    let (evict, evict_bytes) = inner.order.remove(0);
                    inner.map.remove(&evict);
                    inner.bytes -= evict_bytes;
                }
                inner.order.push((key.clone(), plan_bytes));
                inner.bytes += plan_bytes;
                inner.map.insert(key.clone(), plan.clone());
            } else {
                // the estimate was optimistic: remember the key so later
                // requesters skip the in-flight gate instead of repeating
                // this serialize-plan-discard cycle forever
                if inner.oversized.len() >= 64 {
                    inner.oversized.clear(); // crude bound; worst case re-probes
                }
                inner.oversized.insert(key.clone());
            }
        }
        drop(guard);
        plan
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes currently held by cached plans.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }
}

/// Clears the in-flight marker for a key and wakes waiters — on the
/// normal path and on unwind, so a panicking plan never strands waiters.
struct InflightGuard<'a> {
    cache: &'a PlanCache,
    key: String,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.cache.inner.lock().unwrap().inflight.remove(&self.key);
        self.cache.cv.notify_all();
    }
}

/// The process-wide plan cache used by [`super::NativeExecutor::new`].
pub fn global() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new(16))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::Model;

    fn projector(nviews: usize) -> Projector {
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(nviews, 12, 1.0));
        Projector::new(g, vg, Model::SF).with_threads(2)
    }

    #[test]
    fn same_config_shares_one_plan() {
        let cache = PlanCache::new(4);
        let a = cache.get_or_plan(&projector(6));
        let b = cache.get_or_plan(&projector(6));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn different_configs_get_distinct_plans() {
        let cache = PlanCache::new(4);
        let a = cache.get_or_plan(&projector(6));
        let b = cache.get_or_plan(&projector(7));
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn different_backends_get_distinct_plans() {
        use crate::backend::BackendKind;
        let cache = PlanCache::new(4);
        let scalar = cache.get_or_plan(&projector(6).with_backend(BackendKind::Scalar));
        let simd = cache.get_or_plan(&projector(6).with_backend(BackendKind::Simd));
        assert!(!Arc::ptr_eq(&scalar, &simd));
        assert_eq!(scalar.backend(), BackendKind::Scalar);
        assert_eq!(simd.backend(), BackendKind::Simd);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn different_storage_tiers_get_distinct_plans() {
        use crate::precision::StorageTier;
        let cache = PlanCache::new(4);
        let f32p = cache.get_or_plan(&projector(6).with_storage_tier(StorageTier::F32));
        let f16p = cache.get_or_plan(&projector(6).with_storage_tier(StorageTier::F16));
        assert!(!Arc::ptr_eq(&f32p, &f16p));
        assert_eq!(f32p.storage(), StorageTier::F32);
        assert_eq!(f16p.storage(), StorageTier::F16);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn fifo_eviction_bounds_the_cache() {
        let cache = PlanCache::new(2);
        let first = cache.get_or_plan(&projector(4));
        cache.get_or_plan(&projector(5));
        cache.get_or_plan(&projector(6)); // evicts the nviews=4 plan
        assert_eq!(cache.len(), 2);
        let again = cache.get_or_plan(&projector(4)); // re-planned
        assert!(!Arc::ptr_eq(&first, &again));
    }

    #[test]
    fn concurrent_same_key_requests_share_one_plan() {
        let cache = Arc::new(PlanCache::new(4));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || c.get_or_plan(&projector(6))));
        }
        let plans: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p));
        }
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn byte_bound_evicts_and_oversized_plans_bypass_the_cache() {
        // every SF-parallel plan here is a few hundred bytes; a 1-byte
        // budget means nothing is ever cached
        let tiny = PlanCache::with_max_bytes(8, 1);
        let a = tiny.get_or_plan(&projector(6));
        assert!(tiny.is_empty(), "oversized plan must not be cached");
        assert_eq!(tiny.resident_bytes(), 0);
        let b = tiny.get_or_plan(&projector(6));
        assert!(!Arc::ptr_eq(&a, &b), "bypassed plans are re-planned");

        // Budget arithmetic derived from the size-of-based estimator the
        // cache itself consults — sf::parallel_plan_estimate_bytes — not
        // from a hard-coded bytes-per-view constant, so a plan-layout
        // change can never silently invalidate this test. The estimate
        // is exact for pure-2D SF-parallel plans (asserted), so
        // `estimate + 1` is a budget that fits exactly one six-view plan.
        let p6 = projector(6);
        let crate::geometry::Geometry::Parallel(g6) = &p6.geom else {
            unreachable!("projector() builds parallel beams")
        };
        let six_estimate = crate::projector::sf::parallel_plan_estimate_bytes(&p6.vg, g6);
        assert_eq!(
            six_estimate,
            ProjectionPlan::estimate_heap_bytes(&p6),
            "cache and test must share one estimator definition"
        );
        assert_eq!(
            six_estimate,
            p6.plan().approx_heap_bytes(),
            "SF-parallel estimate is exact"
        );
        let budget = six_estimate + 1;
        let snug = PlanCache::with_max_bytes(8, budget);
        snug.get_or_plan(&p6);
        snug.get_or_plan(&projector(5));
        assert_eq!(snug.len(), 1, "byte bound should have evicted the first plan");
        assert!(
            snug.resident_bytes() <= budget,
            "resident bytes {} must respect the derived budget {budget}",
            snug.resident_bytes()
        );
    }

    #[test]
    fn cached_plan_matches_its_projector() {
        let cache = PlanCache::new(2);
        let p = projector(5);
        let plan = cache.get_or_plan(&p);
        assert!(plan.matches(&p));
    }
}
