//! Request/response types for the projection service.
//!
//! A request names an operation (an artifact entry point like `fp_sf`, or
//! a native-projector op like `native_fp`) and carries its f32 input
//! buffers. Requests arrive over the wire as line-delimited JSON (see
//! [`super::server`]) or are constructed in-process by the examples and
//! benches.

use crate::util::json::Json;

/// A unit of work submitted to the coordinator.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Operation name: artifact entry (`fp_sf`, `bp_sf`, `fbp`,
    /// `dc_refine`, `complete_sinogram`, `prior_denoise`) or `native_*`.
    pub op: String,
    pub inputs: Vec<Vec<f32>>,
    /// Submission timestamp (set by the coordinator).
    pub submitted: std::time::Instant,
}

impl Request {
    pub fn new(id: u64, op: impl Into<String>, inputs: Vec<Vec<f32>>) -> Request {
        Request { id, op: op.into(), inputs, submitted: std::time::Instant::now() }
    }

    /// Total payload bytes (inputs only).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|b| b.len() * 4).sum()
    }
}

/// The outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub op: String,
    pub outputs: Vec<Vec<f32>>,
    pub error: Option<String>,
    /// Total time from submission to completion.
    pub latency_us: u64,
    /// Time spent executing (excludes queueing). For batched execution
    /// this is the whole group's wall time — the requests ran together.
    pub exec_us: u64,
    /// How many requests executed together in the same backend call
    /// (1 = alone). Observability for the dynamic batcher: a batched
    /// coordinator under load reports values > 1.
    pub batch_size: usize,
}

impl Response {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Parse a request from its wire JSON (`{"id":1,"op":"fp_sf",
/// "inputs":[[...]]}`).
pub fn request_from_json(v: &Json) -> Result<Request, String> {
    let id = v.get_f64("id").ok_or("missing id")? as u64;
    let op = v.get_str("op").ok_or("missing op")?.to_string();
    let inputs_json = v.get("inputs").and_then(|a| a.as_arr()).ok_or("missing inputs")?;
    let mut inputs = Vec::with_capacity(inputs_json.len());
    for arr in inputs_json {
        let vals = arr.as_arr().ok_or("input must be an array")?;
        let buf: Option<Vec<f32>> = vals.iter().map(|x| x.as_f64().map(|f| f as f32)).collect();
        inputs.push(buf.ok_or("non-numeric input element")?);
    }
    Ok(Request::new(id, op, inputs))
}

/// Serialize a response to wire JSON.
pub fn response_to_json(r: &Response) -> Json {
    let mut fields = vec![
        ("id", Json::Num(r.id as f64)),
        ("op", Json::Str(r.op.clone())),
        ("latency_us", Json::Num(r.latency_us as f64)),
        ("exec_us", Json::Num(r.exec_us as f64)),
        ("batch_size", Json::Num(r.batch_size as f64)),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::Str(e.clone())));
    } else {
        fields.push((
            "outputs",
            Json::Arr(
                r.outputs
                    .iter()
                    .map(|o| Json::Arr(o.iter().map(|&x| Json::Num(x as f64)).collect()))
                    .collect(),
            ),
        ));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn request_roundtrip() {
        let j = parse(r#"{"id": 7, "op": "fp_sf", "inputs": [[1.0, 2.5], [3.0]]}"#).unwrap();
        let r = request_from_json(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.op, "fp_sf");
        assert_eq!(r.inputs, vec![vec![1.0, 2.5], vec![3.0]]);
        assert_eq!(r.input_bytes(), 12);
    }

    #[test]
    fn request_rejects_malformed() {
        for s in [
            r#"{"op": "x", "inputs": []}"#,
            r#"{"id": 1, "inputs": []}"#,
            r#"{"id": 1, "op": "x"}"#,
            r#"{"id": 1, "op": "x", "inputs": [["a"]]}"#,
        ] {
            assert!(request_from_json(&parse(s).unwrap()).is_err(), "{s}");
        }
    }

    #[test]
    fn response_serializes_error_and_ok() {
        let ok = Response { id: 1, op: "fbp".into(), outputs: vec![vec![1.5]], error: None, latency_us: 10, exec_us: 5, batch_size: 1 };
        let s = response_to_json(&ok).to_string();
        assert!(s.contains("\"outputs\""));
        assert!(s.contains("\"batch_size\""));
        assert!(!s.contains("\"error\""));
        let err = Response { id: 2, op: "fbp".into(), outputs: vec![], error: Some("bad".into()), latency_us: 1, exec_us: 0, batch_size: 1 };
        let s = response_to_json(&err).to_string();
        assert!(s.contains("\"error\""));
        assert!(!s.contains("\"outputs\""));
    }
}
