//! Request/response types for the projection service.
//!
//! A request names a typed operation ([`Op`]) and carries its f32 input
//! buffers. Requests arrive over the wire as protocol-v2 binary frames
//! ([`request_from_frame`]), as legacy v1 line-delimited JSON
//! ([`request_from_json`]), or are constructed in-process by the
//! examples and benches (`Request::new` accepts the v1 wire strings for
//! convenience — `"native_fp"` parses to [`Op::NativeFp`]).

use crate::api::LeapError;
use crate::util::json::Json;

use super::op::Op;
use super::wire::{Frame, FrameKind};

/// A unit of work submitted to the coordinator.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// The typed operation (native projector op, protocol-v2 session op,
    /// or a named artifact entry point).
    pub op: Op,
    pub inputs: Vec<Vec<f32>>,
    /// Submission timestamp (set by the coordinator).
    pub submitted: std::time::Instant,
}

impl Request {
    pub fn new(id: u64, op: impl Into<Op>, inputs: Vec<Vec<f32>>) -> Request {
        Request { id, op: op.into(), inputs, submitted: std::time::Instant::now() }
    }

    /// Total payload bytes (inputs only).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|b| b.len() * 4).sum()
    }
}

/// The outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub op: Op,
    pub outputs: Vec<Vec<f32>>,
    /// The typed failure, if any (its [`LeapError::code`] travels on the
    /// wire in both protocol versions).
    pub error: Option<LeapError>,
    /// Total time from submission to completion.
    pub latency_us: u64,
    /// Time spent executing (excludes queueing). For batched execution
    /// this is the whole group's wall time — the requests ran together.
    pub exec_us: u64,
    /// How many requests executed together in the same backend call
    /// (1 = alone). Observability for the dynamic batcher: a batched
    /// coordinator under load reports values > 1.
    pub batch_size: usize,
}

impl Response {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

// ---------------------------------------------------------------------------
// protocol v1 (line-delimited JSON)
// ---------------------------------------------------------------------------

/// Parse a request from its v1 wire JSON (`{"id":1,"op":"fp_sf",
/// "inputs":[[...]]}`).
pub fn request_from_json(v: &Json) -> Result<Request, LeapError> {
    let id = v
        .get_f64("id")
        .ok_or_else(|| LeapError::Protocol("missing id".into()))? as u64;
    let op = v
        .get_str("op")
        .ok_or_else(|| LeapError::Protocol("missing op".into()))?;
    let inputs_json = v
        .get("inputs")
        .and_then(|a| a.as_arr())
        .ok_or_else(|| LeapError::Protocol("missing inputs".into()))?;
    let mut inputs = Vec::with_capacity(inputs_json.len());
    for arr in inputs_json {
        let vals = arr
            .as_arr()
            .ok_or_else(|| LeapError::Protocol("input must be an array".into()))?;
        let buf: Option<Vec<f32>> = vals.iter().map(|x| x.as_f64().map(|f| f as f32)).collect();
        inputs.push(buf.ok_or_else(|| LeapError::Protocol("non-numeric input element".into()))?);
    }
    Ok(Request::new(id, Op::parse_wire(op), inputs))
}

/// Serialize a response to v1 wire JSON. Errors carry both the human
/// message and the stable typed `code`.
pub fn response_to_json(r: &Response) -> Json {
    let mut fields = vec![
        ("id", Json::Num(r.id as f64)),
        ("op", Json::Str(r.op.label())),
        ("latency_us", Json::Num(r.latency_us as f64)),
        ("exec_us", Json::Num(r.exec_us as f64)),
        ("batch_size", Json::Num(r.batch_size as f64)),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::Str(e.to_string())));
        fields.push(("code", Json::Num(e.code() as f64)));
    } else {
        fields.push((
            "outputs",
            Json::Arr(
                r.outputs
                    .iter()
                    .map(|o| Json::Arr(o.iter().map(|&x| Json::Num(x as f64)).collect()))
                    .collect(),
            ),
        ));
    }
    Json::obj(fields)
}

// ---------------------------------------------------------------------------
// protocol v2 (binary frames)
// ---------------------------------------------------------------------------

/// The v2 Request meta for `op`. Session and pipeline ids are encoded
/// as decimal strings — JSON numbers are f64 on this wire and would
/// silently lose precision above 2^53.
pub fn request_meta(op: &Op) -> Json {
    let (name, session, pipeline) = op.wire_fields();
    let mut meta = vec![("op", Json::Str(name.to_string()))];
    if let Some(s) = session {
        meta.push(("session", Json::Str(s.to_string())));
    }
    if let Some(p) = pipeline {
        meta.push(("pipeline", Json::Str(p.to_string())));
    }
    Json::obj(meta)
}

/// Build the v2 Request frame for `op` with one input tensor (senders
/// that already borrow the tensor should prefer
/// [`crate::coordinator::wire::write_frame_parts`] with
/// [`request_meta`] — no owned copy).
pub fn request_to_frame(id: u64, op: &Op, input: Vec<f32>) -> Frame {
    Frame::new(FrameKind::Request, id, request_meta(op), input)
}

/// Parse a session/pipeline id from frame meta: canonically a decimal
/// string (lossless u64); a JSON number is tolerated for hand-rolled
/// clients but only exact below 2^53.
fn id_from_meta(meta: &Json, key: &str) -> Result<Option<u64>, LeapError> {
    match meta.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map(Some)
            .map_err(|_| LeapError::Protocol(format!("bad {key} id {s:?}"))),
        Some(Json::Num(n)) => Ok(Some(*n as u64)),
        Some(other) => Err(LeapError::Protocol(format!(
            "{key} must be a decimal string or number, got {other}"
        ))),
    }
}

/// Parse a v2 Request frame into a [`Request`]. The payload is the
/// single input tensor (native and session ops all take exactly one),
/// **moved** out of the frame — no copy on the serving hot path.
pub fn request_from_frame(f: Frame) -> Result<Request, LeapError> {
    if f.kind != FrameKind::Request {
        return Err(LeapError::Protocol(format!("expected a Request frame, got {:?}", f.kind)));
    }
    let name = f
        .meta
        .get_str("op")
        .ok_or_else(|| LeapError::Protocol("request meta missing op".into()))?;
    let session = id_from_meta(&f.meta, "session")?;
    let pipeline = id_from_meta(&f.meta, "pipeline")?;
    let op = Op::from_wire(name, session, pipeline)?;
    Ok(Request::new(f.id, op, vec![f.payload]))
}

/// Build the v2 reply frame for a completed response: a Response frame
/// whose payload is the output tensor (**moved**, not copied — the
/// caller is done with the response), or an Error frame carrying the
/// typed code. A v2 frame carries exactly one tensor; a multi-output
/// result (possible for artifact backends) is refused with a typed
/// error rather than silently truncated — v1 JSON carries them all.
pub fn response_to_frame(mut r: Response) -> Frame {
    if r.error.is_none() && r.outputs.len() > 1 {
        return Frame::error(
            r.id,
            &LeapError::Unsupported(format!(
                "op {} returned {} output tensors; protocol v2 frames carry exactly one \
                 (use protocol v1 for multi-output ops)",
                r.op.label(),
                r.outputs.len()
            )),
        );
    }
    match &r.error {
        Some(e) => {
            let mut f = Frame::error(r.id, e);
            f.meta = match f.meta {
                Json::Obj(mut m) => {
                    m.insert("latency_us".into(), Json::Num(r.latency_us as f64));
                    Json::Obj(m)
                }
                other => other,
            };
            f
        }
        None => {
            let (name, session, pipeline) = r.op.wire_fields();
            let mut meta = vec![
                ("op", Json::Str(name.to_string())),
                ("latency_us", Json::Num(r.latency_us as f64)),
                ("exec_us", Json::Num(r.exec_us as f64)),
                ("batch_size", Json::Num(r.batch_size as f64)),
            ];
            if let Some(s) = session {
                meta.push(("session", Json::Str(s.to_string())));
            }
            if let Some(p) = pipeline {
                meta.push(("pipeline", Json::Str(p.to_string())));
            }
            let meta = Json::obj(meta);
            let payload =
                if r.outputs.is_empty() { Vec::new() } else { r.outputs.swap_remove(0) };
            Frame::new(FrameKind::Response, r.id, meta, payload)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn request_roundtrip() {
        let j = parse(r#"{"id": 7, "op": "fp_sf", "inputs": [[1.0, 2.5], [3.0]]}"#).unwrap();
        let r = request_from_json(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.op, Op::Artifact("fp_sf".into()));
        assert_eq!(r.inputs, vec![vec![1.0, 2.5], vec![3.0]]);
        assert_eq!(r.input_bytes(), 12);
    }

    #[test]
    fn request_rejects_malformed() {
        for s in [
            r#"{"op": "x", "inputs": []}"#,
            r#"{"id": 1, "inputs": []}"#,
            r#"{"id": 1, "op": "x"}"#,
            r#"{"id": 1, "op": "x", "inputs": [["a"]]}"#,
        ] {
            let e = request_from_json(&parse(s).unwrap()).unwrap_err();
            assert!(matches!(e, LeapError::Protocol(_)), "{s}: {e:?}");
        }
    }

    #[test]
    fn response_serializes_error_and_ok() {
        let ok = Response {
            id: 1,
            op: Op::NativeFbp,
            outputs: vec![vec![1.5]],
            error: None,
            latency_us: 10,
            exec_us: 5,
            batch_size: 1,
        };
        let s = response_to_json(&ok).to_string();
        assert!(s.contains("\"outputs\""));
        assert!(s.contains("\"batch_size\""));
        assert!(s.contains("native_fbp"));
        assert!(!s.contains("\"error\""));
        let err = Response {
            id: 2,
            op: Op::NativeFbp,
            outputs: vec![],
            error: Some(LeapError::Backend("bad".into())),
            latency_us: 1,
            exec_us: 0,
            batch_size: 1,
        };
        let s = response_to_json(&err).to_string();
        assert!(s.contains("\"error\""));
        assert!(s.contains("\"code\""));
        assert!(!s.contains("\"outputs\""));
    }

    #[test]
    fn v2_request_frame_roundtrips_every_op_variant() {
        let variants = vec![
            Op::NativeFp,
            Op::NativeBp,
            Op::NativeFbp,
            Op::SessionFp(3),
            Op::SessionBp(u64::MAX),
            Op::SessionFbp(0),
            Op::SessionPipelineGrad { session: 5, pipeline: (1u64 << 53) + 1 },
            Op::Artifact("fp_sf".into()),
        ];
        for (i, op) in variants.into_iter().enumerate() {
            let payload = vec![0.25f32 * i as f32; i + 1];
            let frame = request_to_frame(77 + i as u64, &op, payload.clone());
            let decoded = crate::coordinator::wire::decode_frame(
                &crate::coordinator::wire::encode_frame(&frame).unwrap(),
            )
            .unwrap();
            let req = request_from_frame(decoded).unwrap();
            assert_eq!(req.op, op, "variant {i}");
            assert_eq!(req.id, 77 + i as u64);
            assert_eq!(req.inputs, vec![payload]);
        }
    }

    #[test]
    fn v2_response_frame_carries_tensor_and_error_codes() {
        let ok = Response {
            id: 5,
            op: Op::SessionFp(2),
            outputs: vec![vec![1.0, -2.0]],
            error: None,
            latency_us: 9,
            exec_us: 4,
            batch_size: 3,
        };
        let f = response_to_frame(ok);
        assert_eq!(f.kind, FrameKind::Response);
        assert_eq!(f.payload, vec![1.0, -2.0]);
        assert_eq!(f.meta.get_f64("batch_size"), Some(3.0));
        assert_eq!(f.meta.get_str("session"), Some("2"));

        let err = Response {
            id: 6,
            op: Op::SessionFp(2),
            outputs: vec![],
            error: Some(LeapError::ShapeMismatch { what: "volume", expected: 4, got: 1 }),
            latency_us: 2,
            exec_us: 0,
            batch_size: 1,
        };
        let f = response_to_frame(err);
        assert_eq!(f.kind, FrameKind::Error);
        assert_eq!(f.to_error().code(), crate::api::codes::SHAPE_MISMATCH);
    }

    #[test]
    fn session_ids_above_2_pow_53_survive_the_wire_exactly() {
        // f64 meta numbers would round 2^53+1 to 2^53; the decimal-string
        // encoding must carry every u64 exactly
        for id in [(1u64 << 53) + 1, u64::MAX - 1, u64::MAX] {
            let op = Op::SessionFp(id);
            let frame = request_to_frame(9, &op, vec![]);
            let decoded = crate::coordinator::wire::decode_frame(
                &crate::coordinator::wire::encode_frame(&frame).unwrap(),
            )
            .unwrap();
            let req = request_from_frame(decoded).unwrap();
            assert_eq!(req.op, Op::SessionFp(id), "id {id} must survive exactly");
        }
        // malformed session ids are typed protocol errors
        let f = Frame::new(
            FrameKind::Request,
            1,
            Json::obj(vec![
                ("op", Json::Str("fp".into())),
                ("session", Json::Str("not-a-number".into())),
            ]),
            vec![],
        );
        assert!(matches!(request_from_frame(f), Err(LeapError::Protocol(_))));
    }

    #[test]
    fn bad_frame_requests_are_typed() {
        let f = Frame::new(FrameKind::Request, 1, Json::obj(vec![]), vec![1.0]);
        assert!(matches!(request_from_frame(f), Err(LeapError::Protocol(_))));
        let f = Frame::new(FrameKind::Hello, 1, Json::Null, vec![]);
        assert!(matches!(request_from_frame(f), Err(LeapError::Protocol(_))));
    }

    #[test]
    fn multi_output_responses_are_refused_on_v2_not_truncated() {
        let r = Response {
            id: 8,
            op: Op::Artifact("loss_grad".into()),
            outputs: vec![vec![1.0], vec![2.0, 3.0]],
            error: None,
            latency_us: 1,
            exec_us: 1,
            batch_size: 1,
        };
        // v1 JSON carries every output …
        let j = response_to_json(&r).to_string();
        assert!(j.contains("outputs"));
        // … v2 refuses with a typed error instead of truncating
        let f = response_to_frame(r);
        assert_eq!(f.kind, FrameKind::Error);
        assert_eq!(f.to_error().code(), crate::api::codes::UNSUPPORTED);
    }
}
