//! Line-delimited JSON TCP server over the coordinator.
//!
//! Protocol (one JSON document per line):
//!   → {"id": 1, "op": "fp_sf", "inputs": [[...f32...], ...]}
//!   ← {"id": 1, "op": "fp_sf", "outputs": [[...]], "latency_us": ..,
//!      "exec_us": .., "batch_size": ..}
//!   → {"id": 2, "op": "__stats"}          — telemetry snapshot
//!   → {"id": 3, "op": "__ops"}            — available operations
//!
//! `batch_size` reports how many requests the dynamic batcher executed
//! together with this one (1 = alone): on the native backend a
//! multi-request batch ran as one stacked batched projection.
//!
//! Built on std::net + threads (the vendored crate set has no tokio; the
//! architecture is identical: accept loop → per-connection reader →
//! shared coordinator → responses written back on the same socket).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::request::{request_from_json, response_to_json};
use super::Coordinator;
use crate::util::json::{parse, Json};

/// A running server; dropping stops accepting (existing connections finish).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `coordinator` until
    /// dropped.
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            loop {
                if stop2.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let coord = coordinator.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, coord);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
            Ok(doc) => {
                let op = doc.get_str("op").unwrap_or("");
                match op {
                    "__stats" => {
                        // the projector worker pool is process-wide and thus
                        // shared by every connection and request: expose its
                        // size and dispatch count next to the queue depth so
                        // operators can see compute saturation per snapshot
                        let (pool_workers, pool_regions) = crate::util::pool::pool_stats();
                        Json::obj(vec![
                            ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
                            ("stats", coord.telemetry().to_json()),
                            ("queue_depth", Json::Num(coord.queue_depth() as f64)),
                            ("budget_in_flight", Json::Num(coord.budget().in_flight() as f64)),
                            ("pool_workers", Json::Num(pool_workers as f64)),
                            ("pool_regions", Json::Num(pool_regions as f64)),
                        ])
                    }
                    "__ops" => Json::obj(vec![
                        ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
                        (
                            "ops",
                            Json::Arr(
                                coord.executor().ops().into_iter().map(Json::Str).collect(),
                            ),
                        ),
                    ]),
                    _ => match request_from_json(&doc) {
                        Err(e) => Json::obj(vec![("error", Json::Str(e))]),
                        Ok(req) => response_to_json(&coord.call(req)),
                    },
                }
            }
        };
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send one op and wait for its reply.
    pub fn call(&mut self, op: &str, inputs: &[&[f32]]) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        let doc = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("op", Json::Str(op.to_string())),
            (
                "inputs",
                Json::Arr(
                    inputs
                        .iter()
                        .map(|b| Json::Arr(b.iter().map(|&x| Json::Num(x as f64)).collect()))
                        .collect(),
                ),
            ),
        ]);
        writeln!(self.writer, "{doc}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    /// Fetch the telemetry snapshot.
    pub fn stats(&mut self) -> Result<Json> {
        writeln!(self.writer, r#"{{"id": 0, "op": "__stats"}}"#)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::MockExecutor;
    use super::super::{BatchPolicy, Coordinator};
    use super::*;

    fn start_mock() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            2,
        ));
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    #[test]
    fn tcp_roundtrip() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("echo", &[&[1.0, 3.0]]).unwrap();
        let outs = reply.get("outputs").unwrap().as_arr().unwrap();
        let first = outs[0].as_arr().unwrap();
        assert_eq!(first[0].as_f64(), Some(2.0));
        assert_eq!(first[1].as_f64(), Some(6.0));
        // the batching observability field rides on every reply
        assert!(reply.get_f64("batch_size").unwrap_or(0.0) >= 1.0);
    }

    #[test]
    fn error_propagates() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("fail", &[&[1.0]]).unwrap();
        assert!(reply.get_str("error").unwrap().contains("mock failure"));
    }

    #[test]
    fn stats_endpoint() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        client.call("echo", &[&[1.0]]).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(
            stats.get("stats").unwrap().get("echo").unwrap().get_f64("count"),
            Some(1.0)
        );
        // the shared projector pool is reported alongside request stats
        assert!(stats.get_f64("pool_workers").is_some());
        assert!(stats.get_f64("pool_regions").is_some());
    }

    #[test]
    fn multiple_clients() {
        let (server, _coord) = start_mock();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..10 {
                    let r = client.call("echo", &[&[t as f32 + i as f32]]).unwrap();
                    assert!(r.get("outputs").is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn malformed_line_gets_error_reply() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "this is not json").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
    }
}
