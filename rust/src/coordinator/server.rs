//! TCP server speaking both wire protocols on one port.
//!
//! **v2 (preferred)** — length-prefixed binary frames with raw
//! little-endian f32 payloads ([`super::wire`], spec in
//! `docs/PROTOCOL.md`): Hello version negotiation, an OpenSession
//! handshake that registers a scan config once (validated, planned,
//! pinned — [`super::session`]) and reports the compute backend the
//! session resolved to, then per-request 24-byte headers + tensors.
//! Drive it with [`BinaryClient`].
//!
//! **v1 (legacy)** — one JSON document per line:
//!   → {"id": 1, "op": "fp_sf", "inputs": [[...f32...], ...]}
//!   ← {"id": 1, "op": "fp_sf", "outputs": [[...]], "latency_us": ..,
//!      "exec_us": .., "batch_size": ..}
//!   → {"id": 2, "op": "__stats"}          — telemetry snapshot
//!   → {"id": 3, "op": "__ops"}            — available operations
//! Error replies carry the human message plus the stable typed `code`
//! ([`crate::api::codes`]). Drive it with [`Client`], kept for
//! compatibility — new clients should speak v2.
//!
//! The protocol is sniffed from the first byte of each connection: `{`
//! (or whitespace) opens a v1 JSON line session, `L` (the frame magic)
//! opens a v2 binary session. Sessions opened over a v2 connection are
//! closed when that connection drops.
//!
//! Built on std::net + threads (the vendored crate set has no tokio; the
//! architecture is identical: accept loop → per-connection reader →
//! shared coordinator → responses written back on the same socket).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::LeapError;
use crate::geometry::config::{geometry_to_json, volume_to_json, ScanConfig};
use crate::projector::Model;
use crate::tape;
use crate::util::json::{parse, Json};

use super::op::Op;
use super::request::{request_from_frame, request_from_json, response_to_frame};
use super::session::SessionRegistry;
use super::wire::{self, Frame, FrameKind};
use super::Coordinator;

/// Per-read **inactivity** timeout applied to a connection until its
/// first complete frame (v2) or line (v1). Without it, a peer that
/// connects and sends zero or one bytes then stalls would pin a server
/// thread (and its connection state) forever — the reads are blocking.
/// Note this bounds the gap between bytes, not the whole exchange: a
/// deliberate slow-drip sender (one byte per 9 s) can stretch its first
/// frame out indefinitely — total-stall protection, not an absolute
/// deadline. Once the first exchange completes the timeout is lifted:
/// idle-but-honest clients (a training loop thinking between gradient
/// requests) are never dropped.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// A running server; dropping stops accepting (existing connections finish).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `coordinator` until
    /// dropped (first-exchange deadline = [`HANDSHAKE_TIMEOUT`]).
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Server, LeapError> {
        Server::start_with_handshake_timeout(addr, coordinator, HANDSHAKE_TIMEOUT)
    }

    /// [`Server::start`] with an explicit first-exchange deadline
    /// (tests use short deadlines to exercise the stall paths).
    pub fn start_with_handshake_timeout(
        addr: &str,
        coordinator: Arc<Coordinator>,
        handshake: Duration,
    ) -> Result<Server, LeapError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            loop {
                if stop2.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let coord = coordinator.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, coord, handshake);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Whether an I/O error is the read-deadline expiring. Both kinds mean
/// the same condition and MUST both be accepted: unix sockets surface
/// an expired `SO_RCVTIMEO` as `WouldBlock`, windows as `TimedOut`.
/// `pub(crate)` so tests and other connection-handling code classify
/// deadlines through this one predicate instead of re-matching kinds.
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

fn handle_conn(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    handshake: Duration,
) -> Result<(), LeapError> {
    // first-exchange deadline (cleared by the per-protocol loops after
    // the first complete frame/line — see HANDSHAKE_TIMEOUT)
    stream.set_read_timeout(Some(handshake))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // sniff the protocol from the first byte without consuming it:
    // JSON documents open with '{' (or whitespace), v2 frames with the
    // "LEAP" magic; anything else is not a protocol we speak
    let first = match reader.fill_buf() {
        Ok(buf) => match buf.first() {
            None => return Ok(()), // closed before sending anything: clean
            Some(&b) => b,
        },
        Err(e) if is_timeout(&e) => {
            // connected, sent nothing, stalled: nothing sniffed, so no
            // reply format is owed — just release the thread
            return Err(LeapError::Io("handshake timed out before any byte arrived".into()));
        }
        Err(e) => return Err(e.into()),
    };
    if first == wire::MAGIC[0] {
        serve_v2(reader, writer, coord)
    } else if first == b'{' || first.is_ascii_whitespace() {
        serve_v1(reader, writer, coord)
    } else {
        // unrecognized protocol: say so once, in the (text) format any
        // probing client can read, then close — never fall into the v1
        // loop to re-reject every subsequent line of noise
        let e = LeapError::Protocol(format!(
            "unrecognized protocol (first byte 0x{first:02x}; expected '{{' for JSON lines \
             or 'L' for LEAP v2 frames)"
        ));
        let reply = Json::obj(vec![
            ("error", Json::Str(e.to_string())),
            ("code", Json::Num(e.code() as f64)),
        ]);
        let _ = writeln!(writer, "{reply}");
        Err(e)
    }
}

// ---------------------------------------------------------------------------
// protocol v1: line-delimited JSON
// ---------------------------------------------------------------------------

fn serve_v1(
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
    coord: Arc<Coordinator>,
) -> Result<(), LeapError> {
    let mut first_exchange = true;
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // clean disconnect
            Ok(_) => {}
            Err(e) if is_timeout(&e) => {
                // stalled before completing the first line: reply with
                // the typed code in the v1 format, then close
                let err = LeapError::Io("handshake timed out mid-line".into());
                let reply = Json::obj(vec![
                    ("error", Json::Str(err.to_string())),
                    ("code", Json::Num(err.code() as f64)),
                ]);
                let _ = writeln!(writer, "{reply}");
                return Err(err);
            }
            Err(e) => return Err(e.into()),
        }
        if first_exchange {
            first_exchange = false;
            // a real v1 speaker: lift the first-exchange deadline so
            // idle-but-connected clients are not dropped
            writer.set_read_timeout(None)?;
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse(&line) {
            Err(e) => Json::obj(vec![
                ("error", Json::Str(format!("bad json: {e}"))),
                ("code", Json::Num(crate::api::codes::PROTOCOL as f64)),
            ]),
            Ok(doc) => {
                let op = doc.get_str("op").unwrap_or("");
                match op {
                    "__stats" => {
                        // the projector worker pool is process-wide and thus
                        // shared by every connection and request: expose its
                        // size and dispatch count next to the queue depth so
                        // operators can see compute saturation per snapshot
                        let (pool_workers, pool_regions) = crate::util::pool::pool_stats();
                        // the backend a sessionless scan would get, plus
                        // the tier actually serving each open session —
                        // operators correlating throughput need to know
                        // which kernel tier produced it
                        let session_backends = Json::Obj(
                            SessionRegistry::global()
                                .session_backends()
                                .into_iter()
                                .map(|(id, b)| (id.to_string(), Json::Str(b.to_string())))
                                .collect(),
                        );
                        Json::obj(vec![
                            ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
                            ("stats", coord.telemetry().to_json()),
                            ("queue_depth", Json::Num(coord.queue_depth() as f64)),
                            ("budget_in_flight", Json::Num(coord.budget().in_flight() as f64)),
                            ("open_sessions", Json::Num(SessionRegistry::global().len() as f64)),
                            ("pool_workers", Json::Num(pool_workers as f64)),
                            ("pool_regions", Json::Num(pool_regions as f64)),
                            (
                                "default_backend",
                                Json::Str(crate::backend::default_kind().name().to_string()),
                            ),
                            ("session_backends", session_backends),
                        ])
                    }
                    "__ops" => Json::obj(vec![
                        ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
                        (
                            "ops",
                            Json::Arr(
                                coord
                                    .executor()
                                    .ops()
                                    .into_iter()
                                    .map(|o| Json::Str(o.label()))
                                    .collect(),
                            ),
                        ),
                    ]),
                    _ => match request_from_json(&doc) {
                        Err(e) => Json::obj(vec![
                            ("error", Json::Str(e.to_string())),
                            ("code", Json::Num(e.code() as f64)),
                        ]),
                        Ok(req) => super::request::response_to_json(&coord.call(req)),
                    },
                }
            }
        };
        writeln!(writer, "{reply}")?;
    }
}

// ---------------------------------------------------------------------------
// protocol v2: binary frames + sessions
// ---------------------------------------------------------------------------

fn serve_v2(
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
    coord: Arc<Coordinator>,
) -> Result<(), LeapError> {
    let registry = SessionRegistry::global();
    // sessions opened over this connection close with it (plans unpin)
    let mut opened: Vec<u64> = Vec::new();
    let result = serve_v2_loop(&mut reader, &mut writer, &coord, registry, &mut opened);
    for id in opened {
        registry.close(id);
    }
    result
}

fn serve_v2_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    coord: &Arc<Coordinator>,
    registry: &'static SessionRegistry,
    opened: &mut Vec<u64>,
) -> Result<(), LeapError> {
    let mut first_exchange = true;
    loop {
        let frame = match wire::read_frame(reader) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()), // clean disconnect
            Err(e) => {
                // typed reject (version mismatch, malformed frame, or the
                // first-exchange deadline expiring mid-frame), then
                // close: framing cannot be trusted after a bad header
                let _ = wire::write_frame(writer, &Frame::error(0, &e));
                return Err(e);
            }
        };
        if first_exchange {
            first_exchange = false;
            // a real v2 speaker: lift the first-exchange deadline (see
            // HANDSHAKE_TIMEOUT)
            writer.set_read_timeout(None)?;
        }
        match frame.kind {
            FrameKind::Hello => {
                let reply = Frame::new(
                    FrameKind::Hello,
                    frame.id,
                    Json::obj(vec![
                        ("version", Json::Num(wire::VERSION as f64)),
                        ("server", Json::Str("leap".into())),
                    ]),
                    Vec::new(),
                );
                wire::write_frame(writer, &reply)?;
            }
            FrameKind::OpenSession => match registry.open_from_meta(&frame.meta) {
                Ok(id) => {
                    opened.push(id);
                    // the authoritative id is the frame's native u64 id
                    // field; the meta copy is a decimal string (f64 JSON
                    // numbers round above 2^53). The reply also names the
                    // compute backend the session resolved to, so clients
                    // that left the knob unset learn what will serve them.
                    let backend = registry.backend_of(id).unwrap_or("unknown");
                    let reply = Frame::new(
                        FrameKind::OpenSession,
                        id,
                        Json::obj(vec![
                            ("session", Json::Str(id.to_string())),
                            ("backend", Json::Str(backend.to_string())),
                        ]),
                        Vec::new(),
                    );
                    wire::write_frame(writer, &reply)?;
                }
                Err(e) => wire::write_frame(writer, &Frame::error(frame.id, &e))?,
            },
            FrameKind::CloseSession => {
                // only the connection that opened a session may close it:
                // ids are sequential, so without this check any client
                // could tear down another connection's session by
                // guessing (the same UnknownSession reply for
                // not-yours and never-existed avoids leaking liveness)
                if opened.contains(&frame.id) && registry.close(frame.id) {
                    opened.retain(|&i| i != frame.id);
                    let reply =
                        Frame::new(FrameKind::CloseSession, frame.id, Json::Null, Vec::new());
                    wire::write_frame(writer, &reply)?;
                } else {
                    let e = LeapError::UnknownSession(frame.id);
                    wire::write_frame(writer, &Frame::error(frame.id, &e))?;
                }
            }
            FrameKind::RegisterPipeline => {
                // connection-scoped like CloseSession: registering on a
                // session you did not open answers exactly like a
                // session that never existed
                if !opened.contains(&frame.id) {
                    let e = LeapError::UnknownSession(frame.id);
                    wire::write_frame(writer, &Frame::error(frame.id, &e))?;
                    continue;
                }
                let result = frame
                    .meta
                    .get("pipeline")
                    .ok_or_else(|| {
                        LeapError::Protocol("register-pipeline meta missing pipeline spec".into())
                    })
                    .and_then(|spec| registry.register_pipeline(frame.id, spec));
                match result {
                    Ok(pid) => {
                        // reply id = pipeline id; meta repeats both ids as
                        // decimal strings (lossless above 2^53)
                        let reply = Frame::new(
                            FrameKind::RegisterPipeline,
                            pid,
                            Json::obj(vec![
                                ("session", Json::Str(frame.id.to_string())),
                                ("pipeline", Json::Str(pid.to_string())),
                            ]),
                            Vec::new(),
                        );
                        wire::write_frame(writer, &reply)?;
                    }
                    Err(e) => wire::write_frame(writer, &Frame::error(frame.id, &e))?,
                }
            }
            FrameKind::Request => {
                let id = frame.id;
                match request_from_frame(frame) {
                    Err(e) => wire::write_frame(writer, &Frame::error(id, &e))?,
                    Ok(req) => {
                        // session ops — projections AND pipeline-grad —
                        // are scoped to the connection that opened the
                        // session (ids are sequential and guessable;
                        // answering not-yours identically to
                        // never-existed leaks neither liveness nor the
                        // victim scan's shape)
                        if let Some(sid) = req.op.session_id() {
                            if !opened.contains(&sid) {
                                let e = LeapError::UnknownSession(sid);
                                wire::write_frame(writer, &Frame::error(id, &e))?;
                                continue;
                            }
                        }
                        let resp = coord.call(req);
                        let reply = response_to_frame(resp);
                        match wire::write_frame(writer, &reply) {
                            Ok(()) => {}
                            // an unframeable reply (tensor over the wire
                            // cap) fails in encode_frame BEFORE any byte
                            // is written, so the stream is still in sync
                            // and a typed error reply is safe
                            Err(e @ LeapError::Protocol(_)) => {
                                wire::write_frame(writer, &Frame::error(id, &e))?;
                            }
                            Err(e) => return Err(e),
                        }
                    }
                }
            }
            FrameKind::Response | FrameKind::Error => {
                let e = LeapError::Protocol(format!(
                    "unexpected {:?} frame from a client",
                    frame.kind
                ));
                wire::write_frame(writer, &Frame::error(frame.id, &e))?;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// clients
// ---------------------------------------------------------------------------

/// Minimal blocking **protocol-v1** client (line-delimited JSON). Kept
/// for compatibility with existing tooling; new clients should use
/// [`BinaryClient`] — v1 ships every f32 as decimal text.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client, LeapError> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send one op and wait for its reply.
    pub fn call(&mut self, op: &str, inputs: &[&[f32]]) -> Result<Json, LeapError> {
        let id = self.next_id;
        self.next_id += 1;
        let doc = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("op", Json::Str(op.to_string())),
            (
                "inputs",
                Json::Arr(
                    inputs
                        .iter()
                        .map(|b| Json::Arr(b.iter().map(|&x| Json::Num(x as f64)).collect()))
                        .collect(),
                ),
            ),
        ]);
        writeln!(self.writer, "{doc}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| LeapError::Protocol(format!("bad reply: {e}")))
    }

    /// Call a single-tensor op and parse the reply: the first output as
    /// a `Vec<f32>`, or the typed error reconstructed from the reply's
    /// `code`/`error` fields.
    pub fn call_tensor(&mut self, op: &str, input: &[f32]) -> Result<Vec<f32>, LeapError> {
        let reply = self.call(op, &[input])?;
        if let Some(msg) = reply.get_str("error") {
            let code = reply.get_f64("code").unwrap_or(0.0) as u16;
            return Err(LeapError::from_wire(code, msg.to_string()));
        }
        let outputs = reply
            .get("outputs")
            .and_then(|o| o.as_arr())
            .ok_or_else(|| LeapError::Protocol("reply missing outputs".into()))?;
        let first = outputs
            .first()
            .and_then(|o| o.as_arr())
            .ok_or_else(|| LeapError::Protocol("reply outputs empty".into()))?;
        first
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| LeapError::Protocol("non-numeric output element".into()))
            })
            .collect()
    }

    /// Fetch the telemetry snapshot.
    pub fn stats(&mut self) -> Result<Json, LeapError> {
        writeln!(self.writer, r#"{{"id": 0, "op": "__stats"}}"#)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| LeapError::Protocol(format!("bad reply: {e}")))
    }
}

/// Blocking **protocol-v2** client: binary frames, sessions, typed
/// errors. See `docs/PROTOCOL.md`.
pub struct BinaryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl BinaryClient {
    /// Connect and negotiate the protocol version (Hello exchange). A
    /// server speaking a different version is a typed
    /// [`LeapError::VersionMismatch`]/[`LeapError::Remote`] — never a
    /// silent misparse.
    pub fn connect(addr: &std::net::SocketAddr) -> Result<BinaryClient, LeapError> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        let mut client = BinaryClient { reader: BufReader::new(stream), writer, next_id: 1 };
        let hello = Frame::new(
            FrameKind::Hello,
            0,
            Json::obj(vec![("version", Json::Num(wire::VERSION as f64))]),
            Vec::new(),
        );
        let reply = client.roundtrip(&hello)?;
        match reply.kind {
            FrameKind::Hello => Ok(client),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} hello reply"))),
        }
    }

    fn roundtrip(&mut self, f: &Frame) -> Result<Frame, LeapError> {
        wire::write_frame(&mut self.writer, f)?;
        wire::read_frame(&mut self.reader)?
            .ok_or_else(|| LeapError::Io("server closed the connection".into()))
    }

    /// Register a scan config; returns the session id to project
    /// against. The config travels exactly once — every subsequent
    /// request is a 24-byte header plus the tensor. The session runs on
    /// the server's default compute backend; use
    /// [`BinaryClient::open_session_with`] to pick one (and learn which
    /// tier an unset knob resolved to).
    pub fn open_session(
        &mut self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
    ) -> Result<u64, LeapError> {
        self.open_session_with(cfg, model, threads, None).map(|(id, _)| id)
    }

    /// [`BinaryClient::open_session`] with an explicit compute-backend
    /// request (`"scalar"`/`"simd"`; the non-executing `"pjrt"` slot and
    /// unknown names are typed server-side errors). Returns the session
    /// id plus the backend name the server actually resolved — when
    /// `backend` is `None` that is the server process's default tier.
    pub fn open_session_with(
        &mut self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
        backend: Option<&str>,
    ) -> Result<(u64, String), LeapError> {
        let mut meta = vec![
            (
                "config",
                Json::obj(vec![
                    ("geometry", geometry_to_json(&cfg.geometry)),
                    ("volume", volume_to_json(&cfg.volume)),
                ]),
            ),
            ("model", Json::Str(model.name().to_string())),
        ];
        if let Some(t) = threads {
            meta.push(("threads", Json::Num(t as f64)));
        }
        if let Some(b) = backend {
            meta.push(("backend", Json::Str(b.to_string())));
        }
        let reply =
            self.roundtrip(&Frame::new(FrameKind::OpenSession, 0, Json::obj(meta), Vec::new()))?;
        match reply.kind {
            FrameKind::OpenSession => {
                let backend = reply.meta.get_str("backend").unwrap_or("unknown").to_string();
                Ok((reply.id, backend))
            }
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} open-session reply"))),
        }
    }

    /// Release a session.
    pub fn close_session(&mut self, session: u64) -> Result<(), LeapError> {
        let reply =
            self.roundtrip(&Frame::new(FrameKind::CloseSession, session, Json::Null, Vec::new()))?;
        match reply.kind {
            FrameKind::CloseSession => Ok(()),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} close-session reply"))),
        }
    }

    /// Execute one typed op; returns the full Response frame (payload =
    /// output tensor; meta carries latency/exec/batch observability).
    /// The input tensor is serialized straight from the borrowed slice
    /// ([`wire::write_frame_parts`]) — no owned copy on the client side.
    pub fn call(&mut self, op: &Op, input: &[f32]) -> Result<Frame, LeapError> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame_parts(
            &mut self.writer,
            FrameKind::Request,
            id,
            &super::request::request_meta(op),
            input,
        )?;
        let reply = wire::read_frame(&mut self.reader)?
            .ok_or_else(|| LeapError::Io("server closed the connection".into()))?;
        match reply.kind {
            FrameKind::Response if reply.id == id => Ok(reply),
            FrameKind::Response => Err(LeapError::Protocol(format!(
                "response id {} does not match request id {id}",
                reply.id
            ))),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} reply"))),
        }
    }

    /// Forward projection on an open session.
    pub fn forward(&mut self, session: u64, vol: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionFp(session), vol)?.payload)
    }

    /// Matched backprojection on an open session.
    pub fn back(&mut self, session: u64, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionBp(session), sino)?.payload)
    }

    /// FBP/FDK reconstruction on an open session.
    pub fn fbp(&mut self, session: u64, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionFbp(session), sino)?.payload)
    }

    /// Register a tape pipeline (its structure, not its parameter
    /// values) on an open session; returns the pipeline id for
    /// [`BinaryClient::pipeline_grad`]. The server rebinds the spec's
    /// `"scan"` operator to the session's pinned plan.
    pub fn register_pipeline(
        &mut self,
        session: u64,
        pipe: &tape::Pipeline,
    ) -> Result<u64, LeapError> {
        let meta = Json::obj(vec![("pipeline", tape::pipeline_to_json(pipe))]);
        let reply =
            self.roundtrip(&Frame::new(FrameKind::RegisterPipeline, session, meta, Vec::new()))?;
        match reply.kind {
            FrameKind::RegisterPipeline => Ok(reply.id),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} register-pipeline reply"))),
        }
    }

    /// Evaluate a registered pipeline's loss + parameter gradients on
    /// the server: params + inputs are packed into one tensor
    /// ([`tape::Pipeline::pack`]), the reply unpacks to the exact f64
    /// loss and per-parameter gradients — bit-identical to calling
    /// [`tape::Pipeline::loss_and_grads_with`] locally on the same plan.
    /// `pipe` is the local copy of the registered pipeline (it defines
    /// the packing layout).
    pub fn pipeline_grad(
        &mut self,
        session: u64,
        pipeline: u64,
        pipe: &tape::Pipeline,
        params: &[&[f32]],
        inputs: &[&[f32]],
    ) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        let packed = pipe.pack(params, inputs)?;
        let reply = self.call(&Op::SessionPipelineGrad { session, pipeline }, &packed)?;
        pipe.unpack_grad_reply(&reply.payload)
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::MockExecutor;
    use super::super::{BatchPolicy, Coordinator, Executor, NativeExecutor, Router, SessionExecutor};
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::Projector;

    fn start_mock() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            2,
        ));
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    fn scan_config() -> ScanConfig {
        ScanConfig {
            geometry: Geometry::Parallel(ParallelBeam::standard_2d(10, 24, 1.0)),
            volume: VolumeGeometry::slice2d(16, 16, 1.0),
        }
    }

    fn start_native() -> (Server, Arc<Coordinator>) {
        let cfg = scan_config();
        let native = NativeExecutor::new(
            Projector::new(cfg.geometry.clone(), cfg.volume.clone(), Model::SF).with_threads(2),
        );
        let router: Arc<dyn Executor> = Arc::new(Router::new(vec![
            Arc::new(native),
            Arc::new(SessionExecutor::new()),
        ]));
        let coord = Arc::new(Coordinator::new(router, BatchPolicy::default(), 1 << 28, 2));
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    #[test]
    fn tcp_roundtrip() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("echo", &[&[1.0, 3.0]]).unwrap();
        let outs = reply.get("outputs").unwrap().as_arr().unwrap();
        let first = outs[0].as_arr().unwrap();
        assert_eq!(first[0].as_f64(), Some(2.0));
        assert_eq!(first[1].as_f64(), Some(6.0));
        // the batching observability field rides on every reply
        assert!(reply.get_f64("batch_size").unwrap_or(0.0) >= 1.0);
    }

    #[test]
    fn error_propagates_with_typed_code() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("fail", &[&[1.0]]).unwrap();
        assert!(reply.get_str("error").unwrap().contains("mock failure"));
        assert_eq!(reply.get_f64("code"), Some(crate::api::codes::BACKEND as f64));
    }

    #[test]
    fn stats_endpoint() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        client.call("echo", &[&[1.0]]).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(
            stats.get("stats").unwrap().get("echo").unwrap().get_f64("count"),
            Some(1.0)
        );
        // the shared projector pool is reported alongside request stats
        assert!(stats.get_f64("pool_workers").is_some());
        assert!(stats.get_f64("pool_regions").is_some());
        assert!(stats.get_f64("open_sessions").is_some());
    }

    #[test]
    fn multiple_clients() {
        let (server, _coord) = start_mock();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..10 {
                    let r = client.call("echo", &[&[t as f32 + i as f32]]).unwrap();
                    assert!(r.get("outputs").is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn malformed_line_gets_error_reply() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "this is not json").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
    }

    #[test]
    fn v2_session_roundtrip_matches_in_process_bits() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let scan = crate::api::ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let mut vol = vec![0.0f32; scan.volume_len()];
        crate::util::rng::Rng::new(9).fill_uniform(&mut vol, 0.0, 1.0);
        let served = client.forward(session, &vol).unwrap();
        let local = scan.forward(&vol).unwrap();
        assert_eq!(served, local, "v2 session forward must be bit-identical");
        let back_served = client.back(session, &served).unwrap();
        assert_eq!(back_served, scan.back(&served).unwrap());
        client.close_session(session).unwrap();
        // using the closed session is a typed error
        let e = client.forward(session, &vol).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
    }

    #[test]
    fn v2_shape_and_geometry_errors_are_typed_on_the_wire() {
        let (server, _coord) = start_native();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&scan_config(), Model::SF, Some(2)).unwrap();
        // wrong tensor length → SHAPE_MISMATCH code, connection survives
        let e = client.forward(session, &[1.0, 2.0, 3.0]).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::SHAPE_MISMATCH, "{e:?}");
        // degenerate config → INVALID_GEOMETRY
        let mut bad = scan_config();
        bad.volume.nx = 0;
        let e = client.open_session(&bad, Model::SF, None).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::INVALID_GEOMETRY, "{e:?}");
        // the connection still works after both errors
        let vol = vec![0.1f32; 256];
        assert!(client.forward(session, &vol).is_ok());
    }

    #[test]
    fn v1_and_v2_clients_share_one_port_and_agree() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let vol = vec![0.02f32; 256];
        // v2 session path
        let mut v2 = BinaryClient::connect(&server.addr).unwrap();
        let session = v2.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let from_v2 = v2.forward(session, &vol).unwrap();
        // v1 JSON path against the statically-configured native backend
        let mut v1 = Client::connect(&server.addr).unwrap();
        let from_v1 = v1.call_tensor("native_fp", &vol).unwrap();
        assert_eq!(from_v1, from_v2, "both protocols must return identical bits");
    }

    #[test]
    fn v2_version_mismatch_is_rejected() {
        let (server, _coord) = start_native();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // a well-formed frame with a bad version byte
        let mut bytes =
            wire::encode_frame(&Frame::new(FrameKind::Hello, 0, Json::Null, vec![])).unwrap();
        bytes[4] = 9;
        writer.write_all(&bytes).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("error frame");
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(
            reply.to_error().code(),
            crate::api::codes::VERSION_MISMATCH,
            "{:?}",
            reply.to_error()
        );
        // and the server closes the connection afterwards
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn v2_malformed_frame_is_rejected_with_protocol_code() {
        let (server, _coord) = start_native();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // correct magic so the connection sniffs as v2, then garbage
        let mut bytes =
            wire::encode_frame(&Frame::new(FrameKind::Hello, 0, Json::Null, vec![])).unwrap();
        bytes[5] = 200; // unknown frame kind
        writer.write_all(&bytes).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("error frame");
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::PROTOCOL);
    }

    #[test]
    fn v2_pipeline_grad_over_tcp_is_bit_identical_to_the_in_process_tape() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let scan = crate::api::ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let local: std::sync::Arc<dyn crate::ops::LinearOp> =
            std::sync::Arc::new(crate::ops::PlanOp::from_plan(scan.plan().clone()));
        let pipe = tape::unrolled_gd(
            local,
            &tape::UnrollCfg { iterations: 2, step_init: 0.01, nonneg: true },
        )
        .unwrap();

        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let pid = client.register_pipeline(session, &pipe).unwrap();

        let mut rng = crate::util::rng::Rng::new(29);
        let params: Vec<Vec<f32>> = pipe
            .params()
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.shape.numel()];
                rng.fill_uniform(&mut v, 0.005, 0.02);
                v
            })
            .collect();
        let inputs: Vec<Vec<f32>> = pipe
            .input_shapes()
            .iter()
            .map(|s| {
                let mut v = vec![0.0f32; s.numel()];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let (served_loss, served_grads) =
            client.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap();
        let (local_loss, local_grads) = pipe.loss_and_grads_with(&pr, &ir).unwrap();
        assert_eq!(
            served_loss.to_bits(),
            local_loss.to_bits(),
            "served loss must be bit-identical to the in-process tape"
        );
        assert_eq!(served_grads, local_grads, "served gradients must be bit-identical");

        // a second connection cannot register on (or grad against) a
        // session it did not open — identical to a nonexistent session
        let mut intruder = BinaryClient::connect(&server.addr).unwrap();
        let e = intruder.register_pipeline(session, &pipe).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
        let e = intruder.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");

        client.close_session(session).unwrap();
        // the pipeline died with its session
        let e = client.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
    }

    #[test]
    fn v2_sessions_negotiate_and_report_their_backend() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let (scalar_id, scalar_name) =
            client.open_session_with(&cfg, Model::SF, Some(2), Some("scalar")).unwrap();
        assert_eq!(scalar_name, "scalar");
        let (simd_id, simd_name) =
            client.open_session_with(&cfg, Model::SF, Some(2), Some("simd")).unwrap();
        assert_eq!(simd_name, "simd");
        // SF-parallel staging is in the bit-identical equivalence class
        // (docs/BACKENDS.md), so the two tiers agree exactly on the wire
        let mut vol = vec![0.0f32; 256];
        crate::util::rng::Rng::new(31).fill_uniform(&mut vol, 0.0, 1.0);
        assert_eq!(
            client.forward(scalar_id, &vol).unwrap(),
            client.forward(simd_id, &vol).unwrap(),
        );
        // an unset knob resolves to the process default — and the reply
        // says which tier that was
        let (_dflt_id, dflt_name) =
            client.open_session_with(&cfg, Model::SF, None, None).unwrap();
        assert!(dflt_name == "scalar" || dflt_name == "simd", "{dflt_name}");
        // v1 telemetry exposes the default and the per-session tiers
        let mut v1 = Client::connect(&server.addr).unwrap();
        let stats = v1.stats().unwrap();
        assert_eq!(stats.get_str("default_backend"), Some(dflt_name.as_str()));
        let per_session = stats.get("session_backends").expect("per-session backend map");
        assert_eq!(per_session.get_str(&scalar_id.to_string()), Some("scalar"));
        assert_eq!(per_session.get_str(&simd_id.to_string()), Some("simd"));
        // the non-executing pjrt slot and unknown names are typed
        // errors on the wire, never a silent fallback
        let e = client.open_session_with(&cfg, Model::SF, None, Some("pjrt")).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNSUPPORTED, "{e:?}");
        let e = client.open_session_with(&cfg, Model::SF, None, Some("warp")).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::INVALID_ARGUMENT, "{e:?}");
    }

    // ── protocol-sniffing robustness (first-exchange hardening) ────────

    /// Read the single reply frame a hardening test expects. A slow
    /// machine can instead trip the client's guard deadline, which
    /// surfaces platform-dependently (`WouldBlock` on unix, `TimedOut`
    /// on windows) — fail with one uniform diagnostic for both rather
    /// than a platform-specific unwrap panic.
    fn expect_reply_frame(reader: &mut BufReader<TcpStream>) -> Frame {
        match wire::read_frame(reader) {
            Ok(Some(f)) => f,
            Ok(None) => panic!("server closed before sending the expected reply frame"),
            Err(e) => panic!("no reply before the client guard deadline: {e}"),
        }
    }

    #[test]
    fn timeout_classification_accepts_both_platform_kinds() {
        use std::io::{Error, ErrorKind};
        // unix surfaces an expired read deadline as WouldBlock, windows
        // as TimedOut; both must classify as the deadline firing
        assert!(is_timeout(&Error::from(ErrorKind::WouldBlock)));
        assert!(is_timeout(&Error::from(ErrorKind::TimedOut)));
        // and real I/O failures must not
        assert!(!is_timeout(&Error::from(ErrorKind::BrokenPipe)));
        assert!(!is_timeout(&Error::from(ErrorKind::UnexpectedEof)));
    }

    #[test]
    fn zero_byte_connection_closes_cleanly_and_server_survives() {
        let (server, _coord) = start_mock();
        {
            let stream = TcpStream::connect(server.addr).unwrap();
            stream.shutdown(std::net::Shutdown::Write).unwrap();
            // server should see EOF and close without writing anything
            let mut reader = BufReader::new(stream);
            let mut buf = String::new();
            let n = reader.read_line(&mut buf).unwrap();
            assert_eq!(n, 0, "no reply owed on a 0-byte connection, got {buf:?}");
        }
        // the accept loop is unharmed: a real client still works
        let mut client = Client::connect(&server.addr).unwrap();
        assert!(client.call("echo", &[&[1.0]]).unwrap().get("outputs").is_some());
    }

    #[test]
    fn one_byte_then_close_is_a_typed_protocol_error() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writer.write_all(b"L").unwrap(); // sniffs as v2 …
        writer.flush().unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap(); // … then EOF mid-header
        let mut reader = BufReader::new(stream);
        let reply = expect_reply_frame(&mut reader);
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::PROTOCOL, "{:?}", reply.to_error());
        // and the connection closes cleanly afterwards
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn one_byte_then_stall_times_out_with_a_typed_error_never_hangs() {
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            1,
        ));
        let server = Server::start_with_handshake_timeout(
            "127.0.0.1:0",
            coord,
            Duration::from_millis(100),
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writer.write_all(b"L").unwrap(); // sniffs as v2 …
        writer.flush().unwrap();
        // … then stall (write half stays open). The handshake deadline
        // must fire: a typed error frame, then the connection closes.
        let mut reader = BufReader::new(stream);
        let reply = expect_reply_frame(&mut reader);
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::IO, "{:?}", reply.to_error());
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn unrecognized_first_byte_is_rejected_with_a_typed_error_line() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        // neither '{' (v1) nor 'L' (v2): a protocol we don't speak
        writer.write_all(b"GET / HTTP/1.1\r\n").unwrap();
        writer.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let reply = parse(&line).expect("one JSON error line");
        assert!(reply.get_str("error").unwrap().contains("unrecognized protocol"), "{line}");
        assert_eq!(reply.get_f64("code"), Some(crate::api::codes::PROTOCOL as f64));
        // then the server closes instead of re-rejecting every line
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "connection must close: {rest:?}");
    }

    #[test]
    fn sessions_close_when_their_connection_drops() {
        let (server, _coord) = start_native();
        let session = {
            let mut client = BinaryClient::connect(&server.addr).unwrap();
            let id = client.open_session(&scan_config(), Model::SF, Some(2)).unwrap();
            // open sessions are visible process-wide (exact counts would
            // race with concurrently-running tests on the global registry)
            assert!(SessionRegistry::global().executor(id).is_some());
            id
        }; // client dropped: connection closes
        // give the server thread a moment to observe the disconnect
        for _ in 0..100 {
            if SessionRegistry::global().executor(session).is_none() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(
            SessionRegistry::global().executor(session).is_none(),
            "disconnect must release the session"
        );
    }
}
