//! Async multiplexed TCP serving plane speaking both wire protocols on
//! one port.
//!
//! **v2 (preferred)** — length-prefixed binary frames with raw
//! little-endian f32 payloads ([`super::wire`], spec in
//! `docs/PROTOCOL.md`): Hello version negotiation, an OpenSession
//! handshake that registers a scan config once (validated, planned,
//! pinned — [`super::session`]) and reports the compute backend the
//! session resolved to, then per-request 24-byte headers + tensors.
//! Drive it with [`BinaryClient`].
//!
//! **v1 (legacy)** — one JSON document per line:
//!   → {"id": 1, "op": "fp_sf", "inputs": [[...f32...], ...]}
//!   ← {"id": 1, "op": "fp_sf", "outputs": [[...]], "latency_us": ..,
//!      "exec_us": .., "batch_size": ..}
//!   → {"id": 2, "op": "__stats"}          — telemetry snapshot
//!   → {"id": 3, "op": "__ops"}            — available operations
//! Error replies carry the human message plus the stable typed `code`
//! ([`crate::api::codes`]). Drive it with [`Client`], kept for
//! compatibility — new clients should speak v2.
//!
//! The protocol is sniffed from the first byte of each connection: `{`
//! (or whitespace) opens a v1 JSON line session, `L` (the frame magic)
//! opens a v2 binary session. Sessions opened over a v2 connection are
//! closed when that connection drops.
//!
//! ## Architecture: one event loop, no thread per connection
//!
//! All connections are **nonblocking** sockets multiplexed on a single
//! readiness-polling thread ([`crate::util::netpoll`] — `poll(2)`
//! without a dependency, mirroring how [`crate::util::pool`] hand-rolls
//! its workers instead of pulling in tokio). Each connection is a small
//! state machine: a read buffer reassembled incrementally (v2 frames
//! via [`wire::decode_frame_bytes`], v1 lines by newline scan), a write
//! buffer, and a FIFO of reply *tickets*. Every inbound request pushes
//! exactly one ticket — either `Ready` bytes (control replies, typed
//! shed errors) or `Waiting` on the coordinator's response channel — and
//! the write side drains tickets strictly front-first, so replies never
//! reorder within a connection even though many requests from many
//! connections are in flight in the shared worker pool simultaneously.
//! OS thread count is O(pool workers + 1), independent of connection
//! count: hundreds of concurrent sessions cost buffers, not threads.
//! Worker completions reach the loop through a wakeup fd in the poll
//! set (a [`crate::util::netpoll::Waker`] registered as a coordinator
//! completion hook): a finishing worker writes one byte, the poll
//! returns, the response flushes — no busy tick while requests are in
//! flight, and the 25 ms idle timeout remains only as a safety net.
//!
//! ## Admission control and load shedding
//!
//! Two gates refuse work *before* it queues, each with a typed
//! [`LeapError::BudgetExceeded`] reply (code 6) on the requester's own
//! connection, in order, with the stream left fully in sync:
//! * per-connection in-flight cap ([`ServerOptions::max_inflight_per_conn`])
//!   — one greedy pipeliner cannot monopolize the pool;
//! * coordinator pending-queue cap ([`super::Coordinator::try_submit`])
//!   — global overload sheds instead of queueing unboundedly.
//! Shed counts and p99 latency ride the `__stats` telemetry snapshot.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::LeapError;
use crate::geometry::config::{geometry_to_json, volume_to_json, ScanConfig};
use crate::projector::Model;
use crate::tape;
use crate::util::json::{parse, Json};
use crate::util::netpoll::{poll_fds, raw_fd, PollFd, Waker, POLLIN, POLLOUT};

use super::op::Op;
use super::request::{
    request_from_frame, request_from_json, response_to_frame, response_to_json, Request, Response,
};
use super::session::SessionRegistry;
use super::wire::{self, Frame, FrameKind};
use super::Coordinator;

/// Deadline for a connection's **first** complete frame (v2) or line
/// (v1), measured from accept. Without it, a peer that connects and
/// sends zero or one bytes then stalls would pin its connection state
/// (and an open fd) forever. Once the first exchange completes the
/// deadline is lifted: idle-but-honest clients (a training loop
/// thinking between gradient requests) are never dropped.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Default per-connection cap on requests awaiting responses (the
/// `Waiting` tickets of one connection). Past it, further requests on
/// that connection shed with a typed [`LeapError::BudgetExceeded`]
/// reply delivered in order — the stream stays in sync and the client
/// can retry after draining replies.
pub const DEFAULT_MAX_INFLIGHT_PER_CONN: usize = 64;

/// Fallback poll timeout while requests await worker responses and the
/// completion waker could not be created (degraded environments without
/// a loopback): short, so finished responses still reach their sockets
/// promptly. With a live [`Waker`] the loop never busy-ticks — worker
/// completions write the wakeup fd and interrupt the poll directly.
const BUSY_TICK: Duration = Duration::from_millis(1);
/// Poll timeout safety net. Readiness (sockets AND the wakeup fd) wakes
/// the loop immediately; this only bounds how long a stop request, a
/// handshake deadline, or a lost wakeup waits.
const IDLE_TICK: Duration = Duration::from_millis(25);

/// Server tuning knobs ([`Server::start_with`]).
#[derive(Clone)]
pub struct ServerOptions {
    /// First-exchange deadline (see [`HANDSHAKE_TIMEOUT`]).
    pub handshake_timeout: Duration,
    /// Per-connection in-flight request cap (see
    /// [`DEFAULT_MAX_INFLIGHT_PER_CONN`]); minimum 1.
    pub max_inflight_per_conn: usize,
    /// Session registry this server opens sessions in. `None` = the
    /// process-wide [`SessionRegistry::global`]. Inject a dedicated
    /// registry (paired with a [`super::SessionExecutor::with_registry`]
    /// backend on the same `Arc`) to isolate concurrent servers in one
    /// process — tests especially — from each other's sessions.
    pub registry: Option<Arc<SessionRegistry>>,
    /// Shard channel this server reports in `__stats` (`cluster` /
    /// `cluster_workers` rows: per-shard dispatch, retry and latency
    /// telemetry). Routing through the cluster is the paired
    /// [`super::SessionExecutor::with_cluster`] backend's job; this
    /// handle only makes the shard plane observable.
    pub cluster: Option<Arc<crate::cluster::ShardServer>>,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            handshake_timeout: HANDSHAKE_TIMEOUT,
            max_inflight_per_conn: DEFAULT_MAX_INFLIGHT_PER_CONN,
            registry: None,
            cluster: None,
        }
    }
}

/// A running server; dropping stops the event loop (in-flight replies
/// are abandoned, open sessions of live connections unpin).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    loop_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `coordinator` until
    /// dropped, with default [`ServerOptions`].
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Server, LeapError> {
        Server::start_with(addr, coordinator, ServerOptions::default())
    }

    /// [`Server::start`] with an explicit first-exchange deadline
    /// (tests use short deadlines to exercise the stall paths).
    pub fn start_with_handshake_timeout(
        addr: &str,
        coordinator: Arc<Coordinator>,
        handshake: Duration,
    ) -> Result<Server, LeapError> {
        Server::start_with(
            addr,
            coordinator,
            ServerOptions { handshake_timeout: handshake, ..ServerOptions::default() },
        )
    }

    /// Bind `addr` and serve `coordinator` on one event-loop thread
    /// until dropped.
    pub fn start_with(
        addr: &str,
        coordinator: Arc<Coordinator>,
        opts: ServerOptions,
    ) -> Result<Server, LeapError> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let registry = opts.registry.clone().unwrap_or_else(SessionRegistry::global_arc);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            event_loop(listener, coordinator, registry, opts, stop2);
        });
        Ok(Server { addr: local, stop, loop_handle: Some(handle) })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
    }
}

/// Whether an I/O error is "not ready yet" on a nonblocking socket or
/// an expired read deadline. Both kinds MUST be accepted: unix surfaces
/// these as `WouldBlock`, windows read deadlines as `TimedOut`.
/// `pub(crate)` so tests and other connection-handling code classify
/// them through this one predicate instead of re-matching kinds.
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

// ---------------------------------------------------------------------------
// the event loop
// ---------------------------------------------------------------------------

fn event_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    registry: Arc<SessionRegistry>,
    opts: ServerOptions,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut fds: Vec<PollFd> = Vec::new();
    // Completion wakeup fd: each worker that finishes a job writes one
    // byte here (via the coordinator's completion hook), interrupting
    // the poll immediately — in-flight responses no longer wait on a
    // 1 ms busy tick. The hook Arc is the registration: dropping it
    // when the loop exits unregisters from the coordinator. Waker
    // creation can fail in loopback-less environments; the loop then
    // degrades to the busy-tick schedule it replaced.
    let waker = Waker::new().ok().map(Arc::new);
    let _hook: Option<Arc<dyn Fn() + Send + Sync>> = waker.as_ref().map(|w| {
        let w = w.clone();
        let hook: Arc<dyn Fn() + Send + Sync> = Arc::new(move || w.wake());
        coord.add_completion_hook(Arc::downgrade(&hook));
        hook
    });
    // conns[i] pairs with fds[i + base]
    let base = 1 + usize::from(waker.is_some());
    while !stop.load(Ordering::SeqCst) {
        // poll set: listener first, then the waker, then every
        // connection in order
        fds.clear();
        fds.push(PollFd::new(raw_fd(&listener), POLLIN));
        if let Some(w) = &waker {
            fds.push(PollFd::new(w.fd(), POLLIN));
        }
        for c in &conns {
            let mut ev = 0i16;
            if !c.done_reading {
                ev |= POLLIN;
            }
            if c.woff < c.wbuf.len() {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(raw_fd(&c.stream), ev));
        }
        let tick = if waker.is_some() {
            IDLE_TICK // worker completions interrupt the poll directly
        } else if conns.iter().any(|c| c.waiting > 0) {
            BUSY_TICK // degraded: no waker, rediscover responses by tick
        } else {
            IDLE_TICK
        };
        poll_fds(&mut fds, tick);
        if let Some(w) = &waker {
            if fds[1].readable() {
                w.drain();
            }
        }

        let polled = conns.len(); // fds[base..base+polled] pairs with conns[..polled]

        // accept every pending connection (new ones join the poll set —
        // and get an immediate first service pass — below)
        if fds[0].readable() {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        // small frames back-to-back: don't let Nagle
                        // hold a reply hostage to the next one
                        let _ = stream.set_nodelay(true);
                        conns.push(Conn::new(stream, Instant::now() + opts.handshake_timeout));
                    }
                    Err(ref e) if is_timeout(e) => break,
                    Err(_) => break,
                }
            }
        }

        let now = Instant::now();
        for (i, c) in conns.iter_mut().enumerate() {
            // freshly accepted connections (i >= polled) were not in the
            // poll set; their sockets are nonblocking, so an optimistic
            // read costs at most one EWOULDBLOCK
            if i >= polled || fds[i + base].readable() {
                c.fill_rbuf();
            }
            c.process_input(&coord, &registry, &opts);
            c.check_deadline(now);
            c.drain_tickets();
            c.flush();
        }
        conns.retain_mut(|c| {
            if c.finished() {
                // sessions opened over this connection close with it
                for id in c.opened.drain(..) {
                    registry.close(id);
                }
                false
            } else {
                true
            }
        });
    }
    // server dropped: unpin sessions of still-live connections
    for c in &mut conns {
        for id in c.opened.drain(..) {
            registry.close(id);
        }
    }
}

// ---------------------------------------------------------------------------
// per-connection state machine
// ---------------------------------------------------------------------------

/// Wire protocol of a connection, sniffed from its first byte.
enum Mode {
    Sniffing,
    V1,
    V2,
}

/// One reply owed on a connection, in request order. The write side
/// drains the FIFO strictly front-first: a resolved-later reply never
/// overtakes an earlier in-flight one, and shed errors (pushed as
/// `Ready`) hold their slot in the same order.
enum Ticket {
    /// Encoded reply bytes, ready to write.
    Ready(Vec<u8>),
    /// A request in the worker pool; `rx` resolves to its response.
    Waiting { id: u64, rx: Receiver<Response>, v1: bool },
}

struct Conn {
    stream: TcpStream,
    mode: Mode,
    /// Unconsumed inbound bytes (partial frames / lines reassemble here).
    rbuf: Vec<u8>,
    /// Outbound bytes not yet accepted by the kernel; `woff` marks the
    /// written prefix.
    wbuf: Vec<u8>,
    woff: usize,
    tickets: VecDeque<Ticket>,
    /// Count of `Waiting` tickets (the per-connection in-flight gauge).
    waiting: usize,
    /// Sessions opened over this connection (closed when it drops).
    opened: Vec<u64>,
    /// First-exchange deadline; `None` once a complete frame/line arrived.
    deadline: Option<Instant>,
    /// Stop consuming input (peer EOF or protocol fault): flush
    /// remaining tickets, then close.
    done_reading: bool,
    /// Fatal socket error: discard immediately.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, deadline: Instant) -> Conn {
        Conn {
            stream,
            mode: Mode::Sniffing,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            woff: 0,
            tickets: VecDeque::new(),
            waiting: 0,
            opened: Vec::new(),
            deadline: Some(deadline),
            done_reading: false,
            dead: false,
        }
    }

    fn finished(&self) -> bool {
        self.dead
            || (self.done_reading && self.tickets.is_empty() && self.woff >= self.wbuf.len())
    }

    /// Protocol fault: whatever remains in `rbuf` is untrusted; reply
    /// tickets already queued still flush, then the connection closes.
    fn fail(&mut self) {
        self.rbuf.clear();
        self.done_reading = true;
        self.deadline = None;
    }

    /// Nonblocking read burst: drain the kernel buffer into `rbuf`.
    fn fill_rbuf(&mut self) {
        if self.done_reading || self.dead {
            return;
        }
        let mut chunk = [0u8; 16 << 10];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.done_reading = true; // peer EOF; leftovers handled in process_input
                    return;
                }
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(ref e) if is_timeout(e) => return,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    fn process_input(
        &mut self,
        coord: &Coordinator,
        registry: &SessionRegistry,
        opts: &ServerOptions,
    ) {
        if self.dead {
            return;
        }
        if matches!(self.mode, Mode::Sniffing) && !self.rbuf.is_empty() {
            // sniff the protocol from the first byte: JSON documents
            // open with '{' (or whitespace), v2 frames with the "LEAP"
            // magic; anything else is not a protocol we speak
            let first = self.rbuf[0];
            if first == wire::MAGIC[0] {
                self.mode = Mode::V2;
            } else if first == b'{' || first.is_ascii_whitespace() {
                self.mode = Mode::V1;
            } else {
                // unrecognized protocol: say so once, in the (text)
                // format any probing client can read, then close —
                // never fall into the v1 loop to re-reject every
                // subsequent line of noise
                let e = LeapError::Protocol(format!(
                    "unrecognized protocol (first byte 0x{first:02x}; expected '{{' for JSON \
                     lines or 'L' for LEAP v2 frames)"
                ));
                self.push_line(&error_json(&e));
                self.fail();
                return;
            }
        }
        match self.mode {
            Mode::Sniffing => {}
            Mode::V1 => self.process_v1(coord, registry, opts),
            Mode::V2 => self.process_v2(coord, registry, opts),
        }
        if self.done_reading && !self.rbuf.is_empty() {
            // peer EOF with a partial frame/line still buffered
            match self.mode {
                Mode::V2 => {
                    let e = LeapError::Protocol("connection closed mid-frame".into());
                    self.push_frame(&Frame::error(0, &e));
                    self.rbuf.clear();
                }
                Mode::V1 => {
                    // an unterminated final line still gets its reply
                    let line = String::from_utf8_lossy(&self.rbuf).into_owned();
                    self.rbuf.clear();
                    self.deadline = None;
                    self.handle_v1_line(&line, coord, registry, opts);
                }
                Mode::Sniffing => self.rbuf.clear(),
            }
        }
    }

    /// First-exchange deadline: expired with nothing sniffed → silent
    /// close (no reply format is owed); expired mid-frame/mid-line →
    /// typed code-10 reply in the sniffed format, then close.
    fn check_deadline(&mut self, now: Instant) {
        let Some(d) = self.deadline else { return };
        if now < d || self.done_reading || self.dead {
            return;
        }
        match self.mode {
            Mode::Sniffing => {}
            Mode::V1 => {
                let e = LeapError::Io("handshake timed out mid-line".into());
                self.push_line(&error_json(&e));
            }
            Mode::V2 => {
                let e = LeapError::Io("handshake timed out mid-frame".into());
                self.push_frame(&Frame::error(0, &e));
            }
        }
        self.fail();
    }

    // ── v1: line-delimited JSON ────────────────────────────────────────

    fn process_v1(
        &mut self,
        coord: &Coordinator,
        registry: &SessionRegistry,
        opts: &ServerOptions,
    ) {
        while let Some(pos) = self.rbuf.iter().position(|&b| b == b'\n') {
            let line_bytes: Vec<u8> = self.rbuf.drain(..=pos).collect();
            // a complete line from a real v1 speaker: lift the deadline
            self.deadline = None;
            let line = String::from_utf8_lossy(&line_bytes).into_owned();
            if line.trim().is_empty() {
                continue;
            }
            self.handle_v1_line(&line, coord, registry, opts);
        }
    }

    fn handle_v1_line(
        &mut self,
        line: &str,
        coord: &Coordinator,
        registry: &SessionRegistry,
        opts: &ServerOptions,
    ) {
        match parse(line) {
            Err(e) => self.push_line(&Json::obj(vec![
                ("error", Json::Str(format!("bad json: {e}"))),
                ("code", Json::Num(crate::api::codes::PROTOCOL as f64)),
            ])),
            Ok(doc) => {
                let op = doc.get_str("op").unwrap_or("");
                match op {
                    "__stats" => {
                        let reply = stats_json(&doc, coord, registry, opts.cluster.as_deref());
                        self.push_line(&reply);
                    }
                    "__ops" => {
                        let reply = Json::obj(vec![
                            ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
                            (
                                "ops",
                                Json::Arr(
                                    coord
                                        .executor()
                                        .ops()
                                        .into_iter()
                                        .map(|o| Json::Str(o.label()))
                                        .collect(),
                                ),
                            ),
                        ]);
                        self.push_line(&reply);
                    }
                    _ => match request_from_json(&doc) {
                        Err(e) => self.push_line(&error_json(&e)),
                        Ok(req) => self.submit_request(req, coord, true, opts),
                    },
                }
            }
        }
    }

    // ── v2: binary frames + sessions ───────────────────────────────────

    fn process_v2(
        &mut self,
        coord: &Coordinator,
        registry: &SessionRegistry,
        opts: &ServerOptions,
    ) {
        loop {
            match wire::decode_frame_bytes(&self.rbuf) {
                Ok(None) => return, // incomplete: wait for more bytes
                Ok(Some((frame, consumed))) => {
                    self.rbuf.drain(..consumed);
                    // a complete frame from a real v2 speaker
                    self.deadline = None;
                    self.handle_v2_frame(frame, coord, registry, opts);
                }
                Err(e) => {
                    // typed reject (version mismatch, malformed frame),
                    // then close: framing cannot be trusted after a bad
                    // header
                    self.push_frame(&Frame::error(0, &e));
                    self.fail();
                    return;
                }
            }
        }
    }

    fn handle_v2_frame(
        &mut self,
        frame: Frame,
        coord: &Coordinator,
        registry: &SessionRegistry,
        opts: &ServerOptions,
    ) {
        match frame.kind {
            FrameKind::Hello => {
                let reply = Frame::new(
                    FrameKind::Hello,
                    frame.id,
                    Json::obj(vec![
                        ("version", Json::Num(wire::VERSION as f64)),
                        ("server", Json::Str("leap".into())),
                    ]),
                    Vec::new(),
                );
                self.push_frame(&reply);
            }
            FrameKind::OpenSession => match registry.open_from_meta(&frame.meta) {
                Ok(id) => {
                    self.opened.push(id);
                    // the authoritative id is the frame's native u64 id
                    // field; the meta copy is a decimal string (f64 JSON
                    // numbers round above 2^53). The reply also names
                    // the compute backend and storage tier the session
                    // resolved to, so clients that left the knobs unset
                    // learn what will serve them (and at which accuracy
                    // class).
                    let backend = registry.backend_of(id).unwrap_or("unknown");
                    let storage = registry.storage_of(id).unwrap_or("unknown");
                    let reply = Frame::new(
                        FrameKind::OpenSession,
                        id,
                        Json::obj(vec![
                            ("session", Json::Str(id.to_string())),
                            ("backend", Json::Str(backend.to_string())),
                            ("storage", Json::Str(storage.to_string())),
                        ]),
                        Vec::new(),
                    );
                    self.push_frame(&reply);
                }
                Err(e) => self.push_frame(&Frame::error(frame.id, &e)),
            },
            FrameKind::CloseSession => {
                // only the connection that opened a session may close
                // it: ids are sequential, so without this check any
                // client could tear down another connection's session by
                // guessing (the same UnknownSession reply for not-yours
                // and never-existed avoids leaking liveness)
                if self.opened.contains(&frame.id) && registry.close(frame.id) {
                    self.opened.retain(|&i| i != frame.id);
                    let reply =
                        Frame::new(FrameKind::CloseSession, frame.id, Json::Null, Vec::new());
                    self.push_frame(&reply);
                } else {
                    let e = LeapError::UnknownSession(frame.id);
                    self.push_frame(&Frame::error(frame.id, &e));
                }
            }
            FrameKind::RegisterPipeline => {
                // connection-scoped like CloseSession: registering on a
                // session you did not open answers exactly like a
                // session that never existed
                if !self.opened.contains(&frame.id) {
                    let e = LeapError::UnknownSession(frame.id);
                    self.push_frame(&Frame::error(frame.id, &e));
                    return;
                }
                let result = frame
                    .meta
                    .get("pipeline")
                    .ok_or_else(|| {
                        LeapError::Protocol("register-pipeline meta missing pipeline spec".into())
                    })
                    .and_then(|spec| registry.register_pipeline(frame.id, spec));
                match result {
                    Ok(pid) => {
                        // reply id = pipeline id; meta repeats both ids
                        // as decimal strings (lossless above 2^53)
                        let reply = Frame::new(
                            FrameKind::RegisterPipeline,
                            pid,
                            Json::obj(vec![
                                ("session", Json::Str(frame.id.to_string())),
                                ("pipeline", Json::Str(pid.to_string())),
                            ]),
                            Vec::new(),
                        );
                        self.push_frame(&reply);
                    }
                    Err(e) => self.push_frame(&Frame::error(frame.id, &e)),
                }
            }
            FrameKind::Request => {
                let id = frame.id;
                match request_from_frame(frame) {
                    Err(e) => self.push_frame(&Frame::error(id, &e)),
                    Ok(req) => {
                        // session ops — projections AND pipeline-grad —
                        // are scoped to the connection that opened the
                        // session (ids are sequential and guessable;
                        // answering not-yours identically to
                        // never-existed leaks neither liveness nor the
                        // victim scan's shape)
                        if let Some(sid) = req.op.session_id() {
                            if !self.opened.contains(&sid) {
                                let e = LeapError::UnknownSession(sid);
                                self.push_frame(&Frame::error(id, &e));
                                return;
                            }
                        }
                        self.submit_request(req, coord, false, opts);
                    }
                }
            }
            FrameKind::Response | FrameKind::Error => {
                let e = LeapError::Protocol(format!(
                    "unexpected {:?} frame from a client",
                    frame.kind
                ));
                self.push_frame(&Frame::error(frame.id, &e));
            }
        }
    }

    // ── admission + reply plumbing ─────────────────────────────────────

    /// Admit one request into the shared worker pool, or shed it with a
    /// typed error reply **in its FIFO slot**. Gates fire in order:
    /// per-connection in-flight cap first, then the coordinator's
    /// pending-queue cap ([`Coordinator::try_submit`]).
    fn submit_request(
        &mut self,
        req: Request,
        coord: &Coordinator,
        v1: bool,
        opts: &ServerOptions,
    ) {
        let id = req.id;
        let cap = opts.max_inflight_per_conn.max(1);
        if self.waiting >= cap {
            let e = LeapError::BudgetExceeded { needed: self.waiting + 1, cap };
            coord.telemetry().record_shed(&req.op.label());
            self.push_bytes(encode_error(id, v1, &e));
            return;
        }
        match coord.try_submit(req) {
            Ok(rx) => {
                self.tickets.push_back(Ticket::Waiting { id, rx, v1 });
                self.waiting += 1;
            }
            Err(e) => self.push_bytes(encode_error(id, v1, &e)),
        }
    }

    /// Move resolved tickets into the write buffer, strictly in FIFO
    /// order: stop at the first still-waiting ticket so a later reply
    /// never overtakes an earlier one.
    fn drain_tickets(&mut self) {
        while let Some(front) = self.tickets.front_mut() {
            let bytes = match front {
                Ticket::Ready(_) => {
                    let Some(Ticket::Ready(b)) = self.tickets.pop_front() else {
                        unreachable!()
                    };
                    b
                }
                Ticket::Waiting { id, rx, v1 } => match rx.try_recv() {
                    Err(TryRecvError::Empty) => return,
                    Ok(resp) => {
                        let b = encode_response(*id, *v1, resp);
                        self.waiting -= 1;
                        self.tickets.pop_front();
                        b
                    }
                    Err(TryRecvError::Disconnected) => {
                        // workers always send before dropping their end;
                        // this arm only fires on coordinator shutdown
                        let e = LeapError::Io("coordinator dropped the request".into());
                        let b = encode_error(*id, *v1, &e);
                        self.waiting -= 1;
                        self.tickets.pop_front();
                        b
                    }
                },
            };
            self.wbuf.extend_from_slice(&bytes);
        }
    }

    /// Nonblocking write burst: hand as much of `wbuf` to the kernel as
    /// it will take now; POLLOUT readiness resumes the rest.
    fn flush(&mut self) {
        if self.dead {
            return;
        }
        while self.woff < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.woff..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => self.woff += n,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(ref e) if is_timeout(e) => return,
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.wbuf.clear();
        self.woff = 0;
    }

    fn push_frame(&mut self, f: &Frame) {
        let bytes = match wire::encode_frame(f) {
            Ok(b) => b,
            // an unframeable reply (tensor over the wire cap) never
            // started writing, so a typed error in its place keeps the
            // stream in sync
            Err(e) => wire::encode_frame(&Frame::error(f.id, &e))
                .expect("error frames always encode"),
        };
        self.push_bytes(bytes);
    }

    fn push_line(&mut self, doc: &Json) {
        let mut s = doc.to_string();
        s.push('\n');
        self.push_bytes(s.into_bytes());
    }

    fn push_bytes(&mut self, bytes: Vec<u8>) {
        self.tickets.push_back(Ticket::Ready(bytes));
    }
}

/// Encode a coordinator response in the connection's protocol.
fn encode_response(id: u64, v1: bool, resp: Response) -> Vec<u8> {
    if v1 {
        let mut s = response_to_json(&resp).to_string();
        s.push('\n');
        s.into_bytes()
    } else {
        let reply = response_to_frame(resp);
        match wire::encode_frame(&reply) {
            Ok(b) => b,
            Err(e) => wire::encode_frame(&Frame::error(id, &e))
                .expect("error frames always encode"),
        }
    }
}

/// Encode a typed error reply in the connection's protocol.
fn encode_error(id: u64, v1: bool, e: &LeapError) -> Vec<u8> {
    if v1 {
        let mut s = error_json(e).to_string();
        s.push('\n');
        s.into_bytes()
    } else {
        wire::encode_frame(&Frame::error(id, e)).expect("error frames always encode")
    }
}

fn error_json(e: &LeapError) -> Json {
    Json::obj(vec![
        ("error", Json::Str(e.to_string())),
        ("code", Json::Num(e.code() as f64)),
    ])
}

/// The v1 `__stats` reply: telemetry (including per-op shed counts and
/// p99 latency), queue depth, budget, sessions, and the shared
/// projector pool — the projector worker pool is process-wide and thus
/// shared by every connection and request, so its size and dispatch
/// count sit next to the queue depth for saturation diagnosis.
fn stats_json(
    doc: &Json,
    coord: &Coordinator,
    registry: &SessionRegistry,
    cluster: Option<&crate::cluster::ShardServer>,
) -> Json {
    let (pool_workers, pool_regions) = crate::util::pool::pool_stats();
    // the backend a sessionless scan would get, plus the tier actually
    // serving each open session — operators correlating throughput need
    // to know which kernel tier produced it
    let session_backends = Json::Obj(
        registry
            .session_backends()
            .into_iter()
            .map(|(id, b)| (id.to_string(), Json::Str(b.to_string())))
            .collect(),
    );
    // same shape for storage tiers: the tier a sessionless scan would
    // get, the tier pinned by each open session, and the bytes of
    // out-of-core volume tiles currently faulted in process-wide (the
    // [`crate::vol`] residency gauge)
    let session_storages = Json::Obj(
        registry
            .session_storages()
            .into_iter()
            .map(|(id, s)| (id.to_string(), Json::Str(s.to_string())))
            .collect(),
    );
    Json::obj(vec![
        ("id", Json::Num(doc.get_f64("id").unwrap_or(0.0))),
        ("stats", coord.telemetry().to_json()),
        ("queue_depth", Json::Num(coord.queue_depth() as f64)),
        ("budget_in_flight", Json::Num(coord.budget().in_flight() as f64)),
        ("open_sessions", Json::Num(registry.len() as f64)),
        ("pool_workers", Json::Num(pool_workers as f64)),
        ("pool_regions", Json::Num(pool_regions as f64)),
        ("default_backend", Json::Str(crate::backend::default_kind().name().to_string())),
        ("session_backends", session_backends),
        ("default_storage", Json::Str(crate::precision::default_tier().name().to_string())),
        ("session_storages", session_storages),
        ("resident_tile_bytes", Json::Num(crate::vol::resident_tile_bytes() as f64)),
        // the shard plane, when one is attached: connected worker count
        // plus the shard channel's own telemetry (shard_fp/shard_bp
        // rows with per-shard dispatch counts, retries and latency)
        (
            "cluster_workers",
            Json::Num(cluster.map(|c| c.workers()).unwrap_or(0) as f64),
        ),
        (
            "cluster",
            cluster.map(|c| c.telemetry().to_json()).unwrap_or(Json::Null),
        ),
    ])
}

// ---------------------------------------------------------------------------
// clients
// ---------------------------------------------------------------------------

/// Minimal blocking **protocol-v1** client (line-delimited JSON). Kept
/// for compatibility with existing tooling; new clients should use
/// [`BinaryClient`] — v1 ships every f32 as decimal text.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client, LeapError> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Send one op and wait for its reply.
    pub fn call(&mut self, op: &str, inputs: &[&[f32]]) -> Result<Json, LeapError> {
        let id = self.next_id;
        self.next_id += 1;
        let doc = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("op", Json::Str(op.to_string())),
            (
                "inputs",
                Json::Arr(
                    inputs
                        .iter()
                        .map(|b| Json::Arr(b.iter().map(|&x| Json::Num(x as f64)).collect()))
                        .collect(),
                ),
            ),
        ]);
        writeln!(self.writer, "{doc}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| LeapError::Protocol(format!("bad reply: {e}")))
    }

    /// Call a single-tensor op and parse the reply: the first output as
    /// a `Vec<f32>`, or the typed error reconstructed from the reply's
    /// `code`/`error` fields.
    pub fn call_tensor(&mut self, op: &str, input: &[f32]) -> Result<Vec<f32>, LeapError> {
        let reply = self.call(op, &[input])?;
        if let Some(msg) = reply.get_str("error") {
            let code = reply.get_f64("code").unwrap_or(0.0) as u16;
            return Err(LeapError::from_wire(code, msg.to_string()));
        }
        let outputs = reply
            .get("outputs")
            .and_then(|o| o.as_arr())
            .ok_or_else(|| LeapError::Protocol("reply missing outputs".into()))?;
        let first = outputs
            .first()
            .and_then(|o| o.as_arr())
            .ok_or_else(|| LeapError::Protocol("reply outputs empty".into()))?;
        first
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|f| f as f32)
                    .ok_or_else(|| LeapError::Protocol("non-numeric output element".into()))
            })
            .collect()
    }

    /// Fetch the telemetry snapshot.
    pub fn stats(&mut self) -> Result<Json, LeapError> {
        writeln!(self.writer, r#"{{"id": 0, "op": "__stats"}}"#)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line).map_err(|e| LeapError::Protocol(format!("bad reply: {e}")))
    }
}

/// Blocking **protocol-v2** client: binary frames, sessions, typed
/// errors. See `docs/PROTOCOL.md`.
pub struct BinaryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl BinaryClient {
    /// Connect and negotiate the protocol version (Hello exchange). A
    /// server speaking a different version is a typed
    /// [`LeapError::VersionMismatch`]/[`LeapError::Remote`] — never a
    /// silent misparse.
    pub fn connect(addr: &std::net::SocketAddr) -> Result<BinaryClient, LeapError> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        let mut client = BinaryClient { reader: BufReader::new(stream), writer, next_id: 1 };
        let hello = Frame::new(
            FrameKind::Hello,
            0,
            Json::obj(vec![("version", Json::Num(wire::VERSION as f64))]),
            Vec::new(),
        );
        let reply = client.roundtrip(&hello)?;
        match reply.kind {
            FrameKind::Hello => Ok(client),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} hello reply"))),
        }
    }

    fn roundtrip(&mut self, f: &Frame) -> Result<Frame, LeapError> {
        wire::write_frame(&mut self.writer, f)?;
        wire::read_frame(&mut self.reader)?
            .ok_or_else(|| LeapError::Io("server closed the connection".into()))
    }

    /// Register a scan config; returns the session id to project
    /// against. The config travels exactly once — every subsequent
    /// request is a 24-byte header plus the tensor. The session runs on
    /// the server's default compute backend; use
    /// [`BinaryClient::open_session_with`] to pick one (and learn which
    /// tier an unset knob resolved to).
    pub fn open_session(
        &mut self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
    ) -> Result<u64, LeapError> {
        self.open_session_with(cfg, model, threads, None).map(|(id, _)| id)
    }

    /// [`BinaryClient::open_session`] with an explicit compute-backend
    /// request (`"scalar"`/`"simd"`; the non-executing `"pjrt"` slot and
    /// unknown names are typed server-side errors). Returns the session
    /// id plus the backend name the server actually resolved — when
    /// `backend` is `None` that is the server process's default tier.
    pub fn open_session_with(
        &mut self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
        backend: Option<&str>,
    ) -> Result<(u64, String), LeapError> {
        self.open_session_tiered(cfg, model, threads, backend, None)
            .map(|(id, backend, _storage)| (id, backend))
    }

    /// [`BinaryClient::open_session_with`] plus an explicit storage-tier
    /// request (`"f32"`/`"f16"`/`"bf16"`; unknown names are typed
    /// server-side errors). Returns the session id with the backend and
    /// storage names the server actually resolved — unset knobs report
    /// the server process's defaults, so clients always learn which
    /// kernel tier and accuracy class will serve them.
    pub fn open_session_tiered(
        &mut self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
        backend: Option<&str>,
        storage: Option<&str>,
    ) -> Result<(u64, String, String), LeapError> {
        let mut meta = vec![
            (
                "config",
                Json::obj(vec![
                    ("geometry", geometry_to_json(&cfg.geometry)),
                    ("volume", volume_to_json(&cfg.volume)),
                ]),
            ),
            ("model", Json::Str(model.name().to_string())),
        ];
        if let Some(t) = threads {
            meta.push(("threads", Json::Num(t as f64)));
        }
        if let Some(b) = backend {
            meta.push(("backend", Json::Str(b.to_string())));
        }
        if let Some(s) = storage {
            meta.push(("storage", Json::Str(s.to_string())));
        }
        let reply =
            self.roundtrip(&Frame::new(FrameKind::OpenSession, 0, Json::obj(meta), Vec::new()))?;
        match reply.kind {
            FrameKind::OpenSession => {
                let backend = reply.meta.get_str("backend").unwrap_or("unknown").to_string();
                let storage = reply.meta.get_str("storage").unwrap_or("unknown").to_string();
                Ok((reply.id, backend, storage))
            }
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} open-session reply"))),
        }
    }

    /// Release a session.
    pub fn close_session(&mut self, session: u64) -> Result<(), LeapError> {
        let reply =
            self.roundtrip(&Frame::new(FrameKind::CloseSession, session, Json::Null, Vec::new()))?;
        match reply.kind {
            FrameKind::CloseSession => Ok(()),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} close-session reply"))),
        }
    }

    /// Execute one typed op; returns the full Response frame (payload =
    /// output tensor; meta carries latency/exec/batch observability).
    /// The input tensor is serialized straight from the borrowed slice
    /// ([`wire::write_frame_parts`]) — no owned copy on the client side.
    pub fn call(&mut self, op: &Op, input: &[f32]) -> Result<Frame, LeapError> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame_parts(
            &mut self.writer,
            FrameKind::Request,
            id,
            &super::request::request_meta(op),
            input,
        )?;
        let reply = wire::read_frame(&mut self.reader)?
            .ok_or_else(|| LeapError::Io("server closed the connection".into()))?;
        match reply.kind {
            FrameKind::Response if reply.id == id => Ok(reply),
            FrameKind::Response => Err(LeapError::Protocol(format!(
                "response id {} does not match request id {id}",
                reply.id
            ))),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} reply"))),
        }
    }

    /// Forward projection on an open session.
    pub fn forward(&mut self, session: u64, vol: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionFp(session), vol)?.payload)
    }

    /// Matched backprojection on an open session.
    pub fn back(&mut self, session: u64, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionBp(session), sino)?.payload)
    }

    /// FBP/FDK reconstruction on an open session.
    pub fn fbp(&mut self, session: u64, sino: &[f32]) -> Result<Vec<f32>, LeapError> {
        Ok(self.call(&Op::SessionFbp(session), sino)?.payload)
    }

    /// Register a tape pipeline (its structure, not its parameter
    /// values) on an open session; returns the pipeline id for
    /// [`BinaryClient::pipeline_grad`]. The server rebinds the spec's
    /// `"scan"` operator to the session's pinned plan.
    pub fn register_pipeline(
        &mut self,
        session: u64,
        pipe: &tape::Pipeline,
    ) -> Result<u64, LeapError> {
        let meta = Json::obj(vec![("pipeline", tape::pipeline_to_json(pipe))]);
        let reply =
            self.roundtrip(&Frame::new(FrameKind::RegisterPipeline, session, meta, Vec::new()))?;
        match reply.kind {
            FrameKind::RegisterPipeline => Ok(reply.id),
            FrameKind::Error => Err(reply.to_error()),
            k => Err(LeapError::Protocol(format!("unexpected {k:?} register-pipeline reply"))),
        }
    }

    /// Evaluate a registered pipeline's loss + parameter gradients on
    /// the server: params + inputs are packed into one tensor
    /// ([`tape::Pipeline::pack`]), the reply unpacks to the exact f64
    /// loss and per-parameter gradients — bit-identical to calling
    /// [`tape::Pipeline::loss_and_grads_with`] locally on the same plan.
    /// `pipe` is the local copy of the registered pipeline (it defines
    /// the packing layout).
    pub fn pipeline_grad(
        &mut self,
        session: u64,
        pipeline: u64,
        pipe: &tape::Pipeline,
        params: &[&[f32]],
        inputs: &[&[f32]],
    ) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        let packed = pipe.pack(params, inputs)?;
        let reply = self.call(&Op::SessionPipelineGrad { session, pipeline }, &packed)?;
        pipe.unpack_grad_reply(&reply.payload)
    }
}

#[cfg(test)]
mod tests {
    use super::super::request::request_meta;
    use super::super::test_support::MockExecutor;
    use super::super::{BatchPolicy, Coordinator, Executor, NativeExecutor, Router, SessionExecutor};
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::projector::Projector;

    fn start_mock() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            2,
        ));
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    fn scan_config() -> ScanConfig {
        ScanConfig {
            geometry: Geometry::Parallel(ParallelBeam::standard_2d(10, 24, 1.0)),
            volume: VolumeGeometry::slice2d(16, 16, 1.0),
        }
    }

    fn start_native() -> (Server, Arc<Coordinator>) {
        let cfg = scan_config();
        let native = NativeExecutor::new(
            Projector::new(cfg.geometry.clone(), cfg.volume.clone(), Model::SF).with_threads(2),
        );
        let router: Arc<dyn Executor> = Arc::new(Router::new(vec![
            Arc::new(native),
            Arc::new(SessionExecutor::new()),
        ]));
        let coord = Arc::new(Coordinator::new(router, BatchPolicy::default(), 1 << 28, 2));
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    #[test]
    fn tcp_roundtrip() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("echo", &[&[1.0, 3.0]]).unwrap();
        let outs = reply.get("outputs").unwrap().as_arr().unwrap();
        let first = outs[0].as_arr().unwrap();
        assert_eq!(first[0].as_f64(), Some(2.0));
        assert_eq!(first[1].as_f64(), Some(6.0));
        // the batching observability field rides on every reply
        assert!(reply.get_f64("batch_size").unwrap_or(0.0) >= 1.0);
    }

    #[test]
    fn error_propagates_with_typed_code() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        let reply = client.call("fail", &[&[1.0]]).unwrap();
        assert!(reply.get_str("error").unwrap().contains("mock failure"));
        assert_eq!(reply.get_f64("code"), Some(crate::api::codes::BACKEND as f64));
    }

    #[test]
    fn stats_endpoint() {
        let (server, _coord) = start_mock();
        let mut client = Client::connect(&server.addr).unwrap();
        client.call("echo", &[&[1.0]]).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(
            stats.get("stats").unwrap().get("echo").unwrap().get_f64("count"),
            Some(1.0)
        );
        // the shared projector pool is reported alongside request stats
        assert!(stats.get_f64("pool_workers").is_some());
        assert!(stats.get_f64("pool_regions").is_some());
        assert!(stats.get_f64("open_sessions").is_some());
    }

    #[test]
    fn multiple_clients() {
        let (server, _coord) = start_mock();
        let addr = server.addr;
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for i in 0..10 {
                    let r = client.call("echo", &[&[t as f32 + i as f32]]).unwrap();
                    assert!(r.get("outputs").is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn malformed_line_gets_error_reply() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writeln!(writer, "this is not json").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
    }

    #[test]
    fn v2_session_roundtrip_matches_in_process_bits() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let scan = crate::api::ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let mut vol = vec![0.0f32; scan.volume_len()];
        crate::util::rng::Rng::new(9).fill_uniform(&mut vol, 0.0, 1.0);
        let served = client.forward(session, &vol).unwrap();
        let local = scan.forward(&vol).unwrap();
        assert_eq!(served, local, "v2 session forward must be bit-identical");
        let back_served = client.back(session, &served).unwrap();
        assert_eq!(back_served, scan.back(&served).unwrap());
        client.close_session(session).unwrap();
        // using the closed session is a typed error
        let e = client.forward(session, &vol).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
    }

    #[test]
    fn v2_shape_and_geometry_errors_are_typed_on_the_wire() {
        let (server, _coord) = start_native();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&scan_config(), Model::SF, Some(2)).unwrap();
        // wrong tensor length → SHAPE_MISMATCH code, connection survives
        let e = client.forward(session, &[1.0, 2.0, 3.0]).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::SHAPE_MISMATCH, "{e:?}");
        // degenerate config → INVALID_GEOMETRY
        let mut bad = scan_config();
        bad.volume.nx = 0;
        let e = client.open_session(&bad, Model::SF, None).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::INVALID_GEOMETRY, "{e:?}");
        // the connection still works after both errors
        let vol = vec![0.1f32; 256];
        assert!(client.forward(session, &vol).is_ok());
    }

    #[test]
    fn v1_and_v2_clients_share_one_port_and_agree() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let vol = vec![0.02f32; 256];
        // v2 session path
        let mut v2 = BinaryClient::connect(&server.addr).unwrap();
        let session = v2.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let from_v2 = v2.forward(session, &vol).unwrap();
        // v1 JSON path against the statically-configured native backend
        let mut v1 = Client::connect(&server.addr).unwrap();
        let from_v1 = v1.call_tensor("native_fp", &vol).unwrap();
        assert_eq!(from_v1, from_v2, "both protocols must return identical bits");
    }

    #[test]
    fn v2_version_mismatch_is_rejected() {
        let (server, _coord) = start_native();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // a well-formed frame with a bad version byte
        let mut bytes =
            wire::encode_frame(&Frame::new(FrameKind::Hello, 0, Json::Null, vec![])).unwrap();
        bytes[4] = 9;
        writer.write_all(&bytes).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("error frame");
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(
            reply.to_error().code(),
            crate::api::codes::VERSION_MISMATCH,
            "{:?}",
            reply.to_error()
        );
        // and the server closes the connection afterwards
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn v2_malformed_frame_is_rejected_with_protocol_code() {
        let (server, _coord) = start_native();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // correct magic so the connection sniffs as v2, then garbage
        let mut bytes =
            wire::encode_frame(&Frame::new(FrameKind::Hello, 0, Json::Null, vec![])).unwrap();
        bytes[5] = 200; // unknown frame kind
        writer.write_all(&bytes).unwrap();
        writer.flush().unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("error frame");
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::PROTOCOL);
    }

    #[test]
    fn v2_pipeline_grad_over_tcp_is_bit_identical_to_the_in_process_tape() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let scan = crate::api::ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let local: std::sync::Arc<dyn crate::ops::LinearOp> =
            std::sync::Arc::new(crate::ops::PlanOp::from_plan(scan.plan().clone()));
        let pipe = tape::unrolled_gd(
            local,
            &tape::UnrollCfg { iterations: 2, step_init: 0.01, nonneg: true },
        )
        .unwrap();

        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
        let pid = client.register_pipeline(session, &pipe).unwrap();

        let mut rng = crate::util::rng::Rng::new(29);
        let params: Vec<Vec<f32>> = pipe
            .params()
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.shape.numel()];
                rng.fill_uniform(&mut v, 0.005, 0.02);
                v
            })
            .collect();
        let inputs: Vec<Vec<f32>> = pipe
            .input_shapes()
            .iter()
            .map(|s| {
                let mut v = vec![0.0f32; s.numel()];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let (served_loss, served_grads) =
            client.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap();
        let (local_loss, local_grads) = pipe.loss_and_grads_with(&pr, &ir).unwrap();
        assert_eq!(
            served_loss.to_bits(),
            local_loss.to_bits(),
            "served loss must be bit-identical to the in-process tape"
        );
        assert_eq!(served_grads, local_grads, "served gradients must be bit-identical");

        // a second connection cannot register on (or grad against) a
        // session it did not open — identical to a nonexistent session
        let mut intruder = BinaryClient::connect(&server.addr).unwrap();
        let e = intruder.register_pipeline(session, &pipe).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
        let e = intruder.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");

        client.close_session(session).unwrap();
        // the pipeline died with its session
        let e = client.pipeline_grad(session, pid, &pipe, &pr, &ir).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNKNOWN_SESSION, "{e:?}");
    }

    #[test]
    fn v2_sessions_negotiate_and_report_their_backend() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let (scalar_id, scalar_name) =
            client.open_session_with(&cfg, Model::SF, Some(2), Some("scalar")).unwrap();
        assert_eq!(scalar_name, "scalar");
        let (simd_id, simd_name) =
            client.open_session_with(&cfg, Model::SF, Some(2), Some("simd")).unwrap();
        assert_eq!(simd_name, "simd");
        // SF-parallel staging is in the bit-identical equivalence class
        // (docs/BACKENDS.md), so the two tiers agree exactly on the wire
        let mut vol = vec![0.0f32; 256];
        crate::util::rng::Rng::new(31).fill_uniform(&mut vol, 0.0, 1.0);
        assert_eq!(
            client.forward(scalar_id, &vol).unwrap(),
            client.forward(simd_id, &vol).unwrap(),
        );
        // an unset knob resolves to the process default — and the reply
        // says which tier that was
        let (_dflt_id, dflt_name) =
            client.open_session_with(&cfg, Model::SF, None, None).unwrap();
        assert!(dflt_name == "scalar" || dflt_name == "simd", "{dflt_name}");
        // v1 telemetry exposes the default and the per-session tiers
        let mut v1 = Client::connect(&server.addr).unwrap();
        let stats = v1.stats().unwrap();
        assert_eq!(stats.get_str("default_backend"), Some(dflt_name.as_str()));
        let per_session = stats.get("session_backends").expect("per-session backend map");
        assert_eq!(per_session.get_str(&scalar_id.to_string()), Some("scalar"));
        assert_eq!(per_session.get_str(&simd_id.to_string()), Some("simd"));
        // the non-executing pjrt slot and unknown names are typed
        // errors on the wire, never a silent fallback
        let e = client.open_session_with(&cfg, Model::SF, None, Some("pjrt")).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::UNSUPPORTED, "{e:?}");
        let e = client.open_session_with(&cfg, Model::SF, None, Some("warp")).unwrap_err();
        assert_eq!(e.code(), crate::api::codes::INVALID_ARGUMENT, "{e:?}");
    }

    #[test]
    fn v2_sessions_negotiate_and_report_their_storage_tier() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let mut client = BinaryClient::connect(&server.addr).unwrap();
        let (f32_id, _, f32_tier) =
            client.open_session_tiered(&cfg, Model::SF, Some(2), None, Some("f32")).unwrap();
        assert_eq!(f32_tier, "f32");
        let (f16_id, _, f16_tier) =
            client.open_session_tiered(&cfg, Model::SF, Some(2), None, Some("f16")).unwrap();
        assert_eq!(f16_tier, "f16");
        // parallel-beam SF forward stores no coefficient table, so the
        // two tiers agree bit-for-bit on the wire (docs/MEMORY.md
        // accuracy classes)
        let mut vol = vec![0.0f32; 256];
        crate::util::rng::Rng::new(33).fill_uniform(&mut vol, 0.0, 1.0);
        assert_eq!(
            client.forward(f32_id, &vol).unwrap(),
            client.forward(f16_id, &vol).unwrap(),
        );
        // an unset knob resolves to the process default — and the reply
        // says which tier that was
        let (_dflt_id, _, dflt_tier) =
            client.open_session_tiered(&cfg, Model::SF, None, None, None).unwrap();
        assert!(["f32", "f16", "bf16"].contains(&dflt_tier.as_str()), "{dflt_tier}");
        // v1 telemetry exposes the default, the per-session tiers and
        // the out-of-core residency gauge
        let mut v1 = Client::connect(&server.addr).unwrap();
        let stats = v1.stats().unwrap();
        assert_eq!(stats.get_str("default_storage"), Some(dflt_tier.as_str()));
        let per_session = stats.get("session_storages").expect("per-session storage map");
        assert_eq!(per_session.get_str(&f32_id.to_string()), Some("f32"));
        assert_eq!(per_session.get_str(&f16_id.to_string()), Some("f16"));
        assert!(stats.get_f64("resident_tile_bytes").is_some());
        // unknown tier names are typed errors on the wire, never a
        // silent fallback
        let e = client
            .open_session_tiered(&cfg, Model::SF, None, None, Some("f8"))
            .unwrap_err();
        assert_eq!(e.code(), crate::api::codes::INVALID_ARGUMENT, "{e:?}");
    }

    // ── protocol-sniffing robustness (first-exchange hardening) ────────

    /// Read the single reply frame a hardening test expects. A slow
    /// machine can instead trip the client's guard deadline, which
    /// surfaces platform-dependently (`WouldBlock` on unix, `TimedOut`
    /// on windows) — fail with one uniform diagnostic for both rather
    /// than a platform-specific unwrap panic.
    fn expect_reply_frame(reader: &mut BufReader<TcpStream>) -> Frame {
        match wire::read_frame(reader) {
            Ok(Some(f)) => f,
            Ok(None) => panic!("server closed before sending the expected reply frame"),
            Err(e) => panic!("no reply before the client guard deadline: {e}"),
        }
    }

    #[test]
    fn timeout_classification_accepts_both_platform_kinds() {
        use std::io::{Error, ErrorKind};
        // unix surfaces an expired read deadline as WouldBlock, windows
        // as TimedOut; both must classify as the deadline firing
        assert!(is_timeout(&Error::from(ErrorKind::WouldBlock)));
        assert!(is_timeout(&Error::from(ErrorKind::TimedOut)));
        // and real I/O failures must not
        assert!(!is_timeout(&Error::from(ErrorKind::BrokenPipe)));
        assert!(!is_timeout(&Error::from(ErrorKind::UnexpectedEof)));
    }

    #[test]
    fn zero_byte_connection_closes_cleanly_and_server_survives() {
        let (server, _coord) = start_mock();
        {
            let stream = TcpStream::connect(server.addr).unwrap();
            stream.shutdown(std::net::Shutdown::Write).unwrap();
            // server should see EOF and close without writing anything
            let mut reader = BufReader::new(stream);
            let mut buf = String::new();
            let n = reader.read_line(&mut buf).unwrap();
            assert_eq!(n, 0, "no reply owed on a 0-byte connection, got {buf:?}");
        }
        // the accept loop is unharmed: a real client still works
        let mut client = Client::connect(&server.addr).unwrap();
        assert!(client.call("echo", &[&[1.0]]).unwrap().get("outputs").is_some());
    }

    #[test]
    fn one_byte_then_close_is_a_typed_protocol_error() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writer.write_all(b"L").unwrap(); // sniffs as v2 …
        writer.flush().unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap(); // … then EOF mid-header
        let mut reader = BufReader::new(stream);
        let reply = expect_reply_frame(&mut reader);
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::PROTOCOL, "{:?}", reply.to_error());
        // and the connection closes cleanly afterwards
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn one_byte_then_stall_times_out_with_a_typed_error_never_hangs() {
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            1,
        ));
        let server = Server::start_with_handshake_timeout(
            "127.0.0.1:0",
            coord,
            Duration::from_millis(100),
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writer.write_all(b"L").unwrap(); // sniffs as v2 …
        writer.flush().unwrap();
        // … then stall (write half stays open). The handshake deadline
        // must fire: a typed error frame, then the connection closes.
        let mut reader = BufReader::new(stream);
        let reply = expect_reply_frame(&mut reader);
        assert_eq!(reply.kind, FrameKind::Error);
        assert_eq!(reply.to_error().code(), crate::api::codes::IO, "{:?}", reply.to_error());
        assert!(matches!(wire::read_frame(&mut reader), Ok(None) | Err(_)));
    }

    #[test]
    fn unrecognized_first_byte_is_rejected_with_a_typed_error_line() {
        let (server, _coord) = start_mock();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        // neither '{' (v1) nor 'L' (v2): a protocol we don't speak
        writer.write_all(b"GET / HTTP/1.1\r\n").unwrap();
        writer.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let reply = parse(&line).expect("one JSON error line");
        assert!(reply.get_str("error").unwrap().contains("unrecognized protocol"), "{line}");
        assert_eq!(reply.get_f64("code"), Some(crate::api::codes::PROTOCOL as f64));
        // then the server closes instead of re-rejecting every line
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "connection must close: {rest:?}");
    }

    #[test]
    fn sessions_close_when_their_connection_drops() {
        let (server, _coord) = start_native();
        let session = {
            let mut client = BinaryClient::connect(&server.addr).unwrap();
            let id = client.open_session(&scan_config(), Model::SF, Some(2)).unwrap();
            // open sessions are visible process-wide (exact counts would
            // race with concurrently-running tests on the global registry)
            assert!(SessionRegistry::global().executor(id).is_some());
            id
        }; // client dropped: connection closes
        // give the server thread a moment to observe the disconnect
        for _ in 0..100 {
            if SessionRegistry::global().executor(session).is_none() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(
            SessionRegistry::global().executor(session).is_none(),
            "disconnect must release the session"
        );
    }

    // ── multiplexing, admission control, load shedding ─────────────────

    #[test]
    fn overload_sheds_with_typed_errors_and_the_stream_stays_in_sync() {
        // 1 worker and a pending queue of 1: a burst of slow requests
        // must overflow the queue and shed
        let coord = Arc::new(
            Coordinator::new(Arc::new(MockExecutor), BatchPolicy::default(), 1 << 20, 1)
                .with_max_pending(1),
        );
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        // v2 handshake
        let hello = Frame::new(
            FrameKind::Hello,
            0,
            Json::obj(vec![("version", Json::Num(wire::VERSION as f64))]),
            Vec::new(),
        );
        wire::write_frame(&mut writer, &hello).unwrap();
        let reply = wire::read_frame(&mut reader).unwrap().expect("hello reply");
        assert_eq!(reply.kind, FrameKind::Hello);

        // pipeline a burst of slow requests without reading a single
        // reply — far faster than one worker can drain them
        const BURST: u64 = 40;
        let meta = request_meta(&Op::Artifact("slow".into()));
        for id in 1..=BURST {
            wire::write_frame_parts(&mut writer, FrameKind::Request, id, &meta, &[id as f32])
                .unwrap();
        }
        writer.flush().unwrap();

        // every request gets exactly one reply, in request order:
        // Response for the admitted ones, a typed BudgetExceeded error
        // for the shed ones — never a skipped or reordered id
        let (mut served, mut shed) = (0u64, 0u64);
        for id in 1..=BURST {
            let f = wire::read_frame(&mut reader).unwrap().expect("one reply per request");
            assert_eq!(f.id, id, "replies must arrive in request order");
            match f.kind {
                FrameKind::Response => {
                    assert_eq!(f.payload, vec![id as f32]);
                    served += 1;
                }
                FrameKind::Error => {
                    let e = f.to_error();
                    assert_eq!(e.code(), crate::api::codes::BUDGET_EXCEEDED, "{e:?}");
                    shed += 1;
                }
                k => panic!("unexpected {k:?} reply"),
            }
        }
        assert!(served > 0, "some of the burst must be admitted");
        assert!(shed > 0, "a 40-deep burst into a 1-deep queue must shed");

        // the connection recovered: a fresh request after the burst is
        // served normally
        let meta = request_meta(&Op::Artifact("echo".into()));
        wire::write_frame_parts(&mut writer, FrameKind::Request, 99, &meta, &[21.0]).unwrap();
        writer.flush().unwrap();
        let f = wire::read_frame(&mut reader).unwrap().expect("post-burst reply");
        assert_eq!(f.kind, FrameKind::Response);
        assert_eq!(f.id, 99);
        assert_eq!(f.payload, vec![42.0]);
        // telemetry counted the sheds
        assert_eq!(coord.telemetry().snapshot()["slow"].shed, shed);
    }

    #[test]
    fn per_connection_inflight_cap_sheds_before_the_queue() {
        // roomy queue, tiny per-connection cap: the connection gate
        // must shed on its own
        let coord = Arc::new(Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy::default(),
            1 << 20,
            1,
        ));
        let server = Server::start_with(
            "127.0.0.1:0",
            coord,
            ServerOptions { max_inflight_per_conn: 4, ..ServerOptions::default() },
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        // one TCP segment of pipelined v1 lines, so they reach the
        // server together and pile past the in-flight cap
        const BURST: usize = 20;
        let mut batch = String::new();
        for id in 1..=BURST {
            batch.push_str(&format!(r#"{{"id": {id}, "op": "slow", "inputs": [[1.0]]}}"#));
            batch.push('\n');
        }
        writer.write_all(batch.as_bytes()).unwrap();
        writer.flush().unwrap();

        let (mut served, mut shed) = (0usize, 0usize);
        for id in 1..=BURST {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let reply = parse(&line).expect("json reply line");
            assert_eq!(reply.get_f64("id"), Some(id as f64), "in order: {line}");
            if reply.get("outputs").is_some() {
                served += 1;
            } else {
                assert_eq!(
                    reply.get_f64("code"),
                    Some(crate::api::codes::BUDGET_EXCEEDED as f64),
                    "{line}"
                );
                shed += 1;
            }
        }
        assert_eq!(served + shed, BURST);
        assert!(served >= 4, "at least one full in-flight window is admitted");
        assert!(shed > 0, "a 20-deep burst must overflow a 4-deep in-flight cap");
    }

    #[test]
    fn many_concurrent_v2_sessions_multiplex_on_one_server_bit_identically() {
        let (server, _coord) = start_native();
        let cfg = scan_config();
        let scan = crate::api::ScanBuilder::from_config(&cfg)
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let mut vol = vec![0.0f32; scan.volume_len()];
        crate::util::rng::Rng::new(77).fill_uniform(&mut vol, 0.0, 1.0);
        let reference = scan.forward(&vol).unwrap();

        let addr = server.addr;
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cfg = cfg.clone();
            let vol = vol.clone();
            let reference = reference.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = BinaryClient::connect(&addr).unwrap();
                let session = client.open_session(&cfg, Model::SF, Some(2)).unwrap();
                for _ in 0..3 {
                    let served = client.forward(session, &vol).unwrap();
                    assert_eq!(served, reference, "every session, every repeat: same bits");
                }
                client.close_session(session).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn injected_registries_isolate_concurrent_servers() {
        fn start_isolated() -> (Server, Arc<SessionRegistry>) {
            let registry = Arc::new(SessionRegistry::new());
            let cfg = scan_config();
            let native = NativeExecutor::new(
                Projector::new(cfg.geometry.clone(), cfg.volume.clone(), Model::SF)
                    .with_threads(2),
            );
            let router: Arc<dyn Executor> = Arc::new(Router::new(vec![
                Arc::new(native),
                Arc::new(SessionExecutor::with_registry(registry.clone())),
            ]));
            let coord = Arc::new(Coordinator::new(router, BatchPolicy::default(), 1 << 28, 2));
            let server = Server::start_with(
                "127.0.0.1:0",
                coord,
                ServerOptions { registry: Some(registry.clone()), ..ServerOptions::default() },
            )
            .unwrap();
            (server, registry)
        }
        let (s1, r1) = start_isolated();
        let (s2, r2) = start_isolated();

        let mut c1 = BinaryClient::connect(&s1.addr).unwrap();
        let session = c1.open_session(&scan_config(), Model::SF, Some(2)).unwrap();
        // the session lives in server 1's registry and nowhere else —
        // not in server 2's, not in the process-global one
        assert!(r1.executor(session).is_some());
        assert!(r2.executor(session).is_none(), "registries must not cross-contaminate");
        assert_eq!(r1.len(), 1);
        assert_eq!(r2.len(), 0);
        // and it serves requests end-to-end through its own executor
        let vol = vec![0.05f32; 256];
        assert!(c1.forward(session, &vol).is_ok());

        drop(c1); // connection drops: the session must release from r1
        for _ in 0..100 {
            if r1.is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(r1.is_empty(), "disconnect must release the session from its own registry");
        drop(s2);
    }
}
