//! Coordinator telemetry: per-op counters and latency aggregates, dumped
//! as JSON by the `serve` CLI and read by the coordinator bench.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub count: u64,
    pub errors: u64,
    pub total_latency_us: u64,
    pub total_exec_us: u64,
    pub max_latency_us: u64,
    pub batches: u64,
    pub batched_requests: u64,
}

impl OpStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_latency_us as f64 / self.count as f64
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }
}

/// Thread-safe telemetry sink.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<BTreeMap<String, OpStats>>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn record(&self, op: &str, latency_us: u64, exec_us: u64, ok: bool) {
        let mut map = self.inner.lock().unwrap();
        let s = map.entry(op.to_string()).or_default();
        s.count += 1;
        if !ok {
            s.errors += 1;
        }
        s.total_latency_us += latency_us;
        s.total_exec_us += exec_us;
        s.max_latency_us = s.max_latency_us.max(latency_us);
    }

    pub fn record_batch(&self, op: &str, size: usize) {
        let mut map = self.inner.lock().unwrap();
        let s = map.entry(op.to_string()).or_default();
        s.batches += 1;
        s.batched_requests += size as u64;
    }

    pub fn snapshot(&self) -> BTreeMap<String, OpStats> {
        self.inner.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> Json {
        let snap = self.snapshot();
        Json::Obj(
            snap.into_iter()
                .map(|(op, s)| {
                    (
                        op,
                        Json::obj(vec![
                            ("count", Json::Num(s.count as f64)),
                            ("errors", Json::Num(s.errors as f64)),
                            ("mean_latency_us", Json::Num(s.mean_latency_us())),
                            ("max_latency_us", Json::Num(s.max_latency_us as f64)),
                            ("mean_exec_us", Json::Num(if s.count > 0 { s.total_exec_us as f64 / s.count as f64 } else { 0.0 })),
                            ("mean_batch", Json::Num(s.mean_batch())),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let t = Telemetry::new();
        t.record("fp", 100, 80, true);
        t.record("fp", 300, 250, true);
        t.record("fp", 50, 40, false);
        t.record_batch("fp", 3);
        let snap = t.snapshot();
        let s = &snap["fp"];
        assert_eq!(s.count, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.max_latency_us, 300);
        assert!((s.mean_latency_us() - 150.0).abs() < 1e-9);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn json_snapshot_parses() {
        let t = Telemetry::new();
        t.record("bp", 10, 5, true);
        let j = t.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(back.get("bp").unwrap().get_f64("count"), Some(1.0));
    }

    #[test]
    fn concurrent_records() {
        let t = std::sync::Arc::new(Telemetry::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    t.record("x", 1, 1, true);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.snapshot()["x"].count, 400);
    }
}
