//! Coordinator telemetry: per-op counters and latency aggregates, dumped
//! as JSON by the `serve` CLI and read by the coordinator bench.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

/// Latency histogram resolution: power-of-two buckets, bucket `b`
/// holding samples in `[2^(b-1), 2^b)` µs (bucket 0 = sub-µs). 32
/// buckets span past half an hour — far beyond any served request.
pub const HIST_BUCKETS: usize = 32;

#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub count: u64,
    pub errors: u64,
    /// Requests refused at admission (bounded pending queue / in-flight
    /// caps) with a typed `BudgetExceeded` — they never reach a worker,
    /// so they are *not* in `count` or the latency aggregates.
    pub shed: u64,
    /// Re-dispatches of cluster shards (deadline missed, worker lost) —
    /// each re-scatter counts once; the shard's eventual completion or
    /// permanent failure lands in `count`/`errors` as usual.
    pub retries: u64,
    pub total_latency_us: u64,
    pub total_exec_us: u64,
    pub max_latency_us: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Log-bucketed latency histogram (see [`HIST_BUCKETS`]); feeds the
    /// percentile estimates without storing per-request samples.
    pub lat_hist: [u64; HIST_BUCKETS],
}

impl OpStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_latency_us as f64 / self.count as f64
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    fn bucket(latency_us: u64) -> usize {
        ((u64::BITS - latency_us.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Latency at quantile `q` (e.g. `0.99`), as the upper edge of the
    /// power-of-two bucket the quantile falls in — an upper bound within
    /// 2× of the true sample, which is the resolution tail-latency
    /// dashboards need without per-request sample storage.
    pub fn percentile_latency_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &n) in self.lat_hist.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let edge = if b == 0 { 0 } else { (1u64 << b) - 1 };
                return edge.min(self.max_latency_us);
            }
        }
        self.max_latency_us
    }

    /// The p99 latency row served by `__stats` and the concurrency bench.
    pub fn p99_latency_us(&self) -> u64 {
        self.percentile_latency_us(0.99)
    }
}

/// Thread-safe telemetry sink.
#[derive(Default)]
pub struct Telemetry {
    inner: Mutex<BTreeMap<String, OpStats>>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    pub fn record(&self, op: &str, latency_us: u64, exec_us: u64, ok: bool) {
        let mut map = self.inner.lock().unwrap();
        let s = map.entry(op.to_string()).or_default();
        s.count += 1;
        if !ok {
            s.errors += 1;
        }
        s.total_latency_us += latency_us;
        s.total_exec_us += exec_us;
        s.max_latency_us = s.max_latency_us.max(latency_us);
        s.lat_hist[OpStats::bucket(latency_us)] += 1;
    }

    /// Count one admission refusal (request shed before any execution).
    pub fn record_shed(&self, op: &str) {
        let mut map = self.inner.lock().unwrap();
        map.entry(op.to_string()).or_default().shed += 1;
    }

    /// Count one cluster-shard re-dispatch (see [`OpStats::retries`]).
    pub fn record_retry(&self, op: &str) {
        let mut map = self.inner.lock().unwrap();
        map.entry(op.to_string()).or_default().retries += 1;
    }

    pub fn record_batch(&self, op: &str, size: usize) {
        let mut map = self.inner.lock().unwrap();
        let s = map.entry(op.to_string()).or_default();
        s.batches += 1;
        s.batched_requests += size as u64;
    }

    pub fn snapshot(&self) -> BTreeMap<String, OpStats> {
        self.inner.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> Json {
        let snap = self.snapshot();
        Json::Obj(
            snap.into_iter()
                .map(|(op, s)| {
                    (
                        op,
                        Json::obj(vec![
                            ("count", Json::Num(s.count as f64)),
                            ("errors", Json::Num(s.errors as f64)),
                            ("shed", Json::Num(s.shed as f64)),
                            ("retries", Json::Num(s.retries as f64)),
                            ("mean_latency_us", Json::Num(s.mean_latency_us())),
                            ("p99_latency_us", Json::Num(s.p99_latency_us() as f64)),
                            ("max_latency_us", Json::Num(s.max_latency_us as f64)),
                            ("mean_exec_us", Json::Num(if s.count > 0 { s.total_exec_us as f64 / s.count as f64 } else { 0.0 })),
                            ("mean_batch", Json::Num(s.mean_batch())),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let t = Telemetry::new();
        t.record("fp", 100, 80, true);
        t.record("fp", 300, 250, true);
        t.record("fp", 50, 40, false);
        t.record_batch("fp", 3);
        let snap = t.snapshot();
        let s = &snap["fp"];
        assert_eq!(s.count, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.max_latency_us, 300);
        assert!((s.mean_latency_us() - 150.0).abs() < 1e-9);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn json_snapshot_parses() {
        let t = Telemetry::new();
        t.record("bp", 10, 5, true);
        t.record_shed("bp");
        let j = t.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(back.get("bp").unwrap().get_f64("count"), Some(1.0));
        assert_eq!(back.get("bp").unwrap().get_f64("shed"), Some(1.0));
        assert!(back.get("bp").unwrap().get_f64("p99_latency_us").is_some());
    }

    #[test]
    fn shard_retries_count_without_touching_latency_aggregates() {
        let t = Telemetry::new();
        t.record_retry("shard_bp");
        t.record_retry("shard_bp");
        t.record("shard_bp", 10, 10, true);
        let s = &t.snapshot()["shard_bp"];
        assert_eq!(s.retries, 2);
        assert_eq!(s.count, 1);
        let j = t.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(back.get("shard_bp").unwrap().get_f64("retries"), Some(2.0));
    }

    #[test]
    fn percentiles_come_from_the_log_histogram() {
        let t = Telemetry::new();
        // 99 fast requests in [2^6, 2^7), one slow outlier in [2^13, 2^14)
        for _ in 0..99 {
            t.record("fp", 100, 80, true);
        }
        t.record("fp", 9000, 8000, true);
        let s = &t.snapshot()["fp"];
        // p50 lands in the fast bucket, p99 too (rank 99 of 100); p100
        // must surface the outlier's bucket
        assert_eq!(s.percentile_latency_us(0.5), 127);
        assert_eq!(s.p99_latency_us(), 127);
        let p100 = s.percentile_latency_us(1.0);
        assert!(
            (9000..=16383).contains(&p100),
            "outlier bucket upper edge, capped by max: {p100}"
        );
        // shed counts stay out of the latency aggregates
        t.record_shed("fp");
        let s = &t.snapshot()["fp"];
        assert_eq!(s.shed, 1);
        assert_eq!(s.count, 100);
    }

    #[test]
    fn concurrent_records() {
        let t = std::sync::Arc::new(Telemetry::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    t.record("x", 1, 1, true);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.snapshot()["x"].count, 400);
    }
}
