//! Protocol-v2 sessions: register a scan config once, then stream
//! tensors against it.
//!
//! v1 clients re-send nothing *about* the scan because the server is
//! pinned to one scan config at startup — which is exactly why it cannot
//! serve heterogeneous traffic. The session handshake fixes both ends:
//! an OpenSession frame carries the scan config (geometry + volume +
//! model) exactly once, the server validates it through
//! [`crate::api::ScanBuilder`] (degenerate configs are typed
//! [`LeapError::InvalidGeometry`] errors, never panics), plans it
//! through the process-wide [`super::plan_cache`] (the session's
//! `Arc<ProjectionPlan>` keeps the plan alive for the session's
//! lifetime), and returns a session id. Every subsequent request is a
//! 24-byte header + raw tensor.
//!
//! [`SessionExecutor`] is the backend that serves the session ops: it
//! maps [`Op::SessionFp`]`(id)` → the session's own
//! [`super::NativeExecutor`] running [`Op::NativeFp`], preserving the
//! batched fast path (the batcher groups by `Op` equality, so one
//! session's backlog still closes into a single stacked
//! `apply_batch_into`; two sessions never mix in one batch).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::api::{LeapError, ScanBuilder};
use crate::backend::BackendKind;
use crate::geometry::config::{geometry_from_json, volume_from_json, ScanConfig};
use crate::ops::{LinearOp, PlanOp};
use crate::precision::StorageTier;
use crate::projector::Model;
use crate::tape;
use crate::util::json::Json;

use super::op::Op;
use super::{Executor, NativeExecutor};

/// Upper bound on the resident footprint one wire-registered session may
/// demand (volume + sinogram + estimated plan bytes). The library API's
/// element cap alone is not enough here: a remote client could otherwise
/// register a validly-shaped terabyte-scale scan and drive the *server*
/// into an allocation abort during planning — the memory budget only
/// admission-controls per-request buffers, not session registration.
/// Oversized configs get a typed [`LeapError::BudgetExceeded`] instead.
pub const SESSION_MAX_BYTES: usize = 8 << 30;

/// Upper bound on concurrently open sessions per registry. Each open
/// session pins its plan (and survives plan-cache eviction), so without
/// a count cap the per-session byte gate would still allow unbounded
/// cumulative pinning from a client that keeps opening fresh configs.
/// Refusals are typed [`LeapError::BudgetExceeded`] (resource code 6).
pub const MAX_OPEN_SESSIONS: usize = 64;

/// Upper bound on tape pipelines registered per session — a registered
/// pipeline pins its node graph (and the `"scan"` op's scratch) for the
/// session lifetime, so registration is capped like sessions are.
pub const MAX_PIPELINES_PER_SESSION: usize = 16;

/// One open session: the executor serving its projection ops, plus the
/// tape pipelines registered against its pinned plan
/// ([`Op::SessionPipelineGrad`]). Pipelines are evaluation-stateless
/// (parameters travel per request), so sharing them behind an `Arc`
/// needs no further locking.
pub struct Session {
    exec: Arc<NativeExecutor>,
    /// Name of the compute backend the session's pinned plan dispatches
    /// through — reported in the OpenSession reply meta and `__stats`,
    /// so served results are attributable to a kernel tier.
    backend: &'static str,
    /// Name of the storage tier the session's pinned plan holds its
    /// data at rest in (coefficient tables, backprojection sinogram
    /// input) — reported in the OpenSession reply meta and `__stats`,
    /// so served results are attributable to an accuracy class.
    storage: &'static str,
    pipelines: Mutex<HashMap<u64, Arc<tape::Pipeline>>>,
    next_pipeline: AtomicU64,
}

/// The open sessions of a process: id → that scan's [`Session`].
pub struct SessionRegistry {
    next: AtomicU64,
    sessions: Mutex<HashMap<u64, Arc<Session>>>,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        SessionRegistry::new()
    }
}

impl SessionRegistry {
    pub fn new() -> SessionRegistry {
        SessionRegistry { next: AtomicU64::new(1), sessions: Mutex::new(HashMap::new()) }
    }

    fn global_cell() -> &'static Arc<SessionRegistry> {
        static REGISTRY: OnceLock<Arc<SessionRegistry>> = OnceLock::new();
        REGISTRY.get_or_init(|| Arc::new(SessionRegistry::new()))
    }

    /// The process-wide registry (shared by the default TCP server and
    /// the default [`SessionExecutor`] backend).
    pub fn global() -> &'static SessionRegistry {
        Self::global_cell()
    }

    /// The process-wide registry as a shareable handle — servers and
    /// executors that take an injected registry default to this one.
    pub fn global_arc() -> Arc<SessionRegistry> {
        Self::global_cell().clone()
    }

    /// Validate `cfg` and open a session for it on the process-default
    /// compute backend and storage tier (see [`Self::open_with`]).
    pub fn open(
        &self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
    ) -> Result<u64, LeapError> {
        self.open_with(cfg, model, threads, None, None)
    }

    /// Validate `cfg` and open a session for it. The scan is planned
    /// through the process-wide plan cache; the session pins the
    /// resulting plan until [`SessionRegistry::close`]. `backend`
    /// selects the compute backend (`None` = process default); the
    /// non-executing PJRT slot is a typed [`LeapError::Unsupported`]
    /// from the builder's capability gate. `storage` selects the
    /// data-at-rest storage tier (`None` = process default, see
    /// `LEAP_STORAGE`); reduced tiers pack the plan's coefficient
    /// tables, so two sessions on different tiers never share a plan.
    pub fn open_with(
        &self,
        cfg: &ScanConfig,
        model: Model,
        threads: Option<usize>,
        backend: Option<BackendKind>,
        storage: Option<StorageTier>,
    ) -> Result<u64, LeapError> {
        // Count gate BEFORE the expensive planning below (approximate —
        // concurrent opens may overshoot by the number in flight; the
        // insert-time check is authoritative).
        if self.sessions.lock().unwrap().len() >= MAX_OPEN_SESSIONS {
            return Err(LeapError::BudgetExceeded {
                needed: MAX_OPEN_SESSIONS + 1,
                cap: MAX_OPEN_SESSIONS,
            });
        }
        // Size gates BEFORE any planning allocation (see
        // SESSION_MAX_BYTES). Overflow-safe in two steps: first bound
        // each buffer in u128 arithmetic (so the per-dimension counts
        // are small enough that the plan-size estimator's usize
        // products cannot wrap), only then consult the estimator.
        let reject = |needed: u128| {
            Err(LeapError::BudgetExceeded {
                needed: needed.min(usize::MAX as u128) as usize,
                cap: SESSION_MAX_BYTES,
            })
        };
        // per-buffer bound = the wire payload cap: a session whose
        // volume or sinogram could never travel in one v2 frame must be
        // refused at open time, not fail on its first response
        let per_buffer_cap = super::wire::MAX_PAYLOAD_BYTES as u128;
        let vol_bytes = (cfg.volume.nx as u128)
            * (cfg.volume.ny as u128)
            * (cfg.volume.nz as u128)
            * 4;
        let g = &cfg.geometry;
        let sino_bytes = (g.nviews() as u128) * (g.nrows() as u128) * (g.ncols() as u128) * 4;
        if vol_bytes > per_buffer_cap || sino_bytes > per_buffer_cap {
            return reject(vol_bytes.max(sino_bytes));
        }
        let probe = crate::projector::Projector::new(g.clone(), cfg.volume.clone(), model);
        let plan_bytes = crate::projector::ProjectionPlan::estimate_heap_bytes(&probe) as u128;
        let needed = vol_bytes + sino_bytes + plan_bytes;
        if needed > SESSION_MAX_BYTES as u128 {
            return reject(needed);
        }
        let mut builder = ScanBuilder::from_config(cfg).model(model);
        if let Some(t) = threads {
            builder = builder.threads(t);
        }
        if let Some(k) = backend {
            builder = builder.backend(k);
        }
        if let Some(t) = storage {
            builder = builder.storage_tier(t);
        }
        let scan = builder.build()?;
        let backend_name = scan.backend().name();
        let storage_name = scan.storage_tier().name();
        let exec = NativeExecutor::with_plan(scan.projector().clone(), scan.plan().clone());
        let session = Session {
            exec: Arc::new(exec),
            backend: backend_name,
            storage: storage_name,
            pipelines: Mutex::new(HashMap::new()),
            next_pipeline: AtomicU64::new(1),
        };
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        {
            let mut sessions = self.sessions.lock().unwrap();
            if sessions.len() >= MAX_OPEN_SESSIONS {
                // authoritative check: racing opens past the early gate
                // drop their freshly-built plan instead of pinning it
                return Err(LeapError::BudgetExceeded {
                    needed: MAX_OPEN_SESSIONS + 1,
                    cap: MAX_OPEN_SESSIONS,
                });
            }
            sessions.insert(id, Arc::new(session));
        }
        Ok(id)
    }

    /// Open a session from OpenSession frame meta:
    /// `{"config": {"geometry": …, "volume": …}, "model": "sf",
    ///   "threads": n, "backend": "simd", "storage": "f16"}` (model,
    /// threads, backend and storage optional; absent knobs take the
    /// process defaults).
    pub fn open_from_meta(&self, meta: &Json) -> Result<u64, LeapError> {
        let cfg_json = meta
            .get("config")
            .ok_or_else(|| LeapError::Protocol("open-session meta missing config".into()))?;
        let geometry = geometry_from_json(
            cfg_json
                .get("geometry")
                .ok_or_else(|| LeapError::Protocol("config missing geometry".into()))?,
        )
        .map_err(LeapError::InvalidGeometry)?;
        let volume = volume_from_json(
            cfg_json
                .get("volume")
                .ok_or_else(|| LeapError::Protocol("config missing volume".into()))?,
        )
        .map_err(LeapError::InvalidGeometry)?;
        let model = match meta.get_str("model") {
            None => Model::SF,
            Some(name) => Model::parse(name)
                .ok_or_else(|| LeapError::InvalidArgument(format!("unknown model {name}")))?,
        };
        let threads = meta.get_usize("threads");
        let backend = match meta.get_str("backend") {
            None => None,
            Some(name) => Some(BackendKind::parse(name).ok_or_else(|| {
                LeapError::InvalidArgument(format!(
                    "unknown backend {name:?} (expected scalar|simd|pjrt)"
                ))
            })?),
        };
        let storage = match meta.get_str("storage") {
            None => None,
            Some(name) => Some(StorageTier::parse(name).ok_or_else(|| {
                LeapError::InvalidArgument(format!(
                    "unknown storage tier {name:?} (expected f32|f16|bf16)"
                ))
            })?),
        };
        self.open_with(&ScanConfig { geometry, volume }, model, threads, backend, storage)
    }

    /// Drop a session — its registered pipelines go with it (their plan
    /// stays cached only if the plan cache still holds it). Returns
    /// whether the id was open.
    pub fn close(&self, id: u64) -> bool {
        self.sessions.lock().unwrap().remove(&id).is_some()
    }

    /// The executor serving session `id`.
    pub fn executor(&self, id: u64) -> Option<Arc<NativeExecutor>> {
        self.sessions.lock().unwrap().get(&id).map(|s| s.exec.clone())
    }

    /// Name of the compute backend serving session `id` (for the
    /// OpenSession reply meta and `__stats` telemetry).
    pub fn backend_of(&self, id: u64) -> Option<&'static str> {
        self.sessions.lock().unwrap().get(&id).map(|s| s.backend)
    }

    /// Snapshot of `(session id, backend name)` for every open session,
    /// id-ordered — `__stats` reports which kernel tier serves each one.
    pub fn session_backends(&self) -> Vec<(u64, &'static str)> {
        let mut v: Vec<(u64, &'static str)> =
            self.sessions.lock().unwrap().iter().map(|(&id, s)| (id, s.backend)).collect();
        v.sort_unstable_by_key(|&(id, _)| id);
        v
    }

    /// Name of the storage tier serving session `id` (for the
    /// OpenSession reply meta and `__stats` telemetry).
    pub fn storage_of(&self, id: u64) -> Option<&'static str> {
        self.sessions.lock().unwrap().get(&id).map(|s| s.storage)
    }

    /// Snapshot of `(session id, storage tier name)` for every open
    /// session, id-ordered — `__stats` reports which accuracy class
    /// serves each one.
    pub fn session_storages(&self) -> Vec<(u64, &'static str)> {
        let mut v: Vec<(u64, &'static str)> =
            self.sessions.lock().unwrap().iter().map(|(&id, s)| (id, s.storage)).collect();
        v.sort_unstable_by_key(|&(id, _)| id);
        v
    }

    /// Validate a tape spec against session `id`'s pinned plan and
    /// register the pipeline; returns the pipeline id
    /// ([`Op::SessionPipelineGrad`] names it). The spec's `"scan"`
    /// operator is rebound to the session's own plan, so every
    /// evaluation uses exactly the floats the in-process tape would.
    /// Oversized pipelines — a packed request or gradient reply that
    /// could not travel in one v2 frame — are refused at registration,
    /// not on their first request.
    pub fn register_pipeline(&self, id: u64, spec: &Json) -> Result<u64, LeapError> {
        let session = self
            .sessions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(LeapError::UnknownSession(id))?;
        let scan: Arc<dyn LinearOp> =
            Arc::new(PlanOp::from_plan(session.exec.shared_plan()));
        let pipe = tape::pipeline_from_json(spec, &[("scan", scan)])?;
        if pipe.loss_node().is_none() {
            return Err(LeapError::InvalidArgument(
                "a served pipeline needs a loss node (pipeline_grad evaluates it)".into(),
            ));
        }
        let frame_cap = super::wire::MAX_PAYLOAD_BYTES / 4;
        let worst = pipe.packed_len().max(pipe.grad_reply_len());
        if worst > frame_cap {
            return Err(LeapError::BudgetExceeded {
                needed: worst * 4,
                cap: super::wire::MAX_PAYLOAD_BYTES,
            });
        }
        // the frame caps only bound params + inputs; a hostile spec can
        // still declare huge *intermediate* nodes (every node's forward
        // value stays alive for the backward sweep), so gate the whole
        // evaluation footprint like session registration gates plans
        let eval_bytes = pipe.eval_bytes_estimate();
        if eval_bytes > SESSION_MAX_BYTES {
            return Err(LeapError::BudgetExceeded {
                needed: eval_bytes,
                cap: SESSION_MAX_BYTES,
            });
        }
        let mut pipelines = session.pipelines.lock().unwrap();
        if pipelines.len() >= MAX_PIPELINES_PER_SESSION {
            return Err(LeapError::BudgetExceeded {
                needed: MAX_PIPELINES_PER_SESSION + 1,
                cap: MAX_PIPELINES_PER_SESSION,
            });
        }
        let pid = session.next_pipeline.fetch_add(1, Ordering::Relaxed);
        pipelines.insert(pid, Arc::new(pipe));
        Ok(pid)
    }

    /// One-lookup typed resolve: a missing session is
    /// [`LeapError::UnknownSession`], a live session without that
    /// pipeline id is [`LeapError::InvalidArgument`]. Takes the global
    /// sessions lock exactly once (the fetched [`Session`] already
    /// distinguishes the two failure modes) — this sits on the
    /// `pipeline_grad` hot path, where a training loop hits it per
    /// request.
    pub fn resolve_pipeline(
        &self,
        session: u64,
        pipeline: u64,
    ) -> Result<Arc<tape::Pipeline>, LeapError> {
        let s = self
            .sessions
            .lock()
            .unwrap()
            .get(&session)
            .cloned()
            .ok_or(LeapError::UnknownSession(session))?;
        let p = s.pipelines.lock().unwrap().get(&pipeline).cloned();
        p.ok_or_else(|| {
            LeapError::InvalidArgument(format!(
                "session {session} has no registered pipeline {pipeline}"
            ))
        })
    }

    /// Number of open sessions.
    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The coordinator backend for session ops: resolves the session id and
/// delegates to that session's [`NativeExecutor`] — whole batches at a
/// time, so the stacked batched projection path survives the
/// indirection.
///
/// With a shard channel attached ([`SessionExecutor::with_cluster`])
/// and workers connected, session forward/back applications scatter
/// across worker processes through [`crate::cluster::ShardedOp`] —
/// bit-identical to local execution by the cluster's determinism
/// contract, so clients cannot observe the difference except in
/// `__stats`. With no workers (or no channel) everything runs locally.
pub struct SessionExecutor {
    registry: Arc<SessionRegistry>,
    cluster: Option<Arc<crate::cluster::ShardServer>>,
}

impl Default for SessionExecutor {
    fn default() -> Self {
        SessionExecutor::new()
    }
}

impl SessionExecutor {
    /// Backend over the process-wide registry.
    pub fn new() -> SessionExecutor {
        SessionExecutor::with_registry(SessionRegistry::global_arc())
    }

    /// Backend over an explicit registry. A server that injects its own
    /// registry (see `ServerOptions`) pairs it with an executor built
    /// through this constructor, so two servers in one process — tests
    /// especially — cannot cross-contaminate sessions through the
    /// process-wide map.
    pub fn with_registry(registry: Arc<SessionRegistry>) -> SessionExecutor {
        SessionExecutor { registry, cluster: None }
    }

    /// [`SessionExecutor::with_registry`] plus a shard channel: while
    /// workers are connected to `cluster`, session projections scatter
    /// across them ([`crate::cluster::ShardedOp`]); with none connected
    /// the executor behaves exactly like a local one.
    pub fn with_cluster(
        registry: Arc<SessionRegistry>,
        cluster: Arc<crate::cluster::ShardServer>,
    ) -> SessionExecutor {
        SessionExecutor { registry, cluster: Some(cluster) }
    }

    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    /// Shareable handle to the registry this executor serves (for wiring
    /// the same registry into a server).
    pub fn registry_arc(&self) -> Arc<SessionRegistry> {
        self.registry.clone()
    }

    fn resolve(&self, op: &Op) -> Result<(Arc<NativeExecutor>, Op), LeapError> {
        let (id, native_op) = op
            .session_parts()
            .ok_or_else(|| LeapError::UnknownOp(op.label()))?;
        let exec = self.registry.executor(id).ok_or(LeapError::UnknownSession(id))?;
        Ok((exec, native_op))
    }

    fn resolve_pipeline(&self, op: &Op) -> Result<Arc<tape::Pipeline>, LeapError> {
        let Op::SessionPipelineGrad { session, pipeline } = op else {
            return Err(LeapError::UnknownOp(op.label()));
        };
        self.registry.resolve_pipeline(*session, *pipeline)
    }

    /// Scatter one session projection across the shard channel's
    /// workers. `None` when this executor has no cluster, no workers
    /// are connected, or the op is not a sharded kind (FBP and
    /// pipeline-grad always run locally) — the caller then takes the
    /// local path. Results are bit-identical either way.
    fn execute_clustered(
        &self,
        exec: &NativeExecutor,
        native_op: &Op,
        inputs: &[&[f32]],
    ) -> Option<Result<Vec<Vec<f32>>, LeapError>> {
        let cluster = self.cluster.as_ref()?;
        if cluster.workers() == 0 || !matches!(native_op, Op::NativeFp | Op::NativeBp) {
            return None;
        }
        let op = crate::cluster::ShardedOp::new(exec.shared_plan(), cluster.clone());
        Some(Self::run_sharded(&op, native_op, inputs))
    }

    /// Validate shapes and run one sharded forward/back application.
    fn run_sharded(
        op: &crate::cluster::ShardedOp,
        native_op: &Op,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>, LeapError> {
        let input = *inputs
            .first()
            .ok_or_else(|| LeapError::Protocol("projection request missing input tensor".into()))?;
        let vol_len = op.domain_shape().numel();
        let sino_len = op.range_shape().numel();
        let forward = matches!(native_op, Op::NativeFp);
        let (want, what, out_len) = if forward {
            (vol_len, "volume", sino_len)
        } else {
            (sino_len, "sinogram", vol_len)
        };
        if input.len() != want {
            return Err(LeapError::ShapeMismatch { what, expected: want, got: input.len() });
        }
        let mut out = vec![0.0f32; out_len];
        if forward {
            op.apply_into(input, &mut out);
        } else {
            op.adjoint_into(input, &mut out);
        }
        Ok(vec![out])
    }

    /// Evaluate one packed pipeline-grad request (see
    /// [`Op::SessionPipelineGrad`] for the payload layout).
    fn pipeline_grad(
        pipe: &tape::Pipeline,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>, LeapError> {
        let packed = inputs
            .first()
            .ok_or_else(|| LeapError::Protocol("pipeline_grad: missing input tensor".into()))?;
        let (params, ins) = pipe.split_packed(packed)?;
        let (loss, grads) = pipe.loss_and_grads_with(&params, &ins)?;
        Ok(vec![pipe.pack_grad_reply(loss, &grads)])
    }
}

impl Executor for SessionExecutor {
    fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
        if matches!(op, Op::SessionPipelineGrad { .. }) {
            let pipe = self.resolve_pipeline(op)?;
            return Self::pipeline_grad(&pipe, inputs);
        }
        let (exec, native_op) = self.resolve(op)?;
        if let Some(result) = self.execute_clustered(&exec, &native_op, inputs) {
            return result;
        }
        exec.execute(&native_op, inputs)
    }

    fn execute_batch(
        &self,
        op: &Op,
        items: &[Vec<&[f32]>],
    ) -> Vec<Result<Vec<Vec<f32>>, LeapError>> {
        if matches!(op, Op::SessionPipelineGrad { .. }) {
            // one pipeline resolve for the whole batch; items evaluate
            // concurrently (each carries its own params in its packed
            // payload). pool regions are caller-participating, so the
            // per-item tape sweeps nesting their own projections inside
            // this outer parallel loop cannot deadlock, and each item's
            // result is independent — the reply order is the slot
            // order, identical to the sequential collect this replaces.
            return match self.resolve_pipeline(op) {
                Ok(pipe) => {
                    let workers = crate::util::pool::default_threads().min(items.len().max(1));
                    let slots: Vec<std::sync::Mutex<Option<Result<Vec<Vec<f32>>, LeapError>>>> =
                        items.iter().map(|_| std::sync::Mutex::new(None)).collect();
                    crate::util::pool::parallel_items(items.len(), workers, |i| {
                        let r = Self::pipeline_grad(&pipe, &items[i]);
                        *slots[i].lock().unwrap() = Some(r);
                    });
                    slots
                        .into_iter()
                        .map(|s| s.into_inner().unwrap().expect("every slot filled"))
                        .collect()
                }
                Err(e) => items.iter().map(|_| Err(e.clone())).collect(),
            };
        }
        match self.resolve(op) {
            Ok((exec, native_op)) => {
                // clustered projections: each item already fans out
                // across every worker, so the batch runs item by item
                // (workers going away mid-batch falls back locally
                // per item — bit-identical either way)
                let clustered = self.cluster.as_ref().is_some_and(|c| c.workers() > 0)
                    && matches!(native_op, Op::NativeFp | Op::NativeBp);
                if clustered {
                    return items
                        .iter()
                        .map(|item| {
                            self.execute_clustered(&exec, &native_op, item)
                                .unwrap_or_else(|| exec.execute(&native_op, item))
                        })
                        .collect();
                }
                // one resolve for the whole batch; the session's native
                // executor runs it as one stacked batched projection
                exec.execute_batch(&native_op, items)
            }
            Err(e) => items.iter().map(|_| Err(e.clone())).collect(),
        }
    }

    fn output_bytes_hint(&self, op: &Op, input_bytes: usize) -> usize {
        if matches!(op, Op::SessionPipelineGrad { .. }) {
            return match self.resolve_pipeline(op) {
                Ok(pipe) => pipe.grad_reply_len() * 4,
                Err(_) => 0,
            };
        }
        match self.resolve(op) {
            Ok((exec, native_op)) => exec.output_bytes_hint(&native_op, input_bytes),
            Err(_) => 0,
        }
    }

    fn accepts(&self, op: &Op) -> bool {
        op.session_id().is_some()
    }

    /// Sessions are dynamic; the static op list is empty (routing goes
    /// through [`Executor::accepts`]).
    fn ops(&self) -> Vec<Op> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::util::json::parse;

    fn config(nviews: usize) -> ScanConfig {
        ScanConfig {
            geometry: Geometry::Parallel(ParallelBeam::standard_2d(nviews, 18, 1.0)),
            volume: VolumeGeometry::slice2d(12, 12, 1.0),
        }
    }

    #[test]
    fn open_execute_close() {
        let exec = SessionExecutor::with_registry(Arc::new(SessionRegistry::new()));
        let id = exec.registry().open(&config(8), Model::SF, Some(2)).unwrap();
        let vol = vec![0.01f32; 144];
        let out = exec.execute(&Op::SessionFp(id), &[&vol]).unwrap();
        assert_eq!(out[0].len(), 8 * 18);
        // matches the in-process plan path bit for bit
        let scan = ScanBuilder::from_config(&config(8))
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(out[0], scan.forward(&vol).unwrap());
        assert!(exec.registry().close(id));
        let e = exec.execute(&Op::SessionFp(id), &[&vol]).unwrap_err();
        assert_eq!(e, LeapError::UnknownSession(id));
    }

    #[test]
    fn session_count_is_capped() {
        let reg = SessionRegistry::new();
        let mut ids = Vec::new();
        for i in 0..MAX_OPEN_SESSIONS {
            ids.push(reg.open(&config(4 + (i % 3)), Model::SF, Some(1)).unwrap());
        }
        let e = reg.open(&config(4), Model::SF, Some(1)).unwrap_err();
        assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
        assert!(reg.close(ids[0]));
        reg.open(&config(4), Model::SF, Some(1)).expect("slot freed by close");
    }

    #[test]
    fn oversized_session_config_is_refused_before_planning() {
        // 2^32 voxels = 16 GiB of volume: over the per-buffer gate, so
        // the registry must refuse with a typed BudgetExceeded without
        // ever attempting to plan (which would abort on allocation)
        let reg = SessionRegistry::new();
        let cfg = ScanConfig {
            geometry: Geometry::Parallel(ParallelBeam::standard_2d(4, 8, 1.0)),
            volume: VolumeGeometry {
                nx: 1 << 14,
                ny: 1 << 14,
                nz: 1 << 4,
                vx: 1.0,
                vy: 1.0,
                vz: 1.0,
                cx: 0.0,
                cy: 0.0,
                cz: 0.0,
            },
        };
        let e = reg.open(&cfg, Model::SF, None).unwrap_err();
        assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
        assert!(reg.is_empty());
    }

    #[test]
    fn degenerate_config_is_a_typed_error() {
        let reg = SessionRegistry::new();
        let mut cfg = config(4);
        cfg.volume.vx = 0.0;
        let e = reg.open(&cfg, Model::SF, None).unwrap_err();
        assert!(matches!(e, LeapError::InvalidGeometry(_)));
        assert!(reg.is_empty());
    }

    #[test]
    fn open_from_meta_parses_and_validates() {
        let reg = SessionRegistry::new();
        let meta = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 18, "nviews": 6},
                           "volume": {"nx": 12}},
                "model": "sf", "threads": 2}"#,
        )
        .unwrap();
        let id = reg.open_from_meta(&meta).unwrap();
        assert!(reg.executor(id).is_some());

        let bad = parse(r#"{"model": "sf"}"#).unwrap();
        assert!(matches!(reg.open_from_meta(&bad), Err(LeapError::Protocol(_))));
        let bad_model = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 8, "nviews": 4},
                           "volume": {"nx": 8}}, "model": "warp"}"#,
        )
        .unwrap();
        assert!(matches!(reg.open_from_meta(&bad_model), Err(LeapError::InvalidArgument(_))));
    }

    #[test]
    fn sessions_carry_their_backend() {
        use crate::backend::BackendKind;
        let reg = SessionRegistry::new();
        let scalar = reg
            .open_with(&config(6), Model::SF, Some(2), Some(BackendKind::Scalar), None)
            .unwrap();
        let simd = reg
            .open_with(&config(6), Model::SF, Some(2), Some(BackendKind::Simd), None)
            .unwrap();
        assert_eq!(reg.backend_of(scalar), Some("scalar"));
        assert_eq!(reg.backend_of(simd), Some("simd"));
        // default-backend sessions report whatever the process resolved to
        let dflt = reg.open(&config(7), Model::SF, Some(1)).unwrap();
        let name = reg.backend_of(dflt).unwrap();
        assert!(name == "scalar" || name == "simd", "{name}");
        assert_eq!(reg.backend_of(u64::MAX), None);
        // the PJRT slot is capability-gated before any plan is built
        let e = reg
            .open_with(&config(6), Model::SF, None, Some(BackendKind::Pjrt), None)
            .unwrap_err();
        assert!(matches!(e, LeapError::Unsupported(ref m) if m.contains("pjrt")), "{e:?}");
    }

    #[test]
    fn sessions_carry_their_storage_tier() {
        let reg = SessionRegistry::new();
        let f32s = reg
            .open_with(&config(6), Model::SF, Some(1), None, Some(StorageTier::F32))
            .unwrap();
        let f16s = reg
            .open_with(&config(6), Model::SF, Some(1), None, Some(StorageTier::F16))
            .unwrap();
        let bf16s = reg
            .open_with(&config(6), Model::SF, Some(1), None, Some(StorageTier::Bf16))
            .unwrap();
        assert_eq!(reg.storage_of(f32s), Some("f32"));
        assert_eq!(reg.storage_of(f16s), Some("f16"));
        assert_eq!(reg.storage_of(bf16s), Some("bf16"));
        assert_eq!(reg.storage_of(u64::MAX), None);
        // default-tier sessions report whatever the process resolved to
        let dflt = reg.open(&config(7), Model::SF, Some(1)).unwrap();
        let name = reg.storage_of(dflt).unwrap();
        assert!(["f32", "f16", "bf16"].contains(&name), "{name}");
        // id-ordered snapshot covers every open session
        let snap = reg.session_storages();
        assert_eq!(snap.len(), 4);
        assert!(snap.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(snap.iter().any(|&(id, s)| id == f16s && s == "f16"));
    }

    #[test]
    fn open_from_meta_parses_the_storage_knob() {
        let reg = SessionRegistry::new();
        let meta = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 18, "nviews": 6},
                           "volume": {"nx": 12}},
                "model": "sf", "threads": 2, "storage": "f16"}"#,
        )
        .unwrap();
        let id = reg.open_from_meta(&meta).unwrap();
        assert_eq!(reg.storage_of(id), Some("f16"));

        let bad = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 8, "nviews": 4},
                           "volume": {"nx": 8}}, "storage": "f8"}"#,
        )
        .unwrap();
        assert!(matches!(reg.open_from_meta(&bad), Err(LeapError::InvalidArgument(_))));
    }

    #[test]
    fn open_from_meta_parses_the_backend_knob() {
        let reg = SessionRegistry::new();
        let meta = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 18, "nviews": 6},
                           "volume": {"nx": 12}},
                "model": "sf", "threads": 2, "backend": "simd"}"#,
        )
        .unwrap();
        let id = reg.open_from_meta(&meta).unwrap();
        assert_eq!(reg.backend_of(id), Some("simd"));

        let bad = parse(
            r#"{"config": {"geometry": {"type": "parallel", "ncols": 8, "nviews": 4},
                           "volume": {"nx": 8}}, "backend": "warp"}"#,
        )
        .unwrap();
        assert!(matches!(reg.open_from_meta(&bad), Err(LeapError::InvalidArgument(_))));
    }

    #[test]
    fn pipeline_grad_matches_the_in_process_tape_bit_for_bit() {
        let exec = SessionExecutor::with_registry(Arc::new(SessionRegistry::new()));
        let id = exec.registry().open(&config(6), Model::SF, Some(2)).unwrap();
        // the same scan through the front door shares the cached plan
        let scan = ScanBuilder::from_config(&config(6))
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let local: Arc<dyn LinearOp> = Arc::new(PlanOp::from_plan(scan.plan().clone()));
        let pipe = tape::unrolled_gd(
            local,
            &tape::UnrollCfg { iterations: 2, step_init: 0.01, nonneg: true },
        )
        .unwrap();
        let pid = exec
            .registry()
            .register_pipeline(id, &tape::pipeline_to_json(&pipe))
            .unwrap();

        let mut rng = crate::util::rng::Rng::new(19);
        let params: Vec<Vec<f32>> = pipe
            .params()
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.shape.numel()];
                rng.fill_uniform(&mut v, 0.005, 0.02);
                v
            })
            .collect();
        let inputs: Vec<Vec<f32>> = pipe
            .input_shapes()
            .iter()
            .map(|s| {
                let mut v = vec![0.0f32; s.numel()];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let packed = pipe.pack(&pr, &ir).unwrap();
        let op = Op::SessionPipelineGrad { session: id, pipeline: pid };
        let out = exec.execute(&op, &[&packed]).unwrap();
        let (loss_served, grads_served) = pipe.unpack_grad_reply(&out[0]).unwrap();
        let (loss_local, grads_local) = pipe.loss_and_grads_with(&pr, &ir).unwrap();
        assert_eq!(loss_served.to_bits(), loss_local.to_bits(), "served loss must be exact");
        assert_eq!(grads_served, grads_local, "served gradients must be exact");

        // wrong packed length is a typed shape error, not a panic
        let e = exec.execute(&op, &[&packed[..3]]).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { .. }), "{e:?}");
        // unknown pipeline vs closed session give distinct typed errors
        let e = exec
            .execute(&Op::SessionPipelineGrad { session: id, pipeline: 999 }, &[&packed])
            .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        assert!(exec.registry().close(id));
        let e = exec.execute(&op, &[&packed]).unwrap_err();
        assert_eq!(e, LeapError::UnknownSession(id));
    }

    #[test]
    fn batched_pipeline_grads_are_ordered_and_bit_identical_to_per_item() {
        // the batch path evaluates items concurrently; replies must
        // land in item order with the exact per-item bytes
        let exec = SessionExecutor::with_registry(Arc::new(SessionRegistry::new()));
        let id = exec.registry().open(&config(6), Model::SF, Some(2)).unwrap();
        let scan = ScanBuilder::from_config(&config(6))
            .model(Model::SF)
            .threads(2)
            .build()
            .unwrap();
        let local: Arc<dyn LinearOp> = Arc::new(PlanOp::from_plan(scan.plan().clone()));
        let pipe = tape::unrolled_gd(
            local,
            &tape::UnrollCfg { iterations: 1, step_init: 0.01, nonneg: true },
        )
        .unwrap();
        let pid = exec
            .registry()
            .register_pipeline(id, &tape::pipeline_to_json(&pipe))
            .unwrap();
        let op = Op::SessionPipelineGrad { session: id, pipeline: pid };

        let mut rng = crate::util::rng::Rng::new(77);
        let mut packed_items = Vec::new();
        for _ in 0..5 {
            let params: Vec<Vec<f32>> = pipe
                .params()
                .iter()
                .map(|p| {
                    let mut v = vec![0.0f32; p.shape.numel()];
                    rng.fill_uniform(&mut v, 0.005, 0.02);
                    v
                })
                .collect();
            let inputs: Vec<Vec<f32>> = pipe
                .input_shapes()
                .iter()
                .map(|s| {
                    let mut v = vec![0.0f32; s.numel()];
                    rng.fill_uniform(&mut v, 0.0, 1.0);
                    v
                })
                .collect();
            let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
            let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            packed_items.push(pipe.pack(&pr, &ir).unwrap());
        }
        let items: Vec<Vec<&[f32]>> =
            packed_items.iter().map(|p| vec![p.as_slice()]).collect();
        let batch = exec.execute_batch(&op, &items);
        assert_eq!(batch.len(), items.len());
        for (item, got) in items.iter().zip(batch) {
            let want = exec.execute(&op, item).unwrap();
            assert_eq!(got.unwrap(), want, "batch reply must match the per-item path");
        }
        // a mix of good and bad items fails only the bad slots
        let mut mixed = items.clone();
        let short = &packed_items[0][..3];
        mixed[2] = vec![short];
        let replies = exec.execute_batch(&op, &mixed);
        assert!(replies[0].is_ok() && replies[1].is_ok() && replies[3].is_ok());
        assert!(matches!(replies[2], Err(LeapError::ShapeMismatch { .. })));
        exec.registry().close(id);
    }

    #[test]
    fn pipelines_per_session_are_capped_and_validated() {
        let reg = SessionRegistry::new();
        let id = reg.open(&config(5), Model::SF, Some(1)).unwrap();
        let scan = ScanBuilder::from_config(&config(5))
            .model(Model::SF)
            .threads(1)
            .build()
            .unwrap();
        let local: Arc<dyn LinearOp> = Arc::new(PlanOp::from_plan(scan.plan().clone()));
        let spec = tape::pipeline_to_json(
            &tape::unrolled_gd(
                local,
                &tape::UnrollCfg { iterations: 1, step_init: 0.01, nonneg: false },
            )
            .unwrap(),
        );
        for _ in 0..MAX_PIPELINES_PER_SESSION {
            reg.register_pipeline(id, &spec).unwrap();
        }
        let e = reg.register_pipeline(id, &spec).unwrap_err();
        assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
        // malformed spec → typed protocol error; unknown session → typed
        let e = reg.register_pipeline(id, &Json::Null).unwrap_err();
        assert!(matches!(e, LeapError::Protocol(_)), "{e:?}");
        let e = reg.register_pipeline(9999, &spec).unwrap_err();
        assert_eq!(e, LeapError::UnknownSession(9999));
    }

    #[test]
    fn pipeline_with_huge_intermediates_is_refused_at_registration() {
        // the packed request/reply are tiny (one scalar param, two small
        // inputs) but the spec declares giant dead fill nodes: the
        // evaluation-footprint gate must refuse it BEFORE any
        // pipeline_grad request can try to materialize them
        let reg = SessionRegistry::new();
        let id = reg.open(&config(5), Model::SF, Some(1)).unwrap();
        let mut nodes = vec![
            r#"{"k": "input", "slot": 0}"#.to_string(),
            r#"{"k": "param", "p": 0}"#.to_string(),
        ];
        // 64 × 2^28-element fills ≈ 64 GiB of forward values
        for _ in 0..64 {
            nodes.push(r#"{"k": "fill", "shape": [268435456, 1, 1], "v": 0.0}"#.to_string());
        }
        nodes.push(r#"{"k": "l2", "pred": 1, "target": 0}"#.to_string());
        let text = format!(
            r#"{{"tape_spec": 1, "inputs": [[1,1,1]],
                "params": [{{"name": "p", "shape": [1,1,1]}}],
                "nodes": [{}], "loss": {}}}"#,
            nodes.join(","),
            nodes.len() - 1
        );
        let spec = parse(&text).unwrap();
        let e = reg.register_pipeline(id, &spec).unwrap_err();
        assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
    }

    #[test]
    fn non_session_ops_are_rejected() {
        let exec = SessionExecutor::new();
        assert!(!exec.accepts(&Op::NativeFp));
        assert!(exec.accepts(&Op::SessionBp(1)));
        let e = exec.execute(&Op::NativeFp, &[&[1.0]]).unwrap_err();
        assert!(matches!(e, LeapError::UnknownOp(_)));
    }

    #[test]
    fn batch_against_one_session_stays_whole() {
        let exec = SessionExecutor::with_registry(Arc::new(SessionRegistry::new()));
        let id = exec.registry().open(&config(6), Model::SF, Some(2)).unwrap();
        let vols: Vec<Vec<f32>> = (0..3).map(|i| vec![0.01f32 * (i + 1) as f32; 144]).collect();
        let items: Vec<Vec<&[f32]>> = vols.iter().map(|v| vec![v.as_slice()]).collect();
        let batched = exec.execute_batch(&Op::SessionFp(id), &items);
        for (i, r) in batched.iter().enumerate() {
            let single = exec.execute(&Op::SessionFp(id), &[&vols[i]]).unwrap();
            assert_eq!(r.as_ref().unwrap()[0], single[0], "item {i}");
        }
        // unknown session: every item fails with the typed error
        let gone = exec.execute_batch(&Op::SessionFp(9999), &items);
        for r in gone {
            assert_eq!(r.unwrap_err(), LeapError::UnknownSession(9999));
        }
    }
}
