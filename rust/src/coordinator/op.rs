//! The typed operation enum routed end-to-end through the coordinator.
//!
//! Requests used to name their operation with a free string matched at
//! the executor (`"native_fp"`, …); a typo was a runtime routing error
//! and every backend re-parsed the string. [`Op`] replaces that:
//! requests, the batcher, the router and the executors all speak this
//! enum, and the string form exists only at the wire boundary
//! ([`Op::parse_wire`] / [`Op::label`]).
//!
//! Session variants carry the protocol-v2 session id (see
//! [`super::session`]): two sessions never batch together (enum equality
//! is batch identity), while repeated requests on one session do — and
//! execute against that session's pinned plan.

use crate::api::LeapError;

/// A coordinator operation.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Forward projection on the native backend's configured scan.
    NativeFp,
    /// Matched backprojection on the native backend's configured scan.
    NativeBp,
    /// FBP/FDK reconstruction on the native backend's configured scan.
    NativeFbp,
    /// Forward projection on an open protocol-v2 session.
    SessionFp(u64),
    /// Matched backprojection on an open protocol-v2 session.
    SessionBp(u64),
    /// FBP/FDK reconstruction on an open protocol-v2 session.
    SessionFbp(u64),
    /// Loss + parameter gradients of a pipeline registered on an open
    /// protocol-v2 session (`RegisterPipeline` frame): the request
    /// payload packs current parameters + inputs
    /// ([`crate::tape::Pipeline::pack`]), the reply packs the f64 loss
    /// (two f32 bit-halves) + per-parameter gradients. Batch identity
    /// includes the pipeline id, so repeated gradient requests on one
    /// registered pipeline batch together and never mix with another's.
    SessionPipelineGrad { session: u64, pipeline: u64 },
    /// A named artifact entry point (PJRT backend) or any other
    /// backend-defined operation.
    Artifact(String),
}

impl Op {
    /// Parse a v1 wire name. Total: unknown names become
    /// [`Op::Artifact`] and fail at routing time with a typed
    /// [`LeapError::UnknownOp`] (session ops are v2-only and cannot be
    /// named in v1).
    pub fn parse_wire(s: &str) -> Op {
        match s {
            "native_fp" => Op::NativeFp,
            "native_bp" => Op::NativeBp,
            "native_fbp" => Op::NativeFbp,
            other => Op::Artifact(other.to_string()),
        }
    }

    /// Build an op from protocol-v2 request meta: the short op name plus
    /// optional session and pipeline ids.
    pub fn from_wire(op: &str, session: Option<u64>, pipeline: Option<u64>) -> Result<Op, LeapError> {
        match (session, pipeline) {
            (Some(id), None) => match op {
                "fp" | "native_fp" => Ok(Op::SessionFp(id)),
                "bp" | "native_bp" => Ok(Op::SessionBp(id)),
                "fbp" | "native_fbp" => Ok(Op::SessionFbp(id)),
                "pipeline_grad" => Err(LeapError::Protocol(
                    "pipeline_grad requires a pipeline id in the request meta".into(),
                )),
                other => Err(LeapError::UnknownOp(format!("{other} (on session {id})"))),
            },
            (Some(session), Some(pipeline)) => match op {
                "pipeline_grad" => Ok(Op::SessionPipelineGrad { session, pipeline }),
                other => Err(LeapError::UnknownOp(format!(
                    "{other} (pipeline ops must be pipeline_grad, on session {session})"
                ))),
            },
            (None, Some(_)) => Err(LeapError::Protocol(
                "a pipeline id without a session id is meaningless".into(),
            )),
            (None, None) => Ok(Op::parse_wire(op)),
        }
    }

    /// The telemetry/wire label. Session ops share one label per kind
    /// (ids are request metadata, not a telemetry dimension).
    pub fn label(&self) -> String {
        match self {
            Op::NativeFp => "native_fp".into(),
            Op::NativeBp => "native_bp".into(),
            Op::NativeFbp => "native_fbp".into(),
            Op::SessionFp(_) => "session_fp".into(),
            Op::SessionBp(_) => "session_bp".into(),
            Op::SessionFbp(_) => "session_fbp".into(),
            Op::SessionPipelineGrad { .. } => "session_pipeline_grad".into(),
            Op::Artifact(name) => name.clone(),
        }
    }

    /// The protocol-v2 wire fields: short op name + session id +
    /// pipeline id. Round-trips through [`Op::from_wire`] for every
    /// variant.
    pub fn wire_fields(&self) -> (&str, Option<u64>, Option<u64>) {
        match self {
            Op::NativeFp => ("native_fp", None, None),
            Op::NativeBp => ("native_bp", None, None),
            Op::NativeFbp => ("native_fbp", None, None),
            Op::SessionFp(id) => ("fp", Some(*id), None),
            Op::SessionBp(id) => ("bp", Some(*id), None),
            Op::SessionFbp(id) => ("fbp", Some(*id), None),
            Op::SessionPipelineGrad { session, pipeline } => {
                ("pipeline_grad", Some(*session), Some(*pipeline))
            }
            Op::Artifact(name) => (name, None, None),
        }
    }

    /// For a projection session op: the session id and the equivalent
    /// native op it executes as on the session's scan. Pipeline-grad ops
    /// have no native equivalent and return `None` (use
    /// [`Op::session_id`] for scoping).
    pub fn session_parts(&self) -> Option<(u64, Op)> {
        match self {
            Op::SessionFp(id) => Some((*id, Op::NativeFp)),
            Op::SessionBp(id) => Some((*id, Op::NativeBp)),
            Op::SessionFbp(id) => Some((*id, Op::NativeFbp)),
            _ => None,
        }
    }

    /// The session this op is scoped to, for **every** session variant
    /// (projection ops and pipeline-grad). Connection-scoping in the
    /// server must use this, not [`Op::session_parts`] — otherwise a new
    /// session-op variant would silently bypass the not-yours check.
    pub fn session_id(&self) -> Option<u64> {
        match self {
            Op::SessionFp(id) | Op::SessionBp(id) | Op::SessionFbp(id) => Some(*id),
            Op::SessionPipelineGrad { session, .. } => Some(*session),
            _ => None,
        }
    }
}

/// The v1 wire strings convert directly, so existing call sites
/// (`Request::new(1, "native_fp", …)`) keep working unchanged.
impl From<&str> for Op {
    fn from(s: &str) -> Op {
        Op::parse_wire(s)
    }
}

impl From<String> for Op {
    fn from(s: String) -> Op {
        Op::parse_wire(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn every_variant() -> Vec<Op> {
        vec![
            Op::NativeFp,
            Op::NativeBp,
            Op::NativeFbp,
            Op::SessionFp(1),
            Op::SessionBp(u64::MAX),
            Op::SessionFbp(42),
            Op::SessionPipelineGrad { session: 7, pipeline: u64::MAX },
            Op::Artifact("fp_sf".into()),
        ]
    }

    #[test]
    fn wire_fields_roundtrip_every_variant() {
        for op in every_variant() {
            let (name, session, pipeline) = op.wire_fields();
            assert_eq!(Op::from_wire(name, session, pipeline).unwrap(), op);
        }
    }

    #[test]
    fn v1_names_parse_totally() {
        assert_eq!(Op::parse_wire("native_fp"), Op::NativeFp);
        assert_eq!(Op::parse_wire("native_bp"), Op::NativeBp);
        assert_eq!(Op::parse_wire("native_fbp"), Op::NativeFbp);
        assert_eq!(Op::parse_wire("fp_sf"), Op::Artifact("fp_sf".into()));
        assert_eq!(Op::from("echo"), Op::Artifact("echo".into()));
    }

    #[test]
    fn unknown_session_op_is_typed() {
        let e = Op::from_wire("warp", Some(3), None).unwrap_err();
        assert!(matches!(e, LeapError::UnknownOp(_)));
        // pipeline_grad without a pipeline id, or a pipeline id without a
        // session, are protocol errors, not routing misses
        let e = Op::from_wire("pipeline_grad", Some(3), None).unwrap_err();
        assert!(matches!(e, LeapError::Protocol(_)));
        let e = Op::from_wire("fp", None, Some(1)).unwrap_err();
        assert!(matches!(e, LeapError::Protocol(_)));
        let e = Op::from_wire("fp", Some(3), Some(1)).unwrap_err();
        assert!(matches!(e, LeapError::UnknownOp(_)));
    }

    #[test]
    fn sessions_do_not_share_batch_identity() {
        assert_ne!(Op::SessionFp(1), Op::SessionFp(2));
        assert_eq!(Op::SessionFp(1), Op::SessionFp(1));
        // pipeline identity includes the pipeline id
        assert_ne!(
            Op::SessionPipelineGrad { session: 1, pipeline: 1 },
            Op::SessionPipelineGrad { session: 1, pipeline: 2 }
        );
    }

    #[test]
    fn session_id_covers_every_session_variant() {
        for op in every_variant() {
            let scoped = op.session_id().is_some();
            let is_session = matches!(
                op,
                Op::SessionFp(_)
                    | Op::SessionBp(_)
                    | Op::SessionFbp(_)
                    | Op::SessionPipelineGrad { .. }
            );
            assert_eq!(scoped, is_session, "{op:?}");
        }
        assert_eq!(
            Op::SessionPipelineGrad { session: 9, pipeline: 1 }.session_id(),
            Some(9)
        );
        // …but it has no native projection equivalent
        assert!(Op::SessionPipelineGrad { session: 9, pipeline: 1 }.session_parts().is_none());
    }
}
