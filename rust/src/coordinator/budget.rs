//! Memory-budget admission control.
//!
//! The paper's headline systems property is the small, predictable
//! footprint: one copy of the volume plus one copy of the projections per
//! job. The coordinator enforces an aggregate cap on in-flight bytes so a
//! burst of requests cannot blow the GPU/host memory — jobs beyond the cap
//! wait in the queue instead of failing OOM mid-flight.

use std::sync::{Condvar, Mutex};

/// Tracks in-flight bytes against a cap. `acquire` blocks until the
/// reservation fits (or returns false for oversized jobs that can never
/// fit).
pub struct MemoryBudget {
    cap: usize,
    state: Mutex<usize>,
    cv: Condvar,
}

impl MemoryBudget {
    pub fn new(cap_bytes: usize) -> MemoryBudget {
        MemoryBudget { cap: cap_bytes, state: Mutex::new(0), cv: Condvar::new() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn in_flight(&self) -> usize {
        *self.state.lock().unwrap()
    }

    /// Reserve `bytes`. Blocks while the cap is exceeded. Returns false if
    /// `bytes > cap` (the job can never be admitted).
    pub fn acquire(&self, bytes: usize) -> bool {
        if bytes > self.cap {
            return false;
        }
        let mut used = self.state.lock().unwrap();
        while *used + bytes > self.cap {
            used = self.cv.wait(used).unwrap();
        }
        *used += bytes;
        true
    }

    /// Non-blocking variant: true if reserved.
    pub fn try_acquire(&self, bytes: usize) -> bool {
        if bytes > self.cap {
            return false;
        }
        let mut used = self.state.lock().unwrap();
        if *used + bytes > self.cap {
            return false;
        }
        *used += bytes;
        true
    }

    pub fn release(&self, bytes: usize) {
        let mut used = self.state.lock().unwrap();
        *used = used.saturating_sub(bytes);
        drop(used);
        self.cv.notify_all();
    }
}

/// Estimate a job's footprint: inputs + outputs, one copy each (the
/// paper's memory model), plus a fixed overhead for the runtime.
pub fn job_bytes(input_bytes: usize, output_bytes: usize) -> usize {
    input_bytes + output_bytes + 4096
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn acquire_release_basic() {
        let b = MemoryBudget::new(100);
        assert!(b.acquire(60));
        assert_eq!(b.in_flight(), 60);
        assert!(b.try_acquire(40));
        assert!(!b.try_acquire(1));
        b.release(60);
        assert!(b.try_acquire(60));
        assert_eq!(b.in_flight(), 100);
    }

    #[test]
    fn oversized_rejected() {
        let b = MemoryBudget::new(10);
        assert!(!b.acquire(11));
        assert!(!b.try_acquire(11));
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let b = Arc::new(MemoryBudget::new(100));
        assert!(b.acquire(90));
        let done = Arc::new(AtomicUsize::new(0));
        let b2 = b.clone();
        let d2 = done.clone();
        let h = std::thread::spawn(move || {
            assert!(b2.acquire(50)); // must wait for the release
            d2.store(1, Ordering::SeqCst);
            b2.release(50);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(done.load(Ordering::SeqCst), 0, "should still be blocked");
        b.release(90);
        h.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn budget_never_exceeded_under_contention() {
        // property test: hammer with random acquire/release from several
        // threads; the in-flight watermark must never exceed the cap
        let b = Arc::new(MemoryBudget::new(1000));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in 0..4 {
            let b = b.clone();
            let peak = peak.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(t as u64);
                for _ in 0..200 {
                    let bytes = 1 + rng.below(400);
                    if b.acquire(bytes) {
                        let now = b.in_flight();
                        peak.fetch_max(now, Ordering::SeqCst);
                        assert!(now <= 1000, "cap exceeded: {now}");
                        b.release(bytes);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 1000);
    }
}
