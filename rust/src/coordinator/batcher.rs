//! Dynamic batcher: groups queued requests by operation so workers execute
//! runs of identical ops back-to-back (one compiled executable stays hot;
//! weights/plans stay in cache), closing a batch at `max_batch` or when the
//! oldest member exceeds `max_wait`.
//!
//! Invariants (property-tested below):
//!  * FIFO order is preserved *within* an op,
//!  * a batch never mixes ops and never exceeds `max_batch`,
//!  * no request waits past `max_wait` once the batcher is polled,
//!  * every submitted request is eventually emitted exactly once.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::op::Op;
use super::request::Request;

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A closed batch: requests sharing one op. A multi-request batch is the
/// unit the coordinator hands to [`super::Executor::execute_batch`] —
/// on the native backend that is one stacked
/// [`crate::ops::LinearOp::apply_batch_into`] application.
#[derive(Debug)]
pub struct Batch {
    /// The typed op every member shares (batch identity is `Op`
    /// equality, so two protocol-v2 sessions never mix in one batch).
    pub op: Op,
    pub requests: Vec<Request>,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Non-thread-safe core (wrapped in a mutex by the coordinator).
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Close and return the next batch, if any is ready at `now`.
    ///
    /// The head request's op defines the batch op; subsequent requests of
    /// the same op (anywhere in the queue, preserving their relative
    /// order) join until `max_batch`. A batch is "ready" when it is full
    /// or its oldest member has waited `max_wait` — otherwise `None`, so a
    /// caller can keep accumulating.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        let head = self.queue.front()?;
        let op = head.op.clone();
        let oldest_wait = now.saturating_duration_since(head.submitted);
        let same_op = self.queue.iter().filter(|r| r.op == op).count();
        let full = same_op >= self.policy.max_batch;
        if !full && oldest_wait < self.policy.max_wait {
            return None;
        }
        let mut requests = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            if req.op == op && requests.len() < self.policy.max_batch {
                requests.push(req);
            } else {
                rest.push_back(req);
            }
        }
        self.queue = rest;
        Some(Batch { op, requests })
    }

    /// Work-conserving pop: return the head batch immediately, regardless
    /// of the deadline (used by idle workers — holding work while capacity
    /// is free only adds latency; batches still form naturally from
    /// backlog under load). See EXPERIMENTS.md §Perf.
    pub fn pop_now(&mut self) -> Option<Batch> {
        let far = Instant::now() + Duration::from_secs(3600);
        self.pop_ready(far)
    }

    /// Drain everything immediately (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        let far_future = Instant::now() + Duration::from_secs(3600);
        while !self.queue.is_empty() {
            if let Some(b) = self.pop_ready(far_future) {
                out.push(b);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn req(id: u64, op: &str) -> Request {
        Request::new(id, op, vec![])
    }

    #[test]
    fn batches_by_op_preserving_order() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, max_wait: Duration::ZERO });
        for (id, op) in [(1, "a"), (2, "b"), (3, "a"), (4, "a"), (5, "b")] {
            b.push(req(id, op));
        }
        let first = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(first.op, Op::Artifact("a".into()));
        assert_eq!(first.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let second = b.pop_ready(Instant::now()).unwrap();
        assert_eq!(second.op, Op::Artifact("b".into()));
        assert_eq!(second.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 5]);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::ZERO });
        for id in 0..7 {
            b.push(req(id, "x"));
        }
        let sizes: Vec<usize> = std::iter::from_fn(|| b.pop_ready(Instant::now()))
            .map(|batch| batch.requests.len())
            .collect();
        assert_eq!(sizes, vec![3, 3, 1]);
    }

    #[test]
    fn waits_for_deadline_when_not_full() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) });
        b.push(req(1, "x"));
        // immediately: not ready (not full, not old)
        assert!(b.pop_ready(Instant::now()).is_none());
        // after the deadline it flushes even at size 1
        let later = Instant::now() + Duration::from_millis(60);
        let batch = b.pop_ready(later).unwrap();
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        b.push(req(1, "x"));
        b.push(req(2, "x"));
        assert!(b.pop_ready(Instant::now()).is_some());
    }

    #[test]
    fn property_every_request_emitted_exactly_once() {
        // randomized schedule: interleave pushes and pops, then drain;
        // multiset of emitted ids equals the submitted ids, FIFO per op
        let mut rng = Rng::new(99);
        for trial in 0..20 {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: 1 + rng.below(5),
                max_wait: Duration::ZERO,
            });
            let ops = ["fp", "bp", "fbp"];
            let mut submitted = Vec::new();
            let mut emitted: Vec<(String, u64)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..50 {
                if rng.f64() < 0.6 {
                    let op = ops[rng.below(3)];
                    b.push(req(next_id, op));
                    submitted.push((op.to_string(), next_id));
                    next_id += 1;
                } else if let Some(batch) = b.pop_ready(Instant::now()) {
                    for r in batch.requests {
                        emitted.push((batch.op.label(), r.id));
                    }
                }
            }
            for batch in b.drain_all() {
                for r in batch.requests {
                    emitted.push((batch.op.label(), r.id));
                }
            }
            // exactly once
            let mut a = submitted.clone();
            let mut e = emitted.clone();
            a.sort();
            e.sort();
            assert_eq!(a, e, "trial {trial}");
            // FIFO within op
            for op in ops {
                let sub: Vec<u64> =
                    submitted.iter().filter(|(o, _)| o == op).map(|&(_, i)| i).collect();
                let emi: Vec<u64> =
                    emitted.iter().filter(|(o, _)| o == op).map(|&(_, i)| i).collect();
                assert_eq!(sub, emi, "trial {trial} op {op}");
            }
        }
    }
}
