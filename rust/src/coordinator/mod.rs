//! The serving layer: request router, dynamic batcher, worker pool and
//! memory-budget admission control over the projection backends.
//!
//! Operations are typed end to end: a [`Request`] carries an [`Op`]
//! (never a free string), the [`batcher::Batcher`] groups by `Op`
//! equality, the [`Router`] asks each backend [`Executor::accepts`], and
//! executors match on the enum. Failures are typed too — every backend
//! returns `Result<_, `[`crate::api::LeapError`]`>`, and the error's
//! stable wire code survives both protocol versions.
//!
//! Three backends implement [`Executor`]:
//! * [`crate::runtime::EngineHost`] — the AOT JAX/Pallas artifacts via
//!   PJRT ([`Op::Artifact`] entry points; fixed shapes, Python never on
//!   this path);
//! * [`NativeExecutor`] — the Rust on-the-fly projectors for one
//!   configured scan ([`Op::NativeFp`]/[`Op::NativeBp`]/[`Op::NativeFbp`]);
//! * [`session::SessionExecutor`] — protocol-v2 sessions: any scan
//!   config registered at runtime ([`Op::SessionFp`]`(id)`, …), each
//!   pinned to its cached plan.
//!
//! Flow: `submit` → [`batcher::Batcher`] groups by op → a worker claims
//! the batch, reserves memory from [`budget::MemoryBudget`], executes,
//! records [`telemetry::Telemetry`], and delivers each
//! [`request::Response`] through its per-request channel.
//! `examples/serve_client.rs` runs the whole stack over TCP via
//! [`server`], speaking both wire protocols (see [`wire`] and
//! `docs/PROTOCOL.md`).

pub mod batcher;
pub mod budget;
pub mod op;
pub mod plan_cache;
pub mod request;
pub mod server;
pub mod session;
pub mod telemetry;
pub mod wire;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::api::LeapError;

pub use batcher::{BatchPolicy, Batcher};
pub use budget::MemoryBudget;
pub use op::Op;
pub use plan_cache::PlanCache;
pub use request::{Request, Response};
pub use session::{SessionExecutor, SessionRegistry};
pub use telemetry::Telemetry;

/// A projection backend the coordinator can route to.
pub trait Executor: Send + Sync {
    /// Execute `op` on the given inputs, returning the outputs. Every
    /// failure — wrong shapes, unknown ops, backend faults — is a typed
    /// [`LeapError`], never a panic.
    fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError>;
    /// Execute a closed batch of same-op requests' input sets in one
    /// backend call, returning exactly one result per item (order
    /// preserved; a bad item fails alone, never the batch). The default
    /// executes the items sequentially; backends with a batched fast
    /// path — [`NativeExecutor`] runs projector batches as **one**
    /// [`crate::ops::LinearOp::apply_batch_into`] (one plan fetch, one
    /// pool dispatch over the stacked inputs) — override it.
    fn execute_batch(
        &self,
        op: &Op,
        items: &[Vec<&[f32]>],
    ) -> Vec<Result<Vec<Vec<f32>>, LeapError>> {
        items.iter().map(|inputs| self.execute(op, inputs)).collect()
    }
    /// Estimated output bytes for admission control.
    fn output_bytes_hint(&self, op: &Op, input_bytes: usize) -> usize {
        let _ = op;
        input_bytes
    }
    /// Whether this backend can execute `op`. The default consults the
    /// static [`Executor::ops`] list; backends with dynamic op spaces
    /// (sessions) override it.
    fn accepts(&self, op: &Op) -> bool {
        self.ops().iter().any(|o| o == op)
    }
    /// Statically-known operations (for `__ops` diagnostics; routing
    /// goes through [`Executor::accepts`]).
    fn ops(&self) -> Vec<Op>;
}

impl Executor for crate::runtime::EngineHost {
    fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
        match op {
            Op::Artifact(name) => {
                self.run(name, inputs).map_err(|e| LeapError::Backend(format!("{e:#}")))
            }
            other => Err(LeapError::UnknownOp(other.label())),
        }
    }

    fn output_bytes_hint(&self, op: &Op, _input_bytes: usize) -> usize {
        match op {
            Op::Artifact(name) => self
                .shapes(name)
                .map(|(_, outs)| outs.iter().map(|s| s.iter().product::<usize>() * 4).sum())
                .unwrap_or(0),
            _ => 0,
        }
    }

    fn ops(&self) -> Vec<Op> {
        self.entry_names().into_iter().map(|s| Op::Artifact(s.to_string())).collect()
    }
}

/// Native-projector backend: the Rust on-the-fly pairs plus FBP, for the
/// scan described by a [`crate::geometry::config::ScanConfig`]. Holds a
/// [`crate::projector::ProjectionPlan`] so every served projection skips
/// per-view re-planning; plans are shared across executors for the same
/// scan config through the [`plan_cache::global`] cache, and built
/// lazily on the first [`Op::NativeFp`]/[`Op::NativeBp`] request so
/// FBP-only workloads never pay for (or pin) a plan.
pub struct NativeExecutor {
    pub projector: crate::projector::Projector,
    plan: std::sync::OnceLock<Arc<crate::projector::ProjectionPlan>>,
}

impl NativeExecutor {
    /// Build an executor; its plan is fetched from (or planned into) the
    /// process-wide cache on first projection use.
    pub fn new(projector: crate::projector::Projector) -> NativeExecutor {
        NativeExecutor { projector, plan: std::sync::OnceLock::new() }
    }

    /// Build an executor around an explicit plan (e.g. a validated
    /// [`crate::api::Scan`]'s — the session path). Panics if the plan
    /// describes a different scan; callers construct both from one
    /// config, so a mismatch is a programming error, not user input.
    pub fn with_plan(
        projector: crate::projector::Projector,
        plan: Arc<crate::projector::ProjectionPlan>,
    ) -> NativeExecutor {
        assert!(plan.matches(&projector), "plan was built for a different scan");
        let cell = std::sync::OnceLock::new();
        let _ = cell.set(plan);
        NativeExecutor { projector, plan: cell }
    }

    fn plan(&self) -> &Arc<crate::projector::ProjectionPlan> {
        self.plan.get_or_init(|| plan_cache::global().get_or_plan(&self.projector))
    }

    /// The executor's (lazily built, cached) plan, shared — the session
    /// layer binds tape pipelines to exactly this plan so served
    /// gradients match the in-process tape bit for bit.
    pub fn shared_plan(&self) -> Arc<crate::projector::ProjectionPlan> {
        self.plan().clone()
    }

    fn vol_from(&self, buf: &[f32]) -> Result<crate::array::Vol3, LeapError> {
        let vg = &self.projector.vg;
        if buf.len() != vg.num_voxels() {
            return Err(LeapError::ShapeMismatch {
                what: "volume",
                expected: vg.num_voxels(),
                got: buf.len(),
            });
        }
        Ok(crate::array::Vol3::from_vec(vg.nx, vg.ny, vg.nz, buf.to_vec()))
    }

    fn sino_from(&self, buf: &[f32]) -> Result<crate::array::Sino, LeapError> {
        let g = &self.projector.geom;
        let want = g.nviews() * g.nrows() * g.ncols();
        if buf.len() != want {
            return Err(LeapError::ShapeMismatch {
                what: "sinogram",
                expected: want,
                got: buf.len(),
            });
        }
        Ok(crate::array::Sino::from_vec(g.nviews(), g.nrows(), g.ncols(), buf.to_vec()))
    }

    fn first_input<'a>(&self, op: &Op, inputs: &[&'a [f32]]) -> Result<&'a [f32], LeapError> {
        inputs
            .first()
            .copied()
            .ok_or_else(|| LeapError::Protocol(format!("{}: missing input tensor", op.label())))
    }
}

impl Executor for NativeExecutor {
    fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
        match op {
            Op::NativeFp => {
                let vol = self.vol_from(self.first_input(op, inputs)?)?;
                let mut sino = self.projector.new_sino();
                self.projector.forward_with_plan(self.plan(), &vol, &mut sino);
                Ok(vec![sino.data])
            }
            Op::NativeBp => {
                let sino = self.sino_from(self.first_input(op, inputs)?)?;
                let mut vol = self.projector.new_vol();
                self.projector.back_with_plan(self.plan(), &sino, &mut vol);
                Ok(vec![vol.data])
            }
            Op::NativeFbp => {
                let sino = self.sino_from(self.first_input(op, inputs)?)?;
                let vol = match &self.projector.geom {
                    crate::geometry::Geometry::Parallel(g) => crate::recon::fbp_parallel(
                        &self.projector.vg,
                        g,
                        &sino,
                        crate::recon::Window::Hann,
                        self.projector.threads,
                    ),
                    crate::geometry::Geometry::Fan(g) => crate::recon::fbp_fan(
                        &self.projector.vg,
                        g,
                        &sino,
                        crate::recon::Window::Hann,
                        self.projector.threads,
                    ),
                    crate::geometry::Geometry::Cone(g) => crate::recon::fdk(
                        &self.projector.vg,
                        g,
                        &sino,
                        crate::recon::Window::Hann,
                        self.projector.threads,
                    ),
                    crate::geometry::Geometry::Modular(_) => {
                        return Err(LeapError::Unsupported(
                            "fbp is not defined for modular beams".into(),
                        ))
                    }
                };
                Ok(vec![vol.data])
            }
            other => Err(LeapError::UnknownOp(other.label())),
        }
    }

    /// Projector batches execute as **one** batched operator
    /// application: the stacked inputs share one plan fetch and one
    /// worker-pool dispatch ([`crate::ops::LinearOp::apply_batch_into`]
    /// on the cached [`crate::projector::ProjectionPlan`]), instead of
    /// N sequential applies each paying its own dispatch. Outputs are
    /// bit-identical to the sequential path (thread-split invariance),
    /// so batching is purely a throughput decision. Wrong-sized items
    /// fail individually; the rest still run batched.
    fn execute_batch(
        &self,
        op: &Op,
        items: &[Vec<&[f32]>],
    ) -> Vec<Result<Vec<Vec<f32>>, LeapError>> {
        use crate::ops::LinearOp;
        let forward = match op {
            Op::NativeFp => true,
            Op::NativeBp => false,
            // no batched fast path (FBP, unknown ops): per-item execute
            _ => return items.iter().map(|inputs| self.execute(op, inputs)).collect(),
        };
        if items.len() < 2 {
            return items.iter().map(|inputs| self.execute(op, inputs)).collect();
        }
        let plan = self.plan(); // one plan fetch for the whole batch
        let dn = plan.vg().num_voxels();
        let g = plan.geom();
        let rn = g.nviews() * g.nrows() * g.ncols();
        let (in_len, out_len) = if forward { (dn, rn) } else { (rn, dn) };
        let mut results: Vec<Option<Result<Vec<Vec<f32>>, LeapError>>> =
            Vec::with_capacity(items.len());
        let mut stacked: Vec<f32> = Vec::new();
        let mut valid: Vec<usize> = Vec::new();
        for (i, inputs) in items.iter().enumerate() {
            if inputs.is_empty() {
                results.push(Some(Err(LeapError::Protocol(format!(
                    "{}: missing input tensor",
                    op.label()
                )))));
            } else if inputs[0].len() != in_len {
                let what = if forward { "volume" } else { "sinogram" };
                results.push(Some(Err(LeapError::ShapeMismatch {
                    what,
                    expected: in_len,
                    got: inputs[0].len(),
                })));
            } else {
                results.push(None);
                stacked.extend_from_slice(inputs[0]);
                valid.push(i);
            }
        }
        if !valid.is_empty() {
            let mut out = vec![0.0f32; valid.len() * out_len];
            if forward {
                plan.apply_batch_into(valid.len(), &stacked, &mut out);
            } else {
                plan.adjoint_batch_into(valid.len(), &stacked, &mut out);
            }
            drop(stacked); // staging copy released before outputs are carved up
            // carve per-item buffers off the stacked output back to front:
            // each split_off moves one item out and truncates the stack,
            // so peak transient memory is one stacked output + a single
            // item — not a second full copy of every output at once
            for (slot, &i) in valid.iter().enumerate().rev() {
                let buf = out.split_off(slot * out_len);
                results[i] = Some(Ok(vec![buf]));
            }
        }
        results.into_iter().map(|r| r.expect("every batch item resolved")).collect()
    }

    fn output_bytes_hint(&self, op: &Op, input_bytes: usize) -> usize {
        let vol_bytes = self.projector.vg.num_voxels() * 4;
        let g = &self.projector.geom;
        let sino_bytes = g.nviews() * g.nrows() * g.ncols() * 4;
        match op {
            Op::NativeFp => sino_bytes,
            Op::NativeBp | Op::NativeFbp => vol_bytes,
            _ => input_bytes,
        }
    }

    fn ops(&self) -> Vec<Op> {
        vec![Op::NativeFp, Op::NativeBp, Op::NativeFbp]
    }
}

/// Routes each op to the first backend that accepts it — the standard
/// deployment runs the PJRT artifact engine alongside the native
/// executor and the session backend.
pub struct Router {
    backends: Vec<Arc<dyn Executor>>,
}

impl Router {
    pub fn new(backends: Vec<Arc<dyn Executor>>) -> Router {
        Router { backends }
    }

    fn route(&self, op: &Op) -> Option<&Arc<dyn Executor>> {
        self.backends.iter().find(|b| b.accepts(op))
    }
}

impl Executor for Router {
    fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
        match self.route(op) {
            Some(b) => b.execute(op, inputs),
            None => Err(LeapError::UnknownOp(op.label())),
        }
    }

    /// Routed batches stay batched: one route lookup, then the chosen
    /// backend's own `execute_batch` (so the native batched fast path is
    /// reachable behind a router, the standard deployment).
    fn execute_batch(
        &self,
        op: &Op,
        items: &[Vec<&[f32]>],
    ) -> Vec<Result<Vec<Vec<f32>>, LeapError>> {
        match self.route(op) {
            Some(b) => b.execute_batch(op, items),
            None => items.iter().map(|_| Err(LeapError::UnknownOp(op.label()))).collect(),
        }
    }

    fn output_bytes_hint(&self, op: &Op, input_bytes: usize) -> usize {
        self.route(op).map(|b| b.output_bytes_hint(op, input_bytes)).unwrap_or(0)
    }

    fn accepts(&self, op: &Op) -> bool {
        self.route(op).is_some()
    }

    fn ops(&self) -> Vec<Op> {
        let mut out = Vec::new();
        for b in &self.backends {
            out.extend(b.ops());
        }
        out.sort_by_key(|o| o.label());
        out.dedup();
        out
    }
}

struct Job {
    ticket: u64,
    client_id: u64,
    tx: Sender<Response>,
}

struct Inner {
    batcher: Mutex<Batcher>,
    cv: Condvar,
    exec: Arc<dyn Executor>,
    budget: MemoryBudget,
    telemetry: Telemetry,
    pending: Mutex<HashMap<u64, Job>>,
    shutdown: AtomicBool,
    next_ticket: AtomicU64,
    /// Admission cap on the pending queue: [`Coordinator::try_submit`]
    /// sheds (typed `BudgetExceeded`) once this many requests are
    /// already queued. `usize::MAX` = unbounded (the `submit` default).
    max_pending: AtomicUsize,
    /// Observers invoked after every delivered response
    /// ([`Coordinator::add_completion_hook`]): the serving plane's event
    /// loop registers its poll waker here so worker completions
    /// interrupt the poll instead of being discovered by a busy tick.
    /// Held weakly — a dropped server unregisters by dropping the only
    /// strong reference, and dead entries prune on the next completion.
    completion_hooks: Mutex<Vec<Weak<dyn Fn() + Send + Sync>>>,
}

/// The coordinator: owns the queue and `workers` executor threads.
pub struct Coordinator {
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(exec: Arc<dyn Executor>, policy: BatchPolicy, budget_bytes: usize, workers: usize) -> Coordinator {
        let inner = Arc::new(Inner {
            batcher: Mutex::new(Batcher::new(policy)),
            cv: Condvar::new(),
            exec,
            budget: MemoryBudget::new(budget_bytes),
            telemetry: Telemetry::new(),
            pending: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            next_ticket: AtomicU64::new(1),
            max_pending: AtomicUsize::new(usize::MAX),
            completion_hooks: Mutex::new(Vec::new()),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        Coordinator { inner, handles }
    }

    /// Bound the pending queue: [`Self::try_submit`] sheds once `n`
    /// requests are already queued. `submit`/`call` stay unbounded
    /// (in-process callers that prefer backpressure-by-blocking).
    pub fn with_max_pending(self, n: usize) -> Coordinator {
        self.inner.max_pending.store(n.max(1), Ordering::Relaxed);
        self
    }

    /// The configured pending-queue admission cap (`usize::MAX` when
    /// unbounded).
    pub fn max_pending(&self) -> usize {
        self.inner.max_pending.load(Ordering::Relaxed)
    }

    /// Admission-controlled submit: sheds with a typed
    /// [`LeapError::BudgetExceeded`] when the pending queue is at
    /// [`Self::max_pending`], instead of queueing unboundedly. This is
    /// the serving plane's entry point — a shed request never reaches a
    /// worker, costs O(1), and is counted per-op in telemetry so
    /// `__stats` exposes shed rates next to p99 latency. The depth check
    /// and the enqueue are not atomic across callers; a burst may
    /// overshoot the cap by the number of concurrent submitters, which
    /// admission control tolerates (the bound is a scheduling target,
    /// not a safety invariant — memory safety comes from `budget.rs`).
    pub fn try_submit(&self, req: Request) -> Result<Receiver<Response>, LeapError> {
        let cap = self.inner.max_pending.load(Ordering::Relaxed);
        let depth = self.inner.batcher.lock().unwrap().len();
        if depth >= cap {
            self.inner.telemetry.record_shed(&req.op.label());
            return Err(LeapError::BudgetExceeded { needed: depth + 1, cap });
        }
        Ok(self.submit(req))
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        let ticket = self.inner.next_ticket.fetch_add(1, Ordering::Relaxed);
        let job = Job { ticket, client_id: req.id, tx };
        let mut internal = req;
        internal.id = ticket;
        self.inner.pending.lock().unwrap().insert(ticket, job);
        {
            let mut b = self.inner.batcher.lock().unwrap();
            b.push(internal);
        }
        self.inner.cv.notify_one();
        rx
    }

    /// Submit and block for the response.
    pub fn call(&self, req: Request) -> Response {
        self.submit(req).recv().expect("coordinator dropped response")
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.inner.telemetry
    }

    pub fn budget(&self) -> &MemoryBudget {
        &self.inner.budget
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.batcher.lock().unwrap().len()
    }

    pub fn executor(&self) -> &Arc<dyn Executor> {
        &self.inner.exec
    }

    /// Register a completion observer, invoked (from the completing
    /// worker's thread) after each response is delivered to its
    /// channel. The registration is weak: keep the returned hook's only
    /// strong `Arc` alive for as long as notifications are wanted —
    /// dropping it unregisters, and the dead entry prunes on the next
    /// completion. Hooks must be cheap and non-blocking (the serving
    /// plane registers a [`crate::util::netpoll::Waker`] send).
    pub fn add_completion_hook(&self, hook: Weak<dyn Fn() + Send + Sync>) {
        self.inner.completion_hooks.lock().unwrap().push(hook);
    }

    /// Drain the queue and stop the workers.
    pub fn shutdown(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let batch = {
            let mut b = inner.batcher.lock().unwrap();
            loop {
                // work-conserving: an idle worker takes the head batch
                // immediately; batching still forms from backlog (perf
                // pass — removed a fixed max_wait of idle latency)
                if let Some(batch) = b.pop_now() {
                    break Some(batch);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                let timeout = Duration::from_millis(1);
                let (nb, _timed_out) = inner.cv.wait_timeout(b, timeout).unwrap();
                b = nb;
            }
        };
        let Some(batch) = batch else {
            if inner.shutdown.load(Ordering::SeqCst) {
                return;
            }
            continue;
        };
        inner.telemetry.record_batch(&batch.op.label(), batch.len());
        let op = batch.op.clone();
        // pair each live request with its job and budget reservation size
        let mut queue: std::collections::VecDeque<(Job, Request, usize)> = batch
            .requests
            .into_iter()
            .filter_map(|req| {
                let job = inner.pending.lock().unwrap().remove(&req.id)?;
                debug_assert_eq!(job.ticket, req.id);
                let in_bytes = req.input_bytes();
                let out_bytes = inner.exec.output_bytes_hint(&req.op, in_bytes);
                Some((job, req, budget::job_bytes(in_bytes, out_bytes)))
            })
            .collect();
        // Execute the closed batch in admission groups: the head of each
        // group reserves memory with the blocking acquire (preserving
        // backpressure against other workers), followers join with
        // try_acquire only — a follower that doesn't fit starts the next
        // group instead of blocking on memory this same thread holds
        // (which would self-deadlock). Each admitted group runs as ONE
        // execute_batch call: one plan fetch and one pool dispatch over
        // the stacked inputs on the native backend.
        while let Some((job, req, bytes)) = queue.pop_front() {
            if !inner.budget.acquire(bytes) {
                // larger than the whole budget: can never run
                respond(
                    &inner,
                    job,
                    &req,
                    Err(LeapError::BudgetExceeded { needed: bytes, cap: inner.budget.cap() }),
                    0,
                    1,
                );
                continue;
            }
            let mut group = vec![(job, req, bytes)];
            while let Some((_, _, next_bytes)) = queue.front() {
                if inner.budget.try_acquire(*next_bytes) {
                    group.push(queue.pop_front().unwrap());
                } else {
                    break;
                }
            }
            let items: Vec<Vec<&[f32]>> = group
                .iter()
                .map(|(_, req, _)| req.inputs.iter().map(|v| v.as_slice()).collect())
                .collect();
            let exec_start = Instant::now();
            let results = inner.exec.execute_batch(&op, &items);
            let exec_us = exec_start.elapsed().as_micros() as u64;
            drop(items); // releases the borrows into `group` before the move below
            debug_assert_eq!(results.len(), group.len(), "one result per batch item");
            let batch_size = group.len();
            let mut results = results.into_iter();
            for (job, req, bytes) in group {
                inner.budget.release(bytes);
                let result = results.next().unwrap_or_else(|| {
                    Err(LeapError::Backend("backend returned short batch".into()))
                });
                respond(&inner, job, &req, result, exec_us, batch_size);
            }
        }
    }
}

/// Build, record and deliver one request's response.
fn respond(
    inner: &Inner,
    job: Job,
    req: &Request,
    result: Result<Vec<Vec<f32>>, LeapError>,
    exec_us: u64,
    batch_size: usize,
) {
    let latency_us = req.submitted.elapsed().as_micros() as u64;
    let response = match result {
        Ok(outputs) => Response {
            id: job.client_id,
            op: req.op.clone(),
            outputs,
            error: None,
            latency_us,
            exec_us,
            batch_size,
        },
        Err(e) => Response {
            id: job.client_id,
            op: req.op.clone(),
            outputs: vec![],
            error: Some(e),
            latency_us,
            exec_us,
            batch_size,
        },
    };
    inner.telemetry.record(&req.op.label(), latency_us, exec_us, response.ok());
    let _ = job.tx.send(response);
    // notify completion observers AFTER the send: an event loop woken by
    // its hook is guaranteed to find the response already in the channel
    // (its try_recv cannot race ahead of the result). Dead weak entries
    // prune here, so an abandoned hook costs one failed upgrade.
    inner
        .completion_hooks
        .lock()
        .unwrap()
        .retain(|h| match h.upgrade() {
            Some(f) => {
                f();
                true
            }
            None => false,
        });
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Deterministic mock backend: `echo` returns inputs ×2; `fail` errors;
    /// `slow` sleeps then echoes.
    pub struct MockExecutor;

    impl Executor for MockExecutor {
        fn execute(&self, op: &Op, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, LeapError> {
            let Op::Artifact(name) = op else {
                return Err(LeapError::UnknownOp(op.label()));
            };
            match name.as_str() {
                "echo" => Ok(inputs.iter().map(|b| b.iter().map(|&x| 2.0 * x).collect()).collect()),
                "slow" => {
                    std::thread::sleep(Duration::from_millis(5));
                    Ok(inputs.iter().map(|b| b.to_vec()).collect())
                }
                "fail" => Err(LeapError::Backend("mock failure".into())),
                other => Err(LeapError::UnknownOp(other.to_string())),
            }
        }

        fn ops(&self) -> Vec<Op> {
            vec![
                Op::Artifact("echo".into()),
                Op::Artifact("slow".into()),
                Op::Artifact("fail".into()),
            ]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::MockExecutor;
    use super::*;

    fn coord(workers: usize) -> Coordinator {
        Coordinator::new(Arc::new(MockExecutor), BatchPolicy::default(), 1 << 20, workers)
    }

    #[test]
    fn echo_roundtrip() {
        let c = coord(2);
        let resp = c.call(Request::new(42, "echo", vec![vec![1.0, 2.0]]));
        assert_eq!(resp.id, 42);
        assert!(resp.ok());
        assert_eq!(resp.outputs, vec![vec![2.0, 4.0]]);
        assert!(resp.latency_us >= resp.exec_us);
    }

    #[test]
    fn errors_are_reported_not_dropped() {
        let c = coord(1);
        let resp = c.call(Request::new(1, "fail", vec![vec![1.0]]));
        assert!(!resp.ok());
        let err = resp.error.as_ref().unwrap();
        assert!(err.to_string().contains("mock failure"));
        assert_eq!(err.code(), crate::api::codes::BACKEND);
        let resp = c.call(Request::new(2, "nosuch", vec![]));
        assert!(!resp.ok());
        assert!(matches!(resp.error, Some(LeapError::UnknownOp(_))));
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let c = Arc::new(coord(3));
        let mut rxs = Vec::new();
        for i in 0..200u64 {
            let op = if i % 3 == 0 { "slow" } else { "echo" };
            rxs.push((i, c.submit(Request::new(i, op, vec![vec![i as f32]]))));
        }
        for (i, rx) in rxs {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
            assert_eq!(r.id, i);
            assert!(r.ok(), "{i}: {:?}", r.error);
        }
        let snap = c.telemetry().snapshot();
        let total: u64 = snap.values().map(|s| s.count).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn duplicate_client_ids_are_fine() {
        // tickets are internal; two requests with the same client id both
        // get their own response
        let c = coord(2);
        let rx1 = c.submit(Request::new(7, "echo", vec![vec![1.0]]));
        let rx2 = c.submit(Request::new(7, "echo", vec![vec![2.0]]));
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        assert_eq!(r1.id, 7);
        assert_eq!(r2.id, 7);
        let mut firsts = vec![r1.outputs[0][0], r2.outputs[0][0]];
        firsts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(firsts, vec![2.0, 4.0]);
    }

    #[test]
    fn completion_hooks_fire_after_delivery_and_prune_when_dropped() {
        use std::sync::atomic::AtomicUsize;
        let c = coord(2);
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = fired.clone();
        let hook: Arc<dyn Fn() + Send + Sync> = Arc::new(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        c.add_completion_hook(Arc::downgrade(&hook));
        let rx = c.submit(Request::new(1, "echo", vec![vec![1.0]]));
        rx.recv().unwrap();
        // the hook runs after the response send, from the worker thread;
        // the recv above synchronizes with the send but not the hook
        // call, so poll briefly
        let mut n = fired.load(Ordering::SeqCst);
        for _ in 0..200 {
            if n >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            n = fired.load(Ordering::SeqCst);
        }
        assert_eq!(n, 1, "one completion, one notification");
        // errors are completions too
        c.call(Request::new(2, "fail", vec![vec![1.0]]));
        let mut n = fired.load(Ordering::SeqCst);
        for _ in 0..200 {
            if n >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            n = fired.load(Ordering::SeqCst);
        }
        assert_eq!(n, 2, "failed requests still notify");
        // dropping the strong Arc unregisters: no further notifications
        drop(hook);
        c.call(Request::new(3, "echo", vec![vec![1.0]]));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(fired.load(Ordering::SeqCst), 2, "dropped hooks must not fire");
    }

    #[test]
    fn oversized_job_rejected_gracefully() {
        let tiny = Coordinator::new(Arc::new(MockExecutor), BatchPolicy::default(), 64, 1);
        let resp = tiny.call(Request::new(1, "echo", vec![vec![0.0; 1000]]));
        assert!(!resp.ok());
        let err = resp.error.as_ref().unwrap();
        assert!(matches!(err, LeapError::BudgetExceeded { .. }), "{err:?}");
        assert!(err.to_string().contains("memory budget"));
    }

    #[test]
    fn batching_recorded_in_telemetry() {
        let c = Coordinator::new(
            Arc::new(MockExecutor),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
            1 << 20,
            1,
        );
        let rxs: Vec<_> = (0..8).map(|i| c.submit(Request::new(i, "echo", vec![vec![1.0]]))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let snap = c.telemetry().snapshot();
        assert!(snap["echo"].mean_batch() > 1.0, "batches formed: {:?}", snap["echo"]);
    }

    #[test]
    fn native_execute_batch_is_bit_identical_to_sequential() {
        use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
        use crate::projector::{Model, Projector};
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 18, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF).with_threads(2);
        let exec = NativeExecutor::new(p);
        let mut rng = crate::util::rng::Rng::new(31);
        let vols: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut v = vec![0.0f32; vg.num_voxels()];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut items: Vec<Vec<&[f32]>> = vols.iter().map(|v| vec![v.as_slice()]).collect();
        // one bad item must fail alone without sinking the batch
        let bad = vec![1.0f32; 3];
        items.insert(1, vec![bad.as_slice()]);
        let results = exec.execute_batch(&Op::NativeFp, &items);
        assert_eq!(results.len(), 4);
        let err = results[1].as_ref().unwrap_err();
        assert!(
            matches!(err, LeapError::ShapeMismatch { what: "volume", .. }),
            "wrong-sized item must fail alone with a typed error: {err:?}"
        );
        for (slot, i) in [(0usize, 0usize), (2, 1), (3, 2)] {
            let batched = results[slot].as_ref().unwrap();
            let single = exec.execute(&Op::NativeFp, &[&vols[i]]).unwrap();
            assert_eq!(batched[0], single[0], "item {i}");
        }
        // and the matched adjoint batches identically
        let sino_len = 8 * 18;
        let sinos: Vec<Vec<f32>> = (0..2)
            .map(|_| {
                let mut s = vec![0.0f32; sino_len];
                rng.fill_uniform(&mut s, 0.0, 1.0);
                s
            })
            .collect();
        let bp_items: Vec<Vec<&[f32]>> = sinos.iter().map(|s| vec![s.as_slice()]).collect();
        let bp = exec.execute_batch(&Op::NativeBp, &bp_items);
        for (i, r) in bp.iter().enumerate() {
            let single = exec.execute(&Op::NativeBp, &[&sinos[i]]).unwrap();
            assert_eq!(r.as_ref().unwrap()[0], single[0], "bp item {i}");
        }
    }

    #[test]
    fn coordinator_batches_native_requests() {
        use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
        use crate::projector::{Model, Projector};
        let vg = VolumeGeometry::slice2d(24, 24, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(16, 32, 1.0));
        let p = Projector::new(g, vg.clone(), Model::SF).with_threads(2);
        let reference = {
            let plan = p.plan();
            let mut vol = p.new_vol();
            vol.fill(0.01);
            plan.forward(&vol).data
        };
        let exec = Arc::new(NativeExecutor::new(p));
        // one worker + queued backlog: after the first pop the remaining
        // requests close into multi-request batches
        let c = Coordinator::new(
            exec,
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) },
            1 << 28,
            1,
        );
        let vol = vec![0.01f32; vg.num_voxels()];
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(Request::new(i, "native_fp", vec![vol.clone()]))).collect();
        let mut max_batch_seen = 0usize;
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.ok(), "{:?}", r.error);
            assert_eq!(r.outputs[0], reference, "batched output must match the plan path");
            max_batch_seen = max_batch_seen.max(r.batch_size);
        }
        let snap = c.telemetry().snapshot();
        assert!(
            snap["native_fp"].mean_batch() > 1.0,
            "batches formed: {:?}",
            snap["native_fp"]
        );
        assert!(max_batch_seen > 1, "at least one multi-request batched execution");
    }

    #[test]
    fn router_routes_session_ops_dynamically() {
        use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
        use crate::geometry::config::ScanConfig;
        let session_exec = Arc::new(SessionExecutor::new());
        let registry = session_exec.registry_arc();
        let router = Router::new(vec![Arc::new(MockExecutor) as Arc<dyn Executor>, session_exec]);
        let cfg = ScanConfig {
            geometry: Geometry::Parallel(ParallelBeam::standard_2d(6, 10, 1.0)),
            volume: VolumeGeometry::slice2d(8, 8, 1.0),
        };
        let id = registry.open(&cfg, crate::projector::Model::SF, Some(1)).unwrap();
        assert!(router.accepts(&Op::SessionFp(id)));
        let vol = vec![0.5f32; 64];
        let out = router.execute(&Op::SessionFp(id), &[&vol]).unwrap();
        assert_eq!(out[0].len(), 60);
        // still routes the mock's artifact ops
        assert!(router.accepts(&Op::Artifact("echo".into())));
        // and unknown ops stay typed
        let e = router.execute(&Op::Artifact("warp".into()), &[&vol]).unwrap_err();
        assert!(matches!(e, LeapError::UnknownOp(_)));
        registry.close(id);
    }

    #[test]
    fn try_submit_sheds_at_the_pending_cap_and_recovers() {
        let c = Coordinator::new(Arc::new(MockExecutor), BatchPolicy::default(), 1 << 20, 1)
            .with_max_pending(2);
        assert_eq!(c.max_pending(), 2);
        // saturate: one slow request occupies the worker, then fill the
        // pending queue past the cap — later try_submits must shed with
        // the typed admission error, not block or queue
        let mut live = Vec::new();
        let mut shed = 0usize;
        for i in 0..50u64 {
            match c.try_submit(Request::new(i, "slow", vec![vec![i as f32]])) {
                Ok(rx) => live.push((i, rx)),
                Err(e) => {
                    assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "cap 2 with 50 fast submits must shed some");
        // every admitted request still completes normally
        for (i, rx) in live {
            let r = rx.recv_timeout(Duration::from_secs(30)).expect("admitted response");
            assert_eq!(r.id, i);
            assert!(r.ok(), "{i}: {:?}", r.error);
        }
        // drained: admission reopens
        let rx = c.try_submit(Request::new(99, "echo", vec![vec![1.0]])).expect("recovered");
        assert!(rx.recv().unwrap().ok());
        // and the sheds were counted per-op
        assert_eq!(c.telemetry().snapshot()["slow"].shed as usize, shed);
    }

    #[test]
    fn shutdown_drains_queue() {
        let c = coord(1);
        let rxs: Vec<_> = (0..20).map(|i| c.submit(Request::new(i, "echo", vec![vec![1.0]]))).collect();
        c.shutdown();
        for rx in rxs {
            assert!(rx.try_recv().is_ok() || rx.recv_timeout(Duration::from_secs(1)).is_ok());
        }
    }
}
