//! Protocol v2: length-prefixed binary frames with raw f32 payloads.
//!
//! Protocol v1 serializes every f32 as decimal JSON text (~8 bytes +
//! parse cost per sample) and re-states the full request envelope each
//! time. v2 frames carry tensors as raw little-endian f32 — zero text
//! overhead, `memcpy`-decodable — next to a small JSON meta header for
//! the fields that are genuinely structural (op name, session id,
//! telemetry). The layout (full spec: `docs/PROTOCOL.md`):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "LEAP" (0x4C 0x45 0x41 0x50)
//! 4       1     protocol version (= 2)
//! 5       1     frame kind (FrameKind)
//! 6       2     reserved, must be 0
//! 8       8     id (u64 LE): request id / session id, kind-dependent
//! 16      4     meta_len (u32 LE): JSON meta bytes
//! 20      4     payload_len (u32 LE): tensor bytes, must be % 4 == 0
//! 24      meta_len     UTF-8 JSON meta object ({} allowed)
//! 24+m    payload_len  raw little-endian f32 tensor data
//! ```
//!
//! Both ends validate every field before trusting any length: bad magic,
//! unsupported version, unknown kind, misaligned or oversized lengths
//! and truncated streams all surface as typed [`LeapError`]s
//! ([`LeapError::Protocol`] / [`LeapError::VersionMismatch`]) — never a
//! panic, never an over-allocation. A v1 (line-delimited JSON) client on
//! the same port keeps working: the server sniffs the first byte of a
//! connection (`{` starts JSON, `L` starts a frame) — see
//! [`super::server`].
//!
//! The cluster's shard channel ([`crate::cluster`]) speaks these same
//! frames with **append-only meta keys** (no new kinds, no layout
//! change): worker registration/heartbeats are `Hello` frames with
//! `"role"`/`"hb"` meta, and shard tasks are `Request` frames whose
//! meta is the `OpenSession` scan meta plus `"shard"`/`"u0"`/`"u1"` —
//! see `docs/PROTOCOL.md` § "Shard channel".

use std::io::{Read, Write};

use crate::api::LeapError;
use crate::util::json::{parse, Json};

/// Frame magic: "LEAP".
pub const MAGIC: [u8; 4] = *b"LEAP";
/// The protocol version this build speaks.
pub const VERSION: u8 = 2;
/// Fixed header bytes before meta/payload.
pub const HEADER_BYTES: usize = 24;
/// Upper bound on the JSON meta section (scan configs are small; a
/// modular geometry with thousands of per-view poses still fits).
pub const MAX_META_BYTES: usize = 16 << 20;
/// Upper bound on a tensor payload (1 GiB ≈ a 16k² f32 slice stack).
pub const MAX_PAYLOAD_BYTES: usize = 1 << 30;

/// What a frame means. The numeric value is the wire byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Version negotiation; first frame in each direction.
    Hello = 0,
    /// Register a scan config; reply carries the session id.
    OpenSession = 1,
    /// Execute an op (`id` = client request id, echoed on the reply).
    Request = 2,
    /// Successful result (payload = output tensor).
    Response = 3,
    /// Typed failure (meta: `code`, `error`).
    Error = 4,
    /// Release a session (`id` = session id).
    CloseSession = 5,
    /// Register a tape pipeline on an open session (`id` = session id,
    /// meta: `{"pipeline": <tape spec>}`, see `docs/AUTODIFF.md`). The
    /// reply's `id` is the new pipeline id, scoped to the session.
    /// Added after the v2 launch: kinds are append-only, and a pre-tape
    /// peer rejects an unknown kind with a typed protocol error rather
    /// than misparsing the stream.
    RegisterPipeline = 6,
}

impl FrameKind {
    fn from_byte(b: u8) -> Option<FrameKind> {
        match b {
            0 => Some(FrameKind::Hello),
            1 => Some(FrameKind::OpenSession),
            2 => Some(FrameKind::Request),
            3 => Some(FrameKind::Response),
            4 => Some(FrameKind::Error),
            5 => Some(FrameKind::CloseSession),
            6 => Some(FrameKind::RegisterPipeline),
            _ => None,
        }
    }
}

/// One protocol-v2 frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    /// Request id (Request/Response/Error) or session id
    /// (OpenSession reply / CloseSession).
    pub id: u64,
    /// Structural fields (op name, session id, config, telemetry).
    pub meta: Json,
    /// The tensor, bit-exact.
    pub payload: Vec<f32>,
}

impl Frame {
    pub fn new(kind: FrameKind, id: u64, meta: Json, payload: Vec<f32>) -> Frame {
        Frame { kind, id, meta, payload }
    }

    /// A typed error reply for `id`.
    pub fn error(id: u64, err: &LeapError) -> Frame {
        Frame::new(
            FrameKind::Error,
            id,
            Json::obj(vec![
                ("code", Json::Num(err.code() as f64)),
                ("error", Json::Str(err.to_string())),
            ]),
            Vec::new(),
        )
    }

    /// Reconstruct the typed error carried by an Error frame.
    pub fn to_error(&self) -> LeapError {
        let code = self.meta.get_f64("code").unwrap_or(0.0) as u16;
        let msg = self.meta.get_str("error").unwrap_or("unspecified remote error").to_string();
        LeapError::from_wire(code, msg)
    }
}

/// Serialize a frame from borrowed parts — the payload is read straight
/// from the caller's slice, so senders (notably [`super::server::BinaryClient`])
/// never copy a tensor into an owned [`Frame`] just to put it on the
/// wire. Rejects parts whose meta or payload exceed the wire caps
/// *before* writing anything — a payload at or beyond 4 GiB would
/// otherwise silently truncate in the u32 length field and
/// desynchronize the stream.
pub fn encode_frame_parts(
    kind: FrameKind,
    id: u64,
    meta: &Json,
    payload: &[f32],
) -> Result<Vec<u8>, LeapError> {
    let payload_bytes = payload
        .len()
        .checked_mul(4)
        .filter(|&b| b <= MAX_PAYLOAD_BYTES)
        .ok_or_else(|| {
            LeapError::Protocol(format!(
                "payload too large to frame ({} samples > {} byte cap)",
                payload.len(),
                MAX_PAYLOAD_BYTES
            ))
        })?;
    let meta = match meta {
        Json::Null => String::new(),
        other => other.to_string(),
    };
    if meta.len() > MAX_META_BYTES {
        return Err(LeapError::Protocol(format!(
            "meta too large to frame ({} > {MAX_META_BYTES} bytes)",
            meta.len()
        )));
    }
    let mut out = Vec::with_capacity(HEADER_BYTES + meta.len() + payload_bytes);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&[0u8; 2]); // reserved
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload_bytes as u32).to_le_bytes());
    out.extend_from_slice(meta.as_bytes());
    for v in payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Serialize an owned frame to bytes (see [`encode_frame_parts`]).
pub fn encode_frame(f: &Frame) -> Result<Vec<u8>, LeapError> {
    encode_frame_parts(f.kind, f.id, &f.meta, &f.payload)
}

/// Parse one frame from a byte buffer that contains exactly one frame.
pub fn decode_frame(bytes: &[u8]) -> Result<Frame, LeapError> {
    let mut cursor = bytes;
    let frame = read_frame(&mut cursor)?
        .ok_or_else(|| LeapError::Protocol("empty frame buffer".into()))?;
    if !cursor.is_empty() {
        return Err(LeapError::Protocol(format!(
            "{} trailing bytes after frame",
            cursor.len()
        )));
    }
    Ok(frame)
}

fn read_exact_or(
    r: &mut impl Read,
    buf: &mut [u8],
    what: &str,
) -> Result<(), LeapError> {
    r.read_exact(buf)
        .map_err(|e| LeapError::Protocol(format!("truncated frame ({what}): {e}")))
}

/// Validate a complete fixed header and extract its fields as
/// `(kind, id, meta_len, payload_len)` — the single definition of
/// header trust shared by the blocking reader ([`read_frame`]) and the
/// incremental decoder ([`decode_frame_bytes`]), so the two paths can
/// never diverge on what they reject.
fn validate_header(header: &[u8; HEADER_BYTES]) -> Result<(FrameKind, u64, usize, usize), LeapError> {
    if header[0..4] != MAGIC {
        return Err(LeapError::Protocol(format!(
            "bad frame magic {:02x}{:02x}{:02x}{:02x} (expected \"LEAP\")",
            header[0], header[1], header[2], header[3]
        )));
    }
    if header[4] != VERSION {
        return Err(LeapError::VersionMismatch { got: header[4], want: VERSION });
    }
    let kind = FrameKind::from_byte(header[5])
        .ok_or_else(|| LeapError::Protocol(format!("unknown frame kind {}", header[5])))?;
    if header[6] != 0 || header[7] != 0 {
        return Err(LeapError::Protocol("reserved header bytes must be zero".into()));
    }
    let id = u64::from_le_bytes(header[8..16].try_into().expect("8 header bytes"));
    let meta_len = u32::from_le_bytes(header[16..20].try_into().expect("4 header bytes")) as usize;
    let payload_len =
        u32::from_le_bytes(header[20..24].try_into().expect("4 header bytes")) as usize;
    if meta_len > MAX_META_BYTES {
        return Err(LeapError::Protocol(format!(
            "meta section too large ({meta_len} > {MAX_META_BYTES} bytes)"
        )));
    }
    if payload_len > MAX_PAYLOAD_BYTES {
        return Err(LeapError::Protocol(format!(
            "payload too large ({payload_len} > {MAX_PAYLOAD_BYTES} bytes)"
        )));
    }
    if payload_len % 4 != 0 {
        return Err(LeapError::Protocol(format!(
            "payload length {payload_len} is not a multiple of 4 (f32 tensor)"
        )));
    }
    Ok((kind, id, meta_len, payload_len))
}

fn parse_meta(meta_bytes: &[u8]) -> Result<Json, LeapError> {
    if meta_bytes.is_empty() {
        return Ok(Json::Null);
    }
    let text = std::str::from_utf8(meta_bytes)
        .map_err(|e| LeapError::Protocol(format!("meta is not utf-8: {e}")))?;
    parse(text).map_err(|e| LeapError::Protocol(format!("bad meta json: {e}")))
}

fn parse_payload(payload_bytes: &[u8]) -> Vec<f32> {
    payload_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
        .collect()
}

/// Incremental (non-blocking) frame decoder for reassembly buffers:
/// given however many bytes have arrived so far, returns
/// `Ok(Some((frame, consumed)))` when a complete frame is at the front
/// (the caller drains `consumed` bytes), `Ok(None)` when more bytes are
/// needed, and a typed error as soon as the prefix can *never* become a
/// valid frame — bad magic fails on the first wrong byte and a bad
/// version/kind/length fails from the header alone, so a desynced or
/// malicious stream is rejected without waiting for (or allocating) the
/// lengths it claims. This is the event-loop counterpart of
/// [`read_frame`]; both trust headers through the same validation.
pub fn decode_frame_bytes(buf: &[u8]) -> Result<Option<(Frame, usize)>, LeapError> {
    let n = buf.len().min(MAGIC.len());
    if buf[..n] != MAGIC[..n] {
        return Err(LeapError::Protocol(format!(
            "bad frame magic prefix {:02x?} (expected \"LEAP\")",
            &buf[..n]
        )));
    }
    if buf.len() < HEADER_BYTES {
        return Ok(None);
    }
    let header: &[u8; HEADER_BYTES] =
        buf[..HEADER_BYTES].try_into().expect("sliced to HEADER_BYTES");
    let (kind, id, meta_len, payload_len) = validate_header(header)?;
    let total = HEADER_BYTES + meta_len + payload_len;
    if buf.len() < total {
        return Ok(None);
    }
    let meta = parse_meta(&buf[HEADER_BYTES..HEADER_BYTES + meta_len])?;
    let payload = parse_payload(&buf[HEADER_BYTES + meta_len..total]);
    Ok(Some((Frame { kind, id, meta, payload }, total)))
}

/// Read one frame from a stream. Returns `Ok(None)` on a clean
/// end-of-stream (no bytes at all); a stream that ends mid-frame is a
/// typed [`LeapError::Protocol`]; a frame with the wrong version byte is
/// [`LeapError::VersionMismatch`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, LeapError> {
    let mut header = [0u8; HEADER_BYTES];
    // distinguish clean EOF (no frame) from truncation (partial header)
    let mut got = 0usize;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(LeapError::Protocol(format!(
                    "truncated frame (header: {got}/{HEADER_BYTES} bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(LeapError::Io(e.to_string())),
        }
    }
    let (kind, id, meta_len, payload_len) = validate_header(&header)?;
    let mut meta_bytes = vec![0u8; meta_len];
    read_exact_or(r, &mut meta_bytes, "meta")?;
    let meta = parse_meta(&meta_bytes)?;
    let mut payload_bytes = vec![0u8; payload_len];
    read_exact_or(r, &mut payload_bytes, "payload")?;
    let payload = parse_payload(&payload_bytes);
    Ok(Some(Frame { kind, id, meta, payload }))
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), LeapError> {
    w.write_all(&encode_frame(f)?)?;
    w.flush()?;
    Ok(())
}

/// Write a frame assembled from borrowed parts — the copy-free send
/// path (see [`encode_frame_parts`]).
pub fn write_frame_parts(
    w: &mut impl Write,
    kind: FrameKind,
    id: u64,
    meta: &Json,
    payload: &[f32],
) -> Result<(), LeapError> {
    w.write_all(&encode_frame_parts(kind, id, meta, payload)?)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;


    fn encode_frame_ok(f: &Frame) -> Vec<u8> {
        encode_frame(f).unwrap()
    }
    fn sample_frame(n: usize, seed: u64) -> Frame {
        let mut rng = Rng::new(seed);
        let mut payload = vec![0.0f32; n];
        rng.fill_uniform(&mut payload, -10.0, 10.0);
        Frame::new(
            FrameKind::Request,
            0xDEAD_BEEF_0000_0001,
            Json::obj(vec![("op", Json::Str("fp".into())), ("session", Json::Num(7.0))]),
            payload,
        )
    }

    #[test]
    fn roundtrip_is_bit_identical_for_odd_sizes() {
        for (i, n) in [0usize, 1, 3, 5, 17, 31, 1023].into_iter().enumerate() {
            let f = sample_frame(n, 100 + i as u64);
            let back = decode_frame(&encode_frame(&f).unwrap()).unwrap();
            assert_eq!(back.kind, f.kind);
            assert_eq!(back.id, f.id);
            assert_eq!(back.meta, f.meta);
            let a: Vec<u32> = f.payload.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = back.payload.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "payload bits must survive, n={n}");
        }
    }

    #[test]
    fn arbitrary_bit_patterns_survive() {
        // NaNs, infinities, denormals: the payload is bits, not numbers
        let payload: Vec<f32> = [0x7fc00001u32, 0x7f800000, 0xff800000, 0x00000001, 0x80000000]
            .iter()
            .map(|&b| f32::from_bits(b))
            .collect();
        let f = Frame::new(FrameKind::Response, 3, Json::Null, payload.clone());
        let back = decode_frame(&encode_frame(&f).unwrap()).unwrap();
        for (a, b) in payload.iter().zip(back.payload.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn truncation_anywhere_is_a_protocol_error_not_a_panic() {
        let bytes = encode_frame_ok(&sample_frame(9, 5));
        for cut in [0usize, 1, 7, HEADER_BYTES - 1, HEADER_BYTES + 3, bytes.len() - 1] {
            let r = decode_frame(&bytes[..cut]);
            assert!(matches!(r, Err(LeapError::Protocol(_))), "cut {cut}: {r:?}");
        }
    }

    #[test]
    fn bad_magic_and_kind_and_reserved_are_rejected() {
        let mut bytes = encode_frame_ok(&sample_frame(2, 6));
        bytes[0] = b'X';
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));

        let mut bytes = encode_frame_ok(&sample_frame(2, 6));
        bytes[5] = 250; // unknown kind
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));

        let mut bytes = encode_frame_ok(&sample_frame(2, 6));
        bytes[6] = 1; // reserved must be zero
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));
    }

    #[test]
    fn version_mismatch_is_its_own_typed_error() {
        let mut bytes = encode_frame_ok(&sample_frame(2, 7));
        bytes[4] = 3;
        let e = decode_frame(&bytes).unwrap_err();
        assert_eq!(e, LeapError::VersionMismatch { got: 3, want: VERSION });
        assert_eq!(e.code(), crate::api::codes::VERSION_MISMATCH);
    }

    #[test]
    fn misaligned_and_oversized_lengths_are_rejected_before_allocation() {
        // payload_len = 6 (not % 4)
        let mut bytes = encode_frame_ok(&sample_frame(2, 8));
        bytes[20..24].copy_from_slice(&6u32.to_le_bytes());
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));

        // payload_len beyond the cap: rejected from the header alone
        let mut bytes = encode_frame_ok(&sample_frame(0, 9));
        bytes[20..24].copy_from_slice(&(u32::MAX / 4 * 4).to_le_bytes());
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));

        // meta_len beyond the cap
        let mut bytes = encode_frame_ok(&sample_frame(0, 10));
        bytes[16..20].copy_from_slice(&(MAX_META_BYTES as u32 + 1).to_le_bytes());
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));
    }

    #[test]
    fn bad_meta_json_is_a_protocol_error() {
        let f = Frame::new(FrameKind::Request, 1, Json::Str("x".into()), vec![]);
        let mut bytes = encode_frame(&f).unwrap();
        // corrupt the meta text ("x" → \x01x)
        let meta_at = HEADER_BYTES;
        bytes[meta_at] = 1;
        assert!(matches!(decode_frame(&bytes), Err(LeapError::Protocol(_))));
    }

    #[test]
    fn error_frames_carry_typed_codes() {
        let e = LeapError::ShapeMismatch { what: "volume", expected: 100, got: 3 };
        let f = Frame::error(9, &e);
        let back = decode_frame(&encode_frame(&f).unwrap()).unwrap();
        assert_eq!(back.kind, FrameKind::Error);
        let typed = back.to_error();
        assert_eq!(typed.code(), crate::api::codes::SHAPE_MISMATCH);
        assert!(typed.to_string().contains("volume"));
    }

    #[test]
    fn incremental_decode_over_byte_dribble_matches_blocking_reader() {
        let f = sample_frame(17, 13);
        let bytes = encode_frame_ok(&f);
        // feed one byte at a time: every proper prefix is Ok(None), the
        // full buffer yields the frame with the exact consumed count
        for cut in 0..bytes.len() {
            let r = decode_frame_bytes(&bytes[..cut]).unwrap();
            assert!(r.is_none(), "prefix of {cut} bytes must ask for more");
        }
        let (back, consumed) = decode_frame_bytes(&bytes).unwrap().unwrap();
        assert_eq!(consumed, bytes.len());
        assert_eq!(back, f);
    }

    #[test]
    fn incremental_decode_consumes_one_frame_and_reports_its_length() {
        let a = sample_frame(4, 14);
        let b = sample_frame(9, 15);
        let mut bytes = encode_frame_ok(&a);
        let a_len = bytes.len();
        bytes.extend_from_slice(&encode_frame_ok(&b));
        let (first, consumed) = decode_frame_bytes(&bytes).unwrap().unwrap();
        assert_eq!(first, a);
        assert_eq!(consumed, a_len);
        let (second, consumed2) = decode_frame_bytes(&bytes[consumed..]).unwrap().unwrap();
        assert_eq!(second, b);
        assert_eq!(consumed + consumed2, bytes.len());
    }

    #[test]
    fn incremental_decode_fails_fast_on_bad_prefixes() {
        // wrong very first byte: rejected without waiting for a header
        assert!(matches!(decode_frame_bytes(b"X"), Err(LeapError::Protocol(_))));
        // JSON on a binary reassembly path: same
        assert!(matches!(decode_frame_bytes(b"{\"op\""), Err(LeapError::Protocol(_))));
        // bad version: rejected from the complete header alone, typed
        let mut bytes = encode_frame_ok(&sample_frame(2, 16));
        bytes[4] = 9;
        let e = decode_frame_bytes(&bytes[..HEADER_BYTES]).unwrap_err();
        assert_eq!(e, LeapError::VersionMismatch { got: 9, want: VERSION });
        // oversized payload length: rejected before any allocation
        let mut bytes = encode_frame_ok(&sample_frame(0, 17));
        bytes[20..24].copy_from_slice(&(u32::MAX / 4 * 4).to_le_bytes());
        assert!(matches!(
            decode_frame_bytes(&bytes[..HEADER_BYTES]),
            Err(LeapError::Protocol(_))
        ));
        // empty buffer just wants more bytes
        assert!(decode_frame_bytes(&[]).unwrap().is_none());
    }

    #[test]
    fn stream_reads_multiple_frames_then_clean_eof() {
        let mut bytes = encode_frame_ok(&sample_frame(4, 11));
        bytes.extend_from_slice(&encode_frame(&sample_frame(7, 12)).unwrap());
        let mut cursor: &[u8] = &bytes;
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap().payload.len(), 4);
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap().payload.len(), 7);
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF");
    }
}
