//! JSON wire form of a [`Pipeline`] — how a client registers a graph on
//! a protocol-v2 session (`RegisterPipeline` frame, see
//! `docs/PROTOCOL.md`).
//!
//! The spec carries the *structure* only: node list (topological, ids =
//! positions), input-slot shapes, parameter names/shapes, the output
//! and loss designations. Parameter **values** never travel in the
//! spec — every `SessionPipelineGrad` request carries its current
//! parameters in the packed payload ([`Pipeline::pack`]), keeping the
//! server stateless about training progress. Operators are referenced
//! by name; the serving side resolves `"scan"` to the session's pinned
//! plan, so a registered pipeline is evaluated against exactly the
//! floats the in-process tape would use — bit-identical results.
//!
//! Every field is validated with typed [`LeapError`]s (malformed spec →
//! [`LeapError::Protocol`], unknown op name → [`LeapError::Unsupported`],
//! shape violations → the builder's own errors). The node/element caps
//! in [`super::build`] bound individual nodes; the serving registry
//! additionally gates the **cumulative** evaluation footprint
//! ([`Pipeline::eval_bytes_estimate`] vs
//! `coordinator::session::SESSION_MAX_BYTES`) so a hostile spec cannot
//! stack many maximal nodes into an OOM at evaluation time.

use std::sync::Arc;

use crate::api::LeapError;
use crate::ops::{LinearOp, Shape};
use crate::util::json::Json;

use super::{NodeKind, Pipeline, PipelineBuilder};

/// Spec format version (append-only evolution, like the wire codes).
/// v1: the elementwise/filter/loss kinds. v2 appends the neural node
/// kinds (`conv2d`, `conv3d`, `avg_pool`, `upsample`, `residual`).
/// Emission always uses the current version; parsing accepts
/// [`MIN_SPEC_VERSION`]`..=`[`SPEC_VERSION`] — a v1 spec is a valid v2
/// spec that happens to use no neural nodes, so old clients keep
/// working against new servers.
pub const SPEC_VERSION: usize = 2;

/// Oldest spec version this build still parses (see [`SPEC_VERSION`]).
pub const MIN_SPEC_VERSION: usize = 1;

/// Cap on the total element count of a spec's **leaves** (params +
/// inputs), enforced while parsing — i.e. before any placeholder is
/// allocated from untrusted shapes. Equals the wire payload cap in
/// f32s: a pipeline over this limit could never receive its packed
/// request in one frame anyway, so nothing legitimate is lost.
pub const MAX_PACKED_ELEMENTS: usize = 1 << 28;

fn shape_to_json(s: Shape) -> Json {
    Json::Arr(s.0.iter().map(|&d| Json::Num(d as f64)).collect())
}

/// Parse and *bound* a shape from untrusted JSON: dimensions must be
/// numbers whose product neither overflows (`checked_mul`) nor exceeds
/// [`super::build::MAX_NODE_ELEMENTS`]. This runs before anything is
/// allocated from the shape — a spec declaring a petabyte tensor is a
/// typed error, not an allocation abort.
fn shape_from_json(v: &Json) -> Result<Shape, LeapError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| LeapError::Protocol("shape must be an array".into()))?;
    if arr.len() != 3 {
        return Err(LeapError::Protocol(format!(
            "shape must have 3 dimensions, got {}",
            arr.len()
        )));
    }
    let mut dims = [0usize; 3];
    for (i, d) in arr.iter().enumerate() {
        dims[i] = d
            .as_usize()
            .ok_or_else(|| LeapError::Protocol(format!("shape dimension {i} must be a number")))?;
    }
    dims[0]
        .checked_mul(dims[1])
        .and_then(|p| p.checked_mul(dims[2]))
        .filter(|&n| n <= super::build::MAX_NODE_ELEMENTS)
        .ok_or_else(|| {
            LeapError::InvalidArgument(format!(
                "shape {dims:?} overflows or exceeds {} elements",
                super::build::MAX_NODE_ELEMENTS
            ))
        })?;
    Ok(Shape(dims))
}

fn get_node_id(v: &Json, key: &str) -> Result<usize, LeapError> {
    v.get_usize(key)
        .ok_or_else(|| LeapError::Protocol(format!("node missing {key:?} id")))
}

fn get_f32(v: &Json, key: &str) -> Result<f32, LeapError> {
    v.get_f64(key)
        .map(|f| f as f32)
        .ok_or_else(|| LeapError::Protocol(format!("node missing {key:?} value")))
}

/// Serialize a pipeline's structure (see the module docs). The inverse
/// of [`pipeline_from_json`] up to operator rebinding.
pub fn pipeline_to_json(p: &Pipeline) -> Json {
    let inputs = Json::Arr(p.input_shapes.iter().map(|&s| shape_to_json(s)).collect());
    let params = Json::Arr(
        p.params
            .iter()
            .map(|pd| {
                Json::obj(vec![
                    ("name", Json::Str(pd.name.clone())),
                    ("shape", shape_to_json(pd.shape)),
                ])
            })
            .collect(),
    );
    let nodes = Json::Arr(
        p.nodes
            .iter()
            .map(|n| {
                let mut f: Vec<(&str, Json)> = Vec::new();
                match &n.kind {
                    NodeKind::Input { slot } => {
                        f.push(("k", Json::Str("input".into())));
                        f.push(("slot", Json::Num(*slot as f64)));
                    }
                    NodeKind::Param { pid } => {
                        f.push(("k", Json::Str("param".into())));
                        f.push(("p", Json::Num(*pid as f64)));
                    }
                    NodeKind::Fill { v } => {
                        f.push(("k", Json::Str("fill".into())));
                        f.push(("shape", shape_to_json(n.shape)));
                        f.push(("v", Json::Num(*v as f64)));
                    }
                    NodeKind::Apply { op, x } => {
                        f.push(("k", Json::Str("apply".into())));
                        f.push(("op", Json::Str(p.ops[*op].name.clone())));
                        f.push(("x", Json::Num(x.0 as f64)));
                    }
                    NodeKind::Adjoint { op, y } => {
                        f.push(("k", Json::Str("adjoint".into())));
                        f.push(("op", Json::Str(p.ops[*op].name.clone())));
                        f.push(("y", Json::Num(y.0 as f64)));
                    }
                    NodeKind::Add { a, b } => {
                        f.push(("k", Json::Str("add".into())));
                        f.push(("a", Json::Num(a.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                    }
                    NodeKind::Sub { a, b } => {
                        f.push(("k", Json::Str("sub".into())));
                        f.push(("a", Json::Num(a.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                    }
                    NodeKind::Mul { a, b } => {
                        f.push(("k", Json::Str("mul".into())));
                        f.push(("a", Json::Num(a.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                    }
                    NodeKind::Scale { x, s } => {
                        f.push(("k", Json::Str("scale".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("s", Json::Num(s.0 as f64)));
                    }
                    NodeKind::Relu { x } => {
                        f.push(("k", Json::Str("relu".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                    }
                    NodeKind::Clamp { x, lo, hi } => {
                        f.push(("k", Json::Str("clamp".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("lo", Json::Num(*lo as f64)));
                        f.push(("hi", Json::Num(*hi as f64)));
                    }
                    NodeKind::FilterRows { x, w, .. } => {
                        f.push(("k", Json::Str("filter_rows".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("w", Json::Num(w.0 as f64)));
                    }
                    NodeKind::Conv2d { x, w, b, .. } => {
                        // k is structural (derived from the weight
                        // node's shape on rebuild) — it never travels
                        f.push(("k", Json::Str("conv2d".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("w", Json::Num(w.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                    }
                    NodeKind::Conv3d { x, w, b, cin, .. } => {
                        f.push(("k", Json::Str("conv3d".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("w", Json::Num(w.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                        f.push(("cin", Json::Num(*cin as f64)));
                    }
                    NodeKind::AvgPool { x, f: factor } => {
                        f.push(("k", Json::Str("avg_pool".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("f", Json::Num(*factor as f64)));
                    }
                    NodeKind::Upsample { x, f: factor } => {
                        f.push(("k", Json::Str("upsample".into())));
                        f.push(("x", Json::Num(x.0 as f64)));
                        f.push(("f", Json::Num(*factor as f64)));
                    }
                    NodeKind::Residual { a, b } => {
                        f.push(("k", Json::Str("residual".into())));
                        f.push(("a", Json::Num(a.0 as f64)));
                        f.push(("b", Json::Num(b.0 as f64)));
                    }
                    NodeKind::L2Loss { pred, target } => {
                        f.push(("k", Json::Str("l2".into())));
                        f.push(("pred", Json::Num(pred.0 as f64)));
                        f.push(("target", Json::Num(target.0 as f64)));
                    }
                    NodeKind::PoissonLoss { pred, target } => {
                        f.push(("k", Json::Str("poisson".into())));
                        f.push(("pred", Json::Num(pred.0 as f64)));
                        f.push(("target", Json::Num(target.0 as f64)));
                    }
                }
                Json::obj(f)
            })
            .collect(),
    );
    let mut fields = vec![
        ("tape_spec", Json::Num(SPEC_VERSION as f64)),
        ("inputs", inputs),
        ("params", params),
        ("nodes", nodes),
    ];
    if let Some(o) = p.output {
        fields.push(("output", Json::Num(o.0 as f64)));
    }
    if let Some(l) = p.loss {
        fields.push(("loss", Json::Num(l.0 as f64)));
    }
    Json::obj(fields)
}

/// Rebuild a pipeline from its wire spec, resolving operator names
/// against `ops` (the serving side passes `[("scan", session plan)]`).
/// Runs the full [`PipelineBuilder`] validation, so a spec that parses
/// is exactly as safe as a locally-built pipeline.
pub fn pipeline_from_json(
    spec: &Json,
    ops: &[(&str, Arc<dyn LinearOp>)],
) -> Result<Pipeline, LeapError> {
    let version = spec
        .get_usize("tape_spec")
        .ok_or_else(|| LeapError::Protocol("pipeline spec missing tape_spec version".into()))?;
    if !(MIN_SPEC_VERSION..=SPEC_VERSION).contains(&version) {
        return Err(LeapError::Unsupported(format!(
            "pipeline spec version {version} (this build speaks \
             {MIN_SPEC_VERSION}..={SPEC_VERSION})"
        )));
    }
    let input_shapes: Vec<Shape> = spec
        .get("inputs")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| LeapError::Protocol("pipeline spec missing inputs".into()))?
        .iter()
        .map(shape_from_json)
        .collect::<Result<_, _>>()?;
    let param_decls: Vec<(String, Shape)> = spec
        .get("params")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| LeapError::Protocol("pipeline spec missing params".into()))?
        .iter()
        .map(|p| {
            let name = p
                .get_str("name")
                .ok_or_else(|| LeapError::Protocol("param missing name".into()))?
                .to_string();
            let shape = shape_from_json(
                p.get("shape")
                    .ok_or_else(|| LeapError::Protocol("param missing shape".into()))?,
            )?;
            Ok((name, shape))
        })
        .collect::<Result<_, LeapError>>()?;
    let nodes = spec
        .get("nodes")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| LeapError::Protocol("pipeline spec missing nodes".into()))?;

    let mut pb = PipelineBuilder::new();
    let mut op_refs = Vec::with_capacity(ops.len());
    for (name, op) in ops {
        op_refs.push((name.to_string(), pb.op(name, op.clone())?));
    }
    let resolve_op = |name: &str,
                      refs: &[(String, super::OpRef)]|
     -> Result<super::OpRef, LeapError> {
        refs.iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| *r)
            .ok_or_else(|| {
                LeapError::Unsupported(format!(
                    "pipeline spec references unknown operator {name:?}"
                ))
            })
    };

    let mut next_input = 0usize;
    let mut next_param = 0usize;
    // cumulative leaf elements, gated BEFORE each param placeholder is
    // allocated: individual shapes are already bounded (shape_from_json),
    // this stops a spec from stacking thousands of maximal leaves
    let mut packed_elems = 0usize;
    let mut take_packed = |n: usize| -> Result<(), LeapError> {
        packed_elems = packed_elems.saturating_add(n);
        if packed_elems > MAX_PACKED_ELEMENTS {
            return Err(LeapError::BudgetExceeded {
                needed: packed_elems.saturating_mul(4),
                cap: MAX_PACKED_ELEMENTS * 4,
            });
        }
        Ok(())
    };
    let mut ids: Vec<super::NodeId> = Vec::with_capacity(nodes.len());
    let child = |ids: &[super::NodeId], idx: usize| -> Result<super::NodeId, LeapError> {
        ids.get(idx).copied().ok_or_else(|| {
            LeapError::Protocol(format!("node references forward/unknown id {idx}"))
        })
    };
    for (pos, n) in nodes.iter().enumerate() {
        let kind = n
            .get_str("k")
            .ok_or_else(|| LeapError::Protocol(format!("node {pos} missing kind")))?;
        let id = match kind {
            "input" => {
                let slot = get_node_id(n, "slot")?;
                if slot != next_input {
                    return Err(LeapError::Protocol(format!(
                        "input nodes must appear in slot order (expected {next_input}, got {slot})"
                    )));
                }
                let shape = *input_shapes.get(slot).ok_or_else(|| {
                    LeapError::Protocol(format!("input slot {slot} has no declared shape"))
                })?;
                take_packed(shape.numel())?;
                next_input += 1;
                pb.input(shape)?
            }
            "param" => {
                let pid = get_node_id(n, "p")?;
                if pid != next_param {
                    return Err(LeapError::Protocol(format!(
                        "param nodes must appear in order (expected {next_param}, got {pid})"
                    )));
                }
                let (name, shape) = param_decls.get(pid).cloned().ok_or_else(|| {
                    LeapError::Protocol(format!("param {pid} is not declared"))
                })?;
                take_packed(shape.numel())?;
                next_param += 1;
                // values travel per-request: declare the parameter with
                // NO stored value, so a registered pipeline pins only
                // its graph — never a frame-sized zero placeholder
                pb.param_uninit(&name, shape)?
            }
            "fill" => {
                let shape = shape_from_json(
                    n.get("shape")
                        .ok_or_else(|| LeapError::Protocol("fill node missing shape".into()))?,
                )?;
                pb.fill(shape, get_f32(n, "v")?)?
            }
            "apply" => {
                let name = n
                    .get_str("op")
                    .ok_or_else(|| LeapError::Protocol("apply node missing op".into()))?;
                let op = resolve_op(name, &op_refs)?;
                pb.apply(op, child(&ids, get_node_id(n, "x")?)?)?
            }
            "adjoint" => {
                let name = n
                    .get_str("op")
                    .ok_or_else(|| LeapError::Protocol("adjoint node missing op".into()))?;
                let op = resolve_op(name, &op_refs)?;
                pb.adjoint(op, child(&ids, get_node_id(n, "y")?)?)?
            }
            "add" => pb.add(
                child(&ids, get_node_id(n, "a")?)?,
                child(&ids, get_node_id(n, "b")?)?,
            )?,
            "sub" => pb.sub(
                child(&ids, get_node_id(n, "a")?)?,
                child(&ids, get_node_id(n, "b")?)?,
            )?,
            "mul" => pb.mul(
                child(&ids, get_node_id(n, "a")?)?,
                child(&ids, get_node_id(n, "b")?)?,
            )?,
            "scale" => pb.scale(
                child(&ids, get_node_id(n, "x")?)?,
                child(&ids, get_node_id(n, "s")?)?,
            )?,
            "relu" => pb.relu(child(&ids, get_node_id(n, "x")?)?)?,
            "clamp" => pb.clamp(
                child(&ids, get_node_id(n, "x")?)?,
                get_f32(n, "lo")?,
                get_f32(n, "hi")?,
            )?,
            "filter_rows" => pb.filter_rows(
                child(&ids, get_node_id(n, "x")?)?,
                child(&ids, get_node_id(n, "w")?)?,
            )?,
            "conv2d" => pb.conv2d(
                child(&ids, get_node_id(n, "x")?)?,
                child(&ids, get_node_id(n, "w")?)?,
                child(&ids, get_node_id(n, "b")?)?,
            )?,
            "conv3d" => pb.conv3d(
                child(&ids, get_node_id(n, "x")?)?,
                child(&ids, get_node_id(n, "w")?)?,
                child(&ids, get_node_id(n, "b")?)?,
                get_node_id(n, "cin")?,
            )?,
            "avg_pool" => {
                pb.avg_pool(child(&ids, get_node_id(n, "x")?)?, get_node_id(n, "f")?)?
            }
            "upsample" => {
                pb.upsample(child(&ids, get_node_id(n, "x")?)?, get_node_id(n, "f")?)?
            }
            "residual" => pb.residual(
                child(&ids, get_node_id(n, "a")?)?,
                child(&ids, get_node_id(n, "b")?)?,
            )?,
            "l2" => pb.l2_loss(
                child(&ids, get_node_id(n, "pred")?)?,
                child(&ids, get_node_id(n, "target")?)?,
            )?,
            "poisson" => pb.poisson_loss(
                child(&ids, get_node_id(n, "pred")?)?,
                child(&ids, get_node_id(n, "target")?)?,
            )?,
            other => {
                return Err(LeapError::Unsupported(format!(
                    "pipeline spec node kind {other:?}"
                )))
            }
        };
        ids.push(id);
    }
    if next_input != input_shapes.len() {
        return Err(LeapError::Protocol(format!(
            "spec declares {} input shapes but has {next_input} input nodes",
            input_shapes.len()
        )));
    }
    if next_param != param_decls.len() {
        return Err(LeapError::Protocol(format!(
            "spec declares {} params but has {next_param} param nodes",
            param_decls.len()
        )));
    }
    if let Some(o) = spec.get_usize("output") {
        pb.set_output(child(&ids, o)?)?;
    }
    if let Some(l) = spec.get_usize("loss") {
        pb.set_loss(child(&ids, l)?)?;
    }
    pb.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{FanBeam, Geometry, VolumeGeometry};
    use crate::ops::PlanOp;
    use crate::projector::{Model, Projector};
    use crate::recon::Window;
    use crate::tape::{learned_fbp, unrolled_gd, UnrollCfg};
    use crate::util::rng::Rng;

    fn fan_op() -> Arc<dyn LinearOp> {
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Fan(FanBeam::standard(8, 14, 1.0, 60.0, 120.0));
        Arc::new(PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(2)))
    }

    #[test]
    fn roundtrip_preserves_gradients_bit_for_bit() {
        let a = fan_op();
        for pipe in [
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 2, step_init: 0.02, nonneg: true })
                .unwrap(),
            learned_fbp(a.clone(), 1.0, Window::Hann).unwrap(),
        ] {
            let spec = pipeline_to_json(&pipe);
            let back = pipeline_from_json(&spec, &[("scan", a.clone())]).unwrap();
            // the rebuilt pipeline must agree on every length…
            assert_eq!(back.packed_len(), pipe.packed_len());
            assert_eq!(back.grad_reply_len(), pipe.grad_reply_len());
            // …and on every float of a loss+grad evaluation
            let mut rng = Rng::new(41);
            let params: Vec<Vec<f32>> = pipe
                .params()
                .iter()
                .map(|p| {
                    let mut v = vec![0.0f32; p.shape.numel()];
                    rng.fill_uniform(&mut v, 0.01, 0.1);
                    v
                })
                .collect();
            let inputs: Vec<Vec<f32>> = pipe
                .input_shapes()
                .iter()
                .map(|s| {
                    let mut v = vec![0.0f32; s.numel()];
                    rng.fill_uniform(&mut v, 0.0, 1.0);
                    v
                })
                .collect();
            let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
            let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let (l1, g1) = pipe.loss_and_grads_with(&pr, &ir).unwrap();
            let (l2, g2) = back.loss_and_grads_with(&pr, &ir).unwrap();
            assert_eq!(l1.to_bits(), l2.to_bits(), "loss must survive the spec");
            assert_eq!(g1, g2, "gradients must survive the spec");
            // a rebuilt pipeline stores NO parameter values (they travel
            // per request): the stored-value entry points are typed
            // errors, not panics — and set_params restores them
            let e = back.loss_and_grads(&ir).unwrap_err();
            assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
            let mut back = back;
            back.set_params(&pr).unwrap();
            let (l3, _) = back.loss_and_grads(&ir).unwrap();
            assert_eq!(l3.to_bits(), l1.to_bits());
        }
    }

    #[test]
    fn spec_roundtrips_through_text() {
        // the registration path parses the meta from wire text
        let a = fan_op();
        let pipe = unrolled_gd(a.clone(), &UnrollCfg {
            iterations: 1,
            step_init: 0.05,
            nonneg: false,
        })
        .unwrap();
        let text = pipeline_to_json(&pipe).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let back = pipeline_from_json(&parsed, &[("scan", a)]).unwrap();
        assert_eq!(back.params().len(), 1);
        assert_eq!(back.input_shapes().len(), 2);
    }

    #[test]
    fn v2_neural_nodes_roundtrip_bit_for_bit() {
        use crate::tape::{unrolled_cnn, UnrollCnnCfg};
        let a = fan_op();
        let pipe = unrolled_cnn(
            a.clone(),
            &UnrollCnnCfg { iterations: 2, step_init: 0.02, channels: 3, ksize: 3, seed: 7 },
        )
        .unwrap();
        let spec = pipeline_to_json(&pipe);
        assert_eq!(spec.get_usize("tape_spec"), Some(SPEC_VERSION));
        let text = spec.to_string();
        let back =
            pipeline_from_json(&crate::util::json::parse(&text).unwrap(), &[("scan", a.clone())])
                .unwrap();
        assert_eq!(back.packed_len(), pipe.packed_len());
        assert_eq!(back.grad_reply_len(), pipe.grad_reply_len());
        let mut rng = Rng::new(57);
        let params: Vec<Vec<f32>> = pipe
            .params()
            .iter()
            .map(|p| {
                let mut v = vec![0.0f32; p.shape.numel()];
                rng.fill_uniform(&mut v, -0.05, 0.05);
                v
            })
            .collect();
        let inputs: Vec<Vec<f32>> = pipe
            .input_shapes()
            .iter()
            .map(|s| {
                let mut v = vec![0.0f32; s.numel()];
                rng.fill_uniform(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let pr: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let ir: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let (l1, g1) = pipe.loss_and_grads_with(&pr, &ir).unwrap();
        let (l2, g2) = back.loss_and_grads_with(&pr, &ir).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits(), "conv pipeline loss must survive the spec");
        assert_eq!(g1, g2, "conv pipeline gradients must survive the spec");
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        let a = fan_op();
        let ops: Vec<(&str, Arc<dyn LinearOp>)> = vec![("scan", a.clone())];
        for (text, expect_protocol) in [
            (r#"{}"#, true),
            (r#"{"tape_spec": 99, "inputs": [], "params": [], "nodes": []}"#, false),
            (
                r#"{"tape_spec": 1, "inputs": [], "params": [],
                    "nodes": [{"k": "warp"}]}"#,
                false,
            ),
            (
                r#"{"tape_spec": 1, "inputs": [], "params": [],
                    "nodes": [{"k": "apply", "op": "other", "x": 0}]}"#,
                false,
            ),
            (
                r#"{"tape_spec": 1, "inputs": [[4,1,1]], "params": [],
                    "nodes": [{"k": "input", "slot": 0},
                              {"k": "add", "a": 0, "b": 7}]}"#,
                true,
            ),
        ] {
            let spec = crate::util::json::parse(text).unwrap();
            let e = pipeline_from_json(&spec, &ops).unwrap_err();
            if expect_protocol {
                assert!(matches!(e, LeapError::Protocol(_)), "{text}: {e:?}");
            } else {
                assert!(
                    matches!(e, LeapError::Unsupported(_) | LeapError::Protocol(_)),
                    "{text}: {e:?}"
                );
            }
        }
    }

    #[test]
    fn absurd_shapes_are_typed_errors_before_any_allocation() {
        let a = fan_op();
        let ops: Vec<(&str, Arc<dyn LinearOp>)> = vec![("scan", a)];
        // a petabyte-scale param (2^52 elements): must be refused while
        // parsing the shape, never reaching the placeholder allocation
        let text = r#"{"tape_spec": 1, "inputs": [],
            "params": [{"name": "p", "shape": [4503599627370496, 1, 1]}],
            "nodes": [{"k": "param", "p": 0}]}"#;
        let e = pipeline_from_json(&crate::util::json::parse(text).unwrap(), &ops).unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        // a product that overflows usize entirely
        let text = r#"{"tape_spec": 1,
            "inputs": [[4503599627370496, 4503599627370496, 2]],
            "params": [], "nodes": [{"k": "input", "slot": 0}]}"#;
        let e = pipeline_from_json(&crate::util::json::parse(text).unwrap(), &ops).unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        // many individually-legal leaves still trip the cumulative cap
        // (input nodes, so the test itself allocates nothing: the gate
        // fires on the second maximal leaf, before any placeholder)
        let text = r#"{"tape_spec": 1,
            "inputs": [[268435456, 1, 1], [268435456, 1, 1]],
            "params": [],
            "nodes": [{"k": "input", "slot": 0}, {"k": "input", "slot": 1}]}"#;
        let e = pipeline_from_json(&crate::util::json::parse(text).unwrap(), &ops).unwrap_err();
        assert!(matches!(e, LeapError::BudgetExceeded { .. }), "{e:?}");
    }

    #[test]
    fn unknown_op_is_unsupported_but_same_named_op_rebinds() {
        // the point of name-based ops: the server rebinds "scan" to its
        // own session plan, so the spec must not carry operator state
        let a = fan_op();
        let pipe = unrolled_gd(a.clone(), &UnrollCfg {
            iterations: 1,
            step_init: 0.05,
            nonneg: false,
        })
        .unwrap();
        let spec = pipeline_to_json(&pipe);
        let e = pipeline_from_json(&spec, &[]).unwrap_err();
        assert!(matches!(e, LeapError::Unsupported(_)), "{e:?}");
    }
}
