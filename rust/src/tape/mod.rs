//! `leap::tape` — reverse-mode autodiff over operator pipelines.
//!
//! [`crate::ops::grad::ProjectionLoss`] differentiates one fixed scalar
//! objective through one operator. Training pipelines need more: compose
//! projectors, filters and solver iterations into a graph, mark some
//! tensors *trainable*, and differentiate the whole thing — the layer
//! TorchRadon and PYRO-NN bolt onto PyTorch, rebuilt here natively so
//! learned/unrolled reconstruction runs on the same matched pairs the
//! rest of the crate serves. The design is a **define-then-run tape**:
//!
//! * A [`Pipeline`] is a static DAG of [`NodeKind`]s over flat `f32`
//!   tensors (shapes carried by [`crate::ops::Shape`]), built through
//!   [`build::PipelineBuilder`] with typed [`LeapError`] validation at
//!   every edge (shape mismatches can never reach evaluation).
//! * The primitive differentiable node is a [`crate::ops::LinearOp`]
//!   application: forward is `apply_into`, and its vector-Jacobian
//!   product is **exactly** `adjoint_into` (and vice versa for adjoint
//!   nodes) — the paper's matched-adjoint property (§2.1) means tape
//!   gradients through projectors are analytic, not approximate, no
//!   matter how many nodes are stacked.
//! * Elementwise glue (`add`/`sub`/`mul`/`scale`, `relu`/`clamp`, and a
//!   parameterized frequency-domain row filter) plus scalar loss nodes
//!   (`l2`, `poisson` — same residual math as `ProjectionLoss`, see
//!   [`crate::ops::grad::l2_residual_in_place`]) cover real
//!   reconstruction pipelines: unrolled gradient descent with learnable
//!   per-iteration steps, learned-FBP with a trainable ramp replacement
//!   ([`unroll`]).
//! * Neural node kinds (`conv2d`/`conv3d` with learnable kernels +
//!   bias, `avg_pool`/`upsample`, `residual` skips — kernels in
//!   [`crate::nn`]) make K-step unrolled solvers with small
//!   per-iteration CNN regularizers (ItNet-style,
//!   [`unroll::unrolled_cnn`]) expressible on the same tape, trained
//!   through the exact projector.
//! * [`Param`](NodeKind::Param) leaves accumulate gradients;
//!   [`optim`] provides deterministic SGD and Adam, and
//!   [`crate::api::Scan::fit`] runs the whole loop behind the typed
//!   front door.
//!
//! Everything is sequential and allocation-order-deterministic: two
//! identical [`Pipeline::loss_and_grads`] calls (or two identical `fit`
//! runs) produce bit-identical floats, and because the underlying
//! projector is thread-count-invariant, so does the same pipeline run at
//! any worker count. That is what lets the serving layer offer
//! [`crate::coordinator::Op::SessionPipelineGrad`]: a pipeline
//! registered over the wire ([`spec`]) against a session's pinned plan
//! returns loss + gradients bit-identical to the in-process tape.
//!
//! ## Shapes and packing
//!
//! Tensors are contiguous `f32` slices; only `numel` matters to the
//! algebra, the `[a, b, c]` dimensions matter to structured nodes
//! (`filter_rows` needs the trailing `ncols`). For the wire, a
//! pipeline's variable data travels as **one packed tensor**:
//! parameters in declaration order, then input slots in order
//! ([`Pipeline::pack`] / [`Pipeline::split_packed`]); gradient replies
//! pack the f64 loss as two f32 bit-halves followed by the per-param
//! gradients ([`Pipeline::pack_grad_reply`]) — bit-exact both ways.

pub mod build;
pub mod optim;
pub mod spec;
pub mod unroll;

pub use build::PipelineBuilder;
pub use optim::{fit, fit_batched, BatchFitCfg, FitCfg, FitReport, Fitter, Optimizer};
pub use spec::{pipeline_from_json, pipeline_to_json};
pub use unroll::{learned_fbp, unrolled_cnn, unrolled_gd, UnrollCfg, UnrollCnnCfg};

use std::sync::Arc;

use crate::api::LeapError;
use crate::nn;
use crate::ops::grad::{l2_residual_in_place, poisson_residual_in_place, POISSON_EPS};
use crate::ops::{LinearOp, Shape};
use crate::recon::filters;
use crate::util::fft::fft_inplace;

/// Handle to a node in a [`Pipeline`] (issued by the builder; ids are
/// topological — a node only ever references smaller ids).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(pub(crate) usize);

/// Handle to a registered [`LinearOp`] inside a pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpRef(pub(crate) usize);

/// One tape node. Forward semantics and the exact reverse-mode rule of
/// each kind are documented on the variant.
#[derive(Clone, Debug)]
pub enum NodeKind {
    /// External tensor, bound per evaluation (`slot` indexes the
    /// `inputs` argument). Never receives gradient flow.
    Input { slot: usize },
    /// Trainable leaf (`pid` indexes the pipeline's parameters). Its
    /// reverse-mode adjoint *is* the loss gradient for that parameter.
    Param { pid: usize },
    /// Constant tensor filled with `v` (e.g. the zero initial iterate of
    /// an unrolled solver).
    Fill { v: f32 },
    /// `y = A·x`. VJP: `dx += Aᵀ·dy` — exact because the pair is
    /// matched.
    Apply { op: usize, x: NodeId },
    /// `x = Aᵀ·y`. VJP: `dy += A·dx`.
    Adjoint { op: usize, y: NodeId },
    /// `y = a + b` (same numel). VJP: `da += dy`, `db += dy`.
    Add { a: NodeId, b: NodeId },
    /// `y = a − b`. VJP: `da += dy`, `db −= dy`.
    Sub { a: NodeId, b: NodeId },
    /// Elementwise `y = a ⊙ b` (per-element learned view/filter
    /// weights). VJP: `da += dy ⊙ b`, `db += dy ⊙ a`.
    Mul { a: NodeId, b: NodeId },
    /// `y = s·x` with `s` a scalar node (numel 1) — learnable step
    /// sizes/gains. VJP: `dx += s·dy`, `ds += Σ dy ⊙ x` (f64
    /// accumulation, cast once).
    Scale { x: NodeId, s: NodeId },
    /// `y = max(x, 0)`. VJP passes where `x > 0` (subgradient 0 at 0).
    Relu { x: NodeId },
    /// `y = clamp(x, lo, hi)`. VJP passes strictly inside `(lo, hi)`.
    Clamp { x: NodeId, lo: f32, hi: f32 },
    /// Frequency-domain filtering of every length-`ncols` row of `x` by
    /// a **learnable half-spectrum** `w` (numel `nfft/2 + 1`,
    /// `nfft = next_pow2(2·ncols)`): the full response is the even
    /// extension `resp[k] = w[min(k, nfft−k)]`, exactly the
    /// [`crate::ops::RampFilterOp`] shape — initialize `w` from
    /// [`crate::recon::filters::ramp_half_spectrum`] and iteration 0 is
    /// analytic FBP's filter. Linear and self-adjoint in `x` (real even
    /// response ⇒ symmetric kernel), so `dx` is the same filter applied
    /// to `dy`; `dw[j] = Σ_rows Σ_{k: min(k,nfft−k)=j}
    /// Re(X_k · conj(D_k))/nfft` with `X`/`D` the FFTs of the
    /// zero-padded row and its adjoint.
    FilterRows { x: NodeId, w: NodeId, ncols: usize, nfft: usize },
    /// 2-D stride-1 **same-padding cross-correlation** of `x` (`[w, h,
    /// cin]` — channels on the slab axis, so a single-slice volume is a
    /// 1-channel image with no reshape) with learnable weights `w`
    /// (`[k², cin, cout]`, tap-fastest) and bias `b` (`[cout, 1, 1]`);
    /// output `[w, h, cout]`. Kernels live in [`crate::nn`]. VJPs are
    /// exact: `dx` gathers the spatially-flipped-kernel correlation of
    /// `dy` ([`crate::nn::conv2d_input_grad`]), `dw[co,ci,tap] =
    /// Σ_image dy ⊙ shifted x` (f64-reduced per tap, cast once),
    /// `db[co] = Σ_image dy[co]`.
    Conv2d { x: NodeId, w: NodeId, b: NodeId, k: usize },
    /// 3-D same-padding cross-correlation over the z-slabs of a volume:
    /// `x` is `[w, h, cin·nz]` (channel axis outside z, so a raw volume
    /// is the `cin = 1` case), weights `[k³, cin, cout]`, bias
    /// `[cout, 1, 1]`, output `[w, h, cout·nz]`. Same exact VJP
    /// structure as [`NodeKind::Conv2d`], one dimension up.
    Conv3d { x: NodeId, w: NodeId, b: NodeId, k: usize, cin: usize },
    /// Factor-`f` spatial average pooling per channel slab:
    /// `[w, h, c] → [w/f, h/f, c]` (block mean). VJP spreads `dy/f²`
    /// over each block — exactly `upsample(dy)/f²`.
    AvgPool { x: NodeId, f: usize },
    /// Factor-`f` nearest-neighbour spatial upsampling per channel slab:
    /// `[w, h, c] → [w·f, h·f, c]`. VJP is the block **sum** — upsample
    /// and avg-pool are adjoints up to the `1/f²` mean weight.
    Upsample { x: NodeId, f: usize },
    /// `y = a + b`, semantically a **residual/skip connection** (the
    /// refinement branch `b` added onto the trunk `a`). Same math and
    /// VJP as [`NodeKind::Add`]; a distinct kind so specs, docs and
    /// shape validation can treat skip edges as what they are.
    Residual { a: NodeId, b: NodeId },
    /// Scalar node `L = ½‖pred − target‖²` (same residual math as
    /// [`crate::ops::grad::ProjectionLoss`]). VJP: `dpred += a·(pred −
    /// target)`, `dtarget −= a·(pred − target)` for upstream scalar `a`.
    L2Loss { pred: NodeId, target: NodeId },
    /// Scalar node `L = Σ max(pred,ε) − target·ln max(pred,ε)` (Poisson
    /// NLL, ε = [`crate::ops::grad::POISSON_EPS`], matching MLEM). VJP:
    /// `dpred += a·(1 − target/max(pred,ε))`,
    /// `dtarget −= a·ln max(pred,ε)`.
    PoissonLoss { pred: NodeId, target: NodeId },
}

/// A node plus its output shape (fixed at build time).
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub shape: Shape,
}

/// A trainable parameter: name, shape and current value (updated in
/// place by [`optim`]).
#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: String,
    pub shape: Shape,
    pub value: Vec<f32>,
}

/// A named operator registered with a pipeline. The name is the wire
/// identity ([`spec`]): the serving side rebinds `"scan"` to the
/// session's pinned plan.
pub(crate) struct OpEntry {
    pub(crate) name: String,
    pub(crate) op: Arc<dyn LinearOp>,
}

/// A built, validated operator pipeline: evaluate it forward
/// ([`Pipeline::eval`], [`Pipeline::loss`]) or differentiate the
/// designated scalar loss with respect to every parameter
/// ([`Pipeline::loss_and_grads`]). See the module docs for semantics.
pub struct Pipeline {
    pub(crate) nodes: Vec<Node>,
    pub(crate) ops: Vec<OpEntry>,
    pub(crate) input_shapes: Vec<Shape>,
    pub(crate) params: Vec<ParamDef>,
    pub(crate) output: Option<NodeId>,
    pub(crate) loss: Option<NodeId>,
    /// Whether each node transitively depends on a parameter — the
    /// backward pass only propagates adjoints along these edges (so no
    /// projection is ever spent on a gradient nobody needs).
    pub(crate) needs_grad: Vec<bool>,
}

/// Forward-pass results: every node's value plus the f64 value of each
/// scalar loss node (f32 storage would truncate the objective the
/// optimizer and the finite-difference tests watch).
struct Evaluated {
    values: Vec<Vec<f32>>,
    losses: Vec<f64>,
}

impl Pipeline {
    /// Declared input-slot shapes (evaluation order).
    pub fn input_shapes(&self) -> &[Shape] {
        &self.input_shapes
    }

    /// The trainable parameters (current values).
    pub fn params(&self) -> &[ParamDef] {
        &self.params
    }

    /// Mutable access to the parameter values (the optimizer's hook;
    /// lengths must not change).
    pub fn params_mut(&mut self) -> &mut [ParamDef] {
        &mut self.params
    }

    /// Replace every parameter value (lengths validated).
    pub fn set_params(&mut self, values: &[&[f32]]) -> Result<(), LeapError> {
        if values.len() != self.params.len() {
            return Err(LeapError::InvalidArgument(format!(
                "pipeline has {} params, got {} values",
                self.params.len(),
                values.len()
            )));
        }
        for (p, v) in self.params.iter_mut().zip(values.iter()) {
            if v.len() != p.shape.numel() {
                return Err(LeapError::ShapeMismatch {
                    what: "parameter",
                    expected: p.shape.numel(),
                    got: v.len(),
                });
            }
            // not copy_from_slice: wire-rebuilt pipelines start with NO
            // stored value (empty vec), and set_params is what gives
            // them one
            p.value.clear();
            p.value.extend_from_slice(v);
        }
        Ok(())
    }

    /// The stored parameter values as slices, or a typed error if any
    /// parameter has no stored value (pipelines rebuilt from a wire
    /// spec carry shapes only — evaluate those through the `*_with`
    /// entry points, or [`Pipeline::set_params`] first).
    fn stored_params(&self) -> Result<Vec<&[f32]>, LeapError> {
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            if p.value.len() != p.shape.numel() {
                return Err(LeapError::InvalidArgument(format!(
                    "parameter {:?} has no stored value; pass parameters explicitly \
                     (loss_and_grads_with / loss_with) or call set_params first",
                    p.name
                )));
            }
            out.push(p.value.as_slice());
        }
        Ok(out)
    }

    /// `(name, domain, range)` of every registered operator — lets
    /// callers ([`crate::api::Scan::fit`]) verify a pipeline was built
    /// for their scan.
    pub fn op_shapes(&self) -> Vec<(&str, Shape, Shape)> {
        self.ops
            .iter()
            .map(|e| (e.name.as_str(), e.op.domain_shape(), e.op.range_shape()))
            .collect()
    }

    /// The designated output node, if any.
    pub fn output_node(&self) -> Option<NodeId> {
        self.output
    }

    /// The designated scalar loss node, if any.
    pub fn loss_node(&self) -> Option<NodeId> {
        self.loss
    }

    /// Shape of the designated output.
    pub fn output_shape(&self) -> Option<Shape> {
        self.output.map(|n| self.nodes[n.0].shape)
    }

    fn check_inputs(&self, inputs: &[&[f32]]) -> Result<(), LeapError> {
        if inputs.len() != self.input_shapes.len() {
            return Err(LeapError::InvalidArgument(format!(
                "pipeline declares {} input slots, got {}",
                self.input_shapes.len(),
                inputs.len()
            )));
        }
        for (s, b) in self.input_shapes.iter().zip(inputs.iter()) {
            if b.len() != s.numel() {
                return Err(LeapError::ShapeMismatch {
                    what: "pipeline input",
                    expected: s.numel(),
                    got: b.len(),
                });
            }
        }
        Ok(())
    }

    fn check_params(&self, params: &[&[f32]]) -> Result<(), LeapError> {
        if params.len() != self.params.len() {
            return Err(LeapError::InvalidArgument(format!(
                "pipeline has {} params, got {}",
                self.params.len(),
                params.len()
            )));
        }
        for (p, b) in self.params.iter().zip(params.iter()) {
            if b.len() != p.shape.numel() {
                return Err(LeapError::ShapeMismatch {
                    what: "parameter",
                    expected: p.shape.numel(),
                    got: b.len(),
                });
            }
        }
        Ok(())
    }

    /// Run the forward pass with explicit parameter values.
    fn forward(&self, params: &[&[f32]], inputs: &[&[f32]]) -> Evaluated {
        let mut values: Vec<Vec<f32>> = Vec::with_capacity(self.nodes.len());
        let mut losses = vec![0.0f64; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            let n = node.shape.numel();
            let v = match &node.kind {
                NodeKind::Input { slot } => inputs[*slot].to_vec(),
                NodeKind::Param { pid } => params[*pid].to_vec(),
                NodeKind::Fill { v } => vec![*v; n],
                NodeKind::Apply { op, x } => {
                    let mut y = vec![0.0f32; n];
                    self.ops[*op].op.apply_into(&values[x.0], &mut y);
                    y
                }
                NodeKind::Adjoint { op, y } => {
                    let mut x = vec![0.0f32; n];
                    self.ops[*op].op.adjoint_into(&values[y.0], &mut x);
                    x
                }
                NodeKind::Add { a, b } => {
                    let (a, b) = (&values[a.0], &values[b.0]);
                    a.iter().zip(b.iter()).map(|(&x, &y)| x + y).collect()
                }
                NodeKind::Sub { a, b } => {
                    let (a, b) = (&values[a.0], &values[b.0]);
                    a.iter().zip(b.iter()).map(|(&x, &y)| x - y).collect()
                }
                NodeKind::Mul { a, b } => {
                    let (a, b) = (&values[a.0], &values[b.0]);
                    a.iter().zip(b.iter()).map(|(&x, &y)| x * y).collect()
                }
                NodeKind::Scale { x, s } => {
                    let s = values[s.0][0];
                    values[x.0].iter().map(|&v| s * v).collect()
                }
                NodeKind::Relu { x } => values[x.0].iter().map(|&v| v.max(0.0)).collect(),
                NodeKind::Clamp { x, lo, hi } => {
                    values[x.0].iter().map(|&v| v.clamp(*lo, *hi)).collect()
                }
                NodeKind::FilterRows { x, w, ncols, nfft } => {
                    let resp = response_from_half(&values[w.0], *nfft);
                    let mut out = values[x.0].clone();
                    filters::filter_rows(&mut out, *ncols, &resp);
                    out
                }
                NodeKind::Conv2d { x, w, b, k } => {
                    let xs = self.nodes[x.0].shape;
                    let (wd, ht, cin) = (xs.0[0], xs.0[1], xs.0[2]);
                    let cout = self.nodes[b.0].shape.numel();
                    let mut out = vec![0.0f32; n];
                    nn::conv2d_forward(
                        &values[x.0],
                        &values[w.0],
                        &values[b.0],
                        wd,
                        ht,
                        cin,
                        cout,
                        *k,
                        &mut out,
                    );
                    out
                }
                NodeKind::Conv3d { x, w, b, k, cin } => {
                    let xs = self.nodes[x.0].shape;
                    let (wd, ht) = (xs.0[0], xs.0[1]);
                    let nz = xs.0[2] / cin;
                    let cout = self.nodes[b.0].shape.numel();
                    let mut out = vec![0.0f32; n];
                    nn::conv3d_forward(
                        &values[x.0],
                        &values[w.0],
                        &values[b.0],
                        wd,
                        ht,
                        nz,
                        *cin,
                        cout,
                        *k,
                        &mut out,
                    );
                    out
                }
                NodeKind::AvgPool { x, f } => {
                    let xs = self.nodes[x.0].shape;
                    let mut out = vec![0.0f32; n];
                    nn::avg_pool_forward(&values[x.0], xs.0[0], xs.0[1], xs.0[2], *f, &mut out);
                    out
                }
                NodeKind::Upsample { x, f } => {
                    let xs = self.nodes[x.0].shape;
                    let mut out = vec![0.0f32; n];
                    nn::upsample_forward(&values[x.0], xs.0[0], xs.0[1], xs.0[2], *f, &mut out);
                    out
                }
                NodeKind::Residual { a, b } => {
                    let (a, b) = (&values[a.0], &values[b.0]);
                    a.iter().zip(b.iter()).map(|(&x, &y)| x + y).collect()
                }
                NodeKind::L2Loss { pred, target } => {
                    let mut r = values[pred.0].clone();
                    let l = l2_residual_in_place(&mut r, &values[target.0]);
                    losses[id] = l;
                    vec![l as f32]
                }
                NodeKind::PoissonLoss { pred, target } => {
                    let mut r = values[pred.0].clone();
                    let l = poisson_residual_in_place(&mut r, &values[target.0]);
                    losses[id] = l;
                    vec![l as f32]
                }
            };
            debug_assert_eq!(v.len(), n, "node {id} value length");
            values.push(v);
        }
        Evaluated { values, losses }
    }

    /// Evaluate the designated output node (e.g. the reconstruction an
    /// unrolled pipeline produces) with the stored parameter values.
    pub fn eval(&self, inputs: &[&[f32]]) -> Result<Vec<f32>, LeapError> {
        let out = self
            .output
            .ok_or_else(|| LeapError::InvalidArgument("pipeline has no output node".into()))?;
        self.check_inputs(inputs)?;
        let params = self.stored_params()?;
        let mut ev = self.forward(&params, inputs);
        Ok(std::mem::take(&mut ev.values[out.0]))
    }

    /// Evaluate the designated scalar loss (f64) with the stored
    /// parameter values.
    pub fn loss(&self, inputs: &[&[f32]]) -> Result<f64, LeapError> {
        let params = self.stored_params()?;
        self.loss_with(&params, inputs)
    }

    /// Evaluate the loss with explicit parameter values.
    pub fn loss_with(&self, params: &[&[f32]], inputs: &[&[f32]]) -> Result<f64, LeapError> {
        let l = self
            .loss
            .ok_or_else(|| LeapError::InvalidArgument("pipeline has no loss node".into()))?;
        self.check_params(params)?;
        self.check_inputs(inputs)?;
        let ev = self.forward(params, inputs);
        Ok(ev.losses[l.0])
    }

    /// Evaluate the loss and the gradient with respect to **every**
    /// parameter (one buffer per parameter, declaration order) using the
    /// stored parameter values.
    pub fn loss_and_grads(&self, inputs: &[&[f32]]) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        let params = self.stored_params()?;
        self.loss_and_grads_with(&params, inputs)
    }

    /// Loss + parameter gradients with explicit parameter values — the
    /// stateless evaluation the serving path uses (the registered
    /// pipeline is shared; each request carries its own parameters).
    pub fn loss_and_grads_with(
        &self,
        params: &[&[f32]],
        inputs: &[&[f32]],
    ) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        let loss_id = self
            .loss
            .ok_or_else(|| LeapError::InvalidArgument("pipeline has no loss node".into()))?;
        self.check_params(params)?;
        self.check_inputs(inputs)?;
        let ev = self.forward(params, inputs);
        let mut adj: Vec<Option<Vec<f32>>> = (0..self.nodes.len()).map(|_| None).collect();
        adj[loss_id.0] = Some(vec![1.0f32]);
        // Reverse topological sweep: node ids only reference smaller ids,
        // so at id every consumer has already deposited its contribution
        // and adj[id] is final. The visit order (and every accumulation
        // order inside it) is fixed by construction — gradients are
        // bit-deterministic run to run.
        for id in (0..self.nodes.len()).rev() {
            if !self.needs_grad[id] {
                continue;
            }
            let Some(d) = adj[id].take() else { continue };
            self.backprop_node(id, &d, &ev, &mut adj);
            if let NodeKind::Param { .. } = self.nodes[id].kind {
                adj[id] = Some(d); // the param's adjoint IS its gradient
            }
        }
        let mut grads = Vec::with_capacity(self.params.len());
        for (pid, p) in self.params.iter().enumerate() {
            let node = self
                .nodes
                .iter()
                .position(|n| matches!(n.kind, NodeKind::Param { pid: q } if q == pid))
                .expect("every param has a node");
            grads.push(match adj[node].take() {
                Some(g) => g,
                None => vec![0.0f32; p.shape.numel()], // loss does not depend on it
            });
        }
        Ok((ev.losses[loss_id.0], grads))
    }

    /// Mean loss + mean parameter gradients over a **mini-batch** of
    /// input items, evaluated data-parallel over the worker pool
    /// (`threads` workers; 0 = [`crate::util::pool::default_threads`]).
    ///
    /// Bit-identical to sequential accumulation at *any* worker count:
    /// each item's evaluation is thread-count-invariant on its own (the
    /// projector guarantees that), results land in per-item slots, and
    /// the reduction walks the slots **in item order** with the exact
    /// float ops a sequential loop would use — f64 loss sum, f32 `axpy`
    /// per gradient, one `1/n` f32 scaling at the end. Nested pool use
    /// is safe: each item's projections claim their own region slots
    /// (`util::pool` regions are caller-participating).
    pub fn loss_and_grads_batch(
        &self,
        params: &[&[f32]],
        items: &[Vec<&[f32]>],
        threads: usize,
    ) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        if items.is_empty() {
            return Err(LeapError::InvalidArgument(
                "mini-batch evaluation needs at least one item".into(),
            ));
        }
        let threads = if threads == 0 { crate::util::pool::default_threads() } else { threads };
        let slots: Vec<std::sync::Mutex<Option<Result<(f64, Vec<Vec<f32>>), LeapError>>>> =
            (0..items.len()).map(|_| std::sync::Mutex::new(None)).collect();
        crate::util::pool::parallel_items(items.len(), threads, |i| {
            *slots[i].lock().unwrap() = Some(self.loss_and_grads_with(params, &items[i]));
        });
        let mut loss_sum = 0.0f64;
        let mut grads: Vec<Vec<f32>> =
            self.params.iter().map(|p| vec![0.0f32; p.shape.numel()]).collect();
        for slot in &slots {
            let (l, g) = slot.lock().unwrap().take().expect("every item evaluated")?;
            loss_sum += l;
            for (acc, gi) in grads.iter_mut().zip(g.iter()) {
                axpy(acc, gi);
            }
        }
        let inv = 1.0f32 / items.len() as f32;
        for g in &mut grads {
            for v in g.iter_mut() {
                *v *= inv;
            }
        }
        Ok((loss_sum / items.len() as f64, grads))
    }

    /// Deposit `d` (the final adjoint of node `id`) into the adjoints of
    /// the nodes it reads, skipping children that cannot reach a
    /// parameter.
    fn backprop_node(&self, id: usize, d: &[f32], ev: &Evaluated, adj: &mut [Option<Vec<f32>>]) {
        let values = &ev.values;
        match &self.nodes[id].kind {
            NodeKind::Input { .. } | NodeKind::Param { .. } | NodeKind::Fill { .. } => {}
            NodeKind::Apply { op, x } => {
                if self.needs_grad[x.0] {
                    let t = self.ops[*op].op.adjoint(d);
                    axpy(self.accum(adj, *x), &t);
                }
            }
            NodeKind::Adjoint { op, y } => {
                if self.needs_grad[y.0] {
                    let t = self.ops[*op].op.apply(d);
                    axpy(self.accum(adj, *y), &t);
                }
            }
            NodeKind::Add { a, b } => {
                if self.needs_grad[a.0] {
                    axpy(self.accum(adj, *a), d);
                }
                if self.needs_grad[b.0] {
                    axpy(self.accum(adj, *b), d);
                }
            }
            NodeKind::Sub { a, b } => {
                if self.needs_grad[a.0] {
                    axpy(self.accum(adj, *a), d);
                }
                if self.needs_grad[b.0] {
                    let acc = self.accum(adj, *b);
                    for (g, &v) in acc.iter_mut().zip(d.iter()) {
                        *g -= v;
                    }
                }
            }
            NodeKind::Mul { a, b } => {
                if self.needs_grad[a.0] {
                    let bv = &values[b.0];
                    let acc = self.accum(adj, *a);
                    for i in 0..acc.len() {
                        acc[i] += d[i] * bv[i];
                    }
                }
                if self.needs_grad[b.0] {
                    let av = &values[a.0];
                    let acc = self.accum(adj, *b);
                    for i in 0..acc.len() {
                        acc[i] += d[i] * av[i];
                    }
                }
            }
            NodeKind::Scale { x, s } => {
                let sv = values[s.0][0];
                if self.needs_grad[x.0] {
                    let acc = self.accum(adj, *x);
                    for (g, &v) in acc.iter_mut().zip(d.iter()) {
                        *g += sv * v;
                    }
                }
                if self.needs_grad[s.0] {
                    let xv = &values[x.0];
                    let mut ds = 0.0f64;
                    for (dv, &x) in d.iter().zip(xv.iter()) {
                        ds += *dv as f64 * x as f64;
                    }
                    self.accum(adj, *s)[0] += ds as f32;
                }
            }
            NodeKind::Relu { x } => {
                if self.needs_grad[x.0] {
                    let xv = &values[x.0];
                    let acc = self.accum(adj, *x);
                    for i in 0..acc.len() {
                        if xv[i] > 0.0 {
                            acc[i] += d[i];
                        }
                    }
                }
            }
            NodeKind::Clamp { x, lo, hi } => {
                if self.needs_grad[x.0] {
                    let xv = &values[x.0];
                    let acc = self.accum(adj, *x);
                    for i in 0..acc.len() {
                        if xv[i] > *lo && xv[i] < *hi {
                            acc[i] += d[i];
                        }
                    }
                }
            }
            NodeKind::FilterRows { x, w, ncols, nfft } => {
                if self.needs_grad[x.0] {
                    // self-adjoint in x: filter the adjoint with the same
                    // response (see the variant docs)
                    let resp = response_from_half(&values[w.0], *nfft);
                    let mut t = d.to_vec();
                    filters::filter_rows(&mut t, *ncols, &resp);
                    axpy(self.accum(adj, *x), &t);
                }
                if self.needs_grad[w.0] {
                    let mut acc64 = vec![0.0f64; *nfft / 2 + 1];
                    filter_rows_weight_grad(&values[x.0], d, *ncols, *nfft, &mut acc64);
                    let acc = self.accum(adj, *w);
                    for (g, &a) in acc.iter_mut().zip(acc64.iter()) {
                        *g += a as f32;
                    }
                }
            }
            NodeKind::Conv2d { x, w, b, k } => {
                let xs = self.nodes[x.0].shape;
                let (wd, ht, cin) = (xs.0[0], xs.0[1], xs.0[2]);
                let cout = self.nodes[b.0].shape.numel();
                if self.needs_grad[x.0] {
                    let wv = &values[w.0];
                    let acc = self.accum(adj, *x);
                    nn::conv2d_input_grad(d, wv, wd, ht, cin, cout, *k, acc);
                }
                if self.needs_grad[w.0] {
                    let xv = &values[x.0];
                    let acc = self.accum(adj, *w);
                    nn::conv2d_weight_grad(xv, d, wd, ht, cin, cout, *k, acc);
                }
                if self.needs_grad[b.0] {
                    let acc = self.accum(adj, *b);
                    nn::conv2d_bias_grad(d, wd, ht, cout, acc);
                }
            }
            NodeKind::Conv3d { x, w, b, k, cin } => {
                let xs = self.nodes[x.0].shape;
                let (wd, ht) = (xs.0[0], xs.0[1]);
                let nz = xs.0[2] / cin;
                let cout = self.nodes[b.0].shape.numel();
                if self.needs_grad[x.0] {
                    let wv = &values[w.0];
                    let acc = self.accum(adj, *x);
                    nn::conv3d_input_grad(d, wv, wd, ht, nz, *cin, cout, *k, acc);
                }
                if self.needs_grad[w.0] {
                    let xv = &values[x.0];
                    let acc = self.accum(adj, *w);
                    nn::conv3d_weight_grad(xv, d, wd, ht, nz, *cin, cout, *k, acc);
                }
                if self.needs_grad[b.0] {
                    let acc = self.accum(adj, *b);
                    nn::conv3d_bias_grad(d, wd, ht, nz, cout, acc);
                }
            }
            NodeKind::AvgPool { x, f } => {
                if self.needs_grad[x.0] {
                    let xs = self.nodes[x.0].shape;
                    let acc = self.accum(adj, *x);
                    nn::avg_pool_input_grad(d, xs.0[0], xs.0[1], xs.0[2], *f, acc);
                }
            }
            NodeKind::Upsample { x, f } => {
                if self.needs_grad[x.0] {
                    let xs = self.nodes[x.0].shape;
                    let acc = self.accum(adj, *x);
                    nn::upsample_input_grad(d, xs.0[0], xs.0[1], xs.0[2], *f, acc);
                }
            }
            NodeKind::Residual { a, b } => {
                if self.needs_grad[a.0] {
                    axpy(self.accum(adj, *a), d);
                }
                if self.needs_grad[b.0] {
                    axpy(self.accum(adj, *b), d);
                }
            }
            NodeKind::L2Loss { pred, target } => {
                let a = d[0];
                let (p, t) = (&values[pred.0], &values[target.0]);
                if self.needs_grad[pred.0] {
                    let acc = self.accum(adj, *pred);
                    for i in 0..acc.len() {
                        acc[i] += a * (p[i] - t[i]);
                    }
                }
                if self.needs_grad[target.0] {
                    let acc = self.accum(adj, *target);
                    for i in 0..acc.len() {
                        acc[i] -= a * (p[i] - t[i]);
                    }
                }
            }
            NodeKind::PoissonLoss { pred, target } => {
                let a = d[0];
                let (p, t) = (&values[pred.0], &values[target.0]);
                if self.needs_grad[pred.0] {
                    let acc = self.accum(adj, *pred);
                    for i in 0..acc.len() {
                        let m = p[i].max(POISSON_EPS);
                        acc[i] += a * (1.0 - t[i] / m);
                    }
                }
                if self.needs_grad[target.0] {
                    let acc = self.accum(adj, *target);
                    for i in 0..acc.len() {
                        let m = p[i].max(POISSON_EPS) as f64;
                        acc[i] -= a * m.ln() as f32;
                    }
                }
            }
        }
    }

    /// The (lazily zero-initialized) adjoint buffer of `child`.
    fn accum<'a>(&self, adj: &'a mut [Option<Vec<f32>>], child: NodeId) -> &'a mut [f32] {
        let n = self.nodes[child.0].shape.numel();
        adj[child.0].get_or_insert_with(|| vec![0.0f32; n]).as_mut_slice()
    }

    // ── wire packing ───────────────────────────────────────────────────

    /// Total f32 count of the packed request tensor: every parameter
    /// (declaration order), then every input slot (order).
    pub fn packed_len(&self) -> usize {
        self.params.iter().map(|p| p.shape.numel()).sum::<usize>()
            + self.input_shapes.iter().map(|s| s.numel()).sum::<usize>()
    }

    /// Pack explicit parameter values and inputs into the single wire
    /// tensor [`crate::coordinator::Op::SessionPipelineGrad`] carries.
    pub fn pack(&self, params: &[&[f32]], inputs: &[&[f32]]) -> Result<Vec<f32>, LeapError> {
        self.check_params(params)?;
        self.check_inputs(inputs)?;
        let mut out = Vec::with_capacity(self.packed_len());
        for p in params {
            out.extend_from_slice(p);
        }
        for i in inputs {
            out.extend_from_slice(i);
        }
        Ok(out)
    }

    /// Split a packed request tensor back into (params, inputs) slices —
    /// the exact inverse of [`Pipeline::pack`], used by the serving
    /// executor so both ends agree on the layout by construction.
    pub fn split_packed<'a>(
        &self,
        buf: &'a [f32],
    ) -> Result<(Vec<&'a [f32]>, Vec<&'a [f32]>), LeapError> {
        if buf.len() != self.packed_len() {
            return Err(LeapError::ShapeMismatch {
                what: "packed pipeline tensor",
                expected: self.packed_len(),
                got: buf.len(),
            });
        }
        let mut off = 0usize;
        let mut params = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n = p.shape.numel();
            params.push(&buf[off..off + n]);
            off += n;
        }
        let mut inputs = Vec::with_capacity(self.input_shapes.len());
        for s in &self.input_shapes {
            let n = s.numel();
            inputs.push(&buf[off..off + n]);
            off += n;
        }
        Ok((params, inputs))
    }

    /// Expected f32 count of a gradient reply: 2 (the f64 loss as two
    /// f32 bit-halves, hi then lo) + every parameter gradient.
    pub fn grad_reply_len(&self) -> usize {
        2 + self.params.iter().map(|p| p.shape.numel()).sum::<usize>()
    }

    /// Upper bound on the transient bytes one `loss_and_grads`
    /// evaluation materializes: every node's forward value plus (worst
    /// case) an adjoint buffer of the same size — the forward pass keeps
    /// all node values alive for the backward sweep. Saturating; the
    /// serving registry gates wire-registered pipelines on this so a
    /// hostile spec full of huge intermediate nodes cannot OOM the
    /// server at evaluation time (the packed request/reply caps only
    /// bound params + inputs, not intermediates).
    pub fn eval_bytes_estimate(&self) -> usize {
        self.nodes
            .iter()
            .fold(0usize, |acc, n| acc.saturating_add(n.shape.numel().saturating_mul(8)))
    }

    /// Pack `(loss, grads)` into the reply tensor. The f64 loss travels
    /// as raw bits split across two f32 slots — the payload is bit-exact
    /// on the wire, so the loss round-trips *exactly* (JSON f64 text
    /// would too, but this keeps the reply a single tensor).
    pub fn pack_grad_reply(&self, loss: f64, grads: &[Vec<f32>]) -> Vec<f32> {
        let bits = loss.to_bits();
        let mut out = Vec::with_capacity(self.grad_reply_len());
        out.push(f32::from_bits((bits >> 32) as u32));
        out.push(f32::from_bits(bits as u32));
        for g in grads {
            out.extend_from_slice(g);
        }
        out
    }

    /// Unpack a gradient reply into `(loss, per-param gradients)`.
    pub fn unpack_grad_reply(&self, buf: &[f32]) -> Result<(f64, Vec<Vec<f32>>), LeapError> {
        if buf.len() != self.grad_reply_len() {
            return Err(LeapError::ShapeMismatch {
                what: "pipeline gradient reply",
                expected: self.grad_reply_len(),
                got: buf.len(),
            });
        }
        let bits = ((buf[0].to_bits() as u64) << 32) | buf[1].to_bits() as u64;
        let loss = f64::from_bits(bits);
        let mut off = 2usize;
        let mut grads = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n = p.shape.numel();
            grads.push(buf[off..off + n].to_vec());
            off += n;
        }
        Ok((loss, grads))
    }
}

/// `acc += v`, elementwise.
fn axpy(acc: &mut [f32], v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    for (a, &b) in acc.iter_mut().zip(v.iter()) {
        *a += b;
    }
}

/// Even extension of a half-spectrum to the full `nfft` response:
/// `resp[k] = w[min(k, nfft−k)]` (f64, what
/// [`crate::recon::filters::filter_rows`] consumes).
pub(crate) fn response_from_half(w: &[f32], nfft: usize) -> Vec<f64> {
    debug_assert_eq!(w.len(), nfft / 2 + 1);
    (0..nfft).map(|k| w[k.min(nfft - k)] as f64).collect()
}

/// Accumulate `dL/dw` for one `FilterRows` node (see the variant docs
/// for the derivation): per row, `dL/dresp_k = Re(X_k·conj(D_k))/nfft`
/// with `X = FFT(x̃)`, `D = FFT(d̃)` (zero-padded rows; the forward FFT
/// here is unnormalized, the inverse carries `1/nfft` — matching
/// [`crate::util::fft::fft_inplace`]), folded onto half-spectrum index
/// `min(k, nfft−k)`. All accumulation is sequential f64 — deterministic.
fn filter_rows_weight_grad(x: &[f32], d: &[f32], ncols: usize, nfft: usize, acc: &mut [f64]) {
    debug_assert_eq!(x.len(), d.len());
    debug_assert_eq!(x.len() % ncols, 0);
    debug_assert_eq!(acc.len(), nfft / 2 + 1);
    let mut xr = vec![0.0f64; nfft];
    let mut xi = vec![0.0f64; nfft];
    let mut dr = vec![0.0f64; nfft];
    let mut di = vec![0.0f64; nfft];
    for (xrow, drow) in x.chunks_exact(ncols).zip(d.chunks_exact(ncols)) {
        xr.fill(0.0);
        xi.fill(0.0);
        dr.fill(0.0);
        di.fill(0.0);
        for (i, &v) in xrow.iter().enumerate() {
            xr[i] = v as f64;
        }
        for (i, &v) in drow.iter().enumerate() {
            dr[i] = v as f64;
        }
        fft_inplace(&mut xr, &mut xi, false);
        fft_inplace(&mut dr, &mut di, false);
        for k in 0..nfft {
            let g = (xr[k] * dr[k] + xi[k] * di[k]) / nfft as f64;
            acc[k.min(nfft - k)] += g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::ops::PlanOp;
    use crate::projector::{Model, Projector};
    use crate::recon::Window;
    use crate::util::rng::Rng;

    fn scan_op() -> Arc<dyn LinearOp> {
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(7, 14, 1.0));
        Arc::new(PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(2)))
    }

    #[test]
    fn response_extension_matches_ramp() {
        // half-spectrum init + even extension reproduce the full ramp
        // response up to the f32 cast of each sample
        let half = filters::ramp_half_spectrum(14, 1.0, Window::Hann);
        let nfft = (half.len() - 1) * 2;
        let full = filters::ramp_response(14, 1.0, Window::Hann);
        assert_eq!(full.len(), nfft);
        let ext = response_from_half(&half, nfft);
        for k in 0..nfft {
            assert_eq!(ext[k], full[k] as f32 as f64, "bin {k}");
        }
    }

    #[test]
    fn tape_l2_matches_projection_loss_bits() {
        // a tape of Apply → L2Loss must reproduce ProjectionLoss exactly:
        // same residual math, same adjoint — bit-identical loss and grad
        let op = scan_op();
        let dn = op.domain_shape().numel();
        let rn = op.range_shape().numel();
        let mut rng = Rng::new(3);
        let mut x0 = vec![0.0f32; dn];
        rng.fill_uniform(&mut x0, 0.2, 1.0);
        let mut b = vec![0.0f32; rn];
        rng.fill_uniform(&mut b, 0.2, 1.0);

        let mut pb = PipelineBuilder::new();
        let a = pb.op("scan", op.clone()).unwrap();
        let x = pb.param("x", op.domain_shape(), x0.clone()).unwrap();
        let meas = pb.input(op.range_shape()).unwrap();
        let ax = pb.apply(a, x).unwrap();
        let l = pb.l2_loss(ax, meas).unwrap();
        pb.set_loss(l).unwrap();
        let pipe = pb.build().unwrap();
        let (loss, grads) = pipe.loss_and_grads(&[&b]).unwrap();

        let reference = crate::ops::ProjectionLoss::new(
            &*op,
            &b,
            crate::ops::Objective::LeastSquares,
        );
        let mut gref = vec![0.0f32; dn];
        let lref = reference.value_and_grad(&x0, &mut gref);
        assert_eq!(loss, lref, "loss must be bit-identical");
        assert_eq!(grads[0], gref, "gradient must be bit-identical");
    }

    #[test]
    fn grad_reply_roundtrip_is_bit_exact() {
        let op = scan_op();
        let mut pb = PipelineBuilder::new();
        let a = pb.op("scan", op.clone()).unwrap();
        let x = pb.param("x", op.domain_shape(), vec![0.5; op.domain_shape().numel()]).unwrap();
        let meas = pb.input(op.range_shape()).unwrap();
        let ax = pb.apply(a, x).unwrap();
        let l = pb.l2_loss(ax, meas).unwrap();
        pb.set_loss(l).unwrap();
        let pipe = pb.build().unwrap();
        for loss in [0.0f64, 1.5e-300, -7.25, f64::MAX, 1.0 / 3.0] {
            // a gradient with awkward bit patterns (NaN, -0, denormal)
            let mut g = vec![0.25f32; pipe.params()[0].shape.numel()];
            g[0] = f32::NAN;
            g[1] = -0.0;
            g[2] = f32::MIN_POSITIVE;
            let packed = pipe.pack_grad_reply(loss, &[g.clone()]);
            let (l2, g2) = pipe.unpack_grad_reply(&packed).unwrap();
            assert_eq!(l2.to_bits(), loss.to_bits());
            let a: Vec<u32> = g.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = g2[0].iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn gradients_are_deterministic() {
        let op = scan_op();
        let mut make = || {
            let mut pb = PipelineBuilder::new();
            let a = pb.op("scan", op.clone()).unwrap();
            let x = pb
                .param("x", op.domain_shape(), vec![0.3; op.domain_shape().numel()])
                .unwrap();
            let s = pb.scalar_param("s", 0.7).unwrap();
            let meas = pb.input(op.range_shape()).unwrap();
            let ax = pb.apply(a, x).unwrap();
            let sax = pb.scale(ax, s).unwrap();
            let l = pb.l2_loss(sax, meas).unwrap();
            pb.set_loss(l).unwrap();
            pb.build().unwrap()
        };
        let b = vec![0.4f32; op.range_shape().numel()];
        let (l1, g1) = make().loss_and_grads(&[&b]).unwrap();
        let (l2, g2) = make().loss_and_grads(&[&b]).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
    }
}
