//! Deterministic first-order optimizers for tape parameters.
//!
//! Minimal on purpose: the tape returns exact gradients, so plain SGD
//! and Adam cover the unrolled/learned-reconstruction training loops
//! this crate targets. Every update is elementwise, sequential f32
//! arithmetic with no randomness and no data-dependent branching —
//! two identical [`fit`] runs produce **bit-identical** parameters,
//! which the test suite asserts (and which makes server-side and
//! client-side training trivially comparable).

use crate::api::LeapError;

use super::Pipeline;

/// Optimizer selector for [`fit`] / [`crate::api::Scan::fit`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optimizer {
    /// `p ← p − lr·g`.
    Sgd { lr: f32 },
    /// Adam (Kingma & Ba 2015) with bias correction.
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    /// Adam with the customary defaults (β₁ 0.9, β₂ 0.999, ε 1e-8).
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    fn validate(&self) -> Result<(), LeapError> {
        let bad = |m: String| Err(LeapError::InvalidArgument(m));
        match *self {
            Optimizer::Sgd { lr } => {
                if !(lr.is_finite() && lr > 0.0) {
                    return bad(format!("sgd lr must be positive and finite (got {lr})"));
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                if !(lr.is_finite() && lr > 0.0) {
                    return bad(format!("adam lr must be positive and finite (got {lr})"));
                }
                for (name, b) in [("beta1", beta1), ("beta2", beta2)] {
                    if !(b.is_finite() && (0.0..1.0).contains(&b)) {
                        return bad(format!("adam {name} must be in [0, 1) (got {b})"));
                    }
                }
                if !(eps.is_finite() && eps > 0.0) {
                    return bad(format!("adam eps must be positive and finite (got {eps})"));
                }
            }
        }
        Ok(())
    }
}

/// Per-parameter optimizer state (Adam moments; empty for SGD).
struct OptState {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u32,
}

impl OptState {
    fn new(pipe: &Pipeline) -> OptState {
        let zeros: Vec<Vec<f32>> =
            pipe.params().iter().map(|p| vec![0.0f32; p.shape.numel()]).collect();
        OptState { m: zeros.clone(), v: zeros, t: 0 }
    }

    fn step(&mut self, opt: &Optimizer, pipe: &mut Pipeline, grads: &[Vec<f32>]) {
        self.t += 1;
        match *opt {
            Optimizer::Sgd { lr } => {
                for (p, g) in pipe.params_mut().iter_mut().zip(grads.iter()) {
                    for (pv, &gv) in p.value.iter_mut().zip(g.iter()) {
                        *pv -= lr * gv;
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                // bias-corrected step size, computed once per step
                let t = self.t as f64;
                let bc1 = 1.0 - (beta1 as f64).powf(t);
                let bc2 = 1.0 - (beta2 as f64).powf(t);
                let alpha = (lr as f64 * bc2.sqrt() / bc1) as f32;
                for ((p, g), (m, v)) in pipe
                    .params_mut()
                    .iter_mut()
                    .zip(grads.iter())
                    .zip(self.m.iter_mut().zip(self.v.iter_mut()))
                {
                    for i in 0..p.value.len() {
                        m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
                        v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
                        p.value[i] -= alpha * m[i] / (v[i].sqrt() + eps);
                    }
                }
            }
        }
    }
}

/// Configuration for [`fit`].
#[derive(Clone, Copy, Debug)]
pub struct FitCfg {
    pub optimizer: Optimizer,
    /// Number of optimizer steps (each = one loss + gradient
    /// evaluation).
    pub iterations: usize,
}

/// What a [`fit`] run did.
#[derive(Clone, Debug)]
pub struct FitReport {
    /// Loss before the first update.
    pub initial_loss: f64,
    /// Loss at the last evaluation (before the final update is applied).
    pub final_loss: f64,
    /// Every evaluated loss, in order (`iterations` entries).
    pub losses: Vec<f64>,
}

/// Train `pipe`'s parameters in place: `iterations` rounds of
/// loss + exact gradients + one optimizer step. Deterministic — see the
/// module docs. Inputs are borrowed once and reused every round (full-
/// batch training; callers wanting stochasticity re-slice between
/// calls).
pub fn fit(pipe: &mut Pipeline, inputs: &[&[f32]], cfg: &FitCfg) -> Result<FitReport, LeapError> {
    cfg.optimizer.validate()?;
    if cfg.iterations == 0 {
        return Err(LeapError::InvalidArgument("fit needs at least one iteration".into()));
    }
    let mut state = OptState::new(pipe);
    let mut losses = Vec::with_capacity(cfg.iterations);
    for _ in 0..cfg.iterations {
        let (loss, grads) = pipe.loss_and_grads(inputs)?;
        losses.push(loss);
        state.step(&cfg.optimizer, pipe, &grads);
    }
    Ok(FitReport {
        initial_loss: losses[0],
        final_loss: *losses.last().expect("at least one iteration"),
        losses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Shape;
    use crate::tape::PipelineBuilder;

    /// Tiny quadratic: L = ½‖p − b‖² with b an input — the optimizer
    /// must walk p toward b.
    fn quadratic(init: &[f32]) -> Pipeline {
        let mut pb = PipelineBuilder::new();
        let p = pb.param("p", Shape([init.len(), 1, 1]), init.to_vec()).unwrap();
        let b = pb.input(Shape([init.len(), 1, 1])).unwrap();
        let l = pb.l2_loss(p, b).unwrap();
        pb.set_loss(l).unwrap();
        pb.build().unwrap()
    }

    #[test]
    fn sgd_descends_a_quadratic() {
        let mut pipe = quadratic(&[0.0, 0.0, 0.0]);
        let target = [1.0f32, -2.0, 3.0];
        let report = fit(
            &mut pipe,
            &[&target],
            &FitCfg { optimizer: Optimizer::Sgd { lr: 0.5 }, iterations: 40 },
        )
        .unwrap();
        assert!(report.final_loss < 1e-6 * report.initial_loss.max(1.0));
        for (p, t) in pipe.params()[0].value.iter().zip(target.iter()) {
            assert!((p - t).abs() < 1e-3, "{p} vs {t}");
        }
    }

    #[test]
    fn adam_descends_a_quadratic() {
        let mut pipe = quadratic(&[5.0, -5.0]);
        let target = [0.5f32, 0.25];
        let report = fit(
            &mut pipe,
            &[&target],
            &FitCfg { optimizer: Optimizer::adam(0.5), iterations: 200 },
        )
        .unwrap();
        assert!(
            report.final_loss < 1e-4,
            "adam should converge: {} → {}",
            report.initial_loss,
            report.final_loss
        );
    }

    #[test]
    fn fit_is_bit_deterministic() {
        let run = || {
            let mut pipe = quadratic(&[2.0, -1.0, 0.5, 4.0]);
            let target = [0.1f32, 0.2, 0.3, 0.4];
            let report = fit(
                &mut pipe,
                &[&target],
                &FitCfg { optimizer: Optimizer::adam(0.1), iterations: 25 },
            )
            .unwrap();
            (pipe.params()[0].value.clone(), report.losses)
        };
        let (p1, l1) = run();
        let (p2, l2) = run();
        let b1: Vec<u32> = p1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = p2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2, "two identical fits must produce bit-identical params");
        let lb1: Vec<u64> = l1.iter().map(|v| v.to_bits()).collect();
        let lb2: Vec<u64> = l2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(lb1, lb2);
    }

    #[test]
    fn bad_optimizer_args_are_typed() {
        let mut pipe = quadratic(&[0.0]);
        let t = [1.0f32];
        for opt in [
            Optimizer::Sgd { lr: -1.0 },
            Optimizer::Sgd { lr: f32::NAN },
            Optimizer::Adam { lr: 0.1, beta1: 1.5, beta2: 0.999, eps: 1e-8 },
            Optimizer::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 0.0 },
        ] {
            let e = fit(&mut pipe, &[&t], &FitCfg { optimizer: opt, iterations: 1 }).unwrap_err();
            assert!(matches!(e, LeapError::InvalidArgument(_)), "{opt:?}: {e:?}");
        }
        let e = fit(
            &mut pipe,
            &[&t],
            &FitCfg { optimizer: Optimizer::Sgd { lr: 0.1 }, iterations: 0 },
        )
        .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)));
    }
}
