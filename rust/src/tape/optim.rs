//! Deterministic first-order optimizers for tape parameters.
//!
//! Minimal on purpose: the tape returns exact gradients, so plain SGD
//! and Adam cover the unrolled/learned-reconstruction training loops
//! this crate targets. Every update is elementwise, sequential f32
//! arithmetic with no randomness and no data-dependent branching —
//! two identical [`fit`] runs produce **bit-identical** parameters,
//! which the test suite asserts (and which makes server-side and
//! client-side training trivially comparable).
//!
//! Three entry points, in increasing generality:
//!
//! * [`fit`] — full-batch: one fixed input set, N optimizer steps.
//! * [`fit_batched`] — mini-batch over a corpus of items with
//!   data-parallel gradient evaluation
//!   ([`Pipeline::loss_and_grads_batch`]); the aggregation is
//!   bit-identical to evaluating the items sequentially, so the
//!   trained parameters do not depend on the thread count.
//! * [`Fitter`] — the stateful core both are built on: the optimizer
//!   plus its per-parameter state (Adam moments, step counter),
//!   exposed so callers can drive custom loops and **checkpoint**:
//!   [`Fitter::save`] serializes parameters + moments + step counter
//!   to little-endian bytes, and [`Fitter::restore`] resumes training
//!   bit-for-bit where it left off.

use crate::api::LeapError;

use super::Pipeline;

/// Optimizer selector for [`fit`] / [`crate::api::Scan::fit`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optimizer {
    /// `p ← p − lr·g`.
    Sgd { lr: f32 },
    /// Adam (Kingma & Ba 2015) with bias correction.
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl Optimizer {
    /// Adam with the customary defaults (β₁ 0.9, β₂ 0.999, ε 1e-8).
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    fn validate(&self) -> Result<(), LeapError> {
        let bad = |m: String| Err(LeapError::InvalidArgument(m));
        match *self {
            Optimizer::Sgd { lr } => {
                if !(lr.is_finite() && lr > 0.0) {
                    return bad(format!("sgd lr must be positive and finite (got {lr})"));
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                if !(lr.is_finite() && lr > 0.0) {
                    return bad(format!("adam lr must be positive and finite (got {lr})"));
                }
                for (name, b) in [("beta1", beta1), ("beta2", beta2)] {
                    if !(b.is_finite() && (0.0..1.0).contains(&b)) {
                        return bad(format!("adam {name} must be in [0, 1) (got {b})"));
                    }
                }
                if !(eps.is_finite() && eps > 0.0) {
                    return bad(format!("adam eps must be positive and finite (got {eps})"));
                }
            }
        }
        Ok(())
    }
}

/// Per-parameter optimizer state (Adam moments; empty for SGD).
struct OptState {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u32,
}

impl OptState {
    fn new(pipe: &Pipeline) -> OptState {
        let zeros: Vec<Vec<f32>> =
            pipe.params().iter().map(|p| vec![0.0f32; p.shape.numel()]).collect();
        OptState { m: zeros.clone(), v: zeros, t: 0 }
    }

    fn step(&mut self, opt: &Optimizer, pipe: &mut Pipeline, grads: &[Vec<f32>]) {
        self.t += 1;
        match *opt {
            Optimizer::Sgd { lr } => {
                for (p, g) in pipe.params_mut().iter_mut().zip(grads.iter()) {
                    for (pv, &gv) in p.value.iter_mut().zip(g.iter()) {
                        *pv -= lr * gv;
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                // bias-corrected step size, computed once per step
                let t = self.t as f64;
                let bc1 = 1.0 - (beta1 as f64).powf(t);
                let bc2 = 1.0 - (beta2 as f64).powf(t);
                let alpha = (lr as f64 * bc2.sqrt() / bc1) as f32;
                for ((p, g), (m, v)) in pipe
                    .params_mut()
                    .iter_mut()
                    .zip(grads.iter())
                    .zip(self.m.iter_mut().zip(self.v.iter_mut()))
                {
                    for i in 0..p.value.len() {
                        m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
                        v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
                        p.value[i] -= alpha * m[i] / (v[i].sqrt() + eps);
                    }
                }
            }
        }
    }
}

/// Configuration for [`fit`].
#[derive(Clone, Copy, Debug)]
pub struct FitCfg {
    pub optimizer: Optimizer,
    /// Number of optimizer steps (each = one loss + gradient
    /// evaluation).
    pub iterations: usize,
}

/// What a [`fit`] run did.
#[derive(Clone, Debug)]
pub struct FitReport {
    /// Loss before the first update.
    pub initial_loss: f64,
    /// Loss at the last evaluation (before the final update is applied).
    pub final_loss: f64,
    /// Every evaluated loss, in order (`iterations` entries).
    pub losses: Vec<f64>,
}

/// Train `pipe`'s parameters in place: `iterations` rounds of
/// loss + exact gradients + one optimizer step. Deterministic — see the
/// module docs. Inputs are borrowed once and reused every round (full-
/// batch training; callers wanting stochasticity re-slice between
/// calls).
pub fn fit(pipe: &mut Pipeline, inputs: &[&[f32]], cfg: &FitCfg) -> Result<FitReport, LeapError> {
    cfg.optimizer.validate()?;
    if cfg.iterations == 0 {
        return Err(LeapError::InvalidArgument("fit needs at least one iteration".into()));
    }
    let mut state = OptState::new(pipe);
    let mut losses = Vec::with_capacity(cfg.iterations);
    for _ in 0..cfg.iterations {
        let (loss, grads) = pipe.loss_and_grads(inputs)?;
        losses.push(loss);
        state.step(&cfg.optimizer, pipe, &grads);
    }
    Ok(FitReport {
        initial_loss: losses[0],
        final_loss: *losses.last().expect("at least one iteration"),
        losses,
    })
}

/// Checkpoint framing: magic + format version + step counter +
/// parameter count, then per parameter its element count followed by
/// the value, first-moment and second-moment planes as little-endian
/// `f32` bytes. `to_le_bytes`/`from_le_bytes` round-trip every bit
/// pattern (including NaNs), so save→restore is exact by construction.
const CKPT_MAGIC: &[u8; 8] = b"LEAPCKPT";
const CKPT_VERSION: u32 = 1;

fn ckpt_err(what: &str) -> LeapError {
    LeapError::InvalidArgument(format!("checkpoint: {what}"))
}

fn ckpt_u32(bytes: &[u8], off: &mut usize) -> Result<u32, LeapError> {
    let end = off.checked_add(4).filter(|&e| e <= bytes.len()).ok_or_else(|| ckpt_err("truncated"))?;
    let v = u32::from_le_bytes(bytes[*off..end].try_into().expect("4 bytes"));
    *off = end;
    Ok(v)
}

fn ckpt_f32s(bytes: &[u8], off: &mut usize, out: &mut [f32]) -> Result<(), LeapError> {
    let need = out.len().checked_mul(4).ok_or_else(|| ckpt_err("length overflow"))?;
    let end = off.checked_add(need).filter(|&e| e <= bytes.len()).ok_or_else(|| ckpt_err("truncated"))?;
    for (i, o) in out.iter_mut().enumerate() {
        let a = *off + 4 * i;
        *o = f32::from_le_bytes(bytes[a..a + 4].try_into().expect("4 bytes"));
    }
    *off = end;
    Ok(())
}

/// A stateful trainer: one optimizer plus its per-parameter state.
///
/// [`fit`] and [`fit_batched`] drive one internally; construct your own
/// when you need a custom loop (eval-gated early stopping, learning-
/// rate schedules between calls) or checkpoint/resume. One update =
/// compute gradients however you like, then [`Fitter::step`].
pub struct Fitter {
    opt: Optimizer,
    state: OptState,
}

impl Fitter {
    /// Fresh state (zero moments, step counter 0) for `pipe`'s current
    /// parameter list. Fails on invalid optimizer hyper-parameters.
    pub fn new(pipe: &Pipeline, optimizer: Optimizer) -> Result<Fitter, LeapError> {
        optimizer.validate()?;
        Ok(Fitter { opt: optimizer, state: OptState::new(pipe) })
    }

    /// Number of optimizer steps taken so far (restored by
    /// [`Fitter::restore`], so Adam bias correction resumes exactly).
    pub fn steps(&self) -> u32 {
        self.state.t
    }

    /// Apply one optimizer update to `pipe`'s parameters from
    /// already-computed gradients (one buffer per parameter, same
    /// order as [`Pipeline::params`]).
    pub fn step(&mut self, pipe: &mut Pipeline, grads: &[Vec<f32>]) -> Result<(), LeapError> {
        if grads.len() != pipe.params().len() {
            return Err(LeapError::InvalidArgument(format!(
                "step got {} gradient buffers for {} parameters",
                grads.len(),
                pipe.params().len()
            )));
        }
        for (p, g) in pipe.params().iter().zip(grads.iter()) {
            if g.len() != p.shape.numel() {
                return Err(LeapError::InvalidArgument(format!(
                    "gradient for parameter '{}' has {} elements, expected {}",
                    p.name,
                    g.len(),
                    p.shape.numel()
                )));
            }
        }
        self.state.step(&self.opt, pipe, grads);
        Ok(())
    }

    /// Serialize training state — `pipe`'s parameter values plus this
    /// fitter's moments and step counter — to bytes. Bit-exact: see
    /// the framing comment on [`CKPT_MAGIC`].
    pub fn save(&self, pipe: &Pipeline) -> Vec<u8> {
        let params = pipe.params();
        let mut out = Vec::new();
        out.extend_from_slice(CKPT_MAGIC);
        out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.state.t.to_le_bytes());
        out.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for (i, p) in params.iter().enumerate() {
            out.extend_from_slice(&(p.shape.numel() as u32).to_le_bytes());
            for plane in [&p.value, &self.state.m[i], &self.state.v[i]] {
                for v in plane.iter() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        out
    }

    /// Restore training state saved by [`Fitter::save`] into `pipe`
    /// (parameter values) and this fitter (moments, step counter).
    /// The checkpoint must match `pipe`'s parameter list exactly;
    /// mismatches and malformed bytes are typed errors and leave a
    /// half-written state only in `self`/`pipe` values already
    /// validated (all size checks happen before any write).
    pub fn restore(&mut self, pipe: &mut Pipeline, bytes: &[u8]) -> Result<(), LeapError> {
        let mut off = 0usize;
        if bytes.len() < CKPT_MAGIC.len() || &bytes[..CKPT_MAGIC.len()] != CKPT_MAGIC {
            return Err(ckpt_err("bad magic"));
        }
        off += CKPT_MAGIC.len();
        let version = ckpt_u32(bytes, &mut off)?;
        if version != CKPT_VERSION {
            return Err(ckpt_err(&format!("unsupported version {version}")));
        }
        let t = ckpt_u32(bytes, &mut off)?;
        let nparams = ckpt_u32(bytes, &mut off)? as usize;
        if nparams != pipe.params().len() {
            return Err(ckpt_err(&format!(
                "holds {nparams} parameters, pipeline has {}",
                pipe.params().len()
            )));
        }
        // parse everything into scratch before touching live state, so
        // a truncated tail can't leave a torn restore behind
        let mut values = Vec::with_capacity(nparams);
        let mut ms = Vec::with_capacity(nparams);
        let mut vs = Vec::with_capacity(nparams);
        for i in 0..nparams {
            let numel = ckpt_u32(bytes, &mut off)? as usize;
            let want = pipe.params()[i].shape.numel();
            if numel != want {
                return Err(ckpt_err(&format!(
                    "parameter '{}' has {numel} elements, expected {want}",
                    pipe.params()[i].name
                )));
            }
            let mut value = vec![0.0f32; numel];
            let mut m = vec![0.0f32; numel];
            let mut v = vec![0.0f32; numel];
            ckpt_f32s(bytes, &mut off, &mut value)?;
            ckpt_f32s(bytes, &mut off, &mut m)?;
            ckpt_f32s(bytes, &mut off, &mut v)?;
            values.push(value);
            ms.push(m);
            vs.push(v);
        }
        if off != bytes.len() {
            return Err(ckpt_err("trailing bytes"));
        }
        for (p, value) in pipe.params_mut().iter_mut().zip(values) {
            p.value = value;
        }
        self.state.m = ms;
        self.state.v = vs;
        self.state.t = t;
        Ok(())
    }
}

/// Configuration for [`fit_batched`].
#[derive(Clone, Copy, Debug)]
pub struct BatchFitCfg {
    pub optimizer: Optimizer,
    /// Full passes over the item list.
    pub epochs: usize,
    /// Items per optimizer step; the last batch of an epoch may be
    /// shorter. Gradients are averaged over the batch.
    pub batch_size: usize,
    /// Worker threads for per-item gradient evaluation
    /// (0 = the default pool width). The result does not depend on
    /// this — aggregation is bit-identical to a sequential pass.
    pub threads: usize,
}

/// Mini-batch training over a corpus: each item is one input set for
/// the pipeline (one buffer per input slot, in
/// [`Pipeline::input_shapes`] order). Per step, the batch's items are
/// evaluated in parallel and their mean loss/gradients drive one
/// optimizer update. Deterministic for a fixed corpus order — items
/// are visited in the given order every epoch (shuffle between calls
/// for stochasticity).
pub fn fit_batched(
    pipe: &mut Pipeline,
    items: &[Vec<Vec<f32>>],
    cfg: &BatchFitCfg,
) -> Result<FitReport, LeapError> {
    if cfg.epochs == 0 {
        return Err(LeapError::InvalidArgument("fit_batched needs at least one epoch".into()));
    }
    if cfg.batch_size == 0 {
        return Err(LeapError::InvalidArgument("fit_batched batch_size must be ≥ 1".into()));
    }
    if items.is_empty() {
        return Err(LeapError::InvalidArgument("fit_batched needs at least one item".into()));
    }
    let mut fitter = Fitter::new(pipe, cfg.optimizer)?;
    let mut losses = Vec::with_capacity(cfg.epochs * items.len().div_ceil(cfg.batch_size));
    for _ in 0..cfg.epochs {
        for chunk in items.chunks(cfg.batch_size) {
            let pr: Vec<&[f32]> = pipe.params().iter().map(|p| p.value.as_slice()).collect();
            let ir: Vec<Vec<&[f32]>> =
                chunk.iter().map(|it| it.iter().map(|b| b.as_slice()).collect()).collect();
            let (loss, grads) = pipe.loss_and_grads_batch(&pr, &ir, cfg.threads)?;
            drop(pr);
            losses.push(loss);
            fitter.step(pipe, &grads)?;
        }
    }
    Ok(FitReport {
        initial_loss: losses[0],
        final_loss: *losses.last().expect("at least one batch"),
        losses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Shape;
    use crate::tape::PipelineBuilder;

    /// Tiny quadratic: L = ½‖p − b‖² with b an input — the optimizer
    /// must walk p toward b.
    fn quadratic(init: &[f32]) -> Pipeline {
        let mut pb = PipelineBuilder::new();
        let p = pb.param("p", Shape([init.len(), 1, 1]), init.to_vec()).unwrap();
        let b = pb.input(Shape([init.len(), 1, 1])).unwrap();
        let l = pb.l2_loss(p, b).unwrap();
        pb.set_loss(l).unwrap();
        pb.build().unwrap()
    }

    #[test]
    fn sgd_descends_a_quadratic() {
        let mut pipe = quadratic(&[0.0, 0.0, 0.0]);
        let target = [1.0f32, -2.0, 3.0];
        let report = fit(
            &mut pipe,
            &[&target],
            &FitCfg { optimizer: Optimizer::Sgd { lr: 0.5 }, iterations: 40 },
        )
        .unwrap();
        assert!(report.final_loss < 1e-6 * report.initial_loss.max(1.0));
        for (p, t) in pipe.params()[0].value.iter().zip(target.iter()) {
            assert!((p - t).abs() < 1e-3, "{p} vs {t}");
        }
    }

    #[test]
    fn adam_descends_a_quadratic() {
        let mut pipe = quadratic(&[5.0, -5.0]);
        let target = [0.5f32, 0.25];
        let report = fit(
            &mut pipe,
            &[&target],
            &FitCfg { optimizer: Optimizer::adam(0.5), iterations: 200 },
        )
        .unwrap();
        assert!(
            report.final_loss < 1e-4,
            "adam should converge: {} → {}",
            report.initial_loss,
            report.final_loss
        );
    }

    #[test]
    fn fit_is_bit_deterministic() {
        let run = || {
            let mut pipe = quadratic(&[2.0, -1.0, 0.5, 4.0]);
            let target = [0.1f32, 0.2, 0.3, 0.4];
            let report = fit(
                &mut pipe,
                &[&target],
                &FitCfg { optimizer: Optimizer::adam(0.1), iterations: 25 },
            )
            .unwrap();
            (pipe.params()[0].value.clone(), report.losses)
        };
        let (p1, l1) = run();
        let (p2, l2) = run();
        let b1: Vec<u32> = p1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = p2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2, "two identical fits must produce bit-identical params");
        let lb1: Vec<u64> = l1.iter().map(|v| v.to_bits()).collect();
        let lb2: Vec<u64> = l2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(lb1, lb2);
    }

    #[test]
    fn checkpoint_save_restore_resumes_bit_identically() {
        let target = [0.3f32, -0.7, 1.1];
        let opt = Optimizer::adam(0.2);
        // uninterrupted reference: 12 steps
        let mut pipe_a = quadratic(&[2.0, -1.0, 0.5]);
        let mut fit_a = Fitter::new(&pipe_a, opt).unwrap();
        for _ in 0..12 {
            let (_, g) = pipe_a.loss_and_grads(&[&target]).unwrap();
            fit_a.step(&mut pipe_a, &g).unwrap();
        }
        // interrupted at 5: save, restore into a FRESH pipe+fitter
        // with junk initialization (restore must overwrite), finish
        let mut pipe_b = quadratic(&[2.0, -1.0, 0.5]);
        let mut fit_b = Fitter::new(&pipe_b, opt).unwrap();
        for _ in 0..5 {
            let (_, g) = pipe_b.loss_and_grads(&[&target]).unwrap();
            fit_b.step(&mut pipe_b, &g).unwrap();
        }
        let bytes = fit_b.save(&pipe_b);
        let mut pipe_c = quadratic(&[9.0, 9.0, 9.0]);
        let mut fit_c = Fitter::new(&pipe_c, opt).unwrap();
        fit_c.restore(&mut pipe_c, &bytes).unwrap();
        assert_eq!(fit_c.steps(), 5, "step counter must survive the checkpoint");
        for _ in 0..7 {
            let (_, g) = pipe_c.loss_and_grads(&[&target]).unwrap();
            fit_c.step(&mut pipe_c, &g).unwrap();
        }
        let ba: Vec<u32> = pipe_a.params()[0].value.iter().map(|v| v.to_bits()).collect();
        let bc: Vec<u32> = pipe_c.params()[0].value.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bc, "resumed run must be bit-identical to the uninterrupted run");
    }

    #[test]
    fn corrupt_checkpoints_are_typed_errors() {
        let pipe = quadratic(&[0.0, 0.0]);
        let fitter = Fitter::new(&pipe, Optimizer::adam(0.1)).unwrap();
        let good = fitter.save(&pipe);
        // restoring the good bytes works
        let mut pipe2 = quadratic(&[1.0, 1.0]);
        let mut f2 = Fitter::new(&pipe2, Optimizer::adam(0.1)).unwrap();
        f2.restore(&mut pipe2, &good).unwrap();
        assert_eq!(pipe2.params()[0].value, vec![0.0, 0.0]);
        // truncated / bad magic / short header are typed errors
        for bad in [&good[..good.len() - 1], &b"NOTACKPT"[..], &good[..4]] {
            let e = f2.restore(&mut pipe2, bad).unwrap_err();
            assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        }
        // trailing garbage is rejected too
        let mut long = good.clone();
        long.push(0);
        assert!(f2.restore(&mut pipe2, &long).is_err());
        // parameter-shape mismatch: 2-element checkpoint into 3-element pipe
        let mut pipe3 = quadratic(&[0.0, 0.0, 0.0]);
        let mut f3 = Fitter::new(&pipe3, Optimizer::adam(0.1)).unwrap();
        let e = f3.restore(&mut pipe3, &good).unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
    }

    #[test]
    fn fit_batched_descends_and_is_thread_invariant() {
        // six items, mean ½‖p−bᵢ‖² — mini-batch training must descend
        // and must not depend on the worker-thread count
        let items: Vec<Vec<Vec<f32>>> = (0..6)
            .map(|i| vec![vec![0.1 * i as f32, 1.0 - 0.1 * i as f32]])
            .collect();
        let run = |threads: usize| {
            let mut pipe = quadratic(&[3.0, -3.0]);
            let rep = fit_batched(
                &mut pipe,
                &items,
                &BatchFitCfg {
                    optimizer: Optimizer::adam(0.3),
                    epochs: 30,
                    batch_size: 4,
                    threads,
                },
            )
            .unwrap();
            (pipe.params()[0].value.clone(), rep)
        };
        let (p1, r1) = run(1);
        let (p2, r2) = run(3);
        assert!(r1.final_loss < r1.initial_loss, "{} → {}", r1.initial_loss, r1.final_loss);
        // 30 epochs × ⌈6/4⌉ batches
        assert_eq!(r1.losses.len(), 60);
        let b1: Vec<u32> = p1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = p2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2, "batched training must not depend on thread count");
        let lb1: Vec<u64> = r1.losses.iter().map(|v| v.to_bits()).collect();
        let lb2: Vec<u64> = r2.losses.iter().map(|v| v.to_bits()).collect();
        assert_eq!(lb1, lb2);
    }

    #[test]
    fn bad_optimizer_args_are_typed() {
        let mut pipe = quadratic(&[0.0]);
        let t = [1.0f32];
        for opt in [
            Optimizer::Sgd { lr: -1.0 },
            Optimizer::Sgd { lr: f32::NAN },
            Optimizer::Adam { lr: 0.1, beta1: 1.5, beta2: 0.999, eps: 1e-8 },
            Optimizer::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 0.0 },
        ] {
            let e = fit(&mut pipe, &[&t], &FitCfg { optimizer: opt, iterations: 1 }).unwrap_err();
            assert!(matches!(e, LeapError::InvalidArgument(_)), "{opt:?}: {e:?}");
        }
        let e = fit(
            &mut pipe,
            &[&t],
            &FitCfg { optimizer: Optimizer::Sgd { lr: 0.1 }, iterations: 0 },
        )
        .unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)));
    }
}
