//! [`PipelineBuilder`] — construct a validated [`Pipeline`].
//!
//! Every edge is checked as it is added (operator chaining, elementwise
//! shape agreement, scalar-ness of scale factors and losses, filter
//! half-spectrum lengths), so a built [`Pipeline`] can never fail a
//! shape check at evaluation time. All failures are typed
//! [`LeapError`]s — this is the tape's half of the front-door contract
//! ([`crate::api`]): panicking kernels below, `Result`s at every surface
//! a user (or the wire) can reach.

use std::sync::Arc;

use crate::api::LeapError;
use crate::ops::{LinearOp, Shape};
use crate::util::fft::next_pow2;

use super::{Node, NodeId, NodeKind, OpEntry, OpRef, ParamDef, Pipeline};

/// Hard cap on a single node's element count (matches the wire payload
/// cap in f32s): wire-registered graphs cannot demand absurd buffers.
/// Public so [`super::spec`] can validate untrusted shapes *before*
/// allocating anything from them.
pub const MAX_NODE_ELEMENTS: usize = 1 << 28;

/// Hard cap on graph size — far above any real unrolled pipeline, low
/// enough that a hostile wire spec cannot DoS the registry.
pub const MAX_NODES: usize = 4096;

/// Derive an **odd** kernel size `k` from a weight node's tap count
/// (`k²` for 2-D, `k³` for 3-D — `dim` is the exponent). Typed error if
/// the count is not an exact odd power: the kernel size is structural
/// (same-padding needs `k` odd) and is never carried separately, so the
/// weight shape is the single source of truth.
fn odd_kernel_root(taps: usize, dim: u32) -> Result<usize, LeapError> {
    let k = (taps as f64).powf(1.0 / dim as f64).round() as usize;
    // float roots can land one off for large counts; scan the neighbours
    let k = [k.saturating_sub(1), k, k + 1]
        .into_iter()
        .find(|&c| c.checked_pow(dim).map(|p| p == taps).unwrap_or(false));
    match k {
        Some(k) if k % 2 == 1 => Ok(k),
        _ => Err(LeapError::InvalidArgument(format!(
            "conv weight dim 0 must be an odd kernel size to the power {dim} (got {taps} taps)"
        ))),
    }
}

/// Builder for a [`Pipeline`]; see the module docs.
#[derive(Default)]
pub struct PipelineBuilder {
    nodes: Vec<Node>,
    ops: Vec<OpEntry>,
    input_shapes: Vec<Shape>,
    params: Vec<ParamDef>,
    output: Option<NodeId>,
    loss: Option<NodeId>,
}

impl PipelineBuilder {
    pub fn new() -> PipelineBuilder {
        PipelineBuilder::default()
    }

    fn push(&mut self, kind: NodeKind, shape: Shape) -> Result<NodeId, LeapError> {
        if self.nodes.len() >= MAX_NODES {
            return Err(LeapError::InvalidArgument(format!(
                "pipeline exceeds {MAX_NODES} nodes"
            )));
        }
        if shape.numel() == 0 || shape.numel() > MAX_NODE_ELEMENTS {
            return Err(LeapError::InvalidArgument(format!(
                "node shape {:?} is empty or above {MAX_NODE_ELEMENTS} elements",
                shape.0
            )));
        }
        self.nodes.push(Node { kind, shape });
        Ok(NodeId(self.nodes.len() - 1))
    }

    fn node(&self, id: NodeId) -> Result<&Node, LeapError> {
        self.nodes.get(id.0).ok_or_else(|| {
            LeapError::InvalidArgument(format!("node id {} is not in this pipeline", id.0))
        })
    }

    fn same_numel(&self, a: NodeId, b: NodeId) -> Result<Shape, LeapError> {
        let (sa, sb) = (self.node(a)?.shape, self.node(b)?.shape);
        if sa.numel() != sb.numel() {
            return Err(LeapError::ShapeMismatch {
                what: "elementwise operands",
                expected: sa.numel(),
                got: sb.numel(),
            });
        }
        Ok(sa)
    }

    /// Register a named operator (the name is the wire identity — the
    /// serving side rebinds `"scan"` to the session's pinned plan).
    /// Duplicate names are rejected.
    pub fn op(&mut self, name: &str, op: Arc<dyn LinearOp>) -> Result<OpRef, LeapError> {
        if self.ops.iter().any(|e| e.name == name) {
            return Err(LeapError::InvalidArgument(format!(
                "operator {name:?} is already registered"
            )));
        }
        self.ops.push(OpEntry { name: name.to_string(), op });
        Ok(OpRef(self.ops.len() - 1))
    }

    /// Declare an input slot (bound per evaluation, in declaration
    /// order).
    pub fn input(&mut self, shape: Shape) -> Result<NodeId, LeapError> {
        let slot = self.input_shapes.len();
        let id = self.push(NodeKind::Input { slot }, shape)?;
        self.input_shapes.push(shape);
        Ok(id)
    }

    /// Declare a trainable parameter with its initial value.
    pub fn param(
        &mut self,
        name: &str,
        shape: Shape,
        init: Vec<f32>,
    ) -> Result<NodeId, LeapError> {
        if init.len() != shape.numel() {
            return Err(LeapError::ShapeMismatch {
                what: "parameter init",
                expected: shape.numel(),
                got: init.len(),
            });
        }
        if self.params.iter().any(|p| p.name == name) {
            return Err(LeapError::InvalidArgument(format!(
                "parameter {name:?} is already declared"
            )));
        }
        let pid = self.params.len();
        self.params.push(ParamDef { name: name.to_string(), shape, value: init });
        self.push(NodeKind::Param { pid }, shape)
    }

    /// Declare a scalar (numel-1) trainable parameter — step sizes,
    /// gains.
    pub fn scalar_param(&mut self, name: &str, init: f32) -> Result<NodeId, LeapError> {
        self.param(name, Shape([1, 1, 1]), vec![init])
    }

    /// Declare a trainable parameter **without** a stored value (the
    /// wire-registration path: parameter values travel per request, so
    /// storing a zero placeholder would pin up to a frame's worth of
    /// memory per registered pipeline for nothing). Pipelines holding
    /// such parameters must be evaluated through the explicit-parameter
    /// `*_with` entry points (the stored-value entry points return a
    /// typed error) or be given values via
    /// [`Pipeline::set_params`] first.
    pub fn param_uninit(&mut self, name: &str, shape: Shape) -> Result<NodeId, LeapError> {
        if self.params.iter().any(|p| p.name == name) {
            return Err(LeapError::InvalidArgument(format!(
                "parameter {name:?} is already declared"
            )));
        }
        let pid = self.params.len();
        self.params.push(ParamDef { name: name.to_string(), shape, value: Vec::new() });
        self.push(NodeKind::Param { pid }, shape)
    }

    /// A constant tensor filled with `v`.
    pub fn fill(&mut self, shape: Shape, v: f32) -> Result<NodeId, LeapError> {
        if !v.is_finite() {
            return Err(LeapError::InvalidArgument(format!("fill value must be finite, got {v}")));
        }
        self.push(NodeKind::Fill { v }, shape)
    }

    /// `y = A·x` through a registered operator.
    pub fn apply(&mut self, op: OpRef, x: NodeId) -> Result<NodeId, LeapError> {
        let entry = self.ops.get(op.0).ok_or_else(|| {
            LeapError::InvalidArgument(format!("operator ref {} is not registered", op.0))
        })?;
        let (dn, rs) = (entry.op.domain_shape(), entry.op.range_shape());
        let xs = self.node(x)?.shape;
        if xs.numel() != dn.numel() {
            return Err(LeapError::ShapeMismatch {
                what: "operator domain",
                expected: dn.numel(),
                got: xs.numel(),
            });
        }
        self.push(NodeKind::Apply { op: op.0, x }, rs)
    }

    /// `x = Aᵀ·y` through a registered operator.
    pub fn adjoint(&mut self, op: OpRef, y: NodeId) -> Result<NodeId, LeapError> {
        let entry = self.ops.get(op.0).ok_or_else(|| {
            LeapError::InvalidArgument(format!("operator ref {} is not registered", op.0))
        })?;
        let (dn, rs) = (entry.op.domain_shape(), entry.op.range_shape());
        let ys = self.node(y)?.shape;
        if ys.numel() != rs.numel() {
            return Err(LeapError::ShapeMismatch {
                what: "operator range",
                expected: rs.numel(),
                got: ys.numel(),
            });
        }
        self.push(NodeKind::Adjoint { op: op.0, y }, dn)
    }

    /// `a + b` (same numel).
    pub fn add(&mut self, a: NodeId, b: NodeId) -> Result<NodeId, LeapError> {
        let s = self.same_numel(a, b)?;
        self.push(NodeKind::Add { a, b }, s)
    }

    /// `a − b` (same numel).
    pub fn sub(&mut self, a: NodeId, b: NodeId) -> Result<NodeId, LeapError> {
        let s = self.same_numel(a, b)?;
        self.push(NodeKind::Sub { a, b }, s)
    }

    /// `a ⊙ b` elementwise (same numel) — learned per-element weights.
    pub fn mul(&mut self, a: NodeId, b: NodeId) -> Result<NodeId, LeapError> {
        let s = self.same_numel(a, b)?;
        self.push(NodeKind::Mul { a, b }, s)
    }

    /// `s·x` with `s` scalar (numel 1).
    pub fn scale(&mut self, x: NodeId, s: NodeId) -> Result<NodeId, LeapError> {
        let ss = self.node(s)?.shape;
        if ss.numel() != 1 {
            return Err(LeapError::ShapeMismatch {
                what: "scale factor",
                expected: 1,
                got: ss.numel(),
            });
        }
        let xs = self.node(x)?.shape;
        self.push(NodeKind::Scale { x, s }, xs)
    }

    /// `max(x, 0)`.
    pub fn relu(&mut self, x: NodeId) -> Result<NodeId, LeapError> {
        let s = self.node(x)?.shape;
        self.push(NodeKind::Relu { x }, s)
    }

    /// `clamp(x, lo, hi)` with finite `lo ≤ hi`.
    pub fn clamp(&mut self, x: NodeId, lo: f32, hi: f32) -> Result<NodeId, LeapError> {
        if !(lo.is_finite() && hi.is_finite() && lo <= hi) {
            return Err(LeapError::InvalidArgument(format!(
                "clamp needs finite lo ≤ hi (got {lo}, {hi})"
            )));
        }
        let s = self.node(x)?.shape;
        self.push(NodeKind::Clamp { x, lo, hi }, s)
    }

    /// Filter every trailing-dimension row of `x` with the learnable
    /// half-spectrum `w` (see [`NodeKind::FilterRows`]). `x`'s shape is
    /// read as `[.., .., ncols]`; `w` must have exactly
    /// `next_pow2(2·ncols)/2 + 1` elements — initialize it from
    /// [`crate::recon::filters::ramp_half_spectrum`] for a ramp start.
    pub fn filter_rows(&mut self, x: NodeId, w: NodeId) -> Result<NodeId, LeapError> {
        let xs = self.node(x)?.shape;
        let ncols = xs.0[2];
        if ncols < 2 {
            return Err(LeapError::InvalidArgument(format!(
                "filter_rows needs rows of ≥ 2 samples (shape {:?})",
                xs.0
            )));
        }
        let nfft = next_pow2(2 * ncols);
        let want = nfft / 2 + 1;
        let wsh = self.node(w)?.shape;
        if wsh.numel() != want {
            return Err(LeapError::ShapeMismatch {
                what: "filter half-spectrum",
                expected: want,
                got: wsh.numel(),
            });
        }
        self.push(NodeKind::FilterRows { x, w, ncols, nfft }, xs)
    }

    /// 2-D same-padding convolution (cross-correlation) of `x` with
    /// learnable weights `w` and bias `b` (see [`NodeKind::Conv2d`]).
    /// `x` is read as `[w, h, cin]` (channels on the slab axis); the
    /// weight node's shape must be **structurally** `[k², cin, cout]`
    /// with `k` odd (the kernel size is derived from it), and the bias
    /// must have `cout` elements. Output: `[w, h, cout]`.
    pub fn conv2d(&mut self, x: NodeId, w: NodeId, b: NodeId) -> Result<NodeId, LeapError> {
        let xs = self.node(x)?.shape;
        let (wd, ht, cin) = (xs.0[0], xs.0[1], xs.0[2]);
        let ws = self.node(w)?.shape;
        let k = odd_kernel_root(ws.0[0], 2)?;
        if ws.0[1] != cin {
            return Err(LeapError::ShapeMismatch {
                what: "conv weight input channels",
                expected: cin,
                got: ws.0[1],
            });
        }
        let cout = ws.0[2];
        let bs = self.node(b)?.shape;
        if bs.numel() != cout {
            return Err(LeapError::ShapeMismatch {
                what: "conv bias",
                expected: cout,
                got: bs.numel(),
            });
        }
        self.push(NodeKind::Conv2d { x, w, b, k }, Shape([wd, ht, cout]))
    }

    /// 3-D same-padding convolution over the z-slabs of `x` (see
    /// [`NodeKind::Conv3d`]). `x` is read as `[w, h, cin·nz]` — the
    /// caller states `cin`, which must divide the slab count (a raw
    /// volume is `cin = 1`). The weight node's shape must be
    /// structurally `[k³, cin, cout]` with `k` odd; bias `cout`
    /// elements. Output: `[w, h, cout·nz]`.
    pub fn conv3d(
        &mut self,
        x: NodeId,
        w: NodeId,
        b: NodeId,
        cin: usize,
    ) -> Result<NodeId, LeapError> {
        let xs = self.node(x)?.shape;
        let (wd, ht, slabs) = (xs.0[0], xs.0[1], xs.0[2]);
        if cin == 0 || slabs % cin != 0 {
            return Err(LeapError::InvalidArgument(format!(
                "conv3d input channels {cin} must divide the {slabs} z-slabs"
            )));
        }
        let nz = slabs / cin;
        let ws = self.node(w)?.shape;
        let k = odd_kernel_root(ws.0[0], 3)?;
        if ws.0[1] != cin {
            return Err(LeapError::ShapeMismatch {
                what: "conv weight input channels",
                expected: cin,
                got: ws.0[1],
            });
        }
        let cout = ws.0[2];
        let bs = self.node(b)?.shape;
        if bs.numel() != cout {
            return Err(LeapError::ShapeMismatch {
                what: "conv bias",
                expected: cout,
                got: bs.numel(),
            });
        }
        let oslabs = cout.checked_mul(nz).filter(|&n| n <= MAX_NODE_ELEMENTS).ok_or_else(
            || {
                LeapError::InvalidArgument(format!(
                    "conv3d output slab count {cout}·{nz} overflows"
                ))
            },
        )?;
        self.push(NodeKind::Conv3d { x, w, b, k, cin }, Shape([wd, ht, oslabs]))
    }

    /// Factor-`f` average pooling per channel slab (`[w, h, c] →
    /// [w/f, h/f, c]`); `f` must divide both spatial dimensions.
    pub fn avg_pool(&mut self, x: NodeId, f: usize) -> Result<NodeId, LeapError> {
        let xs = self.node(x)?.shape;
        if f == 0 || xs.0[0] % f != 0 || xs.0[1] % f != 0 {
            return Err(LeapError::InvalidArgument(format!(
                "pool factor {f} must be ≥ 1 and divide the spatial dims {:?}",
                [xs.0[0], xs.0[1]]
            )));
        }
        self.push(NodeKind::AvgPool { x, f }, Shape([xs.0[0] / f, xs.0[1] / f, xs.0[2]]))
    }

    /// Factor-`f` nearest-neighbour upsampling per channel slab
    /// (`[w, h, c] → [w·f, h·f, c]`).
    pub fn upsample(&mut self, x: NodeId, f: usize) -> Result<NodeId, LeapError> {
        let xs = self.node(x)?.shape;
        if f == 0 {
            return Err(LeapError::InvalidArgument("upsample factor must be ≥ 1".into()));
        }
        // guard the shape arithmetic itself before push() re-checks the
        // element cap — a hostile spec's factor must not overflow usize
        let (ow, oh) = (xs.0[0].checked_mul(f), xs.0[1].checked_mul(f));
        let numel = ow
            .zip(oh)
            .and_then(|(ow, oh)| ow.checked_mul(oh))
            .and_then(|p| p.checked_mul(xs.0[2]));
        match numel {
            Some(n) if n <= MAX_NODE_ELEMENTS => {}
            _ => {
                return Err(LeapError::InvalidArgument(format!(
                    "upsample ×{f} of {:?} overflows or exceeds {MAX_NODE_ELEMENTS} elements",
                    xs.0
                )))
            }
        }
        self.push(
            NodeKind::Upsample { x, f },
            Shape([xs.0[0] * f, xs.0[1] * f, xs.0[2]]),
        )
    }

    /// `a + b` as a residual/skip connection (same math as [`Self::add`];
    /// a distinct node kind — see [`NodeKind::Residual`]).
    pub fn residual(&mut self, a: NodeId, b: NodeId) -> Result<NodeId, LeapError> {
        let s = self.same_numel(a, b)?;
        self.push(NodeKind::Residual { a, b }, s)
    }

    /// Scalar node `½‖pred − target‖²`.
    pub fn l2_loss(&mut self, pred: NodeId, target: NodeId) -> Result<NodeId, LeapError> {
        self.same_numel(pred, target)?;
        self.push(NodeKind::L2Loss { pred, target }, Shape([1, 1, 1]))
    }

    /// Scalar node `Σ max(pred,ε) − target·ln max(pred,ε)` (Poisson
    /// NLL; callers must feed `target ≥ 0`, as MLEM does).
    pub fn poisson_loss(&mut self, pred: NodeId, target: NodeId) -> Result<NodeId, LeapError> {
        self.same_numel(pred, target)?;
        self.push(NodeKind::PoissonLoss { pred, target }, Shape([1, 1, 1]))
    }

    /// Designate the pipeline's output tensor (what [`Pipeline::eval`]
    /// returns — e.g. the reconstruction).
    pub fn set_output(&mut self, n: NodeId) -> Result<(), LeapError> {
        self.node(n)?;
        self.output = Some(n);
        Ok(())
    }

    /// Designate the scalar loss node [`Pipeline::loss_and_grads`]
    /// differentiates. Must be an [`NodeKind::L2Loss`] or
    /// [`NodeKind::PoissonLoss`] node — only those record the f64 loss
    /// value the evaluation reports (a derived scalar like
    /// `scale(l2, λ)` would differentiate fine but *report* a fabricated
    /// 0.0 loss, so it is refused rather than silently miscounted).
    pub fn set_loss(&mut self, n: NodeId) -> Result<(), LeapError> {
        let node = self.node(n)?;
        if node.shape.numel() != 1 {
            return Err(LeapError::ShapeMismatch {
                what: "loss node",
                expected: 1,
                got: node.shape.numel(),
            });
        }
        if !matches!(node.kind, NodeKind::L2Loss { .. } | NodeKind::PoissonLoss { .. }) {
            return Err(LeapError::InvalidArgument(
                "the loss must be an l2/poisson loss node (derived scalars cannot report \
                 their f64 value)"
                    .into(),
            ));
        }
        self.loss = Some(n);
        Ok(())
    }

    /// Finalize: compute the needs-gradient marking and return the
    /// immutable [`Pipeline`]. A pipeline without a loss node is legal
    /// (inference-only) — `loss_and_grads` on it is a typed error.
    pub fn build(self) -> Result<Pipeline, LeapError> {
        if self.nodes.is_empty() {
            return Err(LeapError::InvalidArgument("pipeline has no nodes".into()));
        }
        // needs_grad: forward sweep works because ids are topological —
        // a node needs grad iff it is a Param or reads one that does
        let mut needs = vec![false; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            needs[id] = match &node.kind {
                NodeKind::Param { .. } => true,
                NodeKind::Input { .. } | NodeKind::Fill { .. } => false,
                NodeKind::Apply { x, .. } => needs[x.0],
                NodeKind::Adjoint { y, .. } => needs[y.0],
                NodeKind::Add { a, b }
                | NodeKind::Sub { a, b }
                | NodeKind::Mul { a, b } => needs[a.0] || needs[b.0],
                NodeKind::Scale { x, s } => needs[x.0] || needs[s.0],
                NodeKind::Relu { x } | NodeKind::Clamp { x, .. } => needs[x.0],
                NodeKind::FilterRows { x, w, .. } => needs[x.0] || needs[w.0],
                NodeKind::Conv2d { x, w, b, .. } | NodeKind::Conv3d { x, w, b, .. } => {
                    needs[x.0] || needs[w.0] || needs[b.0]
                }
                NodeKind::AvgPool { x, .. } | NodeKind::Upsample { x, .. } => needs[x.0],
                NodeKind::Residual { a, b } => needs[a.0] || needs[b.0],
                NodeKind::L2Loss { pred, target } | NodeKind::PoissonLoss { pred, target } => {
                    needs[pred.0] || needs[target.0]
                }
            };
        }
        if let Some(l) = self.loss {
            if !needs[l.0] {
                return Err(LeapError::InvalidArgument(
                    "loss node does not depend on any parameter".into(),
                ));
            }
        }
        Ok(Pipeline {
            nodes: self.nodes,
            ops: self.ops,
            input_shapes: self.input_shapes,
            params: self.params,
            output: self.output,
            loss: self.loss,
            needs_grad: needs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{Geometry, ParallelBeam, VolumeGeometry};
    use crate::ops::PlanOp;
    use crate::projector::{Model, Projector};

    fn scan_op() -> Arc<dyn LinearOp> {
        let vg = VolumeGeometry::slice2d(8, 8, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(5, 12, 1.0));
        Arc::new(PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(1)))
    }

    #[test]
    fn shape_mismatches_are_typed_at_build_time() {
        let op = scan_op();
        let mut pb = PipelineBuilder::new();
        let a = pb.op("scan", op.clone()).unwrap();
        let wrong = pb.fill(Shape([3, 1, 1]), 0.0).unwrap();
        let e = pb.apply(a, wrong).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "operator domain", .. }), "{e:?}");
        let x = pb.fill(op.domain_shape(), 0.0).unwrap();
        let e = pb.adjoint(a, x).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "operator range", .. }));
        let y = pb.fill(op.range_shape(), 0.0).unwrap();
        let e = pb.add(x, y).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "elementwise operands", .. }));
        let e = pb.scale(x, y).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "scale factor", .. }));
        let e = pb.set_loss(x).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "loss node", .. }));
        // a scalar that is not a loss node cannot report an f64 loss
        let scalar = pb.fill(Shape([1, 1, 1]), 0.5).unwrap();
        let e = pb.set_loss(scalar).unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
        let e = pb.clamp(x, 1.0, 0.0).unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)));
    }

    #[test]
    fn filter_rows_validates_half_spectrum_length() {
        let op = scan_op();
        let mut pb = PipelineBuilder::new();
        let sino = pb.input(op.range_shape()).unwrap(); // ncols = 12 → nfft = 32
        let short = pb.param("w", Shape([5, 1, 1]), vec![1.0; 5]).unwrap();
        let e = pb.filter_rows(sino, short).unwrap_err();
        assert_eq!(
            e,
            LeapError::ShapeMismatch { what: "filter half-spectrum", expected: 17, got: 5 }
        );
        let w = pb.param("w2", Shape([17, 1, 1]), vec![1.0; 17]).unwrap();
        let f = pb.filter_rows(sino, w).unwrap();
        pb.set_output(f).unwrap();
        let pipe = pb.build().unwrap();
        assert_eq!(pipe.output_shape().unwrap(), op.range_shape());
    }

    #[test]
    fn loss_must_reach_a_param() {
        let op = scan_op();
        let mut pb = PipelineBuilder::new();
        let _unused = pb.param("p", Shape([2, 1, 1]), vec![0.0; 2]).unwrap();
        let x = pb.input(op.domain_shape()).unwrap();
        let y = pb.input(op.domain_shape()).unwrap();
        let l = pb.l2_loss(x, y).unwrap();
        pb.set_loss(l).unwrap();
        let e = pb.build().unwrap_err();
        assert!(matches!(e, LeapError::InvalidArgument(_)), "{e:?}");
    }

    #[test]
    fn conv_and_pool_shapes_are_validated_structurally() {
        let mut pb = PipelineBuilder::new();
        let x = pb.input(Shape([8, 6, 2])).unwrap(); // [w, h, cin=2]
        // an even tap count (4² = 16) is not an odd kernel
        let w_even = pb.fill(Shape([16, 2, 3]), 0.1).unwrap();
        let b3 = pb.fill(Shape([3, 1, 1]), 0.0).unwrap();
        assert!(matches!(pb.conv2d(x, w_even, b3), Err(LeapError::InvalidArgument(_))));
        // channel mismatch: weight says cin = 4, x has 2
        let w_badc = pb.fill(Shape([9, 4, 3]), 0.1).unwrap();
        let e = pb.conv2d(x, w_badc, b3).unwrap_err();
        assert!(
            matches!(e, LeapError::ShapeMismatch { what: "conv weight input channels", .. }),
            "{e:?}"
        );
        // bias count must equal cout
        let w_ok = pb.fill(Shape([9, 2, 3]), 0.1).unwrap();
        let b_bad = pb.fill(Shape([2, 1, 1]), 0.0).unwrap();
        let e = pb.conv2d(x, w_ok, b_bad).unwrap_err();
        assert!(matches!(e, LeapError::ShapeMismatch { what: "conv bias", .. }), "{e:?}");
        // the good case produces [w, h, cout]
        let y = pb.conv2d(x, w_ok, b3).unwrap();
        assert_eq!(pb.node(y).unwrap().shape, Shape([8, 6, 3]));
        // conv3d: cin must divide the slab count
        let w3 = pb.fill(Shape([27, 2, 2]), 0.1).unwrap();
        let b2 = pb.fill(Shape([2, 1, 1]), 0.0).unwrap();
        assert!(matches!(pb.conv3d(x, w3, b2, 3), Err(LeapError::InvalidArgument(_))));
        let y3 = pb.conv3d(x, w3, b2, 2).unwrap(); // nz = 1, cout = 2
        assert_eq!(pb.node(y3).unwrap().shape, Shape([8, 6, 2]));
        // pooling must divide the spatial dims; upsample scales them
        assert!(matches!(pb.avg_pool(x, 3), Err(LeapError::InvalidArgument(_))));
        let p = pb.avg_pool(x, 2).unwrap();
        assert_eq!(pb.node(p).unwrap().shape, Shape([4, 3, 2]));
        let u = pb.upsample(p, 2).unwrap();
        assert_eq!(pb.node(u).unwrap().shape, Shape([8, 6, 2]));
        // residual needs matching numel
        assert!(pb.residual(x, p).is_err());
        assert!(pb.residual(x, u).is_ok());
    }

    #[test]
    fn duplicate_names_are_rejected() {
        let op = scan_op();
        let mut pb = PipelineBuilder::new();
        pb.op("scan", op.clone()).unwrap();
        assert!(pb.op("scan", op.clone()).is_err());
        pb.param("w", Shape([1, 1, 1]), vec![0.0]).unwrap();
        assert!(pb.param("w", Shape([1, 1, 1]), vec![0.0]).is_err());
    }
}
