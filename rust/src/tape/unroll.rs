//! Unrolled-solver and learned-FBP pipeline builders.
//!
//! These are the two canonical trainable-reconstruction shapes the tape
//! exists for (cf. learned primal-dual / LEARN-style unrolling and
//! learned-filter FBP in the TorchRadon/PYRO-NN ecosystems):
//!
//! * [`unrolled_gd`] — K iterations of gradient descent on the data-fit
//!   `½‖Ax − b‖²`, `x_{k+1} = [x_k − s_k·Aᵀ(A·x_k − b)]₊`, with one
//!   **learnable step size** `s_k` per iteration (this is SIRT-shaped:
//!   SIRT is exactly this update with fixed preconditioned steps).
//!   Supervised training loss `½‖x_K − truth‖²`.
//! * [`learned_fbp`] — FBP with every hand-designed ingredient made
//!   trainable: `x̂ = g · Aᵀ( m ⊙ filter_w(b) )` with a learnable
//!   half-spectrum filter `w` (initialized to the analytic apodized
//!   ramp, so iteration 0 *is* classical FBP's filter), learnable
//!   per-sample sinogram weights `m` (initialized to 1 — room for the
//!   fan-beam cosine weighting FBP hard-codes), and a learnable scalar
//!   gain `g`. Supervised L2 loss against the truth volume.
//!
//! Both declare inputs `[measurements, truth]` in that order and mark
//! the reconstruction as the pipeline output, so after training
//! [`super::Pipeline::eval`] reconstructs new data with the learned
//! parameters (the truth slot is only read by the loss — feed zeros at
//! inference, or rebuild without the loss).

use std::sync::Arc;

use crate::api::LeapError;
use crate::ops::LinearOp;
use crate::recon::filters::ramp_half_spectrum;
use crate::recon::Window;
use crate::util::fft::next_pow2;

use super::{Pipeline, PipelineBuilder};

/// Configuration for [`unrolled_gd`].
#[derive(Clone, Copy, Debug)]
pub struct UnrollCfg {
    /// K, the number of unrolled gradient steps (≥ 1).
    pub iterations: usize,
    /// Initial value of every learnable step size (a stable choice is
    /// `1/L` with `L` from
    /// [`crate::recon::fista_tv::power_iter_lipschitz_op`]).
    pub step_init: f32,
    /// Apply a non-negativity relu after each update (projected GD).
    pub nonneg: bool,
}

/// Build a K-step unrolled gradient-descent pipeline over `a` (see the
/// module docs). Inputs: `[measurements (range), truth (domain)]`;
/// params: `step0..step{K-1}`; output `x_K`; loss `½‖x_K − truth‖²`.
pub fn unrolled_gd(a: Arc<dyn LinearOp>, cfg: &UnrollCfg) -> Result<Pipeline, LeapError> {
    if cfg.iterations == 0 {
        return Err(LeapError::InvalidArgument("unroll needs at least one iteration".into()));
    }
    if !(cfg.step_init.is_finite() && cfg.step_init > 0.0) {
        return Err(LeapError::InvalidArgument(format!(
            "step init must be positive and finite (got {})",
            cfg.step_init
        )));
    }
    let (dom, rng) = (a.domain_shape(), a.range_shape());
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a)?;
    let meas = pb.input(rng)?;
    let truth = pb.input(dom)?;
    let mut x = pb.fill(dom, 0.0)?;
    for k in 0..cfg.iterations {
        let ax = pb.apply(op, x)?;
        let r = pb.sub(ax, meas)?;
        let g = pb.adjoint(op, r)?;
        let s = pb.scalar_param(&format!("step{k}"), cfg.step_init)?;
        let sg = pb.scale(g, s)?;
        x = pb.sub(x, sg)?;
        if cfg.nonneg {
            x = pb.relu(x)?;
        }
    }
    pb.set_output(x)?;
    let l = pb.l2_loss(x, truth)?;
    pb.set_loss(l)?;
    pb.build()
}

/// Build a learned-FBP pipeline over `a` (see the module docs).
/// `pitch` is the detector column pitch in mm (the analytic ramp's
/// frequency scale); `window` apodizes the filter's initialization.
/// Inputs: `[sinogram (range), truth (domain)]`; params: `filter`
/// (half-spectrum, ramp-initialized), `weights` (per-sample, 1.0),
/// `gain` (scalar, π/nviews); output the reconstruction; L2 loss.
pub fn learned_fbp(
    a: Arc<dyn LinearOp>,
    pitch: f64,
    window: Window,
) -> Result<Pipeline, LeapError> {
    if !(pitch.is_finite() && pitch > 0.0) {
        return Err(LeapError::InvalidArgument(format!(
            "detector pitch must be positive and finite (got {pitch})"
        )));
    }
    let (dom, rng) = (a.domain_shape(), a.range_shape());
    let nviews = rng.0[0];
    let ncols = rng.0[2];
    if ncols < 2 {
        return Err(LeapError::InvalidArgument(format!(
            "learned fbp needs ≥ 2 detector columns (range {:?})",
            rng.0
        )));
    }
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a)?;
    let sino = pb.input(rng)?;
    let truth = pb.input(dom)?;
    let half = ramp_half_spectrum(ncols, pitch, window);
    let nh = next_pow2(2 * ncols) / 2 + 1;
    debug_assert_eq!(half.len(), nh);
    let w = pb.param("filter", crate::ops::Shape([nh, 1, 1]), half)?;
    let f = pb.filter_rows(sino, w)?;
    let m = pb.param("weights", rng, vec![1.0f32; rng.numel()])?;
    let wf = pb.mul(f, m)?;
    let bp = pb.adjoint(op, wf)?;
    let gain = pb.scalar_param("gain", (std::f64::consts::PI / nviews.max(1) as f64) as f32)?;
    let x = pb.scale(bp, gain)?;
    pb.set_output(x)?;
    let l = pb.l2_loss(x, truth)?;
    pb.set_loss(l)?;
    pb.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::ops::PlanOp;
    use crate::projector::{Model, Projector};
    use crate::util::rng::Rng;

    fn fan_op() -> Arc<dyn LinearOp> {
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Fan(FanBeam::standard(10, 16, 1.0, 60.0, 120.0));
        Arc::new(PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(2)))
    }

    #[test]
    fn unrolled_gd_first_step_matches_hand_gd() {
        // one unrolled step from x0 = 0 is x1 = relu(s·Aᵀb); verify the
        // tape agrees exactly with the hand-computed update
        let a = fan_op();
        let cfg = UnrollCfg { iterations: 1, step_init: 0.01, nonneg: true };
        let pipe = unrolled_gd(a.clone(), &cfg).unwrap();
        let mut rng = Rng::new(17);
        let mut b = vec![0.0f32; a.range_shape().numel()];
        rng.fill_uniform(&mut b, 0.0, 1.0);
        let truth = vec![0.0f32; a.domain_shape().numel()];
        let x1 = pipe.eval(&[&b, &truth]).unwrap();
        // hand: r = A·0 − b = −b; g = Aᵀr; x1 = relu(0 − s·g)
        let g = a.adjoint(&b.iter().map(|&v| -v).collect::<Vec<f32>>());
        let hand: Vec<f32> = g.iter().map(|&gi| (-(0.01 * gi)).max(0.0)).collect();
        assert_eq!(x1, hand, "unrolled step must match the hand-rolled update");
    }

    #[test]
    fn unrolled_gd_declares_k_steps() {
        let a = fan_op();
        let pipe =
            unrolled_gd(a, &UnrollCfg { iterations: 3, step_init: 0.01, nonneg: false }).unwrap();
        let names: Vec<&str> = pipe.params().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["step0", "step1", "step2"]);
        assert_eq!(pipe.input_shapes().len(), 2);
        assert!(pipe.loss_node().is_some() && pipe.output_node().is_some());
    }

    #[test]
    fn learned_fbp_iteration_zero_is_ramp_filtered_backprojection() {
        // with untouched params (ramp filter, unit weights, gain g) the
        // pipeline must equal g·Aᵀ(ramp_filter(b)) through RampFilterOp's
        // own response math (modulo the f32 cast of the response, which
        // response_from_half applies on both paths identically)
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 16, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        let a: Arc<dyn LinearOp> = Arc::new(PlanOp::new(&p));
        let pipe = learned_fbp(a.clone(), 1.0, Window::Hann).unwrap();
        let mut rng = Rng::new(23);
        let mut b = vec![0.0f32; a.range_shape().numel()];
        rng.fill_uniform(&mut b, 0.0, 1.0);
        let truth = vec![0.0f32; a.domain_shape().numel()];
        let x = pipe.eval(&[&b, &truth]).unwrap();
        // hand path with the identical f32-cast response
        let half = ramp_half_spectrum(16, 1.0, Window::Hann);
        let resp = crate::tape::response_from_half(&half, (half.len() - 1) * 2);
        let mut filtered = b.clone();
        crate::recon::filters::filter_rows(&mut filtered, 16, &resp);
        let bp = a.adjoint(&filtered);
        let gain = (std::f64::consts::PI / 8.0) as f32;
        let hand: Vec<f32> = bp.iter().map(|&v| gain * v).collect();
        assert_eq!(x, hand);
    }

    #[test]
    fn degenerate_configs_are_typed() {
        let a = fan_op();
        assert!(matches!(
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 0, step_init: 0.1, nonneg: false }),
            Err(LeapError::InvalidArgument(_))
        ));
        assert!(matches!(
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 1, step_init: 0.0, nonneg: false }),
            Err(LeapError::InvalidArgument(_))
        ));
        assert!(matches!(
            learned_fbp(a, -1.0, Window::Hann),
            Err(LeapError::InvalidArgument(_))
        ));
    }
}
