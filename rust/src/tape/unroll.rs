//! Unrolled-solver and learned-FBP pipeline builders.
//!
//! These are the canonical trainable-reconstruction shapes the tape
//! exists for (cf. learned primal-dual / LEARN-style unrolling and
//! learned-filter FBP in the TorchRadon/PYRO-NN ecosystems):
//!
//! * [`unrolled_gd`] — K iterations of gradient descent on the data-fit
//!   `½‖Ax − b‖²`, `x_{k+1} = [x_k − s_k·Aᵀ(A·x_k − b)]₊`, with one
//!   **learnable step size** `s_k` per iteration (this is SIRT-shaped:
//!   SIRT is exactly this update with fixed preconditioned steps).
//!   Supervised training loss `½‖x_K − truth‖²`.
//! * [`unrolled_cnn`] — the ItNet/learned-proximal shape: the same
//!   data-fit step, then a small per-iteration CNN correction,
//!   `x_{k+1} = [x̃_k + CNN_k(x̃_k)]₊` with
//!   `x̃_k = x_k − s_k·Aᵀ(A·x_k − b)` and `CNN_k` a two-layer
//!   conv→relu→conv residual block (Conv2d on single-slice domains,
//!   Conv3d otherwise). The second conv is **zero-initialized**, so an
//!   untrained pipeline is *exactly* projected gradient descent —
//!   training can only move away from a known-good solver.
//! * [`learned_fbp`] — FBP with every hand-designed ingredient made
//!   trainable: `x̂ = g · Aᵀ( m ⊙ filter_w(b) )` with a learnable
//!   half-spectrum filter `w` (initialized to the analytic apodized
//!   ramp, so iteration 0 *is* classical FBP's filter), learnable
//!   per-sample sinogram weights `m` (initialized to 1 — room for the
//!   fan-beam cosine weighting FBP hard-codes), and a learnable scalar
//!   gain `g`. Supervised L2 loss against the truth volume.
//!
//! All declare inputs `[measurements, truth]` in that order and mark
//! the reconstruction as the pipeline output, so after training
//! [`super::Pipeline::eval`] reconstructs new data with the learned
//! parameters (the truth slot is only read by the loss — feed zeros at
//! inference, or rebuild without the loss).

use std::sync::Arc;

use crate::api::LeapError;
use crate::nn;
use crate::ops::{LinearOp, Shape};
use crate::recon::filters::ramp_half_spectrum;
use crate::recon::Window;
use crate::util::fft::next_pow2;

use super::{Pipeline, PipelineBuilder};

/// Configuration for [`unrolled_gd`].
#[derive(Clone, Copy, Debug)]
pub struct UnrollCfg {
    /// K, the number of unrolled gradient steps (≥ 1).
    pub iterations: usize,
    /// Initial value of every learnable step size (a stable choice is
    /// `1/L` with `L` from
    /// [`crate::recon::fista_tv::power_iter_lipschitz_op`]).
    pub step_init: f32,
    /// Apply a non-negativity relu after each update (projected GD).
    pub nonneg: bool,
}

/// Build a K-step unrolled gradient-descent pipeline over `a` (see the
/// module docs). Inputs: `[measurements (range), truth (domain)]`;
/// params: `step0..step{K-1}`; output `x_K`; loss `½‖x_K − truth‖²`.
pub fn unrolled_gd(a: Arc<dyn LinearOp>, cfg: &UnrollCfg) -> Result<Pipeline, LeapError> {
    if cfg.iterations == 0 {
        return Err(LeapError::InvalidArgument("unroll needs at least one iteration".into()));
    }
    if !(cfg.step_init.is_finite() && cfg.step_init > 0.0) {
        return Err(LeapError::InvalidArgument(format!(
            "step init must be positive and finite (got {})",
            cfg.step_init
        )));
    }
    let (dom, rng) = (a.domain_shape(), a.range_shape());
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a)?;
    let meas = pb.input(rng)?;
    let truth = pb.input(dom)?;
    let mut x = pb.fill(dom, 0.0)?;
    for k in 0..cfg.iterations {
        let ax = pb.apply(op, x)?;
        let r = pb.sub(ax, meas)?;
        let g = pb.adjoint(op, r)?;
        let s = pb.scalar_param(&format!("step{k}"), cfg.step_init)?;
        let sg = pb.scale(g, s)?;
        x = pb.sub(x, sg)?;
        if cfg.nonneg {
            x = pb.relu(x)?;
        }
    }
    pb.set_output(x)?;
    let l = pb.l2_loss(x, truth)?;
    pb.set_loss(l)?;
    pb.build()
}

/// Configuration for [`unrolled_cnn`].
#[derive(Clone, Copy, Debug)]
pub struct UnrollCnnCfg {
    /// K, the number of unrolled iterations (≥ 1).
    pub iterations: usize,
    /// Initial value of every learnable step size (see
    /// [`UnrollCfg::step_init`]).
    pub step_init: f32,
    /// Hidden channels of each per-iteration CNN block (≥ 1).
    pub channels: usize,
    /// Convolution kernel size — odd, ≥ 1 (same padding).
    pub ksize: usize,
    /// Seed for the deterministic He-uniform initialization of the
    /// first conv's weights (the second conv starts at zero).
    pub seed: u64,
}

/// Build a K-step unrolled CNN-regularized solver over `a` (see the
/// module docs). Inputs: `[measurements (range), truth (domain)]`;
/// params per iteration `k`: `step{k}`, `conv{k}a_w`/`conv{k}a_b`
/// (lift to `channels`), `conv{k}b_w`/`conv{k}b_b` (project back,
/// zero-initialized); output `x_K`; loss `½‖x_K − truth‖²`.
pub fn unrolled_cnn(a: Arc<dyn LinearOp>, cfg: &UnrollCnnCfg) -> Result<Pipeline, LeapError> {
    if cfg.iterations == 0 {
        return Err(LeapError::InvalidArgument("unroll needs at least one iteration".into()));
    }
    if !(cfg.step_init.is_finite() && cfg.step_init > 0.0) {
        return Err(LeapError::InvalidArgument(format!(
            "step init must be positive and finite (got {})",
            cfg.step_init
        )));
    }
    if cfg.channels == 0 {
        return Err(LeapError::InvalidArgument("cnn needs ≥ 1 hidden channel".into()));
    }
    if cfg.ksize == 0 || cfg.ksize % 2 == 0 {
        return Err(LeapError::InvalidArgument(format!(
            "kernel size must be odd and ≥ 1 (got {})",
            cfg.ksize
        )));
    }
    let (dom, rng) = (a.domain_shape(), a.range_shape());
    let nz = dom.0[2];
    let (k, c) = (cfg.ksize, cfg.channels);
    let taps = if nz == 1 { k.checked_mul(k) } else { k.checked_mul(k).and_then(|t| t.checked_mul(k)) }
        .ok_or_else(|| LeapError::InvalidArgument(format!("kernel size {k} overflows")))?;
    let wlen = taps.checked_mul(c).ok_or_else(|| {
        LeapError::InvalidArgument(format!("conv weight count {taps}·{c} overflows"))
    })?;
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a)?;
    let meas = pb.input(rng)?;
    let truth = pb.input(dom)?;
    let mut x = pb.fill(dom, 0.0)?;
    for it in 0..cfg.iterations {
        // data-fit gradient step (identical to unrolled_gd)
        let ax = pb.apply(op, x)?;
        let r = pb.sub(ax, meas)?;
        let g = pb.adjoint(op, r)?;
        let s = pb.scalar_param(&format!("step{it}"), cfg.step_init)?;
        let sg = pb.scale(g, s)?;
        let xg = pb.sub(x, sg)?;
        // CNN correction: lift to c channels → relu → project back.
        // The projection starts at zero, so before training the block
        // is the identity residual and x_{k+1} = relu(x̃_k).
        let w1 = pb.param(
            &format!("conv{it}a_w"),
            Shape([taps, 1, c]),
            nn::conv_init(cfg.seed.wrapping_add(it as u64), taps, 1, c),
        )?;
        let b1 = pb.param(&format!("conv{it}a_b"), Shape([c, 1, 1]), vec![0.0f32; c])?;
        let w2 = pb.param(&format!("conv{it}b_w"), Shape([taps, c, 1]), vec![0.0f32; wlen])?;
        let b2 = pb.param(&format!("conv{it}b_b"), Shape([1, 1, 1]), vec![0.0f32; 1])?;
        let corr = if nz == 1 {
            let h = pb.conv2d(xg, w1, b1)?;
            let h = pb.relu(h)?;
            pb.conv2d(h, w2, b2)?
        } else {
            let h = pb.conv3d(xg, w1, b1, 1)?;
            let h = pb.relu(h)?;
            pb.conv3d(h, w2, b2, c)?
        };
        let xr = pb.residual(xg, corr)?;
        x = pb.relu(xr)?;
    }
    pb.set_output(x)?;
    let l = pb.l2_loss(x, truth)?;
    pb.set_loss(l)?;
    pb.build()
}

/// Build a learned-FBP pipeline over `a` (see the module docs).
/// `pitch` is the detector column pitch in mm (the analytic ramp's
/// frequency scale); `window` apodizes the filter's initialization.
/// Inputs: `[sinogram (range), truth (domain)]`; params: `filter`
/// (half-spectrum, ramp-initialized), `weights` (per-sample, 1.0),
/// `gain` (scalar, π/nviews); output the reconstruction; L2 loss.
pub fn learned_fbp(
    a: Arc<dyn LinearOp>,
    pitch: f64,
    window: Window,
) -> Result<Pipeline, LeapError> {
    if !(pitch.is_finite() && pitch > 0.0) {
        return Err(LeapError::InvalidArgument(format!(
            "detector pitch must be positive and finite (got {pitch})"
        )));
    }
    let (dom, rng) = (a.domain_shape(), a.range_shape());
    let nviews = rng.0[0];
    let ncols = rng.0[2];
    if ncols < 2 {
        return Err(LeapError::InvalidArgument(format!(
            "learned fbp needs ≥ 2 detector columns (range {:?})",
            rng.0
        )));
    }
    let mut pb = PipelineBuilder::new();
    let op = pb.op("scan", a)?;
    let sino = pb.input(rng)?;
    let truth = pb.input(dom)?;
    let half = ramp_half_spectrum(ncols, pitch, window);
    let nh = next_pow2(2 * ncols) / 2 + 1;
    debug_assert_eq!(half.len(), nh);
    let w = pb.param("filter", crate::ops::Shape([nh, 1, 1]), half)?;
    let f = pb.filter_rows(sino, w)?;
    let m = pb.param("weights", rng, vec![1.0f32; rng.numel()])?;
    let wf = pb.mul(f, m)?;
    let bp = pb.adjoint(op, wf)?;
    let gain = pb.scalar_param("gain", (std::f64::consts::PI / nviews.max(1) as f64) as f32)?;
    let x = pb.scale(bp, gain)?;
    pb.set_output(x)?;
    let l = pb.l2_loss(x, truth)?;
    pb.set_loss(l)?;
    pb.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{FanBeam, Geometry, ParallelBeam, VolumeGeometry};
    use crate::ops::PlanOp;
    use crate::projector::{Model, Projector};
    use crate::util::rng::Rng;

    fn fan_op() -> Arc<dyn LinearOp> {
        let vg = VolumeGeometry::slice2d(12, 12, 1.0);
        let g = Geometry::Fan(FanBeam::standard(10, 16, 1.0, 60.0, 120.0));
        Arc::new(PlanOp::new(&Projector::new(g, vg, Model::SF).with_threads(2)))
    }

    #[test]
    fn unrolled_gd_first_step_matches_hand_gd() {
        // one unrolled step from x0 = 0 is x1 = relu(s·Aᵀb); verify the
        // tape agrees exactly with the hand-computed update
        let a = fan_op();
        let cfg = UnrollCfg { iterations: 1, step_init: 0.01, nonneg: true };
        let pipe = unrolled_gd(a.clone(), &cfg).unwrap();
        let mut rng = Rng::new(17);
        let mut b = vec![0.0f32; a.range_shape().numel()];
        rng.fill_uniform(&mut b, 0.0, 1.0);
        let truth = vec![0.0f32; a.domain_shape().numel()];
        let x1 = pipe.eval(&[&b, &truth]).unwrap();
        // hand: r = A·0 − b = −b; g = Aᵀr; x1 = relu(0 − s·g)
        let g = a.adjoint(&b.iter().map(|&v| -v).collect::<Vec<f32>>());
        let hand: Vec<f32> = g.iter().map(|&gi| (-(0.01 * gi)).max(0.0)).collect();
        assert_eq!(x1, hand, "unrolled step must match the hand-rolled update");
    }

    #[test]
    fn unrolled_gd_declares_k_steps() {
        let a = fan_op();
        let pipe =
            unrolled_gd(a, &UnrollCfg { iterations: 3, step_init: 0.01, nonneg: false }).unwrap();
        let names: Vec<&str> = pipe.params().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["step0", "step1", "step2"]);
        assert_eq!(pipe.input_shapes().len(), 2);
        assert!(pipe.loss_node().is_some() && pipe.output_node().is_some());
    }

    #[test]
    fn learned_fbp_iteration_zero_is_ramp_filtered_backprojection() {
        // with untouched params (ramp filter, unit weights, gain g) the
        // pipeline must equal g·Aᵀ(ramp_filter(b)) through RampFilterOp's
        // own response math (modulo the f32 cast of the response, which
        // response_from_half applies on both paths identically)
        let vg = VolumeGeometry::slice2d(10, 10, 1.0);
        let g = Geometry::Parallel(ParallelBeam::standard_2d(8, 16, 1.0));
        let p = Projector::new(g, vg, Model::SF).with_threads(2);
        let a: Arc<dyn LinearOp> = Arc::new(PlanOp::new(&p));
        let pipe = learned_fbp(a.clone(), 1.0, Window::Hann).unwrap();
        let mut rng = Rng::new(23);
        let mut b = vec![0.0f32; a.range_shape().numel()];
        rng.fill_uniform(&mut b, 0.0, 1.0);
        let truth = vec![0.0f32; a.domain_shape().numel()];
        let x = pipe.eval(&[&b, &truth]).unwrap();
        // hand path with the identical f32-cast response
        let half = ramp_half_spectrum(16, 1.0, Window::Hann);
        let resp = crate::tape::response_from_half(&half, (half.len() - 1) * 2);
        let mut filtered = b.clone();
        crate::recon::filters::filter_rows(&mut filtered, 16, &resp);
        let bp = a.adjoint(&filtered);
        let gain = (std::f64::consts::PI / 8.0) as f32;
        let hand: Vec<f32> = bp.iter().map(|&v| gain * v).collect();
        assert_eq!(x, hand);
    }

    #[test]
    fn untrained_unrolled_cnn_is_exactly_projected_gd() {
        // the second conv of every block is zero-initialized, so an
        // untrained unrolled_cnn must reproduce unrolled_gd (nonneg)
        // bit for bit — training starts from a known-good solver
        let a = fan_op();
        let cnn = unrolled_cnn(
            a.clone(),
            &UnrollCnnCfg { iterations: 2, step_init: 0.01, channels: 4, ksize: 3, seed: 3 },
        )
        .unwrap();
        let gd = unrolled_gd(
            a.clone(),
            &UnrollCfg { iterations: 2, step_init: 0.01, nonneg: true },
        )
        .unwrap();
        let mut rng = Rng::new(91);
        let mut b = vec![0.0f32; a.range_shape().numel()];
        rng.fill_uniform(&mut b, 0.0, 1.0);
        let truth = vec![0.0f32; a.domain_shape().numel()];
        let xc = cnn.eval(&[&b, &truth]).unwrap();
        let xg = gd.eval(&[&b, &truth]).unwrap();
        assert_eq!(xc, xg);
    }

    #[test]
    fn unrolled_cnn_declares_params_and_handles_3d_domains() {
        let a = fan_op();
        let pipe = unrolled_cnn(
            a,
            &UnrollCnnCfg { iterations: 1, step_init: 0.01, channels: 2, ksize: 3, seed: 1 },
        )
        .unwrap();
        let names: Vec<&str> = pipe.params().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["step0", "conv0a_w", "conv0a_b", "conv0b_w", "conv0b_b"]);
        // conv2d path: k²·1·2 weights for the lift
        assert_eq!(pipe.params()[1].shape.numel(), 9 * 2);
        // a 3-D domain takes the conv3d path (k³ taps) and still builds
        // and evaluates
        let vg = crate::geometry::VolumeGeometry::cube(6, 1.0);
        let cone = crate::geometry::ConeBeam::standard(4, 6, 8, 1.5, 1.5, 50.0, 100.0);
        let a3: Arc<dyn LinearOp> = Arc::new(PlanOp::new(
            &Projector::new(crate::geometry::Geometry::Cone(cone), vg, Model::SF).with_threads(2),
        ));
        let pipe3 = unrolled_cnn(
            a3.clone(),
            &UnrollCnnCfg { iterations: 1, step_init: 0.01, channels: 2, ksize: 3, seed: 1 },
        )
        .unwrap();
        assert_eq!(pipe3.params()[1].shape.numel(), 27 * 2);
        let b = vec![0.5f32; a3.range_shape().numel()];
        let t = vec![0.0f32; a3.domain_shape().numel()];
        let x = pipe3.eval(&[&b, &t]).unwrap();
        assert_eq!(x.len(), a3.domain_shape().numel());
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn degenerate_configs_are_typed() {
        let a = fan_op();
        assert!(matches!(
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 0, step_init: 0.1, nonneg: false }),
            Err(LeapError::InvalidArgument(_))
        ));
        assert!(matches!(
            unrolled_gd(a.clone(), &UnrollCfg { iterations: 1, step_init: 0.0, nonneg: false }),
            Err(LeapError::InvalidArgument(_))
        ));
        assert!(matches!(
            learned_fbp(a.clone(), -1.0, Window::Hann),
            Err(LeapError::InvalidArgument(_))
        ));
        let good = UnrollCnnCfg { iterations: 1, step_init: 0.01, channels: 2, ksize: 3, seed: 0 };
        for bad in [
            UnrollCnnCfg { iterations: 0, ..good },
            UnrollCnnCfg { step_init: -1.0, ..good },
            UnrollCnnCfg { channels: 0, ..good },
            UnrollCnnCfg { ksize: 2, ..good }, // even kernels have no center
            UnrollCnnCfg { ksize: 0, ..good },
        ] {
            assert!(
                matches!(unrolled_cnn(a.clone(), &bad), Err(LeapError::InvalidArgument(_))),
                "{bad:?}"
            );
        }
    }
}
